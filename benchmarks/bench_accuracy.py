"""Accuracy benchmarks validating the paper's theoretical claims.

T1  edge-frequency error bound (Thm 1): with w = ceil(e/sqrt(eps)),
    d = ceil(ln 1/delta): Pr[f̃ - f > eps*n] <= delta, and f̃ >= f always.
T2  point-query bound (Lemma 5.2): w = ceil(e/eps), d = ceil(ln 1/delta):
    Pr[f̃_v - f_v > eps*||f||_1] <= delta.
T3  gLava vs CountMin vs gSketch vs CountSketch at EQUAL SPACE (edge ARE).
T4  square vs non-square (Section 6.1.2) at equal space.
T5  conservative update (beyond-paper) accuracy gain.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import exact_edge_counts, record, time_fn, zipf_stream
from repro.core import (
    CountMin,
    CountSketch,
    GLavaSketch,
    GSketch,
    NodeCountMin,
    SketchConfig,
    queries,
)
from repro.core.hashing import mix_keys

N_NODES = 5000
N_EDGES = 60_000


def _stream():
    s = zipf_stream(N_NODES, N_EDGES)
    return (
        jnp.asarray(s["src"], jnp.uint32),
        jnp.asarray(s["dst"], jnp.uint32),
        jnp.asarray(s["weight"]),
    )


def bench_theorem1_edge_bound():
    """Thm 1 is a PER-QUERY guarantee: Pr[f̃ - f > ε·n] ≤ δ.  The theorem
    statement writes n = "number of nodes", but its own proof bounds
    E[collisions] by (ε'/e)²·Σ f_e(l,m) — i.e. the TOTAL STREAM WEIGHT ‖f‖₁,
    not |V|.  We validate both readings; the literal-|V| one fails whenever
    ‖f‖₁ ≫ |V| (a soundness finding reported in EXPERIMENTS.md §Paper-claims)."""
    src, dst, w = _stream()
    exact = exact_edge_counts(src, dst, w)
    n = N_NODES
    total = float(jnp.sum(w))
    for eps, delta in [(0.01, 0.05), (0.001, 0.05)]:
        cfg = SketchConfig.for_error(eps, delta)
        trials = 12
        viol_nodes = []
        viol_mass = []
        overest_ok = True
        for t in range(trials):
            sk = GLavaSketch.empty(cfg, jax.random.key(t)).update(src, dst, w)
            pairs = list(exact.items())[:512]
            qs = jnp.asarray([p[0][0] for p in pairs], jnp.uint32)
            qd = jnp.asarray([p[0][1] for p in pairs], jnp.uint32)
            ex = np.asarray([p[1] for p in pairs])
            est = np.asarray(queries.edge_query(sk, qs, qd))
            overest_ok &= bool(np.all(est >= ex - 1e-4))
            err = est - ex
            viol_nodes.append(np.mean(err > eps * n))
            viol_mass.append(np.mean(err > eps * total))
        record(
            f"thm1_edge_bound_eps{eps}",
            0.0,
            w=cfg.width_rows,
            d=cfg.depth,
            delta=delta,
            per_query_violation_literal_nV=round(float(np.mean(viol_nodes)), 4),
            literal_nV_holds=bool(np.mean(viol_nodes) <= delta),
            per_query_violation_streammass=round(float(np.mean(viol_mass)), 4),
            streammass_holds=bool(np.mean(viol_mass) <= delta),
            overestimate_invariant=overest_ok,
        )


def bench_lemma52_point_bound():
    src, dst, w = _stream()
    exact_in = np.zeros(N_NODES)
    for d_, wt in zip(np.asarray(dst), np.asarray(w)):
        exact_in[int(d_)] += float(wt)
    total = float(jnp.sum(w))
    eps, delta = 0.005, 0.05
    w_ = int(np.ceil(np.e / eps))
    d_ = max(1, int(np.ceil(np.log(1 / delta))))
    cfg = SketchConfig(depth=d_, width_rows=w_, width_cols=w_)
    trials = 10
    rates = []
    for t in range(trials):
        sk = GLavaSketch.empty(cfg, jax.random.key(100 + t)).update(src, dst, w)
        keys = jnp.arange(0, 2048, dtype=jnp.uint32)
        est = np.asarray(queries.node_in_flow(sk, keys))
        ex = exact_in[:2048]
        # Lemma 5.2 is the CountMin point-query guarantee — per query,
        # error scale ε·‖f‖₁
        rates.append(np.mean(est - ex > eps * total))
    record(
        "lemma52_point_bound",
        0.0,
        w=w_,
        d=d_,
        per_query_violation=round(float(np.mean(rates)), 4),
        delta=delta,
        bound_holds=bool(np.mean(rates) <= delta),
    )


def bench_equal_space_comparison():
    """gLava vs the stream-sketch baselines at equal space (edge ARE on the
    500 hottest pairs)."""
    src, dst, w = _stream()
    exact = exact_edge_counts(src, dst, w)
    hot = sorted(exact.items(), key=lambda kv: -kv[1])[:500]
    qs = jnp.asarray([p[0][0] for p in hot], jnp.uint32)
    qd = jnp.asarray([p[0][1] for p in hot], jnp.uint32)
    ex = np.asarray([p[1] for p in hot])

    depth = 4
    glava_w = 512                      # cells = 4 * 512 * 512 = 1.05 M
    cm_w = glava_w * glava_w           # equal cells for the 1-D sketches

    def are(est):
        return float(np.mean(np.abs(est - ex) / ex))

    sk = GLavaSketch.empty(
        SketchConfig(depth, glava_w, glava_w), jax.random.key(0)
    ).update(src, dst, w)
    record("equal_space_glava", 0.0, cells=depth * glava_w**2,
           are=round(are(np.asarray(queries.edge_query(sk, qs, qd))), 5))

    cm = CountMin.empty(depth, cm_w, jax.random.key(1)).update(src, dst, w)
    record("equal_space_countmin", 0.0, cells=depth * cm_w,
           are=round(are(np.asarray(cm.edge_query(qs, qd))), 5))

    gs = GSketch.from_sample(
        depth, cm_w, 8, np.asarray(src[:5000]), jax.random.key(2)
    ).update(src, dst, w)
    record("equal_space_gsketch", 0.0, cells=depth * cm_w,
           are=round(are(np.asarray(gs.edge_query(qs, qd))), 5))

    cs = CountSketch.empty(depth, cm_w, jax.random.key(3)).update(
        mix_keys(src, dst), w
    )
    record("equal_space_countsketch", 0.0, cells=depth * cm_w,
           are=round(are(np.asarray(cs.query(mix_keys(qs, qd)))), 5))

    # the capability gap (the paper's THESIS): point/path queries at equal
    # space — CountMin supports them only via a second sketch; gLava needs no
    # extra state.
    ncm = NodeCountMin.empty(depth, cm_w, jax.random.key(4)).update(src, dst, w)
    keys = jnp.arange(512, dtype=jnp.uint32)
    exact_in = np.zeros(512)
    for d_, wt in zip(np.asarray(dst), np.asarray(w)):
        if int(d_) < 512:
            exact_in[int(d_)] += float(wt)
    g_in = np.asarray(queries.node_in_flow(sk, keys))
    n_in = np.asarray(ncm.in_flow(keys))
    denom = np.maximum(exact_in, 1.0)
    record("pointquery_glava_no_extra_state", 0.0,
           mae=round(float(np.mean(np.abs(g_in - exact_in))), 3))
    record("pointquery_nodecountmin_extra_sketch", 0.0,
           mae=round(float(np.mean(np.abs(n_in - exact_in))), 3),
           note="needs dedicated 2nd+3rd sketches; no path/subgraph support")


def bench_nonsquare():
    """Section 6.1.2: same space, different shapes.  The paper's motivating
    pathology is row saturation — all edges (a, *) land in ONE row — so the
    workload here has extreme out-degree skew (10 hub sources).  Also
    evaluates the paper's actual proposal: an ENSEMBLE of different shapes
    (n×n, 2n×n/2, n/2×2n, ...) min-merged."""
    rng = np.random.default_rng(11)
    hubs = rng.integers(0, 10, 40_000)             # 10 hot sources
    tails = rng.integers(0, N_NODES, 40_000)
    src = jnp.asarray(np.concatenate([hubs, tails]).astype(np.uint32))
    dst = jnp.asarray(
        np.concatenate([rng.integers(0, N_NODES, 40_000), rng.integers(0, N_NODES, 40_000)]).astype(np.uint32)
    )
    w = jnp.ones(80_000, jnp.float32)
    exact = exact_edge_counts(src, dst, w)
    hot = sorted(exact.items(), key=lambda kv: -kv[1])[:500]
    qs = jnp.asarray([p[0][0] for p in hot], jnp.uint32)
    qd = jnp.asarray([p[0][1] for p in hot], jnp.uint32)
    ex = np.asarray([p[1] for p in hot])

    def are_of(sk):
        est = np.asarray(queries.edge_query(sk, qs, qd))
        return float(np.mean(np.abs(est - ex) / ex))

    shapes = [(512, 512), (1024, 256), (256, 1024), (2048, 128)]
    for wr, wc in shapes:
        errs = []
        for t in range(5):
            sk = GLavaSketch.empty(
                SketchConfig(4, wr, wc), jax.random.key(40 + t)
            ).update(src, dst, w)
            errs.append(are_of(sk))
        record(
            f"nonsquare_{wr}x{wc}", 0.0, cells=4 * wr * wc,
            are=round(float(np.mean(errs)), 5),
        )
    # mixed-shape ensemble (one sketch per shape, Γ = min across all)
    errs = []
    for t in range(5):
        ests = []
        for i, (wr, wc) in enumerate(shapes):
            sk = GLavaSketch.empty(
                SketchConfig(1, wr, wc), jax.random.key(60 + 10 * t + i)
            ).update(src, dst, w)
            ests.append(np.asarray(queries.edge_query(sk, qs, qd)))
        est = np.min(np.stack(ests), axis=0)
        errs.append(float(np.mean(np.abs(est - ex) / ex)))
    record(
        "nonsquare_mixed_ensemble", 0.0, cells=sum(wr * wc for wr, wc in shapes),
        are=round(float(np.mean(errs)), 5),
        note="paper's d-shapes heuristic: n*n, 2n*n/2, n/2*2n, 4n*n/4",
    )


def bench_conservative_update():
    src, dst, w = _stream()
    exact = exact_edge_counts(src, dst, w)
    hot = sorted(exact.items(), key=lambda kv: -kv[1])[:300]
    qs = jnp.asarray([p[0][0] for p in hot], jnp.uint32)
    qd = jnp.asarray([p[0][1] for p in hot], jnp.uint32)
    ex = np.asarray([p[1] for p in hot])
    cfg = SketchConfig(4, 256, 256)
    # sequential CU is slow; subsample the stream
    sub = 20_000
    vanilla = GLavaSketch.empty(cfg, jax.random.key(5)).update(
        src[:sub], dst[:sub], w[:sub]
    )
    cu = GLavaSketch.empty(cfg, jax.random.key(5)).update_conservative(
        src[:sub], dst[:sub], w[:sub]
    )
    exact_sub = exact_edge_counts(src[:sub], dst[:sub], w[:sub])
    ex_s = np.asarray([exact_sub.get(p[0], 0.0) for p in hot])
    keep = ex_s > 0
    v_est = np.asarray(queries.edge_query(vanilla, qs, qd))[keep]
    c_est = np.asarray(queries.edge_query(cu, qs, qd))[keep]
    record(
        "conservative_update_vs_vanilla", 0.0,
        vanilla_are=round(float(np.mean(np.abs(v_est - ex_s[keep]) / ex_s[keep])), 5),
        cu_are=round(float(np.mean(np.abs(c_est - ex_s[keep]) / ex_s[keep])), 5),
    )


def run():
    bench_theorem1_edge_bound()
    bench_lemma52_point_bound()
    bench_equal_space_comparison()
    bench_nonsquare()
    bench_conservative_update()
