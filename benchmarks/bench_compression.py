"""Sketched gradient all-reduce quality (beyond-paper distributed-opt trick):
cosine similarity of the decompressed update vs the true gradient, wire-byte
savings, and convergence parity on a toy problem."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record
from repro.train import compression as comp
from repro.train import optimizer as opt_mod
from repro.train.trainer import compressed_data_parallel_step


def run():
    rng = np.random.default_rng(0)
    n = 1 << 20

    for width, topk in [(1 << 13, 2048), (1 << 15, 8192)]:
        ccfg = comp.CompressorConfig(depth=5, width=width, top_k=topk, momentum=0.0)
        st = comp.init_compressor(ccfg, n, jax.random.key(0))
        # heavy-tailed gradient (realistic for LMs)
        g = jnp.asarray(rng.standard_t(3, n) * (rng.random(n) < 0.1), jnp.float32)
        up, st = comp.roundtrip(st, g)
        cos = float(
            jnp.sum(up * g)
            / jnp.maximum(jnp.linalg.norm(up) * jnp.linalg.norm(g), 1e-9)
        )
        ratio = n / (ccfg.depth * width)
        record(
            f"compress_cosine_w{width}", 0.0,
            cosine=round(cos, 4),
            compression_x=round(ratio, 1),
            wire_bytes_saved_pct=round(100 * (1 - 1 / ratio), 1),
        )

    # convergence parity: compressed vs exact — SGD+momentum as in FetchSGD
    # (sketch-noise + Adam's per-coordinate normalization interact badly;
    # the FetchSGD recipe is momentum-SGD — recorded as a finding)
    w_true = rng.normal(0, 1, (32, 8)).astype(np.float32)
    lr, mu = 5e-2, 0.9

    def loss_fn(params, batch):
        return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2), {}

    def batches():
        r = np.random.default_rng(1)
        while True:
            x = r.normal(0, 1, (64, 32)).astype(np.float32)
            yield {"x": jnp.asarray(x), "y": jnp.asarray(x @ w_true)}

    ccfg = comp.CompressorConfig(depth=5, width=128, top_k=64, momentum=0.0)

    def run_variant(compress: bool):
        params = {"w": jnp.zeros((32, 8), jnp.float32)}
        vel = jnp.zeros(256, jnp.float32)
        cstate = comp.init_compressor(ccfg, 256, jax.random.key(1))

        @jax.jit
        def _step(params, vel, cstate, batch):
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            flat, spec = comp.flatten_grads(grads)
            if compress:
                flat, cstate2 = comp.roundtrip(cstate, flat)
            else:
                cstate2 = cstate
            v = mu * vel + flat
            upd = comp.unflatten_grads(v, spec)
            params = jax.tree.map(lambda p, u: p - lr * u.astype(p.dtype), params, upd)
            return params, v, cstate2, loss

        bs = batches()
        losses = []
        for _ in range(150):
            params, vel, cstate, loss = _step(params, vel, cstate, next(bs))
            losses.append(float(loss))
        return losses

    exact = run_variant(False)
    sketched = run_variant(True)
    record(
        "compress_convergence_parity", 0.0,
        exact_final=round(exact[-1], 4),
        sketched_final=round(sketched[-1], 4),
        compression_x=round(256 / (ccfg.depth * ccfg.width), 2),
        both_converged=bool(
            exact[-1] < 0.1 * exact[0] and sketched[-1] < 0.1 * sketched[0]
        ),
    )


if __name__ == "__main__":
    run()
