"""Compiled-cost rows — the costlint measurements beside the wall-clock
rows, so the trajectory files track WHAT the compiler was asked to do
(flops/edge, bytes/edge, fitted exponents) alongside how fast it ran.
A cheap subset of the cost registry: one ingest boundary, one
register-served family, one closure refresh — enough to spot a scaling
regression in the history without re-paying the full 37-compile sweep.
"""
from __future__ import annotations

from benchmarks.common import record

_SUBSET = (
    "cost.ingest.jit_boundary",
    "cost.query.in_flow",
    "cost.query.closure_refresh",
)


def run():
    from repro.analysis.contracts import COST_ENTRY_POINTS
    from repro.analysis.costlint import measure_entry

    for ep in COST_ENTRY_POINTS:
        if ep.name not in _SUBSET:
            continue
        m = measure_entry(ep)
        derived = {
            f"exp_{f['axis']}": f["measured"] for f in m["axes"]
        }
        derived["peak_bytes"] = m["peak_bytes"]
        if "bytes_per_edge" in m:
            derived["bytes_per_edge"] = round(m["bytes_per_edge"], 1)
        record(ep.name.replace(".", "_"), 0.0, **derived)
