"""Ingest-path benchmarks (paper Section 3.2 constraints): µs/edge for the
paper-faithful scalar path, the vectorized scatter, the one-hot MXU
formulation, and the Pallas kernel (interpret mode on this host — the Pallas
number is a CORRECTNESS artifact here; its perf claim is the roofline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, time_fn
from repro.core import GLavaSketch, SketchConfig


def run():
    cfg = SketchConfig(depth=4, width_rows=1024, width_cols=1024)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    b = 32768
    src = jnp.asarray(rng.integers(0, 1 << 20, b), jnp.uint32)
    dst = jnp.asarray(rng.integers(0, 1 << 20, b), jnp.uint32)
    w = jnp.asarray(rng.integers(1, 5, b), jnp.float32)

    seq = jax.jit(lambda s, a, d_, w_: s.update_sequential(a[:256], d_[:256], w_[:256]))
    us = time_fn(seq, sk, src, dst, w, iters=3)
    record("ingest_sequential_paper_literal", us / 256, batch=256)

    scat = jax.jit(lambda s, a, d_, w_: s.update(a, d_, w_, backend="scatter"))
    us = time_fn(scat, sk, src, dst, w)
    record("ingest_scatter_vectorized", us / b, batch=b)

    oneh = jax.jit(lambda s, a, d_, w_: s.update(a, d_, w_, backend="onehot"))
    us = time_fn(oneh, sk, src, dst, w, iters=3)
    record("ingest_onehot_mxu_formulation", us / b, batch=b)

    pal = jax.jit(lambda s, a, d_, w_: s.update(a[:4096], d_[:4096], w_[:4096], backend="pallas"))
    us = time_fn(pal, sk, src, dst, w, iters=2)
    record("ingest_pallas_interpret", us / 4096, batch=4096,
           note="interpret-mode correctness path on CPU host")

    # O(1)-per-edge invariant: per-edge cost must not grow with sketch fill
    filled = sk.update(src, dst, w)
    us_empty = time_fn(scat, sk, src, dst, w)
    us_full = time_fn(scat, filled, src, dst, w)
    record("ingest_O1_invariance", us_full / b,
           empty_us_per_edge=round(us_empty / b, 3),
           ratio=round(us_full / max(us_empty, 1e-9), 2))

    # linear-time construction: total time ~ linear in stream length
    t1 = time_fn(scat, sk, src[: b // 2], dst[: b // 2], w[: b // 2])
    t2 = time_fn(scat, sk, src, dst, w)
    record("construction_linearity", t2 / b, half_over_full=round(t1 / t2, 2))


if __name__ == "__main__":
    run()
