"""Ingest-path benchmarks (paper Section 3.2 constraints): µs/edge and
edges/sec for the paper-faithful scalar path, every IngestEngine backend
(scatter / onehot / pallas — Pallas runs in interpret mode on CPU hosts, so
its number here is a CORRECTNESS artifact; its perf claim is the roofline),
and the heavy-tail fast path (host pre-aggregation feeding the donated
session boundary; fused one-pass kernel on TPU hosts).

Every row separates COMPILE from STEADY STATE: the first call is timed on
its own (``compile_ms``) and the recorded µs/edge is the median of warm
calls only — mixing the two understated the scatter path and buried the
onehot regression the fast path fixes.

CLI (the backend-sweep mode):

    python -m benchmarks.bench_ingest --backend scatter
    python -m benchmarks.bench_ingest --backend all --batch 65536
    python -m benchmarks.bench_ingest --assert-preagg-win --batch 8192
    python -m benchmarks.bench_ingest --tenants 1 64 1024
    python -m benchmarks.bench_ingest --wal

``--assert-preagg-win`` exits non-zero unless the pre-aggregated session
path beats the plain scatter session on a zipf(1.5) batch — the CI smoke
gate for the fast path.

``run()`` (the trajectory entry point) sweeps all backends plus the
pre-aggregation duplicate-rate grid, so results/benchmarks.json records
edges/sec per (backend, preagg, stream) from every run.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, time_fn, zipf_stream
from repro.api.stream import GraphStream
from repro.core import GLavaSketch, SketchConfig
from repro.core.ingest import BACKENDS

DEPTH, WIDTH = 4, 1024

# The fleet sweep stacks up to 1024 tenant sketches on one host, so it runs
# at a narrower width (T=1024 × K=1 × d=4 × 128² × f32 ≈ 256 MB).
FLEET_WIDTH = 128
FLEET_TENANTS = (1, 64, 1024)
FLEET_BASELINE_T = 64


def _stream(b: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.integers(0, 1 << 20, b), jnp.uint32),
        jnp.asarray(rng.integers(0, 1 << 20, b), jnp.uint32),
        jnp.asarray(rng.integers(1, 5, b), jnp.float32),
    )


def _zipf(b: int, a: float, seed: int = 3):
    st = zipf_stream(1 << 20, b, seed=seed, a=a)
    return st["src"], st["dst"], st["weight"]


def _compile_then_steady(fn, *args, iters: int = 5):
    """(compile_ms, steady_us): first call timed alone, then warm medians."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    compile_ms = (time.perf_counter() - t0) * 1e3
    return compile_ms, time_fn(fn, *args, iters=iters, warmup=1)


def backend_sweep(backends=BACKENDS, batch: int = 32768, depth: int = DEPTH,
                  width: int = WIDTH):
    """Steady-state edges/sec for every requested ingest backend on one
    uniform edge batch (pre-aggregation off — the raw engine number)."""
    cfg = SketchConfig(depth=depth, width_rows=width, width_cols=width)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    out = {}
    for backend in backends:
        b = batch if backend != "pallas" or jax.default_backend() == "tpu" else min(batch, 4096)
        src, dst, w = _stream(b)
        fn = jax.jit(
            lambda s, a, d_, w_, bk=backend: s.update(
                a, d_, w_, backend=bk, preagg="off"
            )
        )
        iters = 2 if backend == "pallas" else 5
        compile_ms, us = _compile_then_steady(fn, sk, src, dst, w, iters=iters)
        eps = b / (us / 1e6)
        out[backend] = eps
        extra = (
            {"note": "interpret-mode correctness path on CPU host"}
            if backend == "pallas" and jax.default_backend() != "tpu"
            else {}
        )
        record(
            f"ingest_backend_{backend}", us / b, batch=b,
            edges_per_s=round(eps), preagg="off",
            compile_ms=round(compile_ms, 1), **extra,
        )
    return out


def preagg_grid(batch: int = 32768, depth: int = DEPTH, width: int = WIDTH):
    """Backend × preagg-on/off × duplicate-rate grid at the sketch.update
    level (the IN-JIT collapse: sort + segment-sum under the same trace)."""
    cfg = SketchConfig(depth=depth, width_rows=width, width_cols=width)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    streams = {
        "uniform": _stream(batch),
        "zipf1.0": tuple(jnp.asarray(x) for x in _zipf(batch, 1.0)),
        "zipf1.5": tuple(jnp.asarray(x) for x in _zipf(batch, 1.5)),
    }
    for backend in ("scatter", "onehot"):
        for stream_name, (src, dst, w) in streams.items():
            for preagg in ("off", "on"):
                fn = jax.jit(
                    lambda s, a, d_, w_, bk=backend, pa=preagg: s.update(
                        a, d_, w_, backend=bk, preagg=pa
                    )
                )
                compile_ms, us = _compile_then_steady(fn, sk, src, dst, w)
                record(
                    f"ingest_{backend}_{stream_name}_preagg_{preagg}",
                    us / batch, batch=batch, stream=stream_name,
                    preagg=preagg, edges_per_s=round(batch / (us / 1e6)),
                    compile_ms=round(compile_ms, 1),
                )


def session_rate(zipf_a: float, batch: int, preagg: str, depth: int = DEPTH,
                 width: int = WIDTH, ingest_backend: str = "scatter"):
    """edges/sec through the REAL session boundary (GraphStream.ingest →
    host collapse → donated jit dispatch → flush), steady state."""
    cfg = SketchConfig(depth=depth, width_rows=width, width_cols=width)
    gs = GraphStream.open(
        cfg, ingest_backend=ingest_backend, query_backend="jnp", preagg=preagg
    )
    src, dst, w = _zipf(batch, zipf_a)

    def step():
        gs.ingest(src, dst, w)
        gs.flush()
        return gs._sketch.counters

    compile_ms, us = _compile_then_steady(step)
    return compile_ms, us, batch / (us / 1e6)


def preagg_session_rows(batch: int = 32768):
    """The tentpole rows: the session fast path on heavy-tail streams, with
    the preagg-off session as the like-for-like comparison."""
    rows = {}
    for name, zipf_a, preagg in (
        ("ingest_preagg_zipf1.5", 1.5, "on"),
        ("ingest_preagg_zipf1.0", 1.0, "on"),
        ("ingest_session_plain_zipf1.5", 1.5, "off"),
    ):
        compile_ms, us, eps = session_rate(zipf_a, batch, preagg)
        rows[name] = eps
        record(
            name, us / batch, batch=batch, preagg=preagg,
            edges_per_s=round(eps), compile_ms=round(compile_ms, 1),
        )
    if jax.default_backend() == "tpu":
        compile_ms, us, eps = session_rate(
            1.5, batch, "auto", ingest_backend="fused"
        )
        record(
            "ingest_fused_zipf1.5", us / batch, batch=batch,
            edges_per_s=round(eps), compile_ms=round(compile_ms, 1),
        )
    return rows


def wal_rows(batch: int = 32768, depth: int = DEPTH, width: int = WIDTH,
             fsync_every: int = 8):
    """Durability tax (DESIGN.md Section 13): the same zipf(1.5) session
    stream with the write-ahead log on (fsync batched every
    ``fsync_every`` mutations) vs off.  ``wal_overhead`` records the
    edges/sec ratio off/on — the price of crash recovery per batch."""
    import shutil
    import tempfile

    cfg = SketchConfig(depth=depth, width_rows=width, width_cols=width)
    src, dst, w = _zipf(batch, 1.5)

    def rate(wal_dir):
        gs = GraphStream.open(
            cfg, ingest_backend="scatter", query_backend="jnp",
            wal_dir=wal_dir, wal_fsync_every=fsync_every,
        )

        def step():
            gs.ingest(src, dst, w)
            gs.flush()
            return gs._sketch.counters

        compile_ms, us = _compile_then_steady(step)
        return compile_ms, us, batch / (us / 1e6)

    _, us_off, eps_off = rate(None)
    tmp = tempfile.mkdtemp(prefix="bench-wal-")
    try:
        compile_ms, us_on, eps_on = rate(tmp)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    record(
        "wal_overhead", us_on / batch, batch=batch,
        edges_per_s=round(eps_on), edges_per_s_nowal=round(eps_off),
        overhead_x=round(us_on / max(us_off, 1e-9), 3),
        fsync_every=fsync_every, compile_ms=round(compile_ms, 1),
    )
    return eps_on, eps_off


def _fleet_rate(fleet, ids, src, dst, w):
    """(compile_ms, steady_us) for one mixed batch through the fleet."""
    def step():
        fleet.ingest_mixed(ids, src, dst, w)
        fleet.flush()
        return fleet._state.cursor

    return _compile_then_steady(step, iters=3)


def fleet_sweep(tenants=FLEET_TENANTS, batch: int = 32768,
                arrival_batch: int = 512, depth: int = DEPTH,
                width: int = FLEET_WIDTH):
    """Multi-tenant fleet ingest (DESIGN.md Section 11): one mixed
    (tenant, edge) arrival batch is ONE stacked donated dispatch, so
    edges/sec holds roughly flat as T grows.  Two figures:

    - throughput: ``fleet_ingest_T{T}`` per-T rows at ``batch`` edges —
      the stacked scatter's steady rate on a bulk mixed batch;
    - the acceptance comparison: the SAME ``arrival_batch``-edge mixed
      tick served by the fleet vs a loop over 64 independent GraphStream
      sessions (slice + dispatch + flush each).  Small per-tenant arrivals
      are the serving regime the fleet targets — the baseline pays 64
      dispatch overheads plus the per-session pad-bucket waste (8 edges
      pad to 256) per tick, the fleet pays one dispatch — and
      ``speedup_vs_sessions`` on the T=64 arrival row is the Section 11
      acceptance figure (≥10×)."""
    from repro.fleet import SketchFleet

    cfg = SketchConfig(depth=depth, width_rows=width, width_cols=width)
    rng = np.random.default_rng(7)
    src = rng.integers(0, 1 << 20, batch).astype(np.uint32)
    dst = rng.integers(0, 1 << 20, batch).astype(np.uint32)
    w = rng.integers(1, 5, batch).astype(np.float32)
    ids64 = rng.integers(0, FLEET_BASELINE_T, batch)

    a_src, a_dst, a_w = src[:arrival_batch], dst[:arrival_batch], w[:arrival_batch]
    a_ids = ids64[:arrival_batch]
    sessions = [
        GraphStream.open(cfg, ingest_backend="scatter", query_backend="jnp")
        for _ in range(FLEET_BASELINE_T)
    ]
    by_tenant = [np.nonzero(a_ids == t)[0] for t in range(FLEET_BASELINE_T)]

    def step_sessions():
        for t, idx in enumerate(by_tenant):
            sessions[t].ingest(a_src[idx], a_dst[idx], a_w[idx])
        for s in sessions:
            s.flush()
        return sessions[0]._sketch.counters

    compile_ms, us = _compile_then_steady(step_sessions, iters=3)
    base_eps = arrival_batch / (us / 1e6)
    record(
        "fleet_baseline_64_sessions", us / arrival_batch, batch=arrival_batch,
        tenants=FLEET_BASELINE_T, fleet_edges_per_s=round(base_eps),
        compile_ms=round(compile_ms, 1),
        note="loop over 64 independent GraphStream sessions, one "
        f"{arrival_batch}-edge mixed arrival tick",
    )

    fleet64 = SketchFleet.open(cfg, capacity=FLEET_BASELINE_T)
    compile_ms, us = _fleet_rate(fleet64, a_ids, a_src, a_dst, a_w)
    arrival_eps = arrival_batch / (us / 1e6)
    record(
        f"fleet_ingest_T{FLEET_BASELINE_T}_arrival", us / arrival_batch,
        batch=arrival_batch, tenants=FLEET_BASELINE_T,
        fleet_edges_per_s=round(arrival_eps),
        compile_ms=round(compile_ms, 1), dispatches_per_batch=1,
        speedup_vs_sessions=round(arrival_eps / base_eps, 2),
        note="same mixed arrival tick as the 64-session baseline, one "
        "stacked dispatch",
    )

    out = {FLEET_BASELINE_T: arrival_eps}
    for t_count in tenants:
        fleet = SketchFleet.open(cfg, capacity=t_count)
        ids = (
            ids64 % t_count
            if t_count <= FLEET_BASELINE_T
            else rng.integers(0, t_count, batch)
        )
        compile_ms, us = _fleet_rate(fleet, ids, src, dst, w)
        eps = batch / (us / 1e6)
        out[t_count] = eps
        record(
            f"fleet_ingest_T{t_count}", us / batch, batch=batch,
            tenants=t_count, fleet_edges_per_s=round(eps),
            compile_ms=round(compile_ms, 1), dispatches_per_batch=1,
        )
    return out, base_eps


def run():
    cfg = SketchConfig(depth=DEPTH, width_rows=WIDTH, width_cols=WIDTH)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    b = 32768
    src, dst, w = _stream(b)

    seq = jax.jit(lambda s, a, d_, w_: s.update_sequential(a[:256], d_[:256], w_[:256]))
    us = time_fn(seq, sk, src, dst, w, iters=3)
    record("ingest_sequential_paper_literal", us / 256, batch=256,
           edges_per_s=round(256 / (us / 1e6)))

    # one engine dispatch point, every backend (the trajectory's per-backend
    # edges/sec record)
    backend_sweep(batch=b)

    # backend × preagg × duplicate-rate grid + the session fast-path rows
    preagg_grid(batch=b)
    preagg_session_rows(batch=b)

    # durability tax: write-ahead-logged session vs plain (wal_overhead)
    wal_rows(batch=b)

    # multi-tenant fleet rows: fleet_edges_per_s per T + the 64-session
    # baseline (the Section 11 speedup_vs_sessions figure)
    fleet_sweep(batch=b)

    # O(1)-per-edge invariant: per-edge cost must not grow with sketch fill
    scat = jax.jit(
        lambda s, a, d_, w_: s.update(a, d_, w_, backend="scatter", preagg="off")
    )
    filled = sk.update(src, dst, w)
    us_empty = time_fn(scat, sk, src, dst, w)
    us_full = time_fn(scat, filled, src, dst, w)
    record("ingest_O1_invariance", us_full / b,
           empty_us_per_edge=round(us_empty / b, 3),
           ratio=round(us_full / max(us_empty, 1e-9), 2))

    # linear-time construction: total time ~ linear in stream length
    t1 = time_fn(scat, sk, src[: b // 2], dst[: b // 2], w[: b // 2])
    t2 = time_fn(scat, sk, src, dst, w)
    record("construction_linearity", t2 / b, half_over_full=round(t1 / t2, 2))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", choices=list(BACKENDS) + ["all"], default="all",
                    help="ingest backend to time (default: sweep all)")
    ap.add_argument("--batch", type=int, default=32768)
    ap.add_argument("--depth", type=int, default=DEPTH)
    ap.add_argument("--width", type=int, default=WIDTH)
    ap.add_argument(
        "--assert-preagg-win", action="store_true",
        help="CI gate: fail unless the pre-aggregated session path beats "
             "the plain scatter session on a zipf(1.5) batch",
    )
    ap.add_argument(
        "--wal", action="store_true",
        help="time the WAL durability tax: zipf(1.5) session ingest with "
             "the write-ahead log on (fsync batched) vs off",
    )
    ap.add_argument(
        "--tenants", type=int, nargs="+", default=None, metavar="T",
        help="fleet sweep: time mixed multi-tenant ingest at these tenant "
             f"counts (e.g. --tenants 1 64 1024; runs at width {FLEET_WIDTH} "
             "and records fleet_edges_per_s plus the 64-session baseline)",
    )
    args = ap.parse_args()
    if args.wal:
        eps_on, eps_off = wal_rows(batch=args.batch, depth=args.depth,
                                   width=args.width)
        print(f"wal on:  {eps_on:,.0f} edges/s")
        print(f"wal off: {eps_off:,.0f} edges/s "
              f"({eps_off / eps_on:.2f}x overhead)")
        return
    if args.tenants:
        eps, base_eps = fleet_sweep(tuple(args.tenants), batch=args.batch,
                                    depth=args.depth)
        print(f"64-session baseline: {base_eps:,.0f} edges/s")
        for t, v in eps.items():
            print(f"fleet T={t}: {v:,.0f} edges/s ({v / base_eps:.1f}x baseline)")
        return
    if args.assert_preagg_win:
        _, _, eps_on = session_rate(1.5, args.batch, "on",
                                    depth=args.depth, width=args.width)
        _, _, eps_off = session_rate(1.5, args.batch, "off",
                                     depth=args.depth, width=args.width)
        print(f"preagg on:  {eps_on:,.0f} edges/s")
        print(f"preagg off: {eps_off:,.0f} edges/s  ({eps_on / eps_off:.2f}x)")
        if eps_on < eps_off:
            print("FAIL: pre-aggregation lost to the plain scatter session")
            sys.exit(1)
        print("OK: pre-aggregation wins")
        return
    backends = BACKENDS if args.backend == "all" else (args.backend,)
    eps = backend_sweep(backends, args.batch, args.depth, args.width)
    for k, v in eps.items():
        print(f"{k}: {v:,.0f} edges/s")


if __name__ == "__main__":
    main()
