"""Ingest-path benchmarks (paper Section 3.2 constraints): µs/edge and
edges/sec for the paper-faithful scalar path and every IngestEngine backend
(scatter / onehot / pallas — Pallas runs in interpret mode on CPU hosts, so
its number here is a CORRECTNESS artifact; its perf claim is the roofline).

CLI (the backend-sweep mode):

    python -m benchmarks.bench_ingest --backend scatter
    python -m benchmarks.bench_ingest --backend all --batch 65536

reports edges/sec per requested backend; ``run()`` (the trajectory entry
point) sweeps all backends so results/benchmarks.json records edges/sec per
backend from every run.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, time_fn
from repro.core import GLavaSketch, SketchConfig
from repro.core.ingest import BACKENDS


def _stream(b: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.integers(0, 1 << 20, b), jnp.uint32),
        jnp.asarray(rng.integers(0, 1 << 20, b), jnp.uint32),
        jnp.asarray(rng.integers(1, 5, b), jnp.float32),
    )


def backend_sweep(backends=BACKENDS, batch: int = 32768, depth: int = 4,
                  width: int = 1024):
    """Time every requested ingest backend on one edge batch; records and
    returns {backend: edges_per_s}."""
    cfg = SketchConfig(depth=depth, width_rows=width, width_cols=width)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    out = {}
    for backend in backends:
        b = batch if backend != "pallas" or jax.default_backend() == "tpu" else min(batch, 4096)
        src, dst, w = _stream(b)
        fn = jax.jit(
            lambda s, a, d_, w_, bk=backend: s.update(a, d_, w_, backend=bk)
        )
        iters = 2 if backend == "pallas" else 3
        us = time_fn(fn, sk, src, dst, w, iters=iters)
        eps = b / (us / 1e6)
        out[backend] = eps
        extra = (
            {"note": "interpret-mode correctness path on CPU host"}
            if backend == "pallas" and jax.default_backend() != "tpu"
            else {}
        )
        record(
            f"ingest_backend_{backend}", us / b, batch=b,
            edges_per_s=round(eps), **extra,
        )
    return out


def run():
    cfg = SketchConfig(depth=4, width_rows=1024, width_cols=1024)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    b = 32768
    src, dst, w = _stream(b)

    seq = jax.jit(lambda s, a, d_, w_: s.update_sequential(a[:256], d_[:256], w_[:256]))
    us = time_fn(seq, sk, src, dst, w, iters=3)
    record("ingest_sequential_paper_literal", us / 256, batch=256,
           edges_per_s=round(256 / (us / 1e6)))

    # one engine dispatch point, every backend (the trajectory's per-backend
    # edges/sec record)
    backend_sweep(batch=b)

    # O(1)-per-edge invariant: per-edge cost must not grow with sketch fill
    scat = jax.jit(lambda s, a, d_, w_: s.update(a, d_, w_, backend="scatter"))
    filled = sk.update(src, dst, w)
    us_empty = time_fn(scat, sk, src, dst, w)
    us_full = time_fn(scat, filled, src, dst, w)
    record("ingest_O1_invariance", us_full / b,
           empty_us_per_edge=round(us_empty / b, 3),
           ratio=round(us_full / max(us_empty, 1e-9), 2))

    # linear-time construction: total time ~ linear in stream length
    t1 = time_fn(scat, sk, src[: b // 2], dst[: b // 2], w[: b // 2])
    t2 = time_fn(scat, sk, src, dst, w)
    record("construction_linearity", t2 / b, half_over_full=round(t1 / t2, 2))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", choices=list(BACKENDS) + ["all"], default="all",
                    help="ingest backend to time (default: sweep all)")
    ap.add_argument("--batch", type=int, default=32768)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--width", type=int, default=1024)
    args = ap.parse_args()
    backends = BACKENDS if args.backend == "all" else (args.backend,)
    eps = backend_sweep(backends, args.batch, args.depth, args.width)
    for k, v in eps.items():
        print(f"{k}: {v:,.0f} edges/s")


if __name__ == "__main__":
    main()
