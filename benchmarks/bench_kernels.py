"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference per kernel.
Interpret-mode timings are NOT TPU performance — they prove the call path;
TPU performance lives in the roofline (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, time_fn
from repro.kernels.closure.ops import transitive_closure as closure_k
from repro.kernels.flow.ops import flows
from repro.kernels.flow.ref import flows_ref
from repro.kernels.ingest.ops import sketch_ingest
from repro.kernels.ingest.ref import sketch_ingest_ref
from repro.kernels.query.ops import edge_query_cells
from repro.kernels.query.ref import edge_query_ref
from repro.core import reach


def run():
    rng = np.random.default_rng(0)
    d, w, b = 4, 512, 4096
    counters = jnp.asarray(rng.integers(0, 50, (d, w, w)), jnp.float32)
    rows = jnp.asarray(rng.integers(0, w, (d, b)), jnp.int32)
    cols = jnp.asarray(rng.integers(0, w, (d, b)), jnp.int32)
    wts = jnp.ones(b, jnp.float32)

    record("kernel_ingest_pallas", time_fn(jax.jit(sketch_ingest), counters, rows, cols, wts, iters=2))
    record("kernel_ingest_ref", time_fn(jax.jit(sketch_ingest_ref), counters, rows, cols, wts))
    record("kernel_query_pallas", time_fn(jax.jit(edge_query_cells), counters, rows, cols, iters=2))
    record("kernel_query_ref", time_fn(jax.jit(edge_query_ref), counters, rows, cols))
    record("kernel_flow_pallas", time_fn(jax.jit(flows), counters, iters=2))
    record("kernel_flow_ref", time_fn(jax.jit(flows_ref), counters))
    small = counters[:1, :256, :256]
    record("kernel_closure_pallas", time_fn(jax.jit(lambda a: closure_k(a[0])), small, iters=2))
    record("kernel_closure_ref", time_fn(jax.jit(lambda a: reach.transitive_closure(a[0])), small))


if __name__ == "__main__":
    run()
