"""Query-capability benchmarks: reachability precision (Section 4.3),
subgraph semantics (Section 4.4), throughput per query family."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, time_fn
from repro.core import GLavaSketch, SketchConfig, queries, reach


def bench_reachability_precision():
    """False-positive rate vs sketch width on a layered DAG (no back-paths:
    every reverse query is a true negative).  Recall is ALWAYS 1 (one-sided
    error, tested separately)."""
    rng = np.random.default_rng(0)
    layers = 4
    per = 100
    src_l, dst_l = [], []
    for l in range(layers - 1):
        s = rng.integers(l * per, (l + 1) * per, 300)
        d = rng.integers((l + 1) * per, (l + 2) * per, 300)
        src_l.append(s)
        dst_l.append(d)
    src = jnp.asarray(np.concatenate(src_l), jnp.uint32)
    dst = jnp.asarray(np.concatenate(dst_l), jnp.uint32)
    q_from = jnp.asarray(rng.integers((layers - 1) * per, layers * per, 400), jnp.uint32)
    q_to = jnp.asarray(rng.integers(0, per, 400), jnp.uint32)
    for w in (64, 128, 256, 512):
        cfg = SketchConfig(depth=4, width_rows=w, width_cols=w)
        fps = []
        for t in range(3):
            sk = GLavaSketch.empty(cfg, jax.random.key(t)).update(src, dst)
            r = np.asarray(queries.reach_query(sk, q_from, q_to))
            fps.append(r.mean())  # all are true negatives
        record(f"reach_fp_rate_w{w}", 0.0, fp_rate=round(float(np.mean(fps)), 4))
    # recall: forward pairs known reachable
    sk = GLavaSketch.empty(SketchConfig(4, 64, 64), jax.random.key(9)).update(src, dst)
    r = np.asarray(queries.reach_query(sk, src[:200], dst[:200]))
    record("reach_recall_direct_edges", 0.0, recall=float(r.mean()))


def bench_subgraph_semantics():
    rng = np.random.default_rng(1)
    src = jnp.asarray(rng.integers(0, 400, 3000), jnp.uint32)
    dst = jnp.asarray(rng.integers(0, 400, 3000), jnp.uint32)
    sk = GLavaSketch.empty(SketchConfig(4, 256, 256), jax.random.key(2)).update(src, dst)
    viol = 0
    zero_sem_ok = True
    for t in range(200):
        k = rng.integers(2, 5)
        idx = rng.integers(0, 3000, k)
        qs, qd = src[idx], dst[idx]
        f = float(queries.subgraph_query(sk, qs, qd))
        fo = float(queries.subgraph_query_opt(sk, qs, qd))
        if fo > f + 1e-5:
            viol += 1
        # insert one absent edge -> revised semantics must yield 0
        qs0 = jnp.concatenate([qs, jnp.asarray([999999], jnp.uint32)])
        qd0 = jnp.concatenate([qd, jnp.asarray([999998], jnp.uint32)])
        if float(queries.subgraph_query(sk, qs0, qd0)) != 0.0:
            zero_sem_ok = False
    record("subgraph_fopt_leq_f", 0.0, violations=viol, trials=200)
    record("subgraph_zero_propagation", 0.0, holds=zero_sem_ok)


def bench_query_throughput():
    cfg = SketchConfig(4, 1024, 1024)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, 100000, 100000), jnp.uint32)
    dst = jnp.asarray(rng.integers(0, 100000, 100000), jnp.uint32)
    sk = sk.update(src, dst)
    q = 4096
    qs, qd = src[:q], dst[:q]
    f_edge = jax.jit(queries.edge_query)
    us = time_fn(f_edge, sk, qs, qd)
    record("throughput_edge_query", us / q, batch=q, total_us=round(us, 1))
    f_in = jax.jit(queries.node_in_flow)
    us = time_fn(f_in, sk, qs)
    record("throughput_point_query", us / q, batch=q, total_us=round(us, 1))
    f_cl = jax.jit(reach.transitive_closure)
    us = time_fn(f_cl, sk.counters, iters=2)
    record("throughput_closure_refresh", us, w=1024, d=4,
           note="amortized over all reach queries between refreshes")


def run():
    bench_reachability_precision()
    bench_subgraph_semantics()
    bench_query_throughput()
