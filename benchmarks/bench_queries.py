"""Query-capability benchmarks: reachability precision (Section 4.3),
subgraph semantics (Section 4.4), throughput per query family and for
mixed heterogeneous batches through the `repro.api` planner.

CLI (the throughput-sweep mode, also run by CI as a smoke check):

    python -m benchmarks.bench_queries                   # full sweep
    python -m benchmarks.bench_queries --smoke           # small shapes, fast
    python -m benchmarks.bench_queries --json out.json   # also dump rows

``run()`` (the trajectory entry point) performs the full sweep so
results/benchmarks.json records queries/sec per family (edge jnp + fused
pallas, flow point queries from the registers, reach against the cached
closure, subgraph), the mixed-batch planner figure, AND the standing-
subscription ticks/sec vs one-shot re-query figure (incremental closure
refresh vs full rebuild) alongside ingest edges/sec; ``benchmarks.run``
copies the query rows to BENCH_queries.json at the repo root as the
cross-PR perf trajectory.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ROWS, record, time_fn
from repro.core import GLavaSketch, QueryEngine, SketchConfig, queries, reach


def bench_reachability_precision():
    """False-positive rate vs sketch width on a layered DAG (no back-paths:
    every reverse query is a true negative).  Recall is ALWAYS 1 (one-sided
    error, tested separately)."""
    rng = np.random.default_rng(0)
    layers = 4
    per = 100
    src_l, dst_l = [], []
    for l in range(layers - 1):
        s = rng.integers(l * per, (l + 1) * per, 300)
        d = rng.integers((l + 1) * per, (l + 2) * per, 300)
        src_l.append(s)
        dst_l.append(d)
    src = jnp.asarray(np.concatenate(src_l), jnp.uint32)
    dst = jnp.asarray(np.concatenate(dst_l), jnp.uint32)
    q_from = jnp.asarray(rng.integers((layers - 1) * per, layers * per, 400), jnp.uint32)
    q_to = jnp.asarray(rng.integers(0, per, 400), jnp.uint32)
    for w in (64, 128, 256, 512):
        cfg = SketchConfig(depth=4, width_rows=w, width_cols=w)
        fps = []
        for t in range(3):
            sk = GLavaSketch.empty(cfg, jax.random.key(t)).update(src, dst)
            r = np.asarray(queries.reach_query(sk, q_from, q_to))
            fps.append(r.mean())  # all are true negatives
        record(f"reach_fp_rate_w{w}", 0.0, fp_rate=round(float(np.mean(fps)), 4))
    # recall: forward pairs known reachable
    sk = GLavaSketch.empty(SketchConfig(4, 64, 64), jax.random.key(9)).update(src, dst)
    r = np.asarray(queries.reach_query(sk, src[:200], dst[:200]))
    record("reach_recall_direct_edges", 0.0, recall=float(r.mean()))


def bench_subgraph_semantics():
    rng = np.random.default_rng(1)
    src = jnp.asarray(rng.integers(0, 400, 3000), jnp.uint32)
    dst = jnp.asarray(rng.integers(0, 400, 3000), jnp.uint32)
    sk = GLavaSketch.empty(SketchConfig(4, 256, 256), jax.random.key(2)).update(src, dst)
    viol = 0
    zero_sem_ok = True
    for t in range(200):
        k = rng.integers(2, 5)
        idx = rng.integers(0, 3000, k)
        qs, qd = src[idx], dst[idx]
        f = float(queries.subgraph_query(sk, qs, qd))
        fo = float(queries.subgraph_query_opt(sk, qs, qd))
        if fo > f + 1e-5:
            viol += 1
        # insert one absent edge -> revised semantics must yield 0
        qs0 = jnp.concatenate([qs, jnp.asarray([999999], jnp.uint32)])
        qd0 = jnp.concatenate([qd, jnp.asarray([999998], jnp.uint32)])
        if float(queries.subgraph_query(sk, qs0, qd0)) != 0.0:
            zero_sem_ok = False
    record("subgraph_fopt_leq_f", 0.0, violations=viol, trials=200)
    record("subgraph_zero_propagation", 0.0, holds=zero_sem_ok)


def bench_query_throughput(smoke: bool = False):
    """Queries/sec per family through the QueryEngine dispatch (the serving
    path): edge on both backends, register-served point queries, reach
    against the precomputed closure, and subgraph.  Records ``qps`` per
    family so BENCH_*.json tracks query throughput alongside ingest
    edges/sec."""
    width = 256 if smoke else 1024
    n_edges = 10_000 if smoke else 100_000
    q = 1024 if smoke else 4096
    cfg = SketchConfig(4, width, width)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, n_edges, n_edges), jnp.uint32)
    dst = jnp.asarray(rng.integers(0, n_edges, n_edges), jnp.uint32)
    sk = sk.update(src, dst)
    qs, qd = src[:q], dst[:q]

    # Both backends always run (the smoke's small width keeps interpret-mode
    # pallas cheap), so CI exercises the fused-kernel dispatch path too.
    for backend in ("jnp", "pallas"):
        eng = QueryEngine(backend)
        interp = backend == "pallas" and jax.default_backend() != "tpu"
        bq = min(q, 512) if interp else q  # interpret mode is slow; tiny batch
        us = time_fn(eng.edge, sk, qs[:bq], qd[:bq], iters=2 if interp else 5)
        extra = {"note": "interpret-mode correctness path on CPU host"} if interp else {}
        record(f"qps_edge_{backend}", us / bq, batch=bq,
               qps=round(bq / (us / 1e6), 1), **extra)

    eng = QueryEngine("jnp")
    for family, fn, args in (
        ("in_flow", eng.in_flow, (sk, qs)),
        ("out_flow", eng.out_flow, (sk, qs)),
    ):
        us = time_fn(fn, *args)
        record(f"qps_{family}_registers", us / q, batch=q,
               qps=round(q / (us / 1e6), 1),
               note="O(d*Q) gather from maintained flow registers")

    # reach: one closure build (epoch-cached), then queries amortize it
    us_cl = time_fn(lambda: eng.closure_for(sk, epoch=None), iters=2)
    record("closure_refresh", us_cl, w=width, d=4,
           note="amortized over all reach queries between refreshes")
    eng.closure_for(sk, epoch=1)  # warm the cache at a fixed epoch
    us = time_fn(eng.reach, sk, qs, qd, 1)
    record("qps_reach_precomputed", us / q, batch=q,
           qps=round(q / (us / 1e6), 1))

    k = 8
    us = time_fn(eng.subgraph, sk, qs[:k], qd[:k])
    record("qps_subgraph", us / k, batch=k, qps=round(k / (us / 1e6), 1))

    bench_mixed_batch(smoke=smoke)


def bench_mixed_batch(smoke: bool = False):
    """Mixed heterogeneous workload through the `repro.api` plan-and-fuse
    path: one shuffled QueryBatch spanning edge/flow/heavy/reach/subgraph
    families, planned into one engine dispatch per family.  Records the
    aggregate queries/sec the facade serves — the number a caller with the
    paper's mixed workload (Section 3.4) actually sees."""
    from repro.api import GraphStream, Query, QueryBatch

    width = 256 if smoke else 1024
    n_edges = 10_000 if smoke else 100_000
    q = 256 if smoke else 1024
    gs = GraphStream.open(
        SketchConfig(4, width, width), ingest_backend="scatter",
        query_backend="jnp",
    )
    rng = np.random.default_rng(0)
    src = rng.integers(0, n_edges, n_edges).astype(np.uint32)
    dst = rng.integers(0, n_edges, n_edges).astype(np.uint32)
    gs.ingest(src, dst)

    batch = QueryBatch([
        Query.edge(src[:q], dst[:q]),
        Query.in_flow(src[:q]),
        Query.out_flow(dst[:q]),
        Query.heavy(src[: q // 4], theta=0.01),
        Query.reach(src[: q // 8], dst[: q // 8]),
        Query.subgraph(src[:4], dst[:4]),
        Query.subgraph(src[4:12], dst[4:12]),
    ])
    n_queries = sum(qq.n_answers for qq in batch)
    gs.query(batch)  # warm the jit caches + the epoch-tagged closure
    us = time_fn(gs.query, batch, iters=5)
    record(
        "qps_mixed_batch",
        us / n_queries,
        batch=n_queries,
        families=len(batch.families),
        qps=round(n_queries / (us / 1e6), 1),
        note="heterogeneous QueryBatch via repro.api planner, one engine "
        "dispatch per family",
    )


def bench_subscription_ticks(smoke: bool = False, config=None):
    """Standing-subscription serving rate vs. re-issuing the same batch as
    one-shot pulls — the reach+flow mixed workload of the paper's
    continuous-monitoring scenarios.  The subscription path compiles the
    batch once and refreshes the reach closure INCREMENTALLY from each
    ingest batch's touched rows; the one-shot baseline re-pays the full
    O(w³ log w) closure rebuild per epoch.  Records ticks/sec for both and
    the speedup (the subscription plane's acceptance figure)."""
    from repro.api import GraphStream, Query, QueryBatch

    width = 256 if smoke else 1024
    cfg = config if config is not None else SketchConfig(4, width, width)
    # Per-tick batches must stay below the incremental-refresh row-fraction
    # budget (0.25·w) or both paths degenerate to full rebuilds.
    tick_batch = max(16, int(cfg.width_rows * 0.15))
    n_seed = 20_000 if smoke else 100_000
    n_ticks = 4 if smoke else 6
    rng = np.random.default_rng(0)
    seed_src = rng.integers(0, n_seed, n_seed).astype(np.uint32)
    seed_dst = rng.integers(0, n_seed, n_seed).astype(np.uint32)
    ticks = [
        (
            rng.integers(0, n_seed, tick_batch).astype(np.uint32),
            rng.integers(0, n_seed, tick_batch).astype(np.uint32),
        )
        for _ in range(n_ticks + 2)
    ]
    workload = QueryBatch([
        Query.reach(seed_src[:64], seed_dst[:64]),
        Query.in_flow(seed_src[:256]),
        Query.out_flow(seed_dst[:256]),
    ])

    def session():
        gs = GraphStream.open(cfg, ingest_backend="scatter", query_backend="jnp")
        gs.ingest(seed_src, seed_dst)
        return gs

    import time as _time

    # standing subscription: one full closure build (warm tick), then
    # incremental refreshes only
    gs = session()
    sub = gs.subscribe(workload, every=1, name="bench")
    gs.ingest(*ticks[0])  # warm tick 1: full closure build + query traces
    gs.ingest(*ticks[1])  # warm tick 2: compiles the incremental refresh
    t0 = _time.perf_counter()
    for s, d in ticks[2:]:
        gs.ingest(s, d)
    sub_s = _time.perf_counter() - t0
    assert sub.ticks == n_ticks + 2
    full, inc = gs.engine.closure_refreshes, gs.engine.closure_incremental_refreshes

    # baseline: re-issue the same batch as a one-shot pull per ingest batch
    gs2 = session()
    gs2.query(workload)  # warm: full build + jit traces
    gs2.ingest(*ticks[0])
    gs2.query(workload)
    gs2.ingest(*ticks[1])
    gs2.query(workload)
    t0 = _time.perf_counter()
    for s, d in ticks[2:]:
        gs2.ingest(s, d)
        gs2.query(workload)
    oneshot_s = _time.perf_counter() - t0

    record(
        "subscription_ticks",
        sub_s / n_ticks * 1e6,
        width=cfg.width_rows,
        tick_batch=tick_batch,
        ticks_per_s=round(n_ticks / sub_s, 2),
        oneshot_per_s=round(n_ticks / oneshot_s, 2),
        speedup_vs_oneshot=round(oneshot_s / sub_s, 2),
        closure_full=full,
        closure_incremental=inc,
        note="reach+flow standing workload; subscription = incremental "
        "closure refresh, baseline = full rebuild per re-query",
    )


FLEET_QUERY_WIDTH = 128
FLEET_QUERY_TENANTS = (1, 64, 1024)


def bench_fleet_queries(tenants=FLEET_QUERY_TENANTS, smoke: bool = False):
    """Queries/sec per family through the FleetQueryEngine — every query
    carries a tenant lane, one jit serves every tenant mix, and reach
    answers against the batched per-tenant closure stack.  Records
    ``fleet_qps`` per (family, T) so BENCH_queries.json tracks multi-tenant
    serving throughput alongside the single-session qps rows."""
    from repro.fleet import SketchFleet

    width = FLEET_QUERY_WIDTH
    n_edges = 20_000 if smoke else 100_000
    q = 1024 if smoke else 4096
    rng = np.random.default_rng(0)
    src = rng.integers(0, n_edges, n_edges).astype(np.uint32)
    dst = rng.integers(0, n_edges, n_edges).astype(np.uint32)
    for t_count in tenants:
        fleet = SketchFleet.open(
            SketchConfig(4, width, width), capacity=t_count
        )
        fleet.ingest_mixed(rng.integers(0, t_count, n_edges), src, dst)
        fleet.flush()
        eng, st = fleet.engine, fleet._state
        slots = jnp.asarray(rng.integers(0, t_count, q), jnp.int32)
        qs = jnp.asarray(src[:q], jnp.uint32)
        qd = jnp.asarray(dst[:q], jnp.uint32)
        for family, fn, args in (
            ("edge", eng.edge, (st, slots, qs, qd)),
            ("in_flow", eng.in_flow, (st, slots, qs)),
            ("out_flow", eng.out_flow, (st, slots, qs)),
        ):
            us = time_fn(fn, *args)
            record(
                f"fleet_qps_{family}_T{t_count}", us / q, batch=q,
                tenants=t_count, fleet_qps=round(q / (us / 1e6), 1),
            )
        # reach: closures for the queried tenants build once (batched),
        # then every call is one stacked gather dispatch.  Cap the distinct
        # closure stack at 64 tenants — the O(w³ log w) per-tenant build is
        # the cost axis, not the gather.
        r_slots = np.asarray(slots) % min(t_count, 64)
        slot_epoch = {
            sess._slot: sess._epoch
            for sess in fleet._sessions.values()
            if sess._slot is not None
        }
        epochs = {int(s): slot_epoch.get(int(s), 0) for s in np.unique(r_slots)}
        eng.reach(st, r_slots, qs, qd, epochs)  # warm: batched closure build
        us = time_fn(eng.reach, st, r_slots, qs, qd, epochs)
        record(
            f"fleet_qps_reach_T{t_count}", us / q, batch=q, tenants=t_count,
            distinct_tenants=len(epochs), fleet_qps=round(q / (us / 1e6), 1),
            closure_builds=eng.closure_builds,
        )


def run(smoke: bool = False):
    bench_reachability_precision()
    bench_subgraph_semantics()
    bench_query_throughput(smoke=smoke)
    bench_subscription_ticks(smoke=smoke)
    # smoke (CI) keeps the sweep at T<=64; the trajectory run records the
    # full {1, 64, 1024} grid
    bench_fleet_queries(
        tenants=(1, 64) if smoke else FLEET_QUERY_TENANTS, smoke=smoke
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes and batches, both backends (CI smoke "
                    "check; pallas runs interpret-mode on CPU but stays "
                    "cheap at smoke width)")
    ap.add_argument("--throughput-only", action="store_true",
                    help="skip the accuracy sections, sweep throughput only")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the recorded rows as JSON (CI uploads "
                    "the smoke sweep as a build artifact)")
    ap.add_argument("--preset", default=None,
                    choices=["smoke", "base", "web"],
                    help="run the subscription-ticks figure on a paper "
                    "preset (base/web sizes want a TPU host — the closure "
                    "rebuild baseline is O(w^3 log w); nonsquare is "
                    "excluded: the workload's reach family needs a square "
                    "sketch)")
    ap.add_argument(
        "--tenants", type=int, nargs="+", default=None, metavar="T",
        help="fleet sweep only: fleet_qps per query family at these tenant "
        f"counts (e.g. --tenants 1 64 1024; width {FLEET_QUERY_WIDTH})",
    )
    args = ap.parse_args()
    if args.tenants:
        bench_fleet_queries(tuple(args.tenants), smoke=args.smoke)
    elif args.preset:
        from repro.configs import glava

        cfg = {
            "smoke": glava.SMOKE,
            "base": glava.BASE,
            "web": glava.WEB,
        }[args.preset]
        bench_subscription_ticks(smoke=args.smoke, config=cfg)
    elif args.throughput_only:
        bench_query_throughput(smoke=args.smoke)
        bench_subscription_ticks(smoke=args.smoke)
    else:
        run(smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(ROWS, f, indent=1)
        print(f"# wrote {len(ROWS)} rows -> {args.json}")


if __name__ == "__main__":
    main()
