"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import numpy as np

ROWS: List[Dict] = []


def record(name: str, us_per_call: float, **derived):
    row = {"name": name, "us_per_call": us_per_call, **derived}
    ROWS.append(row)
    dstr = " ".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.2f},{dstr}")
    return row


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 1) -> float:
    """Median wall time per call in µs (block_until_ready on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def zipf_stream(n_nodes: int, n_edges: int, seed: int = 0, a: float = 1.2):
    from repro.data.graphs import edge_stream

    return edge_stream(n_nodes, n_edges, np.random.default_rng(seed), zipf_a=a)


def exact_edge_counts(src, dst, w):
    import collections

    c = collections.Counter()
    for s, d, wt in zip(np.asarray(src), np.asarray(dst), np.asarray(w)):
        c[(int(s), int(d))] += float(wt)
    return c
