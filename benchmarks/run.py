"""Benchmark harness — one section per validatable paper claim (the paper
has no experimental tables; Thm 1, Lemma 5.2, Sections 3.2/4.3/4.4/6.1.2 are
the claims).  Prints ``name,us_per_call,derived`` CSV rows, writes
results/benchmarks.json (all sections), and writes the query-plane rows to
BENCH_queries.json and the ingest-plane rows (per-backend edges/sec) to
BENCH_ingest.json at the REPO ROOT — the perf-trajectory files tracking
queries/sec per family, the subscription ticks/sec figure, and ingest
edges/sec per backend across PRs.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def main() -> None:
    t0 = time.time()
    from benchmarks import (
        bench_accuracy,
        bench_compression,
        bench_ingest,
        bench_kernels,
        bench_queries,
    )
    from benchmarks.common import ROWS

    print("name,us_per_call,derived")
    section_rows = {}
    for section in (
        ("accuracy (Thm1/Lemma5.2/equal-space/nonsquare/CU)", bench_accuracy.run),
        ("queries (reach/subgraph/throughput/subscriptions)", bench_queries.run),
        ("ingest (Section 3.2 constraints)", bench_ingest.run),
        ("compression (sketched all-reduce)", bench_compression.run),
        ("kernels (pallas vs ref)", bench_kernels.run),
    ):
        name, fn = section
        print(f"# --- {name} ---")
        start = len(ROWS)
        fn()
        section_rows[name.split(" ", 1)[0]] = ROWS[start:]
    out = Path("results")
    out.mkdir(exist_ok=True)
    (out / "benchmarks.json").write_text(json.dumps(ROWS, indent=1))
    # The query-plane trajectory lives at the repo root so successive PRs
    # leave a comparable perf record (ticks/sec, qps per family).
    bench_q = REPO_ROOT / "BENCH_queries.json"
    bench_q.write_text(json.dumps(section_rows.get("queries", []), indent=1))
    # Same for the ingest plane: the per-backend edges/sec sweep rows
    # (ingest_backend_{scatter,onehot,pallas}) seed the trajectory the
    # ROADMAP's tens-of-millions-of-edges/sec push is measured against.
    bench_i = REPO_ROOT / "BENCH_ingest.json"
    bench_i.write_text(json.dumps(section_rows.get("ingest", []), indent=1))
    print(
        f"# done: {len(ROWS)} rows in {time.time()-t0:.1f}s -> "
        f"results/benchmarks.json + {bench_q} + {bench_i}"
    )


if __name__ == "__main__":
    main()
