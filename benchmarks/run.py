"""Benchmark harness — one section per validatable paper claim (the paper
has no experimental tables; Thm 1, Lemma 5.2, Sections 3.2/4.3/4.4/6.1.2 are
the claims).  Prints ``name,us_per_call,derived`` CSV rows, writes
results/benchmarks.json (all sections), and APPENDS this run's query-plane
and ingest-plane rows to BENCH_queries.json / BENCH_ingest.json at the
REPO ROOT as ``{pr, commit, rows}`` history records — the perf-trajectory
files tracking queries/sec per family, the subscription ticks/sec figure,
and ingest edges/sec per backend ACROSS PRs, not just the latest run.
Legacy flat-list files are absorbed as a single seed record.
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def _commit_id() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=REPO_ROOT,
            capture_output=True,
            text=True,
            check=True,
        ).stdout.strip()
    except Exception:
        return "unknown"


def append_history(path: Path, rows, *, pr=None, commit=None) -> list:
    """Append this run's rows to ``path`` as a ``{pr, commit, rows}``
    record, keeping every prior record.  A legacy flat list of rows (the
    pre-history format) becomes the first record with ``pr: 0``.  The PR
    number comes from $BENCH_PR when set, else one past the last record's.
    Re-running under the same PR number replaces that record instead of
    duplicating it."""
    history = []
    if path.exists():
        prior = json.loads(path.read_text())
        if prior and isinstance(prior[0], dict) and "rows" in prior[0]:
            history = prior
        elif prior:
            history = [{"pr": 0, "commit": "legacy", "rows": prior}]
    if pr is None:
        env_pr = os.environ.get("BENCH_PR")
        pr = (
            int(env_pr)
            if env_pr
            else (history[-1]["pr"] + 1 if history else 1)
        )
    record = {
        "pr": int(pr),
        "commit": commit if commit is not None else _commit_id(),
        "rows": rows,
    }
    history = [h for h in history if h["pr"] != record["pr"]] + [record]
    path.write_text(json.dumps(history, indent=1) + "\n")
    return history


def main() -> None:
    t0 = time.time()
    from benchmarks import (
        bench_accuracy,
        bench_compression,
        bench_cost,
        bench_ingest,
        bench_kernels,
        bench_queries,
    )
    from benchmarks.common import ROWS

    print("name,us_per_call,derived")
    section_rows = {}
    for section in (
        ("accuracy (Thm1/Lemma5.2/equal-space/nonsquare/CU)", bench_accuracy.run),
        ("queries (reach/subgraph/throughput/subscriptions)", bench_queries.run),
        ("ingest (Section 3.2 constraints)", bench_ingest.run),
        ("compression (sketched all-reduce)", bench_compression.run),
        ("kernels (pallas vs ref)", bench_kernels.run),
        ("cost (compiled flops/bytes + fitted exponents)", bench_cost.run),
    ):
        name, fn = section
        print(f"# --- {name} ---")
        start = len(ROWS)
        fn()
        section_rows[name.split(" ", 1)[0]] = ROWS[start:]
    out = Path("results")
    out.mkdir(exist_ok=True)
    (out / "benchmarks.json").write_text(json.dumps(ROWS, indent=1))
    # The query-plane trajectory lives at the repo root so successive PRs
    # leave a comparable perf record (ticks/sec, qps per family).  The
    # cost rows ride with the queries section: same cadence, same file.
    bench_q = REPO_ROOT / "BENCH_queries.json"
    append_history(
        bench_q, section_rows.get("queries", []) + section_rows.get("cost", [])
    )
    # Same for the ingest plane: the per-backend edges/sec sweep rows
    # (ingest_backend_{scatter,onehot,pallas}) seed the trajectory the
    # ROADMAP's tens-of-millions-of-edges/sec push is measured against.
    bench_i = REPO_ROOT / "BENCH_ingest.json"
    append_history(bench_i, section_rows.get("ingest", []))
    print(
        f"# done: {len(ROWS)} rows in {time.time()-t0:.1f}s -> "
        f"results/benchmarks.json + {bench_q} + {bench_i}"
    )


if __name__ == "__main__":
    main()
