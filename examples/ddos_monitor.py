"""DoS monitoring (the paper's flagship point-query application,
Section 3.4): watch f̃_v(target, ←) > θ in real time over a packet stream
with an injected volumetric attack, using the Section 4.2 three-step
monitor — all through the :class:`repro.api.GraphStream` facade.

Run: PYTHONPATH=src python examples/ddos_monitor.py
"""
import numpy as np

from repro.api import GraphStream, Query, SketchConfig

N_HOSTS = 20_000
TARGET = 4242
THETA = 2_000.0

gs = GraphStream.open(SketchConfig(depth=4, width_rows=1024, width_cols=1024))
rng = np.random.default_rng(0)

print(f"[ddos] monitoring host {TARGET}: alarm when f̃_v(target,←) > {THETA:,.0f}")
attack_started = None
alarm_at = None
for t in range(40):
    # background traffic
    src = rng.integers(0, N_HOSTS, 5000).astype(np.uint32)
    dst = rng.integers(0, N_HOSTS, 5000).astype(np.uint32)
    nbytes = rng.integers(40, 1500, 5000).astype(np.float32) / 1000.0
    if t >= 25:  # volumetric attack: many sources flood the target
        if attack_started is None:
            attack_started = t
        atk_src = rng.integers(0, N_HOSTS, 3000).astype(np.uint32)
        src = np.concatenate([src, atk_src])
        dst = np.concatenate([dst, np.full(3000, TARGET, np.uint32)])
        nbytes = np.concatenate([nbytes, np.full(3000, 1.4, np.float32)])

    # the paper's 3-step monitor: estimate, alarm, ingest — one facade call
    alarm = gs.monitor(src, dst, nbytes, watch=TARGET, theta=THETA)
    est = float(gs.query(Query.in_flow(TARGET)).value)
    flag = "ALARM" if alarm else "     "
    if t % 5 == 0 or alarm and alarm_at is None:
        print(f"[ddos] t={t:02d} {flag} f̃_v(target,←)={est:10.1f}")
    if alarm and alarm_at is None:
        alarm_at = t

assert attack_started is not None and alarm_at is not None
print(f"[ddos] attack at t={attack_started}, alarm at t={alarm_at} "
      f"(detection lag {alarm_at - attack_started} batches)")
