"""DoS monitoring (the paper's flagship continuous application,
Section 3.4): watch host TARGET's in-flow share of total traffic in real
time over a packet stream with an injected volumetric attack — as a
STANDING SUBSCRIPTION: the threshold query is registered once, compiled
once by the planner, and re-evaluated automatically after every ingest
batch, emitting timestamped alarm events.  θ is the heavy-hitter fraction
of total stream weight (paper-style relative threshold).

Run: PYTHONPATH=src python examples/ddos_monitor.py
"""
import numpy as np

from repro.api import GraphStream, Query, SketchConfig

N_HOSTS = 20_000
TARGET = 4242
THETA = 0.10  # alarm when the target draws > 10% of ALL traffic

gs = GraphStream.open(SketchConfig(depth=4, width_rows=1024, width_cols=1024))
rng = np.random.default_rng(0)

print(f"[ddos] monitoring host {TARGET}: alarm when f̃_v(target,←) > {THETA:.0%} of F̃")

# The standing query: heavy-hitter check + the raw in-flow estimate, with
# an alarm predicate on the in-flow bit.  every=1 → one event per batch.
sub = gs.subscribe(
    Query.heavy(TARGET, THETA),
    Query.in_flow(TARGET),
    every=1,
    alarm=lambda results: bool(np.asarray(results[0].value[0])),
    name="ddos-watch",
)

attack_started = None
alarm_at = None
for t in range(40):
    # background traffic
    src = rng.integers(0, N_HOSTS, 5000).astype(np.uint32)
    dst = rng.integers(0, N_HOSTS, 5000).astype(np.uint32)
    nbytes = rng.integers(40, 1500, 5000).astype(np.float32) / 1000.0
    if t >= 25:  # volumetric attack: many sources flood the target
        if attack_started is None:
            attack_started = t
        atk_src = rng.integers(0, N_HOSTS, 3000).astype(np.uint32)
        src = np.concatenate([src, atk_src])
        dst = np.concatenate([dst, np.full(3000, TARGET, np.uint32)])
        nbytes = np.concatenate([nbytes, np.full(3000, 1.4, np.float32)])

    # ingest drives the subscription: the standing query re-evaluates and
    # emits one event for this batch
    gs.ingest(src, dst, nbytes)
    (event,) = sub.poll()
    est = float(np.asarray(event.results[1].value))
    flag = "ALARM" if event.alarm else "     "
    if t % 5 == 0 or (event.alarm and alarm_at is None):
        print(f"[ddos] t={t:02d} {flag} f̃_v(target,←)={est:10.1f}")
    if event.alarm and alarm_at is None:
        alarm_at = t

assert attack_started is not None and alarm_at is not None
assert sub.ticks == 40
print(f"[ddos] attack at t={attack_started}, alarm at t={alarm_at} "
      f"(detection lag {alarm_at - attack_started} batches)")
