"""DoS monitoring (the paper's flagship continuous application,
Section 3.4): watch host TARGET's in-flow share of total traffic in real
time over a packet stream with an injected volumetric attack — as a
STANDING SUBSCRIPTION: the threshold query is registered once, compiled
once by the planner, and re-evaluated automatically after every ingest
batch, emitting timestamped alarm events.  θ is the heavy-hitter fraction
of total stream weight (paper-style relative threshold).

Run: PYTHONPATH=src python examples/ddos_monitor.py

With ``--wal-dir DIR`` every batch is write-ahead-logged before its
device dispatch, and ``--crash-after N`` runs the crash-replay
self-check: the monitor is killed after N batches (no close, no final
checkpoint), recovered in a fresh session via ``recover()``, driven to
the end, and its event transcript asserted bit-identical to an
uninterrupted run (DESIGN.md Section 13).
"""
import argparse
import tempfile

import numpy as np

from repro.api import GraphStream, Query, SketchConfig

N_HOSTS = 20_000
TARGET = 4242
THETA = 0.10  # alarm when the target draws > 10% of ALL traffic
N_BATCHES = 40
ATTACK_AT = 25
CKPT_EVERY = 10


def _make_batches(n_batches):
    rng = np.random.default_rng(0)
    batches = []
    for t in range(n_batches):
        # background traffic
        src = rng.integers(0, N_HOSTS, 5000).astype(np.uint32)
        dst = rng.integers(0, N_HOSTS, 5000).astype(np.uint32)
        nbytes = rng.integers(40, 1500, 5000).astype(np.float32) / 1000.0
        if t >= ATTACK_AT:  # volumetric attack: many sources flood the target
            atk_src = rng.integers(0, N_HOSTS, 3000).astype(np.uint32)
            src = np.concatenate([src, atk_src])
            dst = np.concatenate([dst, np.full(3000, TARGET, np.uint32)])
            nbytes = np.concatenate([nbytes, np.full(3000, 1.4, np.float32)])
        batches.append((src, dst, nbytes))
    return batches


def _open(wal_dir=None, ckpt_dir=None):
    gs = GraphStream.open(
        SketchConfig(depth=4, width_rows=1024, width_cols=1024),
        wal_dir=wal_dir,
        checkpoint_dir=ckpt_dir,
    )
    # The standing query: heavy-hitter check + the raw in-flow estimate,
    # with an alarm predicate on the in-flow bit.  every=1 → one event
    # per batch.
    sub = gs.subscribe(
        Query.heavy(TARGET, THETA),
        Query.in_flow(TARGET),
        every=1,
        alarm=lambda results: bool(np.asarray(results[0].value[0])),
        name="ddos-watch",
    )
    return gs, sub


def _event_key(event):
    return (
        event.tick,
        bool(event.alarm),
        tuple(np.asarray(event.results[1].value).ravel().tolist()),
    )


def _drive(gs, sub, batches, transcript, start_t=0, verbose=True):
    """Ingest each batch, poll its event, print the monitor line."""
    alarm_at = None
    for t, (src, dst, nbytes) in enumerate(batches, start=start_t):
        gs.ingest(src, dst, nbytes)
        (event,) = sub.poll()
        transcript.append(_event_key(event))
        est = float(np.asarray(event.results[1].value))
        flag = "ALARM" if event.alarm else "     "
        if verbose and (t % 5 == 0 or (event.alarm and alarm_at is None)):
            print(f"[ddos] t={t:02d} {flag} f̃_v(target,←)={est:10.1f}")
        if event.alarm and alarm_at is None:
            alarm_at = t
        if gs._ckpt is not None and (t + 1) % CKPT_EVERY == 0:
            gs.checkpoint()
    return alarm_at


def run_monitor(wal_dir=None, ckpt_dir=None):
    batches = _make_batches(N_BATCHES)
    gs, sub = _open(wal_dir, ckpt_dir)
    print(
        f"[ddos] monitoring host {TARGET}: alarm when f̃_v(target,←) "
        f"> {THETA:.0%} of F̃"
    )
    transcript = []
    alarm_at = _drive(gs, sub, batches, transcript)
    assert alarm_at is not None and alarm_at >= ATTACK_AT
    assert sub.ticks == N_BATCHES
    print(
        f"[ddos] attack at t={ATTACK_AT}, alarm at t={alarm_at} "
        f"(detection lag {alarm_at - ATTACK_AT} batches)"
    )
    return transcript


def run_crash_replay(wal_dir, ckpt_dir, crash_after):
    """Crash after ``crash_after`` batches, recover, finish — and assert
    the stitched event transcript matches an uninterrupted run."""
    print(f"[ddos] uninterrupted oracle run ({N_BATCHES} batches)")
    want = run_monitor()

    batches = _make_batches(N_BATCHES)
    gs, sub = _open(wal_dir, ckpt_dir)
    got = []
    _drive(gs, sub, batches[:crash_after], got, verbose=False)
    consumed = sub.ticks
    print(f"[ddos] CRASH after batch {crash_after} (consumed tick {consumed})")
    del gs  # crash: no close, no final checkpoint

    gs, sub = _open(wal_dir, ckpt_dir)
    sub.seek(consumed)  # the consumer's durable position, BEFORE recover
    report = gs.recover()
    print(
        f"[ddos] recovered: checkpoint step {report.step}, "
        f"{report.mutations_replayed} WAL mutations replayed, "
        f"{sub.events_deduped} events deduped"
    )
    got.extend(_event_key(e) for e in sub.poll())
    _drive(gs, sub, batches[crash_after:], got, start_t=crash_after, verbose=False)

    assert got == want, "replayed transcript diverged from the oracle"
    print(
        f"[ddos] crash-replay OK: {len(got)} events bit-identical to the "
        f"uninterrupted run"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--wal-dir",
        default=None,
        help="write-ahead-log every batch before its device dispatch",
    )
    ap.add_argument(
        "--ckpt-dir",
        default=None,
        help="checkpoint directory (every %d batches)" % CKPT_EVERY,
    )
    ap.add_argument(
        "--crash-after",
        type=int,
        default=None,
        help="crash-replay self-check: kill after N batches, recover(), "
        "assert the event transcript matches an uninterrupted run",
    )
    args = ap.parse_args()
    if args.crash_after is not None:
        wal = args.wal_dir or tempfile.mkdtemp(prefix="ddos-wal-")
        ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="ddos-ckpt-")
        run_crash_replay(wal, ckpt, args.crash_after)
    else:
        run_monitor(args.wal_dir, args.ckpt_dir)


if __name__ == "__main__":
    main()
