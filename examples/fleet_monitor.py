"""Multi-tenant DoS monitoring (DESIGN.md Section 11): one SketchFleet
serves many tenants' packet streams from a single stacked sketch tensor —
every mixed arrival batch is ONE device dispatch — while each tenant
carries its own standing threshold subscription.  A volumetric attack is
injected into exactly one tenant's stream; the alarm must fire there and
ONLY there (per-tenant sketches are fully isolated), and the whole run
must cost exactly one ingest compile regardless of how many tenants the
mixed stream interleaves.

Run: PYTHONPATH=src python examples/fleet_monitor.py
"""
import numpy as np

from repro.api import Query, SketchConfig
from repro.fleet import SketchFleet

N_HOSTS = 20_000
TENANTS = 8
VICTIM_TENANT = 5
TARGET = 4242
THETA = 0.10  # alarm when the target draws > 10% of that tenant's traffic

fleet = SketchFleet.open(
    SketchConfig(depth=4, width_rows=512, width_cols=512), capacity=TENANTS
)
rng = np.random.default_rng(0)

print(
    f"[fleet] {TENANTS} tenants, one stacked sketch: alarm when any "
    f"tenant's f̃_v(host {TARGET},←) > {THETA:.0%} of its own F̃"
)

subs = {
    t: fleet.tenant(t).subscribe(
        Query.heavy(TARGET, THETA),
        Query.in_flow(TARGET),
        every=1,
        alarm=lambda results: bool(np.asarray(results[0].value[0])),
        name=f"ddos-watch-{t}",
    )
    for t in range(TENANTS)
}

attack_started = None
alarm_at = {}
for t_step in range(30):
    # Background traffic for every tenant, interleaved into ONE mixed batch.
    n_bg = 800 * TENANTS
    ids = rng.integers(0, TENANTS, n_bg)
    src = rng.integers(0, N_HOSTS, n_bg).astype(np.uint32)
    dst = rng.integers(0, N_HOSTS, n_bg).astype(np.uint32)
    nbytes = rng.integers(40, 1500, n_bg).astype(np.float32) / 1000.0
    if t_step >= 18:  # flood the victim tenant's target host
        if attack_started is None:
            attack_started = t_step
        # stays inside the same power-of-two pad bucket as the background
        # batch, so the whole run holds at ONE ingest compile
        n_atk = 1600
        ids = np.concatenate([ids, np.full(n_atk, VICTIM_TENANT)])
        src = np.concatenate(
            [src, rng.integers(0, N_HOSTS, n_atk).astype(np.uint32)]
        )
        dst = np.concatenate([dst, np.full(n_atk, TARGET, np.uint32)])
        nbytes = np.concatenate([nbytes, np.full(n_atk, 1.4, np.float32)])

    # One mixed dispatch drives every tenant's standing query.
    fleet.ingest_mixed(ids, src, dst, nbytes)
    for t, sub in subs.items():
        (event,) = sub.poll()
        if event.alarm and t not in alarm_at:
            alarm_at[t] = t_step
            est = float(np.asarray(event.results[1].value))
            print(
                f"[fleet] t={t_step:02d} ALARM tenant {t}: "
                f"f̃_v(target,←)={est:10.1f}"
            )

assert attack_started is not None
assert list(alarm_at) == [VICTIM_TENANT], (
    f"alarm must fire on tenant {VICTIM_TENANT} only, got {sorted(alarm_at)}"
)
assert all(sub.ticks == 30 for sub in subs.values())
assert fleet._ingest._cache_size() == 1, "mixed ingest must compile ONCE"
print(
    f"[fleet] attack on tenant {VICTIM_TENANT} at t={attack_started}, "
    f"alarm at t={alarm_at[VICTIM_TENANT]} (lag "
    f"{alarm_at[VICTIM_TENANT] - attack_started} batches); "
    f"{fleet.stats.batches} mixed batches, 1 ingest compile, "
    f"{fleet._ingest.dispatches} dispatches"
)
