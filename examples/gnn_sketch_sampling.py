"""gLava × GraphSAGE: train on a STREAMED graph where exact degrees are
unavailable — the neighbor sampler's importance weights come from sketch
point queries (DESIGN.md Section 5, direct-applicability row).

Run: PYTHONPATH=src python examples/gnn_sketch_sampling.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import SketchConfig
from repro.data.graphs import citation_graph
from repro.integration.sketch_sampler import StreamingDegreeSketch, sketch_weighted_seeds
from repro.models.gnn import graphsage
from repro.models.gnn.common import GraphBatch
from repro.models.gnn.sampler import CSRGraph, sample_subgraph
from repro.train import optimizer as opt_mod

N, E, F, C = 2000, 12000, 32, 5
rng = np.random.default_rng(0)
g = citation_graph(N, E, F, C, rng)
csr = CSRGraph.from_edges(g["edge_src"], g["edge_dst"], N)

# --- stream the edges through a gLava sketch (one pass) ---------------------
deg_sketch = StreamingDegreeSketch(SketchConfig(depth=4, width_rows=512, width_cols=512))
for lo in range(0, E, 4096):
    deg_sketch.observe(g["edge_src"][lo : lo + 4096], g["edge_dst"][lo : lo + 4096])

est = deg_sketch.degree_estimates(np.arange(N, dtype=np.uint32), direction="in")
exact = np.bincount(g["edge_dst"], minlength=N)
corr = np.corrcoef(est, exact)[0, 1]
print(f"[gnn] sketch degree estimates: corr(est, exact) = {corr:.3f} "
      f"(over-estimates: {np.all(est >= exact - 1e-5)})")

# --- sketch-weighted seeds -> fanout sampling -> SAGE training ---------------
cfg = graphsage.SAGEConfig(name="sage-stream", n_layers=2, d_in=F, d_hidden=32, out_dim=C)
params = graphsage.init_params(cfg, jax.random.key(0))
opt_cfg = opt_mod.AdamWConfig(lr=5e-3, warmup_steps=10, total_steps=120, weight_decay=0.0)
opt = opt_mod.init_adamw(opt_cfg, params)
FANOUTS = (5, 5)
BATCH = 64


@jax.jit
def train_step(params, opt, batch, labels):
    def lfn(p):
        gb = GraphBatch(
            node_feat=batch["node_feat"], edge_src=batch["edge_src"],
            edge_dst=batch["edge_dst"], node_mask=batch["node_mask"],
            edge_mask=batch["edge_mask"],
        )
        logits = graphsage.forward(cfg, p, gb)[:BATCH].astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, labels[:, None], 1)[:, 0]
        return jnp.mean(logz - gold), logits

    (loss, logits), grads = jax.value_and_grad(lfn, has_aux=True)(params)
    params, opt, _ = opt_mod.apply_adamw(opt_cfg, opt, params, grads)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return params, opt, loss, acc

for step in range(120):
    seeds = sketch_weighted_seeds(deg_sketch, N, BATCH, rng, alpha=0.5)
    sub = sample_subgraph(csr, seeds, FANOUTS, rng, features=g["node_feat"])
    labels = jnp.asarray(g["labels"][seeds])
    batch = {k: jnp.asarray(v) for k, v in sub.items() if k != "seed_slots"}
    params, opt, loss, acc = train_step(params, opt, batch, labels)
    if step % 20 == 0:
        print(f"[gnn] step {step:3d} loss={float(loss):.3f} seed-acc={float(acc):.2f}")

print(f"[gnn] final seed accuracy {float(acc):.2f} (chance {1/C:.2f}) — trained "
      "entirely with sketch-estimated degrees")
