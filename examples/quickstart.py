"""Quickstart: the paper's Fig. 1 walkthrough on the public API.

Builds a gLava sketch over a small graph stream, then runs every query
family from Section 3.4: edge frequency, point queries, reachability,
aggregate subgraph (incl. wildcard), triangle counting.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GLavaSketch, SketchConfig, fnv1a_label, queries

# --- the Fig. 1 graph stream (labels a..g) ---------------------------------
EDGES = [
    ("a", "b"), ("a", "b"), ("a", "c"), ("b", "c"), ("b", "a"),
    ("c", "e"), ("c", "e"), ("c", "e"), ("d", "g"), ("g", "b"),
    ("e", "d"), ("f", "a"), ("b", "f"), ("b", "a"),
]
KEY = {l: fnv1a_label(l) for l in "abcdefg"}
k = lambda *ls: jnp.asarray([KEY[l] for l in ls], jnp.uint32)

# --- build the sketch: d=4 hash functions, w=256 node buckets ---------------
cfg = SketchConfig(depth=4, width_rows=256, width_cols=256)
sketch = GLavaSketch.empty(cfg, jax.random.key(0))
src = jnp.asarray([KEY[s] for s, _ in EDGES], jnp.uint32)
dst = jnp.asarray([KEY[d] for _, d in EDGES], jnp.uint32)
sketch = sketch.update(src, dst)  # one pass, O(1)/edge
print(f"sketch: {cfg.depth} x {cfg.width_rows} x {cfg.width_cols} "
      f"({cfg.space_bytes()/1024:.0f} KiB, independent of stream length)")

# --- Q1/Q2 (paper Example 4): edge frequency --------------------------------
est = queries.edge_query(sketch, k("b", "g"), k("c", "b"))
print(f"f̃(b→c) = {est[0]:.0f} (exact 1)   f̃(g→b) = {est[1]:.0f} (exact 1)")

# --- point queries (Section 4.2): DoS-style in-flow monitor ----------------
inflow = queries.node_in_flow(sketch, k("b"))
outflow = queries.node_out_flow(sketch, k("b"))
print(f"f̃_v(b,←) = {inflow[0]:.0f} (exact 3)   f̃_v(b,→) = {outflow[0]:.0f} (exact 4)")

# --- path queries (Section 4.3): reachability -------------------------------
r = queries.reach_query(sketch, k("a", "d", "e"), k("e", "b", "a"))
print(f"r̃(a→e) = {bool(r[0])} (true: a→c→e)   r̃(d→b) = {bool(r[1])} "
      f"(true: d→g→b)   r̃(e→a) = {bool(r[2])} (true: e→d→g→b→a)")

# --- Q3 (Example 6): aggregate subgraph -------------------------------------
f = queries.subgraph_query(sketch, k("a", "a"), k("b", "c"))
print(f"f̃({{(a,b),(a,c)}}) = {f:.0f} (exact 3: weight 2 + 1)")

# --- Q5 wildcard + Q4 triangle (Example 7) ----------------------------------
w = queries.wildcard_edge_query(sketch, k("b"), None)
print(f"f̃(b, *) = {w[0]:.0f} (exact 4: b→c, b→a ×2, b→f)")
t = queries.triangle_query(
    sketch, jnp.uint32(KEY["a"]), jnp.uint32(KEY["b"]), jnp.uint32(KEY["c"])
)
print(f"triangle f̃({{(a,b),(b,c),(c,a)}}) = {t:.0f} (exact 0: (c,a) absent)")

# --- the same analytics on the sketch-as-a-graph (Section 3.3 Remark) -------
pr = queries.sketch_pagerank(sketch, iters=16)
print(f"pagerank on the sketch graph: shape {pr.shape}, rows sum to "
      f"{np.asarray(pr.sum(axis=1)).round(3)}")
