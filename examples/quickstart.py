"""Quickstart: the paper's Fig. 1 walkthrough on the public API.

Opens a :class:`repro.api.GraphStream` session over a small graph stream —
node labels are plain strings; the facade's vectorized key codec
(`fnv1a_labels`) encodes them at the boundary — then answers every query
family from Section 3.4 as ONE heterogeneous `QueryBatch`: edge frequency,
point queries, reachability, aggregate subgraph (incl. wildcard), triangle
counting.  Each answer carries the paper's (ε, δ) one-sided error bound.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import GraphStream, Query, QueryBatch, SketchConfig, fnv1a_labels

# --- the Fig. 1 graph stream (labels a..g) ---------------------------------
EDGES = [
    ("a", "b"), ("a", "b"), ("a", "c"), ("b", "c"), ("b", "a"),
    ("c", "e"), ("c", "e"), ("c", "e"), ("d", "g"), ("g", "b"),
    ("e", "d"), ("f", "a"), ("b", "f"), ("b", "a"),
]

# --- open the session: d=4 hash functions, w=256 node buckets ---------------
cfg = SketchConfig(depth=4, width_rows=256, width_cols=256)
gs = GraphStream.open(cfg)
gs.ingest([s for s, _ in EDGES], [d for _, d in EDGES])  # one pass, O(1)/edge
print(f"sketch: {cfg.depth} x {cfg.width_rows} x {cfg.width_cols} "
      f"({cfg.space_bytes()/1024:.0f} KiB, independent of stream length)")
print(f"label codec: fnv1a_labels(['a','b','c']) = {fnv1a_labels(['a', 'b', 'c'])} "
      f"(vectorized str/int -> uint32 keys)")

# --- the whole Section 3.4 catalogue as ONE mixed batch ---------------------
# The planner groups by family, fuses each family into a single engine
# dispatch, and scatters answers back in request order.
res = gs.query(QueryBatch([
    Query.edge("b", "c"),                      # Q1/Q2 (Example 4)
    Query.edge("g", "b"),
    Query.in_flow("b"),                        # point queries (Section 4.2)
    Query.out_flow("b"),
    Query.reach("a", "e"),                     # path queries (Section 4.3)
    Query.reach("d", "b"),
    Query.reach("e", "a"),
    Query.subgraph(["a", "a"], ["b", "c"]),    # Q3 (Example 6)
    Query.out_flow("b"),                       # Q5 wildcard f̃(b, *) = f̃_v(b, →)
    Query.subgraph(list("abc"), list("bca")),  # Q4 triangle (Example 7)
]))
(e_bc, e_gb, inf_b, outf_b, r_ae, r_db, r_ea, sub, wild, tri) = res

print(f"f̃(b→c) = {e_bc.value:.0f} (exact 1)   f̃(g→b) = {e_gb.value:.0f} (exact 1)")
print(f"f̃_v(b,←) = {inf_b.value:.0f} (exact 3)   f̃_v(b,→) = {outf_b.value:.0f} (exact 4)")
print(f"r̃(a→e) = {bool(r_ae.value)} (true: a→c→e)   r̃(d→b) = {bool(r_db.value)} "
      f"(true: d→g→b)   r̃(e→a) = {bool(r_ea.value)} (true: e→d→g→b→a)")
print(f"f̃({{(a,b),(a,c)}}) = {sub.value:.0f} (exact 3: weight 2 + 1)")
print(f"f̃(b, *) = {wild.value:.0f} (exact 4: b→c, b→a ×2, b→f)")
print(f"triangle f̃({{(a,b),(b,c),(c,a)}}) = {tri.value:.0f} (exact 0: (c,a) absent)")
print(f"every estimate is {e_bc.error}")

# --- the same analytics on the sketch-as-a-graph (Section 3.3 Remark) -------
pr = gs.pagerank(iters=16)
print(f"pagerank on the sketch graph: shape {pr.shape}, rows sum to "
      f"{np.asarray(pr.sum(axis=1)).round(3)}")
