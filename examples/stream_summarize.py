"""END-TO-END DRIVER (the paper's kind is a streaming data structure, so the
e2e deliverable is a summarization service, not a training run): a
network-monitoring service summarizing a high-rate Zipf edge stream through
one :class:`repro.api.GraphStream` session — a live mixed query workload
issued as heterogeneous `QueryBatch`es (planned into one engine dispatch
per family), sliding time windows, and accuracy accounting against exact
ground truth.

Run: PYTHONPATH=src python examples/stream_summarize.py [--edges 400000]
"""
import argparse
import collections
import time

import numpy as np

from repro.api import GraphStream, Query, QueryBatch, SketchConfig
from repro.data.graphs import edge_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=50_000)
    ap.add_argument("--edges", type=int, default=400_000)
    ap.add_argument("--batch", type=int, default=40_000)
    ap.add_argument("--width", type=int, default=2048)
    ap.add_argument("--depth", type=int, default=4)
    args = ap.parse_args()

    cfg = SketchConfig(depth=args.depth, width_rows=args.width, width_cols=args.width)
    gs = GraphStream.open(cfg, ingest_backend="scatter")
    rng = np.random.default_rng(0)
    stream = edge_stream(args.nodes, args.edges, rng, zipf_a=1.3)

    exact_edges = collections.Counter()
    t_start = time.time()
    abs_err, rel_err = [], []

    for lo in range(0, args.edges, args.batch):
        hi = min(args.edges, lo + args.batch)
        s, d, w = stream["src"][lo:hi], stream["dst"][lo:hi], stream["weight"][lo:hi]
        gs.ingest(s, d, w)
        for si, di, wi in zip(s, d, w):
            exact_edges[(int(si), int(di))] += float(wi)

        # live workload: hottest-pair edge frequencies + heavy-hitter watch +
        # reachability, as ONE planned mixed batch
        hot = [p for p, _ in exact_edges.most_common(64)]
        qs = np.asarray([p[0] for p in hot], np.uint32)
        qd = np.asarray([p[1] for p in hot], np.uint32)
        est_r, _, _ = gs.query(QueryBatch([
            Query.edge(qs, qd),
            Query.heavy(np.arange(0, 128, dtype=np.uint32),
                        theta=0.02),  # heavy = > 2% of total stream weight
            Query.reach(qs[:32], qd[:32]),
        ]))
        est = np.asarray(est_r.value)
        exact = np.asarray([exact_edges[p] for p in hot])
        abs_err.extend(np.abs(est - exact).tolist())
        rel_err.extend((np.abs(est - exact) / exact).tolist())
        assert np.all(est >= exact - 1e-4), "over-estimate invariant violated"

    wall = time.time() - t_start
    st = gs.summary()
    # exact per-edge counters for this stream would need one counter per
    # DISTINCT edge and keep GROWING with the stream; the sketch is constant.
    n_distinct = len(exact_edges)
    eps, delta = cfg.error_bound()
    print(
        f"[stream_summarize] {args.edges:,} edges in {wall:.1f}s wall | "
        f"ingest {st['ingest_edges_per_s']:,.0f} edges/s | "
        f"{st['queries_served']:,} queries at {st['queries_per_s']:,.0f}/s | "
        f"{st['closure_refreshes']:.0f} closure refreshes"
    )
    print(
        f"[stream_summarize] sketch space {cfg.space_bytes()/1e6:.1f} MB "
        f"(CONSTANT) vs exact hash-map ≥{n_distinct*24/1e6:.1f} MB and growing "
        f"({n_distinct:,} distinct edges so far) | hot-edge mean-rel-err "
        f"{np.mean(rel_err)*100:.2f}% | over-estimate invariant held "
        f"(paper bound: ε={eps:.1e}, δ={delta:.1e})"
    )


if __name__ == "__main__":
    main()
