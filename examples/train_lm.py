"""Train a language model end-to-end with the full substrate: AdamW,
checkpoint/resume, straggler watchdog, and (optionally) the sketched
gradient all-reduce built on the paper's CountSketch machinery.

Default preset is CPU-sized (a few hundred steps in minutes); ``--preset
100m`` builds the ~100M-param config for real hardware.

Run: PYTHONPATH=src python examples/train_lm.py --steps 200 [--compress]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import GraphStream, Query, SketchConfig
from repro.data.lm import MarkovTokens, bigram_stream
from repro.models import transformer as tfm
from repro.train import compression as comp
from repro.train import optimizer as opt_mod
from repro.train.trainer import TrainerConfig, compressed_data_parallel_step, train_loop

PRESETS = {
    "tiny": tfm.TransformerConfig(
        name="lm-tiny", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=2048, compute_dtype=jnp.float32,
    ),
    "100m": tfm.TransformerConfig(
        name="lm-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
        d_ff=2048, vocab=32768, compute_dtype=jnp.bfloat16,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--compress", action="store_true",
                    help="sketched gradient all-reduce (FetchSGD-style)")
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"[train_lm] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    opt_cfg = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)

    def loss_fn(params, batch):
        return tfm.loss_fn(cfg, params, batch["tokens"])

    gen = MarkovTokens(cfg.vocab, seed=0)
    rng = np.random.default_rng(0)
    # corpus statistics via the paper's sketch: the token-bigram stream IS a
    # graph stream (DESIGN.md Section 5) — summarized in 4×256×256 counters
    bigrams = GraphStream.open(
        SketchConfig(depth=4, width_rows=256, width_cols=256), seed=9
    )

    def batches():
        while True:
            toks = gen.batch(args.batch, args.seq + 1, rng)
            bs = bigram_stream(toks)
            bigrams.ingest(bs["src"], bs["dst"])
            yield {"tokens": toks}

    if args.compress:
        n_params = sum(
            int(np.prod(p.shape))
            for p in jax.tree.leaves(tfm.init_params(cfg, jax.random.key(0)))
        )
        ccfg = comp.CompressorConfig(depth=5, width=1 << 14, top_k=4096)
        step = compressed_data_parallel_step(loss_fn, opt_cfg, ccfg)
        print(f"[train_lm] sketched all-reduce: {n_params/ (5*(1<<14)):.0f}x compression")

        def init_state(key):
            params = tfm.init_params(cfg, key)
            return {
                "params": params,
                "opt": opt_mod.init_adamw(opt_cfg, params),
                "comp": comp.init_compressor(ccfg, n_params, jax.random.key(1)),
            }

    else:
        def init_state(key):
            params = tfm.init_params(cfg, key)
            return {"params": params, "opt": opt_mod.init_adamw(opt_cfg, params)}

        def step(state, batch):
            (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch
            )
            p, o, om = opt_mod.apply_adamw(opt_cfg, state["opt"], state["params"], grads)
            return {"params": p, "opt": o}, {"loss": loss, **om}

    res = train_loop(
        init_state, step, batches(),
        TrainerConfig(
            total_steps=args.steps,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=max(10, args.steps // 4),
            log_every=max(1, args.steps // 10),
        ),
    )
    losses = [h["loss"] for h in res.history]
    print(f"[train_lm] loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    # show the sketch earning its keep: most frequent bigram estimate
    toks = gen.batch(4, 65, rng)
    bs = bigram_stream(toks)
    est = bigrams.query(Query.edge(bs["src"][:8], bs["dst"][:8])).value
    print(f"[train_lm] sketch bigram-frequency estimates (8 probes): {np.asarray(est)}")


if __name__ == "__main__":
    main()
