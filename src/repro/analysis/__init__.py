"""repro.analysis — the static-analysis plane enforcing hot-path contracts.

Two passes, one CLI (``python -m repro.analysis``):

- **Pass 1, jaxpr contract checker** (:mod:`repro.analysis.jaxpr_lint` +
  :mod:`repro.analysis.contracts`): traces a registry of engine entry
  points (every ``IngestEngine`` backend, every ``QueryEngine`` family,
  ``refresh_closure``, the subscription tick, each ``kernels/*/ops.py``
  wrapper, the distributed plane) and checks declarative contracts on the
  traced jaxprs — no host callbacks, no wide-dtype promotion, no
  full-counter reduction for register-served families, buffer donation
  applied through the jit boundary, collectives only under ``shard_map``,
  and at most one trace per family per shape signature.
- **Pass 2, source lint** (:mod:`repro.analysis.source_lint`): AST rules
  specific to this codebase — ``jax.jit`` only in the engine cache
  modules, no host syncs in traced modules, no ``jnp.*`` inside Python
  loops in hot modules, ``REPRO_*`` env reads only at dispatch
  boundaries, and every Pallas kernel keeps a registered ref +
  bit-equality test.

Pre-existing violations are either fixed or explicitly baselined with a
one-line justification in :mod:`repro.analysis.baseline`; the CLI exits
nonzero on any NEW (unbaselined) violation.  DESIGN.md Section 9 has the
architecture and the full contract table.
"""
from repro.analysis.contracts import (  # noqa: F401
    ENTRY_POINTS,
    EntryPoint,
    TracedEntry,
    Violation,
    apply_baseline,
)
from repro.analysis.jaxpr_lint import (  # noqa: F401
    reduces_full_counters,
    run_jaxpr_pass,
    walk_jaxprs,
)
from repro.analysis.runner import main, run_analysis  # noqa: F401
from repro.analysis.source_lint import lint_file, lint_tree  # noqa: F401
