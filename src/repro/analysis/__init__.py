"""repro.analysis — the static-analysis plane enforcing hot-path contracts.

Three passes, one CLI (``python -m repro.analysis``):

- **Pass 1, jaxpr contract checker** (:mod:`repro.analysis.jaxpr_lint` +
  :mod:`repro.analysis.contracts`): traces a registry of engine entry
  points (every ``IngestEngine`` backend, every ``QueryEngine`` family,
  ``refresh_closure``, the subscription tick, each ``kernels/*/ops.py``
  wrapper, the distributed plane, the turnstile-delete and
  window-advance session boundaries) and checks declarative contracts on
  the traced jaxprs — no host callbacks, no wide-dtype promotion, no
  full-counter reduction for register-served families, buffer donation
  applied through the jit boundary, collectives only under ``shard_map``,
  and at most one trace per family per shape signature.
- **Pass 2, source lint** (:mod:`repro.analysis.source_lint`): AST rules
  specific to this codebase — ``jax.jit`` only in the engine cache
  modules, no host syncs in traced modules, no ``jnp.*`` inside Python
  loops in hot modules, ``REPRO_*`` env reads only at dispatch
  boundaries, and every Pallas kernel keeps a registered ref +
  bit-equality test.
- **Pass 3, costlint** (:mod:`repro.analysis.costlint` + the cost
  registry in :mod:`repro.analysis.contracts`): lowers-and-compiles each
  cost entry point at 2–3 geometrically spaced sizes, pulls XLA's
  ``cost_analysis()`` / ``memory_analysis()``, fits per-axis scaling
  exponents, and machine-checks the paper's complexity claims — ingest
  O(B·d) and O(1) in tenants, register-served queries O(d·Q) independent
  of width, closure refresh O(T_touched·w²) — plus the memory-side
  donation proof and the absolute ceilings committed in
  ``ANALYSIS_BUDGETS.json`` (ratcheted via ``--update-budgets``).

Pre-existing violations are either fixed or explicitly baselined with a
one-line justification in ``baseline.json`` (prunable via
``--prune-baseline``); the CLI exits nonzero on any NEW (unbaselined)
violation.  DESIGN.md Sections 9 and 12 have the architecture and the
full contract tables.
"""
from repro.analysis.contracts import (  # noqa: F401
    COST_ENTRY_POINTS,
    AxisContract,
    CostEntryPoint,
    CostProbe,
    ENTRY_POINTS,
    EntryPoint,
    TracedEntry,
    Violation,
    apply_baseline,
)
from repro.analysis.costlint import (  # noqa: F401
    budgets_from_measurements,
    cost_table_markdown,
    load_budgets,
    measure_entry,
    run_cost_pass,
)
from repro.analysis.jaxpr_lint import (  # noqa: F401
    reduces_full_counters,
    run_jaxpr_pass,
    walk_jaxprs,
)
from repro.analysis.runner import main, run_analysis  # noqa: F401
from repro.analysis.source_lint import lint_file, lint_tree  # noqa: F401
