import sys

from repro.analysis.runner import main

sys.exit(main(sys.argv[1:]))
