"""Accepted pre-existing violations, each with a one-line justification.

The baseline lives in ``baseline.json`` next to this module so the CLI
can prune it programmatically (``--prune-baseline``).  Entries are keyed
``(rule, subject)`` — subjects use the same spelling the passes emit
(``path::scope:lineno`` for source findings, the entry-point name for
jaxpr/cost findings).  A baselined finding still appears in the report
(marked ``baselined``) but does not fail the CLI; REMOVE the entry when
the underlying code is fixed, so the gate starts protecting it.

Line numbers in subjects make baselines brittle on purpose: moving the
code re-surfaces the finding for re-review.  The staleness check runs
the other direction — an entry whose pass ran but which matched no
current violation is dead weight (the code was fixed, or the subject
moved) and is flagged / prunable.

Context for the committed entries: the seven ``direct-jit`` kernel sites
are module-scope ``@functools.partial(jax.jit, ...)`` decorators on
fixed-shape Pallas wrappers — one decorator site per kernel, traced once
per (shape, interpret) signature; these ARE the kernel plane's cache
modules.  The ``jnp-in-loop`` site is ``_run_padded``'s host-side chunk
loop, which bounds the number of distinct padded shapes the jit cache
ever sees (DESIGN.md Section 5); ``jnp.pad`` there stages the next
dispatch's operand, it is not traced work.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

BASELINE_PATH = pathlib.Path(__file__).with_name("baseline.json")

# Which pass emits which rule — staleness is only decidable for rules
# whose pass actually ran this invocation.
RULE_PASS: Dict[str, str] = {
    # source pass
    "direct-jit": "source",
    "host-sync": "source",
    "jnp-in-loop": "source",
    "env-read": "source",
    "kernel-ref": "source",
    # jaxpr pass
    "no-host-callback": "jaxpr",
    "no-wide-dtype": "jaxpr",
    "no-counter-reduction": "jaxpr",
    "collectives-under-shard-map": "jaxpr",
    "donation-applied": "jaxpr",
    "retrace": "jaxpr",
    "entry-point-broken": "jaxpr",
    # costlint pass
    "cost-exponent": "costlint",
    "cost-donation-memory": "costlint",
    "cost-budget": "costlint",
    "cost-entry-broken": "costlint",
}


def load_baseline(
    path: Optional[pathlib.Path] = None,
) -> Dict[Tuple[str, str], str]:
    p = pathlib.Path(path) if path is not None else BASELINE_PATH
    if not p.exists():
        return {}
    return {
        (e["rule"], e["subject"]): e["justification"]
        for e in json.loads(p.read_text())
    }


BASELINE: Dict[Tuple[str, str], str] = load_baseline()


def stale_baseline_entries(
    baseline: Dict[Tuple[str, str], str],
    violations: Iterable,
    passes: Sequence[str],
) -> List[Tuple[str, str]]:
    """Baseline keys whose rule's pass ran this invocation but which
    matched no violation (baselined or not) — the accepted debt no longer
    exists, so the entry should be deleted before it masks a new finding
    at the same site."""
    seen = {(v.rule, v.subject) for v in violations}
    return [
        key
        for key in baseline
        if RULE_PASS.get(key[0]) in passes and key not in seen
    ]


def prune_baseline(
    stale: Sequence[Tuple[str, str]],
    path: Optional[pathlib.Path] = None,
) -> int:
    """Delete ``stale`` keys from the baseline file; returns the number of
    entries removed."""
    p = pathlib.Path(path) if path is not None else BASELINE_PATH
    if not p.exists() or not stale:
        return 0
    dead = set(stale)
    entries = json.loads(p.read_text())
    kept = [e for e in entries if (e["rule"], e["subject"]) not in dead]
    removed = len(entries) - len(kept)
    if removed:
        p.write_text(json.dumps(kept, indent=1) + "\n")
    return removed
