"""Accepted pre-existing violations, each with a one-line justification.

Keyed ``(rule, subject)`` — subjects use the same spelling the passes
emit (``path::scope:lineno`` for source findings, the entry-point name
for jaxpr findings).  A baselined finding still appears in the report
(marked ``baselined``) but does not fail the CLI; REMOVE the entry when
the underlying code is fixed, so the gate starts protecting it.

Line numbers in subjects make baselines brittle on purpose: moving the
code re-surfaces the finding for re-review.
"""
from __future__ import annotations

from typing import Dict, Tuple

BASELINE: Dict[Tuple[str, str], str] = {
    # Module-scope @functools.partial(jax.jit, ...) on the fixed-shape
    # Pallas wrappers: one decorator site per kernel, traced once per
    # (shape, interpret) signature — these ARE the kernel plane's cache
    # modules, there is no per-family cache to fragment.
    ("direct-jit", "kernels/closure/kernel.py::closure_step_pallas:41"):
        "module-scope jit of a fixed-shape Pallas wrapper (kernel-plane cache site)",
    ("direct-jit", "kernels/flow/kernel.py::flows_pallas:38"):
        "module-scope jit of a fixed-shape Pallas wrapper (kernel-plane cache site)",
    ("direct-jit", "kernels/countsketch/kernel.py::countsketch_pallas:46"):
        "module-scope jit of a fixed-shape Pallas wrapper (kernel-plane cache site)",
    ("direct-jit", "kernels/query/kernel.py::multi_query_pallas:98"):
        "module-scope jit of a fixed-shape Pallas wrapper (kernel-plane cache site)",
    ("direct-jit", "kernels/query/kernel.py::query_pallas:121"):
        "module-scope jit of a fixed-shape Pallas wrapper (kernel-plane cache site)",
    ("direct-jit", "kernels/ingest/kernel.py::ingest_pallas:58"):
        "module-scope jit of a fixed-shape Pallas wrapper (kernel-plane cache site)",
    ("direct-jit", "kernels/ingest_fused/kernel.py::fused_ingest_pallas:97"):
        "module-scope jit of a fixed-shape Pallas wrapper (kernel-plane cache site)",
    # _run_padded's chunk loop runs on the HOST between jit dispatches by
    # design: it bounds the number of distinct padded shapes the jit cache
    # ever sees (DESIGN.md Section 5); jnp.pad here prepares the next
    # dispatch's operand, it is not traced work.
    ("jnp-in-loop", "core/query_engine.py::_run_padded:179"):
        "host-side chunk loop; jnp.pad stages the next bounded-shape dispatch",
}
