"""Entry-point registry + declarative hot-path contracts.

The repo's performance story rests on invariants that used to live in one
test or nowhere: register-served queries never reduce the full counter
tensor, hot paths never sync to the host, the ingest jit boundary donates
the sketch buffers, collectives only ever run under ``shard_map``, and one
jit trace serves a whole family per shape signature.  This module makes
those invariants DATA: every engine entry point registers here with the
contracts it must satisfy, and :mod:`repro.analysis.jaxpr_lint` checks
them against the traced jaxprs.

Contract vocabulary (see DESIGN.md Section 9 for the full table):

``no-host-callback``            no host-transfer/callback primitive in the
                                traced jaxpr (``pure_callback`` & co.).
``no-wide-dtype``               no float64/int64/complex128 aval anywhere —
                                a weak-type or x64 promotion doubles HBM
                                traffic silently.
``no-counter-reduction``        no reduction primitive consumes an operand
                                of the full (d, w_r, w_c) counter shape —
                                the register-served O(d·Q) guarantee.
``collectives-under-shard-map`` psum/pmin/all_gather/... appear only inside
                                a ``shard_map`` sub-jaxpr.
``donation-applied``            the jit boundary actually aliases the
                                donated sketch buffers into its outputs
                                (``tf.aliasing_output`` in the lowering) —
                                a dropped donation silently re-adds the
                                full-sketch copy per batch.

Dynamic contracts (the retrace detector) live in :data:`DYNAMIC_CHECKS`:
they drive the real engines twice with value-identical but object-fresh
inputs and assert the jit/closure caches do not grow — catching cache-key
bugs like the by-``is`` closure-cache miss fixed in PR 5.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# violations + baseline
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Violation:
    """One contract breach: ``rule`` names the contract/lint rule,
    ``subject`` the entry point or ``file::function``, ``message`` the
    specifics.  ``baselined`` marks a pre-existing, justified breach."""

    rule: str
    subject: str
    message: str
    pass_name: str
    baselined: bool = False
    justification: str = ""

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        tag = "~" if self.baselined else "!"
        line = f"{tag} [{self.pass_name}] {self.rule} {self.subject}: {self.message}"
        if self.baselined:
            line += f"  (baselined: {self.justification})"
        return line


def apply_baseline(
    violations: List[Violation], baseline: Optional[Dict[Tuple[str, str], str]]
) -> List[Violation]:
    """Mark violations whose (rule, subject) carries a baseline entry."""
    if not baseline:
        return list(violations)
    out = []
    for v in violations:
        just = baseline.get((v.rule, v.subject))
        if just is not None and not v.baselined:
            v = dataclasses.replace(v, baselined=True, justification=just)
        out.append(v)
    return out


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TracedEntry:
    """What one entry point hands the jaxpr checker: a traceable ``fn`` +
    ``args``, the counter-tensor shape for the reduction rule, and (for the
    donation contract) the jit-wrapped callable to lower."""

    fn: Callable
    args: Tuple
    counters_shape: Optional[Tuple[int, ...]] = None
    jit_fn: Optional[Callable] = None


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    name: str
    contracts: Tuple[str, ...]
    build: Callable[[], TracedEntry]


HOT = ("no-host-callback", "no-wide-dtype", "collectives-under-shard-map")
REGISTER_SERVED = HOT + ("no-counter-reduction",)

_FIXTURE_WIDTH = 64
_FIXTURE_DEPTH = 2


def _fixture_sketch():
    import jax
    import jax.numpy as jnp

    from repro.core.sketch import GLavaSketch, SketchConfig

    cfg = SketchConfig(
        depth=_FIXTURE_DEPTH, width_rows=_FIXTURE_WIDTH, width_cols=_FIXTURE_WIDTH
    )
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    src = jnp.arange(8, dtype=jnp.uint32)
    dst = jnp.arange(8, 16, dtype=jnp.uint32)
    w = jnp.ones(8, jnp.float32)
    return sk, src, dst, w


def copy_sketch(sk):
    """Value-identical sketch with FRESH array objects (and fresh hash-family
    arrays) — the retrace detector's probe for identity-keyed caches."""
    import jax
    import jax.numpy as jnp

    return jax.tree_util.tree_map(lambda x: jnp.asarray(np.asarray(x)), sk)


def _ingest_entry(backend: str) -> Callable[[], TracedEntry]:
    def build():
        from repro.core.ingest import ingest

        sk, src, dst, w = _fixture_sketch()
        rows, cols = sk.hash_edges(src, dst)
        return TracedEntry(
            fn=lambda c, r, cc, ww: ingest(c, r, cc, ww, backend=backend),
            args=(sk.counters, rows, cols, w),
        )

    return build


def _ingest_jit_boundary() -> TracedEntry:
    """The GraphStream ingest jit boundary — the REAL session callable, so
    the donation contract breaks if ``GraphStream.__init__`` stops donating
    the sketch pytree."""
    from repro.api.stream import GraphStream
    from repro.core.sketch import SketchConfig

    gs = GraphStream.open(
        SketchConfig(
            depth=_FIXTURE_DEPTH,
            width_rows=_FIXTURE_WIDTH,
            width_cols=_FIXTURE_WIDTH,
        ),
        ingest_backend="scatter",
        query_backend="jnp",
    )
    import jax

    _, src, dst, w = _fixture_sketch()
    leaves = jax.tree_util.tree_leaves(gs._sketch)
    uniq = tuple(leaves[i] for i in gs._uniq_leaf_idx)
    return TracedEntry(
        fn=gs._jit_update,
        args=(uniq, src, dst, w),
        jit_fn=gs._jit_update,
    )


def _preagg_entry() -> TracedEntry:
    """In-jit pre-aggregation: sort + segment-sum collapse, static shapes."""
    from repro.core.ingest import preaggregate_edges

    _, src, dst, w = _fixture_sketch()
    return TracedEntry(
        lambda s, d, ww: preaggregate_edges(s, d, ww, out_size=4),
        (src, dst, w),
    )


def _preagg_update_entry() -> TracedEntry:
    """The full pre-aggregated update (collapse + cond + scatter)."""
    sk, src, dst, w = _fixture_sketch()
    return TracedEntry(
        lambda s, d, ww: sk.update(
            s, d, ww, backend="scatter", preagg="on"
        ).counters,
        (src, dst, w),
        tuple(sk.counters.shape),
    )


def _preagg_jit_boundary() -> TracedEntry:
    """The GraphStream host-collapsed dispatch boundary — the REAL session
    callable, so the donation contract breaks if ``_jit_update_pre`` stops
    donating the sketch pytree."""
    import jax
    import jax.numpy as jnp

    from repro.api.stream import GraphStream
    from repro.core.ingest import preaggregate_host
    from repro.core.sketch import SketchConfig

    gs = GraphStream.open(
        SketchConfig(
            depth=_FIXTURE_DEPTH,
            width_rows=_FIXTURE_WIDTH,
            width_cols=_FIXTURE_WIDTH,
        ),
        ingest_backend="scatter",
        query_backend="jnp",
    )
    _, src, dst, w = _fixture_sketch()
    pre = preaggregate_host(np.asarray(src), np.asarray(dst), np.asarray(w))
    leaves = jax.tree_util.tree_leaves(gs._sketch)
    uniq = tuple(leaves[i] for i in gs._uniq_leaf_idx)
    args = (
        uniq,
        jnp.asarray(pre.src),
        jnp.asarray(pre.dst),
        jnp.asarray(pre.weights),
        jnp.asarray(pre.src_unique),
        jnp.asarray(pre.src_totals),
        jnp.asarray(pre.dst_unique),
        jnp.asarray(pre.dst_totals),
    )
    return TracedEntry(
        fn=gs._jit_update_pre, args=args, jit_fn=gs._jit_update_pre
    )


def _fused_update_entry() -> TracedEntry:
    """The fused one-pass session update (ref twin off TPU)."""
    sk, src, dst, w = _fixture_sketch()
    return TracedEntry(
        lambda s, d, ww: sk.update_fused(s, d, ww)[0].counters,
        (src, dst, w),
        tuple(sk.counters.shape),
    )


def _delete_jit_boundary() -> TracedEntry:
    """The turnstile-delete session boundary (paper Section 6.1.1) — the
    SAME donated jit the additive path uses, traced with negative weights,
    so a delete-specific regression (say, a re-derive of the flow registers
    by full reduction) cannot hide from the contracts."""
    from repro.api.stream import GraphStream

    jit_fn, args, shape = GraphStream.cost_probe_update(
        width=_FIXTURE_WIDTH, depth=_FIXTURE_DEPTH, batch=8, negative=True
    )
    return TracedEntry(fn=jit_fn, args=args, counters_shape=shape, jit_fn=jit_fn)


def _advance_window_boundary() -> TracedEntry:
    """The sliding-window advance boundary: donated ring expiry.  The
    counter shape here is the whole (K, d, w_r, w_c) ring — advance must
    stay pure data movement (zero the expiring slice in place), never a
    whole-ring reduction to re-derive the flow registers."""
    from repro.api.stream import GraphStream

    jit_fn, args, shape = GraphStream.cost_probe_advance(
        width=_FIXTURE_WIDTH, depth=_FIXTURE_DEPTH, slices=4
    )
    return TracedEntry(fn=jit_fn, args=args, counters_shape=shape, jit_fn=jit_fn)


def _update_slice_boundary() -> TracedEntry:
    """The event-time slice-routing boundary (DESIGN.md Section 13): one
    batch folded into ONE ring slot, with the slot riding as a traced
    int32 — a single compiled update must serve every slice, and the whole
    (K, d, w_r, w_c) ring must pass through by donation, never by copy."""
    from repro.api.stream import GraphStream

    jit_fn, args, shape = GraphStream.cost_probe_update_slice(
        width=_FIXTURE_WIDTH, depth=_FIXTURE_DEPTH, slices=4, batch=8
    )
    return TracedEntry(fn=jit_fn, args=args, counters_shape=shape, jit_fn=jit_fn)


def _query_entry(family: str) -> Callable[[], TracedEntry]:
    def build():
        import jax.numpy as jnp

        from repro.core import queries, reach

        sk, src, dst, w = _fixture_sketch()
        shape = tuple(sk.counters.shape)
        theta = jnp.asarray(10.0, jnp.float32)
        thetas = jnp.full(src.shape, 0.5, jnp.float32)
        if family == "edge":
            return TracedEntry(queries.edge_query, (sk, src, dst), shape)
        if family == "edge.pallas":
            from repro.core.query_engine import _pallas_edge_query

            return TracedEntry(_pallas_edge_query, (sk, src, dst), shape)
        if family in ("in_flow", "out_flow", "flow"):
            fn = getattr(queries, f"node_{family}" if family != "flow" else "node_flow")
            return TracedEntry(fn, (sk, src), shape)
        if family == "heavy":
            return TracedEntry(queries.check_heavy_keys, (sk, src, theta), shape)
        if family == "heavy_vec":
            return TracedEntry(queries.check_heavy_keys_vec, (sk, src, thetas), shape)
        if family == "heavy_rel_vec":
            return TracedEntry(
                queries.check_heavy_keys_rel_vec, (sk, src, thetas), shape
            )
        if family == "monitor_step":
            return TracedEntry(
                lambda s, a, b, ww, watch: queries.monitor_step(
                    s, a, b, ww, watch, theta=100.0
                ),
                (sk, src, dst, w, src[0]),
                shape,
            )
        if family == "subgraph":
            return TracedEntry(queries.subgraph_query, (sk, src[:3], dst[:3]), shape)
        if family == "subgraph_batch":
            s2 = jnp.stack([src[:4], src[4:]])
            d2 = jnp.stack([dst[:4], dst[4:]])
            mask = jnp.ones(s2.shape, bool)
            return TracedEntry(
                queries.subgraph_query_batch, (sk, s2, d2, mask), shape
            )
        if family == "reach_pre":
            closure = reach.transitive_closure(sk.counters)
            return TracedEntry(
                reach.reach_query_precomputed, (sk, closure, src, src), shape
            )
        if family == "closure":
            return TracedEntry(reach.transitive_closure, (sk.counters,), shape)
        if family == "closure_refresh":
            closure = reach.transitive_closure(sk.counters)
            rows = sk.row_hash(src)
            return TracedEntry(
                reach.closure_refresh, (closure, sk.counters, rows), shape
            )
        raise ValueError(f"no fixture for query family {family!r}")

    return build


def _kernel_entry(name: str) -> Callable[[], TracedEntry]:
    def build():
        import jax.numpy as jnp

        sk, src, dst, w = _fixture_sketch()
        if name == "ingest":
            from repro.kernels.ingest import ops

            rows, cols = sk.hash_edges(src, dst)
            return TracedEntry(ops.sketch_ingest, (sk.counters, rows, cols, w))
        if name == "query":
            from repro.kernels.query import ops

            rows, cols = sk.hash_edges(src, dst)
            return TracedEntry(
                lambda c, r, cc: ops.edge_query_min(c, r, cc, interpret=True),
                (sk.counters, rows, cols),
            )
        if name == "closure":
            from repro.kernels.closure import ops

            return TracedEntry(
                lambda c: ops.transitive_closure(c, interpret=True), (sk.counters,)
            )
        if name == "flow":
            from repro.kernels.flow import ops

            return TracedEntry(
                lambda c: ops.flows(c, interpret=True), (sk.counters,)
            )
        if name == "ingest_fused":
            from repro.kernels.ingest_fused import ops

            rows, cols = sk.hash_edges(src, dst)
            return TracedEntry(
                lambda c, rf, cf, r, cc, ww: ops.fused_ingest(
                    c, rf, cf, r, cc, ww, interpret=True
                ),
                (sk.counters, sk.row_flows, sk.col_flows, rows, cols, w),
            )
        if name == "countsketch":
            from repro.kernels.countsketch import ops

            fam = sk.row_hash
            vec = jnp.arange(512, dtype=jnp.float32)
            return TracedEntry(
                lambda v: ops.countsketch(v, fam, interpret=True), (vec,)
            )
        raise ValueError(f"no fixture for kernel {name!r}")

    return build


def _single_device_mesh():
    import jax

    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model")
    )


def _distributed_ingest_entry() -> TracedEntry:
    from repro.core.distributed import distributed_ingest

    sk, src, dst, w = _fixture_sketch()
    mesh = _single_device_mesh()
    return TracedEntry(
        lambda s, d, ww: distributed_ingest(mesh, sk, s, d, ww).counters,
        (src, dst, w),
    )


def _distributed_point_entry() -> TracedEntry:
    from repro.core.distributed import distributed_point_query

    sk, src, dst, w = _fixture_sketch()
    mesh = _single_device_mesh()
    return TracedEntry(
        lambda keys: distributed_point_query(
            mesh, sk, keys, use_registers=False
        ),
        (src,),
    )


def _fleet_fixture():
    """A 4-slot fleet stack plus mixed query lanes (slot as a DATA lane)."""
    import jax
    import jax.numpy as jnp

    from repro.core.sketch import SketchConfig
    from repro.fleet.stack import FleetSketch

    cfg = SketchConfig(
        depth=_FIXTURE_DEPTH, width_rows=_FIXTURE_WIDTH, width_cols=_FIXTURE_WIDTH
    )
    st = FleetSketch.empty(cfg, 4, jax.random.key(0))
    slots = jnp.tile(jnp.arange(4, dtype=jnp.int32), 2)
    src = jnp.arange(8, dtype=jnp.uint32)
    dst = jnp.arange(8, 16, dtype=jnp.uint32)
    w = jnp.ones(8, jnp.float32)
    return st, slots, src, dst, w


def _fleet_ingest_entry() -> TracedEntry:
    """The stacked scatter — T tenants folded by ONE update trace."""
    st, slots, src, dst, w = _fleet_fixture()
    return TracedEntry(
        lambda sl, s, d, ww: st.update(sl, s, d, ww).counters,
        (slots, src, dst, w),
        tuple(st.counters.shape),
    )


def _fleet_ingest_jit_boundary() -> TracedEntry:
    """The REAL FleetIngestEngine donated dispatch — the donation contract
    breaks if the engine stops donating the stacked pytree."""
    import jax

    from repro.fleet.ingest import FleetIngestEngine

    st, slots, src, dst, w = _fleet_fixture()
    eng = FleetIngestEngine(st)
    leaves = jax.tree_util.tree_leaves(st)
    uniq = tuple(leaves[i] for i in eng._uniq_leaf_idx)
    return TracedEntry(
        fn=eng._jit_update,
        args=(uniq, slots, src, dst, w),
        jit_fn=eng._jit_update,
    )


def _fleet_query_entry(family: str) -> Callable[[], TracedEntry]:
    def build():
        import jax.numpy as jnp

        from repro.fleet import query as fq

        st, slots, src, dst, w = _fleet_fixture()
        shape = tuple(st.counters.shape)
        if family == "edge":
            return TracedEntry(fq.fleet_edge_query, (st, slots, src, dst), shape)
        if family in ("in_flow", "out_flow", "flow"):
            fn = getattr(fq, f"fleet_{family}")
            return TracedEntry(fn, (st, slots, src), shape)
        if family == "heavy_rel_vec":
            thetas = jnp.full(src.shape, 0.5, jnp.float32)
            return TracedEntry(
                fq.fleet_heavy_rel_vec, (st, slots, src, thetas), shape
            )
        if family == "subgraph_batch":
            s2 = jnp.stack([src[:4], src[4:]])
            d2 = jnp.stack([dst[:4], dst[4:]])
            mask = jnp.ones(s2.shape, bool)
            return TracedEntry(
                fq.fleet_subgraph_batch,
                (st, slots[: s2.shape[0]], s2, d2, mask),
                shape,
            )
        sel = jnp.arange(4, dtype=jnp.int32)
        if family == "reach_pre":
            closures = fq.fleet_closure_build(st.counters, sel)
            return TracedEntry(
                fq.fleet_reach_pre, (st, closures, slots, src, dst), shape
            )
        if family == "closure":
            return TracedEntry(fq.fleet_closure_build, (st.counters, sel), shape)
        if family == "closure_refresh":
            closures = fq.fleet_closure_build(st.counters, sel)
            rows = jnp.tile(st.row_hash(src[:4])[None], (4, 1, 1))
            return TracedEntry(
                fq.fleet_closure_refresh,
                (closures, st.counters, sel, rows),
                shape,
            )
        raise ValueError(f"no fixture for fleet query family {family!r}")

    return build


ENTRY_POINTS: Tuple[EntryPoint, ...] = (
    # -- every IngestEngine backend dispatch ------------------------------
    EntryPoint("ingest.scatter", HOT, _ingest_entry("scatter")),
    EntryPoint("ingest.onehot", HOT, _ingest_entry("onehot")),
    EntryPoint("ingest.pallas", HOT, _ingest_entry("pallas")),
    # -- the session ingest jit boundary (donated sketch buffers) ---------
    EntryPoint(
        "ingest.jit_boundary", HOT + ("donation-applied",), _ingest_jit_boundary
    ),
    # -- the heavy-tail fast path: pre-aggregation + fused one-pass ingest --
    EntryPoint("ingest.preagg", HOT, _preagg_entry),
    EntryPoint("ingest.preagg_update", HOT, _preagg_update_entry),
    EntryPoint(
        "ingest.preagg_boundary",
        HOT + ("donation-applied",),
        _preagg_jit_boundary,
    ),
    EntryPoint("ingest.fused_update", HOT, _fused_update_entry),
    # -- the session boundaries that used to escape the registry -----------
    EntryPoint(
        "ingest.delete_boundary",
        REGISTER_SERVED + ("donation-applied",),
        _delete_jit_boundary,
    ),
    EntryPoint(
        "window.advance_boundary",
        REGISTER_SERVED + ("donation-applied",),
        _advance_window_boundary,
    ),
    # -- the event-time plane: watermark-routed slice updates ---------------
    EntryPoint(
        "stream.update_slice_boundary",
        REGISTER_SERVED + ("donation-applied",),
        _update_slice_boundary,
    ),
    # -- every QueryEngine family -----------------------------------------
    EntryPoint("query.edge", HOT, _query_entry("edge")),
    EntryPoint("query.edge.pallas", HOT, _query_entry("edge.pallas")),
    EntryPoint("query.in_flow", REGISTER_SERVED, _query_entry("in_flow")),
    EntryPoint("query.out_flow", REGISTER_SERVED, _query_entry("out_flow")),
    EntryPoint("query.flow", REGISTER_SERVED, _query_entry("flow")),
    EntryPoint("query.heavy", REGISTER_SERVED, _query_entry("heavy")),
    EntryPoint("query.heavy_vec", REGISTER_SERVED, _query_entry("heavy_vec")),
    EntryPoint(
        "query.heavy_rel_vec", REGISTER_SERVED, _query_entry("heavy_rel_vec")
    ),
    EntryPoint(
        "query.monitor_step", REGISTER_SERVED, _query_entry("monitor_step")
    ),
    EntryPoint("query.subgraph", HOT, _query_entry("subgraph")),
    EntryPoint("query.subgraph_batch", HOT, _query_entry("subgraph_batch")),
    EntryPoint("query.reach_pre", REGISTER_SERVED, _query_entry("reach_pre")),
    EntryPoint("query.closure", HOT, _query_entry("closure")),
    EntryPoint("query.closure_refresh", HOT, _query_entry("closure_refresh")),
    # -- every kernels/*/ops.py wrapper (interpret-mode trace) -------------
    EntryPoint("kernels.ingest.ops", HOT, _kernel_entry("ingest")),
    EntryPoint(
        "kernels.ingest_fused.ops", HOT, _kernel_entry("ingest_fused")
    ),
    EntryPoint("kernels.query.ops", HOT, _kernel_entry("query")),
    EntryPoint("kernels.closure.ops", HOT, _kernel_entry("closure")),
    EntryPoint("kernels.flow.ops", HOT, _kernel_entry("flow")),
    EntryPoint("kernels.countsketch.ops", HOT, _kernel_entry("countsketch")),
    # -- the distributed plane (collectives MUST sit under shard_map) ------
    EntryPoint("distributed.ingest", HOT, _distributed_ingest_entry),
    EntryPoint("distributed.point_query", HOT, _distributed_point_entry),
    # -- the fleet plane: T tenants, one dispatch (DESIGN.md Section 11) ---
    EntryPoint("fleet.ingest.update", HOT, _fleet_ingest_entry),
    EntryPoint(
        "fleet.ingest.jit_boundary",
        HOT + ("donation-applied",),
        _fleet_ingest_jit_boundary,
    ),
    EntryPoint("fleet.query.edge", HOT, _fleet_query_entry("edge")),
    EntryPoint(
        "fleet.query.in_flow", REGISTER_SERVED, _fleet_query_entry("in_flow")
    ),
    EntryPoint(
        "fleet.query.out_flow", REGISTER_SERVED, _fleet_query_entry("out_flow")
    ),
    EntryPoint("fleet.query.flow", REGISTER_SERVED, _fleet_query_entry("flow")),
    EntryPoint(
        "fleet.query.heavy_rel_vec",
        REGISTER_SERVED,
        _fleet_query_entry("heavy_rel_vec"),
    ),
    EntryPoint(
        "fleet.query.subgraph_batch", HOT, _fleet_query_entry("subgraph_batch")
    ),
    EntryPoint(
        "fleet.query.reach_pre",
        REGISTER_SERVED,
        _fleet_query_entry("reach_pre"),
    ),
    EntryPoint("fleet.query.closure", HOT, _fleet_query_entry("closure")),
    EntryPoint(
        "fleet.query.closure_refresh",
        HOT,
        _fleet_query_entry("closure_refresh"),
    ),
)


# ---------------------------------------------------------------------------
# pass 3: compiled-cost contracts (costlint)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AxisContract:
    """Declared scaling ceiling along ONE problem-size axis: the log-log
    least-squares slope of ``metric`` over the geometric ``sizes`` ladder
    must stay within ``exponent + tol``.

    ``metric`` defaults to "flops" because XLA's "bytes accessed" counts
    whole-operand reads — the register planes and the fleet stack are read
    as operands, so bytes grow with w and T even for genuinely O(d·Q) /
    O(1)-in-T programs.  Flops is the clean per-query work signal; declare
    ``metric="bytes"`` only where traffic itself is the claim."""

    axis: str                   # "B" | "Q" | "T" | "w" | "S"
    exponent: float             # declared upper-bound exponent
    sizes: Tuple[int, ...]      # geometrically spaced probe sizes
    tol: float = 0.35
    metric: str = "flops"       # "flops" | "bytes"


@dataclasses.dataclass(frozen=True)
class CostProbe:
    """What one cost entry point hands the compiler at ONE size point: a
    traceable ``fn`` + ``args`` (``jit_fn`` when the callable is already a
    donated session boundary) plus the sketch-state bytes at this size —
    the donation memory proof compares alias/temp bytes against it."""

    fn: Callable
    args: Tuple
    jit_fn: Optional[Callable] = None
    state_bytes: int = 0


@dataclasses.dataclass(frozen=True)
class CostEntryPoint:
    """One compiled-cost contract.  ``build(**sizes)`` instantiates the
    probe at a size point (kwargs are the axis names); costlint compiles
    every point on each axis's ladder (the base point — every axis at its
    smallest size — is shared), pulls ``cost_analysis()`` +
    ``memory_analysis()``, fits per-axis exponents, and checks them against
    the declared ceilings, the donation memory proof (``donated=True``),
    and the committed absolute budgets (``ANALYSIS_BUDGETS.json``).
    ``edges_axis`` names the axis whose largest point normalizes the
    bytes-accessed budget to bytes/edge."""

    name: str
    axes: Tuple[AxisContract, ...]
    build: Callable[..., CostProbe]
    donated: bool = False
    edges_axis: Optional[str] = None


def _counters_nbytes(shape: Tuple[int, ...]) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return 4 * n  # float32 counters


def _cost_ingest_scatter(B: int = 64, w: int = 64) -> CostProbe:
    import jax
    import jax.numpy as jnp

    from repro.core.ingest import ingest
    from repro.core.sketch import GLavaSketch, SketchConfig

    cfg = SketchConfig(depth=_FIXTURE_DEPTH, width_rows=w, width_cols=w)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    src = jnp.arange(B, dtype=jnp.uint32)
    dst = src + jnp.uint32(B)
    rows, cols = sk.hash_edges(src, dst)
    wts = jnp.ones(B, jnp.float32)
    return CostProbe(
        fn=lambda c, r, cc, ww: ingest(c, r, cc, ww, backend="scatter"),
        args=(sk.counters, rows, cols, wts),
        state_bytes=_counters_nbytes(tuple(sk.counters.shape)),
    )


def _cost_ingest_boundary(B: int = 64, w: int = 64) -> CostProbe:
    from repro.api.stream import GraphStream

    jit_fn, args, shape = GraphStream.cost_probe_update(
        width=w, depth=_FIXTURE_DEPTH, batch=B
    )
    return CostProbe(
        fn=jit_fn, args=args, jit_fn=jit_fn,
        state_bytes=_counters_nbytes(shape),
    )


def _cost_update_slice_boundary(
    B: int = 64, w: int = 64, K: int = 4
) -> CostProbe:
    from repro.api.stream import GraphStream

    jit_fn, args, shape = GraphStream.cost_probe_update_slice(
        width=w, depth=_FIXTURE_DEPTH, slices=K, batch=B
    )
    return CostProbe(
        fn=jit_fn, args=args, jit_fn=jit_fn,
        state_bytes=_counters_nbytes(shape),
    )


def _cost_fleet_ingest_boundary(
    B: int = 64, T: int = 2, w: int = 64
) -> CostProbe:
    from repro.fleet.ingest import FleetIngestEngine

    jit_fn, args, shape = FleetIngestEngine.cost_probe(
        tenants=T, width=w, depth=_FIXTURE_DEPTH, batch=B
    )
    return CostProbe(
        fn=jit_fn, args=args, jit_fn=jit_fn,
        state_bytes=_counters_nbytes(shape),
    )


def _cost_query(family: str) -> Callable[..., CostProbe]:
    def build(Q: int = 32, w: int = 64) -> CostProbe:
        from repro.core.query_engine import QueryEngine

        fn, args, shape = QueryEngine.family_probe(
            family, width=w, depth=_FIXTURE_DEPTH, n_queries=Q
        )
        return CostProbe(
            fn=fn, args=args, state_bytes=_counters_nbytes(shape)
        )

    return build


def _cost_closure(family: str) -> Callable[..., CostProbe]:
    def build(w: int = 64) -> CostProbe:
        from repro.core.query_engine import QueryEngine

        fn, args, shape = QueryEngine.family_probe(
            family, width=w, depth=_FIXTURE_DEPTH
        )
        return CostProbe(
            fn=fn, args=args, state_bytes=_counters_nbytes(shape)
        )

    return build


def _cost_fleet_query(family: str) -> Callable[..., CostProbe]:
    def build(
        Q: int = 32, T: int = 2, w: int = 64, S: int = 2
    ) -> CostProbe:
        from repro.fleet.query import FleetQueryEngine

        fn, args, shape = FleetQueryEngine.family_probe(
            family,
            tenants=T,
            width=w,
            depth=_FIXTURE_DEPTH,
            n_queries=Q,
            touched=S,
        )
        return CostProbe(
            fn=fn, args=args, state_bytes=_counters_nbytes(shape)
        )

    return build


_B3 = (64, 128, 256)
_Q2 = (32, 128)
_T3 = (2, 4, 8)
_T2 = (2, 8)
_W2 = (32, 128)
_W3 = (32, 64, 128)
_S2 = (2, 8)

COST_ENTRY_POINTS: Tuple[CostEntryPoint, ...] = (
    # Paper Thm 1 / Section 3.2: maintenance is O(B·d) per batch and free
    # of the width — the hash + scatter never touch w-many cells.
    CostEntryPoint(
        "cost.ingest.scatter",
        (AxisContract("B", 1.0, _B3), AxisContract("w", 0.0, _W2)),
        _cost_ingest_scatter,
        edges_axis="B",
    ),
    CostEntryPoint(
        "cost.ingest.jit_boundary",
        (AxisContract("B", 1.0, _B3),),
        _cost_ingest_boundary,
        donated=True,
        edges_axis="B",
    ),
    # Event-time slice routing: O(B·d) scatter work plus ONE slice of
    # data movement — the traced-slot extract/store is O(d·w²), and the
    # ring length K must stay out of the per-batch cost entirely (a K
    # exponent > 0 would mean the boundary copies the whole ring instead
    # of riding the donated pass-through).
    CostEntryPoint(
        "cost.stream.update_slice",
        (
            AxisContract("B", 1.0, _B3),
            AxisContract("w", 2.0, _W2, tol=0.4),
            AxisContract("K", 0.0, (4, 8, 16)),
        ),
        _cost_update_slice_boundary,
        donated=True,
        edges_axis="B",
    ),
    # Fleet arrivals: the tenant axis rides the scatter INDEX, so T tenants
    # cost O(1) in T — the invariant PR 8's review had to catch by hand.
    CostEntryPoint(
        "cost.fleet.ingest_boundary",
        (AxisContract("B", 1.0, _B3), AxisContract("T", 0.0, _T3)),
        _cost_fleet_ingest_boundary,
        donated=True,
        edges_axis="B",
    ),
    # Register-served query families: O(d·Q) gathers, exponent ≈ 0 in w.
    CostEntryPoint(
        "cost.query.edge",
        (AxisContract("Q", 1.0, _Q2), AxisContract("w", 0.0, _W2)),
        _cost_query("edge"),
    ),
    CostEntryPoint(
        "cost.query.in_flow",
        (AxisContract("Q", 1.0, _Q2), AxisContract("w", 0.0, _W2)),
        _cost_query("in_flow"),
    ),
    CostEntryPoint(
        "cost.query.heavy_rel_vec",
        (AxisContract("Q", 1.0, _Q2), AxisContract("w", 0.0, _W2)),
        _cost_query("heavy_rel_vec"),
    ),
    # Fleet query families: the slot is a DATA lane — exponent ≈ 0 in T.
    CostEntryPoint(
        "cost.fleet.query.in_flow",
        (AxisContract("Q", 1.0, _Q2), AxisContract("T", 0.0, _T2)),
        _cost_fleet_query("in_flow"),
    ),
    CostEntryPoint(
        "cost.fleet.query.heavy_rel_vec",
        (AxisContract("Q", 1.0, _Q2), AxisContract("T", 0.0, _T2)),
        _cost_fleet_query("heavy_rel_vec"),
    ),
    # Closure maintenance: the touched-row refresh is O(T_touched·w²); only
    # the full rebuild may pay O(w³ log w).
    CostEntryPoint(
        "cost.query.closure_refresh",
        (AxisContract("w", 2.0, _W3, tol=0.4),),
        _cost_closure("closure_refresh"),
    ),
    CostEntryPoint(
        "cost.query.closure",
        (AxisContract("w", 3.0, _W3, tol=0.5),),
        _cost_closure("closure"),
    ),
    CostEntryPoint(
        "cost.fleet.closure_refresh",
        (AxisContract("w", 2.0, _W3, tol=0.4), AxisContract("S", 1.0, _S2)),
        _cost_fleet_query("closure_refresh"),
    ),
)


# ---------------------------------------------------------------------------
# dynamic contracts — the retrace detector
# ---------------------------------------------------------------------------


def _cache_size(jitted) -> Optional[int]:
    return jitted._cache_size() if hasattr(jitted, "_cache_size") else None


def check_retrace_query_families(engine_cls=None) -> List[Violation]:
    """At most ONE trace per family per shape signature: dispatch each
    family twice — the second time with value-identical but object-fresh
    sketch/key arrays — and assert the per-family jit cache did not grow.
    A second trace means the cache key depends on object identity or on a
    value that changes per batch (the class of bug PR 5 fixed)."""
    import jax.numpy as jnp

    from repro.core.query_engine import QueryEngine

    engine_cls = engine_cls or QueryEngine
    eng = engine_cls("jnp", pad_q=8)
    sk, src, dst, w = _fixture_sketch()
    thetas = jnp.full(src.shape, 0.5, jnp.float32)
    calls = {
        "edge": lambda e, s, fresh: e.edge(s, *fresh((src, dst))),
        "in_flow": lambda e, s, fresh: e.in_flow(s, *fresh((src,))),
        "out_flow": lambda e, s, fresh: e.out_flow(s, *fresh((src,))),
        "flow": lambda e, s, fresh: e.flow(s, *fresh((src,))),
        "heavy_rel_vec": lambda e, s, fresh: e.heavy_rel_vec(
            s, *fresh((src, thetas))
        ),
    }
    out: List[Violation] = []
    for family, call in calls.items():
        call(eng, sk, lambda xs: xs)
        sizes = {f: _cache_size(fn) for f, fn in eng._jits.items()}
        fresh = lambda xs: tuple(jnp.asarray(np.asarray(x)) for x in xs)
        call(eng, copy_sketch(sk), fresh)
        for f, fn in eng._jits.items():
            before, after = sizes.get(f), _cache_size(fn)
            if before is not None and after is not None and after > before:
                out.append(
                    Violation(
                        rule="retrace",
                        subject=f"query.{family}",
                        message=(
                            f"family {f!r} re-traced on a value-identical "
                            f"same-shape dispatch ({before} -> {after} cache "
                            "entries): jit cache key leaks per-batch state"
                        ),
                        pass_name="jaxpr",
                    )
                )
    return out


def check_closure_cache_value_keyed() -> List[Violation]:
    """The epoch-tagged closure cache must key the hash family BY VALUE:
    jit-updated sketches carry fresh array objects every batch, so an
    identity-keyed cache rebuilds the O(w³ log w) closure per batch (the
    exact PR 5 bug)."""
    import jax.numpy as jnp

    from repro.core.query_engine import QueryEngine

    eng = QueryEngine("jnp", pad_q=8)
    sk, src, _, _ = _fixture_sketch()
    q = src[:2]
    eng.reach(sk, q, q, epoch=0)
    builds = eng.closure_refreshes
    eng.reach(copy_sketch(sk), jnp.asarray(np.asarray(q)), q, epoch=0)
    if eng.closure_refreshes != builds:
        return [
            Violation(
                rule="retrace",
                subject="query.reach.closure_cache",
                message=(
                    "closure cache MISSED on a value-identical sketch at the "
                    "same epoch — the cache key depends on array object "
                    "identity instead of hash-family value"
                ),
                pass_name="jaxpr",
            )
        ]
    return []


def check_subscription_tick() -> List[Violation]:
    """The subscription tick contract: over N additions-only mutations, a
    standing reach+flow+edge batch performs exactly ONE full closure build,
    N-1 incremental touched-row refreshes, and never re-traces a family
    after its first tick."""
    from repro.api.query import Query
    from repro.api.stream import GraphStream
    from repro.core.sketch import SketchConfig

    gs = GraphStream.open(
        SketchConfig(
            depth=_FIXTURE_DEPTH,
            width_rows=_FIXTURE_WIDTH,
            width_cols=_FIXTURE_WIDTH,
        ),
        ingest_backend="scatter",
        query_backend="jnp",
    )
    gs.subscribe(
        Query.reach(1, 2), Query.in_flow(2), Query.edge(1, 2), every=1
    )
    rng = np.random.default_rng(0)
    sizes_after_first: Dict[str, Optional[int]] = {}
    n_ticks = 3
    for tick in range(n_ticks):
        src = rng.integers(0, 30, 6).astype(np.uint32)
        dst = rng.integers(0, 30, 6).astype(np.uint32)
        gs.ingest(src, dst)
        if tick == 0:
            sizes_after_first = {
                f: _cache_size(fn) for f, fn in gs.engine._jits.items()
            }
    out: List[Violation] = []
    if gs.engine.closure_refreshes != 1:
        out.append(
            Violation(
                rule="retrace",
                subject="subscription.tick",
                message=(
                    f"{gs.engine.closure_refreshes} full closure builds over "
                    f"{n_ticks} additions-only ticks (want exactly 1 — later "
                    "ticks must ride the touched-row incremental refresh)"
                ),
                pass_name="jaxpr",
            )
        )
    if gs.engine.closure_incremental_refreshes != n_ticks - 1:
        out.append(
            Violation(
                rule="retrace",
                subject="subscription.tick",
                message=(
                    f"{gs.engine.closure_incremental_refreshes} incremental "
                    f"refreshes over {n_ticks} ticks (want {n_ticks - 1})"
                ),
                pass_name="jaxpr",
            )
        )
    for f, fn in gs.engine._jits.items():
        before, after = sizes_after_first.get(f), _cache_size(fn)
        if before is not None and after is not None and after > before:
            out.append(
                Violation(
                    rule="retrace",
                    subject="subscription.tick",
                    message=(
                        f"family {f!r} re-traced after its first tick "
                        f"({before} -> {after} jit cache entries)"
                    ),
                    pass_name="jaxpr",
                )
            )
    return out


def _fleet_fixture_config():
    from repro.core.sketch import SketchConfig

    return SketchConfig(
        depth=_FIXTURE_DEPTH,
        width_rows=_FIXTURE_WIDTH,
        width_cols=_FIXTURE_WIDTH,
    )


def check_fleet_permutation() -> List[Violation]:
    """Tenant ids are DATA, not jit structure: replaying the same-shape
    mixed workload under permuted tenant-id assignments must not grow the
    fleet's ingest jit cache (one compile serves every tenant mix) or any
    query-family cache once the shape ladder is warm."""
    from repro.fleet import SketchFleet

    fleet = SketchFleet.open(_fleet_fixture_config(), capacity=4)
    rng = np.random.default_rng(0)
    rounds = ([0, 1, 2, 3], [0, 1, 2, 3], [3, 0, 1, 2], [1, 3, 0, 2])
    out: List[Violation] = []
    warm: Optional[int] = None
    for i, perm in enumerate(rounds):
        ids = np.asarray(perm)[rng.integers(0, 4, 64)]
        src = rng.integers(0, 100, 64).astype(np.uint32)
        dst = rng.integers(0, 100, 64).astype(np.uint32)
        fleet.ingest_mixed(ids, src, dst)
        # A small delete per tenant poisons touched-tracking, so reach
        # deterministically takes the full-build path every round — this
        # check is about cache stability, not the refresh ladder.
        fleet.ingest_mixed(
            np.asarray(perm),
            src[:4],
            dst[:4],
            -np.ones(4, np.float32),
        )
        for t in perm:
            sess = fleet.tenant(t)
            sess.edge_frequency(src[:8], dst[:8])
            sess.in_flow(src[:8])
            sess.reachable(src[:4], dst[:4])
        ingest_sz = fleet._ingest._cache_size()
        if ingest_sz is not None and ingest_sz > 1:
            out.append(
                Violation(
                    rule="retrace",
                    subject="fleet.ingest",
                    message=(
                        f"fleet ingest traced {ingest_sz} signatures after "
                        f"round {i} (want exactly 1 — the tenant axis must "
                        "ride the scatter index, not the trace)"
                    ),
                    pass_name="jaxpr",
                )
            )
            break
        qsz = fleet.engine._cache_size()
        if i == 1:
            warm = qsz
        if warm is not None and i > 1 and qsz > warm:
            out.append(
                Violation(
                    rule="retrace",
                    subject="fleet.query",
                    message=(
                        f"fleet query caches grew {warm} -> {qsz} under a "
                        "tenant-id permutation (round "
                        f"{i}): a jit cache key leaks the tenant assignment"
                    ),
                    pass_name="jaxpr",
                )
            )
            break
    return out


def check_fleet_subscription_tick() -> List[Violation]:
    """The fleet subscription tick contract: a standing reach+flow+edge
    batch on one tenant over N additions-only mixed batches performs
    exactly ONE full closure build, N-1 batched incremental refreshes, ONE
    ingest compile, and never re-traces a family after its first tick."""
    from repro.api.query import Query
    from repro.fleet import SketchFleet

    fleet = SketchFleet.open(_fleet_fixture_config(), capacity=4)
    sess = fleet.tenant("hot")
    sess.subscribe(
        Query.reach(1, 2), Query.in_flow(2), Query.edge(1, 2), every=1
    )
    rng = np.random.default_rng(0)
    sizes_after_first: Dict[str, Optional[int]] = {}
    n_ticks = 3
    for tick in range(n_ticks):
        src = rng.integers(0, 30, 6).astype(np.uint32)
        dst = rng.integers(0, 30, 6).astype(np.uint32)
        sess.ingest(src, dst)
        if tick == 0:
            sizes_after_first = {
                f: _cache_size(fn) for f, fn in fleet.engine._jits.items()
            }
    out: List[Violation] = []
    if fleet.engine.closure_builds != 1:
        out.append(
            Violation(
                rule="retrace",
                subject="fleet.subscription.tick",
                message=(
                    f"{fleet.engine.closure_builds} full closure builds over "
                    f"{n_ticks} additions-only ticks (want exactly 1 — later "
                    "ticks must ride the batched incremental refresh)"
                ),
                pass_name="jaxpr",
            )
        )
    if fleet.engine.closure_incremental_refreshes != n_ticks - 1:
        out.append(
            Violation(
                rule="retrace",
                subject="fleet.subscription.tick",
                message=(
                    f"{fleet.engine.closure_incremental_refreshes} incremental "
                    f"refreshes over {n_ticks} ticks (want {n_ticks - 1})"
                ),
                pass_name="jaxpr",
            )
        )
    ingest_sz = fleet._ingest._cache_size()
    if ingest_sz is not None and ingest_sz != 1:
        out.append(
            Violation(
                rule="retrace",
                subject="fleet.subscription.tick",
                message=(
                    f"fleet ingest traced {ingest_sz} signatures over "
                    f"{n_ticks} same-shape ticks (want exactly 1)"
                ),
                pass_name="jaxpr",
            )
        )
    for f, fn in fleet.engine._jits.items():
        before, after = sizes_after_first.get(f), _cache_size(fn)
        if before is not None and after is not None and after > before:
            out.append(
                Violation(
                    rule="retrace",
                    subject="fleet.subscription.tick",
                    message=(
                        f"fleet family {f!r} re-traced after its first tick "
                        f"({before} -> {after} jit cache entries)"
                    ),
                    pass_name="jaxpr",
                )
            )
    return out


DYNAMIC_CHECKS: Dict[str, Callable[[], List[Violation]]] = {
    "retrace.query_families": check_retrace_query_families,
    "retrace.closure_cache": check_closure_cache_value_keyed,
    "retrace.subscription_tick": check_subscription_tick,
    "retrace.fleet_permutation": check_fleet_permutation,
    "retrace.fleet_subscription_tick": check_fleet_subscription_tick,
}
