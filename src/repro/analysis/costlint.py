"""costlint — pass 3: compiled-cost & scaling-law contracts.

The paper's headline guarantees are asymptotic: constant maintenance
cost per edge update, O(d·Q) query evaluation, O(T_touched·w²) closure
refresh.  tracelint (passes 1–2) checks the *structure* of the traced
programs; this pass checks the *compiled cost curves*.  For every
:class:`~repro.analysis.contracts.CostEntryPoint` it lowers-and-compiles
the probe at 2–3 geometrically spaced sizes per axis (batch B, queries Q,
tenants T, width w, touched-stack S), pulls XLA's ``cost_analysis()``
(flops, bytes accessed) and ``memory_analysis()`` (argument/temp/alias
bytes) per point via the shared :mod:`repro.roofline.analysis` plumbing,
fits per-axis log-log exponents, and emits violations when

- ``cost-exponent``        a fitted exponent exceeds its declared ceiling
                           (+tol) — a silent O(B²) ingest or T-wide scan;
- ``cost-donation-memory`` a donated boundary stops aliasing the sketch
                           state or allocates a full-sketch temp — the
                           memory-side proof of donation, complementing
                           the ``donation-applied`` aliasing check;
- ``cost-budget``          an absolute ceiling from the committed
                           ``ANALYSIS_BUDGETS.json`` regresses (peak
                           compiled bytes, bytes accessed per edge, total
                           compile count), with a human-readable diff.

Budgets ratchet: ``python -m repro.analysis --update-budgets`` re-measures
and rewrites the ceilings at ``measured × margin``; the file is committed
so CI fails on regressions, not on noise.
"""
from __future__ import annotations

import json
import math
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.contracts import (
    COST_ENTRY_POINTS,
    CostEntryPoint,
    Violation,
)

# Headroom multiplier applied by --update-budgets: ceilings absorb
# XLA-version jitter without hiding a real (≥25%) regression.
BUDGET_MARGIN = 1.25

# src/repro/analysis/costlint.py -> repo root
DEFAULT_BUDGETS_PATH = (
    pathlib.Path(__file__).resolve().parents[3] / "ANALYSIS_BUDGETS.json"
)


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------


def _fit_exponent(sizes: Sequence[int], values: Sequence[float]) -> float:
    """Log-log least-squares slope; values clip at 1 so an all-zero metric
    (e.g. flops of a pure-copy program) fits exponent 0, not -inf."""
    import numpy as np

    xs = np.log(np.asarray(sizes, dtype=float))
    ys = np.log(np.maximum(np.asarray(values, dtype=float), 1.0))
    if xs.size < 2:
        return 0.0
    return float(np.polyfit(xs, ys, 1)[0])


def _compile_point(entry: CostEntryPoint, sizes: Dict[str, int]) -> Dict:
    import jax

    from repro.roofline.analysis import (
        compiled_cost_dict,
        compiled_memory_dict,
    )

    probe = entry.build(**sizes)
    jf = probe.jit_fn if probe.jit_fn is not None else jax.jit(probe.fn)
    compiled = jf.lower(*probe.args).compile()
    cost = compiled_cost_dict(compiled)
    return {
        "sizes": dict(sizes),
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "memory": compiled_memory_dict(compiled) or {},
        "state_bytes": int(probe.state_bytes),
    }


def measure_entry(entry: CostEntryPoint) -> Dict:
    """Compile ``entry`` at every point of every axis ladder (the base
    point — each axis at its smallest size — is compiled once and shared)
    and fit the per-axis exponents.  Returns the measurement record the
    report/budget/table layers consume."""
    base = {a.axis: a.sizes[0] for a in entry.axes}

    def key(sizes: Dict[str, int]) -> Tuple:
        return tuple(sorted(sizes.items()))

    points: Dict[Tuple, Dict] = {}
    for ax in entry.axes:
        for s in ax.sizes:
            sizes = dict(base, **{ax.axis: s})
            if key(sizes) not in points:
                points[key(sizes)] = _compile_point(entry, sizes)

    fits = []
    for ax in entry.axes:
        values = [
            points[key(dict(base, **{ax.axis: s}))][ax.metric]
            for s in ax.sizes
        ]
        measured = _fit_exponent(ax.sizes, values)
        fits.append(
            {
                "axis": ax.axis,
                "metric": ax.metric,
                "declared": ax.exponent,
                "tol": ax.tol,
                "measured": round(measured, 3),
                "sizes": list(ax.sizes),
                "values": values,
                "ok": measured <= ax.exponent + ax.tol,
            }
        )

    base_point = points[key(base)]
    peak = max(
        (p["memory"].get("peak_bytes_per_device_est", 0) for p in points.values()),
        default=0,
    )
    meas = {
        "entry": entry.name,
        "donated": entry.donated,
        "axes": fits,
        "compiles": len(points),
        "peak_bytes": int(peak),
        "base_memory": base_point["memory"],
        "state_bytes": base_point["state_bytes"],
    }
    if entry.edges_axis is not None:
        ax = next(a for a in entry.axes if a.axis == entry.edges_axis)
        big = points[key(dict(base, **{ax.axis: ax.sizes[-1]}))]
        meas["edges_at_max"] = int(ax.sizes[-1])
        meas["bytes_per_edge"] = big["bytes"] / float(ax.sizes[-1])
    return meas


# ---------------------------------------------------------------------------
# contract checks
# ---------------------------------------------------------------------------


def _exponent_violations(meas: Dict) -> List[Violation]:
    out = []
    for fit in meas["axes"]:
        if fit["ok"]:
            continue
        vals = ", ".join(f"{v:.4g}" for v in fit["values"])
        out.append(
            Violation(
                rule="cost-exponent",
                subject=f"{meas['entry']}[{fit['axis']}]",
                message=(
                    f"measured {fit['metric']} exponent {fit['measured']:.2f} "
                    f"over {fit['axis']} ∈ {fit['sizes']} exceeds declared "
                    f"O(n^{fit['declared']:g}) + {fit['tol']:g} tol "
                    f"({fit['metric']}: {vals})"
                ),
                pass_name="costlint",
            )
        )
    return out


def _donation_violations(meas: Dict) -> List[Violation]:
    """Memory-side donation proof at the base point: the compiled boundary
    must alias at least the sketch-state bytes into its outputs AND must
    not stage a full-sketch temp — either failure means XLA re-allocates
    the summary per batch even though the jaxpr-side aliasing annotation
    looks fine."""
    if not meas["donated"] or not meas["base_memory"]:
        return []
    state = meas["state_bytes"]
    alias = meas["base_memory"].get("alias_size_in_bytes", 0)
    temp = meas["base_memory"].get("temp_size_in_bytes", 0)
    out = []
    if alias < state:
        out.append(
            Violation(
                rule="cost-donation-memory",
                subject=meas["entry"],
                message=(
                    f"donated boundary aliases only {alias} bytes "
                    f"(< {state} sketch-state bytes): donation dropped, the "
                    "compiled program re-allocates the summary per batch"
                ),
                pass_name="costlint",
            )
        )
    if temp >= state:
        out.append(
            Violation(
                rule="cost-donation-memory",
                subject=meas["entry"],
                message=(
                    f"donated boundary allocates {temp} temp bytes "
                    f"(>= {state} sketch-state bytes): a full-sketch copy "
                    "escaped donation into scratch memory"
                ),
                pass_name="costlint",
            )
        )
    return out


def _budget_violations(
    measurements: List[Dict],
    budgets: Optional[Dict],
    full_registry: bool,
) -> List[Violation]:
    if budgets is None:
        return [
            Violation(
                rule="cost-budget",
                subject="ANALYSIS_BUDGETS.json",
                message=(
                    "no committed budgets file — run `python -m "
                    "repro.analysis --update-budgets` and commit the result"
                ),
                pass_name="costlint",
            )
        ]
    out = []
    entries = budgets.get("entries", {})
    for m in measurements:
        b = entries.get(m["entry"])
        if b is None:
            out.append(
                Violation(
                    rule="cost-budget",
                    subject=m["entry"],
                    message=(
                        "no committed ceiling for this entry — run "
                        "--update-budgets and commit ANALYSIS_BUDGETS.json"
                    ),
                    pass_name="costlint",
                )
            )
            continue
        ceil = b.get("peak_bytes")
        if ceil and m["peak_bytes"] > ceil:
            out.append(
                Violation(
                    rule="cost-budget",
                    subject=m["entry"],
                    message=(
                        f"compiled peak memory {m['peak_bytes']} B exceeds "
                        f"committed ceiling {ceil} B "
                        f"(+{(m['peak_bytes'] / ceil - 1) * 100:.0f}%)"
                    ),
                    pass_name="costlint",
                )
            )
        bpe_ceil = b.get("bytes_per_edge")
        if bpe_ceil and m.get("bytes_per_edge", 0.0) > bpe_ceil:
            out.append(
                Violation(
                    rule="cost-budget",
                    subject=m["entry"],
                    message=(
                        f"{m['bytes_per_edge']:.1f} bytes accessed per edge "
                        f"exceeds committed ceiling {bpe_ceil:.1f} "
                        f"(+{(m['bytes_per_edge'] / bpe_ceil - 1) * 100:.0f}%)"
                    ),
                    pass_name="costlint",
                )
            )
    cc_ceil = budgets.get("compile_count")
    total = sum(m["compiles"] for m in measurements)
    if full_registry and cc_ceil and total > cc_ceil:
        out.append(
            Violation(
                rule="cost-budget",
                subject="costlint.compile_count",
                message=(
                    f"{total} compiles across the cost registry exceeds the "
                    f"committed ceiling {cc_ceil} — a new entry or size "
                    "ladder landed without --update-budgets"
                ),
                pass_name="costlint",
            )
        )
    return out


def run_cost_pass(
    entry_points: Optional[Sequence[CostEntryPoint]] = None,
    *,
    budgets: Optional[Dict] = None,
    check_budgets: bool = True,
    full_registry: Optional[bool] = None,
) -> Tuple[List[Violation], List[Dict]]:
    """Measure every cost entry point and check all three contract classes.
    Returns ``(violations, measurements)``.  ``check_budgets=False`` skips
    the absolute-ceiling class (fixture tests, --update-budgets runs)."""
    if full_registry is None:
        full_registry = entry_points is None
    eps = COST_ENTRY_POINTS if entry_points is None else tuple(entry_points)
    violations: List[Violation] = []
    measurements: List[Dict] = []
    for ep in eps:
        try:
            meas = measure_entry(ep)
        except Exception as e:  # noqa: BLE001 — a broken probe IS a finding
            violations.append(
                Violation(
                    rule="cost-entry-broken",
                    subject=ep.name,
                    message=f"cost probe failed to build/compile: {e!r}",
                    pass_name="costlint",
                )
            )
            continue
        measurements.append(meas)
        violations.extend(_exponent_violations(meas))
        violations.extend(_donation_violations(meas))
    if check_budgets:
        violations.extend(
            _budget_violations(measurements, budgets, full_registry)
        )
    return violations, measurements


# ---------------------------------------------------------------------------
# budgets: load / ratchet
# ---------------------------------------------------------------------------


def load_budgets(path: Optional[pathlib.Path] = None) -> Optional[Dict]:
    p = pathlib.Path(path) if path is not None else DEFAULT_BUDGETS_PATH
    if not p.exists():
        return None
    return json.loads(p.read_text())


def budgets_from_measurements(
    measurements: List[Dict],
    *,
    margin: float = BUDGET_MARGIN,
    prior: Optional[Dict] = None,
    full_registry: bool = True,
) -> Dict:
    """The ratchet: ceilings at measured × margin.  Entries not measured
    this run (a --cost-entries filter) keep their prior ceilings; the
    compile-count ceiling only moves on full-registry runs."""
    entries = dict((prior or {}).get("entries", {}))
    for m in measurements:
        e = {"peak_bytes": int(math.ceil(m["peak_bytes"] * margin))}
        if "bytes_per_edge" in m:
            e["bytes_per_edge"] = round(m["bytes_per_edge"] * margin, 1)
        entries[m["entry"]] = e
    compile_count = (
        sum(m["compiles"] for m in measurements)
        if full_registry
        else (prior or {}).get("compile_count")
    )
    out = {"margin": margin, "entries": dict(sorted(entries.items()))}
    if compile_count is not None:
        out["compile_count"] = compile_count
    return out


def write_budgets(budgets: Dict, path: Optional[pathlib.Path] = None) -> pathlib.Path:
    p = pathlib.Path(path) if path is not None else DEFAULT_BUDGETS_PATH
    p.write_text(json.dumps(budgets, indent=1, sort_keys=True) + "\n")
    return p


# ---------------------------------------------------------------------------
# the cost table (CI job summary / report artifact)
# ---------------------------------------------------------------------------


def cost_table_markdown(measurements: List[Dict]) -> str:
    """Entry point → declared complexity → measured exponents, as a GitHub
    markdown table (posted into the CI job summary)."""
    lines = [
        "| entry point | axis | metric | declared | measured | sizes | ok |",
        "|---|---|---|---|---|---|---|",
    ]
    for m in measurements:
        for fit in m["axes"]:
            sizes = "×".join(str(s) for s in fit["sizes"])
            lines.append(
                f"| {m['entry']} | {fit['axis']} | {fit['metric']} "
                f"| O(n^{fit['declared']:g})+{fit['tol']:g} "
                f"| {fit['measured']:.2f} | {sizes} "
                f"| {'✓' if fit['ok'] else '✗'} |"
            )
    lines.append("")
    for m in measurements:
        extra = (
            f", {m['bytes_per_edge']:.1f} B/edge @ {m['edges_at_max']} edges"
            if "bytes_per_edge" in m
            else ""
        )
        lines.append(
            f"- `{m['entry']}`: {m['compiles']} compiles, "
            f"peak {m['peak_bytes']} B{extra}"
        )
    return "\n".join(lines) + "\n"
