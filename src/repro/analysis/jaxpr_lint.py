"""Pass 1 — the jaxpr contract checker.

Generalizes the ad-hoc ``_walk_jaxprs`` helper that used to live inside
``tests/test_query_engine.py`` into a rule engine: each entry point in
:data:`repro.analysis.contracts.ENTRY_POINTS` is traced with
``jax.make_jaxpr`` and its declared contracts are checked against every
(sub-)jaxpr, including the bodies of ``scan``/``while``/``cond``/
``pallas_call``/``shard_map`` equations.  The donation contract inspects
the *lowering* instead (donation is applied at lowering time — it never
shows up in the jaxpr), and the retrace contracts drive the live engines
(see ``contracts.DYNAMIC_CHECKS``).
"""
from __future__ import annotations

from typing import Iterable, Iterator, List, Optional, Tuple

from repro.analysis.contracts import (
    DYNAMIC_CHECKS,
    ENTRY_POINTS,
    EntryPoint,
    TracedEntry,
    Violation,
)

# Primitives that move data or control to the host mid-computation.  Any of
# these inside a hot-path jaxpr serializes the async dispatch pipeline.
HOST_CALLBACK_PRIMITIVES = frozenset(
    {
        "pure_callback",
        "io_callback",
        "debug_callback",
        "callback",
        "outside_call",
        "host_callback_call",
        "infeed",
        "outfeed",
        "device_put",
    }
)

# Cross-device collectives: legal ONLY under shard_map (outside one they
# either fail at run time on a mesh or silently run replicated).
COLLECTIVE_PRIMITIVES = frozenset(
    {
        "psum",
        "psum2",  # shard_map-era spelling in jax 0.4.x
        "pmin",
        "pmin2",
        "pmax",
        "pmax2",
        "pmean",
        "all_gather",
        "all_to_all",
        "ppermute",
        "pshuffle",
        "reduce_scatter",
        "psum_scatter",
    }
)

# 64-bit/complex128 avals double HBM traffic; the sketch plane is float32 /
# uint32 end to end and jax's x64 flag is off, so any wide aval is a
# promotion bug (e.g. a Python float snuck in as weak float64).
WIDE_DTYPES = frozenset({"float64", "int64", "uint64", "complex128"})

REDUCTION_PREFIX = "reduce_"

SHARD_MAP_PRIMITIVES = frozenset({"shard_map", "pjit_shard_map"})


def walk_jaxprs(jaxpr, in_shard_map: bool = False) -> Iterator[Tuple[object, bool]]:
    """Yield ``(jaxpr, in_shard_map)`` for a jaxpr and every sub-jaxpr
    reachable through equation params (scan/while/cond bodies, pallas_call
    kernels, shard_map bodies, nested pjit calls), tracking whether the
    walk is currently inside a ``shard_map`` region."""
    yield jaxpr, in_shard_map
    for eqn in jaxpr.eqns:
        inner = in_shard_map or eqn.primitive.name in SHARD_MAP_PRIMITIVES
        for param in eqn.params.values():
            yield from _walk_param(param, inner)


def _walk_param(param, in_shard_map: bool) -> Iterator[Tuple[object, bool]]:
    if hasattr(param, "jaxpr"):  # ClosedJaxpr
        yield from walk_jaxprs(param.jaxpr, in_shard_map)
    elif hasattr(param, "eqns"):  # raw Jaxpr
        yield from walk_jaxprs(param, in_shard_map)
    elif isinstance(param, (tuple, list)):
        for item in param:
            yield from _walk_param(item, in_shard_map)


def _trace(entry: TracedEntry):
    import jax

    return jax.make_jaxpr(entry.fn)(*entry.args)


# ---------------------------------------------------------------------------
# per-contract checkers — each takes the traced closed jaxpr and the entry
# ---------------------------------------------------------------------------


def check_no_host_callback(closed, entry: TracedEntry, name: str) -> List[Violation]:
    out = []
    for jaxpr, _ in walk_jaxprs(closed.jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in HOST_CALLBACK_PRIMITIVES:
                out.append(
                    Violation(
                        rule="no-host-callback",
                        subject=name,
                        message=(
                            f"host-transfer primitive {eqn.primitive.name!r} "
                            "in a hot-path jaxpr"
                        ),
                        pass_name="jaxpr",
                    )
                )
    return out


def check_no_wide_dtype(closed, entry: TracedEntry, name: str) -> List[Violation]:
    out = []
    seen = set()
    for jaxpr, _ in walk_jaxprs(closed.jaxpr):
        for eqn in jaxpr.eqns:
            for var in list(eqn.outvars) + list(eqn.invars):
                aval = getattr(var, "aval", None)
                dtype = getattr(aval, "dtype", None)
                if dtype is None:
                    continue
                dname = str(dtype)
                if dname in WIDE_DTYPES and (eqn.primitive.name, dname) not in seen:
                    seen.add((eqn.primitive.name, dname))
                    out.append(
                        Violation(
                            rule="no-wide-dtype",
                            subject=name,
                            message=(
                                f"{dname} aval produced around primitive "
                                f"{eqn.primitive.name!r} — weak-type/x64 "
                                "promotion on the hot path"
                            ),
                            pass_name="jaxpr",
                        )
                    )
    return out


def check_no_counter_reduction(
    closed, entry: TracedEntry, name: str
) -> List[Violation]:
    shape = entry.counters_shape
    if shape is None:
        return []
    out = []
    for jaxpr, _ in walk_jaxprs(closed.jaxpr):
        for eqn in jaxpr.eqns:
            if not eqn.primitive.name.startswith(REDUCTION_PREFIX):
                continue
            for var in eqn.invars:
                aval = getattr(var, "aval", None)
                if aval is not None and tuple(getattr(aval, "shape", ())) == shape:
                    out.append(
                        Violation(
                            rule="no-counter-reduction",
                            subject=name,
                            message=(
                                f"{eqn.primitive.name!r} consumes the full "
                                f"{shape} counter tensor — register-served "
                                "families must stay O(d·Q) gathers"
                            ),
                            pass_name="jaxpr",
                        )
                    )
    return out


def check_collectives_under_shard_map(
    closed, entry: TracedEntry, name: str
) -> List[Violation]:
    out = []
    for jaxpr, in_shard_map in walk_jaxprs(closed.jaxpr):
        if in_shard_map:
            continue
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in COLLECTIVE_PRIMITIVES:
                out.append(
                    Violation(
                        rule="collectives-under-shard-map",
                        subject=name,
                        message=(
                            f"collective {eqn.primitive.name!r} outside any "
                            "shard_map region"
                        ),
                        pass_name="jaxpr",
                    )
                )
    return out


def check_donation_applied(entry: TracedEntry, name: str) -> List[Violation]:
    """Donation never appears in the jaxpr — it is applied when the jit is
    LOWERED.  A donated-but-unusable buffer (shape/dtype mismatch with every
    output, or donation silently dropped) keeps the full per-batch sketch
    copy alive, so we assert the lowering actually aliases inputs into
    outputs (``tf.aliasing_output`` on the entry computation)."""
    if entry.jit_fn is None:
        return [
            Violation(
                rule="donation-applied",
                subject=name,
                message="entry declares donation contract but exposes no jit_fn",
                pass_name="jaxpr",
            )
        ]
    lowered = entry.jit_fn.lower(*entry.args)
    text = lowered.as_text()
    if "tf.aliasing_output" not in text:
        return [
            Violation(
                rule="donation-applied",
                subject=name,
                message=(
                    "lowering carries no tf.aliasing_output attribute — "
                    "sketch buffers are NOT donated through the jit "
                    "boundary (each batch pays a full counter-tensor copy)"
                ),
                pass_name="jaxpr",
            )
        ]
    return []


_CHECKERS = {
    "no-host-callback": check_no_host_callback,
    "no-wide-dtype": check_no_wide_dtype,
    "no-counter-reduction": check_no_counter_reduction,
    "collectives-under-shard-map": check_collectives_under_shard_map,
}


def check_entry_point(ep: EntryPoint) -> List[Violation]:
    try:
        entry = ep.build()
    except Exception as exc:  # a broken fixture is itself a finding
        return [
            Violation(
                rule="entry-point-broken",
                subject=ep.name,
                message=f"fixture failed to build: {type(exc).__name__}: {exc}",
                pass_name="jaxpr",
            )
        ]
    out: List[Violation] = []
    jaxpr_contracts = [c for c in ep.contracts if c in _CHECKERS]
    if jaxpr_contracts:
        try:
            closed = _trace(entry)
        except Exception as exc:
            return [
                Violation(
                    rule="entry-point-broken",
                    subject=ep.name,
                    message=f"trace failed: {type(exc).__name__}: {exc}",
                    pass_name="jaxpr",
                )
            ]
        for contract in jaxpr_contracts:
            out.extend(_CHECKERS[contract](closed, entry, ep.name))
    if "donation-applied" in ep.contracts:
        out.extend(check_donation_applied(entry, ep.name))
    return out


def run_jaxpr_pass(
    entry_points: Optional[Iterable[EntryPoint]] = None,
    *,
    dynamic: bool = True,
) -> List[Violation]:
    """Check every registered entry point; then run the dynamic retrace
    detectors against the live engines."""
    out: List[Violation] = []
    for ep in entry_points if entry_points is not None else ENTRY_POINTS:
        out.extend(check_entry_point(ep))
    if dynamic and entry_points is None:
        for check_name, check in DYNAMIC_CHECKS.items():
            try:
                out.extend(check())
            except Exception as exc:
                out.append(
                    Violation(
                        rule="entry-point-broken",
                        subject=check_name,
                        message=f"dynamic check crashed: {type(exc).__name__}: {exc}",
                        pass_name="jaxpr",
                    )
                )
    return out


# ---------------------------------------------------------------------------
# test-facing helper (API-compatible replacement for the old private copy
# in tests/test_query_engine.py)
# ---------------------------------------------------------------------------


def reduces_full_counters(fn, counters_shape: Tuple[int, ...], *args) -> bool:
    """True iff tracing ``fn(*args)`` yields any reduction primitive whose
    operand has exactly ``counters_shape`` — i.e. the full counter tensor is
    reduced instead of being served from the flow registers."""
    entry = TracedEntry(fn=fn, args=args, counters_shape=tuple(counters_shape))
    closed = _trace(entry)
    return bool(check_no_counter_reduction(closed, entry, "<adhoc>"))
