"""CLI + report assembly for ``python -m repro.analysis``.

Runs the jaxpr contract pass and the AST source pass, folds in the
baseline, and renders a text or JSON report.  Exit status is 0 iff there
are zero UNBASELINED violations — the CI gate.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, Iterable, List, Optional

from repro.analysis.contracts import Violation, apply_baseline

_PASSES = ("jaxpr", "source")


def _default_root() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[1]  # src/repro


def _default_tests_dir(root: pathlib.Path) -> Optional[pathlib.Path]:
    for cand in (root.parents[1] / "tests" if len(root.parents) >= 2 else None,):
        if cand is not None and cand.is_dir():
            return cand
    return None


def run_analysis(
    passes: Iterable[str] = _PASSES,
    *,
    root: Optional[pathlib.Path] = None,
    tests_dir: Optional[pathlib.Path] = None,
    entry_points=None,
    baseline: Optional[Dict] = None,
) -> Dict:
    """Run the requested passes and return the report dict:
    ``{ok, counts, checked_entry_points, violations: [...]}``.  ``ok`` is
    True iff no unbaselined violation survived."""
    from repro.analysis.baseline import BASELINE

    passes = tuple(passes)
    root = pathlib.Path(root) if root is not None else _default_root()
    tests_dir = (
        pathlib.Path(tests_dir) if tests_dir is not None else _default_tests_dir(root)
    )
    baseline = BASELINE if baseline is None else baseline

    violations: List[Violation] = []
    checked: List[str] = []
    if "jaxpr" in passes:
        from repro.analysis.contracts import ENTRY_POINTS
        from repro.analysis.jaxpr_lint import run_jaxpr_pass

        eps = ENTRY_POINTS if entry_points is None else tuple(entry_points)
        checked = [ep.name for ep in eps]
        violations.extend(
            run_jaxpr_pass(None if entry_points is None else eps)
        )
    if "source" in passes:
        from repro.analysis.source_lint import lint_tree

        violations.extend(lint_tree(root, tests_dir))

    violations = apply_baseline(violations, baseline)
    new = [v for v in violations if not v.baselined]
    old = [v for v in violations if v.baselined]
    return {
        "ok": not new,
        "passes": list(passes),
        "root": str(root),
        "checked_entry_points": checked,
        "counts": {
            "violations": len(new),
            "baselined": len(old),
            "entry_points": len(checked),
        },
        "violations": [v.to_json() for v in violations],
    }


def _render_text(report: Dict) -> str:
    lines = []
    for v in report["violations"]:
        lines.append(Violation(**v).render())
    c = report["counts"]
    lines.append(
        f"repro.analysis: {c['entry_points']} entry points, "
        f"{c['violations']} violation(s), {c['baselined']} baselined"
    )
    lines.append("OK" if report["ok"] else "FAIL")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Hot-path contract checks: jaxpr pass + source lint.",
    )
    parser.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="report format on stdout",
    )
    parser.add_argument(
        "--output", type=pathlib.Path, default=None,
        help="also write the JSON report to this path",
    )
    parser.add_argument(
        "--passes", default=",".join(_PASSES),
        help="comma-separated subset of passes: jaxpr,source",
    )
    parser.add_argument(
        "--root", type=pathlib.Path, default=None,
        help="package tree to lint (default: the installed repro package)",
    )
    parser.add_argument(
        "--tests-dir", type=pathlib.Path, default=None,
        help="tests directory for the kernel-ref coverage rule",
    )
    args = parser.parse_args(argv)

    passes = tuple(p.strip() for p in args.passes.split(",") if p.strip())
    unknown = [p for p in passes if p not in _PASSES]
    if unknown:
        parser.error(f"unknown pass(es): {', '.join(unknown)}")

    report = run_analysis(passes, root=args.root, tests_dir=args.tests_dir)

    if args.output is not None:
        args.output.write_text(json.dumps(report, indent=2) + "\n")
    if args.format == "json":
        print(json.dumps(report, indent=2))
    else:
        print(_render_text(report))
    return 0 if report["ok"] else 1
