"""CLI + report assembly for ``python -m repro.analysis``.

Runs the jaxpr contract pass, the AST source pass, and the compiled-cost
pass (costlint), folds in the baseline, and renders a text or JSON
report.  Exit status is 0 iff there are zero UNBASELINED violations —
the CI gate.  Stale baseline entries (their pass ran, no violation
matched) are surfaced as warnings and removable via ``--prune-baseline``.

Budget maintenance: ``--update-budgets`` re-measures the cost registry
and rewrites ``ANALYSIS_BUDGETS.json`` ceilings at measured × margin
(the ratchet); ``--cost-table PATH`` writes the exponent table as
markdown for the CI job summary.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, Iterable, List, Optional

from repro.analysis.contracts import Violation, apply_baseline

_PASSES = ("jaxpr", "source", "costlint")


def _default_root() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[1]  # src/repro


def _default_tests_dir(root: pathlib.Path) -> Optional[pathlib.Path]:
    for cand in (root.parents[1] / "tests" if len(root.parents) >= 2 else None,):
        if cand is not None and cand.is_dir():
            return cand
    return None


def run_analysis(
    passes: Iterable[str] = _PASSES,
    *,
    root: Optional[pathlib.Path] = None,
    tests_dir: Optional[pathlib.Path] = None,
    entry_points=None,
    cost_entry_points=None,
    budgets_path: Optional[pathlib.Path] = None,
    check_budgets: bool = True,
    baseline: Optional[Dict] = None,
) -> Dict:
    """Run the requested passes and return the report dict:
    ``{ok, counts, checked_entry_points, cost, violations: [...]}``.
    ``ok`` is True iff no unbaselined violation survived."""
    from repro.analysis.baseline import BASELINE, stale_baseline_entries

    passes = tuple(passes)
    root = pathlib.Path(root) if root is not None else _default_root()
    tests_dir = (
        pathlib.Path(tests_dir) if tests_dir is not None else _default_tests_dir(root)
    )
    baseline = BASELINE if baseline is None else baseline

    violations: List[Violation] = []
    checked: List[str] = []
    cost_checked: List[str] = []
    measurements: List[Dict] = []
    if "jaxpr" in passes:
        from repro.analysis.contracts import ENTRY_POINTS
        from repro.analysis.jaxpr_lint import run_jaxpr_pass

        eps = ENTRY_POINTS if entry_points is None else tuple(entry_points)
        checked = [ep.name for ep in eps]
        violations.extend(
            run_jaxpr_pass(None if entry_points is None else eps)
        )
    if "source" in passes:
        from repro.analysis.source_lint import lint_tree

        violations.extend(lint_tree(root, tests_dir))
    if "costlint" in passes:
        from repro.analysis.contracts import COST_ENTRY_POINTS
        from repro.analysis.costlint import load_budgets, run_cost_pass

        ceps = (
            COST_ENTRY_POINTS
            if cost_entry_points is None
            else tuple(cost_entry_points)
        )
        cost_checked = [ep.name for ep in ceps]
        cost_violations, measurements = run_cost_pass(
            None if cost_entry_points is None else ceps,
            budgets=load_budgets(budgets_path),
            check_budgets=check_budgets,
        )
        violations.extend(cost_violations)

    stale = stale_baseline_entries(baseline, violations, passes)
    violations = apply_baseline(violations, baseline)
    new = [v for v in violations if not v.baselined]
    old = [v for v in violations if v.baselined]
    return {
        "ok": not new,
        "passes": list(passes),
        "root": str(root),
        "checked_entry_points": checked,
        "checked_cost_entries": cost_checked,
        "counts": {
            "violations": len(new),
            "baselined": len(old),
            "entry_points": len(checked),
            "cost_entry_points": len(cost_checked),
            "stale_baseline": len(stale),
        },
        "stale_baseline": [list(k) for k in stale],
        "cost": measurements,
        "violations": [v.to_json() for v in violations],
    }


def _render_text(report: Dict) -> str:
    lines = []
    for v in report["violations"]:
        lines.append(Violation(**v).render())
    for rule, subject in report.get("stale_baseline", []):
        lines.append(
            f"WARN stale baseline entry ({rule}, {subject}) matched no "
            "current violation — remove it or run --prune-baseline"
        )
    for m in report.get("cost", []):
        fits = ", ".join(
            f"{f['axis']}:{f['measured']:.2f}/{f['declared']:g}"
            f"{'' if f['ok'] else '!'}"
            for f in m["axes"]
        )
        lines.append(
            f"cost {m['entry']}: {fits} ({m['compiles']} compiles, "
            f"peak {m['peak_bytes']} B)"
        )
    c = report["counts"]
    lines.append(
        f"repro.analysis: {c['entry_points']} entry points, "
        f"{c.get('cost_entry_points', 0)} cost entries, "
        f"{c['violations']} violation(s), {c['baselined']} baselined, "
        f"{c.get('stale_baseline', 0)} stale baseline"
    )
    lines.append("OK" if report["ok"] else "FAIL")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=(
            "Hot-path contract checks: jaxpr pass + source lint + "
            "compiled-cost contracts (costlint)."
        ),
    )
    parser.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="report format on stdout",
    )
    parser.add_argument(
        "--output", type=pathlib.Path, default=None,
        help="also write the JSON report to this path",
    )
    parser.add_argument(
        "--passes", default=",".join(_PASSES),
        help="comma-separated subset of passes: jaxpr,source,costlint",
    )
    parser.add_argument(
        "--root", type=pathlib.Path, default=None,
        help="package tree to lint (default: the installed repro package)",
    )
    parser.add_argument(
        "--tests-dir", type=pathlib.Path, default=None,
        help="tests directory for the kernel-ref coverage rule",
    )
    parser.add_argument(
        "--budgets", type=pathlib.Path, default=None,
        help="path to ANALYSIS_BUDGETS.json (default: repo root)",
    )
    parser.add_argument(
        "--update-budgets", action="store_true",
        help=(
            "re-measure the cost registry and rewrite the budgets file at "
            "measured x margin (the ratchet), then exit 0"
        ),
    )
    parser.add_argument(
        "--prune-baseline", action="store_true",
        help=(
            "run the requested passes, delete baseline entries that match "
            "no current violation, and exit 0"
        ),
    )
    parser.add_argument(
        "--baseline", type=pathlib.Path, default=None,
        help="path to baseline.json (default: the committed one)",
    )
    parser.add_argument(
        "--cost-entries", default=None,
        help="comma-separated cost entry names to restrict costlint to",
    )
    parser.add_argument(
        "--cost-table", type=pathlib.Path, default=None,
        help="write the cost exponent table (markdown) to this path",
    )
    args = parser.parse_args(argv)

    passes = tuple(p.strip() for p in args.passes.split(",") if p.strip())
    unknown = [p for p in passes if p not in _PASSES]
    if unknown:
        parser.error(f"unknown pass(es): {', '.join(unknown)}")

    cost_entry_points = None
    if args.cost_entries is not None:
        from repro.analysis.contracts import COST_ENTRY_POINTS

        wanted = {n.strip() for n in args.cost_entries.split(",") if n.strip()}
        cost_entry_points = tuple(
            ep for ep in COST_ENTRY_POINTS if ep.name in wanted
        )
        missing = wanted - {ep.name for ep in cost_entry_points}
        if missing:
            parser.error(f"unknown cost entries: {', '.join(sorted(missing))}")

    if args.update_budgets:
        from repro.analysis.costlint import (
            budgets_from_measurements,
            load_budgets,
            run_cost_pass,
            write_budgets,
        )

        full = cost_entry_points is None
        violations, measurements = run_cost_pass(
            cost_entry_points, check_budgets=False
        )
        budgets = budgets_from_measurements(
            measurements,
            prior=load_budgets(args.budgets),
            full_registry=full,
        )
        path = write_budgets(budgets, args.budgets)
        print(
            f"wrote {path}: {len(budgets['entries'])} entry ceilings, "
            f"compile_count={budgets.get('compile_count')}"
        )
        for v in violations:
            print(v.render(), file=sys.stderr)
        return 0

    baseline = None
    if args.baseline is not None:
        from repro.analysis.baseline import load_baseline

        baseline = load_baseline(args.baseline)

    report = run_analysis(
        passes,
        root=args.root,
        tests_dir=args.tests_dir,
        cost_entry_points=cost_entry_points,
        budgets_path=args.budgets,
        baseline=baseline,
    )

    if args.prune_baseline:
        from repro.analysis.baseline import prune_baseline

        stale = [tuple(k) for k in report["stale_baseline"]]
        removed = prune_baseline(stale, args.baseline)
        print(f"pruned {removed} stale baseline entr{'y' if removed == 1 else 'ies'}")
        return 0

    if args.cost_table is not None and report.get("cost"):
        from repro.analysis.costlint import cost_table_markdown

        args.cost_table.write_text(cost_table_markdown(report["cost"]))

    if args.output is not None:
        args.output.write_text(json.dumps(report, indent=2) + "\n")
    if args.format == "json":
        print(json.dumps(report, indent=2))
    else:
        print(_render_text(report))
    return 0 if report["ok"] else 1
