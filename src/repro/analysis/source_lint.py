"""Pass 2 — AST source lint for this codebase's hot-path idioms.

Rules (scoped by path relative to the lint root, so the same rules run
over ``src/repro`` in CI and over small fixture trees in the analyzer's
own tests):

``direct-jit``   ``jax.jit`` appears only in the two engine cache modules
                 (``core/query_engine.py``, ``api/stream.py``).  Ad-hoc
                 jits fragment the per-family cache and defeat the
                 retrace accounting.  Scope: core/, api/, kernels/,
                 serve/.
``host-sync``    no ``.item()`` / ``jax.device_get`` / ``np.asarray`` in
                 modules whose functions run under trace — each one
                 forces a device sync (or a tracer error) mid-pipeline.
                 Scope: kernels/** plus ``core/queries.py``,
                 ``core/reach.py``, ``core/window.py``.
``jnp-in-loop``  no ``jnp.*`` call inside a Python ``for``/``while`` in
                 hot modules — each iteration dispatches a fresh op (and
                 under trace unrolls the loop); use ``lax.fori_loop`` /
                 ``scan``.  Scope: core/, kernels/.
``env-read``     ``REPRO_*`` environment variables are read only at the
                 two dispatch boundaries (``core/ingest.py``,
                 ``core/query_engine.py``); reads elsewhere make config
                 ambient and untestable.
``kernel-ref``   every ``kernels/<name>/`` with a ``kernel.py`` ships
                 ``ops.py`` + ``ref.py`` and the kernel test imports both
                 the ops wrapper and the ref oracle (bit-equality
                 harness).
"""
from __future__ import annotations

import ast
import pathlib
from typing import List, Optional, Union

from repro.analysis.contracts import Violation

# -- per-rule path scopes (POSIX-style, relative to the lint root) ----------

DIRECT_JIT_DIRS = ("core", "api", "kernels", "serve", "fleet")
DIRECT_JIT_ALLOW = (
    "core/query_engine.py",
    "api/stream.py",
    "fleet/ingest.py",
    "fleet/query.py",
)

HOST_SYNC_DIRS = ("kernels",)
HOST_SYNC_FILES = ("core/queries.py", "core/reach.py", "core/window.py")

JNP_LOOP_DIRS = ("core", "kernels")

ENV_READ_ALLOW = ("core/ingest.py", "core/query_engine.py")

HOST_SYNC_CALLS = frozenset({"device_get", "block_until_ready"})


def _in_dirs(rel: str, dirs) -> bool:
    return any(rel == d or rel.startswith(d + "/") for d in dirs)


def _attr_chain(node: ast.AST) -> Optional[str]:
    """'jax.numpy.pad' for Attribute/Name chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Visitor(ast.NodeVisitor):
    def __init__(self, rel: str, path: str):
        self.rel = rel
        self.path = path
        self.loop_depth = 0
        self.def_stack: List[str] = []
        self.violations: List[Violation] = []
        self.jnp_aliases = {"jnp"}  # names bound to jax.numpy
        self.np_aliases = {"np", "numpy"}

    # -- context tracking ---------------------------------------------------

    def visit_Import(self, node: ast.Import):
        for alias in node.names:
            if alias.name == "jax.numpy":
                self.jnp_aliases.add(alias.asname or "jax")
            if alias.name == "numpy":
                self.np_aliases.add(alias.asname or "numpy")
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self._visit_def(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef):
        self._visit_def(node)

    def _visit_def(self, node):
        self.def_stack.append(node.name)
        outer_depth, self.loop_depth = self.loop_depth, 0
        self.generic_visit(node)
        self.loop_depth = outer_depth
        self.def_stack.pop()

    def visit_For(self, node: ast.For):
        self._visit_loop(node)

    def visit_While(self, node: ast.While):
        self._visit_loop(node)

    def _visit_loop(self, node):
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    # -- rule checks --------------------------------------------------------

    def _subject(self, node) -> str:
        where = "::".join(self.def_stack) or "<module>"
        return f"{self.rel}::{where}:{node.lineno}"

    def _flag(self, rule: str, node, message: str):
        self.violations.append(
            Violation(rule=rule, subject=self._subject(node), message=message,
                      pass_name="source")
        )

    def visit_Attribute(self, node: ast.Attribute):
        chain = _attr_chain(node)
        if (
            chain in ("jax.jit", "jax.numpy.jit")
            and _in_dirs(self.rel, DIRECT_JIT_DIRS)
            and self.rel not in DIRECT_JIT_ALLOW
        ):
            self._flag(
                "direct-jit",
                node,
                "jax.jit outside the engine cache modules "
                f"({', '.join(DIRECT_JIT_ALLOW)})",
            )
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        self._check_host_sync(node)
        self._check_jnp_in_loop(node)
        self._check_env_read(node)
        self.generic_visit(node)

    def _hot_for_sync(self) -> bool:
        return _in_dirs(self.rel, HOST_SYNC_DIRS) or self.rel in HOST_SYNC_FILES

    def _check_host_sync(self, node: ast.Call):
        if not self._hot_for_sync():
            return
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr == "item" and not node.args and not node.keywords:
                self._flag(
                    "host-sync", node,
                    ".item() forces a device->host sync (tracer error under jit)",
                )
                return
            chain = _attr_chain(f)
            if chain is None:
                return
            root, _, rest = chain.partition(".")
            if rest in HOST_SYNC_CALLS and root == "jax":
                self._flag("host-sync", node, f"jax.{rest} on a hot path")
            elif rest == "asarray" and root in self.np_aliases:
                self._flag(
                    "host-sync", node,
                    f"{chain}() materializes a traced value on the host",
                )

    def _check_jnp_in_loop(self, node: ast.Call):
        if self.loop_depth == 0 or not _in_dirs(self.rel, JNP_LOOP_DIRS):
            return
        chain = _attr_chain(node.func)
        if chain is None:
            return
        root = chain.split(".", 1)[0]
        if root in self.jnp_aliases or chain.startswith("jax.numpy."):
            self._flag(
                "jnp-in-loop", node,
                f"{chain}() inside a Python loop dispatches per iteration "
                "(use lax.fori_loop/scan or hoist)",
            )

    def _check_env_read(self, node: ast.Call):
        if self.rel in ENV_READ_ALLOW:
            return
        chain = _attr_chain(node.func)
        key_arg = None
        if chain in ("os.environ.get", "os.getenv") and node.args:
            key_arg = node.args[0]
        elif chain is None and isinstance(node.func, ast.Name):
            return
        if key_arg is None:
            return
        if isinstance(key_arg, ast.Constant) and isinstance(key_arg.value, str):
            if key_arg.value.startswith("REPRO_"):
                self._flag(
                    "env-read", node,
                    f"{key_arg.value} read outside the dispatch boundaries "
                    f"({', '.join(ENV_READ_ALLOW)})",
                )

    def visit_Subscript(self, node: ast.Subscript):
        # os.environ["REPRO_*"]
        if self.rel not in ENV_READ_ALLOW:
            chain = _attr_chain(node.value)
            sl = node.slice
            if (
                chain == "os.environ"
                and isinstance(sl, ast.Constant)
                and isinstance(sl.value, str)
                and sl.value.startswith("REPRO_")
            ):
                self._flag(
                    "env-read", node,
                    f"{sl.value} read outside the dispatch boundaries "
                    f"({', '.join(ENV_READ_ALLOW)})",
                )
        self.generic_visit(node)


def lint_file(path: Union[str, pathlib.Path], rel: Optional[str] = None) -> List[Violation]:
    """Lint one source file.  ``rel`` is its rule-scope path (POSIX,
    relative to the lint root); defaults to the file name."""
    path = pathlib.Path(path)
    rel = rel if rel is not None else path.name
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as exc:
        return [
            Violation(
                rule="syntax-error", subject=rel,
                message=f"unparseable: {exc}", pass_name="source",
            )
        ]
    visitor = _Visitor(rel, str(path))
    visitor.visit(tree)
    return visitor.violations


def _check_kernel_refs(
    root: pathlib.Path, tests_dir: Optional[pathlib.Path]
) -> List[Violation]:
    out: List[Violation] = []
    kernels = root / "kernels"
    if not kernels.is_dir():
        return out
    test_text = ""
    test_file = (tests_dir / "test_kernels.py") if tests_dir else None
    if test_file is not None and test_file.exists():
        test_text = test_file.read_text()
    for kdir in sorted(p for p in kernels.iterdir() if p.is_dir()):
        if not (kdir / "kernel.py").exists():
            continue
        name = kdir.name
        for required in ("ops.py", "ref.py"):
            if not (kdir / required).exists():
                out.append(
                    Violation(
                        rule="kernel-ref", subject=f"kernels/{name}",
                        message=f"Pallas kernel package missing {required}",
                        pass_name="source",
                    )
                )
        if test_file is None:
            continue
        for mod in ("ops", "ref"):
            if f"kernels.{name}.{mod}" not in test_text:
                out.append(
                    Violation(
                        rule="kernel-ref", subject=f"kernels/{name}",
                        message=(
                            f"{test_file.name} never imports "
                            f"kernels.{name}.{mod} — no bit-equality "
                            "coverage against the ref oracle"
                        ),
                        pass_name="source",
                    )
                )
    return out


def lint_tree(
    root: Union[str, pathlib.Path],
    tests_dir: Optional[Union[str, pathlib.Path]] = None,
) -> List[Violation]:
    """Run every source rule over a package tree rooted at ``root``
    (normally ``src/repro``).  ``tests_dir`` enables the kernel-ref
    coverage check against ``test_kernels.py``."""
    root = pathlib.Path(root)
    tests = pathlib.Path(tests_dir) if tests_dir is not None else None
    out: List[Violation] = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        if rel.startswith("analysis/"):
            continue  # the analyzer is host-side tooling, not a hot path
        out.extend(lint_file(path, rel))
    out.extend(_check_kernel_refs(root, tests))
    return out
