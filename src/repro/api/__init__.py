"""`repro.api` — the canonical public API of the gLava reproduction.

One import gives callers the whole paper surface:

- :class:`GraphStream` — the session facade: open a summary (config,
  preset, or target (ε, δ)), ingest labeled edge batches, run mixed query
  workloads, advance windows, checkpoint, merge.
- :class:`Query` / :class:`QueryBatch` / :class:`QueryResult` — the typed
  query IR: queries are data; heterogeneous batches are planned into at
  most one engine dispatch per family and answered in request order with
  (ε, δ) :class:`ErrorBound` annotations.
- :class:`Subscription` / :class:`SubscriptionEvent` — the standing-query
  plane: ``gs.subscribe(...)`` registers a batch compiled once and
  re-evaluated incrementally after every k-th mutation, emitting
  timestamped events (``sub.poll()`` / ``gs.events()`` / callbacks);
  ``gs.monitor`` is a thin threshold-subscription wrapper.
- :func:`encode_labels` / :func:`fnv1a_labels` — the vectorized key codec
  (str/int node labels -> uint32 keys) applied at this boundary.
- :class:`SketchConfig` — re-exported so callers can size summaries
  without importing ``repro.core``.

`repro.core` remains importable for internals (kernels, engines, the
sketch algebra), but every user-facing entry point — serving engine,
launch driver, examples, benchmarks — routes through this package.
"""
from repro.api.codec import encode_label, encode_labels
from repro.api.planner import CompiledPlan, compile_batch, execute, plan
from repro.api.query import (
    FAMILIES,
    ErrorBound,
    Query,
    QueryBatch,
    QueryResult,
    error_bound_for,
    validate_theta,
)
from repro.api.stream import (
    GraphStream,
    IngestReceipt,
    RecoveryReport,
    StreamStats,
)
from repro.api.subscription import Subscription, SubscriptionEvent
from repro.core.hashing import fnv1a_labels
from repro.core.sketch import SketchConfig
from repro.stream.events import EventFeed, EventOverflowError
from repro.stream.wal import WriteAheadLog
from repro.stream.watermark import WatermarkTracker

__all__ = [
    "FAMILIES",
    "CompiledPlan",
    "ErrorBound",
    "EventFeed",
    "EventOverflowError",
    "GraphStream",
    "IngestReceipt",
    "Query",
    "QueryBatch",
    "QueryResult",
    "RecoveryReport",
    "SketchConfig",
    "StreamStats",
    "Subscription",
    "SubscriptionEvent",
    "WatermarkTracker",
    "WriteAheadLog",
    "compile_batch",
    "encode_label",
    "encode_labels",
    "error_bound_for",
    "execute",
    "fnv1a_labels",
    "plan",
    "validate_theta",
]
