"""Key codec: node labels -> uint32 device keys, at the API boundary.

Graph streams carry IPs, user ids, URLs — arbitrary str/int labels.  The
device planes (hashing, ingest, query kernels) speak uint32 only, so the
:class:`~repro.api.stream.GraphStream` facade encodes every label batch
exactly once, here, with the vectorized FNV-1a from
:func:`repro.core.hashing.fnv1a_labels`:

- integer labels (Python ints, any numpy/JAX integer dtype) are a masked
  cast — the identity on values already in the uint32 key space, so code
  that always used raw integer node ids sees the exact same keys;
- string labels hash with 32-bit FNV-1a, byte-column-vectorized over the
  batch (no Python loop per label).

Encoding is deterministic and stateless: the same label maps to the same
key in every process, which is what lets sketches built on different
workers merge (same hash family + same key codec = same cells).
"""
from __future__ import annotations

import numpy as np

from repro.core.hashing import fnv1a_labels


def encode_labels(labels) -> np.ndarray:
    """Encode node labels (scalar, sequence, or array; str or int) to uint32.

    Returns an array of the input's shape — 0-d for a scalar label; callers
    that need a batch axis wrap with ``np.atleast_1d``."""
    return fnv1a_labels(labels)


def encode_label(label) -> np.uint32:
    """Scalar convenience: one label -> one uint32 key."""
    return np.uint32(encode_labels(label))
