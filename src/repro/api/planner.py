"""Plan-and-fuse execution of heterogeneous QueryBatches.

The planner is the piece that turns "queries are data" into engine
efficiency: a shuffled mixed-family batch is

1. **grouped** by family (request indices remembered),
2. **fused** — each family's key arrays are concatenated (subgraph edge
   lists are padded to the group's max k with a validity mask, which is
   exact under the revised absent-edge semantics), so the whole family is
   AT MOST ONE :class:`~repro.core.query_engine.QueryEngine` dispatch —
   the engine then pads once per family and hits its persistent jit cache,
3. **scattered** back into request order as :class:`QueryResult`\\ s with
   per-family (ε, δ) annotations.

Answers are bit-identical to issuing each family's queries directly
against the engine (property-tested): fusion only ever concatenates along
the query axis of elementwise-batched estimators, and subgraph padding is
masked by index, never by value.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.api.query import Query, QueryBatch, QueryResult, error_bound_for
from repro.core.query_engine import QueryEngine
from repro.core.sketch import GLavaSketch


def plan(batch: QueryBatch) -> Dict[str, List[Tuple[int, Query]]]:
    """Group a batch by family, preserving request indices.  Family order is
    first appearance; each family maps to its (request_index, query) list."""
    groups: Dict[str, List[Tuple[int, Query]]] = {}
    for idx, q in enumerate(batch):
        groups.setdefault(q.family, []).append((idx, q))
    return groups


def _concat(items: List[Tuple[int, Query]], attr: str) -> jnp.ndarray:
    return jnp.asarray(
        np.concatenate([getattr(q, attr) for _, q in items]), jnp.uint32
    )


def _scatter(results, items, values, sizes):
    """Slice a family's fused answer array back onto the request slots."""
    lo = 0
    for (idx, q), n in zip(items, sizes):
        vals = values[lo : lo + n]
        results[idx] = vals[0] if q.scalar else vals
        lo += n


def execute(
    engine: QueryEngine,
    sketch: GLavaSketch,
    batch: QueryBatch,
    epoch: Optional[int] = None,
) -> List[QueryResult]:
    """Run a planned batch through the engine: one dispatch per family
    present, answers in request order.  ``epoch`` tags the engine's closure
    cache for the reach family (one closure build per sketch epoch)."""
    groups = plan(batch)
    values: List = [None] * len(batch)

    for family, items in groups.items():
        sizes = [q.n_answers for _, q in items]
        if family == "edge":
            out = np.asarray(
                engine.edge(sketch, _concat(items, "u"), _concat(items, "v"))
            )
            _scatter(values, items, out, sizes)
        elif family in ("in_flow", "out_flow", "flow"):
            out = np.asarray(
                getattr(engine, family)(sketch, _concat(items, "u"))
            )
            _scatter(values, items, out, sizes)
        elif family == "heavy":
            thetas = np.concatenate(
                [np.full(n, q.theta, np.float32) for (_, q), n in zip(items, sizes)]
            )
            in_h, out_h = engine.heavy_vec(sketch, _concat(items, "u"), thetas)
            in_h, out_h = np.asarray(in_h), np.asarray(out_h)
            lo = 0
            for (idx, q), n in zip(items, sizes):
                i_part, o_part = in_h[lo : lo + n], out_h[lo : lo + n]
                values[idx] = (
                    (i_part[0], o_part[0]) if q.scalar else (i_part, o_part)
                )
                lo += n
        elif family == "reach":
            out = np.asarray(
                engine.reach(
                    sketch, _concat(items, "u"), _concat(items, "v"), epoch=epoch
                )
            )
            _scatter(values, items, out, sizes)
        elif family == "subgraph":
            n = len(items)
            k_max = max(q.u.shape[0] for _, q in items)
            src = np.zeros((n, k_max), np.uint32)
            dst = np.zeros((n, k_max), np.uint32)
            mask = np.zeros((n, k_max), bool)
            for row, (_, q) in enumerate(items):
                k = q.u.shape[0]
                src[row, :k] = q.u
                dst[row, :k] = q.v
                mask[row, :k] = True
            out = np.asarray(
                engine.subgraph_batch(
                    sketch, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(mask)
                )
            )
            for row, (idx, _) in enumerate(items):
                values[idx] = out[row]
        else:  # pragma: no cover — Query.__post_init__ rejects unknowns
            raise ValueError(f"planner has no rule for family {family!r}")

    bounds = {f: error_bound_for(f, sketch.config) for f in groups}
    return [
        QueryResult(query=q, value=values[i], error=bounds[q.family])
        for i, q in enumerate(batch)
    ]
