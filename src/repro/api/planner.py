"""Plan-and-fuse execution of heterogeneous QueryBatches.

The planner is the piece that turns "queries are data" into engine
efficiency: a shuffled mixed-family batch is

1. **grouped** by family (request indices remembered),
2. **fused** — each family's key arrays are concatenated (subgraph edge
   lists are padded to the group's max k with a validity mask, which is
   exact under the revised absent-edge semantics), so the whole family is
   AT MOST ONE :class:`~repro.core.query_engine.QueryEngine` dispatch —
   the engine then pads once per family and hits its persistent jit cache,
3. **scattered** back into request order as :class:`QueryResult`\\ s with
   per-family (ε, δ) annotations.

Compilation is separate from execution: :func:`compile_batch` does the
grouping/fusing ONCE and returns a :class:`CompiledPlan` whose
:meth:`~CompiledPlan.run` re-executes against any (sketch, epoch) — the
standing-subscription plane registers a batch, compiles it once, and then
pays only the engine dispatches per re-evaluation tick.  One-shot
:func:`execute` is just ``compile_batch(batch).run(...)``.

Answers are bit-identical to issuing each family's queries directly
against the engine (property-tested): fusion only ever concatenates along
the query axis of elementwise-batched estimators, and subgraph padding is
masked by index, never by value.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.api.query import Query, QueryBatch, QueryResult, error_bound_for
from repro.core.query_engine import QueryEngine
from repro.core.sketch import GLavaSketch


def plan(batch: QueryBatch) -> Dict[str, List[Tuple[int, Query]]]:
    """Group a batch by family, preserving request indices.  Family order is
    first appearance; each family maps to its (request_index, query) list."""
    groups: Dict[str, List[Tuple[int, Query]]] = {}
    for idx, q in enumerate(batch):
        groups.setdefault(q.family, []).append((idx, q))
    return groups


def _concat(items: List[Tuple[int, Query]], attr: str) -> jnp.ndarray:
    return jnp.asarray(
        np.concatenate([getattr(q, attr) for _, q in items]), jnp.uint32
    )


def _scatter(results, items, values, sizes):
    """Slice a family's fused answer array back onto the request slots."""
    lo = 0
    for (idx, q), n in zip(items, sizes):
        vals = values[lo : lo + n]
        results[idx] = vals[0] if q.scalar else vals
        lo += n


@dataclasses.dataclass(frozen=True)
class _FamilyPlan:
    """One family's fused dispatch: request bookkeeping + device arrays."""

    family: str
    items: Tuple[Tuple[int, Query], ...]
    sizes: Tuple[int, ...]
    args: Tuple  # fused device arrays, family-shaped


class CompiledPlan:
    """A QueryBatch compiled ONCE into per-family fused dispatches.

    Holds the grouped request indices and the fused device-resident key
    (and θ / mask) arrays, so repeated execution — the subscription plane's
    per-tick re-evaluation — skips all host-side planning and pays exactly
    the per-family engine dispatches.  Immutable; safe to run against any
    sketch sharing the batch's key space."""

    def __init__(self, batch: QueryBatch):
        self.batch = batch
        self.groups = plan(batch)
        self.families = tuple(self.groups)
        self.has_reach = "reach" in self.groups
        self._plans: List[_FamilyPlan] = []
        for family, items in self.groups.items():
            sizes = tuple(q.n_answers for _, q in items)
            if family == "edge" or family == "reach":
                args = (_concat(items, "u"), _concat(items, "v"))
            elif family in ("in_flow", "out_flow", "flow"):
                args = (_concat(items, "u"),)
            elif family == "heavy":
                thetas = np.concatenate(
                    [
                        np.full(n, q.theta, np.float32)
                        for (_, q), n in zip(items, sizes)
                    ]
                )
                args = (_concat(items, "u"), jnp.asarray(thetas))
            elif family == "subgraph":
                n = len(items)
                k_max = max(q.u.shape[0] for _, q in items)
                src = np.zeros((n, k_max), np.uint32)
                dst = np.zeros((n, k_max), np.uint32)
                mask = np.zeros((n, k_max), bool)
                for row, (_, q) in enumerate(items):
                    k = q.u.shape[0]
                    src[row, :k] = q.u
                    dst[row, :k] = q.v
                    mask[row, :k] = True
                args = (jnp.asarray(src), jnp.asarray(dst), jnp.asarray(mask))
            else:  # pragma: no cover — Query.__post_init__ rejects unknowns
                raise ValueError(f"planner has no rule for family {family!r}")
            self._plans.append(
                _FamilyPlan(family, tuple(items), sizes, args)
            )

    def __len__(self) -> int:
        return len(self.batch)

    def run(
        self,
        engine: QueryEngine,
        sketch: GLavaSketch,
        epoch: Optional[int] = None,
    ) -> List[QueryResult]:
        """Execute the compiled plan: one engine dispatch per family
        present, answers in request order.  ``epoch`` tags the engine's
        closure cache for the reach family (the subscription plane refreshes
        that cache incrementally before calling run)."""
        if not self._plans:
            return []
        values: List = [None] * len(self.batch)
        for fp in self._plans:
            if fp.family == "edge":
                out = np.asarray(engine.edge(sketch, *fp.args))
                _scatter(values, fp.items, out, fp.sizes)
            elif fp.family in ("in_flow", "out_flow", "flow"):
                out = np.asarray(getattr(engine, fp.family)(sketch, *fp.args))
                _scatter(values, fp.items, out, fp.sizes)
            elif fp.family == "heavy":
                in_h, out_h = engine.heavy_rel_vec(sketch, *fp.args)
                in_h, out_h = np.asarray(in_h), np.asarray(out_h)
                lo = 0
                for (idx, q), n in zip(fp.items, fp.sizes):
                    i_part, o_part = in_h[lo : lo + n], out_h[lo : lo + n]
                    values[idx] = (
                        (i_part[0], o_part[0]) if q.scalar else (i_part, o_part)
                    )
                    lo += n
            elif fp.family == "reach":
                out = np.asarray(engine.reach(sketch, *fp.args, epoch=epoch))
                _scatter(values, fp.items, out, fp.sizes)
            elif fp.family == "subgraph":
                out = np.asarray(engine.subgraph_batch(sketch, *fp.args))
                for row, (idx, _) in enumerate(fp.items):
                    values[idx] = out[row]

        bounds = {f: error_bound_for(f, sketch.config) for f in self.groups}
        return [
            QueryResult(query=q, value=values[i], error=bounds[q.family])
            for i, q in enumerate(self.batch)
        ]


def compile_batch(batch: QueryBatch) -> CompiledPlan:
    """Compile a batch once for repeated execution (the subscription path)."""
    return CompiledPlan(batch)


def execute(
    engine: QueryEngine,
    sketch: GLavaSketch,
    batch: QueryBatch,
    epoch: Optional[int] = None,
) -> List[QueryResult]:
    """One-shot plan-and-fuse: compile, run, discard the plan."""
    return CompiledPlan(batch).run(engine, sketch, epoch=epoch)
