"""The typed query IR: queries are DATA, not method calls.

The paper's pitch is one summary answering "a wide range of graph queries"
over one stream (Section 3.4's catalogue).  This module makes that mixed
workload expressible: each supported family is a :class:`Query` constructor
—

    Query.edge(u, v)          f̃_e(u → v)            weight estimate
    Query.in_flow(n)          f̃_v(n, ←)             aggregate in-flow
    Query.out_flow(n)         f̃_v(n, →)             aggregate out-flow
    Query.flow(n)             f̃_v(n, ⊥ / total)     total incident flow
    Query.heavy(n, θ)         f̃_v(n) > θ·F̃          heavy-hitter check (θ ∈ (0,1])
    Query.reach(u, v)         r̃(u → v)              reachability
    Query.subgraph(us, vs)    f̃({(us_i, vs_i)})     aggregate subgraph

— and a heterogeneous :class:`QueryBatch` is planned by
:mod:`repro.api.planner` into AT MOST ONE :class:`~repro.core.query_engine.
QueryEngine` dispatch per family, with answers scattered back into request
order as :class:`QueryResult`\\ s carrying the paper's (ε, δ) one-sided
error annotations (:class:`ErrorBound`, derived from ``SketchConfig``).

Node labels (str/int) are encoded at Query construction by the
:mod:`repro.api.codec`, so the IR below the constructors is already in the
uint32 key space.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, Optional, Tuple

import numpy as np

from repro.api.codec import encode_labels

# Families a Query may carry; the planner groups a batch by these.
FAMILIES = ("edge", "in_flow", "out_flow", "flow", "heavy", "reach", "subgraph")

# Families whose answers are counts with the paper's one-sided additive
# error; the rest are booleans with one-sided (no-false-negative) error.
_COUNT_FAMILIES = frozenset({"edge", "in_flow", "out_flow", "flow", "subgraph"})


@dataclasses.dataclass(frozen=True)
class ErrorBound:
    """The paper's one-sided guarantee attached to a QueryResult.

    For count families: ``estimate <= truth + epsilon * F`` (F the total
    stream weight) with probability at least ``1 - delta``, and NEVER an
    under-estimate (Thm 1).  For boolean families (reach, heavy):
    ``epsilon`` is None and the guarantee is no false negatives, with false
    positives occurring with probability at most ``delta``-ish per query
    (hash-collision driven)."""

    epsilon: Optional[float]
    delta: float
    side: str  # "over-estimate" | "no-false-negative"

    def __str__(self) -> str:
        if self.epsilon is None:
            return f"one-sided ({self.side}), δ={self.delta:.2e}"
        return f"one-sided ({self.side}), ε={self.epsilon:.2e}, δ={self.delta:.2e}"


def error_bound_for(family: str, config) -> ErrorBound:
    """Derive the family's ErrorBound from a SketchConfig (its ``error_bound``
    is the exact inverse of ``SketchConfig.for_error`` — round-trip tested)."""
    eps, delta = config.error_bound()
    if family in _COUNT_FAMILIES:
        return ErrorBound(epsilon=eps, delta=delta, side="over-estimate")
    return ErrorBound(epsilon=None, delta=delta, side="no-false-negative")


def validate_theta(theta) -> float:
    """Validate a heavy-hitter / monitor threshold θ: a FRACTION of the
    total stream weight F̃, so ``0 < θ <= 1`` (and finite — a NaN θ would
    otherwise compare false everywhere and silently report nothing heavy).
    Raises a clear ``ValueError``; shared by ``Query.heavy``,
    ``GraphStream.monitor``, and subscription construction."""
    try:
        theta = float(theta)
    except (TypeError, ValueError):
        raise ValueError(f"theta must be a real number, got {theta!r}")
    if not (0.0 < theta <= 1.0):  # also rejects NaN (all comparisons false)
        raise ValueError(
            "theta is the heavy-hitter fraction of the total stream weight "
            f"F and must satisfy 0 < theta <= 1, got {theta!r}"
        )
    return theta


def _encode_batchable(labels) -> Tuple[np.ndarray, bool]:
    """Encode labels -> ((Q,) uint32 keys, was_scalar)."""
    keys = encode_labels(labels)
    scalar = np.ndim(keys) == 0
    keys = np.atleast_1d(keys).astype(np.uint32, copy=False)
    if keys.ndim != 1:
        raise ValueError(f"expected scalar or 1-D labels, got shape {keys.shape}")
    return keys, scalar


@dataclasses.dataclass(frozen=True)
class Query:
    """One logical query: a family tag plus encoded key payload.

    Endpoint payloads may be scalar labels (scalar result) or 1-D label
    batches (array result, one answer per element) — except ``subgraph``,
    whose (k,) edge list is ONE query with a scalar answer.  Construct via
    the family staticmethods, not directly."""

    family: str
    u: Optional[np.ndarray] = None      # (Q,) or (k,) uint32
    v: Optional[np.ndarray] = None
    theta: Optional[float] = None       # heavy-hitter threshold
    scalar: bool = True                 # unwrap the answer to a scalar

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown query family {self.family!r} (want {FAMILIES})")

    # -- constructors (the public IR) ---------------------------------------

    @staticmethod
    def edge(u, v) -> "Query":
        """Edge-frequency estimate f̃_e(u → v) (Section 4.1)."""
        ku, su = _encode_batchable(u)
        kv, sv = _encode_batchable(v)
        ku, kv = np.broadcast_arrays(ku, kv)
        return Query("edge", np.ascontiguousarray(ku), np.ascontiguousarray(kv),
                     scalar=su and sv)

    @staticmethod
    def in_flow(n) -> "Query":
        """Aggregate in-flow point query f̃_v(n, ←) (Section 4.2)."""
        k, s = _encode_batchable(n)
        return Query("in_flow", k, scalar=s)

    @staticmethod
    def out_flow(n) -> "Query":
        """Aggregate out-flow point query f̃_v(n, →) (Section 4.2)."""
        k, s = _encode_batchable(n)
        return Query("out_flow", k, scalar=s)

    @staticmethod
    def flow(n) -> "Query":
        """Total incident flow (in + out for directed streams)."""
        k, s = _encode_batchable(n)
        return Query("flow", k, scalar=s)

    @staticmethod
    def heavy(n, theta: float) -> "Query":
        """Heavy-hitter check: is f̃_v(n) > θ·F̃ (in- and out-flow), with θ a
        FRACTION of the total stream weight F̃ in (0, 1] (validated — a
        clear ValueError beats silently-all-false bits from a nonsense θ)?
        The answer is an (in_heavy, out_heavy) boolean pair per node."""
        k, s = _encode_batchable(n)
        return Query("heavy", k, theta=validate_theta(theta), scalar=s)

    @staticmethod
    def reach(u, v) -> "Query":
        """Reachability r̃(u → v) (Section 4.3); requires a square sketch."""
        ku, su = _encode_batchable(u)
        kv, sv = _encode_batchable(v)
        ku, kv = np.broadcast_arrays(ku, kv)
        return Query("reach", np.ascontiguousarray(ku), np.ascontiguousarray(kv),
                     scalar=su and sv)

    @staticmethod
    def subgraph(us, vs) -> "Query":
        """Aggregate subgraph weight f̃({(us_i, vs_i)}) for one edge list
        (Section 4.4 revised exact-match semantics): one scalar answer."""
        ku, _ = _encode_batchable(us)
        kv, _ = _encode_batchable(vs)
        if ku.shape != kv.shape:
            raise ValueError(
                f"subgraph endpoint lists must match: {ku.shape} vs {kv.shape}"
            )
        if ku.size == 0:
            raise ValueError("subgraph query needs at least one edge")
        return Query("subgraph", ku, kv, scalar=True)

    # -- plumbing -----------------------------------------------------------

    @property
    def n_answers(self) -> int:
        """How many answer slots this query occupies in its family batch."""
        return 1 if self.family == "subgraph" else int(self.u.shape[0])


@dataclasses.dataclass(frozen=True)
class QueryBatch:
    """An ordered heterogeneous batch of queries — the planner's unit.

    Results always come back in THIS order, regardless of how the planner
    groups families for dispatch."""

    queries: Tuple[Query, ...]

    def __init__(self, queries):
        object.__setattr__(self, "queries", tuple(queries))
        for q in self.queries:
            if not isinstance(q, Query):
                raise TypeError(f"QueryBatch holds Query objects, got {type(q)}")

    def __len__(self) -> int:
        return len(self.queries)

    def __iter__(self) -> Iterator[Query]:
        return iter(self.queries)

    def __getitem__(self, i) -> Query:
        return self.queries[i]

    @property
    def families(self) -> Tuple[str, ...]:
        """Distinct families present, in first-appearance order."""
        seen = dict.fromkeys(q.family for q in self.queries)
        return tuple(seen)


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """One query's answer: the value, the originating query, and the paper's
    (ε, δ) one-sided error annotation."""

    query: Query
    value: Any            # scalar / ndarray; heavy -> (in_heavy, out_heavy)
    error: ErrorBound

    @property
    def family(self) -> str:
        return self.query.family
