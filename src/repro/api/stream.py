"""`GraphStream` — the one session facade over the paper's summary S.

The paper maintains a SINGLE summary supporting a wide range of graph
queries over one stream.  `GraphStream` is that object for callers: it
wraps the ingest plane (:class:`~repro.core.ingest.IngestEngine`, double-
buffered batched dispatch), the query plane (:class:`~repro.core.
query_engine.QueryEngine`, planned + fused by :mod:`repro.api.planner`),
the standing-query plane (:mod:`repro.api.subscription`), and the optional
sliding window (:class:`~repro.core.window.SlidingWindowSketch`),
distributed plane (`mesh=`), and :class:`~repro.checkpoint.manager.
CheckpointManager` behind one handle::

    from repro.api import GraphStream, Query

    gs = GraphStream.open("smoke")           # or a SketchConfig / (ε, δ)
    gs.ingest(["alice", "bob"], ["bob", "carol"])      # labels, not keys

    # one-shot pull
    res = gs.query(Query.edge("alice", "bob"),
                   Query.in_flow("bob"),
                   Query.reach("alice", "carol"))
    print(res[0].value, res[0].error)        # (ε, δ)-annotated estimate

    # standing subscription: compiled once, re-evaluated incrementally
    # after every 4th mutation, results as timestamped events
    sub = gs.subscribe(Query.reach("alice", "carol"),
                       Query.in_flow("carol"), every=4)
    gs.ingest(more_src, more_dst)
    for event in sub.poll():
        print(event.tick, event.results)

Node labels (str/int) are encoded exactly once at this boundary by the
vectorized key codec (:mod:`repro.api.codec`); everything below speaks
uint32.  Every entry point of the repo (serving engine, launch driver,
examples, benchmarks) routes through this facade — ``repro.core`` stays
importable for internals, but `repro.api` is the canonical public API.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, Iterator, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.codec import encode_labels
from repro.api.planner import execute
from repro.api.query import (
    ErrorBound,
    Query,
    QueryBatch,
    QueryResult,
    error_bound_for,
    validate_theta,
)
from repro.api.subscription import (
    DEFAULT_MAX_PENDING,
    Subscription,
    SubscriptionEvent,
)
from repro.core import queries as queries_mod
from repro.core.ingest import (
    pad_bucket,
    preaggregate_host,
    resolve_backend,
    resolve_preagg,
    touched_row_keys,
)
from repro.core.query_engine import QueryEngine
from repro.core.sketch import GLavaSketch, SketchConfig
from repro.core.window import SlidingWindowSketch

# Session-wide event feed bound (per-subscription queues have their own);
# when nobody drains ``gs.events()`` the oldest entries drop.
EVENT_LOG_MAXLEN = 4096


@dataclasses.dataclass
class StreamStats:
    """Session counters (ingest/query throughput, closure refreshes,
    subscription ticks)."""

    edges_ingested: int = 0
    ingest_s: float = 0.0
    queries_served: int = 0
    query_s: float = 0.0
    closure_refreshes: int = 0
    closure_incremental_refreshes: int = 0
    subscription_ticks: int = 0

    def summary(self) -> Dict[str, float]:
        return {
            "edges_ingested": self.edges_ingested,
            "ingest_edges_per_s": self.edges_ingested / max(self.ingest_s, 1e-9),
            "queries_served": self.queries_served,
            "queries_per_s": self.queries_served / max(self.query_s, 1e-9),
            "closure_refreshes": self.closure_refreshes,
            "closure_incremental_refreshes": self.closure_incremental_refreshes,
            "subscription_ticks": self.subscription_ticks,
        }


@dataclasses.dataclass(frozen=True)
class IngestReceipt:
    """What one ``ingest`` call did: the post-batch epoch, the batch size,
    and the batch's touched-key set — the unique uint32 node keys whose
    sketch ROWS the batch wrote.  ``None`` means "no usable delta": the
    batch carried negative weights (not additions-only), overflowed the
    row-width tracking cap, or the session had already stopped tracking
    (a prior non-additive mutation with no closure sync since).  The
    subscription plane feeds non-``None`` sets to the incremental closure
    refresh; ``None`` forces the next refresh to rebuild from scratch.

    Fused-ingest sessions (``ingest_backend="fused"``) report the delta as
    ``touched_rows`` instead: the (d, w_r) bool row-bucket bitmap the
    one-pass kernel emitted on device — no host unique pass at all.
    ``touched_keys`` is ``None`` for those receipts."""

    epoch: int
    n_edges: int
    touched_keys: Optional[np.ndarray]
    touched_rows: Optional[jax.Array] = None


def _preset(name: str) -> SketchConfig:
    from repro.configs import glava

    presets = {
        "smoke": glava.SMOKE,
        "base": glava.BASE,
        "web": glava.WEB,
        "nonsquare": glava.NONSQUARE,
    }
    if name not in presets:
        raise ValueError(f"unknown preset {name!r} (want {sorted(presets)})")
    return presets[name]


class GraphStream:
    """One graph-stream session: a summary plus its ingest/query engines.

    Construct via :meth:`open`.  All mutation bumps the sketch *epoch*,
    which tags the query engine's transitive-closure cache so reach
    queries amortize one closure per quiescent period."""

    def __init__(
        self,
        config: SketchConfig,
        *,
        seed: int = 0,
        window_slices: Optional[int] = None,
        ingest_backend: str = "auto",
        query_backend: str = "auto",
        checkpoint_dir: Optional[str] = None,
        keep: int = 3,
        mesh: Optional[jax.sharding.Mesh] = None,
        double_buffer: bool = True,
        max_inflight: int = 2,
        preagg: str = "auto",
    ):
        if mesh is not None and window_slices:
            raise ValueError("windowed + distributed sessions are not supported yet")
        self.config = config
        if window_slices:
            self._window: Optional[SlidingWindowSketch] = SlidingWindowSketch.empty(
                config, window_slices, jax.random.key(seed)
            )
            self._sketch: Optional[GLavaSketch] = None
        else:
            self._window = None
            self._sketch = GLavaSketch.empty(config, jax.random.key(seed))
        # "fused" is a session-level mode, not an IngestEngine backend: the
        # one-pass kernel updates counters + registers + touched bitmap
        # together, which only a plain local session can consume.
        self._fused = ingest_backend == "fused"
        if self._fused and (mesh is not None or window_slices):
            raise ValueError("fused ingest needs a plain local session")
        self.ingest_backend = (
            "fused" if self._fused else resolve_backend(ingest_backend)
        )
        # Host-side pre-aggregation of duplicate (src, dst) pairs before
        # dispatch ("auto" honours REPRO_INGEST_PREAGG, else batches >=
        # PREAGG_MIN_BATCH) — the heavy-tail ingest fast path.
        self._preagg = preagg
        self.engine = QueryEngine(query_backend)
        self.stats = StreamStats()
        self._mesh = mesh
        self._epoch = 0
        # Standing-query plane: registered subscriptions, the session-wide
        # event feed, and the touched-key accumulator feeding the
        # incremental closure refresh (None = "not additions-only since the
        # last closure sync; full rebuild required").
        self._subs: Dict[int, Subscription] = {}
        self._next_sub_id = 0
        self._event_log: collections.deque = collections.deque(
            maxlen=EVENT_LOG_MAXLEN
        )
        self._touched: Optional[List[np.ndarray]] = []
        self._touched_count = 0
        self._monitor_subs: Dict[Tuple[int, float], Subscription] = {}
        # Double-buffered ingest: JAX dispatch is async, so staging the next
        # host batch overlaps the device accumulating the previous one; the
        # deque bounds how many un-materialized updates may be in flight.
        self._max_inflight = max_inflight if double_buffer else 0
        self._inflight: collections.deque = collections.deque()
        backend = self.ingest_backend
        # Donate the live summary through the jit boundary: the update is a
        # scatter-add into the (d, w_r, w_c) counters, so XLA writes them in
        # place instead of allocating a full copy per batch.  Two wrinkles:
        # square sketches alias col_hash to row_hash, and donating the same
        # buffer twice is an XLA error — so the boundary dispatches over the
        # DEDUPLICATED leaf tuple and rebuilds the pytree on both sides.
        # And the double-buffer queue must not hold the counters themselves
        # (they become the donated, hence deleted, inputs of the next
        # dispatch), so the update also returns a tiny completion token the
        # queue blocks on instead.
        live0 = self._window if self._window is not None else self._sketch
        leaves0, treedef = jax.tree_util.tree_flatten(live0)
        seen: Dict[int, int] = {}
        slots = []       # leaf position -> unique-buffer slot
        uniq_idx = []    # unique-buffer slot -> first leaf position
        for i, leaf in enumerate(leaves0):
            j = seen.setdefault(id(leaf), len(uniq_idx))
            if j == len(uniq_idx):
                uniq_idx.append(i)
            slots.append(j)
        self._live_treedef = treedef
        self._uniq_leaf_idx = tuple(uniq_idx)
        slots = tuple(slots)

        if self._fused:

            def _update(uniq, s, d, w):
                live = jax.tree_util.tree_unflatten(
                    treedef, [uniq[j] for j in slots]
                )
                new, touched = live.update_fused(s, d, w)
                return jax.tree_util.tree_leaves(new), jnp.sum(w), touched

        else:

            def _update(uniq, s, d, w):
                live = jax.tree_util.tree_unflatten(
                    treedef, [uniq[j] for j in slots]
                )
                # In-jit pre-aggregation stays off HERE: the session already
                # collapses heavy-tail batches host-side (below), so a
                # second device sort would be pure overhead.
                new = live.update(s, d, w, backend=backend, preagg="off")
                return jax.tree_util.tree_leaves(new), jnp.sum(w)

        self._jit_update = jax.jit(_update, donate_argnums=0)

        def _update_pre(uniq, s, d, w, su, sw, du, dw):
            live = jax.tree_util.tree_unflatten(treedef, [uniq[j] for j in slots])
            new = live.update_preaggregated(
                s, d, w, su, sw, du, dw, backend=backend
            )
            return jax.tree_util.tree_leaves(new), jnp.sum(w)

        # The host-collapsed fast path's donated boundary: distinct pairs
        # feed the counter scatter, per-endpoint marginal totals feed the
        # flow registers.  Arrays arrive padded to power-of-two buckets
        # (pad_bucket) so variable collapse sizes cost a bounded trace
        # ladder, not a retrace per batch.
        self._jit_update_pre = jax.jit(_update_pre, donate_argnums=0)

        # Window expiry boundary: advancing the ring is pure data movement
        # over the (K, d, w_r, w_c) slices, so donating the window lets XLA
        # zero the expiring slice in place instead of copying the whole
        # ring per advance — the same dedup-dispatch shape as _jit_update.
        if self._window is not None:

            def _advance(uniq):
                live = jax.tree_util.tree_unflatten(
                    treedef, [uniq[j] for j in slots]
                )
                return jax.tree_util.tree_leaves(live.advance())

            self._jit_advance = jax.jit(_advance, donate_argnums=0)
        else:
            self._jit_advance = None
        self._ckpt = None
        if checkpoint_dir is not None:
            from repro.checkpoint.manager import CheckpointManager

            self._ckpt = CheckpointManager(checkpoint_dir, keep=keep)

    # -- construction ---------------------------------------------------------

    @classmethod
    def open(
        cls,
        config: Union[SketchConfig, str, None] = None,
        *,
        epsilon: Optional[float] = None,
        delta: Optional[float] = None,
        **kwargs,
    ) -> "GraphStream":
        """Open a session from a :class:`SketchConfig`, a preset name
        ("smoke" / "base" / "web" / "nonsquare"), or a target (ε, δ) pair
        sized per paper Thm 1 / Lemma 5.2.  Remaining kwargs are forwarded
        to the constructor (seed, window_slices, ingest_backend,
        query_backend, checkpoint_dir, mesh, ...)."""
        if isinstance(config, str):
            config = _preset(config)
        elif config is None:
            if epsilon is None or delta is None:
                raise ValueError("open() needs a config, a preset, or (epsilon, delta)")
            config = SketchConfig.for_error(epsilon, delta)
        elif not isinstance(config, SketchConfig):
            raise TypeError(f"config must be SketchConfig or preset name, got {config!r}")
        return cls(config, **kwargs)

    # -- costlint sizing hooks -------------------------------------------------

    @classmethod
    def cost_probe_update(
        cls,
        *,
        width: int = 64,
        depth: int = 2,
        batch: int = 64,
        negative: bool = False,
    ):
        """The REAL donated ingest jit boundary instantiated at a
        parameterized (w, d, B) — the sizing hook costlint compiles at a
        geometric size ladder to fit scaling exponents.  ``negative=True``
        probes the turnstile-delete path (same boundary, negative weights).
        Returns ``(jit_fn, args, counters_shape)``."""
        gs = cls.open(
            SketchConfig(depth=depth, width_rows=width, width_cols=width),
            ingest_backend="scatter",
            query_backend="jnp",
        )
        leaves = jax.tree_util.tree_leaves(gs._sketch)
        uniq = tuple(leaves[i] for i in gs._uniq_leaf_idx)
        src = jnp.arange(batch, dtype=jnp.uint32)
        dst = src + jnp.uint32(batch)
        w = jnp.full((batch,), -1.0 if negative else 1.0, jnp.float32)
        return gs._jit_update, (uniq, src, dst, w), tuple(gs._sketch.counters.shape)

    @classmethod
    def cost_probe_advance(
        cls, *, width: int = 64, depth: int = 2, slices: int = 4
    ):
        """The donated window-advance boundary at a parameterized (w, d, K).
        Returns ``(jit_fn, args, slices_shape)``."""
        gs = cls.open(
            SketchConfig(depth=depth, width_rows=width, width_cols=width),
            window_slices=slices,
            ingest_backend="scatter",
            query_backend="jnp",
        )
        leaves = jax.tree_util.tree_leaves(gs._window)
        uniq = tuple(leaves[i] for i in gs._uniq_leaf_idx)
        return gs._jit_advance, (uniq,), tuple(gs._window.slices.shape)

    # -- state ---------------------------------------------------------------

    @property
    def epoch(self) -> int:
        """Mutation counter; tags the engine's closure cache."""
        return self._epoch

    @property
    def sketch(self) -> GLavaSketch:
        """The live summary (window sessions materialize the window sum)."""
        self.flush()
        return self._live()

    def _live(self) -> GLavaSketch:
        return self._window.window_sketch() if self._window else self._sketch

    def error_bound(self, family: str = "edge") -> ErrorBound:
        """The (ε, δ) annotation this session attaches to ``family`` results."""
        return error_bound_for(family, self.config)

    # -- ingest ---------------------------------------------------------------

    def _dispatch_update(self, live, s, d, w):
        """One donated ingest dispatch: live pytree -> (new live, token,
        touched-row bitmap or None).  Fused sessions get the bitmap from
        the one-pass kernel; plain sessions return None."""
        leaves = jax.tree_util.tree_leaves(live)
        uniq = tuple(leaves[i] for i in self._uniq_leaf_idx)
        if self._fused:
            new_leaves, token, touched = self._jit_update(uniq, s, d, w)
        else:
            new_leaves, token = self._jit_update(uniq, s, d, w)
            touched = None
        new = jax.tree_util.tree_unflatten(self._live_treedef, new_leaves)
        return new, token, touched

    def _dispatch_update_pre(self, live, pre):
        """One donated dispatch of a host-collapsed batch (PreaggBatch).
        Zero-weight bucket padding is exact: counters never hold -0.0, so
        adding +0.0 anywhere is the identity."""
        s = jnp.asarray(pad_bucket(pre.src))
        d = jnp.asarray(pad_bucket(pre.dst))
        w = jnp.asarray(pad_bucket(pre.weights))
        su = jnp.asarray(pad_bucket(pre.src_unique))
        sw = jnp.asarray(pad_bucket(pre.src_totals))
        du = jnp.asarray(pad_bucket(pre.dst_unique))
        dw = jnp.asarray(pad_bucket(pre.dst_totals))
        leaves = jax.tree_util.tree_leaves(live)
        uniq = tuple(leaves[i] for i in self._uniq_leaf_idx)
        new_leaves, token = self._jit_update_pre(uniq, s, d, w, su, sw, du, dw)
        return jax.tree_util.tree_unflatten(self._live_treedef, new_leaves), token

    def ingest(self, src, dst, weights=None) -> IngestReceipt:
        """Fold one edge batch into the summary.  ``src``/``dst`` are label
        batches (str or int — encoded here by the key codec); returns as
        soon as the device accepts the batch (double-buffered; call
        :meth:`flush` or any query to synchronize) — UNLESS a subscription
        comes due on this mutation, in which case the batch lands and the
        standing queries re-evaluate before returning.

        Returns an :class:`IngestReceipt` carrying the batch's touched-key
        set (the rows it wrote) — the delta the incremental closure refresh
        consumes."""
        t0 = time.time()
        s_np = np.atleast_1d(encode_labels(src))
        d_np = np.atleast_1d(encode_labels(dst))
        if s_np.shape != d_np.shape:
            raise ValueError(
                f"src/dst shape mismatch: {s_np.shape} vs {d_np.shape}"
            )
        n_edges = int(s_np.shape[0])
        w_np = (
            np.ones(n_edges, np.float32)
            if weights is None
            else np.asarray(weights, np.float32)
        )
        additive = weights is None or not bool(np.any(w_np < 0))
        # Heavy-tail fast path: collapse duplicate (src, dst) pairs on the
        # host (we are already host-side for label encoding), so the device
        # scatters one slot per distinct pair and the flow registers one
        # slot per distinct endpoint.  Exact for signed weights.
        pre = None
        if resolve_preagg(self._preagg, batch=n_edges):
            pre = preaggregate_host(s_np, d_np, w_np)
        # Only pay the host-side unique scan while a touched-key delta can
        # still be consumed; once tracking is poisoned (prior delete /
        # overflow, no closure sync since) the set is discarded anyway and
        # the hot ingest path skips it entirely.  The collapsed batch gives
        # the unique sources for free; fused sessions skip all of this —
        # their delta is the kernel's device-emitted bitmap.
        touched = None
        if self._touched is not None and additive and not self._fused:
            if pre is not None:
                if self.config.directed:
                    touched = pre.src_unique
                else:
                    touched = np.unique(
                        np.concatenate([pre.src_unique, pre.dst_unique])
                    )
                if touched.size > self.config.width_rows:
                    touched = None
            else:
                touched = touched_row_keys(
                    s_np,
                    None if self.config.directed else d_np,
                    cap=self.config.width_rows,
                )
        touched_rows = None
        if self._mesh is not None:
            from repro.core.distributed import distributed_ingest

            self.flush()
            if pre is not None:
                self._sketch = distributed_ingest(
                    self._mesh,
                    self._sketch,
                    jnp.asarray(pre.src),
                    jnp.asarray(pre.dst),
                    jnp.asarray(pre.weights),
                    preagg_marginals=(
                        jnp.asarray(pre.src_unique),
                        jnp.asarray(pre.src_totals),
                        jnp.asarray(pre.dst_unique),
                        jnp.asarray(pre.dst_totals),
                    ),
                )
            else:
                self._sketch = distributed_ingest(
                    self._mesh,
                    self._sketch,
                    jnp.asarray(s_np),
                    jnp.asarray(d_np),
                    jnp.asarray(w_np),
                )
            self._inflight.append(self._sketch.counters)
        elif pre is not None and not self._fused:
            live = self._window if self._window is not None else self._sketch
            new, token = self._dispatch_update_pre(live, pre)
            if self._window is not None:
                self._window = new
            else:
                self._sketch = new
            self._inflight.append(token)
        else:
            if pre is not None:  # fused + collapsed: pairs through the kernel
                s = jnp.asarray(pad_bucket(pre.src))
                d = jnp.asarray(pad_bucket(pre.dst))
                w = jnp.asarray(pad_bucket(pre.weights))
            else:
                s, d, w = jnp.asarray(s_np), jnp.asarray(d_np), jnp.asarray(w_np)
            live = self._window if self._window is not None else self._sketch
            new, token, touched_rows = self._dispatch_update(live, s, d, w)
            if self._window is not None:
                self._window = new
            else:
                self._sketch = new
            self._inflight.append(token)
        while len(self._inflight) > self._max_inflight:
            jax.block_until_ready(self._inflight.popleft())
        self.stats.edges_ingested += n_edges
        self.stats.ingest_s += time.time() - t0
        self._epoch += 1
        if self._fused:
            self._note_touched(touched_rows if additive else None)
        else:
            self._note_touched(touched)
        receipt = IngestReceipt(
            epoch=self._epoch,
            n_edges=n_edges,
            touched_keys=touched,
            touched_rows=touched_rows if additive else None,
        )
        self._after_mutation()
        return receipt

    def delete(self, src, dst, weights=None) -> IngestReceipt:
        """Turnstile deletion: negative-weight ingest (paper Section 6.1.1).
        Not additions-only, so the receipt's touched set is ``None`` and any
        cached reachability closure rebuilds from scratch on next use."""
        if weights is None:
            weights = np.ones(len(np.atleast_1d(np.asarray(src))), np.float32)
        return self.ingest(src, dst, -np.asarray(weights))

    def flush(self) -> None:
        """Block until every dispatched ingest batch has landed on device."""
        if not self._inflight:
            return
        t0 = time.time()
        while self._inflight:
            jax.block_until_ready(self._inflight.popleft())
        self.stats.ingest_s += time.time() - t0

    # -- queries --------------------------------------------------------------

    def query(self, *queries) -> Union[QueryResult, List[QueryResult]]:
        """Answer queries against the live summary.

        Accepts a single :class:`Query` (returns one :class:`QueryResult`),
        several Query arguments, or one :class:`QueryBatch` (returns a
        request-ordered result list).  The planner fuses the batch into at
        most one engine dispatch per family."""
        single = len(queries) == 1 and isinstance(queries[0], Query)
        if len(queries) == 1 and isinstance(queries[0], QueryBatch):
            batch = queries[0]
        else:
            batch = QueryBatch(queries)
        if len(batch) == 0:
            # Nothing to answer: do not flush, plan, or touch the engine.
            return []
        self.flush()
        t0 = time.time()
        if any(q.family == "reach" for q in batch):
            # Sync the closure cache from the session's touched-key delta so
            # one-shot reach pulls ride the same incremental refresh as
            # standing subscriptions instead of re-squaring the closure.
            self._ensure_closure()
        results = execute(self.engine, self._live(), batch, epoch=self._epoch)
        self.stats.query_s += time.time() - t0
        self._count_served(results)
        self._sync_engine_stats()
        return results[0] if single else results

    # -- standing queries (subscriptions) -------------------------------------

    def subscribe(
        self,
        *queries,
        every: int = 1,
        on_result: Optional[Callable[[SubscriptionEvent], None]] = None,
        alarm: Optional[Callable[[List[QueryResult]], bool]] = None,
        name: Optional[str] = None,
        max_pending: int = DEFAULT_MAX_PENDING,
    ) -> Subscription:
        """Register a standing query batch: a :class:`QueryBatch` (or Query
        arguments, like :meth:`query`) compiled ONCE by the planner and
        re-evaluated automatically after every ``every``-th mutation
        (ingest / delete / advance_window / merge), emitting timestamped
        :class:`SubscriptionEvent`\\ s through ``Subscription.poll()``, the
        session-wide :meth:`events` feed, and the optional ``on_result``
        callback.  ``alarm`` is a predicate over the request-ordered result
        list whose value rides on each event (threshold monitors).

        Re-evaluation is INCREMENTAL: flow/heavy families read the
        maintained registers, edge/subgraph plans replay their fused
        jit-cached dispatches, and reach subscriptions refresh the cached
        transitive closure from the rows touched since the last tick
        (``QueryEngine.refresh_closure``) instead of re-squaring — one full
        closure build per additions-only stream, N incremental refreshes."""
        if len(queries) == 1 and isinstance(queries[0], QueryBatch):
            batch = queries[0]
        else:
            batch = QueryBatch(queries)
        for q in batch:
            if q.family == "heavy":
                validate_theta(q.theta)
        sub = Subscription(
            self,
            self._next_sub_id,
            batch,
            every=every,
            on_result=on_result,
            alarm=alarm,
            name=name,
            max_pending=max_pending,
        )
        self._next_sub_id += 1
        self._subs[sub.id] = sub
        return sub

    @property
    def subscriptions(self) -> Tuple[Subscription, ...]:
        """The active subscriptions, registration-ordered."""
        return tuple(self._subs.values())

    def events(self) -> Iterator[SubscriptionEvent]:
        """Drain the session-wide event feed (all subscriptions, emission
        order).  Non-blocking: yields the pending events and stops."""
        while self._event_log:
            yield self._event_log.popleft()

    def _unsubscribe(self, sub: Subscription) -> None:
        self._subs.pop(sub.id, None)
        if sub.plan.has_reach:
            # The cancelled plan may be the only closure consumer; session
            # teardown/reuse paths (and the fleet's slot recycling) must not
            # find a stale closure that a later epoch tag could collide with.
            self.engine.invalidate()

    def _note_touched(self, batch_delta) -> None:
        """Accumulate one batch's touched-row delta for the next closure
        sync — a unique key array (plain sessions) or a (d, w_r) bool
        device bitmap (fused sessions); ``None`` (non-additive batch) or
        overflowing the row width forces the next sync to rebuild from
        scratch."""
        if self._touched is None:
            return
        if batch_delta is None:
            self._touched = None
            self._touched_count = 0
            return
        self._touched.append(batch_delta)
        if getattr(batch_delta, "ndim", 1) == 2:
            return  # bitmap: bounded by (d, w_r), no overflow cap needed
        self._touched_count += int(batch_delta.size)
        if self._touched_count > self.config.width_rows:
            self._touched = None
            self._touched_count = 0

    def _ensure_closure(self) -> None:
        """Bring the engine's closure cache up to the current epoch — by
        touched-row refresh when the history since the last sync is
        additions-only, else by full rebuild."""
        delta = None
        if self._touched is not None:
            if not self._touched:
                delta = np.zeros(0, np.uint32)
            elif getattr(self._touched[0], "ndim", 1) == 2:
                # Fused sessions: OR the per-batch device bitmaps (cheap
                # device ops), sync once for the refresh.
                bitmap = self._touched[0]
                for b in self._touched[1:]:
                    bitmap = bitmap | b
                delta = np.asarray(bitmap)
            else:
                delta = np.unique(np.concatenate(self._touched)).astype(
                    np.uint32
                )
        self.engine.refresh_closure(self._live(), delta, self._epoch)
        self._touched = []
        self._touched_count = 0

    def _after_mutation(self) -> None:
        """Re-evaluate every subscription that came due on this mutation."""
        due = [
            s for s in list(self._subs.values()) if s.active and s._note_mutation()
        ]
        if not due:
            return
        self.flush()
        t0 = time.time()
        if any(s.plan.has_reach for s in due):
            self._ensure_closure()
        sketch = self._live()
        now = time.time()
        for sub in due:
            results = sub.plan.run(self.engine, sketch, epoch=self._epoch)
            event = SubscriptionEvent(
                subscription_id=sub.id,
                name=sub.name,
                tick=sub.ticks + 1,
                epoch=self._epoch,
                timestamp=now,
                results=tuple(results),
                alarm=None if sub.alarm is None else bool(sub.alarm(results)),
            )
            sub._deliver(event)
            self._event_log.append(event)
            self.stats.subscription_ticks += 1
            self._count_served(results)
        self.stats.query_s += time.time() - t0
        self._sync_engine_stats()

    def _count_served(self, results) -> None:
        for r in results:
            v = r.value
            self.stats.queries_served += (
                int(np.size(v[0])) if isinstance(v, tuple) else int(np.size(v))
            )

    def _sync_engine_stats(self) -> None:
        self.stats.closure_refreshes = self.engine.closure_refreshes
        self.stats.closure_incremental_refreshes = (
            self.engine.closure_incremental_refreshes
        )

    def monitor(self, src, dst, weights, watch, theta: float) -> bool:
        """Paper Section 4.2's real-time monitor as a thin wrapper over a
        threshold subscription: a standing ``Query.heavy(watch, θ)`` with an
        ``alarm`` predicate on the in-flow bit, registered once per
        (watch, θ) and evaluated right after this batch is ingested.  θ is
        the fraction of the total stream weight F̃ (``0 < θ <= 1``,
        validated).  Returns the alarm decision; the subscription keeps
        monitoring subsequent ingests (events via :meth:`events`)."""
        theta = validate_theta(theta)
        key = (int(np.uint32(encode_labels(watch))), theta)
        sub = self._monitor_subs.get(key)
        if sub is None or not sub.active:
            sub = self.subscribe(
                Query.heavy(watch, theta),
                every=1,
                alarm=lambda results: bool(np.asarray(results[0].value[0])),
                name=f"monitor:{key[0]}@{theta:g}",
            )
            self._monitor_subs[key] = sub
        self.ingest(src, dst, weights)
        sub.poll()  # the wrapper consumes its events; last_event remains
        return bool(sub.last_event.alarm)

    def pagerank(self, damping: float = 0.85, iters: int = 32) -> np.ndarray:
        """Run PageRank directly on the summary-as-a-graph (Section 3.3
        Remark): returns (d, w) bucket ranks."""
        self.flush()
        return np.asarray(queries_mod.sketch_pagerank(self._live(), damping, iters))

    # -- convenience wrappers (vectorized; used by the serving engine) --------

    def edge_frequency(self, src, dst) -> np.ndarray:
        return np.atleast_1d(self.query(Query.edge(src, dst)).value)

    def in_flow(self, keys) -> np.ndarray:
        return np.atleast_1d(self.query(Query.in_flow(keys)).value)

    def out_flow(self, keys) -> np.ndarray:
        return np.atleast_1d(self.query(Query.out_flow(keys)).value)

    def heavy_hitters(self, keys, theta: float) -> np.ndarray:
        in_heavy, _ = self.query(Query.heavy(keys, theta)).value
        return np.atleast_1d(in_heavy)

    def reachable(self, src, dst) -> np.ndarray:
        return np.atleast_1d(self.query(Query.reach(src, dst)).value)

    def subgraph_weight(self, src, dst) -> float:
        return float(self.query(Query.subgraph(src, dst)).value)

    # -- lifecycle ------------------------------------------------------------

    def advance_window(self) -> None:
        """Move the sliding window to the next time slice (expiring the
        oldest slice); no-op for non-windowed sessions.  Counts as a
        mutation for subscriptions; expiry removes edges, so any cached
        reachability closure rebuilds from scratch on next use."""
        if self._window is not None:
            self.flush()
            leaves = jax.tree_util.tree_leaves(self._window)
            uniq = tuple(leaves[i] for i in self._uniq_leaf_idx)
            new_leaves = self._jit_advance(uniq)
            self._window = jax.tree_util.tree_unflatten(
                self._live_treedef, new_leaves
            )
            self._epoch += 1
            self._note_touched(None)
            self._after_mutation()

    def merge(self, other: "GraphStream") -> "GraphStream":
        """Merge another session's summary into this one (linearity; the
        paper's distributed merge-by-add).  Both must share a hash family —
        open them with the same config + seed."""
        if self._window is not None or other._window is not None:
            raise ValueError("merge() runs on non-windowed sessions")
        self.flush()
        other.flush()
        if not self._sketch.same_family(other._sketch):
            raise ValueError(
                "cannot merge sketches with different hash families "
                "(open both sessions with the same config and seed)"
            )
        self._sketch = self._sketch.merge(other._sketch)
        self.stats.edges_ingested += other.stats.edges_ingested
        self._epoch += 1
        self._note_touched(None)  # foreign rows everywhere: full rebuild
        self._after_mutation()
        return self

    def checkpoint(self, step: Optional[int] = None) -> int:
        """Durably save the session state (requires ``checkpoint_dir``).
        Returns the step the checkpoint was saved under."""
        if self._ckpt is None:
            raise ValueError("open the session with checkpoint_dir= to checkpoint")
        self.flush()
        step = self._epoch if step is None else step
        state = self._window if self._window is not None else self._sketch
        self._ckpt.save(step, state, metadata={"epoch": self._epoch})
        return step

    def restore(self, step: Optional[int] = None) -> int:
        """Restore session state from the checkpoint directory (latest step
        by default).  Handles pre-register checkpoints via the fill-missing
        schema-evolution path.  Returns the restored step."""
        if self._ckpt is None:
            raise ValueError("open the session with checkpoint_dir= to restore")
        self.flush()
        like = self._window if self._window is not None else self._sketch
        state, meta = self._ckpt.restore(step, like=like, fill_missing=True)
        if meta.get("filled_leaves"):
            # Registers absent from an old checkpoint: rebuild from counters.
            if isinstance(state, GLavaSketch):
                state = state.with_counters(state.counters)
            else:
                state = dataclasses.replace(
                    state,
                    row_flows=jnp.sum(state.slices, axis=3),
                    col_flows=jnp.sum(state.slices, axis=2),
                )
        if self._window is not None:
            self._window = state
        else:
            self._sketch = state
        self._epoch = int(meta.get("epoch", meta["step"]))
        self.engine.invalidate()  # any cached closure predates the restore
        self._touched = []
        self._touched_count = 0
        return int(meta["step"])

    def summary(self) -> Dict[str, float]:
        """Flushed session stats — the only honest read of ingest throughput
        while ingest is double-buffered."""
        self.flush()
        return self.stats.summary()
