"""`GraphStream` — the one session facade over the paper's summary S.

The paper maintains a SINGLE summary supporting a wide range of graph
queries over one stream.  `GraphStream` is that object for callers: it
wraps the ingest plane (:class:`~repro.core.ingest.IngestEngine`, double-
buffered batched dispatch), the query plane (:class:`~repro.core.
query_engine.QueryEngine`, planned + fused by :mod:`repro.api.planner`),
and the optional sliding window (:class:`~repro.core.window.
SlidingWindowSketch`), distributed plane (`mesh=`), and
:class:`~repro.checkpoint.manager.CheckpointManager` behind one handle::

    from repro.api import GraphStream, Query

    gs = GraphStream.open("smoke")           # or a SketchConfig / (ε, δ)
    gs.ingest(["alice", "bob"], ["bob", "carol"])      # labels, not keys
    res = gs.query(Query.edge("alice", "bob"),
                   Query.in_flow("bob"),
                   Query.reach("alice", "carol"))
    print(res[0].value, res[0].error)        # (ε, δ)-annotated estimate

Node labels (str/int) are encoded exactly once at this boundary by the
vectorized key codec (:mod:`repro.api.codec`); everything below speaks
uint32.  Every entry point of the repo (serving engine, launch driver,
examples, benchmarks) routes through this facade — ``repro.core`` stays
importable for internals, but `repro.api` is the canonical public API.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.codec import encode_labels
from repro.api.planner import execute
from repro.api.query import ErrorBound, Query, QueryBatch, QueryResult, error_bound_for
from repro.core import queries as queries_mod
from repro.core.ingest import resolve_backend
from repro.core.query_engine import QueryEngine
from repro.core.sketch import GLavaSketch, SketchConfig
from repro.core.window import SlidingWindowSketch


@dataclasses.dataclass
class StreamStats:
    """Session counters (ingest/query throughput, closure refreshes)."""

    edges_ingested: int = 0
    ingest_s: float = 0.0
    queries_served: int = 0
    query_s: float = 0.0
    closure_refreshes: int = 0

    def summary(self) -> Dict[str, float]:
        return {
            "edges_ingested": self.edges_ingested,
            "ingest_edges_per_s": self.edges_ingested / max(self.ingest_s, 1e-9),
            "queries_served": self.queries_served,
            "queries_per_s": self.queries_served / max(self.query_s, 1e-9),
            "closure_refreshes": self.closure_refreshes,
        }


def _preset(name: str) -> SketchConfig:
    from repro.configs import glava

    presets = {
        "smoke": glava.SMOKE,
        "base": glava.BASE,
        "web": glava.WEB,
        "nonsquare": glava.NONSQUARE,
    }
    if name not in presets:
        raise ValueError(f"unknown preset {name!r} (want {sorted(presets)})")
    return presets[name]


class GraphStream:
    """One graph-stream session: a summary plus its ingest/query engines.

    Construct via :meth:`open`.  All mutation bumps the sketch *epoch*,
    which tags the query engine's transitive-closure cache so reach
    queries amortize one closure per quiescent period."""

    def __init__(
        self,
        config: SketchConfig,
        *,
        seed: int = 0,
        window_slices: Optional[int] = None,
        ingest_backend: str = "auto",
        query_backend: str = "auto",
        checkpoint_dir: Optional[str] = None,
        keep: int = 3,
        mesh: Optional[jax.sharding.Mesh] = None,
        double_buffer: bool = True,
        max_inflight: int = 2,
    ):
        if mesh is not None and window_slices:
            raise ValueError("windowed + distributed sessions are not supported yet")
        self.config = config
        if window_slices:
            self._window: Optional[SlidingWindowSketch] = SlidingWindowSketch.empty(
                config, window_slices, jax.random.key(seed)
            )
            self._sketch: Optional[GLavaSketch] = None
        else:
            self._window = None
            self._sketch = GLavaSketch.empty(config, jax.random.key(seed))
        self.ingest_backend = resolve_backend(ingest_backend)
        self.engine = QueryEngine(query_backend)
        self.stats = StreamStats()
        self._mesh = mesh
        self._epoch = 0
        # Double-buffered ingest: JAX dispatch is async, so staging the next
        # host batch overlaps the device accumulating the previous one; the
        # deque bounds how many un-materialized updates may be in flight.
        self._max_inflight = max_inflight if double_buffer else 0
        self._inflight: collections.deque = collections.deque()
        backend = self.ingest_backend
        self._jit_update = jax.jit(
            lambda live, s, d, w: live.update(s, d, w, backend=backend)
        )
        self._ckpt = None
        if checkpoint_dir is not None:
            from repro.checkpoint.manager import CheckpointManager

            self._ckpt = CheckpointManager(checkpoint_dir, keep=keep)

    # -- construction ---------------------------------------------------------

    @classmethod
    def open(
        cls,
        config: Union[SketchConfig, str, None] = None,
        *,
        epsilon: Optional[float] = None,
        delta: Optional[float] = None,
        **kwargs,
    ) -> "GraphStream":
        """Open a session from a :class:`SketchConfig`, a preset name
        ("smoke" / "base" / "web" / "nonsquare"), or a target (ε, δ) pair
        sized per paper Thm 1 / Lemma 5.2.  Remaining kwargs are forwarded
        to the constructor (seed, window_slices, ingest_backend,
        query_backend, checkpoint_dir, mesh, ...)."""
        if isinstance(config, str):
            config = _preset(config)
        elif config is None:
            if epsilon is None or delta is None:
                raise ValueError("open() needs a config, a preset, or (epsilon, delta)")
            config = SketchConfig.for_error(epsilon, delta)
        elif not isinstance(config, SketchConfig):
            raise TypeError(f"config must be SketchConfig or preset name, got {config!r}")
        return cls(config, **kwargs)

    # -- state ---------------------------------------------------------------

    @property
    def epoch(self) -> int:
        """Mutation counter; tags the engine's closure cache."""
        return self._epoch

    @property
    def sketch(self) -> GLavaSketch:
        """The live summary (window sessions materialize the window sum)."""
        self.flush()
        return self._live()

    def _live(self) -> GLavaSketch:
        return self._window.window_sketch() if self._window else self._sketch

    def error_bound(self, family: str = "edge") -> ErrorBound:
        """The (ε, δ) annotation this session attaches to ``family`` results."""
        return error_bound_for(family, self.config)

    # -- ingest ---------------------------------------------------------------

    def ingest(self, src, dst, weights=None) -> None:
        """Fold one edge batch into the summary.  ``src``/``dst`` are label
        batches (str or int — encoded here by the key codec); returns as
        soon as the device accepts the batch (double-buffered; call
        :meth:`flush` or any query to synchronize)."""
        t0 = time.time()
        s = jnp.asarray(np.atleast_1d(encode_labels(src)))
        d = jnp.asarray(np.atleast_1d(encode_labels(dst)))
        if s.shape != d.shape:
            raise ValueError(f"src/dst shape mismatch: {s.shape} vs {d.shape}")
        w = (
            jnp.ones(s.shape, jnp.float32)
            if weights is None
            else jnp.asarray(weights, jnp.float32)
        )
        if self._mesh is not None:
            from repro.core.distributed import distributed_ingest

            self.flush()
            self._sketch = distributed_ingest(self._mesh, self._sketch, s, d, w)
            self._inflight.append(self._sketch.counters)
        elif self._window is not None:
            self._window = self._jit_update(self._window, s, d, w)
            self._inflight.append(self._window.slices)
        else:
            self._sketch = self._jit_update(self._sketch, s, d, w)
            self._inflight.append(self._sketch.counters)
        while len(self._inflight) > self._max_inflight:
            jax.block_until_ready(self._inflight.popleft())
        self.stats.edges_ingested += int(s.shape[0])
        self.stats.ingest_s += time.time() - t0
        self._epoch += 1

    def delete(self, src, dst, weights=None) -> None:
        """Turnstile deletion: negative-weight ingest (paper Section 6.1.1)."""
        if weights is None:
            weights = np.ones(len(np.atleast_1d(np.asarray(src))), np.float32)
        self.ingest(src, dst, -np.asarray(weights))

    def flush(self) -> None:
        """Block until every dispatched ingest batch has landed on device."""
        if not self._inflight:
            return
        t0 = time.time()
        while self._inflight:
            jax.block_until_ready(self._inflight.popleft())
        self.stats.ingest_s += time.time() - t0

    # -- queries --------------------------------------------------------------

    def query(self, *queries) -> Union[QueryResult, List[QueryResult]]:
        """Answer queries against the live summary.

        Accepts a single :class:`Query` (returns one :class:`QueryResult`),
        several Query arguments, or one :class:`QueryBatch` (returns a
        request-ordered result list).  The planner fuses the batch into at
        most one engine dispatch per family."""
        single = len(queries) == 1 and isinstance(queries[0], Query)
        if len(queries) == 1 and isinstance(queries[0], QueryBatch):
            batch = queries[0]
        else:
            batch = QueryBatch(queries)
        self.flush()
        t0 = time.time()
        results = execute(self.engine, self._live(), batch, epoch=self._epoch)
        self.stats.query_s += time.time() - t0
        for r in results:
            v = r.value
            self.stats.queries_served += (
                int(np.size(v[0])) if isinstance(v, tuple) else int(np.size(v))
            )
        self.stats.closure_refreshes = self.engine.closure_refreshes
        return results[0] if single else results

    def monitor(self, src, dst, weights, watch, theta: float) -> bool:
        """Paper Section 4.2's three-step real-time monitor: estimate the
        watched node's in-flow, alarm if this batch pushes it over θ, then
        ingest the batch.  Returns the alarm decision."""
        if self._window is not None:
            raise ValueError("monitor() runs on non-windowed sessions")
        self.flush()
        t0 = time.time()
        s = jnp.asarray(np.atleast_1d(encode_labels(src)))
        d = jnp.asarray(np.atleast_1d(encode_labels(dst)))
        w = jnp.asarray(weights, jnp.float32)
        watch_key = jnp.asarray(np.uint32(encode_labels(watch)))
        alarm, self._sketch = queries_mod.monitor_step(
            self._sketch, s, d, w, watch_key, theta
        )
        self.stats.edges_ingested += int(s.shape[0])
        self.stats.ingest_s += time.time() - t0
        self._epoch += 1
        return bool(alarm)

    def pagerank(self, damping: float = 0.85, iters: int = 32) -> np.ndarray:
        """Run PageRank directly on the summary-as-a-graph (Section 3.3
        Remark): returns (d, w) bucket ranks."""
        self.flush()
        return np.asarray(queries_mod.sketch_pagerank(self._live(), damping, iters))

    # -- convenience wrappers (vectorized; used by the serving engine) --------

    def edge_frequency(self, src, dst) -> np.ndarray:
        return np.atleast_1d(self.query(Query.edge(src, dst)).value)

    def in_flow(self, keys) -> np.ndarray:
        return np.atleast_1d(self.query(Query.in_flow(keys)).value)

    def out_flow(self, keys) -> np.ndarray:
        return np.atleast_1d(self.query(Query.out_flow(keys)).value)

    def heavy_hitters(self, keys, theta: float) -> np.ndarray:
        in_heavy, _ = self.query(Query.heavy(keys, theta)).value
        return np.atleast_1d(in_heavy)

    def reachable(self, src, dst) -> np.ndarray:
        return np.atleast_1d(self.query(Query.reach(src, dst)).value)

    def subgraph_weight(self, src, dst) -> float:
        return float(self.query(Query.subgraph(src, dst)).value)

    # -- lifecycle ------------------------------------------------------------

    def advance_window(self) -> None:
        """Move the sliding window to the next time slice (expiring the
        oldest slice); no-op for non-windowed sessions."""
        if self._window is not None:
            self.flush()
            self._window = self._window.advance()
            self._epoch += 1

    def merge(self, other: "GraphStream") -> "GraphStream":
        """Merge another session's summary into this one (linearity; the
        paper's distributed merge-by-add).  Both must share a hash family —
        open them with the same config + seed."""
        if self._window is not None or other._window is not None:
            raise ValueError("merge() runs on non-windowed sessions")
        self.flush()
        other.flush()
        if not self._sketch.same_family(other._sketch):
            raise ValueError(
                "cannot merge sketches with different hash families "
                "(open both sessions with the same config and seed)"
            )
        self._sketch = self._sketch.merge(other._sketch)
        self.stats.edges_ingested += other.stats.edges_ingested
        self._epoch += 1
        return self

    def checkpoint(self, step: Optional[int] = None) -> int:
        """Durably save the session state (requires ``checkpoint_dir``).
        Returns the step the checkpoint was saved under."""
        if self._ckpt is None:
            raise ValueError("open the session with checkpoint_dir= to checkpoint")
        self.flush()
        step = self._epoch if step is None else step
        state = self._window if self._window is not None else self._sketch
        self._ckpt.save(step, state, metadata={"epoch": self._epoch})
        return step

    def restore(self, step: Optional[int] = None) -> int:
        """Restore session state from the checkpoint directory (latest step
        by default).  Handles pre-register checkpoints via the fill-missing
        schema-evolution path.  Returns the restored step."""
        if self._ckpt is None:
            raise ValueError("open the session with checkpoint_dir= to restore")
        self.flush()
        like = self._window if self._window is not None else self._sketch
        state, meta = self._ckpt.restore(step, like=like, fill_missing=True)
        if meta.get("filled_leaves"):
            # Registers absent from an old checkpoint: rebuild from counters.
            if isinstance(state, GLavaSketch):
                state = state.with_counters(state.counters)
            else:
                state = dataclasses.replace(
                    state,
                    row_flows=jnp.sum(state.slices, axis=3),
                    col_flows=jnp.sum(state.slices, axis=2),
                )
        if self._window is not None:
            self._window = state
        else:
            self._sketch = state
        self._epoch = int(meta.get("epoch", meta["step"]))
        self.engine.invalidate()  # any cached closure predates the restore
        return int(meta["step"])

    def summary(self) -> Dict[str, float]:
        """Flushed session stats — the only honest read of ingest throughput
        while ingest is double-buffered."""
        self.flush()
        return self.stats.summary()
