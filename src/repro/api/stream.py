"""`GraphStream` — the one session facade over the paper's summary S.

The paper maintains a SINGLE summary supporting a wide range of graph
queries over one stream.  `GraphStream` is that object for callers: it
wraps the ingest plane (:class:`~repro.core.ingest.IngestEngine`, double-
buffered batched dispatch), the query plane (:class:`~repro.core.
query_engine.QueryEngine`, planned + fused by :mod:`repro.api.planner`),
the standing-query plane (:mod:`repro.api.subscription`), and the optional
sliding window (:class:`~repro.core.window.SlidingWindowSketch`),
distributed plane (`mesh=`), and :class:`~repro.checkpoint.manager.
CheckpointManager` behind one handle::

    from repro.api import GraphStream, Query

    gs = GraphStream.open("smoke")           # or a SketchConfig / (ε, δ)
    gs.ingest(["alice", "bob"], ["bob", "carol"])      # labels, not keys

    # one-shot pull
    res = gs.query(Query.edge("alice", "bob"),
                   Query.in_flow("bob"),
                   Query.reach("alice", "carol"))
    print(res[0].value, res[0].error)        # (ε, δ)-annotated estimate

    # standing subscription: compiled once, re-evaluated incrementally
    # after every 4th mutation, results as timestamped events
    sub = gs.subscribe(Query.reach("alice", "carol"),
                       Query.in_flow("carol"), every=4)
    gs.ingest(more_src, more_dst)
    for event in sub.poll():
        print(event.tick, event.results)

Node labels (str/int) are encoded exactly once at this boundary by the
vectorized key codec (:mod:`repro.api.codec`); everything below speaks
uint32.  Every entry point of the repo (serving engine, launch driver,
examples, benchmarks) routes through this facade — ``repro.core`` stays
importable for internals, but `repro.api` is the canonical public API.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Callable, Dict, Iterator, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.codec import encode_label, encode_labels
from repro.api.planner import execute
from repro.api.query import (
    ErrorBound,
    Query,
    QueryBatch,
    QueryResult,
    error_bound_for,
    validate_theta,
)
from repro.api.subscription import (
    DEFAULT_MAX_PENDING,
    Subscription,
    SubscriptionEvent,
    sub_progress_key,
)
from repro.core import queries as queries_mod
from repro.core.ingest import (
    pad_bucket,
    preaggregate_host,
    resolve_backend,
    resolve_preagg,
    touched_row_keys,
)
from repro.core.query_engine import QueryEngine
from repro.core.sketch import GLavaSketch, SketchConfig
from repro.core.window import SlidingWindowSketch
from repro.stream.events import EventFeed
from repro.stream.wal import (
    AdvanceMutation,
    EdgeMutation,
    WriteAheadLog,
)
from repro.stream.watermark import (
    DEFAULT_SOURCE,
    WatermarkTracker,
    slice_of,
    slices_of,
)

# Session-wide event feed bound (per-subscription queues have their own);
# past it the session's ``events_policy`` applies and ``events_dropped``
# counts the loss (no more silent truncation).
EVENT_LOG_MAXLEN = 4096

LATE_POLICIES = ("retract", "drop")


@dataclasses.dataclass
class StreamStats:
    """Session counters (ingest/query throughput, closure refreshes,
    subscription ticks)."""

    edges_ingested: int = 0
    ingest_s: float = 0.0
    queries_served: int = 0
    query_s: float = 0.0
    closure_refreshes: int = 0
    closure_incremental_refreshes: int = 0
    subscription_ticks: int = 0
    auto_advances: int = 0

    def summary(self) -> Dict[str, float]:
        return {
            "edges_ingested": self.edges_ingested,
            "ingest_edges_per_s": self.edges_ingested / max(self.ingest_s, 1e-9),
            "queries_served": self.queries_served,
            "queries_per_s": self.queries_served / max(self.query_s, 1e-9),
            "closure_refreshes": self.closure_refreshes,
            "closure_incremental_refreshes": self.closure_incremental_refreshes,
            "subscription_ticks": self.subscription_ticks,
            "auto_advances": self.auto_advances,
        }


@dataclasses.dataclass(frozen=True)
class IngestReceipt:
    """What one ``ingest`` call did: the post-batch epoch, the batch size,
    and the batch's touched-key set — the unique uint32 node keys whose
    sketch ROWS the batch wrote.  ``None`` means "no usable delta": the
    batch carried negative weights (not additions-only), overflowed the
    row-width tracking cap, or the session had already stopped tracking
    (a prior non-additive mutation with no closure sync since).  The
    subscription plane feeds non-``None`` sets to the incremental closure
    refresh; ``None`` forces the next refresh to rebuild from scratch.

    Fused-ingest sessions (``ingest_backend="fused"``) report the delta as
    ``touched_rows`` instead: the (d, w_r) bool row-bucket bitmap the
    one-pass kernel emitted on device — no host unique pass at all.
    ``touched_keys`` is ``None`` for those receipts."""

    epoch: int
    n_edges: int
    touched_keys: Optional[np.ndarray]
    touched_rows: Optional[jax.Array] = None
    # Event-time plane (None / 0 for arrival-ordered sessions): the
    # batch's event-time span, the session watermark after folding it,
    # how many edges the lateness policy dropped/retracted, how many
    # slice advances the watermark drove, and the batch's durable WAL
    # commit seq (None when the session has no WAL).
    event_time_min: Optional[float] = None
    event_time_max: Optional[float] = None
    watermark: Optional[float] = None
    late_dropped: int = 0
    late_retracted: int = 0
    auto_advances: int = 0
    wal_seq: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """What :meth:`GraphStream.recover` did: the checkpoint step it
    restored (None = no checkpoint, full-genesis replay), how many WAL
    mutations it replayed, and the session epoch / WAL position after."""

    step: Optional[int]
    mutations_replayed: int
    epoch: int
    wal_seq: int


def _preset(name: str) -> SketchConfig:
    from repro.configs import glava

    presets = {
        "smoke": glava.SMOKE,
        "base": glava.BASE,
        "web": glava.WEB,
        "nonsquare": glava.NONSQUARE,
    }
    if name not in presets:
        raise ValueError(f"unknown preset {name!r} (want {sorted(presets)})")
    return presets[name]


class GraphStream:
    """One graph-stream session: a summary plus its ingest/query engines.

    Construct via :meth:`open`.  All mutation bumps the sketch *epoch*,
    which tags the query engine's transitive-closure cache so reach
    queries amortize one closure per quiescent period."""

    def __init__(
        self,
        config: SketchConfig,
        *,
        seed: int = 0,
        window_slices: Optional[int] = None,
        ingest_backend: str = "auto",
        query_backend: str = "auto",
        checkpoint_dir: Optional[str] = None,
        keep: int = 3,
        mesh: Optional[jax.sharding.Mesh] = None,
        double_buffer: bool = True,
        max_inflight: int = 2,
        preagg: str = "auto",
        wal_dir: Optional[str] = None,
        wal_fsync_every: int = 1,
        slice_width: Optional[float] = None,
        max_lateness: Optional[float] = None,
        late_policy: str = "retract",
        events_policy: str = "drop_oldest",
    ):
        if mesh is not None and window_slices:
            raise ValueError("windowed + distributed sessions are not supported yet")
        # Event-time plane: slice_width maps event times onto the window
        # ring; max_lateness bounds out-of-orderness (how far behind the
        # per-source maximum the watermark trails).
        if late_policy not in LATE_POLICIES:
            raise ValueError(
                f"unknown late_policy {late_policy!r} (want one of {LATE_POLICIES})"
            )
        self._late_policy = late_policy
        self._tracker: Optional[WatermarkTracker] = None
        self._slice_width: Optional[float] = None
        self._lead = 0
        self._head_slice: Optional[int] = None
        # Host mirror of the ring's current-slot index: slot(b) for an
        # absolute slice b is (b - head_slice + ring_pos) % K, an invariant
        # because the head and the ring only ever advance together.
        self._ring_pos = 0
        if max_lateness is not None and slice_width is None:
            raise ValueError("max_lateness needs slice_width= (event-time slicing)")
        if slice_width is not None:
            if not window_slices:
                raise ValueError("slice_width needs window_slices= (a sliding window)")
            slice_width = float(slice_width)
            if not (slice_width > 0.0) or not math.isfinite(slice_width):
                raise ValueError(f"slice_width must be finite and > 0, got {slice_width}")
            lateness = float(max_lateness) if max_lateness is not None else 0.0
            self._tracker = WatermarkTracker(lateness)
            self._slice_width = slice_width
            # Head slices the ring must keep open AHEAD of the watermark:
            # an in-bound edge (t >= W) from the watermark-defining source
            # sits at most max_lateness past W, i.e. <= lead slices ahead.
            self._lead = int(math.ceil(lateness / slice_width))
            if self._lead + 1 > window_slices:
                raise ValueError(
                    f"max_lateness={lateness:g} spans {self._lead} slices of "
                    f"width {slice_width:g} — it must fit inside the "
                    f"window ring (window_slices={window_slices}); widen the "
                    f"slices or deepen the window"
                )
        self._wal = (
            WriteAheadLog(wal_dir, fsync_every=wal_fsync_every)
            if wal_dir is not None
            else None
        )
        self._replaying = False
        self._last_restore_meta: Dict = {}
        self.config = config
        if window_slices:
            self._window: Optional[SlidingWindowSketch] = SlidingWindowSketch.empty(
                config, window_slices, jax.random.key(seed)
            )
            self._sketch: Optional[GLavaSketch] = None
        else:
            self._window = None
            self._sketch = GLavaSketch.empty(config, jax.random.key(seed))
        # "fused" is a session-level mode, not an IngestEngine backend: the
        # one-pass kernel updates counters + registers + touched bitmap
        # together, which only a plain local session can consume.
        self._fused = ingest_backend == "fused"
        if self._fused and (mesh is not None or window_slices):
            raise ValueError("fused ingest needs a plain local session")
        self.ingest_backend = (
            "fused" if self._fused else resolve_backend(ingest_backend)
        )
        # Host-side pre-aggregation of duplicate (src, dst) pairs before
        # dispatch ("auto" honours REPRO_INGEST_PREAGG, else batches >=
        # PREAGG_MIN_BATCH) — the heavy-tail ingest fast path.
        self._preagg = preagg
        self.engine = QueryEngine(query_backend)
        self.stats = StreamStats()
        self._mesh = mesh
        self._epoch = 0
        # Standing-query plane: registered subscriptions, the session-wide
        # event feed, and the touched-key accumulator feeding the
        # incremental closure refresh (None = "not additions-only since the
        # last closure sync; full rebuild required").
        self._subs: Dict[int, Subscription] = {}
        self._next_sub_id = 0
        self._event_log = EventFeed(EVENT_LOG_MAXLEN, events_policy)
        self._touched: Optional[List[np.ndarray]] = []
        self._touched_count = 0
        self._monitor_subs: Dict[Tuple[int, float], Subscription] = {}
        # Double-buffered ingest: JAX dispatch is async, so staging the next
        # host batch overlaps the device accumulating the previous one; the
        # deque bounds how many un-materialized updates may be in flight.
        self._max_inflight = max_inflight if double_buffer else 0
        self._inflight: collections.deque = collections.deque()
        backend = self.ingest_backend
        # Donate the live summary through the jit boundary: the update is a
        # scatter-add into the (d, w_r, w_c) counters, so XLA writes them in
        # place instead of allocating a full copy per batch.  Two wrinkles:
        # square sketches alias col_hash to row_hash, and donating the same
        # buffer twice is an XLA error — so the boundary dispatches over the
        # DEDUPLICATED leaf tuple and rebuilds the pytree on both sides.
        # And the double-buffer queue must not hold the counters themselves
        # (they become the donated, hence deleted, inputs of the next
        # dispatch), so the update also returns a tiny completion token the
        # queue blocks on instead.
        live0 = self._window if self._window is not None else self._sketch
        leaves0, treedef = jax.tree_util.tree_flatten(live0)
        seen: Dict[int, int] = {}
        slots = []       # leaf position -> unique-buffer slot
        uniq_idx = []    # unique-buffer slot -> first leaf position
        for i, leaf in enumerate(leaves0):
            j = seen.setdefault(id(leaf), len(uniq_idx))
            if j == len(uniq_idx):
                uniq_idx.append(i)
            slots.append(j)
        self._live_treedef = treedef
        self._uniq_leaf_idx = tuple(uniq_idx)
        slots = tuple(slots)

        if self._fused:

            def _update(uniq, s, d, w):
                live = jax.tree_util.tree_unflatten(
                    treedef, [uniq[j] for j in slots]
                )
                new, touched = live.update_fused(s, d, w)
                return jax.tree_util.tree_leaves(new), jnp.sum(w), touched

        else:

            def _update(uniq, s, d, w):
                live = jax.tree_util.tree_unflatten(
                    treedef, [uniq[j] for j in slots]
                )
                # In-jit pre-aggregation stays off HERE: the session already
                # collapses heavy-tail batches host-side (below), so a
                # second device sort would be pure overhead.
                new = live.update(s, d, w, backend=backend, preagg="off")
                return jax.tree_util.tree_leaves(new), jnp.sum(w)

        self._jit_update = jax.jit(_update, donate_argnums=0)

        def _update_pre(uniq, s, d, w, su, sw, du, dw):
            live = jax.tree_util.tree_unflatten(treedef, [uniq[j] for j in slots])
            new = live.update_preaggregated(
                s, d, w, su, sw, du, dw, backend=backend
            )
            return jax.tree_util.tree_leaves(new), jnp.sum(w)

        # The host-collapsed fast path's donated boundary: distinct pairs
        # feed the counter scatter, per-endpoint marginal totals feed the
        # flow registers.  Arrays arrive padded to power-of-two buckets
        # (pad_bucket) so variable collapse sizes cost a bounded trace
        # ladder, not a retrace per batch.
        self._jit_update_pre = jax.jit(_update_pre, donate_argnums=0)

        # Window expiry boundary: advancing the ring is pure data movement
        # over the (K, d, w_r, w_c) slices, so donating the window lets XLA
        # zero the expiring slice in place instead of copying the whole
        # ring per advance — the same dedup-dispatch shape as _jit_update.
        if self._window is not None:

            def _advance(uniq):
                live = jax.tree_util.tree_unflatten(
                    treedef, [uniq[j] for j in slots]
                )
                return jax.tree_util.tree_leaves(live.advance())

            self._jit_advance = jax.jit(_advance, donate_argnums=0)

            # Event-time routing boundary: fold a batch into an ARBITRARY
            # ring slot (late-but-in-bound edges land in the slice their
            # event time belongs to).  The slot is a traced int32 scalar,
            # so ONE compiled update serves all K slices; the ring is
            # donated exactly like _jit_update.
            def _update_slice(uniq, s, d, w, slot):
                live = jax.tree_util.tree_unflatten(
                    treedef, [uniq[j] for j in slots]
                )
                new = live.update_at(slot, s, d, w, backend=backend)
                return jax.tree_util.tree_leaves(new), jnp.sum(w)

            self._jit_update_slice = jax.jit(_update_slice, donate_argnums=0)
        else:
            self._jit_advance = None
            self._jit_update_slice = None
        self._ckpt = None
        if checkpoint_dir is not None:
            from repro.checkpoint.manager import CheckpointManager

            self._ckpt = CheckpointManager(checkpoint_dir, keep=keep)

    # -- construction ---------------------------------------------------------

    @classmethod
    def open(
        cls,
        config: Union[SketchConfig, str, None] = None,
        *,
        epsilon: Optional[float] = None,
        delta: Optional[float] = None,
        **kwargs,
    ) -> "GraphStream":
        """Open a session from a :class:`SketchConfig`, a preset name
        ("smoke" / "base" / "web" / "nonsquare"), or a target (ε, δ) pair
        sized per paper Thm 1 / Lemma 5.2.  Remaining kwargs are forwarded
        to the constructor (seed, window_slices, ingest_backend,
        query_backend, checkpoint_dir, mesh, ...)."""
        if isinstance(config, str):
            config = _preset(config)
        elif config is None:
            if epsilon is None or delta is None:
                raise ValueError("open() needs a config, a preset, or (epsilon, delta)")
            config = SketchConfig.for_error(epsilon, delta)
        elif not isinstance(config, SketchConfig):
            raise TypeError(f"config must be SketchConfig or preset name, got {config!r}")
        return cls(config, **kwargs)

    # -- costlint sizing hooks -------------------------------------------------

    @classmethod
    def cost_probe_update(
        cls,
        *,
        width: int = 64,
        depth: int = 2,
        batch: int = 64,
        negative: bool = False,
    ):
        """The REAL donated ingest jit boundary instantiated at a
        parameterized (w, d, B) — the sizing hook costlint compiles at a
        geometric size ladder to fit scaling exponents.  ``negative=True``
        probes the turnstile-delete path (same boundary, negative weights).
        Returns ``(jit_fn, args, counters_shape)``."""
        gs = cls.open(
            SketchConfig(depth=depth, width_rows=width, width_cols=width),
            ingest_backend="scatter",
            query_backend="jnp",
        )
        leaves = jax.tree_util.tree_leaves(gs._sketch)
        uniq = tuple(leaves[i] for i in gs._uniq_leaf_idx)
        src = jnp.arange(batch, dtype=jnp.uint32)
        dst = src + jnp.uint32(batch)
        w = jnp.full((batch,), -1.0 if negative else 1.0, jnp.float32)
        return gs._jit_update, (uniq, src, dst, w), tuple(gs._sketch.counters.shape)

    @classmethod
    def cost_probe_advance(
        cls, *, width: int = 64, depth: int = 2, slices: int = 4
    ):
        """The donated window-advance boundary at a parameterized (w, d, K).
        Returns ``(jit_fn, args, slices_shape)``."""
        gs = cls.open(
            SketchConfig(depth=depth, width_rows=width, width_cols=width),
            window_slices=slices,
            ingest_backend="scatter",
            query_backend="jnp",
        )
        leaves = jax.tree_util.tree_leaves(gs._window)
        uniq = tuple(leaves[i] for i in gs._uniq_leaf_idx)
        return gs._jit_advance, (uniq,), tuple(gs._window.slices.shape)

    @classmethod
    def cost_probe_update_slice(
        cls, *, width: int = 64, depth: int = 2, slices: int = 4, batch: int = 64
    ):
        """The donated event-time slice-routing boundary at a parameterized
        (w, d, K, B) — one batch folded into one traced ring slot.
        Returns ``(jit_fn, args, slices_shape)``."""
        gs = cls.open(
            SketchConfig(depth=depth, width_rows=width, width_cols=width),
            window_slices=slices,
            ingest_backend="scatter",
            query_backend="jnp",
        )
        leaves = jax.tree_util.tree_leaves(gs._window)
        uniq = tuple(leaves[i] for i in gs._uniq_leaf_idx)
        src = jnp.arange(batch, dtype=jnp.uint32)
        dst = src + jnp.uint32(batch)
        w = jnp.ones((batch,), jnp.float32)
        slot = jnp.array(0, jnp.int32)
        return (
            gs._jit_update_slice,
            (uniq, src, dst, w, slot),
            tuple(gs._window.slices.shape),
        )

    # -- state ---------------------------------------------------------------

    @property
    def epoch(self) -> int:
        """Mutation counter; tags the engine's closure cache."""
        return self._epoch

    @property
    def watermark(self) -> Optional[float]:
        """The event-time low watermark (None on arrival-ordered sessions;
        -inf before the first timestamped batch)."""
        return None if self._tracker is None else self._tracker.watermark

    @property
    def late_dropped(self) -> int:
        """Too-late edges dropped by ``late_policy="drop"`` (monotone)."""
        return 0 if self._tracker is None else self._tracker.late_dropped

    @property
    def late_retracted(self) -> int:
        """Too-late edges backed out via the turnstile-delete path by
        ``late_policy="retract"`` (monotone)."""
        return 0 if self._tracker is None else self._tracker.late_retracted

    @property
    def events_dropped(self) -> int:
        """Session-feed events lost to the overflow policy (monotone); the
        per-subscription counters live on ``Subscription.events_dropped``."""
        return self._event_log.dropped

    @property
    def wal_seq(self) -> Optional[int]:
        """The WAL's last durable record seq (None without a WAL)."""
        return None if self._wal is None else self._wal.last_seq

    @property
    def sketch(self) -> GLavaSketch:
        """The live summary (window sessions materialize the window sum)."""
        self.flush()
        return self._live()

    def _live(self) -> GLavaSketch:
        return self._window.window_sketch() if self._window else self._sketch

    def error_bound(self, family: str = "edge") -> ErrorBound:
        """The (ε, δ) annotation this session attaches to ``family`` results."""
        return error_bound_for(family, self.config)

    # -- ingest ---------------------------------------------------------------

    def _dispatch_update(self, live, s, d, w):
        """One donated ingest dispatch: live pytree -> (new live, token,
        touched-row bitmap or None).  Fused sessions get the bitmap from
        the one-pass kernel; plain sessions return None."""
        leaves = jax.tree_util.tree_leaves(live)
        uniq = tuple(leaves[i] for i in self._uniq_leaf_idx)
        if self._fused:
            new_leaves, token, touched = self._jit_update(uniq, s, d, w)
        else:
            new_leaves, token = self._jit_update(uniq, s, d, w)
            touched = None
        new = jax.tree_util.tree_unflatten(self._live_treedef, new_leaves)
        return new, token, touched

    def _dispatch_update_pre(self, live, pre):
        """One donated dispatch of a host-collapsed batch (PreaggBatch).
        Zero-weight bucket padding is exact: counters never hold -0.0, so
        adding +0.0 anywhere is the identity."""
        s = jnp.asarray(pad_bucket(pre.src))
        d = jnp.asarray(pad_bucket(pre.dst))
        w = jnp.asarray(pad_bucket(pre.weights))
        su = jnp.asarray(pad_bucket(pre.src_unique))
        sw = jnp.asarray(pad_bucket(pre.src_totals))
        du = jnp.asarray(pad_bucket(pre.dst_unique))
        dw = jnp.asarray(pad_bucket(pre.dst_totals))
        leaves = jax.tree_util.tree_leaves(live)
        uniq = tuple(leaves[i] for i in self._uniq_leaf_idx)
        new_leaves, token = self._jit_update_pre(uniq, s, d, w, su, sw, du, dw)
        return jax.tree_util.tree_unflatten(self._live_treedef, new_leaves), token

    def ingest(
        self, src, dst, weights=None, *, timestamps=None, source=None
    ) -> IngestReceipt:
        """Fold one edge batch into the summary.  ``src``/``dst`` are label
        batches (str or int — encoded here by the key codec); returns as
        soon as the device accepts the batch (double-buffered; call
        :meth:`flush` or any query to synchronize) — UNLESS a subscription
        comes due on this mutation, in which case the batch lands and the
        standing queries re-evaluate before returning.

        ``timestamps`` is the per-edge EVENT-TIME column (float seconds,
        any epoch).  On an event-time session (opened with ``slice_width=``
        / ``max_lateness=``) it is required: the watermark tracker folds the
        batch, auto-advances the sliding window when the watermark crosses
        a slice boundary, routes late-but-in-bound edges into the slice
        their event time belongs to, and drops or retracts too-late edges
        per ``late_policy``.  ``source`` names the emitting stream for the
        per-source low-watermark merge (one slow source holds the session
        watermark back).

        Returns an :class:`IngestReceipt` carrying the batch's touched-key
        set (the rows it wrote) — the delta the incremental closure refresh
        consumes — plus the event-time fields (watermark, late counts, WAL
        seq) when those planes are active."""
        s_np = np.atleast_1d(encode_labels(src))
        d_np = np.atleast_1d(encode_labels(dst))
        if s_np.shape != d_np.shape:
            raise ValueError(
                f"src/dst shape mismatch: {s_np.shape} vs {d_np.shape}"
            )
        n_edges = int(s_np.shape[0])
        w_np = (
            np.ones(n_edges, np.float32)
            if weights is None
            else np.asarray(weights, np.float32)
        )
        ts_np = None
        if timestamps is not None:
            ts_np = np.atleast_1d(np.asarray(timestamps, np.float64))
            if ts_np.shape != s_np.shape:
                raise ValueError(
                    f"timestamps/src shape mismatch: {ts_np.shape} vs {s_np.shape}"
                )
            if ts_np.size and not np.all(np.isfinite(ts_np)):
                raise ValueError("event timestamps must be finite")
        elif self._tracker is not None:
            raise ValueError(
                "event-time session (opened with slice_width=/max_lateness=) "
                "requires timestamps= on every ingest"
            )
        source_key = (
            DEFAULT_SOURCE if source is None else int(encode_label(source))
        )
        return self._ingest_encoded(s_np, d_np, w_np, ts_np, source_key)

    def _ingest_encoded(
        self,
        s_np: np.ndarray,
        d_np: np.ndarray,
        w_np: np.ndarray,
        ts_np: Optional[np.ndarray],
        source_key: int,
    ) -> IngestReceipt:
        """Post-codec ingest: the path WAL replay re-enters (keys are
        already uint32, the source label is already hashed).  Appends to
        the WAL FIRST — before any device dispatch — so an acknowledged
        batch is always recoverable."""
        t0 = time.time()
        n_edges = int(s_np.shape[0])
        wal_seq = None
        if self._wal is not None and not self._replaying:
            wal_seq = self._wal.append_edges(
                s_np, d_np, w_np, ts_np, source_key=source_key
            )
        ev_min = ev_max = None
        if ts_np is not None and n_edges:
            ev_min, ev_max = float(ts_np.min()), float(ts_np.max())
        if self._tracker is not None:
            return self._ingest_eventtime(
                t0, s_np, d_np, w_np, ts_np, source_key,
                ev_min=ev_min, ev_max=ev_max, wal_seq=wal_seq,
            )
        additive = not bool(np.any(w_np < 0))
        # Heavy-tail fast path: collapse duplicate (src, dst) pairs on the
        # host (we are already host-side for label encoding), so the device
        # scatters one slot per distinct pair and the flow registers one
        # slot per distinct endpoint.  Exact for signed weights.
        pre = None
        if resolve_preagg(self._preagg, batch=n_edges):
            pre = preaggregate_host(s_np, d_np, w_np)
        # Only pay the host-side unique scan while a touched-key delta can
        # still be consumed; once tracking is poisoned (prior delete /
        # overflow, no closure sync since) the set is discarded anyway and
        # the hot ingest path skips it entirely.  The collapsed batch gives
        # the unique sources for free; fused sessions skip all of this —
        # their delta is the kernel's device-emitted bitmap.
        touched = None
        if self._touched is not None and additive and not self._fused:
            if pre is not None:
                if self.config.directed:
                    touched = pre.src_unique
                else:
                    touched = np.unique(
                        np.concatenate([pre.src_unique, pre.dst_unique])
                    )
                if touched.size > self.config.width_rows:
                    touched = None
            else:
                touched = touched_row_keys(
                    s_np,
                    None if self.config.directed else d_np,
                    cap=self.config.width_rows,
                )
        touched_rows = None
        if self._mesh is not None:
            from repro.core.distributed import distributed_ingest

            self.flush()
            if pre is not None:
                self._sketch = distributed_ingest(
                    self._mesh,
                    self._sketch,
                    jnp.asarray(pre.src),
                    jnp.asarray(pre.dst),
                    jnp.asarray(pre.weights),
                    preagg_marginals=(
                        jnp.asarray(pre.src_unique),
                        jnp.asarray(pre.src_totals),
                        jnp.asarray(pre.dst_unique),
                        jnp.asarray(pre.dst_totals),
                    ),
                )
            else:
                self._sketch = distributed_ingest(
                    self._mesh,
                    self._sketch,
                    jnp.asarray(s_np),
                    jnp.asarray(d_np),
                    jnp.asarray(w_np),
                )
            self._inflight.append(self._sketch.counters)
        elif pre is not None and not self._fused:
            live = self._window if self._window is not None else self._sketch
            new, token = self._dispatch_update_pre(live, pre)
            if self._window is not None:
                self._window = new
            else:
                self._sketch = new
            self._inflight.append(token)
        else:
            if pre is not None:  # fused + collapsed: pairs through the kernel
                s = jnp.asarray(pad_bucket(pre.src))
                d = jnp.asarray(pad_bucket(pre.dst))
                w = jnp.asarray(pad_bucket(pre.weights))
            else:
                s, d, w = jnp.asarray(s_np), jnp.asarray(d_np), jnp.asarray(w_np)
            live = self._window if self._window is not None else self._sketch
            new, token, touched_rows = self._dispatch_update(live, s, d, w)
            if self._window is not None:
                self._window = new
            else:
                self._sketch = new
            self._inflight.append(token)
        while len(self._inflight) > self._max_inflight:
            jax.block_until_ready(self._inflight.popleft())
        self.stats.edges_ingested += n_edges
        self.stats.ingest_s += time.time() - t0
        self._epoch += 1
        if self._fused:
            self._note_touched(touched_rows if additive else None)
        else:
            self._note_touched(touched)
        receipt = IngestReceipt(
            epoch=self._epoch,
            n_edges=n_edges,
            touched_keys=touched,
            touched_rows=touched_rows if additive else None,
            event_time_min=ev_min,
            event_time_max=ev_max,
            wal_seq=wal_seq,
        )
        self._after_mutation()
        return receipt

    def _dispatch_update_slice(self, s_np, d_np, w_np, slot: int) -> None:
        """One donated event-time dispatch into ring slot ``slot``.  Arrays
        are padded to power-of-two buckets (zero weights are the identity)
        so variable per-slice group sizes cost a bounded trace ladder."""
        s = jnp.asarray(pad_bucket(s_np))
        d = jnp.asarray(pad_bucket(d_np))
        w = jnp.asarray(pad_bucket(w_np))
        leaves = jax.tree_util.tree_leaves(self._window)
        uniq = tuple(leaves[i] for i in self._uniq_leaf_idx)
        new_leaves, token = self._jit_update_slice(
            uniq, s, d, w, jnp.asarray(slot, jnp.int32)
        )
        self._window = jax.tree_util.tree_unflatten(
            self._live_treedef, new_leaves
        )
        self._inflight.append(token)

    def _ingest_eventtime(
        self,
        t0: float,
        s_np: np.ndarray,
        d_np: np.ndarray,
        w_np: np.ndarray,
        ts_np: np.ndarray,
        source_key: int,
        *,
        ev_min: Optional[float],
        ev_max: Optional[float],
        wal_seq: Optional[int],
    ) -> IngestReceipt:
        """Event-time ingest: watermark fold -> auto-advance -> slice
        routing -> late-edge policy, all driven by the batch's event-time
        column.  Deterministic given the mutation sequence, which is what
        makes WAL replay bit-identical."""
        K = self._window.n_slices
        width = self._slice_width
        late_dropped = late_retracted = auto_adv = 0
        watermark = None
        additive = not bool(np.any(w_np < 0))
        late_mask = None
        floor_slot = 0
        if n_edges := int(s_np.shape[0]):
            # Lateness is judged against the watermark PROMISED before this
            # batch arrived — the batch's own maximum must not retroactively
            # declare its earlier edges late, or an in-order batch spanning
            # more than max_lateness would retract its own head.
            promised = self._tracker.watermark
            watermark = self._tracker.observe(source_key, ev_max)
            b = slices_of(ts_np, width)
            late_mask = ts_np < promised
            # New ring head: the watermark keeps `lead` slices open past
            # itself; an in-bound burst ahead of a lagging source can push
            # the head further.  Monotone by construction.
            target = slice_of(watermark, width) + self._lead
            if not late_mask.all():
                target = max(target, int(b[~late_mask].max()))
            prev = self._head_slice if self._head_slice is not None else target
            target = max(target, prev)
            auto_adv = target - prev
            self._head_slice = target
            for _ in range(auto_adv):
                self._advance_once()
            self.stats.auto_advances += auto_adv
            # Oldest live slice after the advances; in-bound-by-watermark
            # edges that still land below the ring (a fast source far ahead
            # of a slow one) are operationally late too.  Ring slots are
            # addressed RELATIVE to the head — the ring's current slot need
            # not start congruent to the first head slice.
            slot_off = (self._ring_pos - self._head_slice) % K
            floor_slice = self._head_slice - K + 1
            floor_slot = int((floor_slice + slot_off) % K)
            late_mask = late_mask | (b < floor_slice)
            n_late = int(late_mask.sum())
            if n_late and self._late_policy == "drop":
                keep = ~late_mask
                s_np, d_np, w_np, b = s_np[keep], d_np[keep], w_np[keep], b[keep]
                late_dropped = n_late
                self._tracker.late_dropped += n_late
            elif n_late:
                # Retract path: the whole batch lands (late edges clamped
                # to the oldest live slice), then the late subset is backed
                # out through the turnstile-delete path — same slot,
                # negative weights.
                b = np.where(late_mask, floor_slice, b)
                late_retracted = n_late
                self._tracker.late_retracted += n_late
            touched = None
            if self._touched is not None and additive and late_retracted == 0:
                touched = touched_row_keys(
                    s_np,
                    None if self.config.directed else d_np,
                    cap=self.config.width_rows,
                )
            slots = (b + slot_off) % K
            for slot in np.unique(slots).astype(np.int32):
                m = slots == slot
                self._dispatch_update_slice(s_np[m], d_np[m], w_np[m], int(slot))
            if late_retracted and self._late_policy == "retract":
                m = late_mask
                self._dispatch_update_slice(
                    s_np[m], d_np[m], -w_np[m], floor_slot
                )
                additive = False  # the retraction is a turnstile delete
        else:
            touched = np.zeros(0, np.uint32) if self._touched is not None else None
        while len(self._inflight) > self._max_inflight:
            jax.block_until_ready(self._inflight.popleft())
        self.stats.edges_ingested += n_edges
        self.stats.ingest_s += time.time() - t0
        self._epoch += 1
        self._note_touched(touched if additive else None)
        receipt = IngestReceipt(
            epoch=self._epoch,
            n_edges=n_edges,
            touched_keys=touched if additive else None,
            event_time_min=ev_min,
            event_time_max=ev_max,
            watermark=watermark,
            late_dropped=late_dropped,
            late_retracted=late_retracted,
            auto_advances=auto_adv,
            wal_seq=wal_seq,
        )
        self._after_mutation()
        return receipt

    def delete(
        self, src, dst, weights=None, *, timestamps=None, source=None
    ) -> IngestReceipt:
        """Turnstile deletion: negative-weight ingest (paper Section 6.1.1).
        Not additions-only, so the receipt's touched set is ``None`` and any
        cached reachability closure rebuilds from scratch on next use.
        Event-time sessions route the retraction into the slice the
        original edge's ``timestamps`` place it in."""
        if weights is None:
            weights = np.ones(len(np.atleast_1d(np.asarray(src))), np.float32)
        return self.ingest(
            src, dst, -np.asarray(weights), timestamps=timestamps, source=source
        )

    def flush(self) -> None:
        """Block until every dispatched ingest batch has landed on device."""
        if not self._inflight:
            return
        t0 = time.time()
        while self._inflight:
            jax.block_until_ready(self._inflight.popleft())
        self.stats.ingest_s += time.time() - t0

    # -- queries --------------------------------------------------------------

    def query(self, *queries) -> Union[QueryResult, List[QueryResult]]:
        """Answer queries against the live summary.

        Accepts a single :class:`Query` (returns one :class:`QueryResult`),
        several Query arguments, or one :class:`QueryBatch` (returns a
        request-ordered result list).  The planner fuses the batch into at
        most one engine dispatch per family."""
        single = len(queries) == 1 and isinstance(queries[0], Query)
        if len(queries) == 1 and isinstance(queries[0], QueryBatch):
            batch = queries[0]
        else:
            batch = QueryBatch(queries)
        if len(batch) == 0:
            # Nothing to answer: do not flush, plan, or touch the engine.
            return []
        self.flush()
        t0 = time.time()
        if any(q.family == "reach" for q in batch):
            # Sync the closure cache from the session's touched-key delta so
            # one-shot reach pulls ride the same incremental refresh as
            # standing subscriptions instead of re-squaring the closure.
            self._ensure_closure()
        results = execute(self.engine, self._live(), batch, epoch=self._epoch)
        self.stats.query_s += time.time() - t0
        self._count_served(results)
        self._sync_engine_stats()
        return results[0] if single else results

    # -- standing queries (subscriptions) -------------------------------------

    def subscribe(
        self,
        *queries,
        every: int = 1,
        on_result: Optional[Callable[[SubscriptionEvent], None]] = None,
        alarm: Optional[Callable[[List[QueryResult]], bool]] = None,
        name: Optional[str] = None,
        max_pending: int = DEFAULT_MAX_PENDING,
        overflow: str = "drop_oldest",
    ) -> Subscription:
        """Register a standing query batch: a :class:`QueryBatch` (or Query
        arguments, like :meth:`query`) compiled ONCE by the planner and
        re-evaluated automatically after every ``every``-th mutation
        (ingest / delete / advance_window / merge), emitting timestamped
        :class:`SubscriptionEvent`\\ s through ``Subscription.poll()``, the
        session-wide :meth:`events` feed, and the optional ``on_result``
        callback.  ``alarm`` is a predicate over the request-ordered result
        list whose value rides on each event (threshold monitors).

        Re-evaluation is INCREMENTAL: flow/heavy families read the
        maintained registers, edge/subgraph plans replay their fused
        jit-cached dispatches, and reach subscriptions refresh the cached
        transitive closure from the rows touched since the last tick
        (``QueryEngine.refresh_closure``) instead of re-squaring — one full
        closure build per additions-only stream, N incremental refreshes."""
        if len(queries) == 1 and isinstance(queries[0], QueryBatch):
            batch = queries[0]
        else:
            batch = QueryBatch(queries)
        for q in batch:
            if q.family == "heavy":
                validate_theta(q.theta)
        sub = Subscription(
            self,
            self._next_sub_id,
            batch,
            every=every,
            on_result=on_result,
            alarm=alarm,
            name=name,
            max_pending=max_pending,
            overflow=overflow,
        )
        self._next_sub_id += 1
        self._subs[sub.id] = sub
        return sub

    @property
    def subscriptions(self) -> Tuple[Subscription, ...]:
        """The active subscriptions, registration-ordered."""
        return tuple(self._subs.values())

    def events(self) -> Iterator[SubscriptionEvent]:
        """Drain the session-wide event feed (all subscriptions, emission
        order).  Non-blocking: yields the pending events and stops."""
        while self._event_log:
            yield self._event_log.popleft()

    def _unsubscribe(self, sub: Subscription) -> None:
        self._subs.pop(sub.id, None)
        if sub.plan.has_reach:
            # The cancelled plan may be the only closure consumer; session
            # teardown/reuse paths (and the fleet's slot recycling) must not
            # find a stale closure that a later epoch tag could collide with.
            self.engine.invalidate()

    def _note_touched(self, batch_delta) -> None:
        """Accumulate one batch's touched-row delta for the next closure
        sync — a unique key array (plain sessions) or a (d, w_r) bool
        device bitmap (fused sessions); ``None`` (non-additive batch) or
        overflowing the row width forces the next sync to rebuild from
        scratch."""
        if self._touched is None:
            return
        if batch_delta is None:
            self._touched = None
            self._touched_count = 0
            return
        self._touched.append(batch_delta)
        if getattr(batch_delta, "ndim", 1) == 2:
            return  # bitmap: bounded by (d, w_r), no overflow cap needed
        self._touched_count += int(batch_delta.size)
        if self._touched_count > self.config.width_rows:
            self._touched = None
            self._touched_count = 0

    def _ensure_closure(self) -> None:
        """Bring the engine's closure cache up to the current epoch — by
        touched-row refresh when the history since the last sync is
        additions-only, else by full rebuild."""
        delta = None
        if self._touched is not None:
            if not self._touched:
                delta = np.zeros(0, np.uint32)
            elif getattr(self._touched[0], "ndim", 1) == 2:
                # Fused sessions: OR the per-batch device bitmaps (cheap
                # device ops), sync once for the refresh.
                bitmap = self._touched[0]
                for b in self._touched[1:]:
                    bitmap = bitmap | b
                delta = np.asarray(bitmap)
            else:
                delta = np.unique(np.concatenate(self._touched)).astype(
                    np.uint32
                )
        self.engine.refresh_closure(self._live(), delta, self._epoch)
        self._touched = []
        self._touched_count = 0

    def _after_mutation(self) -> None:
        """Re-evaluate every subscription that came due on this mutation."""
        due = [
            s for s in list(self._subs.values()) if s.active and s._note_mutation()
        ]
        if not due:
            return
        self.flush()
        t0 = time.time()
        if any(s.plan.has_reach for s in due):
            self._ensure_closure()
        sketch = self._live()
        now = time.time()
        for sub in due:
            results = sub.plan.run(self.engine, sketch, epoch=self._epoch)
            event = SubscriptionEvent(
                subscription_id=sub.id,
                name=sub.name,
                tick=sub.ticks + 1,
                epoch=self._epoch,
                timestamp=now,
                results=tuple(results),
                alarm=None if sub.alarm is None else bool(sub.alarm(results)),
            )
            if sub._deliver(event):
                # Dedup'd re-emissions (exactly-once replay floor) still
                # advance the subscription's progress, but never re-enter
                # the feeds or callbacks.
                self._event_log.push(event)
            self.stats.subscription_ticks += 1
            self._count_served(results)
        self.stats.query_s += time.time() - t0
        self._sync_engine_stats()

    def _count_served(self, results) -> None:
        for r in results:
            v = r.value
            self.stats.queries_served += (
                int(np.size(v[0])) if isinstance(v, tuple) else int(np.size(v))
            )

    def _sync_engine_stats(self) -> None:
        self.stats.closure_refreshes = self.engine.closure_refreshes
        self.stats.closure_incremental_refreshes = (
            self.engine.closure_incremental_refreshes
        )

    def monitor(self, src, dst, weights, watch, theta: float) -> bool:
        """Paper Section 4.2's real-time monitor as a thin wrapper over a
        threshold subscription: a standing ``Query.heavy(watch, θ)`` with an
        ``alarm`` predicate on the in-flow bit, registered once per
        (watch, θ) and evaluated right after this batch is ingested.  θ is
        the fraction of the total stream weight F̃ (``0 < θ <= 1``,
        validated).  Returns the alarm decision; the subscription keeps
        monitoring subsequent ingests (events via :meth:`events`)."""
        theta = validate_theta(theta)
        key = (int(np.uint32(encode_labels(watch))), theta)
        sub = self._monitor_subs.get(key)
        if sub is None or not sub.active:
            sub = self.subscribe(
                Query.heavy(watch, theta),
                every=1,
                alarm=lambda results: bool(np.asarray(results[0].value[0])),
                name=f"monitor:{key[0]}@{theta:g}",
            )
            self._monitor_subs[key] = sub
        self.ingest(src, dst, weights)
        sub.poll()  # the wrapper consumes its events; last_event remains
        return bool(sub.last_event.alarm)

    def pagerank(self, damping: float = 0.85, iters: int = 32) -> np.ndarray:
        """Run PageRank directly on the summary-as-a-graph (Section 3.3
        Remark): returns (d, w) bucket ranks."""
        self.flush()
        return np.asarray(queries_mod.sketch_pagerank(self._live(), damping, iters))

    # -- convenience wrappers (vectorized; used by the serving engine) --------

    def edge_frequency(self, src, dst) -> np.ndarray:
        return np.atleast_1d(self.query(Query.edge(src, dst)).value)

    def in_flow(self, keys) -> np.ndarray:
        return np.atleast_1d(self.query(Query.in_flow(keys)).value)

    def out_flow(self, keys) -> np.ndarray:
        return np.atleast_1d(self.query(Query.out_flow(keys)).value)

    def heavy_hitters(self, keys, theta: float) -> np.ndarray:
        in_heavy, _ = self.query(Query.heavy(keys, theta)).value
        return np.atleast_1d(in_heavy)

    def reachable(self, src, dst) -> np.ndarray:
        return np.atleast_1d(self.query(Query.reach(src, dst)).value)

    def subgraph_weight(self, src, dst) -> float:
        return float(self.query(Query.subgraph(src, dst)).value)

    # -- lifecycle ------------------------------------------------------------

    def advance_window(self) -> None:
        """Move the sliding window to the next time slice (expiring the
        oldest slice); no-op for non-windowed sessions.  Counts as a
        mutation for subscriptions; expiry removes edges, so any cached
        reachability closure rebuilds from scratch on next use.

        On an event-time session this also moves the ring head one slice
        forward (an explicit advance DECLARES a new open slice; the
        watermark keeps driving automatic ones).  Explicit advances are
        WAL-logged; watermark-driven ones are not — replay re-derives them
        from the logged event times."""
        if self._window is None:
            return
        if self._wal is not None and not self._replaying:
            self._wal.append_advance()
        if self._head_slice is not None:
            self._head_slice += 1
        self._advance_once()

    def _advance_once(self) -> None:
        """One ring advance through the donated boundary: expiry + epoch
        bump + subscription tick.  Shared by explicit ``advance_window``
        and the watermark-driven automatic path (which is NOT WAL-logged)."""
        self.flush()
        leaves = jax.tree_util.tree_leaves(self._window)
        uniq = tuple(leaves[i] for i in self._uniq_leaf_idx)
        new_leaves = self._jit_advance(uniq)
        self._window = jax.tree_util.tree_unflatten(
            self._live_treedef, new_leaves
        )
        self._ring_pos = (self._ring_pos + 1) % self._window.n_slices
        self._epoch += 1
        self._note_touched(None)
        self._after_mutation()

    def merge(self, other: "GraphStream") -> "GraphStream":
        """Merge another session's summary into this one (linearity; the
        paper's distributed merge-by-add).  Both must share a hash family —
        open them with the same config + seed."""
        if self._window is not None or other._window is not None:
            raise ValueError("merge() runs on non-windowed sessions")
        self.flush()
        other.flush()
        if not self._sketch.same_family(other._sketch):
            raise ValueError(
                "cannot merge sketches with different hash families "
                "(open both sessions with the same config and seed)"
            )
        if self._wal is not None and not self._replaying:
            # The merged-in state never went through this WAL: log a
            # barrier replay refuses to cross, and checkpoint() right
            # after so recovery never needs to.
            self._wal.append_merge_barrier()
        self._sketch = self._sketch.merge(other._sketch)
        self.stats.edges_ingested += other.stats.edges_ingested
        self._epoch += 1
        self._note_touched(None)  # foreign rows everywhere: full rebuild
        self._after_mutation()
        return self

    def _sub_key(self, sub: Subscription) -> str:
        return sub_progress_key(sub)

    def checkpoint(self, step: Optional[int] = None) -> int:
        """Durably save the session state (requires ``checkpoint_dir``).
        Returns the step the checkpoint was saved under.

        With a WAL attached, the checkpoint also records its durable WAL
        position (``wal_seq``), the watermark-tracker state, and each
        active subscription's tick progress — everything :meth:`recover`
        needs for exactly-once replay — then rotates the WAL segment and
        drops segments every retained checkpoint already covers."""
        if self._ckpt is None:
            raise ValueError("open the session with checkpoint_dir= to checkpoint")
        self.flush()
        step = self._epoch if step is None else step
        state = self._window if self._window is not None else self._sketch
        meta: Dict = {"epoch": self._epoch}
        if self._wal is not None:
            self._wal.sync()
            meta["wal_seq"] = self._wal.last_seq
        if self._tracker is not None:
            meta["watermark"] = self._tracker.state()
            meta["head_slice"] = self._head_slice
        subs = {
            self._sub_key(s): {"ticks": s.ticks, "pending": s._mutations_pending}
            for s in self._subs.values()
            if s.active
        }
        if subs:
            meta["subs"] = subs
        self._ckpt.save(step, state, metadata=meta)
        if self._wal is not None:
            # Rotation keyed to the checkpoint step: the next mutation
            # opens a fresh segment, so no segment straddles the boundary
            # and GC can reason per whole segment.
            self._wal.rotate()
            covered = None
            for s in self._ckpt.all_steps():
                try:
                    seq = int(self._ckpt.read_metadata(s).get("wal_seq", 0))
                except Exception:
                    seq = 0  # unreadable manifest: assume it covers nothing
                covered = seq if covered is None else min(covered, seq)
            if covered:
                self._wal.gc(covered)
        return step

    def restore(self, step: Optional[int] = None) -> int:
        """Restore session state from the checkpoint directory (latest step
        by default).  Handles pre-register checkpoints via the fill-missing
        schema-evolution path.  Returns the restored step."""
        if self._ckpt is None:
            raise ValueError("open the session with checkpoint_dir= to restore")
        self.flush()
        like = self._window if self._window is not None else self._sketch
        state, meta = self._ckpt.restore(step, like=like, fill_missing=True)
        if meta.get("filled_leaves"):
            # Registers absent from an old checkpoint: rebuild from counters.
            if isinstance(state, GLavaSketch):
                state = state.with_counters(state.counters)
            else:
                state = dataclasses.replace(
                    state,
                    row_flows=jnp.sum(state.slices, axis=3),
                    col_flows=jnp.sum(state.slices, axis=2),
                )
        if self._window is not None:
            self._window = state
            # Re-sync the host ring-position mirror with the restored ring
            # (the head-relative slot mapping depends on it).
            self._ring_pos = int(np.asarray(state.current))
        else:
            self._sketch = state
        self._epoch = int(meta.get("epoch", meta["step"]))
        if self._tracker is not None:
            wm_state = meta.get("watermark")
            if wm_state is not None:
                self._tracker = WatermarkTracker.from_state(wm_state)
                head = meta.get("head_slice")
                self._head_slice = None if head is None else int(head)
            else:
                # Pre-event-time checkpoint: start the tracker fresh.
                self._tracker = WatermarkTracker(self._tracker.max_lateness)
                self._head_slice = None
        subs_meta = meta.get("subs") or {}
        for sub in self._subs.values():
            m = subs_meta.get(self._sub_key(sub))
            if m is not None:
                sub.ticks = int(m["ticks"])
                sub._mutations_pending = int(m["pending"])
        self.engine.invalidate()  # any cached closure predates the restore
        self._touched = []
        self._touched_count = 0
        self._last_restore_meta = meta
        return int(meta["step"])

    def recover(self, step: Optional[int] = None) -> RecoveryReport:
        """Crash recovery (requires ``wal_dir``): restore the newest usable
        checkpoint — falling back past a corrupt one, or starting from the
        empty summary when none exists — then replay the WAL suffix through
        the normal mutation path (no re-append).  Standing subscriptions
        registered BEFORE calling this re-evaluate during replay exactly as
        the pre-crash session did: ticks resume from the checkpointed
        progress, and events a consumer already processed are deduplicated
        by (subscription, tick) via :meth:`Subscription.seek` — together,
        exactly-once delivery.  The post-recovery event sequence is
        bit-identical to the uninterrupted run (property-tested)."""
        if self._wal is None:
            raise ValueError("open the session with wal_dir= to recover")
        restored_step = None
        after_seq = 0
        if self._ckpt is not None:
            try:
                restored_step = self.restore(step)
                after_seq = int(self._last_restore_meta.get("wal_seq", 0))
            except FileNotFoundError:
                restored_step = None  # genesis replay over the empty summary
        self._replaying = True
        replayed = 0
        try:
            for mut in self._wal.replay(after_seq=after_seq):
                if isinstance(mut, EdgeMutation):
                    self._ingest_encoded(
                        mut.src, mut.dst, mut.weights, mut.timestamps,
                        mut.source_key,
                    )
                elif isinstance(mut, AdvanceMutation):
                    self.advance_window()
                else:  # MergeMutation — state entered outside this log
                    raise RuntimeError(
                        f"WAL suffix crosses a merge barrier (seq {mut.seq}): "
                        f"the merged-in summary never went through this log. "
                        f"checkpoint() immediately after merge() so recovery "
                        f"never needs to replay past it"
                    )
                replayed += 1
        finally:
            self._replaying = False
        self.flush()
        return RecoveryReport(
            step=restored_step,
            mutations_replayed=replayed,
            epoch=self._epoch,
            wal_seq=self._wal.last_seq,
        )

    def summary(self) -> Dict[str, float]:
        """Flushed session stats — the only honest read of ingest throughput
        while ingest is double-buffered."""
        self.flush()
        out = self.stats.summary()
        out["events_dropped"] = self.events_dropped
        if self._tracker is not None:
            out["watermark"] = self._tracker.watermark
            out["late_dropped"] = self._tracker.late_dropped
            out["late_retracted"] = self._tracker.late_retracted
        return out
