"""Standing queries: registered continuous subscriptions over a stream.

The paper's headline scenarios — cyber-security monitoring, DDoS
detection, transportation alarms — re-ask the SAME queries after every
edge batch.  This module makes that workload first-class (the gSketch
lesson: summaries serve a *known* query workload):

    sub = gs.subscribe(Query.reach("a", "b"), Query.in_flow("b"),
                       every=4, on_result=handle)
    ...
    gs.ingest(src, dst)            # every 4th mutation re-evaluates
    for event in sub.poll():       # or gs.events() across subscriptions
        print(event.tick, event.results)

A :class:`Subscription` owns the batch compiled ONCE by the planner
(:class:`~repro.api.planner.CompiledPlan`) and a bounded event queue; the
session (:class:`~repro.api.stream.GraphStream`) drives re-evaluation
after every ``every``-th mutation (ingest / delete / advance_window /
merge), refreshing the reach family's cached transitive closure
INCREMENTALLY from the rows the mutations touched
(``QueryEngine.refresh_closure``) instead of re-squaring from scratch.
Each evaluation emits one timestamped :class:`SubscriptionEvent` carrying
the request-ordered (ε, δ)-annotated results — pushed to the subscription
queue, the session-wide ``gs.events()`` feed, and the ``on_result``
callback.  An optional ``alarm`` predicate turns a subscription into a
threshold monitor (``GraphStream.monitor`` is a thin wrapper over one).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, List, Optional, Tuple

from repro.api.planner import CompiledPlan
from repro.api.query import QueryBatch, QueryResult
from repro.stream.events import EventFeed

# Events kept per subscription when nobody polls; past this the overflow
# policy applies (default drop_oldest — monitoring workloads care about
# the newest state) and ``events_dropped`` counts the loss.
DEFAULT_MAX_PENDING = 1024


def sub_progress_key(sub: "Subscription") -> str:
    """Stable identity for checkpointed subscription progress: named
    subscriptions match by name across a process restart; anonymous ones
    match by registration-order id (deterministic when the recovering
    process re-subscribes in the same order)."""
    return f"name:{sub.name}" if sub.name else f"id:{sub.id}"


@dataclasses.dataclass(frozen=True)
class SubscriptionEvent:
    """One re-evaluation of a standing query batch.

    ``tick`` counts this subscription's evaluations from 1; ``epoch`` is
    the session mutation epoch the results reflect; ``timestamp`` is the
    host wall-clock at evaluation.  ``results`` are request-ordered
    :class:`QueryResult`\\ s (the same objects a one-shot ``gs.query`` of
    the batch would return — bit-identical, property-tested).  ``alarm``
    is the subscription's predicate evaluated on the results, or ``None``
    when no predicate was registered."""

    subscription_id: int
    name: Optional[str]
    tick: int
    epoch: int
    timestamp: float
    results: Tuple[QueryResult, ...]
    alarm: Optional[bool] = None


class Subscription:
    """A registered continuous query batch (construct via
    ``GraphStream.subscribe``, not directly).

    The batch is compiled once; the session re-runs the compiled plan
    after every ``every``-th mutation and delivers events here.  ``poll()``
    drains pending events, ``cancel()`` deregisters (idempotent), and the
    object iterates over pending events (``for ev in sub: ...``)."""

    def __init__(
        self,
        stream,
        sub_id: int,
        batch: QueryBatch,
        every: int = 1,
        on_result: Optional[Callable[[SubscriptionEvent], None]] = None,
        alarm: Optional[Callable[[List[QueryResult]], bool]] = None,
        name: Optional[str] = None,
        max_pending: int = DEFAULT_MAX_PENDING,
        overflow: str = "drop_oldest",
    ):
        if len(batch) == 0:
            raise ValueError("a subscription needs at least one query")
        every = int(every)
        if every < 1:
            raise ValueError(f"every must be a positive mutation count, got {every}")
        self._stream = stream
        self.id = sub_id
        self.name = name
        self.batch = batch
        self.plan = CompiledPlan(batch)
        self.every = every
        self.on_result = on_result
        self.alarm = alarm
        self.ticks = 0
        self.active = True
        self.last_event: Optional[SubscriptionEvent] = None
        self._mutations_pending = 0
        self._events = EventFeed(max_pending, overflow)
        # Exactly-once replay floor: events with tick <= _seen_tick were
        # already consumed before a crash and are deduplicated on re-emit.
        self._seen_tick = 0
        self.events_deduped = 0

    # -- event plane ---------------------------------------------------------

    def poll(self, max_events: Optional[int] = None) -> List[SubscriptionEvent]:
        """Drain (up to ``max_events``) pending events, oldest first."""
        return self._events.drain(max_events)

    def __iter__(self) -> Iterator[SubscriptionEvent]:
        while self._events:
            yield self._events.popleft()

    @property
    def pending(self) -> int:
        return len(self._events)

    @property
    def events_dropped(self) -> int:
        """Pending events lost to queue overflow (monotone counter; the
        explicit replacement for the old silent ``deque(maxlen)`` loss)."""
        return self._events.dropped

    def seek(self, tick: int) -> None:
        """Exactly-once consumption floor: after :meth:`GraphStream.recover`
        re-emits the replayed event stream, events with ``tick <=`` this
        value are deduplicated (they were delivered before the crash).
        Call with the last tick the consumer durably processed."""
        self._seen_tick = max(self._seen_tick, int(tick))

    def cancel(self) -> None:
        """Deregister: no further evaluations or events (idempotent)."""
        if self.active:
            self.active = False
            self._stream._unsubscribe(self)

    # -- session-side hooks --------------------------------------------------

    def _note_mutation(self) -> bool:
        """Count one session mutation; True when the subscription is due."""
        self._mutations_pending += 1
        return self._mutations_pending >= self.every

    def _deliver(self, event: SubscriptionEvent) -> bool:
        """Accept one evaluation.  Returns False when the event was
        deduplicated by the exactly-once floor (already consumed before a
        crash) — progress counters still advance, but nothing is queued,
        no callback fires, and the session feed skips it too."""
        self._mutations_pending = 0
        self.ticks = event.tick
        if event.tick <= self._seen_tick:
            self.events_deduped += 1
            return False
        self.last_event = event
        self._events.push(event)
        if self.on_result is not None:
            self.on_result(event)
        return True

    def __repr__(self) -> str:  # pragma: no cover — debugging sugar
        tag = f" {self.name!r}" if self.name else ""
        return (
            f"<Subscription #{self.id}{tag} families={self.plan.families} "
            f"every={self.every} ticks={self.ticks} "
            f"{'active' if self.active else 'cancelled'}>"
        )
