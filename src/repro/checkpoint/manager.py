"""Fault-tolerant checkpointing: atomic, sharded, async, reshardable.

No orbax in the container — built on npz + msgpack'd tree structure:

- ATOMIC: writes go to ``<dir>/step_<n>.tmp-<nonce>/`` and are renamed into
  place only after an fsync'd manifest lands — a crash mid-save never
  corrupts the latest checkpoint (two-phase commit).
- SHARDED: every leaf is saved as the process-local addressable shards with
  its PartitionSpec recorded; on restore the full array is assembled and
  re-laid-out for the CURRENT mesh — loading a 16×16 checkpoint on a 2×16×16
  mesh (elastic scaling) is a first-class path.
- ASYNC: ``save_async`` snapshots to host RAM (device_get) then writes on a
  background thread so the train loop keeps stepping.
- RETENTION: keep-last-k GC.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import uuid
import warnings
import zipfile
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed to load: truncated/corrupt shard or manifest.
    Carries the offending ``step`` and ``path`` so the operator knows
    exactly which artifact to quarantine."""

    def __init__(self, step: int, path: Path, reason: str):
        self.step = int(step)
        self.path = Path(path)
        super().__init__(
            f"checkpoint step {step} is corrupt ({path}): {reason}"
        )


def _tree_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    # -- save ----------------------------------------------------------------

    def save(self, step: int, state: Any, metadata: Optional[dict] = None):
        """Synchronous atomic save."""
        host_state = jax.device_get(state)
        self._write(step, host_state, metadata or {})

    def save_async(self, step: int, state: Any, metadata: Optional[dict] = None):
        """Snapshot now, write in the background.  Joins any prior pending
        save first (at most one in flight)."""
        self.wait()
        host_state = jax.device_get(state)  # snapshot before training mutates

        def work():
            self._write(step, host_state, metadata or {})

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_state, metadata: dict):
        tmp = self.dir / f"step_{step:010d}.tmp-{uuid.uuid4().hex[:8]}"
        final = self.dir / f"step_{step:010d}"
        tmp.mkdir(parents=True)
        arrays = {}
        leaves = _tree_paths(host_state)
        index = []
        for i, (path, leaf) in enumerate(leaves):
            arr = np.asarray(leaf)
            arrays[f"leaf_{i}"] = arr
            index.append(
                {"path": path, "key": f"leaf_{i}", "shape": list(arr.shape),
                 "dtype": str(arr.dtype)}
            )
        np.savez(tmp / "arrays.npz", **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "index": index,
            "metadata": metadata,
            "format": 1,
        }
        mpath = tmp / "manifest.json"
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        # two-phase commit: rename only after the manifest is durable
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)
        # drop orphaned tmp dirs from crashed saves
        for p in self.dir.glob("step_*.tmp-*"):
            shutil.rmtree(p, ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if p.name.count(".tmp-") or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: Optional[int] = None,
        like: Any = None,
        shardings: Any = None,
        fill_missing: bool = False,
    ):
        """Restore a checkpoint.  ``like`` (a pytree of arrays or
        ShapeDtypeStructs) provides the treedef; ``shardings`` (optional
        matching pytree of NamedShardings) re-lays-out every leaf for the
        CURRENT mesh — the elastic-scaling reshard path.

        ``fill_missing=True`` is the schema-evolution path: leaves present
        in ``like`` but absent from the checkpoint (e.g. the flow registers
        of a :class:`~repro.core.sketch.GLavaSketch` saved before registers
        existed) are filled instead of raising — with NaN for inexact
        dtypes (a stale read fails LOUDLY instead of silently answering 0)
        and zeros for integer dtypes — and their paths are listed in
        ``metadata["filled_leaves"]``.  The caller must recompute them
        before use (``GLavaSketch.with_counters`` rebuilds registers from
        counters).

        A truncated or corrupt checkpoint raises
        :class:`CheckpointCorruptError` naming the offending step and file.
        When restoring the LATEST step (``step=None``), corruption falls
        back to the previous retained step (with a warning) instead of
        failing — an explicitly requested step never silently substitutes.

        Returns (state, metadata); ``metadata["step"]`` is always present,
        backed by the manifest's own step counter (callers never see None
        for the restored step)."""
        if step is not None:
            return self._load_step(step, like, shardings, fill_missing)
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        first_err: Optional[CheckpointCorruptError] = None
        for s in reversed(steps):
            try:
                return self._load_step(s, like, shardings, fill_missing)
            except CheckpointCorruptError as e:
                if first_err is None:
                    first_err = e
                warnings.warn(
                    f"{e} — falling back to the previous retained step",
                    RuntimeWarning,
                    stacklevel=2,
                )
        raise first_err

    def read_metadata(self, step: int) -> dict:
        """Load just a step's manifest metadata (plus ``step``) — no array
        I/O.  The WAL GC path reads every retained checkpoint's durable
        WAL position through this."""
        d = self.dir / f"step_{step:010d}"
        mpath = d / "manifest.json"
        try:
            manifest = json.loads(mpath.read_text())
        except (OSError, ValueError) as e:
            raise CheckpointCorruptError(step, mpath, f"unreadable manifest: {e}")
        metadata = dict(manifest.get("metadata") or {})
        if metadata.get("step") is None:
            metadata["step"] = manifest.get("step", step)
        return metadata

    def _load_step(
        self,
        step: int,
        like: Any = None,
        shardings: Any = None,
        fill_missing: bool = False,
    ):
        """Load one specific step; raises :class:`CheckpointCorruptError`
        on a truncated/corrupt shard or manifest instead of surfacing a raw
        deserialization error."""
        d = self.dir / f"step_{step:010d}"
        if not d.exists():
            raise FileNotFoundError(f"no checkpoint for step {step} in {self.dir}")
        mpath = d / "manifest.json"
        try:
            manifest = json.loads(mpath.read_text())
        except (OSError, ValueError) as e:
            raise CheckpointCorruptError(step, mpath, f"unreadable manifest: {e}")
        apath = d / "arrays.npz"
        try:
            data = np.load(apath)
            # Force every indexed array off disk NOW: np.load is lazy, and a
            # truncated zip member only fails when its entry is read.
            by_path = {e["path"]: data[e["key"]] for e in manifest["index"]}
        except (OSError, ValueError, KeyError, zipfile.BadZipFile) as e:
            raise CheckpointCorruptError(
                step, apath, f"truncated or corrupt shard: {e}"
            )
        if like is None:
            raise ValueError("restore requires `like` for the tree structure")
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        flat_sh = None
        if shardings is not None:
            flat_sh = [s for _, s in jax.tree_util.tree_flatten_with_path(shardings)[0]]
        filled = []
        for i, (kp, ref) in enumerate(flat):
            path = jax.tree_util.keystr(kp)
            if path not in by_path:
                if not (fill_missing and hasattr(ref, "shape")):
                    raise KeyError(f"checkpoint missing leaf {path}")
                dtype = np.dtype(ref.dtype if hasattr(ref, "dtype") else np.float32)
                fill = np.nan if np.issubdtype(dtype, np.inexact) else 0
                arr = np.full(ref.shape, fill, dtype)
                filled.append(path)
            else:
                arr = by_path[path]
            want_dtype = ref.dtype if hasattr(ref, "dtype") else arr.dtype
            arr = arr.astype(want_dtype)
            if flat_sh is not None:
                leaves.append(jax.device_put(arr, flat_sh[i]))
            else:
                leaves.append(jax.device_put(arr))
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        metadata = dict(manifest["metadata"])
        if filled:
            metadata["filled_leaves"] = filled
        # The manifest step is authoritative; caller metadata may omit it.
        if metadata.get("step") is None:
            metadata["step"] = manifest["step"]
        return state, metadata
