from repro.configs.base import (
    ARCH_IDS,
    ArchSpec,
    ShapeSpec,
    all_archs,
    all_cells,
    get_arch,
    load_all,
    triplet_budget,
)

__all__ = [
    "ARCH_IDS",
    "ArchSpec",
    "ShapeSpec",
    "all_archs",
    "all_cells",
    "get_arch",
    "load_all",
    "triplet_budget",
]
