"""arctic-480b [hf:Snowflake/snowflake-arctic-base]: 35L d_model=7168 56H
(GQA kv=8) d_ff=4864 vocab=32000, MoE 128 experts top-2 + dense residual.

~475B total params — the FSDP fit case: bf16 params + bf16 Adam moments
sharded over all mesh axes (DESIGN.md Section 4).  128 experts / 16-way
model axis = 8 experts per chip (partition="expert" = EP).
n_heads=56 does not divide the 16-way model axis; the merged head*dh dim
(7168) does — the sharding resolver uses the merged dim (DESIGN.md).
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.layers import MoEArgs
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="arctic-480b",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,
    vocab=32000,
    rope_theta=1e6,
    moe=MoEArgs(n_experts=128, top_k=2, dense_residual=True, partition="expert"),
    param_dtype=jnp.bfloat16,
)

SMOKE = TransformerConfig(
    name="arctic-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=96,
    vocab=256,
    moe=MoEArgs(n_experts=8, top_k=2, dense_residual=True, partition="expert"),
    compute_dtype=jnp.float32,
)

SPEC = register(
    ArchSpec(
        arch_id="arctic-480b",
        family="lm",
        config=FULL,
        smoke_config=SMOKE,
        shapes=lm_shapes(None),
        notes="Dense-residual MoE; pure full attention -> long_500k skipped.",
    )
)
