"""Architecture registry: every assigned arch is a selectable config
(``--arch <id>``) carrying its FULL published config, a reduced SMOKE config
(same family, tiny dims), and its assigned input-shape set.

Shape cells marked ``skip`` record rule-driven inapplicability (e.g.
long_500k on pure full-attention archs) — see DESIGN.md Section 5.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional

ARCH_IDS = [
    "mixtral-8x22b",
    "arctic-480b",
    "qwen3-4b",
    "olmo-1b",
    "granite-8b",
    "dimenet",
    "graphsage-reddit",
    "gat-cora",
    "schnet",
    "bert4rec",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train|prefill|decode|gnn_full|gnn_minibatch|gnn_molecule|
    #                    recsys_train|recsys_serve|recsys_retrieval
    params: Dict[str, Any]
    skip: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str        # lm | gnn | recsys
    config: Any
    smoke_config: Any
    shapes: Dict[str, ShapeSpec]
    notes: str = ""

    def cells(self):
        return [(self.arch_id, s) for s in self.shapes]


# -- LM shape set (seq_len × global_batch; decode/long lower serve_step) ----


def lm_shapes(sliding_window: Optional[int]) -> Dict[str, ShapeSpec]:
    skip_long = (
        None
        if sliding_window is not None
        else "pure full-attention arch: long_500k needs sub-quadratic attention "
        "(DESIGN.md Section 5); SWA/SSM archs only"
    )
    return {
        "train_4k": ShapeSpec("train_4k", "train", dict(seq_len=4096, global_batch=256)),
        "prefill_32k": ShapeSpec(
            "prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)
        ),
        "decode_32k": ShapeSpec(
            "decode_32k", "decode", dict(seq_len=32768, global_batch=128)
        ),
        "long_500k": ShapeSpec(
            "long_500k", "decode", dict(seq_len=524288, global_batch=1), skip=skip_long
        ),
    }


# -- GNN shape set ----------------------------------------------------------

TRIPLET_FACTOR = 8          # static triplet budget = factor × n_edges …
TRIPLET_CAP = 1 << 26       # … capped (documented coverage bound; log at use)


def gnn_shapes() -> Dict[str, ShapeSpec]:
    return {
        "full_graph_sm": ShapeSpec(
            "full_graph_sm",
            "gnn_full",
            dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7),
        ),
        "minibatch_lg": ShapeSpec(
            "minibatch_lg",
            "gnn_minibatch",
            dict(
                n_graph_nodes=232_965,
                n_graph_edges=114_615_892,
                batch_nodes=1024,
                fanouts=(15, 10),
                d_feat=602,
                n_classes=41,
            ),
        ),
        "ogb_products": ShapeSpec(
            "ogb_products",
            "gnn_full",
            dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100, n_classes=47),
        ),
        "molecule": ShapeSpec(
            "molecule",
            "gnn_molecule",
            dict(n_nodes=30, n_edges=64, batch=128),
        ),
    }


def triplet_budget(n_edges: int) -> int:
    return min(TRIPLET_FACTOR * n_edges, TRIPLET_CAP)


# -- RecSys shape set --------------------------------------------------------


def recsys_shapes() -> Dict[str, ShapeSpec]:
    return {
        "train_batch": ShapeSpec("train_batch", "recsys_train", dict(batch=65536)),
        "serve_p99": ShapeSpec("serve_p99", "recsys_serve", dict(batch=512)),
        "serve_bulk": ShapeSpec("serve_bulk", "recsys_serve", dict(batch=262144)),
        "retrieval_cand": ShapeSpec(
            "retrieval_cand", "recsys_retrieval", dict(batch=1, n_candidates=1_000_000)
        ),
    }


_REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    if not _REGISTRY:
        load_all()
    return _REGISTRY[arch_id]


def all_archs() -> Dict[str, ArchSpec]:
    if not _REGISTRY:
        load_all()
    return dict(_REGISTRY)


def load_all():
    for arch in ARCH_IDS:
        importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    importlib.import_module("repro.configs.glava")


def all_cells(include_skipped: bool = False):
    """The 40 (arch × shape) cells; skipped cells carry their reason."""
    cells = []
    for arch_id, spec in all_archs().items():
        if arch_id == "glava":
            continue
        for shape_name, shape in spec.shapes.items():
            if shape.skip and not include_skipped:
                continue
            cells.append((arch_id, shape_name))
    return cells
