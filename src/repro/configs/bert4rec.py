"""bert4rec [arXiv:1904.06690]: embed_dim=64, 2 blocks, 2 heads, seq_len=200,
bidirectional sequential interaction.  Item table sized 1M (retrieval_cand
scores 1M candidates), sharded on the vocab axis."""
from repro.configs.base import ArchSpec, recsys_shapes, register
from repro.models.recsys.bert4rec import Bert4RecConfig

import jax.numpy as jnp

FULL = Bert4RecConfig(
    name="bert4rec", n_items=1_000_000, embed_dim=64, n_blocks=2, n_heads=2,
    seq_len=200,
)
SMOKE = Bert4RecConfig(
    name="bert4rec-smoke", n_items=500, embed_dim=16, n_blocks=2, n_heads=2,
    seq_len=12, compute_dtype=jnp.float32,
)

SPEC = register(
    ArchSpec(
        arch_id="bert4rec",
        family="recsys",
        config=FULL,
        smoke_config=SMOKE,
        shapes=recsys_shapes(),
        notes="Encoder-only: no autoregressive decode shape exists for this "
        "family; all four recsys shapes are live.",
    )
)
