"""dimenet [arXiv:2003.03123]: 6 blocks, d_hidden=128, n_bilinear=8,
n_spherical=7, n_radial=6 — triplet-gather kernel regime.

Triplet lists get a static budget min(8·n_edges, 2^26) (configs.base.
triplet_budget); the cap is logged whenever it truncates (DESIGN.md)."""
from repro.configs.base import ArchSpec, gnn_shapes, register
from repro.models.gnn.dimenet import DimeNetConfig

FULL = DimeNetConfig(
    name="dimenet", n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7,
    n_radial=6, cutoff=5.0,
)
SMOKE = DimeNetConfig(
    name="dimenet-smoke", n_blocks=2, d_hidden=16, n_bilinear=4, n_spherical=3,
    n_radial=4, cutoff=5.0, n_atom_types=10,
)

SPEC = register(
    ArchSpec(
        arch_id="dimenet",
        family="gnn",
        config=FULL,
        smoke_config=SMOKE,
        shapes=gnn_shapes(),
        notes="Quadratic-in-degree triplet lists; budgeted statically.",
    )
)
