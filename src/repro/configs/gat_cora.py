"""gat-cora [arXiv:1710.10903]: 2 layers, d_hidden=8 per head, 8 heads,
attention aggregator (SDDMM + segment-softmax regime)."""
from repro.configs.base import ArchSpec, gnn_shapes, register
from repro.models.gnn.gat import GATConfig

FULL = GATConfig(name="gat-cora", n_layers=2, d_in=1433, d_hidden=8, n_heads=8, out_dim=7)
SMOKE = GATConfig(name="gat-smoke", n_layers=2, d_in=12, d_hidden=4, n_heads=2, out_dim=3)

SPEC = register(
    ArchSpec(
        arch_id="gat-cora",
        family="gnn",
        config=FULL,
        smoke_config=SMOKE,
        shapes=gnn_shapes(),
        notes="Edge-softmax attention; d_in/out_dim overridden per shape cell.",
    )
)
