"""The paper's own configs: production gLava sketch sizes.

Sized from Thm 1 / Lemma 5.2 (w = e/sqrt(eps) resp. e/eps, d = ln(1/delta))
for network-monitoring workloads.  glava-web's counters are 64 GiB total —
row-sharded over the model axis per DESIGN.md Section 4."""
import dataclasses

from repro.configs.base import ArchSpec, ShapeSpec, register
from repro.core.sketch import SketchConfig

# d=4 ≈ ln(1/δ) for δ=2%, w=65536 → ε ≈ (e/w)² ≈ 1.7e-9 for edge queries.
WEB = SketchConfig(depth=4, width_rows=65536, width_cols=65536)
BASE = SketchConfig(depth=5, width_rows=8192, width_cols=8192)
NONSQUARE = SketchConfig(depth=5, width_rows=16384, width_cols=4096)
SMOKE = SketchConfig(depth=3, width_rows=256, width_cols=256)

STREAM_SHAPES = {
    "ingest_1m": ShapeSpec("ingest_1m", "sketch_ingest", dict(batch=1_048_576)),
    "query_64k": ShapeSpec("query_64k", "sketch_query", dict(batch=65536)),
}

SPEC = register(
    ArchSpec(
        arch_id="glava",
        family="sketch",
        config=BASE,
        smoke_config=SMOKE,
        shapes=STREAM_SHAPES,
        notes="The paper's data structure itself, as a servable config.",
    )
)
