"""granite-8b [arXiv:2405.04324]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152 — llama-architecture code model."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="granite-8b",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=49152,
    rope_theta=1e4,
    param_dtype=jnp.bfloat16,
)

SMOKE = TransformerConfig(
    name="granite-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    compute_dtype=jnp.float32,
)

SPEC = register(
    ArchSpec(
        arch_id="granite-8b",
        family="lm",
        config=FULL,
        smoke_config=SMOKE,
        shapes=lm_shapes(None),
        notes="Dense llama-arch; long_500k skipped (full attention).",
    )
)
