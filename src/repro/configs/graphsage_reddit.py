"""graphsage-reddit [arXiv:1706.02216]: 2 layers, d_hidden=128, mean
aggregator, sample sizes 25-10 (minibatch_lg shape overrides to 15-10)."""
from repro.configs.base import ArchSpec, gnn_shapes, register
from repro.models.gnn.graphsage import SAGEConfig

FULL = SAGEConfig(name="graphsage-reddit", n_layers=2, d_in=602, d_hidden=128, out_dim=41)
SMOKE = SAGEConfig(name="graphsage-smoke", n_layers=2, d_in=16, d_hidden=8, out_dim=4)

SPEC = register(
    ArchSpec(
        arch_id="graphsage-reddit",
        family="gnn",
        config=FULL,
        smoke_config=SMOKE,
        shapes=gnn_shapes(),
        notes="SpMM regime; d_in/out_dim are overridden per shape cell.",
    )
)
