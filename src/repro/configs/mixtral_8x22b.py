"""mixtral-8x22b [arXiv:2401.04088; hf]: 56L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=32768, MoE 8 experts top-2, sliding-window attention.

SWA bounds the KV cache by the window → long_500k decode is sub-quadratic
and RUNS for this arch (the only assigned LM with a live long_500k cell).
8 experts < 16-way model axis → expert tensors are TP-sharded on the FFN dim
(partition="ffn") instead of EP (DESIGN.md Section 4).
"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.layers import MoEArgs
from repro.models.transformer import TransformerConfig

SLIDING_WINDOW = 4096

FULL = TransformerConfig(
    name="mixtral-8x22b",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab=32768,
    sliding_window=SLIDING_WINDOW,
    rope_theta=1e6,
    moe=MoEArgs(n_experts=8, top_k=2, partition="ffn"),
    param_dtype=jnp.bfloat16,
)

SMOKE = TransformerConfig(
    name="mixtral-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=256,
    sliding_window=8,
    moe=MoEArgs(n_experts=4, top_k=2, partition="ffn"),
    compute_dtype=jnp.float32,
)

SPEC = register(
    ArchSpec(
        arch_id="mixtral-8x22b",
        family="lm",
        config=FULL,
        smoke_config=SMOKE,
        shapes=lm_shapes(SLIDING_WINDOW),
        notes="MoE top-2 + SWA; long_500k uses the ring KV cache (window 4096).",
    )
)
