"""olmo-1b [arXiv:2402.00838]: 16L d_model=2048 16H (MHA: kv=16) d_ff=8192
vocab=50304 — non-parametric LayerNorm, tied embeddings."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="olmo-1b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    norm="layernorm_nonparam",
    tie_embeddings=True,
    rope_theta=1e4,
    param_dtype=jnp.bfloat16,
)

SMOKE = TransformerConfig(
    name="olmo-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    norm="layernorm_nonparam",
    tie_embeddings=True,
    compute_dtype=jnp.float32,
)

SPEC = register(
    ArchSpec(
        arch_id="olmo-1b",
        family="lm",
        config=FULL,
        smoke_config=SMOKE,
        shapes=lm_shapes(None),
        notes="Non-parametric LN, tied embeddings; long_500k skipped.",
    )
)
