"""qwen3-4b [hf:Qwen/Qwen3-8B family]: 36L d_model=2560 32H (GQA kv=8)
d_ff=9728 vocab=151936 — qk-norm, explicit head_dim=128."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="qwen3-4b",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=9728,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    param_dtype=jnp.bfloat16,
)

SMOKE = TransformerConfig(
    name="qwen3-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=256,
    qk_norm=True,
    compute_dtype=jnp.float32,
)

SPEC = register(
    ArchSpec(
        arch_id="qwen3-4b",
        family="lm",
        config=FULL,
        smoke_config=SMOKE,
        shapes=lm_shapes(None),
        notes="Dense GQA + qk-norm; long_500k skipped (full attention).",
    )
)
