"""schnet [arXiv:1706.08566]: 3 interactions, d_hidden=64, 300 RBF, cutoff 10.

Molecular net: positions are REQUIRED inputs.  On the citation/product graph
shapes the pipeline synthesizes 3-D positions and the model projects the
continuous features (feature_mode="project"); on molecule it embeds atom
types (DESIGN.md Section 5)."""
from repro.configs.base import ArchSpec, gnn_shapes, register
from repro.models.gnn.schnet import SchNetConfig

FULL = SchNetConfig(
    name="schnet", n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0
)
SMOKE = SchNetConfig(
    name="schnet-smoke", n_interactions=2, d_hidden=16, n_rbf=24, cutoff=5.0,
    n_atom_types=10,
)

SPEC = register(
    ArchSpec(
        arch_id="schnet",
        family="gnn",
        config=FULL,
        smoke_config=SMOKE,
        shapes=gnn_shapes(),
        notes="Triplet-free molecular regime; task head per shape cell.",
    )
)
