"""gLava core: the paper's contribution as composable JAX modules."""
from repro.core.hashing import (
    HashFamily,
    MERSENNE_P,
    affine_hash,
    affine_hash_np,
    fnv1a_label,
    fnv1a_labels,
    make_hash_family,
    mix_keys,
    mulmod31,
    sign_hash,
)
from repro.core.ingest import IngestEngine, ingest, resolve_backend
from repro.core.sketch import (
    CountMin,
    CountSketch,
    GLavaSketch,
    GSketch,
    NodeCountMin,
    SketchConfig,
)
from repro.core import queries
from repro.core import reach
from repro.core.window import SlidingWindowSketch
from repro.core.query_engine import QueryEngine, resolve_query_backend

__all__ = [
    "HashFamily",
    "MERSENNE_P",
    "affine_hash",
    "affine_hash_np",
    "fnv1a_label",
    "fnv1a_labels",
    "make_hash_family",
    "mix_keys",
    "mulmod31",
    "sign_hash",
    "IngestEngine",
    "ingest",
    "resolve_backend",
    "CountMin",
    "CountSketch",
    "GLavaSketch",
    "GSketch",
    "NodeCountMin",
    "SketchConfig",
    "queries",
    "reach",
    "SlidingWindowSketch",
    "QueryEngine",
    "resolve_query_backend",
]
