"""Distributed gLava: the paper's Section 6.3 as an explicit JAX program.

The sketch is a *linear* projection of the stream, so the distributed recipe
is exactly the paper's: every worker ingests its local shard of the stream
into a local copy of the (same-hash-family) sketch, and the global sketch is
the elementwise SUM of the locals.  Expressed with ``shard_map``:

- the edge batch is sharded over the ``(pod, data)`` mesh axes,
- the counter tensor's ROW axis is sharded over ``model`` (so a 16-way model
  axis holds w_r/16 rows per chip — sketches wider than one chip's HBM are
  supported),
- each device accumulates only rows it owns (the one-hot formulation masks
  out-of-shard rows for free), and
- ``psum`` over (pod, data) merges the partial sketches.

Query-side collectives: point/edge queries gather from the row-owner and
psum-combine masked partials.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.ingest import ingest
from repro.core.sketch import GLavaSketch, scatter_flows, scatter_register
from repro.distributed.compat import shard_map


def distributed_ingest(
    mesh: jax.sharding.Mesh,
    sketch: GLavaSketch,
    src: jax.Array,
    dst: jax.Array,
    weights: Optional[jax.Array] = None,
    *,
    stream_axes: Sequence[str] = ("data",),
    model_axis: str = "model",
    backend: str = "onehot",
    preagg_marginals=None,
) -> GLavaSketch:
    """Ingest a GLOBAL edge batch, sharded over `stream_axes`, into a sketch
    whose rows are sharded over `model_axis`.  Returns the updated sketch
    with the same shardings.

    Per-device accumulation goes through the same :mod:`repro.core.ingest`
    dispatch as local ingest (``row_offset`` masks out-of-shard rows), so
    the distributed result is bit-identical to the local oracle for
    integer weights — the engine's exact-equivalence contract.

    Pre-aggregation composes from the outside: a host-collapsed batch
    (:func:`repro.core.ingest.preaggregate_host`) is just a smaller edge
    batch, so callers (the GraphStream mesh branch) pass the collapsed
    pairs here directly.  When they do, ``preagg_marginals`` =
    ``(src_unique, src_totals, dst_unique, dst_totals)`` lets the
    replicated flow registers update from the per-endpoint totals — one
    register add per distinct endpoint instead of per pair."""
    if weights is None:
        weights = jnp.ones(src.shape, jnp.float32)
    weights = weights.astype(jnp.float32)
    r, c = sketch.hash_edges(src, dst)  # (d, B) — computed under pjit; cheap
    d, wr, wc = sketch.counters.shape
    tp = mesh.shape[model_axis]
    assert wr % tp == 0, f"sketch rows {wr} must divide model axis {tp}"
    wr_shard = wr // tp
    stream_spec = P(None, tuple(stream_axes))  # (d, B) sharded on batch

    def body(counters_shard, r, c, w):
        row_lo = jax.lax.axis_index(model_axis) * wr_shard
        upd = ingest(counters_shard, r, c, w, backend=backend, row_offset=row_lo)
        # Merge stream shards: the paper's distributed merge-by-add.
        delta = upd - counters_shard
        delta = jax.lax.psum(delta, tuple(stream_axes))
        return counters_shard + delta

    counters = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(None, model_axis, None),  # counters: rows sharded
            stream_spec,                # r
            stream_spec,                # c
            P(tuple(stream_axes)),      # weights
        ),
        out_specs=P(None, model_axis, None),
    )(sketch.counters, r, c, weights)
    # Flow registers are O(d·w) and replicated — maintain them with the
    # plain global scatter (same add order as local ingest, so the
    # registers stay bit-identical to the local oracle's), or from the
    # per-endpoint marginal totals when the batch was host-collapsed.
    if preagg_marginals is not None:
        src_unique, src_totals, dst_unique, dst_totals = preagg_marginals
        row_flows = scatter_register(
            sketch.row_flows, sketch.row_hash(src_unique), src_totals
        )
        col_flows = scatter_register(
            sketch.col_flows, sketch.col_hash(dst_unique), dst_totals
        )
    else:
        row_flows, col_flows = scatter_flows(
            sketch.row_flows, sketch.col_flows, r, c, weights
        )
    return dataclasses.replace(
        sketch, counters=counters, row_flows=row_flows, col_flows=col_flows
    )


def distributed_edge_query(
    mesh: jax.sharding.Mesh,
    sketch: GLavaSketch,
    src: jax.Array,
    dst: jax.Array,
    *,
    model_axis: str = "model",
) -> jax.Array:
    """Batched f̃_e over a row-sharded sketch: each shard contributes the
    cells it owns (others contribute +inf), min-reduced over model axis."""
    r, c = sketch.hash_edges(src, dst)  # (d, Q)
    d, wr, wc = sketch.counters.shape
    tp = mesh.shape[model_axis]
    wr_shard = wr // tp

    def body(counters_shard, r, c):
        my_idx = jax.lax.axis_index(model_axis)
        local_r = r - my_idx * wr_shard
        in_shard = (local_r >= 0) & (local_r < wr_shard)
        safe_r = jnp.clip(local_r, 0, wr_shard - 1)
        d_idx = jnp.broadcast_to(jnp.arange(d)[:, None], r.shape)
        vals = counters_shard[d_idx, safe_r, c]
        vals = jnp.where(in_shard, vals, jnp.inf)
        vals = jax.lax.pmin(vals, model_axis)  # (d, Q) now replicated
        return jnp.min(vals, axis=0)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(None, model_axis, None), P(), P()),
        out_specs=P(),
        check_vma=False,
    )(sketch.counters, r, c)


def distributed_point_query(
    mesh: jax.sharding.Mesh,
    sketch: GLavaSketch,
    keys: jax.Array,
    direction: str = "in",
    *,
    model_axis: str = "model",
    use_registers: bool = True,
) -> jax.Array:
    """f̃_v over a row-sharded sketch.

    Fast path (default): the flow registers are replicated and maintained by
    :func:`distributed_ingest`, so a point query is an O(d·Q) gather with no
    collective at all.  ``use_registers=False`` keeps the counter-reduction
    collective path (owner-shard row sums for out-flow; psum of partial
    column sums for in-flow) for counters that were mutated outside the
    sketch API and may carry stale registers."""
    if use_registers:
        from repro.core import queries

        if direction == "in":
            return queries.node_in_flow(sketch, keys)
        return queries.node_out_flow(sketch, keys)
    d, wr, wc = sketch.counters.shape
    tp = mesh.shape[model_axis]
    wr_shard = wr // tp
    if direction == "in":
        h = sketch.col_hash(keys)  # (d, Q) — column index, not sharded

        def body(counters_shard, h):
            col_sums = jax.lax.psum(
                jnp.sum(counters_shard, axis=1), model_axis
            )  # (d, wc)
            vals = jnp.take_along_axis(col_sums, h, axis=1)
            return jnp.min(vals, axis=0)

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(P(None, model_axis, None), P()),
            out_specs=P(),
            check_vma=False,
        )(sketch.counters, h)
    else:
        h = sketch.row_hash(keys)

        def body(counters_shard, h):
            my_idx = jax.lax.axis_index(model_axis)
            local = h - my_idx * wr_shard
            in_shard = (local >= 0) & (local < wr_shard)
            safe = jnp.clip(local, 0, wr_shard - 1)
            row_sums = jnp.sum(counters_shard, axis=2)  # (d, wr_shard)
            vals = jnp.take_along_axis(row_sums, safe, axis=1)
            vals = jnp.where(in_shard, vals, jnp.inf)
            vals = jax.lax.pmin(vals, model_axis)
            return jnp.min(vals, axis=0)

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(P(None, model_axis, None), P()),
            out_specs=P(),
            check_vma=False,
        )(sketch.counters, h)
