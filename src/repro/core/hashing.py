"""Pairwise-independent hashing over the Mersenne prime 2**31 - 1.

The paper (Section 6.2) requires hash functions drawn uniformly from a
pairwise-independent family ``h(x) = ((a*x + b) mod p) mod w``.  gLava needs
the hash *inside* jit/Pallas (sketch updates happen on-device), and JAX in
this container runs without x64, so the 62-bit product ``a*x`` is computed
with 16-bit limbs in uint32 arithmetic, reduced mod p = 2**31 - 1 using
``2**31 ≡ 1 (mod p)``.

Everything here is validated against exact big-int arithmetic in
``tests/test_hashing.py`` (hypothesis property tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

MERSENNE_P = (1 << 31) - 1  # 2**31 - 1, prime
_P31 = np.uint32(MERSENNE_P)
_MASK16 = np.uint32(0xFFFF)
_MASK15 = np.uint32(0x7FFF)


def _fold31(v: jax.Array) -> jax.Array:
    """One folding step: v (uint32) -> (v >> 31) + (v & (2**31-1))."""
    return (v >> np.uint32(31)) + (v & _P31)


def _reduce31(v: jax.Array) -> jax.Array:
    """Full reduction of a uint32 value mod p (two folds + conditional sub)."""
    v = _fold31(_fold31(v))
    return jnp.where(v >= _P31, v - _P31, v)


def _add_mod31(u: jax.Array, v: jax.Array) -> jax.Array:
    """(u + v) mod p for u, v < 2**31 (sum fits in uint32)."""
    s = u + v
    s = _fold31(s)
    return jnp.where(s >= _P31, s - _P31, s)


def mulmod31(a: jax.Array, x: jax.Array) -> jax.Array:
    """(a * x) mod (2**31 - 1) for a, x uint32 < 2**31, in uint32 limb math.

    Split a = a1*2**16 + a0, x = x1*2**16 + x0 (a1, x1 < 2**15):
      a*x = a1*x1*2**32 + (a1*x0 + a0*x1)*2**16 + a0*x0
    with 2**32 ≡ 2 and 2**31 ≡ 1 (mod p).
    """
    a = a.astype(jnp.uint32)
    x = x.astype(jnp.uint32)
    a1, a0 = a >> np.uint32(16), a & _MASK16
    x1, x0 = x >> np.uint32(16), x & _MASK16
    hi = a1 * x1                      # < 2**30
    mid = a1 * x0 + a0 * x1           # < 2**32 (fits)
    lo = a0 * x0                      # < 2**32
    # hi * 2**32 ≡ hi * 2
    hi_term = _reduce31(hi << np.uint32(1))
    # mid * 2**16: reduce mid first, then split mid = mh*2**15 + ml so that
    # mid*2**16 = mh*2**31 + ml*2**16 ≡ mh + ml*2**16 (ml*2**16 < 2**31).
    mid = _reduce31(mid)
    mh = mid >> np.uint32(15)
    ml = mid & _MASK15
    mid_term = _add_mod31(mh, ml << np.uint32(16))
    return _add_mod31(_add_mod31(hi_term, mid_term), _reduce31(lo))


def affine_hash(keys: jax.Array, a: jax.Array, b: jax.Array, w: int) -> jax.Array:
    """h(x) = (((a*x + b) mod p) mod w) as int32 in [0, w).

    ``keys`` may be any uint32 values; they are reduced mod p first.  ``a``
    and ``b`` broadcast against ``keys`` so a (d, 1) parameter array hashes a
    (n,) key array to (d, n) bucket indices in one call.
    """
    k = _reduce31(keys.astype(jnp.uint32))
    h = _add_mod31(mulmod31(a, k), b.astype(jnp.uint32))
    return (h % np.uint32(w)).astype(jnp.int32)


def sign_hash(keys: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """CountSketch sign hash: ±1 (int32), from the low bit of the affine hash."""
    k = _reduce31(keys.astype(jnp.uint32))
    h = _add_mod31(mulmod31(a, k), b.astype(jnp.uint32))
    return (1 - 2 * (h & np.uint32(1)).astype(jnp.int32))


def mix_keys(x: jax.Array, y: jax.Array) -> jax.Array:
    """Mix two uint32 keys into one (edge key for CountMin baselines).

    Multiplicative mixing (Knuth constant) keeps the composition injective
    enough for sketching; exactness is not required — only spread.
    """
    x = x.astype(jnp.uint32)
    y = y.astype(jnp.uint32)
    h = x * np.uint32(0x9E3779B1)
    h = (h ^ y) * np.uint32(0x85EBCA6B)
    h = h ^ (h >> np.uint32(13))
    return h


# ---------------------------------------------------------------------------
# Hash family (pytree)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HashFamily:
    """d independent affine hashes onto [0, w).  ``a``/``b`` have shape (d,)."""

    a: jax.Array
    b: jax.Array
    w: int = dataclasses.field(metadata=dict(static=True))

    @property
    def depth(self) -> int:
        return self.a.shape[0]

    def __call__(self, keys: jax.Array) -> jax.Array:
        """keys (...,) uint32 -> (d, ...) int32 bucket indices."""
        d = self.a.shape[0]
        shape = (d,) + (1,) * keys.ndim
        return affine_hash(keys[None], self.a.reshape(shape), self.b.reshape(shape), self.w)

    def signs(self, keys: jax.Array) -> jax.Array:
        """keys (...,) -> (d, ...) ±1 signs (uses an independent slice of b)."""
        d = self.a.shape[0]
        shape = (d,) + (1,) * keys.ndim
        # Derive a decorrelated parameter set for the sign bits.
        a2 = self.b.reshape(shape) | np.uint32(1)
        b2 = self.a.reshape(shape)
        return sign_hash(keys[None], a2, b2)


def make_hash_family(key: jax.Array, depth: int, width: int) -> HashFamily:
    """Sample a HashFamily: a ~ U[1, p-1], b ~ U[0, p-1]."""
    ka, kb = jax.random.split(key)
    a = jax.random.randint(ka, (depth,), 1, MERSENNE_P, dtype=jnp.uint32)
    b = jax.random.randint(kb, (depth,), 0, MERSENNE_P, dtype=jnp.uint32)
    return HashFamily(a=a, b=b, w=int(width))


# ---------------------------------------------------------------------------
# Host-side (numpy, exact uint64) reference used by the data pipeline
# ---------------------------------------------------------------------------


def affine_hash_np(keys: np.ndarray, a: np.ndarray, b: np.ndarray, w: int) -> np.ndarray:
    """Exact uint64 reference of affine_hash (host path + test oracle)."""
    k = keys.astype(np.uint64) % np.uint64(MERSENNE_P)
    h = (a.astype(np.uint64) * k + b.astype(np.uint64)) % np.uint64(MERSENNE_P)
    return (h % np.uint64(w)).astype(np.int32)


def fnv1a_label(label: Any) -> int:
    """Stable 32-bit FNV-1a of an arbitrary node label (host side).

    Graph streams carry IPs / user-IDs / strings; this maps them to the
    uint32 key space the device hashes expect.
    """
    if isinstance(label, (int, np.integer)):
        return int(label) & 0xFFFFFFFF
    data = str(label).encode("utf-8")
    h = 0x811C9DC5
    for byte in data:
        h ^= byte
        h = (h * 0x01000193) & 0xFFFFFFFF
    return h


def fnv1a_labels(labels) -> np.ndarray:
    """Vectorized :func:`fnv1a_label`: a batch of node labels -> uint32 keys.

    Element-for-element identical to ``fnv1a_label`` (tested), but vectorized
    over the batch: integer labels are one masked cast; string labels loop
    over BYTE COLUMNS of the utf-8 matrix (max-label-length iterations, each
    an O(n) numpy op) instead of Python-looping per label.  Labels containing
    NUL bytes fall back to the per-element path (numpy's fixed-width byte
    storage cannot represent embedded NULs).  Returns an array of
    ``labels``' shape (0-d for a scalar label).
    """
    if isinstance(labels, (list, tuple)) and not (
        all(isinstance(x, str) for x in labels)
        or all(isinstance(x, (int, np.integer)) for x in labels)
    ):
        # Mixed int/str labels: np.asarray would silently stringify the ints
        # ("1" hashes differently from 1) — force the per-element path.
        labels = np.asarray(labels, dtype=object)
    arr = np.asarray(labels)
    if arr.dtype == np.uint32:
        return arr  # the mask is the identity — no copy on the hot path
    if arr.dtype.kind in "ib":  # bools are ints to fnv1a_label (True -> 1)
        return (arr.astype(np.int64) & 0xFFFFFFFF).astype(np.uint32)
    if arr.dtype.kind == "u":
        return (arr.astype(np.uint64) & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    if arr.dtype.kind == "U" and "\x00" not in "".join(arr.ravel().tolist()):
        flat = arr.ravel()
        enc = np.char.encode(flat, "utf-8")  # S<width>, NUL-padded
        width = enc.dtype.itemsize
        h = np.full(flat.shape, 0x811C9DC5, np.uint32)
        if width and flat.size:
            mat = np.ascontiguousarray(enc).view(np.uint8).reshape(flat.size, width)
            lengths = np.char.str_len(enc)  # utf-8 byte length per label
            prime = np.uint32(0x01000193)
            with np.errstate(over="ignore"):  # uint32 wraparound is the hash
                for j in range(width):
                    live = j < lengths
                    h = np.where(live, (h ^ mat[:, j].astype(np.uint32)) * prime, h)
        return h.reshape(arr.shape)
    # object / bytes / float / NUL-bearing labels: per-element semantics
    out = np.fromiter(
        (fnv1a_label(x) for x in arr.ravel()), np.uint32, count=arr.size
    )
    return out.reshape(arr.shape)
