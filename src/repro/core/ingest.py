"""IngestEngine — the ONE dispatch point for sketch ingest.

Every path that folds an edge batch into gLava counters (local update,
sliding-window slices, the serving engine, the row-sharded distributed
plane, and the Pallas kernel wrapper) routes through :func:`ingest` /
:class:`IngestEngine`.  The engine owns the hash-bucket scatter semantics,
the padding/chunking bookkeeping, and the row-shard masking, so backends
cannot drift apart.

Exact-equivalence contract
--------------------------
For integer-valued fp32 weights with total per-cell mass below ``2**24``,
all backends — and any row-sharded decomposition of them — produce
BIT-IDENTICAL counters:

    ingest(C, r, c, w, backend=B1)
      == ingest(C, r, c, w, backend=B2)                       (any B1, B2)
      == sum over shards of ingest(C_shard, r, c, w, row_offset=k*wr_shard)

because fp32 addition of exactly-representable integers is associative in
the reachable range, and out-of-shard edges contribute exactly zero (index
masking, never weight rounding).  ``repro.core.distributed`` relies on this
for its psum merge; tests assert it for square and non-square configs.

Ingest-backend selection
------------------------
``scatter``  The paper-faithful semantics: ``M[h(x), h(y)] += w`` as one
             vectorized scatter-add.  Best on CPU/GPU and the reference
             oracle everywhere.
``onehot``   The MXU formulation: per edge chunk of size ``chunk``,
             ``M += OneHot(r)^T @ (OneHot(c) * w)`` — a systolic matmul.
             Best for XLA:TPU without Pallas.
``pallas``   The Pallas TPU kernel implementing the one-hot formulation
             with explicit VMEM tiling (``repro.kernels.ingest``).  The
             fast path on TPU hardware; on CPU hosts it runs in interpret
             mode (a correctness artifact, not a perf claim).
``auto``     Resolves via the ``REPRO_INGEST_BACKEND`` environment
             variable if set, else ``pallas`` on TPU backends and
             ``scatter`` elsewhere.

Row-sharded ingest (``row_offset``/``num_rows_total``) shifts global row
ids into shard-local coordinates and masks out-of-shard edges; every
backend supports it, so the distributed plane can use the same fast path
as a single device.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_CHUNK = 2048
BACKENDS = ("scatter", "onehot", "pallas")


def resolve_backend(backend: Optional[str]) -> str:
    """Resolve "auto"/None to a concrete backend name."""
    if backend in (None, "auto"):
        env = os.environ.get("REPRO_INGEST_BACKEND", "").strip().lower()
        if env:
            backend = env
        else:
            backend = (
                "pallas" if jax.default_backend() == "tpu" else "scatter"
            )
    if backend not in BACKENDS:
        raise ValueError(f"unknown ingest backend: {backend!r} (want {BACKENDS})")
    return backend


def touched_row_keys(src, dst=None, cap: Optional[int] = None):
    """The unique uint32 node keys whose ROW buckets one ingest batch can
    touch — ``src`` always; ``dst`` too when the sketch mirrors edges
    (undirected ingest writes row h(dst) as well).  Feeds the query plane's
    incremental closure refresh (``QueryEngine.refresh_closure``), which
    only needs a SUPERSET of the changed rows.

    Returns ``None`` when the unique count exceeds ``cap`` (typically the
    sketch row width): past that the refresh would touch most rows anyway,
    so callers fall back to a full rebuild rather than carry the set."""
    keys = np.atleast_1d(np.asarray(src))
    if dst is not None:
        keys = np.concatenate([keys, np.atleast_1d(np.asarray(dst))])
    uniq = np.unique(keys.astype(np.uint32, copy=False))
    if cap is not None and uniq.size > cap:
        return None
    return uniq


def pad_to(x: jax.Array, multiple: int, axis: int, value=0) -> jax.Array:
    """Right-pad ``axis`` to the next multiple (shared by kernel wrappers)."""
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# backends — all take shard-LOCAL row ids plus the in-shard mask
# ---------------------------------------------------------------------------


def _scatter(counters, local_r, cols, weights, in_shard, chunk):
    d, wr, wc = counters.shape
    d_idx = jnp.broadcast_to(jnp.arange(d)[:, None], local_r.shape)
    w = jnp.where(in_shard, jnp.broadcast_to(weights[None, :], local_r.shape), 0.0)
    safe_r = jnp.where(in_shard, local_r, 0)
    # Flat 1-D scatter with the bounds check promised away: safe_r/cols are
    # in-range by construction (masking above; hash codomain), and the flat
    # formulation measures ~40% faster than the 3-D scatter on XLA:CPU.
    flat = ((d_idx * wr + safe_r) * wc + cols).reshape(-1)
    return (
        counters.reshape(-1)
        .at[flat]
        .add(w.reshape(-1), mode="promise_in_bounds")
        .reshape(d, wr, wc)
    )


def _onehot(counters, local_r, cols, weights, in_shard, chunk):
    d, wr, wc = counters.shape
    batch = local_r.shape[1]
    chunk = min(chunk, batch)
    # Out-of-shard rows hit the sentinel one-hot class, sliced away below —
    # masking by INDEX, so weights stay untouched (exactness contract).
    # Padded slots (batch rounded up to a whole number of chunks) use the
    # same sentinel with weight zero, so ONE scan body covers every chunk
    # and the remainder no longer costs a second trace.
    r_sent = jnp.where(in_shard, local_r, wr)
    r_sent = pad_to(r_sent, chunk, 1, value=wr)
    cols = pad_to(cols, chunk, 1)
    weights = pad_to(weights, chunk, 0)

    def one_chunk(counters, args):
        rc, cc, wchunk = args  # (d, C), (d, C), (C,)
        oh_r = jax.nn.one_hot(rc, wr + 1, dtype=jnp.float32)[..., :wr]  # (d, C, wr)
        oh_c = jax.nn.one_hot(cc, wc, dtype=jnp.float32)                # (d, C, wc)
        oh_c = oh_c * wchunk[None, :, None]
        return counters + jnp.einsum("dbr,dbc->drc", oh_r, oh_c), None

    n = r_sent.shape[1] // chunk
    rs = r_sent.reshape(d, n, chunk).transpose(1, 0, 2)
    cs = cols.reshape(d, n, chunk).transpose(1, 0, 2)
    ws = weights.reshape(n, chunk)
    counters, _ = jax.lax.scan(one_chunk, counters, (rs, cs, ws))
    return counters


def _pallas(counters, local_r, cols, weights, in_shard, chunk):
    from repro.kernels.ingest.kernel import CHUNK_B, TILE_C, TILE_R, ingest_pallas

    d, wr, wc = counters.shape
    # Out-of-shard rows become -1: the kernel's iota compare matches nothing.
    r = jnp.where(in_shard, local_r, -1).astype(jnp.int32)
    cp = pad_to(pad_to(counters.astype(jnp.float32), TILE_R, 1), TILE_C, 2)
    rp = pad_to(r, CHUNK_B, 1, value=-1)
    cl = pad_to(cols.astype(jnp.int32), CHUNK_B, 1)
    wp = pad_to(weights, CHUNK_B, 0)  # padded edges carry weight 0
    out = ingest_pallas(cp, rp, cl, wp, interpret=jax.default_backend() != "tpu")
    return out[:, :wr, :wc]


_BACKEND_FNS = {"scatter": _scatter, "onehot": _onehot, "pallas": _pallas}


# ---------------------------------------------------------------------------
# the dispatch point
# ---------------------------------------------------------------------------


def ingest(
    counters: jax.Array,   # (d, wr_local, wc) fp32
    rows: jax.Array,       # (d, B) int — GLOBAL row buckets
    cols: jax.Array,       # (d, B) int — column buckets
    weights: jax.Array,    # (B,) fp32
    *,
    backend: str = "scatter",
    chunk: int = DEFAULT_CHUNK,
    row_offset: jax.Array | int = 0,
) -> jax.Array:
    """Fold one hashed edge batch into ``counters`` (see module docstring).

    ``row_offset`` is the global row id of this counter shard's row 0; rows
    outside ``[row_offset, row_offset + wr_local)`` contribute exactly
    nothing.  ``row_offset=0`` with full-width counters is plain local
    ingest (the mask is all-true and free after fusion).
    """
    backend = resolve_backend(backend)
    wr_local = counters.shape[1]
    local_r = rows.astype(jnp.int32) - jnp.asarray(row_offset, jnp.int32)
    in_shard = (local_r >= 0) & (local_r < wr_local)
    cols = cols.astype(jnp.int32)
    weights = weights.astype(jnp.float32)
    return _BACKEND_FNS[backend](counters, local_r, cols, weights, in_shard, chunk)


@dataclasses.dataclass(frozen=True)
class IngestEngine:
    """A resolved (backend, chunk) pair with the `ingest` dispatch bound."""

    backend: str = "scatter"
    chunk: int = DEFAULT_CHUNK

    def __post_init__(self):
        object.__setattr__(self, "backend", resolve_backend(self.backend))

    def __call__(self, counters, rows, cols, weights, row_offset=0):
        return ingest(
            counters,
            rows,
            cols,
            weights,
            backend=self.backend,
            chunk=self.chunk,
            row_offset=row_offset,
        )


# ---------------------------------------------------------------------------
# in-batch pre-aggregation — the heavy-tail fast path (DESIGN.md Section 10)
# ---------------------------------------------------------------------------
#
# Real graph streams are heavy-tailed: a zipf(1.5) batch of 32768 edges has
# only ~20% unique (src, dst) pairs, so a plain scatter pays for every
# duplicate.  Pre-aggregation collapses the batch to one slot per distinct
# pair BEFORE any backend sees it.  Because the collapse is a plain sum of
# signed weights it is EXACT for turnstile deletes and sliding-window slices
# too, and in the integer-fp32 regime (per-pair |Σw| and every running
# prefix < 2**24) it is bit-identical to ingesting the raw batch.
#
# Two implementations with one semantics:
#   * ``preaggregate_edges`` — traced, static-shape (sort + segment sums via
#     cumsum prefix differences; no ``jnp.unique``).  Rides INSIDE any jit,
#     so device-resident pipelines (TPU) collapse without a host round-trip.
#   * ``preaggregate_host`` — numpy (argsort + ``np.add.reduceat``).  The
#     session boundary (``api/stream.py``) is already host-side for label
#     encoding, and one host argsort is ~3x cheaper than the XLA:CPU sort,
#     so GraphStream uses this variant and additionally gets the per-src /
#     per-dst marginal totals that let the flow registers collapse further.

PREAGG_MIN_BATCH = 1024  # below this the sort costs more than it saves
PREAGG_SHRINK = 4        # in-jit collapsed slots = batch // PREAGG_SHRINK
PREAGG_MIN_OUT = 256     # floor on the collapsed slot count


def resolve_preagg(mode: Optional[str] = None, batch: Optional[int] = None) -> bool:
    """Resolve a pre-aggregation mode ("auto"/"on"/"off"/None) to a bool.

    "auto" (and None) honours the ``REPRO_INGEST_PREAGG`` environment
    variable if set, else enables pre-aggregation for batches of at least
    ``PREAGG_MIN_BATCH`` edges.  "on" forces it regardless of batch size
    (tests exercise small batches this way); "off" disables it."""
    if mode in (None, "auto"):
        env = os.environ.get("REPRO_INGEST_PREAGG", "").strip().lower()
        mode = env or "auto"
    if mode == "auto":
        return batch is None or batch >= PREAGG_MIN_BATCH
    if mode in ("on", "1", "true"):
        return True
    if mode in ("off", "0", "false"):
        return False
    raise ValueError(f"unknown preagg mode: {mode!r} (want auto/on/off)")


def preaggregate_edges(src, dst, weights, out_size: int):
    """Collapse duplicate (src, dst) pairs inside a jit — static shapes only.

    Sorts the batch by a 32-bit mixed pair key, finds run boundaries by
    neighbour compare on the sorted (src, dst) themselves (so key collisions
    merely split a run — never merge distinct pairs), and segment-sums the
    weights by cumulative-sum prefix differences (O(B) gathers; NOT
    ``jax.ops.segment_sum``, whose scatter would cost as much as the ingest
    it is meant to save).

    Returns ``(s_rep, d_rep, w_agg, n_seg)`` with static shape
    ``(out_size,)`` each: representative keys and summed weights for the
    first ``min(n_seg, out_size)`` segments.  Slots past ``n_seg`` carry
    weight exactly 0.0 with a (duplicated) real key, so scattering them is a
    no-op in the counting regime.  When ``n_seg > out_size`` the collapse
    does not fit — callers branch to the raw batch (``lax.cond``)."""
    from repro.core.hashing import mix_keys

    b = src.shape[0]
    key = mix_keys(src, dst)
    _, order = jax.lax.sort_key_val(key, jnp.arange(b, dtype=jnp.int32))
    s2, d2, w2 = src[order], dst[order], weights[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), (s2[1:] != s2[:-1]) | (d2[1:] != d2[:-1])]
    )
    seg = jnp.cumsum(first.astype(jnp.int32)) - 1  # (B,) non-decreasing
    n_seg = seg[-1] + 1
    csum = jnp.concatenate([jnp.zeros((1,), w2.dtype), jnp.cumsum(w2)])
    starts = jnp.searchsorted(
        seg, jnp.arange(out_size, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    ends = jnp.concatenate([starts[1:], jnp.full((1,), b, jnp.int32)])
    w_agg = csum[ends] - csum[starts]
    reps = jnp.clip(starts, 0, b - 1)
    return s2[reps], d2[reps], w_agg, n_seg


@dataclasses.dataclass(frozen=True)
class PreaggBatch:
    """A host-collapsed edge batch: distinct pairs plus marginal totals.

    ``src/dst/weights`` hold one slot per distinct (src, dst) pair of the
    raw batch with exactly-summed signed weights.  ``src_unique/src_totals``
    and ``dst_unique/dst_totals`` are the per-endpoint marginals — the flow
    registers only need those, which is a second collapse on top of the
    pair collapse (one row-register add per distinct src, not per pair)."""

    src: np.ndarray          # (P,) uint32 — distinct pair sources
    dst: np.ndarray          # (P,) uint32 — distinct pair destinations
    weights: np.ndarray      # (P,) float32 — per-pair summed weight
    src_unique: np.ndarray   # (S,) uint32
    src_totals: np.ndarray   # (S,) float32
    dst_unique: np.ndarray   # (D,) uint32
    dst_totals: np.ndarray   # (D,) float32

    @property
    def n_pairs(self) -> int:
        return int(self.src.size)


def preaggregate_host(src, dst, weights) -> PreaggBatch:
    """Numpy twin of :func:`preaggregate_edges` for the session boundary.

    One stable argsort of the 64-bit pair key gives the pair collapse via
    ``np.add.reduceat``; the per-src marginals fall out of the same order
    (sources are contiguous in pair order), and a second small argsort of
    the collapsed pairs gives the per-dst marginals.  Exact for signed
    weights; bit-identical to the raw batch in the integer regime."""
    sn = np.atleast_1d(np.asarray(src, np.uint32))
    dn = np.atleast_1d(np.asarray(dst, np.uint32))
    wn = np.atleast_1d(np.asarray(weights, np.float32))
    if sn.size == 0:
        empty_u, empty_f = sn[:0], wn[:0]
        return PreaggBatch(sn, dn, wn, empty_u, empty_f, empty_u, empty_f)
    pair = (sn.astype(np.uint64) << np.uint64(32)) | dn.astype(np.uint64)
    order = np.argsort(pair, kind="stable")
    ps, ss, ds, ws = pair[order], sn[order], dn[order], wn[order]
    first = np.empty(ps.size, bool)
    first[0] = True
    first[1:] = ps[1:] != ps[:-1]
    starts = np.flatnonzero(first)
    s_rep, d_rep = ss[starts], ds[starts]
    w_agg = np.add.reduceat(ws, starts).astype(np.float32)
    sfirst = np.empty(starts.size, bool)
    sfirst[0] = True
    sfirst[1:] = s_rep[1:] != s_rep[:-1]
    sstarts = np.flatnonzero(sfirst)
    src_unique = s_rep[sstarts]
    src_totals = np.add.reduceat(w_agg, sstarts).astype(np.float32)
    dorder = np.argsort(d_rep, kind="stable")
    dr, dw = d_rep[dorder], w_agg[dorder]
    dfirst = np.empty(dr.size, bool)
    dfirst[0] = True
    dfirst[1:] = dr[1:] != dr[:-1]
    dstarts = np.flatnonzero(dfirst)
    dst_unique = dr[dstarts]
    dst_totals = np.add.reduceat(dw, dstarts).astype(np.float32)
    return PreaggBatch(
        s_rep, d_rep, w_agg, src_unique, src_totals, dst_unique, dst_totals
    )


def bucket_size(n: int, minimum: int = 256) -> int:
    """Next power-of-two at or above ``n`` (floored at ``minimum``) — the
    padded-shape ladder that bounds how many traces variable-size collapsed
    batches can cost at a jit boundary."""
    size = minimum
    while size < n:
        size *= 2
    return size


def pad_bucket(x: np.ndarray, minimum: int = 256, value=0) -> np.ndarray:
    """Right-pad a 1-D host array to its :func:`bucket_size` with ``value``."""
    pad = bucket_size(x.size, minimum) - x.size
    if pad == 0:
        return x
    return np.concatenate([x, np.full(pad, value, x.dtype)])
