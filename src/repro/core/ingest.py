"""IngestEngine — the ONE dispatch point for sketch ingest.

Every path that folds an edge batch into gLava counters (local update,
sliding-window slices, the serving engine, the row-sharded distributed
plane, and the Pallas kernel wrapper) routes through :func:`ingest` /
:class:`IngestEngine`.  The engine owns the hash-bucket scatter semantics,
the padding/chunking bookkeeping, and the row-shard masking, so backends
cannot drift apart.

Exact-equivalence contract
--------------------------
For integer-valued fp32 weights with total per-cell mass below ``2**24``,
all backends — and any row-sharded decomposition of them — produce
BIT-IDENTICAL counters:

    ingest(C, r, c, w, backend=B1)
      == ingest(C, r, c, w, backend=B2)                       (any B1, B2)
      == sum over shards of ingest(C_shard, r, c, w, row_offset=k*wr_shard)

because fp32 addition of exactly-representable integers is associative in
the reachable range, and out-of-shard edges contribute exactly zero (index
masking, never weight rounding).  ``repro.core.distributed`` relies on this
for its psum merge; tests assert it for square and non-square configs.

Ingest-backend selection
------------------------
``scatter``  The paper-faithful semantics: ``M[h(x), h(y)] += w`` as one
             vectorized scatter-add.  Best on CPU/GPU and the reference
             oracle everywhere.
``onehot``   The MXU formulation: per edge chunk of size ``chunk``,
             ``M += OneHot(r)^T @ (OneHot(c) * w)`` — a systolic matmul.
             Best for XLA:TPU without Pallas.
``pallas``   The Pallas TPU kernel implementing the one-hot formulation
             with explicit VMEM tiling (``repro.kernels.ingest``).  The
             fast path on TPU hardware; on CPU hosts it runs in interpret
             mode (a correctness artifact, not a perf claim).
``auto``     Resolves via the ``REPRO_INGEST_BACKEND`` environment
             variable if set, else ``pallas`` on TPU backends and
             ``scatter`` elsewhere.

Row-sharded ingest (``row_offset``/``num_rows_total``) shifts global row
ids into shard-local coordinates and masks out-of-shard edges; every
backend supports it, so the distributed plane can use the same fast path
as a single device.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_CHUNK = 2048
BACKENDS = ("scatter", "onehot", "pallas")


def resolve_backend(backend: Optional[str]) -> str:
    """Resolve "auto"/None to a concrete backend name."""
    if backend in (None, "auto"):
        env = os.environ.get("REPRO_INGEST_BACKEND", "").strip().lower()
        if env:
            backend = env
        else:
            backend = (
                "pallas" if jax.default_backend() == "tpu" else "scatter"
            )
    if backend not in BACKENDS:
        raise ValueError(f"unknown ingest backend: {backend!r} (want {BACKENDS})")
    return backend


def touched_row_keys(src, dst=None, cap: Optional[int] = None):
    """The unique uint32 node keys whose ROW buckets one ingest batch can
    touch — ``src`` always; ``dst`` too when the sketch mirrors edges
    (undirected ingest writes row h(dst) as well).  Feeds the query plane's
    incremental closure refresh (``QueryEngine.refresh_closure``), which
    only needs a SUPERSET of the changed rows.

    Returns ``None`` when the unique count exceeds ``cap`` (typically the
    sketch row width): past that the refresh would touch most rows anyway,
    so callers fall back to a full rebuild rather than carry the set."""
    keys = np.atleast_1d(np.asarray(src))
    if dst is not None:
        keys = np.concatenate([keys, np.atleast_1d(np.asarray(dst))])
    uniq = np.unique(keys.astype(np.uint32, copy=False))
    if cap is not None and uniq.size > cap:
        return None
    return uniq


def pad_to(x: jax.Array, multiple: int, axis: int, value=0) -> jax.Array:
    """Right-pad ``axis`` to the next multiple (shared by kernel wrappers)."""
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# backends — all take shard-LOCAL row ids plus the in-shard mask
# ---------------------------------------------------------------------------


def _scatter(counters, local_r, cols, weights, in_shard, chunk):
    d = counters.shape[0]
    d_idx = jnp.broadcast_to(jnp.arange(d)[:, None], local_r.shape)
    w = jnp.where(in_shard, jnp.broadcast_to(weights[None, :], local_r.shape), 0.0)
    safe_r = jnp.where(in_shard, local_r, 0)
    return counters.at[d_idx, safe_r, cols].add(w)


def _onehot(counters, local_r, cols, weights, in_shard, chunk):
    d, wr, wc = counters.shape
    batch = local_r.shape[1]
    chunk = min(chunk, batch)
    # Out-of-shard rows hit the sentinel one-hot class, sliced away below —
    # masking by INDEX, so weights stay untouched (exactness contract).
    r_sent = jnp.where(in_shard, local_r, wr)

    def one_chunk(counters, args):
        rc, cc, wchunk = args  # (d, C), (d, C), (C,)
        oh_r = jax.nn.one_hot(rc, wr + 1, dtype=jnp.float32)[..., :wr]  # (d, C, wr)
        oh_c = jax.nn.one_hot(cc, wc, dtype=jnp.float32)                # (d, C, wc)
        oh_c = oh_c * wchunk[None, :, None]
        return counters + jnp.einsum("dbr,dbc->drc", oh_r, oh_c), None

    n_full = batch // chunk
    if n_full:
        rs = r_sent[:, : n_full * chunk].reshape(d, n_full, chunk).transpose(1, 0, 2)
        cs = cols[:, : n_full * chunk].reshape(d, n_full, chunk).transpose(1, 0, 2)
        ws = weights[: n_full * chunk].reshape(n_full, chunk)
        counters, _ = jax.lax.scan(one_chunk, counters, (rs, cs, ws))
    if batch - n_full * chunk:
        counters, _ = one_chunk(
            counters,
            (
                r_sent[:, n_full * chunk :],
                cols[:, n_full * chunk :],
                weights[n_full * chunk :],
            ),
        )
    return counters


def _pallas(counters, local_r, cols, weights, in_shard, chunk):
    from repro.kernels.ingest.kernel import CHUNK_B, TILE_C, TILE_R, ingest_pallas

    d, wr, wc = counters.shape
    # Out-of-shard rows become -1: the kernel's iota compare matches nothing.
    r = jnp.where(in_shard, local_r, -1).astype(jnp.int32)
    cp = pad_to(pad_to(counters.astype(jnp.float32), TILE_R, 1), TILE_C, 2)
    rp = pad_to(r, CHUNK_B, 1, value=-1)
    cl = pad_to(cols.astype(jnp.int32), CHUNK_B, 1)
    wp = pad_to(weights, CHUNK_B, 0)  # padded edges carry weight 0
    out = ingest_pallas(cp, rp, cl, wp, interpret=jax.default_backend() != "tpu")
    return out[:, :wr, :wc]


_BACKEND_FNS = {"scatter": _scatter, "onehot": _onehot, "pallas": _pallas}


# ---------------------------------------------------------------------------
# the dispatch point
# ---------------------------------------------------------------------------


def ingest(
    counters: jax.Array,   # (d, wr_local, wc) fp32
    rows: jax.Array,       # (d, B) int — GLOBAL row buckets
    cols: jax.Array,       # (d, B) int — column buckets
    weights: jax.Array,    # (B,) fp32
    *,
    backend: str = "scatter",
    chunk: int = DEFAULT_CHUNK,
    row_offset: jax.Array | int = 0,
) -> jax.Array:
    """Fold one hashed edge batch into ``counters`` (see module docstring).

    ``row_offset`` is the global row id of this counter shard's row 0; rows
    outside ``[row_offset, row_offset + wr_local)`` contribute exactly
    nothing.  ``row_offset=0`` with full-width counters is plain local
    ingest (the mask is all-true and free after fusion).
    """
    backend = resolve_backend(backend)
    wr_local = counters.shape[1]
    local_r = rows.astype(jnp.int32) - jnp.asarray(row_offset, jnp.int32)
    in_shard = (local_r >= 0) & (local_r < wr_local)
    cols = cols.astype(jnp.int32)
    weights = weights.astype(jnp.float32)
    return _BACKEND_FNS[backend](counters, local_r, cols, weights, in_shard, chunk)


@dataclasses.dataclass(frozen=True)
class IngestEngine:
    """A resolved (backend, chunk) pair with the `ingest` dispatch bound."""

    backend: str = "scatter"
    chunk: int = DEFAULT_CHUNK

    def __post_init__(self):
        object.__setattr__(self, "backend", resolve_backend(self.backend))

    def __call__(self, counters, rows, cols, weights, row_offset=0):
        return ingest(
            counters,
            rows,
            cols,
            weights,
            backend=self.backend,
            chunk=self.chunk,
            row_offset=row_offset,
        )
