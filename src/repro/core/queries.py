"""Query estimators over gLava sketches (paper Sections 3.4 and 4).

Every estimator follows the paper's map/reduce recipe: evaluate on each of
the d sketches independently, merge with Γ (min for weights, AND for
booleans).  All estimators are batched over queries and jit-compatible.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.sketch import GLavaSketch
from repro.core import reach as reach_mod


# ---------------------------------------------------------------------------
# Edge queries (Section 4.1)
# ---------------------------------------------------------------------------


def edge_query(sketch: GLavaSketch, src: jax.Array, dst: jax.Array) -> jax.Array:
    """f̃_e(a, b) = min_i ω_i(h_i(a), h_i(b)) for a batch of (a, b) pairs."""
    r, c = sketch.hash_edges(src, dst)  # (d, Q) each
    d_idx = jnp.broadcast_to(jnp.arange(r.shape[0])[:, None], r.shape)
    vals = sketch.counters[d_idx, r, c]  # (d, Q)
    est = jnp.min(vals, axis=0)
    if not sketch.config.directed:
        est = undirected_selfloop_correction(est, src, dst)
    return est


def undirected_selfloop_correction(est, src, dst):
    """Undirected ingest doubled every edge (x,y) & (y,x); each direction
    carries the full weight, so no correction is needed — but guard the
    self-loop double count.  Self-loop mass is always even (every loop was
    ingested twice), so integer counters halve exactly; divide in the
    counter dtype to keep the estimate dtype-stable.  Shared by the jnp and
    Pallas query backends so the halving cannot drift between them."""
    if jnp.issubdtype(est.dtype, jnp.floating):
        half = (est * est.dtype.type(0.5)).astype(est.dtype)
    else:
        half = est // jnp.asarray(2, est.dtype)
    return jnp.where(src == dst, half, est)


# ---------------------------------------------------------------------------
# Point queries (Sections 4.2 / 5.2)
# ---------------------------------------------------------------------------


def node_in_flow(sketch: GLavaSketch, keys: jax.Array) -> jax.Array:
    """f̃_v(a, ←): aggregated weight INTO a-nodes = min_i colsum(M_i[:, h_i(a)]).

    Served from the maintained ``col_flows`` register — an O(d·Q) gather;
    the O(d·w_r·w_c) counter tensor is never reduced (DESIGN.md Section 3)."""
    h = sketch.col_hash(keys)                    # (d, Q)
    vals = jnp.take_along_axis(sketch.col_flows, h, axis=1)
    return jnp.min(vals, axis=0)


def node_out_flow(sketch: GLavaSketch, keys: jax.Array) -> jax.Array:
    """f̃_v(a, →): aggregated weight OUT of a-nodes = min_i rowsum(M_i[h_i(a), :]).

    Served from the maintained ``row_flows`` register (O(d·Q) gather)."""
    h = sketch.row_hash(keys)
    vals = jnp.take_along_axis(sketch.row_flows, h, axis=1)
    return jnp.min(vals, axis=0)


def node_flow(sketch: GLavaSketch, keys: jax.Array) -> jax.Array:
    """f̃_v(a, ⊥) for undirected graphs: total incident weight."""
    if sketch.config.directed:
        return node_in_flow(sketch, keys) + node_out_flow(sketch, keys)
    # Undirected ingest mirrors each edge, so row sums already count every
    # incident edge exactly once per direction.
    return node_out_flow(sketch, keys)


def monitor_step(
    sketch: GLavaSketch,
    src: jax.Array,
    dst: jax.Array,
    weight: jax.Array,
    watch_key: jax.Array,
    theta: float,
) -> Tuple[jax.Array, GLavaSketch]:
    """Paper Section 4.2's 3-step real-time monitor for f̃_v(a,←) > θ
    (DoS-style alarm): estimate current in-flow, alarm if the incoming edge
    pushes it over θ, then update the sketches.  Batched over the edge batch;
    `watch_key` is the monitored node label a."""
    inflow = node_in_flow(sketch, watch_key[None])[0]
    hits = (dst == watch_key).astype(jnp.float32) * weight
    alarm = inflow + jnp.sum(hits) > theta
    new_sketch = sketch.update(src, dst, weight)
    return alarm, new_sketch


# ---------------------------------------------------------------------------
# Path queries (Section 4.3)
# ---------------------------------------------------------------------------

reach_query = reach_mod.reach_query
reach_query_precomputed = reach_mod.reach_query_precomputed
transitive_closure = reach_mod.transitive_closure


# ---------------------------------------------------------------------------
# Aggregate subgraph queries (Sections 3.4 / 4.4)
# ---------------------------------------------------------------------------


def subgraph_query(
    sketch: GLavaSketch, src: jax.Array, dst: jax.Array
) -> jax.Array:
    """f̃(Q) for Q = {(x_1,y_1)..(x_k,y_k)} given as (k,) key arrays.

    Paper semantics (Section 4.4): per sketch i, weight_i(Q) = Σ_k cell_ik if
    every constituent edge is present in that sketch, else 0 (the revised
    exact-match semantics); then f̃(Q) = min_i weight_i(Q).
    """
    r, c = sketch.hash_edges(src, dst)  # (d, k)
    d_idx = jnp.broadcast_to(jnp.arange(r.shape[0])[:, None], r.shape)
    cells = sketch.counters[d_idx, r, c]          # (d, k)
    present = jnp.all(cells > 0, axis=1)          # (d,)
    weight_i = jnp.where(present, jnp.sum(cells, axis=1), 0.0)
    return jnp.min(weight_i)


def subgraph_query_opt(
    sketch: GLavaSketch, src: jax.Array, dst: jax.Array
) -> jax.Array:
    """The paper's optimized f̃'(Q) = Σ_k f̃_e(x_k, y_k) — min per edge first,
    then sum.  Satisfies f̃'(Q) <= f̃(Q) (property-tested), with the revised
    semantics' zero-propagation applied."""
    per_edge = edge_query(sketch, src, dst)  # (k,)
    total = jnp.sum(per_edge)
    return jnp.where(jnp.any(per_edge == 0), 0.0, total)


def subgraph_query_batch(
    sketch: GLavaSketch, src: jax.Array, dst: jax.Array, mask: jax.Array
) -> jax.Array:
    """Batched f̃(Q) for n subgraph queries padded to a common edge count k.

    ``src``/``dst`` are (n, k) key arrays, ``mask`` (n, k) bool marks REAL
    edges — padded slots are treated as trivially present with weight 0, so
    a padded query answers exactly what :func:`subgraph_query` answers on
    its unpadded edge list (bit-identical in the integer-weight regime; the
    plan-and-fuse API plane uses this to serve a whole subgraph family in
    one dispatch)."""
    r = sketch.row_hash(src)  # (d, n, k)
    c = sketch.col_hash(dst)
    d_idx = jnp.arange(r.shape[0])[:, None, None]
    cells = sketch.counters[d_idx, r, c]                      # (d, n, k)
    live = mask[None, :, :]
    present = jnp.all(jnp.where(live, cells > 0, True), axis=2)   # (d, n)
    wsum = jnp.sum(jnp.where(live, cells, 0.0), axis=2)           # (d, n)
    weight_i = jnp.where(present, wsum, 0.0)
    return jnp.min(weight_i, axis=0)                               # (n,)


def check_heavy_keys_vec(sketch: GLavaSketch, keys: jax.Array, thetas: jax.Array):
    """Per-query-threshold variant of :func:`check_heavy_keys`: ``thetas``
    is a (Q,) array riding alongside ``keys``, so one dispatch serves a
    heterogeneous heavy-hitter batch.  Elementwise identical to the scalar-θ
    path."""
    return node_in_flow(sketch, keys) > thetas, node_out_flow(sketch, keys) > thetas


def stream_total_weight(sketch: GLavaSketch) -> jax.Array:
    """F̃ — the total stream weight estimate (the (*, *) wildcard): exact
    from any single sketch in the integer regime; min over sketches is the
    paper's estimator.  An O(d·w_r) register reduction."""
    return jnp.min(jnp.sum(sketch.row_flows, axis=1))


def check_heavy_keys_rel_vec(
    sketch: GLavaSketch, keys: jax.Array, thetas: jax.Array
):
    """RELATIVE heavy-hitter check — the API plane's θ semantics: a node is
    heavy when its flow exceeds the fraction ``θ ∈ (0, 1]`` of the total
    stream weight F̃ (:func:`stream_total_weight`), the paper's workload-
    independent heavy-hitter definition.  ``thetas`` is a per-query (Q,)
    fraction array (padded lanes compare against 0·F̃ and are sliced away by
    the engine).  The core absolute-threshold path
    (:func:`check_heavy_keys`) remains for callers that track F themselves.
    """
    cut = thetas.astype(jnp.float32) * stream_total_weight(sketch).astype(
        jnp.float32
    )
    return node_in_flow(sketch, keys) > cut, node_out_flow(sketch, keys) > cut


def wildcard_edge_query(
    sketch: GLavaSketch,
    src: Optional[jax.Array],
    dst: Optional[jax.Array],
) -> jax.Array:
    """f̃_e with one wildcard endpoint (paper Section 3.4 extension):
    f̃_e(x, *) = f̃_v(x, →) and f̃_e(*, y) = f̃_v(y, ←)."""
    if src is None and dst is None:
        # (*, *): total stream weight — exact from any single sketch; the
        # row register already holds the per-row marginals, so this is an
        # O(d·w_r) reduction instead of O(d·w_r·w_c).
        return jnp.min(jnp.sum(sketch.row_flows, axis=1))[None]
    if dst is None:
        return node_out_flow(sketch, src)
    if src is None:
        return node_in_flow(sketch, dst)
    return edge_query(sketch, src, dst)


def bound_wildcard_path2(
    sketch: GLavaSketch, b: jax.Array, c: jax.Array
) -> jax.Array:
    """Bound-wildcard query f̃({(*_1, b), (c, *_1)}) — the common-neighbor /
    triangle-closing count of Example 7 (Q6): estimate Σ_u w(u→b)·w(c→u).

    Per sketch: Σ_u M[u, h(b)] · M[h(c), u] = (row h(c) of M) · (col h(b) of M)
    — one dot product on the MXU; min over d sketches.  Requires square
    sketches (shared node space)."""
    if not sketch.config.is_square:
        raise ValueError("bound wildcards require a square sketch")
    hb = sketch.col_hash(b)  # (d, Q)
    hc = sketch.row_hash(c)  # (d, Q)
    d_idx = jnp.arange(sketch.depth)[:, None]
    col_b = sketch.counters[d_idx, :, hb]  # (d, Q, w) — column h(b), as rows
    row_c = sketch.counters[d_idx, hc, :]  # (d, Q, w)
    per_sketch = jnp.einsum("dqw,dqw->dq", col_b, row_c)
    return jnp.min(per_sketch, axis=0)


def triangle_query(
    sketch: GLavaSketch, a: jax.Array, b: jax.Array, c: jax.Array
) -> jax.Array:
    """f̃ of the labeled 3-clique {(a,b),(b,c),(c,a)} (Example 7, Q4)."""
    src = jnp.stack([a, b, c])
    dst = jnp.stack([b, c, a])
    return subgraph_query(sketch, src, dst)


def global_triangle_estimate(sketch: GLavaSketch) -> jax.Array:
    """Global (unlabeled) directed-triangle mass estimate: min_i tr(M_i³)/sth.
    Provided as a graph-analytics demo of "run any algorithm on the sketch" —
    min over sketches of trace(M³) counts weighted closed 3-walks."""
    m = sketch.counters
    m3 = jnp.einsum("dij,djk,dki->d", m, m, m)
    return jnp.min(m3)


# ---------------------------------------------------------------------------
# Heavy hitters & analytics (supported-queries breadth, Section 3.4 "beyond")
# ---------------------------------------------------------------------------


def heavy_hitter_buckets(sketch: GLavaSketch, theta: float):
    """Buckets whose in/out flow exceeds θ in ALL d sketches — candidate
    heavy-hitter node sets (superset of true heavy hitters; no false
    negatives by the CountMin over-estimate property).  Reads the maintained
    flow registers — no counter reduction."""
    return sketch.row_flows > theta, sketch.col_flows > theta


def check_heavy_keys(sketch: GLavaSketch, keys: jax.Array, theta: float):
    """Boolean monitor f̃_v(a,←) > θ and f̃_v(a,→) > θ for a key batch."""
    return node_in_flow(sketch, keys) > theta, node_out_flow(sketch, keys) > theta


def sketch_pagerank(
    sketch: GLavaSketch, damping: float = 0.85, iters: int = 32
) -> jax.Array:
    """PageRank run directly on each sketch graph (off-the-shelf algorithm on
    the summary, paper Section 3.3 Remark).  Returns (d, w) bucket ranks."""
    m = sketch.counters
    out = jnp.sum(m, axis=2, keepdims=True)
    p = jnp.where(out > 0, m / jnp.maximum(out, 1e-9), 0.0)  # row-stochastic
    w = m.shape[-1]
    rank = jnp.full((m.shape[0], w), 1.0 / w)

    def body(_, rank):
        step = jnp.einsum("dw,dwk->dk", rank, p)  # one propagation, reused
        leaked = 1.0 - damping * step.sum(-1, keepdims=True)
        return damping * step + leaked / w

    return jax.lax.fori_loop(0, iters, body, rank)
