"""QueryEngine — the ONE dispatch point for sketch queries.

Mirrors :class:`repro.core.ingest.IngestEngine` on the query side: every
query family (edge, point/flow, heavy-hitter, subgraph, reachability) is
served through one engine that owns

- the **jit cache**: one persistent ``jax.jit`` callable per (family,
  backend); jit itself then caches per (shape, dtype), so repeated queries
  never re-trace — callers like ``SketchServer`` stop paying a trace per
  freshly-created lambda;
- **query-batch padding/chunking**: key batches are right-padded to a
  multiple of ``pad_q`` (and processed in ``chunk``-sized pieces beyond
  that), so the per-(family, shape) cache stays small no matter how ragged
  the arriving batch sizes are;
- the **epoch-tagged closure cache**: reachability needs the transitive
  closure of the counters — O(w³ log w) to build, O(d·Q) to query.  The
  engine caches one closure tagged with the caller's *epoch* (any int that
  changes when the sketch changes, e.g. a count of ingested batches);
  repeated reach queries within an epoch amortize a single closure build;
- the **backend convention**: ``jnp`` (pure XLA) or ``pallas`` (the fused
  multi-query kernel from ``repro.kernels.query`` and the blocked closure
  kernel from ``repro.kernels.closure``); ``auto`` resolves via the
  ``REPRO_QUERY_BACKEND`` environment variable, else pallas on TPU and jnp
  elsewhere — the same convention as ingest.

See DESIGN.md Sections 3–4 for how the engine and the flow registers fit
together.
"""
from __future__ import annotations

import collections
import os
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import queries, reach
from repro.core.sketch import GLavaSketch

QUERY_BACKENDS = ("jnp", "pallas")
DEFAULT_PAD_Q = 256
DEFAULT_CHUNK_Q = 16384
# Incremental-closure hygiene: touched-row batches pad to multiples of this
# (few jit shapes), refreshes fall back to a full rebuild when a batch
# touches more than CLOSURE_REFRESH_FRAC of the rows (the O(T·w²) refresh
# stops winning) or after CLOSURE_STALENESS_BUDGET incremental refreshes
# since the last full build (perf hygiene — the refresh itself is exact).
CLOSURE_REFRESH_PAD_T = 64
CLOSURE_REFRESH_FRAC = 0.25
CLOSURE_STALENESS_BUDGET = 256


def resolve_query_backend(backend: Optional[str]) -> str:
    """Resolve "auto"/None to a concrete query backend name."""
    if backend in (None, "auto"):
        env = os.environ.get("REPRO_QUERY_BACKEND", "").strip().lower()
        if env:
            backend = env
        else:
            backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in QUERY_BACKENDS:
        raise ValueError(
            f"unknown query backend: {backend!r} (want {QUERY_BACKENDS})"
        )
    return backend


def _pallas_edge_query(sketch: GLavaSketch, src: jax.Array, dst: jax.Array):
    from repro.kernels.query import ops as query_ops

    est = query_ops.edge_query(
        sketch, src, dst, interpret=jax.default_backend() != "tpu"
    )
    # The kernel computes in fp32; counter values are exact integers there
    # (counting regime), so the cast back to the counter dtype is lossless
    # and keeps both backends dtype-identical.
    est = est.astype(sketch.counters.dtype)
    if not sketch.config.directed:
        est = queries.undirected_selfloop_correction(est, src, dst)
    return est


def _pallas_closure(counters: jax.Array):
    from repro.kernels.closure.ops import transitive_closure

    return transitive_closure(counters)


# family -> (jnp fn, pallas fn); point/flow families are O(d·Q) register
# gathers either way, so both backends share the jnp path.
_FAMILIES: Dict[str, Tuple[Callable, Callable]] = {
    "edge": (queries.edge_query, _pallas_edge_query),
    "in_flow": (queries.node_in_flow, queries.node_in_flow),
    "out_flow": (queries.node_out_flow, queries.node_out_flow),
    "flow": (queries.node_flow, queries.node_flow),
    "heavy": (queries.check_heavy_keys, queries.check_heavy_keys),
    "heavy_vec": (queries.check_heavy_keys_vec, queries.check_heavy_keys_vec),
    "heavy_rel_vec": (
        queries.check_heavy_keys_rel_vec,
        queries.check_heavy_keys_rel_vec,
    ),
    "subgraph": (queries.subgraph_query, queries.subgraph_query),
    "subgraph_opt": (queries.subgraph_query_opt, queries.subgraph_query_opt),
    "subgraph_batch": (queries.subgraph_query_batch, queries.subgraph_query_batch),
    "reach_pre": (
        reach.reach_query_precomputed,
        reach.reach_query_precomputed,
    ),
    "closure": (reach.transitive_closure, _pallas_closure),
    # The touched-row refresh is small-matmul work XLA handles well on any
    # backend; the pallas closure kernel only pays off for full rebuilds.
    "closure_refresh": (reach.closure_refresh, reach.closure_refresh),
}

class QueryEngine:
    """A resolved query backend with per-family jit caching, query padding,
    and an epoch-tagged transitive-closure cache."""

    def __init__(
        self,
        backend: str = "auto",
        pad_q: int = DEFAULT_PAD_Q,
        chunk_q: int = DEFAULT_CHUNK_Q,
        closure_staleness_budget: int = CLOSURE_STALENESS_BUDGET,
        closure_refresh_frac: float = CLOSURE_REFRESH_FRAC,
    ):
        self.backend = resolve_query_backend(backend)
        self.pad_q = pad_q
        self.chunk_q = max(chunk_q, pad_q)
        self.closure_staleness_budget = closure_staleness_budget
        self.closure_refresh_frac = closure_refresh_frac
        self._jits: Dict[str, Callable] = {}
        self._closure: Optional[jax.Array] = None
        self._closure_epoch: Optional[int] = None
        self._closure_family: Optional[bytes] = None
        self.closure_refreshes = 0           # full O(w³ log w) builds
        self.closure_incremental_refreshes = 0  # touched-row O(T·w²) refreshes
        self._incremental_since_full = 0
        # Engine dispatches per family (one per padded/chunked batch call) —
        # the API planner's one-dispatch-per-family contract is asserted
        # against these counts.
        self.dispatches: collections.Counter = collections.Counter()

    # -- jit cache -----------------------------------------------------------

    def _fn(self, family: str) -> Callable:
        fn = self._jits.get(family)
        if fn is None:
            jnp_fn, pallas_fn = _FAMILIES[family]
            fn = jax.jit(pallas_fn if self.backend == "pallas" else jnp_fn)
            self._jits[family] = fn
        return fn

    @staticmethod
    def family_probe(
        family: str,
        *,
        width: int = 64,
        depth: int = 2,
        n_queries: int = 32,
    ):
        """Costlint sizing hook: the family's jnp estimator + args built at
        a parameterized (w, d, Q), so the cost pass can compile the same
        callable the engine jit-caches across a geometric size ladder.
        Returns ``(fn, args, counters_shape)``."""
        from repro.core import reach
        from repro.core.sketch import GLavaSketch, SketchConfig

        cfg = SketchConfig(depth=depth, width_rows=width, width_cols=width)
        sk = GLavaSketch.empty(cfg, jax.random.key(0))
        keys = jnp.arange(n_queries, dtype=jnp.uint32)
        shape = tuple(sk.counters.shape)
        jnp_fn = _FAMILIES[family][0]
        if family == "edge":
            return jnp_fn, (sk, keys, keys + jnp.uint32(1)), shape
        if family in ("in_flow", "out_flow", "flow"):
            return jnp_fn, (sk, keys), shape
        if family in ("heavy_vec", "heavy_rel_vec"):
            thetas = jnp.full((n_queries,), 0.5, jnp.float32)
            return jnp_fn, (sk, keys, thetas), shape
        if family == "closure":
            return jnp_fn, (sk.counters,), shape
        if family == "closure_refresh":
            closure = reach.transitive_closure(sk.counters)
            rows = sk.row_hash(keys[: min(8, n_queries)])
            return jnp_fn, (closure, sk.counters, rows), shape
        raise ValueError(f"no cost probe for query family {family!r}")

    # -- padding/chunking ----------------------------------------------------

    def _run_padded(
        self,
        family: str,
        sketch_args,
        keys: Tuple[jax.Array, ...],
        tail_args: Tuple = (),
    ):
        """Run a per-query family over key arrays (each (Q,)): pad Q up to a
        multiple of pad_q so the jit cache sees few distinct shapes, chunk
        batches beyond chunk_q, slice the answers back to Q.  ``tail_args``
        ride along un-padded after the key arrays (e.g. a traced θ)."""
        self.dispatches[family] += 1
        fn = self._fn(family)
        q = keys[0].shape[0]
        outs = []
        for lo in range(0, max(q, 1), self.chunk_q):
            hi = min(q, lo + self.chunk_q)
            part = [k[lo:hi] for k in keys]
            n = hi - lo
            pad = (-n) % self.pad_q
            if pad:
                part = [jnp.pad(k, (0, pad)) for k in part]
            out = fn(*sketch_args, *part, *tail_args)
            outs.append(
                jax.tree_util.tree_map(lambda o: o[:n], out)
                if pad
                else out
            )
        if len(outs) == 1:
            return outs[0]
        return jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs), *outs
        )

    # -- query families ------------------------------------------------------

    def edge(self, sketch: GLavaSketch, src, dst):
        return self._run_padded("edge", (sketch,), (src, dst))

    def in_flow(self, sketch: GLavaSketch, keys):
        return self._run_padded("in_flow", (sketch,), (keys,))

    def out_flow(self, sketch: GLavaSketch, keys):
        return self._run_padded("out_flow", (sketch,), (keys,))

    def flow(self, sketch: GLavaSketch, keys):
        return self._run_padded("flow", (sketch,), (keys,))

    def heavy(self, sketch: GLavaSketch, keys, theta: float):
        # theta rides along as a traced array so one trace serves all θ.
        return self._run_padded(
            "heavy", (sketch,), (keys,), (jnp.asarray(theta, jnp.float32),)
        )

    def heavy_vec(self, sketch: GLavaSketch, keys, thetas):
        """Heavy-hitter check with a PER-QUERY θ array — lets the planner
        serve a mixed-θ heavy family in one dispatch.  ``thetas`` pads with
        zeros alongside the keys (padded lanes are sliced away)."""
        return self._run_padded(
            "heavy_vec",
            (sketch,),
            (keys, jnp.asarray(thetas, jnp.float32)),
        )

    def heavy_rel_vec(self, sketch: GLavaSketch, keys, thetas):
        """Per-query RELATIVE-θ heavy-hitter check: flows compare against
        θ·F̃ with F̃ the total-stream-weight register estimate — the API
        plane's heavy semantics (θ a fraction in (0, 1], validated at Query
        construction)."""
        return self._run_padded(
            "heavy_rel_vec",
            (sketch,),
            (keys, jnp.asarray(thetas, jnp.float32)),
        )

    def subgraph(self, sketch: GLavaSketch, src, dst, optimized: bool = False):
        # Subgraph queries reduce over the WHOLE edge set — zero-padding
        # would change the answer (absent-edge semantics) — so they jit at
        # their exact (small-k) shape instead of going through _run_padded.
        family = "subgraph_opt" if optimized else "subgraph"
        self.dispatches[family] += 1
        return self._fn(family)(sketch, src, dst)

    def subgraph_batch(self, sketch: GLavaSketch, src, dst, mask):
        """n subgraph queries padded to a common k with a validity mask —
        masked padding keeps the revised absent-edge semantics exact, so a
        whole subgraph family is one dispatch (jitted at the (n, k) shape)."""
        self.dispatches["subgraph_batch"] += 1
        return self._fn("subgraph_batch")(sketch, src, dst, mask)

    # -- reachability + closure cache ----------------------------------------

    @staticmethod
    def _family_key(sketch: GLavaSketch) -> bytes:
        """Hash-family identity BY VALUE: jit-updated sketches carry fresh
        array objects every batch, so object identity would spuriously miss;
        the (d, 1) coefficient array is cheap to snapshot."""
        return np.asarray(sketch.row_hash.a).tobytes()

    def _closure_fresh(self, sketch: GLavaSketch, epoch: Optional[int]) -> bool:
        return (
            self._closure is not None
            and epoch is not None
            and epoch == self._closure_epoch
            and self._closure_family == self._family_key(sketch)
        )

    def closure_for(
        self, sketch: GLavaSketch, epoch: Optional[int] = None
    ) -> jax.Array:
        """The transitive closure of ``sketch.counters``, rebuilt only when
        ``epoch`` differs from the cached tag (``None`` always rebuilds).

        The cache is additionally tagged with the sketch's hash-family
        VALUE, so one engine serving sketches from differently-seeded
        streams cannot cross-serve a closure even if their caller-managed
        epochs collide.  Two SAME-seeded streams share a family value, so
        the epoch is the only discriminator between them — the engine's
        contract is one stream per engine (the `GraphStream` facade owns
        an engine per session); core callers multiplexing one engine
        across same-family sketches must keep their epochs disjoint."""
        if not self._closure_fresh(sketch, epoch):
            self._closure = self._fn("closure")(sketch.counters)
            self._closure_epoch = epoch
            self._closure_family = self._family_key(sketch)
            self.closure_refreshes += 1
            self._incremental_since_full = 0
        return self._closure

    def refresh_closure(
        self,
        sketch: GLavaSketch,
        touched_keys,
        epoch: Optional[int] = None,
    ) -> jax.Array:
        """Bring the cached closure up to ``epoch`` INCREMENTALLY from the
        node keys whose rows the mutations since the cached epoch touched
        (``reach.closure_refresh`` — exact for additions-only histories).

        ``touched_keys`` is a unique (U,) uint32 key array, OR a (d, w_r)
        bool BITMAP of touched row buckets (the fused ingest kernel's
        device-emitted form — ``GLavaSketch.update_fused``), or ``None``
        meaning "unknown / not additions-only" (deletes, window expiry,
        merges) which — like a missing or foreign cached closure — falls
        back to a full :meth:`closure_for` build.  So does a refresh past
        the staleness budget (``closure_staleness_budget`` incremental
        refreshes since the last full build) or a batch touching more than
        ``closure_refresh_frac`` of the rows, where re-squaring is cheaper.
        The subscription plane drives this per re-evaluation tick; counts
        land in ``closure_incremental_refreshes``."""
        if self._closure_fresh(sketch, epoch):
            return self._closure
        can_incremental = (
            self._closure is not None
            and touched_keys is not None
            and epoch is not None
            and self._closure_family == self._family_key(sketch)
            and self._incremental_since_full < self.closure_staleness_budget
        )
        rows = None
        w_r = sketch.counters.shape[1]
        if can_incremental:
            touched_keys = np.atleast_1d(np.asarray(touched_keys))
            if touched_keys.ndim == 2:
                # Touched-row bitmap: per-depth row indices, right-padded
                # with row 0 to a shared T (idempotent under the union).
                bitmap = touched_keys.astype(bool)
                counts = bitmap.sum(axis=1)
                t_max = int(counts.max()) if counts.size else 0
                if t_max > self.closure_refresh_frac * w_r:
                    can_incremental = False
                elif t_max > 0:
                    t_pad = t_max + (-t_max) % CLOSURE_REFRESH_PAD_T
                    rows_np = np.zeros((bitmap.shape[0], t_pad), np.int32)
                    for i in range(bitmap.shape[0]):
                        idx = np.flatnonzero(bitmap[i])
                        rows_np[i, : idx.size] = idx
                    rows = jnp.asarray(rows_np)
                touched_size = t_max
            else:
                if touched_keys.size > self.closure_refresh_frac * w_r:
                    can_incremental = False
                touched_size = touched_keys.size
        if not can_incremental:
            return self.closure_for(sketch, epoch)
        if touched_size == 0:
            # Nothing touched: the counters are unchanged, only retag.
            self._closure_epoch = epoch
            return self._closure
        if rows is None:
            rows = sketch.row_hash(
                jnp.asarray(touched_keys.astype(np.uint32, copy=False))
            )  # (d, U)
            pad = (-rows.shape[1]) % CLOSURE_REFRESH_PAD_T
            if pad:
                # Padding with row 0 is exact: an untouched row only restates
                # paths the cached closure already contains.
                rows = jnp.pad(rows, ((0, 0), (0, pad)))
        self._closure = self._fn("closure_refresh")(
            self._closure, sketch.counters, rows
        )
        self._closure_epoch = epoch
        self.closure_incremental_refreshes += 1
        self._incremental_since_full += 1
        return self._closure

    def reach(
        self,
        sketch: GLavaSketch,
        src,
        dst,
        epoch: Optional[int] = None,
    ):
        """Batched r̃(a, b) against the epoch-cached closure: repeated reach
        queries amortize one O(w³ log w) closure instead of recomputing it
        per call."""
        closure = self.closure_for(sketch, epoch)
        return self._run_padded("reach_pre", (sketch, closure), (src, dst))

    def invalidate(self):
        """Drop the cached closure (e.g. the sketch object was swapped)."""
        self._closure = None
        self._closure_epoch = None
        self._closure_family = None
        self._incremental_since_full = 0
