"""Reachability on graph sketches via MXU transitive closure.

The paper (Section 4.3) runs an arbitrary black-box ``reach()`` on each
sketch and ANDs the d answers.  BFS-style reach is pointer-chasing — the
TPU-shaped equivalent is transitive closure by repeated boolean matrix
squaring: ``A <- A OR (A @ A > 0)``, ``ceil(log2 w)`` squarings, each a dense
(w, w) matmul on the MXU.  One closure answers *all-pairs* reachability, so
the cost amortizes over query batches (DESIGN.md Section 2).

A Pallas blocked implementation lives in ``repro.kernels.closure``; the
functions here are the pure-jnp system path (and the oracle for that kernel).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def transitive_closure(adj: jax.Array, include_self: bool = True) -> jax.Array:
    """Boolean transitive closure of (..., w, w) adjacency (float/bool in,
    bool out).  Batched over leading dims (the d sketches)."""
    a = (adj > 0)
    w = adj.shape[-1]
    if include_self:
        eye = jnp.eye(w, dtype=bool)
        a = a | eye
    n_steps = max(1, math.ceil(math.log2(max(2, w))))

    def body(_, a):
        af = a.astype(jnp.float32)
        prod = jnp.einsum("...ik,...kj->...ij", af, af)
        return a | (prod > 0)

    return jax.lax.fori_loop(0, n_steps, body, a)


def reach_query(sketch, src_keys: jax.Array, dst_keys: jax.Array) -> jax.Array:
    """Batched r̃(a, b): AND over the d sketches of per-sketch reachability
    (paper Section 4.3 map/reduce).  Requires a square sketch (row and column
    bucket spaces must coincide for path semantics)."""
    if not sketch.config.is_square:
        raise ValueError("reachability requires a square gLava sketch")
    closure = transitive_closure(sketch.counters)            # (d, w, w) bool
    r = sketch.row_hash(src_keys)                            # (d, Q)
    c = sketch.row_hash(dst_keys)                            # (d, Q) same hash
    d_idx = jnp.broadcast_to(jnp.arange(r.shape[0])[:, None], r.shape)
    per_sketch = closure[d_idx, r, c]                        # (d, Q)
    return jnp.all(per_sketch, axis=0)


def reach_query_precomputed(sketch, closure: jax.Array, src_keys, dst_keys):
    """Same as :func:`reach_query` but against a cached closure (serving path:
    recompute closure once per sketch epoch, answer query batches in O(d)
    gathers)."""
    r = sketch.row_hash(src_keys)
    c = sketch.row_hash(dst_keys)
    d_idx = jnp.broadcast_to(jnp.arange(r.shape[0])[:, None], r.shape)
    return jnp.all(closure[d_idx, r, c], axis=0)


def k_hop_reach(adj: jax.Array, k: int) -> jax.Array:
    """Nodes reachable within exactly <= k hops (bounded-path variant used by
    the GNN sampler integration)."""
    a = (adj > 0)
    w = adj.shape[-1]
    out = a | jnp.eye(w, dtype=bool)
    for _ in range(max(0, k - 1)):
        prod = jnp.einsum(
            "...ik,...kj->...ij", out.astype(jnp.float32), a.astype(jnp.float32)
        )
        out = out | (prod > 0)
    return out
