"""Reachability on graph sketches via MXU transitive closure.

The paper (Section 4.3) runs an arbitrary black-box ``reach()`` on each
sketch and ANDs the d answers.  BFS-style reach is pointer-chasing — the
TPU-shaped equivalent is transitive closure by repeated boolean matrix
squaring: ``A <- A OR (A @ A > 0)``, ``ceil(log2 w)`` squarings, each a dense
(w, w) matmul on the MXU.  One closure answers *all-pairs* reachability, so
the cost amortizes over query batches (DESIGN.md Section 2).

A Pallas blocked implementation lives in ``repro.kernels.closure``; the
functions here are the pure-jnp system path (and the oracle for that kernel).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def transitive_closure(adj: jax.Array, include_self: bool = True) -> jax.Array:
    """Boolean transitive closure of (..., w, w) adjacency (float/bool in,
    bool out).  Batched over leading dims (the d sketches)."""
    a = (adj > 0)
    w = adj.shape[-1]
    if include_self:
        eye = jnp.eye(w, dtype=bool)
        a = a | eye
    n_steps = max(1, math.ceil(math.log2(max(2, w))))

    def body(_, a):
        af = a.astype(jnp.float32)
        prod = jnp.einsum("...ik,...kj->...ij", af, af)
        return a | (prod > 0)

    return jax.lax.fori_loop(0, n_steps, body, a)


def closure_refresh(
    closure: jax.Array, counters: jax.Array, rows: jax.Array
) -> jax.Array:
    """Incrementally refresh a cached transitive closure from touched rows.

    ``closure`` is the (d, w, w) bool closure of a PREVIOUS counters state;
    ``counters`` is the current state, reachable from the previous one by
    ADDITIONS ONLY (positive-weight ingest — deletions/window expiry must
    rebuild from scratch); ``rows`` is a (d, T) int32 array of row buckets
    covering every row that changed in between (a superset is fine:
    unchanged rows contribute no new paths, and duplicate/padding indices
    are idempotent under the boolean union).

    Every path in the new graph decomposes into old-edge runs (already in
    ``closure``) interleaved with departures from touched rows, so with
    B = closure, Δ = the touched rows of the new adjacency, and
    S = (Δ·B) restricted to touched columns ((T, T) — touched-row to
    touched-row hops), the exact new closure is

        B  ∨  B[:, R] · S* · (Δ·B)

    with S* the reflexive-transitive closure of the small S.  Cost is
    O(T·w²) + O(T³ log T) per sketch instead of the O(w³ log w) full
    re-squaring — the win the subscription plane's per-batch refresh rides
    on (DESIGN.md Section 8).  Element-identical to a from-scratch
    :func:`transitive_closure` of ``counters`` (property-tested)."""
    b = closure.astype(jnp.float32)                               # (d, w, w)
    d_idx = jnp.arange(closure.shape[0])[:, None]
    delta = (counters[d_idx, rows, :] > 0).astype(jnp.float32)    # (d, T, w)
    # One touched-row departure followed by any old path (B includes self).
    u = jnp.einsum("dtw,dwv->dtv", delta, b) > 0                  # (d, T, w)
    # Touched-row to touched-row hop graph and its small closure.
    s = jnp.take_along_axis(u, rows[:, None, :], axis=2)          # (d, T, T)
    s_star = transitive_closure(s, include_self=True)             # (d, T, T)
    # Any number of touched-row departures, ending anywhere.
    w_reach = (
        jnp.einsum(
            "dts,dsv->dtv", s_star.astype(jnp.float32), u.astype(jnp.float32)
        )
        > 0
    )                                                             # (d, T, w)
    # Old path into a touched row, then the touched-row path machinery.
    g = jnp.take_along_axis(b, rows[:, None, :], axis=2)          # (d, w, T)
    add = jnp.einsum("dwt,dtv->dwv", g, w_reach.astype(jnp.float32)) > 0
    return closure | add


def reach_query(sketch, src_keys: jax.Array, dst_keys: jax.Array) -> jax.Array:
    """Batched r̃(a, b): AND over the d sketches of per-sketch reachability
    (paper Section 4.3 map/reduce).  Requires a square sketch (row and column
    bucket spaces must coincide for path semantics)."""
    if not sketch.config.is_square:
        raise ValueError("reachability requires a square gLava sketch")
    closure = transitive_closure(sketch.counters)            # (d, w, w) bool
    r = sketch.row_hash(src_keys)                            # (d, Q)
    c = sketch.row_hash(dst_keys)                            # (d, Q) same hash
    d_idx = jnp.broadcast_to(jnp.arange(r.shape[0])[:, None], r.shape)
    per_sketch = closure[d_idx, r, c]                        # (d, Q)
    return jnp.all(per_sketch, axis=0)


def reach_query_precomputed(sketch, closure: jax.Array, src_keys, dst_keys):
    """Same as :func:`reach_query` but against a cached closure (serving path:
    recompute closure once per sketch epoch, answer query batches in O(d)
    gathers)."""
    r = sketch.row_hash(src_keys)
    c = sketch.row_hash(dst_keys)
    d_idx = jnp.broadcast_to(jnp.arange(r.shape[0])[:, None], r.shape)
    return jnp.all(closure[d_idx, r, c], axis=0)


def k_hop_reach(adj: jax.Array, k: int) -> jax.Array:
    """Nodes reachable within exactly <= k hops (bounded-path variant used by
    the GNN sampler integration)."""
    a = (adj > 0)
    w = adj.shape[-1]
    init = a | jnp.eye(w, dtype=bool)

    def hop(_, out):
        prod = jnp.einsum(
            "...ik,...kj->...ij", out.astype(jnp.float32), a.astype(jnp.float32)
        )
        return out | (prod > 0)

    return jax.lax.fori_loop(0, max(0, k - 1), hop, init)
