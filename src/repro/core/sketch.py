"""gLava graph sketches and the stream-sketch baselines the paper compares to.

The central object is :class:`GLavaSketch` — ``d`` independent graph sketches,
each a ``w_r × w_c`` weighted adjacency matrix over *hashed node buckets*
(paper Section 3.3).  Square sketches (``w_r == w_c``, one hash per sketch)
support graph-algorithm queries (reachability, subgraph matching); non-square
sketches (paper Section 6.1.2) trade that for lower combined-collision
probability at equal space.

Ingest backends
---------------
All ingest goes through :mod:`repro.core.ingest` (the ``IngestEngine``
single dispatch point), which owns the ``scatter`` / ``onehot`` / ``pallas``
backends, their padding/chunking, and the row-shard masking used by the
distributed plane.  All backends agree exactly for integer-valued weights
(tested).  Sketches are *linear*: ``sketch(S1 + S2) = sketch(S1) +
sketch(S2)`` — the property the paper's distributed setting (Section 6.3)
and our ``psum`` merge rely on.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import (
    HashFamily,
    make_hash_family,
    mix_keys,
)
from repro.core.ingest import (
    DEFAULT_CHUNK,
    PREAGG_MIN_OUT,
    PREAGG_SHRINK,
    IngestEngine,
    ingest,
    preaggregate_edges,
    resolve_preagg,
)


@dataclasses.dataclass(frozen=True)
class SketchConfig:
    """Static configuration of a gLava sketch."""

    depth: int = 4          # d — number of independent sketches
    width_rows: int = 1024  # w_r
    width_cols: int = 1024  # w_c (== width_rows for the square/paper-default)
    directed: bool = True

    @property
    def is_square(self) -> bool:
        return self.width_rows == self.width_cols

    @property
    def num_cells(self) -> int:
        return self.depth * self.width_rows * self.width_cols

    def space_bytes(self) -> int:
        return self.num_cells * 4

    @staticmethod
    def for_error(epsilon: float, delta: float, square: bool = True) -> "SketchConfig":
        """Size per paper Thm 1 / Lemma 5.2: w = ceil(e/sqrt(eps)) per side,
        d = ceil(ln(1/delta))."""
        w = int(np.ceil(np.e / np.sqrt(epsilon)))
        d = max(1, int(np.ceil(np.log(1.0 / delta))))
        return SketchConfig(depth=d, width_rows=w, width_cols=w)

    def error_bound(self) -> tuple:
        """The (ε, δ) this sketch certifies — the inverse of :meth:`for_error`:
        ε = e²/(w_r·w_c) (additive error ε·F with probability ≥ 1 − δ, paper
        Thm 1), δ = e^(−d).  Nudged up by a 1e-12 relative factor so
        ``SketchConfig.for_error(*cfg.error_bound())`` round-trips to the same
        square config despite float rounding in the ceil(e/sqrt(ε)) inverse
        (e.g. w=7 lands on 8 without the nudge)."""
        eps = float(np.e**2 / (self.width_rows * self.width_cols)) * (1 + 1e-12)
        delta = float(np.exp(-self.depth)) * (1 + 1e-12)
        return eps, delta


def scatter_flows(
    row_flows: jax.Array,  # (d, w_r)
    col_flows: jax.Array,  # (d, w_c)
    rows: jax.Array,       # (d, B)
    cols: jax.Array,       # (d, B)
    weights: jax.Array,    # (B,)
):
    """Fold one hashed edge batch into the flow registers — the SAME
    scatter-add semantics as counter ingest, restricted to the two 1-D
    marginals.  For integer-valued weights this bit-matches
    ``jnp.sum(counters, axis=2)`` / ``axis=1`` of the correspondingly
    updated counters (fp32 integer addition is order-independent in the
    exact range — the IngestEngine equivalence contract)."""
    return (
        scatter_register(row_flows, rows, weights),
        scatter_register(col_flows, cols, weights),
    )


def scatter_register(register: jax.Array, buckets: jax.Array, weights: jax.Array):
    """Scatter-add ``weights`` into one (d, w) flow register at per-depth
    ``buckets`` (d, B).  Flat 1-D formulation with the bounds check promised
    away — buckets come from the hash family, in-range by construction."""
    d, w = register.shape
    d_idx = jnp.broadcast_to(jnp.arange(d)[:, None], buckets.shape)
    vals = jnp.broadcast_to(weights[None, :], buckets.shape).astype(register.dtype)
    flat = (d_idx * w + buckets).reshape(-1)
    return (
        register.reshape(-1)
        .at[flat]
        .add(vals.reshape(-1), mode="promise_in_bounds")
        .reshape(d, w)
    )


def scatter_stacked(
    counters: jax.Array,   # (N, d, w_r, w_c) — N stacked sketch planes
    row_flows: jax.Array,  # (N, d, w_r)
    col_flows: jax.Array,  # (N, d, w_c)
    plane: jax.Array,      # (B,) int32 — target plane per edge
    rows: jax.Array,       # (d, B)
    cols: jax.Array,       # (d, B)
    weights: jax.Array,    # (B,)
):
    """Scatter-add one hashed edge batch into STACKED sketch planes.

    The fleet plane stacks many same-config sketches (tenant × window
    slice) along a leading axis; ``plane`` selects the target per edge, so
    ONE flat 1-D scatter folds a mixed multi-tenant batch into the whole
    stack — the one-dispatch fleet ingest.  Same ``promise_in_bounds``
    idiom as :func:`scatter_register` (plane indices come from the slot
    router, hashes from the family — in range by construction), and per
    plane bit-identical to updating each plane's own sketch in the
    integer-weight regime (fp32 integer addition is order-independent)."""
    n, d, w_r, w_c = counters.shape
    d_idx = jnp.arange(d, dtype=plane.dtype)[:, None]
    base = plane[None, :] * d + d_idx                          # (d, B)
    vals = jnp.broadcast_to(weights[None, :], rows.shape).astype(counters.dtype)
    flat_c = ((base * w_r + rows) * w_c + cols).reshape(-1)
    counters = (
        counters.reshape(-1)
        .at[flat_c]
        .add(vals.reshape(-1), mode="promise_in_bounds")
        .reshape(n, d, w_r, w_c)
    )
    row_flows = (
        row_flows.reshape(-1)
        .at[(base * w_r + rows).reshape(-1)]
        .add(vals.reshape(-1), mode="promise_in_bounds")
        .reshape(n, d, w_r)
    )
    col_flows = (
        col_flows.reshape(-1)
        .at[(base * w_c + cols).reshape(-1)]
        .add(vals.reshape(-1), mode="promise_in_bounds")
        .reshape(n, d, w_c)
    )
    return counters, row_flows, col_flows


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GLavaSketch:
    """d graph sketches with per-sketch row/col hash functions (a pytree).

    Alongside the (d, w_r, w_c) counters the sketch maintains two *flow
    registers* — ``row_flows[i] == sum(counters[i], axis=1)`` (out-flow per
    row bucket) and ``col_flows[i] == sum(counters[i], axis=0)`` (in-flow
    per column bucket) — updated by the same scatter that updates the
    counters.  Point, wildcard, heavy-hitter, and monitor queries read these
    O(d·w) registers instead of re-reducing the O(d·w_r·w_c) counter tensor
    (DESIGN.md Section 3)."""

    counters: jax.Array   # (d, w_r, w_c) float32
    row_hash: HashFamily
    col_hash: HashFamily
    config: SketchConfig = dataclasses.field(metadata=dict(static=True))
    row_flows: jax.Array = None  # (d, w_r) — row sums of counters
    col_flows: jax.Array = None  # (d, w_c) — col sums of counters

    def __post_init__(self):
        # Backfill registers when constructed positionally from counters
        # alone (old call sites / restored checkpoints).
        if self.row_flows is None:
            object.__setattr__(self, "row_flows", jnp.sum(self.counters, axis=2))
        if self.col_flows is None:
            object.__setattr__(self, "col_flows", jnp.sum(self.counters, axis=1))

    @property
    def depth(self) -> int:
        return self.config.depth

    # -- constructors -------------------------------------------------------

    @staticmethod
    def empty(config: SketchConfig, key: jax.Array) -> "GLavaSketch":
        kr, kc = jax.random.split(key)
        row_hash = make_hash_family(kr, config.depth, config.width_rows)
        if config.is_square:
            # Paper default: ONE hash per sketch maps both endpoints, so the
            # sketch's row space and column space coincide (required for
            # running graph algorithms on the sketch).
            col_hash = row_hash
        else:
            col_hash = make_hash_family(kc, config.depth, config.width_cols)
        counters = jnp.zeros(
            (config.depth, config.width_rows, config.width_cols), jnp.float32
        )
        return GLavaSketch(
            counters,
            row_hash,
            col_hash,
            config,
            jnp.zeros((config.depth, config.width_rows), jnp.float32),
            jnp.zeros((config.depth, config.width_cols), jnp.float32),
        )

    # -- ingest -------------------------------------------------------------

    def hash_edges(self, src: jax.Array, dst: jax.Array):
        """(B,) uint32 keys -> ((d,B) row buckets, (d,B) col buckets)."""
        return self.row_hash(src), self.col_hash(dst)

    def _apply_batch(self, engine: IngestEngine, src, dst, weights):
        """Counters + flow registers for one (possibly collapsed) batch,
        including the undirected mirror — returns the three arrays."""
        r, c = self.hash_edges(src, dst)
        counters = engine(self.counters, r, c, weights)
        row_flows, col_flows = scatter_flows(
            self.row_flows, self.col_flows, r, c, weights
        )
        if not self.config.directed:
            # Undirected: also accumulate the mirrored edge so the adjacency
            # matrix stays symmetric (paper Section 6.1.1).
            r2, c2 = self.hash_edges(dst, src)
            counters = engine(counters, r2, c2, weights)
            row_flows, col_flows = scatter_flows(
                row_flows, col_flows, r2, c2, weights
            )
        return counters, row_flows, col_flows

    def update(
        self,
        src: jax.Array,
        dst: jax.Array,
        weights: Optional[jax.Array] = None,
        backend: str = "auto",
        chunk: int = DEFAULT_CHUNK,
        preagg: str = "auto",
    ) -> "GLavaSketch":
        """Ingest a batch of stream elements (x, y; w).

        ``backend`` resolves through the :class:`IngestEngine` convention:
        "auto" honours ``REPRO_INGEST_BACKEND``, else pallas on TPU and
        scatter elsewhere.

        ``preagg`` resolves through :func:`repro.core.ingest.resolve_preagg`
        ("auto" honours ``REPRO_INGEST_PREAGG``, else batches of at least
        ``PREAGG_MIN_BATCH``): when on, duplicate (src, dst) pairs are
        collapsed in-jit (:func:`preaggregate_edges`) and the scatter runs
        on ``batch // PREAGG_SHRINK`` slots; a ``lax.cond`` falls back to
        the raw batch when the collapse does not fit (low-duplication
        traffic).  Exact for signed weights — turnstile deletes included."""
        if weights is None:
            weights = jnp.ones(src.shape, jnp.float32)
        weights = weights.astype(jnp.float32)
        engine = IngestEngine(backend, chunk)
        b = int(src.shape[0])
        out_size = max(PREAGG_MIN_OUT, b // PREAGG_SHRINK)
        if resolve_preagg(preagg, batch=b) and out_size < b:
            s_rep, d_rep, w_agg, n_seg = preaggregate_edges(
                src, dst, weights, out_size
            )
            counters, row_flows, col_flows = jax.lax.cond(
                n_seg <= out_size,
                lambda: self._apply_batch(engine, s_rep, d_rep, w_agg),
                lambda: self._apply_batch(engine, src, dst, weights),
            )
        else:
            counters, row_flows, col_flows = self._apply_batch(
                engine, src, dst, weights
            )
        return dataclasses.replace(
            self, counters=counters, row_flows=row_flows, col_flows=col_flows
        )

    def update_preaggregated(
        self,
        src: jax.Array,          # (P,) distinct-pair sources
        dst: jax.Array,          # (P,) distinct-pair destinations
        weights: jax.Array,      # (P,) per-pair summed weights
        src_unique: jax.Array,   # (S,) distinct sources
        src_totals: jax.Array,   # (S,) per-source summed weights
        dst_unique: jax.Array,   # (D,) distinct destinations
        dst_totals: jax.Array,   # (D,) per-destination summed weights
        backend: str = "auto",
        chunk: int = DEFAULT_CHUNK,
    ) -> "GLavaSketch":
        """Ingest a HOST-COLLAPSED batch (:func:`preaggregate_host`).

        Counters take one scatter slot per distinct pair through the normal
        :class:`IngestEngine` dispatch (any backend); the flow registers
        take one slot per distinct ENDPOINT — the marginal totals — which
        is the second collapse the session fast path rides.  Zero-weight
        padding slots are no-ops in the counting regime (counters never
        hold -0.0), so callers may pad all seven arrays freely."""
        weights = weights.astype(jnp.float32)
        engine = IngestEngine(backend, chunk)
        r, c = self.hash_edges(src, dst)
        counters = engine(self.counters, r, c, weights)
        row_flows = scatter_register(
            self.row_flows, self.row_hash(src_unique), src_totals
        )
        col_flows = scatter_register(
            self.col_flows, self.col_hash(dst_unique), dst_totals
        )
        if not self.config.directed:
            r2, c2 = self.hash_edges(dst, src)
            counters = engine(counters, r2, c2, weights)
            row_flows = scatter_register(
                row_flows, self.row_hash(dst_unique), dst_totals
            )
            col_flows = scatter_register(
                col_flows, self.col_hash(src_unique), src_totals
            )
        return dataclasses.replace(
            self, counters=counters, row_flows=row_flows, col_flows=col_flows
        )

    def update_fused(
        self,
        src: jax.Array,
        dst: jax.Array,
        weights: Optional[jax.Array] = None,
        interpret: Optional[bool] = None,
    ):
        """One-pass fused ingest: counters, both flow registers, AND the
        touched-row bitmap in a single sweep over the batch
        (``repro.kernels.ingest_fused`` — the Pallas kernel on TPU, its
        bit-identical jnp ref twin elsewhere).

        Returns ``(new_sketch, touched)`` where ``touched`` is a (d, w_r)
        bool bitmap of row buckets this batch wrote — the device-resident
        replacement for the host-side ``touched_row_keys`` pass, consumed
        by ``QueryEngine.refresh_closure``."""
        from repro.kernels.ingest_fused.ops import fused_ingest

        if weights is None:
            weights = jnp.ones(src.shape, jnp.float32)
        weights = weights.astype(jnp.float32)
        r, c = self.hash_edges(src, dst)
        counters, row_flows, col_flows, touched = fused_ingest(
            self.counters,
            self.row_flows,
            self.col_flows,
            r.astype(jnp.int32),
            c.astype(jnp.int32),
            weights,
            interpret=interpret,
        )
        if not self.config.directed:
            r2, c2 = self.hash_edges(dst, src)
            counters, row_flows, col_flows, touched2 = fused_ingest(
                counters,
                row_flows,
                col_flows,
                r2.astype(jnp.int32),
                c2.astype(jnp.int32),
                weights,
                interpret=interpret,
            )
            touched = touched | touched2
        new = dataclasses.replace(
            self, counters=counters, row_flows=row_flows, col_flows=col_flows
        )
        return new, touched

    def delete(
        self,
        src,
        dst,
        weights=None,
        backend: str = "auto",
        chunk: int = DEFAULT_CHUNK,
    ):
        """Turnstile deletion (paper Section 6.1.1): negative-weight update.

        Resolves the backend through the :class:`IngestEngine` exactly like
        :meth:`update`, so ``REPRO_INGEST_BACKEND`` / the TPU pallas fast
        path apply to deletes too."""
        if weights is None:
            weights = jnp.ones(src.shape, jnp.float32)
        return self.update(src, dst, -weights, backend=backend, chunk=chunk)

    def update_sequential(self, src, dst, weights=None) -> "GLavaSketch":
        """Strictly-sequential per-edge ingest (the paper's literal Step 2).

        Used as the semantics oracle in tests; O(B) sequential steps.
        """
        if weights is None:
            weights = jnp.ones(src.shape, jnp.float32)
        weights = weights.astype(jnp.float32)
        r, c = self.hash_edges(src, dst)

        def body(counters, inputs):
            ri, ci, wi = inputs
            d_idx = jnp.arange(self.depth)
            return counters.at[d_idx, ri, ci].add(wi), None

        counters, _ = jax.lax.scan(body, self.counters, (r.T, c.T, weights))
        if not self.config.directed:
            r2, c2 = self.hash_edges(dst, src)
            counters = ingest(counters, r2, c2, weights)
        return self.with_counters(counters)

    def update_conservative(self, src, dst, weights=None) -> "GLavaSketch":
        """Conservative-update (Estan–Varghese) variant — beyond-paper accuracy
        optimization: bump each edge's cells only up to the new lower bound.
        Order-dependent, hence sequential (lax.scan)."""
        if weights is None:
            weights = jnp.ones(src.shape, jnp.float32)
        weights = weights.astype(jnp.float32)
        r, c = self.hash_edges(src, dst)

        def body(counters, inputs):
            ri, ci, wi = inputs
            d_idx = jnp.arange(self.depth)
            cur = counters[d_idx, ri, ci]          # (d,)
            est = jnp.min(cur)                      # current min-estimate
            new = jnp.maximum(cur, est + wi)        # raise to new lower bound
            return counters.at[d_idx, ri, ci].set(new), None

        counters, _ = jax.lax.scan(body, self.counters, (r.T, c.T, weights))
        # Conservative update is NON-linear (cells move by data-dependent
        # amounts), so the registers cannot be maintained by the edge
        # scatter — recompute them from the final counters.
        return self.with_counters(counters)

    # -- linear-sketch algebra ----------------------------------------------

    def with_counters(self, counters: jax.Array) -> "GLavaSketch":
        """Replace the counter tensor wholesale and recompute the flow
        registers from it (the safe path for counter-level surgery —
        non-linear updates, restored checkpoints without registers)."""
        return dataclasses.replace(
            self,
            counters=counters,
            row_flows=jnp.sum(counters, axis=2),
            col_flows=jnp.sum(counters, axis=1),
        )

    def merge(self, other: "GLavaSketch") -> "GLavaSketch":
        """Merge two sketches built with the SAME hash family (linearity)."""
        return dataclasses.replace(
            self,
            counters=self.counters + other.counters,
            row_flows=self.row_flows + other.row_flows,
            col_flows=self.col_flows + other.col_flows,
        )

    def scale(self, gamma: float) -> "GLavaSketch":
        """Exponential decay of history (streaming time-window variant)."""
        return dataclasses.replace(
            self,
            counters=self.counters * gamma,
            row_flows=self.row_flows * gamma,
            col_flows=self.col_flows * gamma,
        )

    def same_family(self, other: "GLavaSketch") -> bool:
        return bool(
            np.array_equal(np.asarray(self.row_hash.a), np.asarray(other.row_hash.a))
            and np.array_equal(np.asarray(self.row_hash.b), np.asarray(other.row_hash.b))
            and np.array_equal(np.asarray(self.col_hash.a), np.asarray(other.col_hash.a))
            and np.array_equal(np.asarray(self.col_hash.b), np.asarray(other.col_hash.b))
        )


# ---------------------------------------------------------------------------
# Baselines: CountMin (edge-keyed), node-stream CountMin, CountSketch, gSketch
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CountMin:
    """Classic CountMin over *edge keys* (the Example-2 baseline).

    Treats each stream element independently — supports edge-frequency and
    additive aggregate-subgraph estimates, and (by construction) nothing that
    needs cross-element connectivity.
    """

    counters: jax.Array  # (d, w) float32
    hash: HashFamily

    @staticmethod
    def empty(depth: int, width: int, key: jax.Array) -> "CountMin":
        fam = make_hash_family(key, depth, width)
        return CountMin(jnp.zeros((depth, width), jnp.float32), fam)

    def update(self, src, dst, weights=None) -> "CountMin":
        if weights is None:
            weights = jnp.ones(src.shape, jnp.float32)
        k = mix_keys(src, dst)
        h = self.hash(k)  # (d, B)
        d_idx = jnp.broadcast_to(jnp.arange(h.shape[0])[:, None], h.shape)
        w = jnp.broadcast_to(weights[None, :].astype(jnp.float32), h.shape)
        return dataclasses.replace(self, counters=self.counters.at[d_idx, h].add(w))

    def edge_query(self, src, dst) -> jax.Array:
        h = self.hash(mix_keys(src, dst))  # (d, Q)
        vals = jnp.take_along_axis(self.counters, h, axis=1)  # (d, Q)
        return jnp.min(vals, axis=0)

    def merge(self, other: "CountMin") -> "CountMin":
        return dataclasses.replace(self, counters=self.counters + other.counters)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NodeCountMin:
    """CountMin over a node stream (paper Section 5.2's reduction): drop one
    endpoint of every edge and sketch the remaining node stream.  This is the
    classic way to answer in/out-flow point queries WITHOUT a graph sketch —
    our point-query baseline."""

    counters_out: jax.Array  # (d, w) keyed by src
    counters_in: jax.Array   # (d, w) keyed by dst
    hash: HashFamily

    @staticmethod
    def empty(depth: int, width: int, key: jax.Array) -> "NodeCountMin":
        fam = make_hash_family(key, depth, width)
        z = jnp.zeros((depth, width), jnp.float32)
        return NodeCountMin(z, z, fam)

    def update(self, src, dst, weights=None) -> "NodeCountMin":
        if weights is None:
            weights = jnp.ones(src.shape, jnp.float32)
        weights = weights.astype(jnp.float32)
        hs, hd = self.hash(src), self.hash(dst)
        d_idx = jnp.broadcast_to(jnp.arange(hs.shape[0])[:, None], hs.shape)
        w = jnp.broadcast_to(weights[None, :], hs.shape)
        return dataclasses.replace(
            self,
            counters_out=self.counters_out.at[d_idx, hs].add(w),
            counters_in=self.counters_in.at[d_idx, hd].add(w),
        )

    def out_flow(self, keys) -> jax.Array:
        h = self.hash(keys)
        return jnp.min(jnp.take_along_axis(self.counters_out, h, axis=1), axis=0)

    def in_flow(self, keys) -> jax.Array:
        h = self.hash(keys)
        return jnp.min(jnp.take_along_axis(self.counters_in, h, axis=1), axis=0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CountSketch:
    """Signed sketch (AMS/CountSketch) over edge keys — unbiased estimator,
    median merge.  Reused by ``repro.train.compression`` for sketched
    gradient all-reduce (the structure is linear, hence psum-compatible)."""

    counters: jax.Array  # (d, w) float32
    hash: HashFamily

    @staticmethod
    def empty(depth: int, width: int, key: jax.Array) -> "CountSketch":
        fam = make_hash_family(key, depth, width)
        return CountSketch(jnp.zeros((depth, width), jnp.float32), fam)

    def update(self, keys, weights) -> "CountSketch":
        h = self.hash(keys)              # (d, B)
        s = self.hash.signs(keys)        # (d, B) ±1
        d_idx = jnp.broadcast_to(jnp.arange(h.shape[0])[:, None], h.shape)
        w = s.astype(jnp.float32) * weights[None, :].astype(jnp.float32)
        return dataclasses.replace(self, counters=self.counters.at[d_idx, h].add(w))

    def query(self, keys) -> jax.Array:
        h = self.hash(keys)
        s = self.hash.signs(keys).astype(jnp.float32)
        vals = jnp.take_along_axis(self.counters, h, axis=1) * s
        return jnp.median(vals, axis=0)

    def merge(self, other: "CountSketch") -> "CountSketch":
        return dataclasses.replace(self, counters=self.counters + other.counters)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GSketch:
    """gSketch (Zhao et al., PVLDB'11) — CountMin partitioned by a data
    sample so hot regions of the stream get proportionally wider partitions.

    Simplified faithfully to its core idea: a coarse partitioner hash over
    the edge's source routes each element to one of ``k`` CountMin partitions
    whose widths were allocated proportionally to sampled partition mass.
    """

    partitions: CountMin                 # stacked: counters (k, d, w_max)
    widths: jax.Array                    # (k,) int32 — active width per part
    part_hash: HashFamily                # 1-deep hash onto [0, k)

    @staticmethod
    def from_sample(
        depth: int,
        total_width: int,
        k: int,
        sample_src: np.ndarray,
        key: jax.Array,
    ) -> "GSketch":
        kp, kc = jax.random.split(key)
        part_hash = make_hash_family(kp, 1, k)
        # Allocate widths proportional to sampled mass per partition.
        part_of = np.asarray(part_hash(jnp.asarray(sample_src, jnp.uint32)))[0]
        mass = np.bincount(part_of, minlength=k).astype(np.float64) + 1.0
        widths = np.maximum(8, (total_width * mass / mass.sum()).astype(np.int64))
        w_max = int(widths.max())
        fam = make_hash_family(kc, depth, w_max)
        counters = jnp.zeros((k, depth, w_max), jnp.float32)
        return GSketch(
            CountMin(counters, fam), jnp.asarray(widths, jnp.int32), part_hash
        )

    def update(self, src, dst, weights=None) -> "GSketch":
        if weights is None:
            weights = jnp.ones(src.shape, jnp.float32)
        part = self.part_hash(src)[0]                     # (B,)
        k = mix_keys(src, dst)
        h_full = self.partitions.hash(k)                  # (d, B) in [0, w_max)
        w_act = self.widths[part][None, :]                # (1, B)
        h = h_full % w_act
        d = h.shape[0]
        d_idx = jnp.broadcast_to(jnp.arange(d)[:, None], h.shape)
        p_idx = jnp.broadcast_to(part[None, :], h.shape)
        w = jnp.broadcast_to(weights[None, :].astype(jnp.float32), h.shape)
        counters = self.partitions.counters.at[p_idx, d_idx, h].add(w)
        return dataclasses.replace(
            self, partitions=dataclasses.replace(self.partitions, counters=counters)
        )

    def edge_query(self, src, dst) -> jax.Array:
        part = self.part_hash(src)[0]
        h = self.partitions.hash(mix_keys(src, dst)) % self.widths[part][None, :]
        p_idx = jnp.broadcast_to(part[None, :], h.shape)
        d_idx = jnp.broadcast_to(
            jnp.arange(h.shape[0])[:, None], h.shape
        )
        vals = self.partitions.counters[p_idx, d_idx, h]
        return jnp.min(vals, axis=0)
