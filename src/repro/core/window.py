"""Sliding time-window sketches (paper Section 6.1.1 deletions).

The paper supports deleting elements "out of a certain time window" by
negative updates.  Re-streaming expired edges is usually impossible (they
were never stored — that's the point of a sketch), so the standard systems
realization is a ring of K slice-sketches: slice s covers one time slice;
the window estimate is the sum of live slices (linearity); expiry subtracts
a whole slice in O(d·w²) without replaying the stream.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sketch import GLavaSketch, SketchConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SlidingWindowSketch:
    """Ring buffer of K slice sketches sharing one hash family."""

    slices: jax.Array        # (K, d, w_r, w_c)
    current: jax.Array       # () int32 — index of the active slice
    template: GLavaSketch    # hash family + config carrier (counters unused)

    @staticmethod
    def empty(config: SketchConfig, n_slices: int, key: jax.Array):
        template = GLavaSketch.empty(config, key)
        slices = jnp.zeros((n_slices,) + template.counters.shape, jnp.float32)
        return SlidingWindowSketch(slices, jnp.array(0, jnp.int32), template)

    @property
    def n_slices(self) -> int:
        return self.slices.shape[0]

    def update(self, src, dst, weights=None, backend: str = "scatter"):
        """Ingest into the active slice."""
        active = dataclasses.replace(
            self.template, counters=self.slices[self.current]
        )
        active = active.update(src, dst, weights, backend=backend)
        return dataclasses.replace(
            self, slices=self.slices.at[self.current].set(active.counters)
        )

    def advance(self) -> "SlidingWindowSketch":
        """Move to the next time slice, expiring the oldest (zeroing the slot
        the ring wraps onto).  O(d·w²), no stream replay."""
        nxt = (self.current + 1) % self.n_slices
        return dataclasses.replace(
            self,
            current=nxt,
            slices=self.slices.at[nxt].set(0.0),
        )

    def window_sketch(self) -> GLavaSketch:
        """Materialize the whole-window sketch (sum of live slices)."""
        return dataclasses.replace(
            self.template, counters=jnp.sum(self.slices, axis=0)
        )
