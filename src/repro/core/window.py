"""Sliding time-window sketches (paper Section 6.1.1 deletions).

The paper supports deleting elements "out of a certain time window" by
negative updates.  Re-streaming expired edges is usually impossible (they
were never stored — that's the point of a sketch), so the standard systems
realization is a ring of K slice-sketches: slice s covers one time slice;
the window estimate is the sum of live slices (linearity); expiry subtracts
a whole slice in O(d·w²) without replaying the stream.

Each slice also carries its flow registers (row/col marginal sums — see
:class:`repro.core.sketch.GLavaSketch`), so the materialized window sketch
gets maintained registers by summing the O(d·w) slice registers instead of
re-reducing the O(d·w²) counters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sketch import GLavaSketch, SketchConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SlidingWindowSketch:
    """Ring buffer of K slice sketches sharing one hash family."""

    slices: jax.Array        # (K, d, w_r, w_c)
    current: jax.Array       # () int32 — index of the active slice
    template: GLavaSketch    # hash family + config carrier (counters unused)
    row_flows: jax.Array = None  # (K, d, w_r) per-slice row registers
    col_flows: jax.Array = None  # (K, d, w_c) per-slice col registers

    def __post_init__(self):
        if self.row_flows is None:
            object.__setattr__(self, "row_flows", jnp.sum(self.slices, axis=3))
        if self.col_flows is None:
            object.__setattr__(self, "col_flows", jnp.sum(self.slices, axis=2))

    @staticmethod
    def empty(config: SketchConfig, n_slices: int, key: jax.Array):
        template = GLavaSketch.empty(config, key)
        slices = jnp.zeros((n_slices,) + template.counters.shape, jnp.float32)
        return SlidingWindowSketch(
            slices,
            jnp.array(0, jnp.int32),
            template,
            jnp.zeros((n_slices,) + template.row_flows.shape, jnp.float32),
            jnp.zeros((n_slices,) + template.col_flows.shape, jnp.float32),
        )

    @property
    def n_slices(self) -> int:
        return self.slices.shape[0]

    def _active(self) -> GLavaSketch:
        return self._active_at(self.current)

    def _active_at(self, slot) -> GLavaSketch:
        return dataclasses.replace(
            self.template,
            counters=self.slices[slot],
            row_flows=self.row_flows[slot],
            col_flows=self.col_flows[slot],
        )

    def _store(self, active: GLavaSketch) -> "SlidingWindowSketch":
        return self._store_at(self.current, active)

    def _store_at(self, slot, active: GLavaSketch) -> "SlidingWindowSketch":
        return dataclasses.replace(
            self,
            slices=self.slices.at[slot].set(active.counters),
            row_flows=self.row_flows.at[slot].set(active.row_flows),
            col_flows=self.col_flows.at[slot].set(active.col_flows),
        )

    def update(self, src, dst, weights=None, backend: str = "auto",
               preagg: str = "auto"):
        """Ingest into the active slice (counters AND its registers).
        Pre-aggregation applies per-slice exactly like local ingest — the
        collapse is a signed-weight sum, so slice boundaries and later
        whole-slice expiry are unaffected."""
        active = self._active().update(
            src, dst, weights, backend=backend, preagg=preagg
        )
        return self._store(active)

    def update_at(self, slot, src, dst, weights=None,
                  backend: str = "auto") -> "SlidingWindowSketch":
        """Event-time ingest: fold a batch into an ARBITRARY ring slot (a
        traced int32 index), not just the active slice — how late-but-in-
        bound edges land in the slice their event time belongs to.  The
        slot rides through the jit boundary as data, so one compiled
        update serves every slice."""
        active = self._active_at(slot).update(
            src, dst, weights, backend=backend, preagg="off"
        )
        return self._store_at(slot, active)

    def update_preaggregated(self, *args, **kwargs) -> "SlidingWindowSketch":
        """Host-collapsed ingest into the active slice — the session fast
        path (see :meth:`GLavaSketch.update_preaggregated`)."""
        return self._store(self._active().update_preaggregated(*args, **kwargs))

    def advance(self) -> "SlidingWindowSketch":
        """Move to the next time slice, expiring the oldest (zeroing the slot
        the ring wraps onto).  O(d·w²), no stream replay."""
        nxt = (self.current + 1) % self.n_slices
        return dataclasses.replace(
            self,
            current=nxt,
            slices=self.slices.at[nxt].set(0.0),
            row_flows=self.row_flows.at[nxt].set(0.0),
            col_flows=self.col_flows.at[nxt].set(0.0),
        )

    def window_sketch(self) -> GLavaSketch:
        """Materialize the whole-window sketch (sum of live slices).  The
        registers come from the summed slice registers — no counter
        reduction."""
        return dataclasses.replace(
            self.template,
            counters=jnp.sum(self.slices, axis=0),
            row_flows=jnp.sum(self.row_flows, axis=0),
            col_flows=jnp.sum(self.col_flows, axis=0),
        )
