"""Graph data plane: synthetic generators (Zipf/uniform/temporal streams,
citation-style graphs, molecule batches) and the triplet builder for
directional message passing.  Host-side numpy feeding padded device batches.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import triplet_budget


def random_edges(
    n_nodes: int, n_edges: int, rng, zipf_a: Optional[float] = None
) -> Tuple[np.ndarray, np.ndarray]:
    """Edge list; zipf_a skews endpoint popularity (heavy hitters — the
    regime the paper's sketches are built for)."""
    if zipf_a:
        ranks = np.arange(1, n_nodes + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        p /= p.sum()
        src = rng.choice(n_nodes, size=n_edges, p=p)
        dst = rng.choice(n_nodes, size=n_edges, p=p)
    else:
        src = rng.integers(0, n_nodes, n_edges)
        dst = rng.integers(0, n_nodes, n_edges)
    return src.astype(np.int32), dst.astype(np.int32)


def edge_stream(
    n_nodes: int, n_edges: int, rng, zipf_a: float = 1.1, max_weight: int = 8
) -> Dict[str, np.ndarray]:
    """A weighted, timestamped graph stream (x, y; w, t) — paper Section 3.1."""
    src, dst = random_edges(n_nodes, n_edges, rng, zipf_a)
    w = rng.integers(1, max_weight + 1, n_edges).astype(np.float32)
    t = np.sort(rng.random(n_edges)).astype(np.float32)
    return {"src": src.astype(np.uint32), "dst": dst.astype(np.uint32), "weight": w, "time": t}


def build_triplets(
    edge_src: np.ndarray,
    edge_dst: np.ndarray,
    budget: Optional[int] = None,
    edge_mask: Optional[np.ndarray] = None,
) -> Dict[str, np.ndarray]:
    """Directional triplet lists for DimeNet: for every edge e_out=(j→i),
    pair with every edge e_in=(k→j), k != i.

    Returns padded {"in": (T,), "out": (T,), "mask": (T,)} with
    T = budget or triplet_budget(len(edges)).  Truncation (rare; only on
    pathological degree skew) is recorded in the returned "truncated" flag.
    """
    e = len(edge_src)
    t_cap = budget if budget is not None else triplet_budget(e)
    valid = np.ones(e, bool) if edge_mask is None else edge_mask.astype(bool)
    in_by_node: Dict[int, list] = {}
    for idx in np.nonzero(valid)[0]:
        in_by_node.setdefault(int(edge_dst[idx]), []).append(idx)
    t_in, t_out = [], []
    truncated = False
    for e_out in np.nonzero(valid)[0]:
        j, i = int(edge_src[e_out]), int(edge_dst[e_out])
        for e_in in in_by_node.get(j, ()):
            if int(edge_src[e_in]) == i:
                continue  # exclude backtracking k == i
            t_in.append(e_in)
            t_out.append(e_out)
            if len(t_in) >= t_cap:
                truncated = True
                break
        if truncated:
            break
    n = len(t_in)
    out = {
        "in": np.zeros(t_cap, np.int32),
        "out": np.zeros(t_cap, np.int32),
        "mask": np.zeros(t_cap, np.float32),
        "truncated": truncated,
    }
    out["in"][:n] = t_in
    out["out"][:n] = t_out
    out["mask"][:n] = 1.0
    return out


def citation_graph(
    n_nodes: int, n_edges: int, d_feat: int, n_classes: int, rng
) -> Dict[str, np.ndarray]:
    """Cora/products-style node-classification graph with correlated
    class/feature structure (so training actually learns)."""
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    centroids = rng.normal(0, 1, (n_classes, d_feat)).astype(np.float32)
    feats = centroids[labels] + 0.5 * rng.normal(0, 1, (n_nodes, d_feat)).astype(
        np.float32
    )
    # homophilous edges: 70% within class
    n_homo = int(0.7 * n_edges)
    src_h = rng.integers(0, n_nodes, n_homo)
    # partner within same class via sorted-by-label trick
    order = np.argsort(labels, kind="stable")
    pos_of = np.empty(n_nodes, np.int64)
    pos_of[order] = np.arange(n_nodes)
    jitter = rng.integers(-5, 6, n_homo)
    dst_h = order[np.clip(pos_of[src_h] + jitter, 0, n_nodes - 1)]
    src_r, dst_r = random_edges(n_nodes, n_edges - n_homo, rng)
    src = np.concatenate([src_h, src_r]).astype(np.int32)
    dst = np.concatenate([dst_h, dst_r]).astype(np.int32)
    positions = rng.normal(0, 3, (n_nodes, 3)).astype(np.float32)  # for molecular nets
    return {
        "node_feat": feats,
        "edge_src": src,
        "edge_dst": dst,
        "labels": labels,
        "positions": positions,
    }


def molecule_batch(
    n_graphs: int, nodes_per: int, edges_per: int, n_atom_types: int, rng
) -> Dict[str, np.ndarray]:
    """Batch of small molecules: atom types + 3-D positions + edges within a
    cutoff-ish radius; regression target = synthetic 'energy'."""
    n = n_graphs * nodes_per
    types = rng.integers(1, n_atom_types, n).astype(np.int32)
    positions = rng.normal(0, 1.5, (n, 3)).astype(np.float32)
    src_l, dst_l = [], []
    for g in range(n_graphs):
        base = g * nodes_per
        s = rng.integers(0, nodes_per, edges_per) + base
        d = rng.integers(0, nodes_per, edges_per) + base
        src_l.append(s)
        dst_l.append(d)
    src = np.concatenate(src_l).astype(np.int32)
    dst = np.concatenate(dst_l).astype(np.int32)
    graph_ids = np.repeat(np.arange(n_graphs, dtype=np.int32), nodes_per)
    dists = np.linalg.norm(positions[dst] - positions[src], axis=1)
    energy = np.zeros(n_graphs, np.float32)
    np.add.at(energy, graph_ids[src], np.exp(-dists).astype(np.float32))
    return {
        "node_feat": types,
        "positions": positions,
        "edge_src": src,
        "edge_dst": dst,
        "graph_ids": graph_ids,
        "labels": energy[:, None],
    }
