"""Synthetic LM token pipeline: Zipf-distributed tokens with a Markov
backbone (so a ~100M model trained a few hundred steps shows a real loss
drop), plus the token-bigram graph-stream view that feeds the gLava data
statistics (DESIGN.md Section 5: LM integration is system-level)."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class MarkovTokens:
    """Order-1 Markov chain over a Zipf vocabulary."""

    def __init__(self, vocab: int, branch: int = 8, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.branch = branch
        # each token can transition to `branch` successors
        self.succ = rng.integers(0, vocab, (vocab, branch)).astype(np.int32)
        ranks = np.arange(1, branch + 1, dtype=np.float64)
        p = ranks ** -1.2
        self.p = (p / p.sum()).astype(np.float64)

    def batch(self, batch: int, seq: int, rng) -> np.ndarray:
        toks = np.empty((batch, seq), np.int32)
        cur = rng.integers(0, self.vocab, batch)
        toks[:, 0] = cur
        for t in range(1, seq):
            choice = rng.choice(self.branch, size=batch, p=self.p)
            cur = self.succ[cur, choice]
            toks[:, t] = cur
        return toks


def token_batches(
    vocab: int, batch: int, seq: int, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    gen = MarkovTokens(vocab, seed=seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        yield {"tokens": gen.batch(batch, seq + 1, rng)}


def bigram_stream(tokens: np.ndarray) -> Dict[str, np.ndarray]:
    """The token-bigram view of an LM batch AS a graph stream (src=t_i,
    dst=t_{i+1}) — what the data pipeline feeds into gLava for corpus
    statistics."""
    src = tokens[:, :-1].reshape(-1).astype(np.uint32)
    dst = tokens[:, 1:].reshape(-1).astype(np.uint32)
    return {"src": src, "dst": dst, "weight": np.ones(len(src), np.float32)}
