"""RecSys data plane: Zipf-popular item interaction sequences + Cloze
masking (BERT4Rec training), and the user→item bipartite interaction stream
consumed by the gLava popularity sketch (negative sampling / candidate
stats)."""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def item_popularity(n_items: int, a: float = 1.05) -> np.ndarray:
    ranks = np.arange(1, n_items + 1, dtype=np.float64)
    p = ranks ** -a
    return p / p.sum()


def interaction_sequences(
    n_items: int, batch: int, seq: int, rng, p: np.ndarray | None = None
) -> np.ndarray:
    """(B, S) item ids in [1, n_items]; 0 is PAD.  Random-length prefixes are
    padded to model ragged user histories."""
    if p is None:
        p = item_popularity(n_items)
    items = rng.choice(n_items, size=(batch, seq), p=p).astype(np.int32) + 1
    lengths = rng.integers(seq // 4, seq + 1, batch)
    mask = np.arange(seq)[None, :] < lengths[:, None]
    # left-pad (recent history at the end, as BERT4Rec does)
    out = np.zeros((batch, seq), np.int32)
    for b in range(batch):
        L = lengths[b]
        out[b, seq - L :] = items[b, :L]
    return out


def cloze_mask(
    items: np.ndarray, mask_id: int, rng, mask_prob: float = 0.2
) -> Tuple[np.ndarray, np.ndarray]:
    """BERT4Rec Cloze: returns (masked_items, targets) — targets hold the
    true item at masked positions, 0 elsewhere."""
    maskable = items != 0
    m = (rng.random(items.shape) < mask_prob) & maskable
    # guarantee ≥1 mask per row (mask the last valid position)
    none = ~m.any(axis=1)
    last_valid = items.shape[1] - 1 - np.argmax(maskable[:, ::-1], axis=1)
    m[np.nonzero(none)[0], last_valid[none]] = True
    m &= maskable
    masked = np.where(m, mask_id, items)
    targets = np.where(m, items, 0)
    return masked.astype(np.int32), targets.astype(np.int32)


def cloze_mask_positions(
    items: np.ndarray, mask_id: int, max_masked: int, rng, mask_prob: float = 0.2
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Static-shape Cloze for the sampled-softmax loss: at most `max_masked`
    positions per row.  Returns (masked_items, mask_positions (B, M),
    mask_targets (B, M) — 0 marks unused slots)."""
    b, s = items.shape
    masked, targets = cloze_mask(items, mask_id, rng, mask_prob)
    positions = np.zeros((b, max_masked), np.int32)
    ptargets = np.zeros((b, max_masked), np.int32)
    for i in range(b):
        idx = np.nonzero(targets[i])[0][:max_masked]
        # un-mask any overflow beyond the static budget
        overflow = np.nonzero(targets[i])[0][max_masked:]
        masked[i, overflow] = items[i, overflow]
        positions[i, : len(idx)] = idx
        ptargets[i, : len(idx)] = targets[i, idx]
    return masked, positions, ptargets


def interaction_stream(items: np.ndarray, user_ids: np.ndarray) -> Dict[str, np.ndarray]:
    """User→item interactions as a bipartite graph stream for the
    (non-square!) gLava sketch — users hash on rows, items on columns."""
    b, s = items.shape
    src = np.repeat(user_ids.astype(np.uint32), s)
    dst = items.reshape(-1).astype(np.uint32)
    keep = dst != 0
    return {
        "src": src[keep],
        "dst": dst[keep],
        "weight": np.ones(int(keep.sum()), np.float32),
    }
