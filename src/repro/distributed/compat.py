"""Version-portable ``shard_map``.

``jax.shard_map`` (with ``check_vma``) only exists on newer JAX; older
releases (including the pinned 0.4.x toolchain) expose it as
``jax.experimental.shard_map.shard_map`` with the ``check_rep`` keyword.
Every shard_map call site in this repo goes through this wrapper so the
distributed plane runs unchanged on both.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable

import jax

try:  # newer JAX: top-level export
    _shard_map = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

# The replication-check kwarg was renamed check_rep -> check_vma after the
# top-level export appeared, so probe the signature rather than the attr:
# 0.6.x-era jax.shard_map still takes check_rep.
try:
    _CHECK_KW = (
        "check_vma"
        if "check_vma" in inspect.signature(_shard_map).parameters
        else "check_rep"
    )
except (TypeError, ValueError):  # signature not introspectable
    _CHECK_KW = "check_vma"


def shard_map(
    f: Callable,
    *,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    check_vma: bool = True,
) -> Callable:
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on old.

    ``check_vma`` maps onto the legacy ``check_rep`` flag (both gate the
    same replication/varying-axis static check).
    """
    kwargs = {_CHECK_KW: check_vma}
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )
