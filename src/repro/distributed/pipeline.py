"""Pipeline parallelism (experimental, DESIGN.md Section 4): GPipe-style
microbatch pipelining over a ``pipe`` mesh axis with explicit
``collective_permute`` activation transfers, expressed under shard_map.

The default path for the assigned shapes is TP×FSDP(×EP) — at these depths
the PP bubble (S−1)/(M+S−1) loses to EP+FSDP — but PP is the right tool for
>10k-chip deployments where a single layer no longer fits a TP group, so the
schedule ships as a first-class, tested module.

Semantics: ``pipeline_apply(stage_fn, stage_params, x, mesh)`` computes
    y = stage_fn(p_{S-1}, stage_fn(p_{S-2}, … stage_fn(p_0, x)))
with stage s resident on pipe-rank s, microbatches streamed GPipe-style:
tick t has rank s working on microbatch t−s (bubble at the ends).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map


def pipeline_apply(
    stage_fn: Callable,
    stage_params,          # pytree, each leaf (S, ...) — stage-major
    x: jax.Array,          # (M, mb, D) microbatched input
    mesh: jax.sharding.Mesh,
    axis: str = "pipe",
) -> jax.Array:
    """Run the S-stage pipeline over M microbatches.  Returns (M, mb, D)."""
    s_stages = mesh.shape[axis]
    m, mb, d = x.shape
    n_ticks = m + s_stages - 1
    fwd_pairs = [(i, (i + 1) % s_stages) for i in range(s_stages)]

    def body(params_local, x_local):
        # params_local: this rank's stage params (leaves (1, ...))
        params_local = jax.tree.map(lambda a: a[0], params_local)
        rank = jax.lax.axis_index(axis)
        # every rank holds the full microbatch queue but only rank 0 injects
        # (x is replicated along `axis` by the in_spec)
        out_acc = jnp.zeros((m, mb, d), x_local.dtype)
        recv = jnp.zeros((mb, d), x_local.dtype)

        def tick(t, carry):
            recv, out_acc = carry
            # stage input: rank 0 takes microbatch t from the queue (if any),
            # others take what arrived from the left neighbor
            inject = jax.lax.dynamic_slice_in_dim(
                x_local, jnp.clip(t, 0, m - 1), 1, axis=0
            )[0]
            stage_in = jnp.where(rank == 0, inject, recv)
            stage_out = stage_fn(params_local, stage_in)
            # last rank commits microbatch (t - (S-1)) when it is valid
            mb_idx = t - (s_stages - 1)
            valid_out = (rank == s_stages - 1) & (mb_idx >= 0) & (mb_idx < m)
            out_acc = jax.lax.cond(
                valid_out,
                lambda acc: jax.lax.dynamic_update_slice_in_dim(
                    acc, stage_out[None], jnp.clip(mb_idx, 0, m - 1), axis=0
                ),
                lambda acc: acc,
                out_acc,
            )
            # ship activations rightward for the next tick
            recv = jax.lax.ppermute(stage_out, axis, fwd_pairs)
            return recv, out_acc

        recv, out_acc = jax.lax.fori_loop(0, n_ticks, tick, (recv, out_acc))
        # only the last rank's accumulator is the real output: broadcast it
        out_acc = jnp.where(rank == s_stages - 1, out_acc, 0.0)
        return jax.lax.psum(out_acc, axis)

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x)


def microbatch(x: jax.Array, n_micro: int) -> jax.Array:
    """(B, D) -> (M, B/M, D)."""
    b = x.shape[0]
    assert b % n_micro == 0
    return x.reshape(n_micro, b // n_micro, *x.shape[1:])


def pipeline_bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble: (S-1)/(M+S-1) — the quantity that makes EP+FSDP win at
    the assigned depths (DESIGN.md Section 4)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
