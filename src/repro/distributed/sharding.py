"""Logical-axis → mesh-axis resolution with divisibility fallback.

Models annotate params/batches with LOGICAL axis names ("vocab", "heads",
"embed", "batch", ...).  This module maps them onto the production mesh and
REPLICATES any dim the mesh doesn't divide evenly (e.g. arctic's 56 heads on
a 16-way model axis — the merged head*dh dim shards instead), recording every
fallback so the dry-run report can surface them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def default_rules(mesh: Mesh) -> Dict[str, Tuple[str, ...]]:
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return {
        # tensor-parallel dims
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "ffn": ("model",),
        "experts": ("model",),
        "head_dim": ("model",),   # KV-cache contraction-dim sharding
        # FSDP / ZeRO-3 dim
        "embed": dp,
        # data-parallel dims
        "batch": dp,
        "nodes": dp,
        "edges": dp,
        "triplets": dp,
        "candidates": dp,
        "stream": dp,
        # sketch rows (paper plane)
        "sketch_rows": ("model",),
        "seq": ("model",),        # sequence parallelism (long-context KV)
    }


@dataclasses.dataclass
class ResolveReport:
    fallbacks: List[str] = dataclasses.field(default_factory=list)

    def note(self, msg: str):
        self.fallbacks.append(msg)


def resolve_pspec(
    logical: Optional[Tuple],
    shape: Tuple[int, ...],
    mesh: Mesh,
    rules: Dict[str, Tuple[str, ...]],
    report: Optional[ResolveReport] = None,
    path: str = "",
) -> P:
    """One array's logical names -> PartitionSpec, replicating non-divisible
    dims."""
    if logical is None:
        return P()
    parts = []
    used_axes: set = set()
    for dim, name in zip(shape, logical):
        if name is None:
            parts.append(None)
            continue
        axes = tuple(a for a in rules.get(name, ()) if a in mesh.shape and a not in used_axes)
        if not axes:
            parts.append(None)
            continue
        total = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % total != 0:
            # try a prefix of the axes that divides
            ok = None
            for cut in range(len(axes) - 1, 0, -1):
                t = int(np.prod([mesh.shape[a] for a in axes[:cut]]))
                if dim % t == 0:
                    ok = axes[:cut]
                    break
            if ok is None:
                if report is not None:
                    report.note(
                        f"{path}: dim {dim} ({name}) % mesh{axes}={total} != 0 -> replicated"
                    )
                parts.append(None)
                continue
            if report is not None:
                report.note(f"{path}: dim {dim} ({name}) -> partial axes {ok}")
            axes = ok
        used_axes.update(axes)
        parts.append(axes if len(axes) > 1 else axes[0])
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def resolve_tree(
    logical_tree: Any,
    shape_tree: Any,
    mesh: Mesh,
    rules: Optional[Dict] = None,
    report: Optional[ResolveReport] = None,
) -> Any:
    """Pytree of logical tuples + pytree of ShapeDtypeStructs -> pytree of
    NamedShardings (aligned with shape_tree)."""
    rules = rules or default_rules(mesh)
    flat_shapes, treedef = jax.tree.flatten(
        shape_tree, is_leaf=lambda x: hasattr(x, "shape")
    )
    flat_logical = treedef.flatten_up_to(logical_tree)
    paths = [str(i) for i in range(len(flat_shapes))]
    out = [
        NamedSharding(
            mesh,
            resolve_pspec(
                lg, tuple(sh.shape), mesh, rules, report, path=p
            ),
        )
        for lg, sh, p in zip(flat_logical, flat_shapes, paths)
    ]
    return jax.tree.unflatten(treedef, out)


def like_tree(logical_leaf_fn, tree) -> Any:
    """Build a logical tree by mapping a fn over the leaves of `tree`."""
    return jax.tree.map(logical_leaf_fn, tree)


def sketch_plane_shardings(
    mesh: Mesh,
    *,
    model_axis: str = "model",
    stream_axes: Optional[Tuple[str, ...]] = None,
) -> Tuple[NamedSharding, NamedSharding]:
    """Canonical placement for the distributed sketch plane (paper §6.3):
    returns ``(counter_sharding, stream_sharding)`` — counters row-sharded
    over the model axis, the edge stream sharded over the data axes.  Used
    by ``repro.core.distributed`` callers and tests so every entry point
    places the plane identically."""
    if stream_axes is None:
        stream_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    counter_sh = NamedSharding(mesh, P(None, model_axis, None))
    stream_sh = NamedSharding(mesh, P(stream_axes))
    return counter_sh, stream_sh
