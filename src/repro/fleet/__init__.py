"""Multi-tenant fleet serving: T per-tenant gLava sketches, one device
dispatch (DESIGN.md Section 11).

    from repro.fleet import SketchFleet

    fleet = SketchFleet.open("smoke", capacity=64, seed=0)
    fleet.tenant("acme").ingest(src, dst)
    fleet.ingest_mixed(tenant_ids, src, dst)          # the fleet hot path
    res = fleet.tenant("acme").query(Query.edge("a", "b"))
"""
from repro.fleet.ingest import FleetIngestEngine, group_stream, pad_grouped
from repro.fleet.query import FleetQueryEngine
from repro.fleet.session import FleetStats, SketchFleet, TenantSession
from repro.fleet.stack import FleetSketch

__all__ = [
    "FleetIngestEngine",
    "FleetQueryEngine",
    "FleetSketch",
    "FleetStats",
    "SketchFleet",
    "TenantSession",
    "group_stream",
    "pad_grouped",
]
