"""FleetIngestEngine — the fleet's ONE donated jit boundary.

A mixed arrival stream of ``(tenant_id, src, dst, weight)`` records is
segment-grouped by resident slot on the host (a stable sort, so each
tenant's edges keep their arrival order — required for bit-identity with
per-tenant sessions), padded to a power-of-two bucket, and folded into
the whole ``(T, K, d, w_r, w_c)`` stack by a single donated jit dispatch.
The tenant axis rides in the scatter index (``FleetSketch.update``), so
T tenants cost exactly ONE compile and ONE device call per batch — the
acceptance contract asserted via ``_cache_size()`` / ``dispatches``.

Donation follows the ``GraphStream`` boundary exactly: the live pytree's
leaves are deduplicated by object identity (square configs alias
``col_hash`` to ``row_hash`` — donating the same buffer twice is an
error), the unique tuple is donated, and a scalar completion token
(``sum(weights)``) rides out for bounded-inflight backpressure.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ingest import pad_bucket
from repro.fleet.stack import FleetSketch


def group_stream(
    slots: np.ndarray,
    src: np.ndarray,
    dst: np.ndarray,
    weights: np.ndarray,
):
    """Segment-group a mixed arrival stream by tenant slot.

    Stable argsort on the slot lane: within a tenant the arrival order is
    preserved, so the grouped stream replayed through the stacked scatter
    is bit-identical to each tenant ingesting its own sub-stream.
    Returns the grouped lanes plus ``(uniq_slots, starts, counts)``
    segment descriptors for per-tenant bookkeeping."""
    order = np.argsort(slots, kind="stable")
    slots = slots[order]
    src, dst, weights = src[order], dst[order], weights[order]
    uniq, starts, counts = np.unique(slots, return_index=True, return_counts=True)
    return slots, src, dst, weights, uniq, starts, counts


def pad_grouped(slots, src, dst, weights):
    """Pad grouped lanes to a shared power-of-two bucket so the jit cache
    holds one entry per bucket, not one per batch length.  Weight padding
    is 0 — a no-op for the scatter — and padded slots point at slot 0,
    which the zero weight makes harmless."""
    return (
        jnp.asarray(pad_bucket(slots.astype(np.int32))),
        jnp.asarray(pad_bucket(src)),
        jnp.asarray(pad_bucket(dst)),
        jnp.asarray(pad_bucket(weights)),
    )


class FleetIngestEngine:
    """Owns the fleet's donated update dispatch and its counters."""

    def __init__(self, state: FleetSketch):
        leaves0, treedef = jax.tree_util.tree_flatten(state)
        seen: dict = {}
        slot_of_leaf = []
        uniq_idx: list = []
        for i, leaf in enumerate(leaves0):
            j = seen.setdefault(id(leaf), len(uniq_idx))
            if j == len(uniq_idx):
                uniq_idx.append(i)
            slot_of_leaf.append(j)
        self._treedef = treedef
        self._uniq_leaf_idx = tuple(uniq_idx)
        slot_map = tuple(slot_of_leaf)

        def _update(uniq, slots, s, d, w):
            live = jax.tree_util.tree_unflatten(
                treedef, [uniq[j] for j in slot_map]
            )
            new = live.update(slots, s, d, w)
            return jax.tree_util.tree_leaves(new), jnp.sum(w)

        self._jit_update = jax.jit(_update, donate_argnums=0)
        self.dispatches = 0

    def _cache_size(self):
        sz = getattr(self._jit_update, "_cache_size", None)
        return sz() if callable(sz) else None

    @classmethod
    def cost_probe(
        cls,
        *,
        tenants: int = 4,
        width: int = 64,
        depth: int = 2,
        batch: int = 64,
    ):
        """Costlint sizing hook: a fresh fleet's donated update boundary at
        a parameterized (T, w, d, B) — compiled across a geometric ladder
        to prove arrivals stay O(B·d) flops and O(1) in T.  Returns
        ``(jit_fn, args, counters_shape)``."""
        from repro.core.sketch import SketchConfig

        cfg = SketchConfig(depth=depth, width_rows=width, width_cols=width)
        state = FleetSketch.empty(cfg, tenants, jax.random.key(0))
        eng = cls(state)
        leaves = jax.tree_util.tree_leaves(state)
        uniq = tuple(leaves[i] for i in eng._uniq_leaf_idx)
        slots = jnp.arange(batch, dtype=jnp.int32) % tenants
        src = jnp.arange(batch, dtype=jnp.uint32)
        dst = src + jnp.uint32(batch)
        w = jnp.ones(batch, jnp.float32)
        return (
            eng._jit_update,
            (uniq, slots, src, dst, w),
            tuple(state.counters.shape),
        )

    def dispatch(
        self,
        state: FleetSketch,
        slots: jax.Array,
        src: jax.Array,
        dst: jax.Array,
        weights: jax.Array,
    ) -> Tuple[FleetSketch, jax.Array]:
        """One donated device call for one grouped+padded mixed batch.
        Returns the new fleet state and the completion token."""
        leaves = jax.tree_util.tree_leaves(state)
        uniq = tuple(leaves[i] for i in self._uniq_leaf_idx)
        new_leaves, token = self._jit_update(uniq, slots, src, dst, weights)
        self.dispatches += 1
        return jax.tree_util.tree_unflatten(self._treedef, new_leaves), token
