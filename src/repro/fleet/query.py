"""FleetQueryEngine — every query family batched across the tenant axis.

Each family function is the fleet twin of its :mod:`repro.core.queries`
estimator: queries carry a per-query ``slots`` lane alongside the key
lanes, the gather picks up the tenant as one more advanced index, and the
window axis (K slices) is summed ON THE GATHERED CELLS — O(K·d·Q) work,
never a T-wide reduction — so answers are bit-identical to running the
plain estimator on that tenant's window-summed ``GLavaSketch`` (fp32
integer addition is order-independent in the exact regime).  One jit per
family serves every tenant mix: the slot lane is data, not structure, so
permuting tenant ids across calls cannot retrace (the fleet no-retrace
contract).

Reachability keeps the per-tenant epoch-tagged closure cache, but builds
and refreshes are BATCHED: stale tenants' window-summed counter stacks go
through one ``transitive_closure`` call (already batched over leading
dims) or one vmapped ``closure_refresh``, padded to a power-of-two stack
depth so the jit cache holds a short ladder of shapes.  The cache is
keyed by SLOT, and per-tenant epochs restart at 0 for every slot
occupant — so every residency change (eviction, admission, session
close, reach-subscription cancel) must ``drop_closure(slot)`` or a
readmitted tenant could be served the previous occupant's closure at a
colliding epoch (the stale-closure fix this PR ships with a regression
test)."""
from __future__ import annotations

import collections
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reach
from repro.core.hashing import affine_hash_np
from repro.core.queries import undirected_selfloop_correction
from repro.core.query_engine import (
    CLOSURE_REFRESH_FRAC,
    CLOSURE_REFRESH_PAD_T,
    CLOSURE_STALENESS_BUDGET,
    DEFAULT_CHUNK_Q,
    DEFAULT_PAD_Q,
)
from repro.fleet.stack import FleetSketch


# ---------------------------------------------------------------------------
# Fleet family functions (slot-indexed twins of repro.core.queries)
# ---------------------------------------------------------------------------


def _window_cells(state: FleetSketch, slots, r, c):
    """(K, d, Q) counter cells at per-query (slot, row, col)."""
    k, d = state.counters.shape[1], state.counters.shape[2]
    k_idx = jnp.arange(k)[:, None, None]
    d_idx = jnp.arange(d)[None, :, None]
    return state.counters[slots[None, None, :], k_idx, d_idx, r[None], c[None]]


def fleet_edge_query(state: FleetSketch, slots, src, dst):
    """f̃_e(a, b) per (tenant, edge) query — min over d of window-summed cells."""
    r, c = state.row_hash(src), state.col_hash(dst)
    est = jnp.min(jnp.sum(_window_cells(state, slots, r, c), axis=0), axis=0)
    if not state.config.directed:
        est = undirected_selfloop_correction(est, src, dst)
    return est


def _register_gather(register, slots, h):
    """(T, K, d, w) register → (Q,) min-d of window-summed per-query gathers."""
    k, d = register.shape[1], register.shape[2]
    k_idx = jnp.arange(k)[:, None, None]
    d_idx = jnp.arange(d)[None, :, None]
    vals = register[slots[None, None, :], k_idx, d_idx, h[None]]  # (K, d, Q)
    return jnp.min(jnp.sum(vals, axis=0), axis=0)


def fleet_in_flow(state: FleetSketch, slots, keys):
    return _register_gather(state.col_flows, slots, state.col_hash(keys))


def fleet_out_flow(state: FleetSketch, slots, keys):
    return _register_gather(state.row_flows, slots, state.row_hash(keys))


def fleet_flow(state: FleetSketch, slots, keys):
    if state.config.directed:
        return fleet_in_flow(state, slots, keys) + fleet_out_flow(
            state, slots, keys
        )
    return fleet_out_flow(state, slots, keys)


def fleet_stream_totals(state: FleetSketch, slots):
    """Per-query F̃ (Q,) — min over d of the queried tenant's row-flow mass.
    Register-served, and the slot gather comes FIRST: the reduction runs on
    the (Q, K, d, w_r) gathered rows, so the cost scales with the query
    chunk, never a T-wide scan of the fleet stack."""
    return jnp.min(jnp.sum(state.row_flows[slots], axis=(1, 3)), axis=1)


def fleet_heavy_rel_vec(state: FleetSketch, slots, keys, thetas):
    """Relative-θ heavy check against the QUERY'S OWN tenant total F̃."""
    cut = thetas.astype(jnp.float32) * fleet_stream_totals(state, slots).astype(
        jnp.float32
    )
    return (
        fleet_in_flow(state, slots, keys) > cut,
        fleet_out_flow(state, slots, keys) > cut,
    )


def fleet_subgraph_batch(state: FleetSketch, slots, src, dst, mask):
    """n masked subgraph queries, each against its own tenant's window."""
    r = state.row_hash(src)  # (d, n, k)
    c = state.col_hash(dst)
    kk = state.counters.shape[1]
    k_idx = jnp.arange(kk)[:, None, None, None]
    d_idx = jnp.arange(r.shape[0])[None, :, None, None]
    cells = jnp.sum(
        state.counters[slots[None, None, :, None], k_idx, d_idx, r[None], c[None]],
        axis=0,
    )  # (d, n, k)
    live = mask[None, :, :]
    present = jnp.all(jnp.where(live, cells > 0, True), axis=2)
    wsum = jnp.sum(jnp.where(live, cells, 0.0), axis=2)
    return jnp.min(jnp.where(present, wsum, 0.0), axis=0)


def fleet_reach_pre(state: FleetSketch, closures, pos, src, dst):
    """Batched r̃(a, b) against a stacked (S, d, w, w) closure plane;
    ``pos`` maps each query to its tenant's stack position."""
    r = state.row_hash(src)
    c = state.row_hash(dst)
    d_idx = jnp.arange(r.shape[0])[:, None]
    return jnp.all(closures[pos[None, :], d_idx, r, c], axis=0)


def fleet_closure_build(counters, sel):
    """Batched full closure of the selected tenants' window-summed
    adjacencies — ``transitive_closure`` is already batched over leading
    dims, so S stale tenants cost one device call, no vmap needed."""
    return reach.transitive_closure(jnp.sum(counters[sel], axis=1))


def fleet_closure_refresh(closures, counters, sel, rows):
    """Batched incremental refresh: vmapped ``closure_refresh`` over the
    (S, d, w, w) closure stack / selected window-summed counters / per-
    tenant touched-row plans."""
    return jax.vmap(reach.closure_refresh)(
        closures, jnp.sum(counters[sel], axis=1), rows
    )


_FLEET_FAMILIES: Dict[str, Callable] = {
    "edge": fleet_edge_query,
    "in_flow": fleet_in_flow,
    "out_flow": fleet_out_flow,
    "flow": fleet_flow,
    "heavy_rel_vec": fleet_heavy_rel_vec,
    "subgraph_batch": fleet_subgraph_batch,
    "reach_pre": fleet_reach_pre,
    "closure": fleet_closure_build,
    "closure_refresh": fleet_closure_refresh,
}


def _pad_pow2(seq: List) -> List:
    """Pad a non-empty list to the next power of two by repeating its first
    element — closure stacks see a short ladder of jit shapes, and the
    repeated entry's rebuild/refresh is idempotent."""
    n = len(seq)
    m = 1 << max(0, n - 1).bit_length() if n > 1 else 1
    return list(seq) + [seq[0]] * (m - n)


class FleetQueryEngine:
    """Per-family jit caching + query padding + the slot-keyed, epoch-tagged
    batched closure cache — the QueryEngine surface, fleet-wide."""

    def __init__(
        self,
        pad_q: int = DEFAULT_PAD_Q,
        chunk_q: int = DEFAULT_CHUNK_Q,
        closure_staleness_budget: int = CLOSURE_STALENESS_BUDGET,
        closure_refresh_frac: float = CLOSURE_REFRESH_FRAC,
    ):
        self.pad_q = pad_q
        self.chunk_q = max(chunk_q, pad_q)
        self.closure_staleness_budget = closure_staleness_budget
        self.closure_refresh_frac = closure_refresh_frac
        self._jits: Dict[str, Callable] = {}
        # slot -> (closure (d, w, w) bool, epoch); per-slot staleness count.
        self._closures: Dict[int, Tuple[jax.Array, int]] = {}
        self._since_full: Dict[int, int] = {}
        self.closure_builds = 0
        self.closure_incremental_refreshes = 0
        self.dispatches: collections.Counter = collections.Counter()

    # -- jit cache -----------------------------------------------------------

    def _fn(self, family: str) -> Callable:
        fn = self._jits.get(family)
        if fn is None:
            fn = jax.jit(_FLEET_FAMILIES[family])
            self._jits[family] = fn
        return fn

    def _cache_size(self) -> int:
        """Total traced signatures across all family jits — the fleet
        no-retrace contract asserts this stays flat under tenant-id
        permutations."""
        total = 0
        for fn in self._jits.values():
            sz = getattr(fn, "_cache_size", None)
            if callable(sz):
                total += sz()
        return total

    @staticmethod
    def family_probe(
        family: str,
        *,
        tenants: int = 4,
        width: int = 64,
        depth: int = 2,
        n_queries: int = 32,
        touched: int = 2,
    ):
        """Costlint sizing hook: the fleet family estimator + args at a
        parameterized (T, w, d, Q, S) — compiled across a geometric ladder
        to prove register families are O(d·Q) with exponent ≈ 0 in T and
        closure maintenance is O(S·w²), never a T-wide scan.  ``touched``
        is S, the stale-tenant stack depth for the closure families.
        Returns ``(fn, args, counters_shape)``."""
        from repro.core.sketch import SketchConfig

        cfg = SketchConfig(depth=depth, width_rows=width, width_cols=width)
        state = FleetSketch.empty(cfg, tenants, jax.random.key(0))
        slots = jnp.arange(n_queries, dtype=jnp.int32) % tenants
        keys = jnp.arange(n_queries, dtype=jnp.uint32)
        shape = tuple(state.counters.shape)
        if family == "edge":
            args = (state, slots, keys, keys + jnp.uint32(1))
        elif family in ("in_flow", "out_flow", "flow"):
            args = (state, slots, keys)
        elif family == "heavy_rel_vec":
            thetas = jnp.full((n_queries,), 0.5, jnp.float32)
            args = (state, slots, keys, thetas)
        elif family == "closure":
            sel = jnp.arange(touched, dtype=jnp.int32) % tenants
            return fleet_closure_build, (state.counters, sel), shape
        elif family == "closure_refresh":
            sel = jnp.arange(touched, dtype=jnp.int32) % tenants
            closures = fleet_closure_build(state.counters, sel)
            rows = jnp.tile(
                state.row_hash(keys[: min(8, n_queries)])[None],
                (touched, 1, 1),
            )
            return (
                fleet_closure_refresh,
                (closures, state.counters, sel, rows),
                shape,
            )
        else:
            raise ValueError(f"no cost probe for fleet family {family!r}")
        return _FLEET_FAMILIES[family], args, shape

    # -- padding/chunking (same discipline as QueryEngine._run_padded) -------

    def _run_padded(self, family: str, head, keys, tail=()):
        self.dispatches[family] += 1
        fn = self._fn(family)
        q = keys[0].shape[0]
        outs = []
        for lo in range(0, max(q, 1), self.chunk_q):
            hi = min(q, lo + self.chunk_q)
            part = [k[lo:hi] for k in keys]
            n = hi - lo
            pad = (-n) % self.pad_q
            if pad:
                # Slot/pos lanes pad with 0 — they gather slot 0, and the
                # padded answers are sliced away below.
                part = [jnp.pad(k, (0, pad)) for k in part]
            out = fn(*head, *part, *tail)
            outs.append(
                jax.tree_util.tree_map(lambda o: o[:n], out) if pad else out
            )
        if len(outs) == 1:
            return outs[0]
        return jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *outs)

    # -- query families ------------------------------------------------------

    def edge(self, state: FleetSketch, slots, src, dst):
        return self._run_padded("edge", (state,), (slots, src, dst))

    def in_flow(self, state: FleetSketch, slots, keys):
        return self._run_padded("in_flow", (state,), (slots, keys))

    def out_flow(self, state: FleetSketch, slots, keys):
        return self._run_padded("out_flow", (state,), (slots, keys))

    def flow(self, state: FleetSketch, slots, keys):
        return self._run_padded("flow", (state,), (slots, keys))

    def heavy_rel_vec(self, state: FleetSketch, slots, keys, thetas):
        return self._run_padded(
            "heavy_rel_vec",
            (state,),
            (slots, keys, jnp.asarray(thetas, jnp.float32)),
        )

    def subgraph_batch(self, state: FleetSketch, slots, src, dst, mask):
        # Subgraph batches jit at their exact (n, k) shape — zero-padding
        # the edge axis would change absent-edge semantics (same rule as
        # QueryEngine.subgraph_batch).
        self.dispatches["subgraph_batch"] += 1
        return self._fn("subgraph_batch")(state, slots, src, dst, mask)

    # -- batched closure plane ----------------------------------------------

    def drop_closure(self, slot: int) -> None:
        """Forget one slot's closure — REQUIRED on every slot occupancy
        change (evict / admit / close / reach-subscription cancel): epochs
        restart per occupant, so a stale entry could otherwise satisfy the
        next occupant's epoch tag."""
        self._closures.pop(slot, None)
        self._since_full.pop(slot, None)

    def invalidate(self) -> None:
        self._closures.clear()
        self._since_full.clear()

    def refresh_closures(self, state: FleetSketch, items) -> None:
        """Bring many tenants' closures up to their epochs in at most one
        full-build dispatch plus one incremental-refresh dispatch.

        ``items`` is ``[(slot, delta, epoch)]`` with ``delta`` the unique
        touched-key array accumulated since the slot's cached epoch, or
        ``None`` for "unknown / not additions-only" (deletes, window
        advance, fault-in) which forces a full rebuild — the same
        escalation ladder as ``QueryEngine.refresh_closure`` (frac /
        staleness-budget fallbacks, empty-delta retag)."""
        w_r = state.config.width_rows
        build: List[Tuple[int, int]] = []
        refresh: List[Tuple[int, np.ndarray, int]] = []
        for slot, delta, epoch in items:
            cached = self._closures.get(slot)
            if cached is not None and cached[1] == epoch:
                continue
            if (
                cached is None
                or delta is None
                or self._since_full.get(slot, 0) >= self.closure_staleness_budget
            ):
                build.append((slot, epoch))
                continue
            delta = np.atleast_1d(np.asarray(delta))
            if delta.size > self.closure_refresh_frac * w_r:
                build.append((slot, epoch))
                continue
            if delta.size == 0:
                # Nothing touched: counters unchanged, only retag.
                self._closures[slot] = (cached[0], epoch)
                continue
            refresh.append((slot, delta, epoch))
        if build:
            self._build(state, build)
        if refresh:
            self._refresh(state, refresh)

    def _build(self, state: FleetSketch, items) -> None:
        sel = jnp.asarray(
            np.asarray(_pad_pow2([s for s, _ in items]), np.int32)
        )
        closures = self._fn("closure")(state.counters, sel)
        self.dispatches["closure"] += 1
        for i, (slot, epoch) in enumerate(items):
            self._closures[slot] = (closures[i], epoch)
            self._since_full[slot] = 0
            self.closure_builds += 1

    def _refresh(self, state: FleetSketch, items) -> None:
        a = np.asarray(state.row_hash.a).reshape(-1)
        b = np.asarray(state.row_hash.b).reshape(-1)
        w_r = state.config.width_rows
        t_max = max(delta.size for _, delta, _ in items)
        t_pad = t_max + (-t_max) % CLOSURE_REFRESH_PAD_T
        # Row plans on the host via the exact hash twin; padding with row 0
        # is idempotent (an untouched row restates known paths).
        rows_np = np.zeros((len(items), a.shape[0], t_pad), np.int32)
        for i, (_, delta, _) in enumerate(items):
            rows_np[i, :, : delta.size] = affine_hash_np(
                delta.astype(np.uint32, copy=False)[None, :],
                a[:, None],
                b[:, None],
                w_r,
            )
        idx = _pad_pow2(list(range(len(items))))
        slots = [items[j][0] for j in idx]
        sel = jnp.asarray(np.asarray(slots, np.int32))
        closures = jnp.stack([self._closures[s][0] for s in slots])
        rows = jnp.asarray(rows_np[np.asarray(idx)])
        out = self._fn("closure_refresh")(closures, state.counters, sel, rows)
        self.dispatches["closure_refresh"] += 1
        for i, (slot, _, epoch) in enumerate(items):
            self._closures[slot] = (out[i], epoch)
            self._since_full[slot] = self._since_full.get(slot, 0) + 1
            self.closure_incremental_refreshes += 1

    def reach(
        self,
        state: FleetSketch,
        slots,
        src,
        dst,
        epochs: Dict[int, int],
        touched: Optional[Dict[int, Optional[np.ndarray]]] = None,
    ):
        """Batched r̃(a, b) with a per-query tenant lane: ensure every
        distinct tenant's closure is at its epoch (one batched build and/or
        refresh), stack the fresh closures, and answer all queries in one
        gather dispatch."""
        slots_np = np.asarray(slots)
        uniq = np.unique(slots_np)
        self.refresh_closures(
            state,
            [
                (int(s), (touched or {}).get(int(s)), epochs[int(s)])
                for s in uniq
            ],
        )
        stack_slots = _pad_pow2([int(s) for s in uniq])
        closures = jnp.stack([self._closures[s][0] for s in stack_slots])
        pos = jnp.asarray(np.searchsorted(uniq, slots_np).astype(np.int32))
        return self._run_padded(
            "reach_pre",
            (state, closures),
            (pos, jnp.asarray(src), jnp.asarray(dst)),
        )
