"""SketchFleet — the multi-tenant session plane over the stacked engines.

One fleet serves T tenants through the ``GraphStream`` API, per tenant::

    fleet = SketchFleet.open("smoke", capacity=64, seed=0)
    fleet.tenant("acme").ingest(src, dst)
    fleet.tenant("acme").subscribe(Query.reach("a", "b"), every=4)
    res = fleet.tenant("acme").query(Query.edge("a", "b"))

    # the fleet hot path: one mixed arrival stream, ONE device dispatch
    fleet.ingest_mixed(tenant_ids, src, dst, weights)

Residency: tenants occupy *slots* in the stacked ``FleetSketch``; an LRU
of resident tenants (touched on every ``tenant()`` access) evicts the
coldest tenant to a host-side checkpoint shard (one
``CheckpointManager`` directory per tenant, ``keep=1``) when a new
tenant needs a slot, and faults it back in on next touch.  Host-side
session state — epoch, stats, standing subscriptions, touched-key
deltas — lives in the persistent :class:`TenantSession` object, so
subscriptions survive eviction.  Every slot occupancy change drops the
slot's cached closure (see ``FleetQueryEngine.drop_closure``).

Bit-identity: a fleet opened with seed s gives every tenant the same
hash family as ``GraphStream(config, seed=s)``, ingest preserves
per-tenant arrival order (stable segment grouping), and queries gather
per tenant — so each tenant is bit-identical to an independent session
fed its sub-stream (property-tested across every query family).
"""
from __future__ import annotations

import collections
import dataclasses
import json
import shutil
import time
from pathlib import Path
from typing import Callable, Dict, Iterator, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.codec import encode_labels
from repro.api.planner import execute
from repro.api.query import Query, QueryBatch, QueryResult, validate_theta
from repro.api.stream import (
    EVENT_LOG_MAXLEN,
    IngestReceipt,
    RecoveryReport,
    StreamStats,
    _preset,
)
from repro.api.subscription import (
    DEFAULT_MAX_PENDING,
    Subscription,
    SubscriptionEvent,
    sub_progress_key,
)
from repro.checkpoint.manager import CheckpointManager
from repro.core.hashing import fnv1a_label
from repro.core.ingest import touched_row_keys
from repro.core.sketch import GLavaSketch, SketchConfig
from repro.fleet.ingest import FleetIngestEngine, group_stream, pad_grouped
from repro.fleet.query import FleetQueryEngine
from repro.fleet.stack import FleetSketch
from repro.stream.events import EventFeed
from repro.stream.wal import (
    AdvanceMutation,
    EdgeMutation,
    MergeMutation,
    WriteAheadLog,
)


def _tenant_dirname(tenant_id) -> str:
    """Filesystem-safe, collision-safe directory name for one tenant:
    a sanitized prefix of the id for operators plus its FNV-1a hash so
    distinct ids that sanitize alike never share a shard/WAL directory."""
    safe = "".join(
        ch if ch.isalnum() or ch in "._-" else "_"
        for ch in str(tenant_id)[:40]
    )
    return f"{safe}-{fnv1a_label(tenant_id):08x}"


@dataclasses.dataclass
class FleetStats:
    """Fleet-wide counters (per-tenant counters live on each session)."""

    edges_ingested: int = 0
    batches: int = 0
    ingest_s: float = 0.0
    subscription_ticks: int = 0
    evictions: int = 0
    fault_ins: int = 0

    def summary(self) -> Dict[str, float]:
        return {
            "edges_ingested": self.edges_ingested,
            "batches": self.batches,
            "ingest_edges_per_s": self.edges_ingested / max(self.ingest_s, 1e-9),
            "subscription_ticks": self.subscription_ticks,
            "evictions": self.evictions,
            "fault_ins": self.fault_ins,
        }


class _TenantEngineView:
    """A ``QueryEngine``-shaped adapter for one tenant: prepends the
    tenant's slot lane to every fleet engine dispatch, so the planner's
    :class:`~repro.api.planner.CompiledPlan` (and therefore subscriptions)
    runs against the fleet unchanged."""

    def __init__(self, session: "TenantSession"):
        self._session = session

    def _slots(self, n: int) -> jax.Array:
        return jnp.full((int(n),), self._session._slot, jnp.int32)

    def _engine(self) -> FleetQueryEngine:
        return self._session._fleet.engine

    def edge(self, state, src, dst):
        return self._engine().edge(state, self._slots(src.shape[0]), src, dst)

    def in_flow(self, state, keys):
        return self._engine().in_flow(state, self._slots(keys.shape[0]), keys)

    def out_flow(self, state, keys):
        return self._engine().out_flow(state, self._slots(keys.shape[0]), keys)

    def flow(self, state, keys):
        return self._engine().flow(state, self._slots(keys.shape[0]), keys)

    def heavy_rel_vec(self, state, keys, thetas):
        return self._engine().heavy_rel_vec(
            state, self._slots(keys.shape[0]), keys, thetas
        )

    def subgraph_batch(self, state, src, dst, mask):
        return self._engine().subgraph_batch(
            state, self._slots(src.shape[0]), src, dst, mask
        )

    def reach(self, state, src, dst, epoch=None):
        sess = self._session
        slots = np.full(int(src.shape[0]), sess._slot, np.int32)
        return self._engine().reach(
            state,
            slots,
            src,
            dst,
            epochs={sess._slot: sess._epoch if epoch is None else epoch},
        )


class TenantSession:
    """One tenant's ``GraphStream``-shaped handle into the fleet.

    The session object is persistent across evictions: device state moves
    between its fleet slot and a host checkpoint shard, while epoch,
    stats, subscriptions, and the touched-key delta stay here."""

    def __init__(self, fleet: "SketchFleet", tenant_id):
        self._fleet = fleet
        self.tenant_id = tenant_id
        self._slot: Optional[int] = None
        self._shard_step: Optional[int] = None
        self._epoch = 0
        self._subs: Dict[int, Subscription] = {}
        self._next_sub_id = 0
        self._event_log = EventFeed(EVENT_LOG_MAXLEN, fleet._events_policy)
        self._touched: Optional[list] = []
        self._touched_count = 0
        self._closed = False
        self.stats = StreamStats()
        self._view = _TenantEngineView(self)

    # -- state ----------------------------------------------------------------

    @property
    def config(self) -> SketchConfig:
        return self._fleet.config

    @property
    def epoch(self) -> int:
        return self._epoch

    @property
    def resident(self) -> bool:
        return self._slot is not None

    @property
    def sketch(self) -> GLavaSketch:
        """This tenant's window-summed summary as a plain ``GLavaSketch``."""
        self._touch()
        self._fleet.flush()
        return self._fleet._state.tenant_sketch(self._slot)

    def _touch(self) -> "TenantSession":
        self._check_open()
        self._fleet.tenant(self.tenant_id)
        return self

    def _check_open(self) -> None:
        if self._closed:
            raise ValueError(
                f"tenant session {self.tenant_id!r} is closed"
            )

    # -- ingest ---------------------------------------------------------------

    def ingest(self, src, dst, weights=None, *, timestamps=None) -> IngestReceipt:
        """Fold one edge batch into THIS tenant's summary — delegates to
        the fleet's mixed-stream hot path with a constant tenant lane."""
        receipts = self._fleet.ingest_mixed(
            self.tenant_id, src, dst, weights, timestamps=timestamps
        )
        return receipts[self.tenant_id]

    def delete(self, src, dst, weights=None, *, timestamps=None) -> IngestReceipt:
        """Turnstile deletion (negative-weight ingest) for this tenant."""
        if weights is None:
            weights = np.ones(
                len(np.atleast_1d(np.asarray(src))), np.float32
            )
        return self.ingest(
            src, dst, -np.asarray(weights), timestamps=timestamps
        )

    def flush(self) -> None:
        self._fleet.flush()

    def advance_window(self) -> None:
        """Advance THIS tenant's sliding window (no-op for non-windowed
        fleets).  A mutation for this tenant's subscriptions; expiry is not
        additions-only, so the slot's next closure use rebuilds."""
        if self._fleet._window_slices <= 1:
            return
        self._touch()
        fleet = self._fleet
        if fleet._wal_dir is not None and not fleet._replaying:
            fleet._wal_lane(self.tenant_id).append_advance()
        fleet.flush()
        fleet._state = fleet._state.advance(self._slot)
        self._epoch += 1
        self._note_touched(None)
        fleet._tick_subscriptions([self])

    # -- queries --------------------------------------------------------------

    def query(self, *queries) -> Union[QueryResult, List[QueryResult]]:
        """Answer queries against this tenant's live summary — same planner
        and semantics as ``GraphStream.query``, dispatched fleet-wide."""
        single = len(queries) == 1 and isinstance(queries[0], Query)
        if len(queries) == 1 and isinstance(queries[0], QueryBatch):
            batch = queries[0]
        else:
            batch = QueryBatch(queries)
        if len(batch) == 0:
            return []
        self._touch()
        fleet = self._fleet
        fleet.flush()
        t0 = time.time()
        if any(q.family == "reach" for q in batch):
            fleet.engine.refresh_closures(
                fleet._state,
                [(self._slot, self._consume_touched(), self._epoch)],
            )
        results = execute(self._view, fleet._state, batch, epoch=self._epoch)
        self.stats.query_s += time.time() - t0
        self._count_served(results)
        return results[0] if single else results

    # convenience wrappers (the serving engine's per-family endpoints)
    def edge_frequency(self, src, dst) -> np.ndarray:
        return np.atleast_1d(self.query(Query.edge(src, dst)).value)

    def in_flow(self, keys) -> np.ndarray:
        return np.atleast_1d(self.query(Query.in_flow(keys)).value)

    def out_flow(self, keys) -> np.ndarray:
        return np.atleast_1d(self.query(Query.out_flow(keys)).value)

    def heavy_hitters(self, keys, theta: float) -> np.ndarray:
        in_heavy, _ = self.query(Query.heavy(keys, theta)).value
        return np.atleast_1d(in_heavy)

    def reachable(self, src, dst) -> np.ndarray:
        return np.atleast_1d(self.query(Query.reach(src, dst)).value)

    def subgraph_weight(self, src, dst) -> float:
        return float(self.query(Query.subgraph(src, dst)).value)

    # -- standing queries ------------------------------------------------------

    def subscribe(
        self,
        *queries,
        every: int = 1,
        on_result: Optional[Callable[[SubscriptionEvent], None]] = None,
        alarm: Optional[Callable[[List[QueryResult]], bool]] = None,
        name: Optional[str] = None,
        max_pending: int = DEFAULT_MAX_PENDING,
    ) -> Subscription:
        """Register a standing query batch on THIS tenant — compiled once,
        re-evaluated after every ``every``-th of this tenant's mutations
        (fleet mutations to other tenants do not tick it)."""
        self._check_open()
        if len(queries) == 1 and isinstance(queries[0], QueryBatch):
            batch = queries[0]
        else:
            batch = QueryBatch(queries)
        for q in batch:
            if q.family == "heavy":
                validate_theta(q.theta)
        sub = Subscription(
            self,
            self._next_sub_id,
            batch,
            every=every,
            on_result=on_result,
            alarm=alarm,
            name=name,
            max_pending=max_pending,
        )
        self._next_sub_id += 1
        self._subs[sub.id] = sub
        return sub

    @property
    def subscriptions(self) -> Tuple[Subscription, ...]:
        return tuple(self._subs.values())

    def events(self) -> Iterator[SubscriptionEvent]:
        """Drain this tenant's event feed (non-blocking)."""
        while self._event_log:
            yield self._event_log.popleft()

    @property
    def events_dropped(self) -> int:
        """Events lost from this tenant's feed to queue overflow."""
        return self._event_log.dropped

    def _unsubscribe(self, sub: Subscription) -> None:
        self._subs.pop(sub.id, None)
        if sub.plan.has_reach and self._slot is not None:
            # The cancelled plan may be the only consumer of this slot's
            # cached closure; per-tenant epochs restart per slot occupant,
            # so a surviving entry could serve a LATER occupant whose epoch
            # collides.  Drop it now (the stale-closure fix).
            self._fleet.engine.drop_closure(self._slot)

    # -- touched-key tracking (mirrors GraphStream) ---------------------------

    def _note_touched(self, batch_delta) -> None:
        if self._touched is None:
            return
        if batch_delta is None:
            self._touched = None
            self._touched_count = 0
            return
        self._touched.append(batch_delta)
        self._touched_count += int(batch_delta.size)
        if self._touched_count > self.config.width_rows:
            self._touched = None
            self._touched_count = 0

    def _consume_touched(self) -> Optional[np.ndarray]:
        """The unique touched-key delta accumulated since the last closure
        sync (``None`` = unknown / not additions-only); resets tracking."""
        if self._touched is None:
            delta = None
        elif not self._touched:
            delta = np.zeros(0, np.uint32)
        else:
            delta = np.unique(np.concatenate(self._touched)).astype(np.uint32)
        self._touched = []
        self._touched_count = 0
        return delta

    def _count_served(self, results) -> None:
        for r in results:
            v = r.value
            self.stats.queries_served += (
                int(np.size(v[0])) if isinstance(v, tuple) else int(np.size(v))
            )

    # -- lifecycle ------------------------------------------------------------

    def close(self) -> None:
        """Cancel subscriptions, release the slot, and forget the session.
        Idempotent; the tenant id can be re-opened as a fresh tenant."""
        if self._closed:
            return
        for sub in list(self._subs.values()):
            sub.cancel()
        fleet = self._fleet
        if fleet._wal_dir is not None:
            # Forgetting the tenant forgets its durable log too — a kept
            # lane would resurrect this tenant (or pollute a fresh one
            # under the same id) on the next recover().
            lane = fleet._wal_lanes.pop(self.tenant_id, None)
            if lane is not None:
                lane.close()
            if isinstance(self.tenant_id, (str, int, np.integer)):
                shutil.rmtree(
                    Path(fleet._wal_dir) / _tenant_dirname(self.tenant_id),
                    ignore_errors=True,
                )
        if self._slot is not None:
            fleet.flush()
            fleet.engine.drop_closure(self._slot)
            fleet._state = fleet._state.clear_tenant(self._slot)
            fleet._free.append(self._slot)
            fleet._resident.pop(self.tenant_id, None)
            self._slot = None
        fleet._sessions.pop(self.tenant_id, None)
        self._closed = True

    def summary(self) -> Dict[str, float]:
        self._fleet.flush()
        return self.stats.summary()


class SketchFleet:
    """T tenant sessions behind one stacked device state + one engine pair."""

    def __init__(
        self,
        config: SketchConfig,
        *,
        capacity: int = 8,
        seed: int = 0,
        window_slices: Optional[int] = None,
        checkpoint_dir: Optional[str] = None,
        max_inflight: int = 2,
        pad_q: Optional[int] = None,
        wal_dir: Optional[str] = None,
        wal_fsync_every: int = 1,
        events_policy: str = "drop_oldest",
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if window_slices is not None and window_slices < 2:
            raise ValueError("window_slices must be >= 2 (or None)")
        self.config = config
        self.capacity = capacity
        self.seed = seed
        self._window_slices = window_slices or 1
        self._state = FleetSketch.empty(
            config, capacity, jax.random.key(seed), self._window_slices
        )
        self._ingest = FleetIngestEngine(self._state)
        self.engine = (
            FleetQueryEngine() if pad_q is None else FleetQueryEngine(pad_q=pad_q)
        )
        self._sessions: Dict = {}
        self._resident: "collections.OrderedDict" = collections.OrderedDict()
        self._free: List[int] = list(range(capacity - 1, -1, -1))
        self._ckpt_dir = checkpoint_dir
        self._max_inflight = max_inflight
        self._inflight: collections.deque = collections.deque()
        self._events_policy = events_policy
        self._event_log = EventFeed(EVENT_LOG_MAXLEN, events_policy)
        self._wal_dir = wal_dir
        self._wal_fsync_every = int(wal_fsync_every)
        self._wal_lanes: Dict = {}
        self._replaying = False
        self.stats = FleetStats()

    @classmethod
    def open(
        cls,
        config: Union[SketchConfig, str, None] = None,
        *,
        epsilon: Optional[float] = None,
        delta: Optional[float] = None,
        **kwargs,
    ) -> "SketchFleet":
        """Open a fleet from a :class:`SketchConfig`, a preset name, or a
        target (ε, δ) pair — the same resolution as ``GraphStream.open``."""
        if isinstance(config, str):
            config = _preset(config)
        elif config is None:
            if epsilon is None or delta is None:
                raise ValueError(
                    "open() needs a config, a preset, or (epsilon, delta)"
                )
            config = SketchConfig.for_error(epsilon, delta)
        elif not isinstance(config, SketchConfig):
            raise TypeError(
                f"config must be SketchConfig or preset name, got {config!r}"
            )
        return cls(config, **kwargs)

    # -- residency / LRU -------------------------------------------------------

    def tenant(self, tenant_id) -> TenantSession:
        """This tenant's session — created on first touch, admitted to a
        slot (possibly evicting the coldest resident), LRU-bumped on every
        access."""
        sess = self._sessions.get(tenant_id)
        if sess is None:
            sess = TenantSession(self, tenant_id)
            self._sessions[tenant_id] = sess
        if sess._slot is None:
            self._admit(sess)
        else:
            self._resident.move_to_end(tenant_id)
        return sess

    @property
    def tenants(self) -> Tuple:
        """All known tenant ids (resident or evicted)."""
        return tuple(self._sessions)

    @property
    def resident_tenants(self) -> Tuple:
        """Resident tenant ids, coldest first."""
        return tuple(self._resident)

    def events(self) -> Iterator[SubscriptionEvent]:
        """Drain the fleet-wide event feed (all tenants, emission order)."""
        while self._event_log:
            yield self._event_log.popleft()

    @property
    def events_dropped(self) -> int:
        """Events lost from the fleet-wide feed to queue overflow."""
        return self._event_log.dropped

    def _admit(self, sess: TenantSession) -> None:
        slot = self._free.pop() if self._free else self._evict_coldest()
        sess._slot = slot
        self._resident[sess.tenant_id] = sess
        # Occupancy change: never let this occupant see a predecessor's
        # closure at a colliding epoch.
        self.engine.drop_closure(slot)
        if sess._shard_step is not None:
            self._restore_shard(sess)
            self.stats.fault_ins += 1

    def _evict_coldest(self) -> int:
        if self._ckpt_dir is None:
            raise ValueError(
                f"fleet is at capacity ({self.capacity} resident tenants); "
                "open the fleet with checkpoint_dir= to evict cold tenants "
                "to host shards"
            )
        tenant_id, sess = next(iter(self._resident.items()))
        self.flush()
        mgr = self._shard_manager(tenant_id)
        meta = {
            "epoch": sess._epoch,
            "edges_ingested": sess.stats.edges_ingested,
        }
        lane = None
        if self._wal_dir is not None:
            # Valid even mid-recovery: an evictable tenant has fully
            # replayed, so its state reflects everything in its lane.
            lane = self._wal_lane(tenant_id)
            lane.sync()
            meta["wal_seq"] = lane.last_seq
        if sess._subs:
            meta["subs"] = {
                sub_progress_key(sub): {
                    "ticks": sub.ticks,
                    "pending": sub._mutations_pending,
                }
                for sub in sess._subs.values()
                if sub.active
            }
        mgr.save(sess._epoch, self._state.tenant_shard(sess._slot), metadata=meta)
        sess._shard_step = sess._epoch
        if lane is not None:
            # The shard is durable: records at or below its wal_seq are
            # covered, so rotate and drop fully-covered segments (keep=1 —
            # this shard is the only restore point).
            lane.rotate()
            lane.gc(int(meta["wal_seq"]))
        slot = sess._slot
        self._state = self._state.clear_tenant(slot)
        self.engine.drop_closure(slot)
        sess._slot = None
        # The accumulated delta describes a closure that no longer exists;
        # fault-in restarts from "unknown" so the next reach rebuilds.
        sess._touched = None
        sess._touched_count = 0
        del self._resident[tenant_id]
        self.stats.evictions += 1
        return slot

    def _restore_shard(self, sess: TenantSession) -> None:
        mgr = self._shard_manager(sess.tenant_id)
        st = self._state
        like = {
            "counters": jnp.zeros(st.counters.shape[1:], jnp.float32),
            "row_flows": jnp.zeros(st.row_flows.shape[1:], jnp.float32),
            "col_flows": jnp.zeros(st.col_flows.shape[1:], jnp.float32),
            "cursor": jnp.zeros((), jnp.int32),
        }
        shard, meta = mgr.restore(sess._shard_step, like=like)
        self._state = self._state.load_tenant(sess._slot, shard)
        sess._epoch = int(meta.get("epoch", meta["step"]))

    def _shard_manager(self, tenant_id) -> CheckpointManager:
        return CheckpointManager(
            Path(self._ckpt_dir) / "tenants" / _tenant_dirname(tenant_id),
            keep=1,
        )

    # -- per-tenant WAL lanes --------------------------------------------------

    def _wal_lane(self, tenant_id) -> WriteAheadLog:
        """This tenant's write-ahead-log lane (opened lazily).  Lane
        directories are keyed by the same collision-safe name as eviction
        shards; ``tenant.json`` records the original id so
        :meth:`recover` can re-open sessions from disk alone."""
        lane = self._wal_lanes.get(tenant_id)
        if lane is None:
            if not isinstance(tenant_id, (str, int, np.integer)):
                raise TypeError(
                    "WAL lanes need str/int tenant ids (stored in "
                    f"tenant.json for recovery), got {type(tenant_id).__name__}"
                )
            lane_dir = Path(self._wal_dir) / _tenant_dirname(tenant_id)
            lane = WriteAheadLog(lane_dir, fsync_every=self._wal_fsync_every)
            ident = lane_dir / "tenant.json"
            if not ident.exists():
                ident.write_text(json.dumps({"tenant_id": tenant_id}))
            self._wal_lanes[tenant_id] = lane
        return lane

    def _wal_append(self, sess, s_np, d_np, w_np, ts_np) -> Optional[int]:
        """Durably log one tenant's slice of an arrival batch BEFORE its
        device dispatch; returns the commit seq (None when WAL is off or
        this ingest is itself a replay)."""
        if self._wal_dir is None or self._replaying:
            return None
        return self._wal_lane(sess.tenant_id).append_edges(
            s_np, d_np, w_np, timestamps=ts_np
        )

    def recover(self) -> Dict:
        """Crash recovery for a freshly opened fleet (requires ``wal_dir``):
        for every WAL lane on disk, re-open its tenant (``tenant.json``
        names the id), fault in the newest eviction shard if one exists,
        and replay the lane's suffix — records past the shard's durable
        ``wal_seq`` — through the normal mixed-ingest path.

        Re-register standing subscriptions BEFORE calling this (matched by
        name, or registration order for anonymous ones) and ``seek()`` each
        to its last consumed tick so the replayed event stream deduplicates
        exactly-once.  Returns ``{tenant_id: RecoveryReport}``."""
        if self._wal_dir is None:
            raise ValueError("recover() requires wal_dir=")
        root = Path(self._wal_dir)
        reports: Dict = {}
        lane_dirs = sorted(root.iterdir()) if root.exists() else []
        for lane_dir in lane_dirs:
            ident = lane_dir / "tenant.json"
            if not ident.exists():
                continue
            tenant_id = json.loads(ident.read_text())["tenant_id"]
            after_seq = 0
            step = None
            shard_meta: Dict = {}
            if self._ckpt_dir is not None:
                mgr = self._shard_manager(tenant_id)
                step = mgr.latest_step()
                if step is not None:
                    shard_meta = mgr.read_metadata(step)
                    after_seq = int(shard_meta.get("wal_seq", 0))
                    sess = self._sessions.get(tenant_id)
                    if sess is None:
                        sess = TenantSession(self, tenant_id)
                        self._sessions[tenant_id] = sess
                    if sess._slot is None:
                        # Fault the shard in through the normal admission
                        # path instead of replaying from genesis.
                        sess._shard_step = step
            sess = self.tenant(tenant_id)
            subs_meta = shard_meta.get("subs") or {}
            for sub in sess._subs.values():
                m = subs_meta.get(sub_progress_key(sub))
                if m is not None:
                    sub.ticks = int(m["ticks"])
                    sub._mutations_pending = int(m["pending"])
            lane = self._wal_lane(tenant_id)
            replayed = 0
            self._replaying = True
            try:
                for mut in lane.replay(after_seq=after_seq):
                    if isinstance(mut, EdgeMutation):
                        self.ingest_mixed(
                            tenant_id,
                            mut.src,
                            mut.dst,
                            mut.weights,
                            timestamps=mut.timestamps,
                        )
                    elif isinstance(mut, AdvanceMutation):
                        sess.advance_window()
                    elif isinstance(mut, MergeMutation):
                        raise RuntimeError(
                            "WAL contains a merge barrier past the last "
                            "eviction shard — merged state cannot be "
                            "replayed from edge records; evict or "
                            "checkpoint tenants immediately after merging"
                        )
                    replayed += 1
            finally:
                self._replaying = False
            reports[tenant_id] = RecoveryReport(
                step=step,
                mutations_replayed=replayed,
                epoch=sess._epoch,
                wal_seq=lane.last_seq,
            )
        self.flush()
        return reports

    # -- the fleet hot path ----------------------------------------------------

    def ingest_mixed(
        self, tenant_ids, src, dst, weights=None, *, timestamps=None
    ) -> Dict:
        """Fold one MIXED arrival stream — ``(tenant_id, src, dst, weight)``
        records — into the whole fleet in ONE donated device dispatch.

        ``tenant_ids`` is a single id (the whole batch is that tenant's) or
        a per-edge sequence.  The stream is segment-grouped by resident
        slot on the host (stable — per-tenant arrival order is preserved),
        padded to a power-of-two bucket, and scattered into the stack.
        A batch spanning more distinct tenants than the fleet has slots is
        split into capacity-sized tenant groups, one dispatch per group, so
        LRU admission can never evict a tenant an in-flight group still
        routes to.  Returns ``{tenant_id: IngestReceipt}``.

        ``timestamps`` (optional per-edge event times) are recorded in
        each tenant's WAL lane — the fleet plane does not window by event
        time, but replay hands them back so a later fault-in can."""
        t0 = time.time()
        s_np = np.atleast_1d(encode_labels(src))
        d_np = np.atleast_1d(encode_labels(dst))
        if s_np.shape != d_np.shape:
            raise ValueError(
                f"src/dst shape mismatch: {s_np.shape} vs {d_np.shape}"
            )
        n_edges = int(s_np.shape[0])
        w_np = (
            np.ones(n_edges, np.float32)
            if weights is None
            else np.atleast_1d(np.asarray(weights, np.float32))
        )
        if w_np.shape != (n_edges,):
            raise ValueError(
                f"weights/src shape mismatch: {w_np.shape} vs {(n_edges,)}"
            )
        ts_np = None
        if timestamps is not None:
            ts_np = np.atleast_1d(np.asarray(timestamps, np.float64))
            if ts_np.shape != (n_edges,):
                raise ValueError(
                    f"timestamps/src shape mismatch: {ts_np.shape} vs "
                    f"{(n_edges,)}"
                )
            if not np.all(np.isfinite(ts_np)):
                raise ValueError("timestamps must be finite")
        additive = weights is None or not bool(np.any(w_np < 0))

        if isinstance(tenant_ids, (str, bytes, int, np.integer)):
            sess = self.tenant(tenant_ids)
            wal_seqs = {id(sess): self._wal_append(sess, s_np, d_np, w_np, ts_np)}
            slot_np = np.full(n_edges, sess._slot, np.int32)
            return self._dispatch_group(
                [(sess, 0, n_edges)], slot_np, s_np, d_np, w_np, additive,
                t0, wal_seqs,
            )
        ids = np.asarray(tenant_ids)
        if ids.shape[0] != n_edges:
            raise ValueError(
                f"tenant_ids/src shape mismatch: {ids.shape[0]} vs {n_edges}"
            )
        uniq_ids, inverse = np.unique(ids, return_inverse=True)
        if uniq_ids.shape[0] <= self.capacity:
            return self._route_group(
                uniq_ids, inverse, s_np, d_np, w_np, ts_np, additive, t0
            )
        # More distinct tenants than slots: admitted one at a time, this
        # batch's own tenants would evict each other before the slot lane
        # is built.  Split into groups of at most `capacity` tenants —
        # each group is fully admitted, routed, and dispatched before the
        # next group's admissions may evict it.
        receipts: Dict = {}
        for lo in range(0, uniq_ids.shape[0], self.capacity):
            hi = min(lo + self.capacity, uniq_ids.shape[0])
            pick = (inverse >= lo) & (inverse < hi)
            receipts.update(
                self._route_group(
                    uniq_ids[lo:hi],
                    inverse[pick] - lo,
                    s_np[pick],
                    d_np[pick],
                    w_np[pick],
                    None if ts_np is None else ts_np[pick],
                    additive,
                    time.time(),
                )
            )
        return receipts

    def _route_group(
        self, uniq_ids, inverse, s_np, d_np, w_np, ts_np, additive, t0
    ) -> Dict:
        """Admit one group of at most ``capacity`` distinct tenants and
        dispatch its edges.  The cap guarantees the admission loop cannot
        evict a group member once touched (every touch rewarms the LRU and
        at most ``capacity - k`` evictions remain after the k-th touch), so
        every edge routes to a live slot."""
        sessions = [self.tenant(t) for t in uniq_ids.tolist()]
        # Log each tenant's slice in arrival order BEFORE the dispatch (and
        # before grouping permutes the arrays) — the WAL is the authority
        # on what the device state is allowed to contain.
        wal_seqs: Dict[int, Optional[int]] = {}
        for k, sess in enumerate(sessions):
            mask = inverse == k
            wal_seqs[id(sess)] = self._wal_append(
                sess,
                s_np[mask],
                d_np[mask],
                w_np[mask],
                None if ts_np is None else ts_np[mask],
            )
        slot_np = np.asarray(
            [s._slot for s in sessions], np.int32
        )[inverse]
        slot_np, s_np, d_np, w_np, uniq_slots, starts, counts = group_stream(
            slot_np, s_np, d_np, w_np
        )
        by_slot = {s._slot: s for s in sessions}
        segments = [
            (by_slot[int(sl)], int(st), int(ct))
            for sl, st, ct in zip(uniq_slots, starts, counts)
        ]
        return self._dispatch_group(
            segments, slot_np, s_np, d_np, w_np, additive, t0, wal_seqs
        )

    def _dispatch_group(
        self, segments, slot_np, s_np, d_np, w_np, additive, t0, wal_seqs=None
    ) -> Dict:
        """One grouped, padded, donated device dispatch + its bookkeeping
        (touched-key deltas, receipts, stats, subscription ticks)."""
        n_edges = int(s_np.shape[0])
        wal_seqs = wal_seqs or {}
        # Per-tenant touched-key deltas (feeds each tenant's incremental
        # closure refresh) — only while that tenant's tracking is live.
        deltas: Dict[int, Optional[np.ndarray]] = {}
        for sess, st, ct in segments:
            if not additive:
                sess._note_touched(None)
            elif sess._touched is not None:
                delta = touched_row_keys(
                    s_np[st : st + ct],
                    None if self.config.directed else d_np[st : st + ct],
                    cap=self.config.width_rows,
                )
                deltas[id(sess)] = delta
                sess._note_touched(delta)

        slots_j, s_j, d_j, w_j = pad_grouped(slot_np, s_np, d_np, w_np)
        self._state, token = self._ingest.dispatch(
            self._state, slots_j, s_j, d_j, w_j
        )
        self._inflight.append(token)
        while len(self._inflight) > self._max_inflight:
            jax.block_until_ready(self._inflight.popleft())

        dt = time.time() - t0
        receipts: Dict = {}
        for sess, st, ct in segments:
            sess._epoch += 1
            sess.stats.edges_ingested += ct
            sess.stats.ingest_s += dt / len(segments)
            receipts[sess.tenant_id] = IngestReceipt(
                epoch=sess._epoch,
                n_edges=ct,
                touched_keys=deltas.get(id(sess)) if additive else None,
                wal_seq=wal_seqs.get(id(sess)),
            )
        self.stats.edges_ingested += n_edges
        self.stats.batches += 1
        self.stats.ingest_s += dt
        self._tick_subscriptions([sess for sess, _, _ in segments])
        return receipts

    def flush(self) -> None:
        """Block until every dispatched fleet batch has landed on device."""
        if not self._inflight:
            return
        t0 = time.time()
        while self._inflight:
            jax.block_until_ready(self._inflight.popleft())
        self.stats.ingest_s += time.time() - t0

    # -- subscription ticking --------------------------------------------------

    def _tick_subscriptions(self, sessions: List[TenantSession]) -> None:
        """Re-evaluate every standing query that came due across the
        mutated tenants: reach-bearing plans share ONE batched closure
        sync, then each plan replays its compiled dispatches."""
        due: List[Tuple[TenantSession, Subscription]] = []
        for sess in sessions:
            for sub in list(sess._subs.values()):
                if sub.active and sub._note_mutation():
                    due.append((sess, sub))
        if not due:
            return
        self.flush()
        t0 = time.time()
        reach_sessions: Dict[int, TenantSession] = {}
        for sess, sub in due:
            if sub.plan.has_reach:
                reach_sessions.setdefault(id(sess), sess)
        if reach_sessions:
            self.engine.refresh_closures(
                self._state,
                [
                    (sess._slot, sess._consume_touched(), sess._epoch)
                    for sess in reach_sessions.values()
                ],
            )
        # The shared closure sync is charged evenly; each subscription then
        # pays for its own replay only (per-iteration clock, so a late
        # subscription never re-counts an earlier one's elapsed time).
        sync_s = (time.time() - t0) / len(due)
        now = time.time()
        for sess, sub in due:
            t1 = time.time()
            results = sub.plan.run(sess._view, self._state, epoch=sess._epoch)
            event = SubscriptionEvent(
                subscription_id=sub.id,
                name=sub.name,
                tick=sub.ticks + 1,
                epoch=sess._epoch,
                timestamp=now,
                results=tuple(results),
                alarm=None if sub.alarm is None else bool(sub.alarm(results)),
            )
            if sub._deliver(event):
                sess._event_log.push(event)
                self._event_log.push(event)
            sess.stats.subscription_ticks += 1
            self.stats.subscription_ticks += 1
            sess._count_served(results)
            sess.stats.query_s += sync_s + (time.time() - t1)

    # -- introspection ---------------------------------------------------------

    def summary(self) -> Dict[str, float]:
        self.flush()
        out = self.stats.summary()
        out.update(
            tenants=len(self._sessions),
            resident=len(self._resident),
            capacity=self.capacity,
            events_dropped=self._event_log.dropped,
            ingest_dispatches=self._ingest.dispatches,
            closure_builds=self.engine.closure_builds,
            closure_incremental_refreshes=(
                self.engine.closure_incremental_refreshes
            ),
        )
        return out
