"""FleetSketch — T tenant gLava sketches stacked into one dense tensor.

One fleet holds ``capacity`` tenant *slots*, each a full sliding-window
gLava sketch, laid out as ``(T, K, d, w_r, w_c)`` counters plus the
matching stacked flow registers and a per-tenant window cursor.  All
slots share ONE hash family (seeded exactly like ``GLavaSketch.empty``,
so a fleet tenant is bit-identical to an independent ``GraphStream``
opened with the same seed) — sharing the family is what makes the stack
vmappable/scatterable as a single dense operand and what lets closure
planes be built for many tenants in one batched ``transitive_closure``
call.

``K`` is the sliding-window ring depth; non-windowed fleets use ``K=1``
so the ingest scatter, eviction shards, and query gathers have ONE
uniform code path.  Per-slot views (``tenant_sketch``) sum the window
axis, mirroring ``SlidingWindowSketch.window_sketch()``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.hashing import HashFamily, make_hash_family
from repro.core.sketch import GLavaSketch, SketchConfig, scatter_stacked


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FleetSketch:
    """The fleet's device state: every resident tenant's sketch, stacked."""

    counters: jax.Array   # (T, K, d, w_r, w_c) float32
    row_flows: jax.Array  # (T, K, d, w_r)
    col_flows: jax.Array  # (T, K, d, w_c)
    cursor: jax.Array     # (T,) int32 — active window slice per tenant
    row_hash: HashFamily  # shared across all slots
    col_hash: HashFamily  # IS row_hash for square configs (one leaf)
    config: SketchConfig = dataclasses.field(metadata=dict(static=True))

    # -- construction -------------------------------------------------------

    @staticmethod
    def empty(
        config: SketchConfig,
        capacity: int,
        key: jax.Array,
        window_slices: int = 1,
    ) -> "FleetSketch":
        # Seed derivation matches GLavaSketch.empty exactly: tenants of a
        # fleet opened with seed s are bit-identical to GraphStream(seed=s).
        kr, kc = jax.random.split(key)
        row_hash = make_hash_family(kr, config.depth, config.width_rows)
        col_hash = (
            row_hash
            if config.is_square
            else make_hash_family(kc, config.depth, config.width_cols)
        )
        t, k, d = capacity, max(1, window_slices), config.depth
        return FleetSketch(
            jnp.zeros((t, k, d, config.width_rows, config.width_cols), jnp.float32),
            jnp.zeros((t, k, d, config.width_rows), jnp.float32),
            jnp.zeros((t, k, d, config.width_cols), jnp.float32),
            jnp.zeros((t,), jnp.int32),
            row_hash,
            col_hash,
            config,
        )

    @property
    def capacity(self) -> int:
        return self.counters.shape[0]

    @property
    def n_slices(self) -> int:
        return self.counters.shape[1]

    # -- ingest -------------------------------------------------------------

    def update(
        self,
        slots: jax.Array,    # (B,) int32 — resident slot per edge
        src: jax.Array,      # (B,) uint32
        dst: jax.Array,      # (B,) uint32
        weights: jax.Array,  # (B,) float32
    ) -> "FleetSketch":
        """Fold one mixed multi-tenant edge batch into the stack — a single
        flat scatter regardless of how many tenants the batch spans.  Each
        edge lands in its tenant's ACTIVE window slice (plane = slot·K +
        cursor[slot]), so the tenant axis rides in the scatter index and no
        per-tenant loop or vmap is needed."""
        t, k, d, w_r, w_c = self.counters.shape
        slots = slots.astype(jnp.int32)
        plane = slots * k + self.cursor[slots]
        r, c = self.row_hash(src), self.col_hash(dst)
        counters, row_flows, col_flows = scatter_stacked(
            self.counters.reshape(t * k, d, w_r, w_c),
            self.row_flows.reshape(t * k, d, w_r),
            self.col_flows.reshape(t * k, d, w_c),
            plane, r, c, weights,
        )
        if not self.config.directed:
            r2, c2 = self.row_hash(dst), self.col_hash(src)
            counters, row_flows, col_flows = scatter_stacked(
                counters, row_flows, col_flows, plane, r2, c2, weights
            )
        return dataclasses.replace(
            self,
            counters=counters.reshape(t, k, d, w_r, w_c),
            row_flows=row_flows.reshape(t, k, d, w_r),
            col_flows=col_flows.reshape(t, k, d, w_c),
        )

    # -- per-slot views / residency ops (host-side session plane) -----------

    def tenant_sketch(self, slot: int) -> GLavaSketch:
        """One tenant's window-summed sketch as a plain ``GLavaSketch`` —
        the same view ``SlidingWindowSketch.window_sketch()`` serves."""
        return GLavaSketch(
            jnp.sum(self.counters[slot], axis=0),
            self.row_hash,
            self.col_hash,
            self.config,
            jnp.sum(self.row_flows[slot], axis=0),
            jnp.sum(self.col_flows[slot], axis=0),
        )

    def tenant_shard(self, slot: int) -> dict:
        """The tenant's evictable device state (window-resolved, per slice)
        as a checkpointable pytree."""
        return {
            "counters": self.counters[slot],
            "row_flows": self.row_flows[slot],
            "col_flows": self.col_flows[slot],
            "cursor": self.cursor[slot],
        }

    def load_tenant(self, slot: int, shard: dict) -> "FleetSketch":
        return dataclasses.replace(
            self,
            counters=self.counters.at[slot].set(shard["counters"]),
            row_flows=self.row_flows.at[slot].set(shard["row_flows"]),
            col_flows=self.col_flows.at[slot].set(shard["col_flows"]),
            cursor=self.cursor.at[slot].set(
                jnp.asarray(shard["cursor"], jnp.int32)
            ),
        )

    def clear_tenant(self, slot: int) -> "FleetSketch":
        return dataclasses.replace(
            self,
            counters=self.counters.at[slot].set(0.0),
            row_flows=self.row_flows.at[slot].set(0.0),
            col_flows=self.col_flows.at[slot].set(0.0),
            cursor=self.cursor.at[slot].set(0),
        )

    def advance(self, slot: int) -> "FleetSketch":
        """Advance one tenant's window ring and zero the slice it wraps
        onto — same semantics as ``SlidingWindowSketch.advance()``."""
        nxt = (self.cursor[slot] + 1) % self.n_slices
        return dataclasses.replace(
            self,
            cursor=self.cursor.at[slot].set(nxt),
            counters=self.counters.at[slot, nxt].set(0.0),
            row_flows=self.row_flows.at[slot, nxt].set(0.0),
            col_flows=self.col_flows.at[slot, nxt].set(0.0),
        )
