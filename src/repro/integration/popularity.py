"""gLava → RecSys integration: a NON-SQUARE user×item sketch (paper
Section 6.1.2) over the interaction stream drives popularity-aware negative
sampling for BERT4Rec.

Users hash on rows (h1 → [0, m)), items on columns (h2 → [0, p)) — the
bipartite stream is exactly the paper's non-square use case.  Item
popularity = f̃_v(item, ←) (in-flow point query); negatives are drawn
∝ popularity^beta, the standard word2vec/recsys correction, WITHOUT storing
per-item exact counters (sublinear space)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketch import GLavaSketch, SketchConfig
from repro.core import queries


class InteractionPopularitySketch:
    def __init__(
        self,
        n_items_hint: int,
        depth: int = 4,
        width_users: int = 4096,
        width_items: int = 8192,
        seed: int = 0,
    ):
        cfg = SketchConfig(depth=depth, width_rows=width_users, width_cols=width_items)
        self.sketch = GLavaSketch.empty(cfg, jax.random.key(seed))
        self.n_items = n_items_hint
        self._ingest = jax.jit(lambda sk, u, i: sk.update(u, i, backend="scatter"))

    def observe(self, user_ids: np.ndarray, item_ids: np.ndarray):
        self.sketch = self._ingest(
            self.sketch,
            jnp.asarray(user_ids, jnp.uint32),
            jnp.asarray(item_ids, jnp.uint32),
        )

    def item_popularity(self, items: np.ndarray) -> np.ndarray:
        est = queries.node_in_flow(self.sketch, jnp.asarray(items, jnp.uint32))
        return np.asarray(est)

    def sample_negatives(
        self, k: int, rng, beta: float = 0.75, candidate_pool: int = 65536
    ) -> np.ndarray:
        """Draw k popularity^beta-weighted negatives from a uniform candidate
        pool (two-stage: pool keeps the point-query batch bounded)."""
        pool = rng.integers(1, self.n_items + 1, candidate_pool).astype(np.uint32)
        pop = self.item_popularity(pool)
        w = np.power(np.maximum(pop, 1e-6), beta)
        w /= w.sum()
        return rng.choice(pool, size=k, replace=True, p=w).astype(np.int32)

    def user_activity(self, user_ids: np.ndarray) -> np.ndarray:
        est = queries.node_out_flow(self.sketch, jnp.asarray(user_ids, jnp.uint32))
        return np.asarray(est)
