"""gLava → GNN integration: sketch-estimated degrees drive the neighbor
sampler (DESIGN.md Section 5, Arch-applicability).

On a STREAMED graph the exact degree table does not exist — the training
pipeline sees edges once.  The gLava point query f̃_v(a, →)/f̃_v(a, ←)
(paper Section 4.2) estimates per-node degree in O(d) after a single row/col
flow reduction, and those estimates replace exact degrees in the
importance-seed sampler.  Over-estimates only (CountMin property) → sampling
weights are biased up for collided nodes, never starved to zero.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketch import GLavaSketch, SketchConfig
from repro.core import queries


class StreamingDegreeSketch:
    """Maintains a gLava sketch over a streamed edge list and serves degree
    estimates to the sampler."""

    def __init__(self, config: SketchConfig, seed: int = 0, backend: str = "onehot"):
        self.sketch = GLavaSketch.empty(config, jax.random.key(seed))
        self.backend = backend
        self._ingest = jax.jit(
            lambda sk, s, d: sk.update(s, d, backend="scatter")
        )

    def observe(self, src: np.ndarray, dst: np.ndarray):
        self.sketch = self._ingest(
            self.sketch, jnp.asarray(src, jnp.uint32), jnp.asarray(dst, jnp.uint32)
        )

    def degree_estimates(self, nodes: np.ndarray, direction: str = "out") -> np.ndarray:
        keys = jnp.asarray(nodes, jnp.uint32)
        if direction == "out":
            est = queries.node_out_flow(self.sketch, keys)
        else:
            est = queries.node_in_flow(self.sketch, keys)
        return np.asarray(est)

    def seed_weights(self, n_nodes: int, alpha: float = 0.5, chunk: int = 65536):
        """deg^alpha importance weights for ALL nodes (chunked point
        queries)."""
        out = np.empty(n_nodes, np.float64)
        for lo in range(0, n_nodes, chunk):
            hi = min(n_nodes, lo + chunk)
            est = self.degree_estimates(np.arange(lo, hi, dtype=np.uint32))
            out[lo:hi] = np.power(np.maximum(est, 1.0), alpha)
        return out / out.sum()


def sketch_weighted_seeds(
    deg_sketch: StreamingDegreeSketch,
    n_nodes: int,
    batch: int,
    rng,
    alpha: float = 0.5,
) -> np.ndarray:
    p = deg_sketch.seed_weights(n_nodes, alpha)
    return rng.choice(n_nodes, size=batch, replace=False, p=p).astype(np.int32)
