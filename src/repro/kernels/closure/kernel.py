"""Pallas TPU kernel: one boolean-matrix-squaring step of transitive closure
(paper Section 4.3's reach(), TPU-native — DESIGN.md Section 2).

out = A OR (A @ A > 0), blocked matmul with OR-semantics accumulation:
grid (w/TI, w/TJ, w/TK) with the contraction axis innermost; the saturate
(>0 → 1) happens on the last k-step so intermediate sums can use plain fp32
adds on the MXU.  ops.py iterates ceil(log2(w)) squarings.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 256


def _closure_kernel(a_row_ref, a_col_ref, a_diag_ref, out_ref, *, n_k):
    i_k = pl.program_id(2)

    @pl.when(i_k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    acc = jax.lax.dot_general(
        a_row_ref[...],
        a_col_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += acc

    @pl.when(i_k == n_k - 1)
    def _saturate():
        got = (out_ref[...] > 0.0) | (a_diag_ref[...] > 0.0)
        out_ref[...] = got.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def closure_step_pallas(a, interpret: bool = True):
    """One squaring step for (w, w) f32 0/1 adjacency; w % TILE == 0."""
    w = a.shape[0]
    n_k = w // TILE
    grid = (w // TILE, w // TILE, n_k)
    return pl.pallas_call(
        functools.partial(_closure_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE, TILE), lambda i, j, k: (i, k)),  # A row-block
            pl.BlockSpec((TILE, TILE), lambda i, j, k: (k, j)),  # A col-block
            pl.BlockSpec((TILE, TILE), lambda i, j, k: (i, j)),  # A (for OR)
        ],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((w, w), jnp.float32),
        interpret=interpret,
    )(a, a, a)
