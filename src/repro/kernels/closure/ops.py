"""jit'd wrapper: full transitive closure by repeated Pallas squaring."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.closure.kernel import TILE, closure_step_pallas


def transitive_closure(adj, include_self: bool = True, interpret: bool = True):
    """(..., w, w) weighted adjacency -> boolean closure, via the Pallas
    blocked-squaring kernel.  Batched over leading dims (the d sketches)."""
    w = adj.shape[-1]
    pad = (-w) % TILE
    a = (adj > 0).astype(jnp.float32)
    if include_self:
        a = jnp.clip(a + jnp.eye(w, dtype=jnp.float32), 0.0, 1.0)
    if pad:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 2) + [(0, pad), (0, pad)])
    step = lambda m: closure_step_pallas(m, interpret=interpret)
    for _ in range(a.ndim - 2):
        step = jax.vmap(step)
    n_steps = max(1, math.ceil(math.log2(max(2, w))))
    for _ in range(n_steps):
        a = step(a)
    out = a[..., :w, :w]
    return out > 0
