"""Pure-jnp oracle for one boolean-squaring step of transitive closure."""
import jax.numpy as jnp


def closure_step_ref(a):
    """a (w, w) f32 in {0,1} -> a OR (a @ a > 0), as f32 {0,1}."""
    prod = a @ a
    return jnp.clip(a + (prod > 0).astype(jnp.float32), 0.0, 1.0)
