"""Pallas TPU kernel: CountSketch compression of a gradient vector.

The sketched-all-reduce path (repro.train.compression) compresses a flat
gradient into a (d, w) signed table.  Scatter → one-hot MXU matmul, same
adaptation as the ingest kernel:

    table[d] += (OneHot_buckets ⊙ sign)^T @ grad_chunk

Grid (d, w/TW, n/CN) with the chunk axis innermost (accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_W = 256
CHUNK_N = 1024


def _cs_kernel(h_ref, s_ref, v_ref, out_ref):
    i_w = pl.program_id(1)
    i_n = pl.program_id(2)

    @pl.when(i_n == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    h = h_ref[0, :]                       # (CN,)
    s = s_ref[0, :].astype(jnp.float32)
    v = v_ref[...]                        # (CN,)
    local = h - i_w * TILE_W
    iota = jax.lax.broadcasted_iota(jnp.int32, (CHUNK_N, TILE_W), 1)
    oh = (iota == local[:, None]).astype(jnp.float32)  # (CN, TW)
    contrib = jax.lax.dot_general(
        oh * (s * v)[:, None],
        jnp.ones((CHUNK_N, 1), jnp.float32),
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]                                # (TW,) column sums
    out_ref[...] += contrib[None]


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def countsketch_pallas(vec, h, s, width: int, interpret: bool = True):
    """vec (n,) f32; h (d, n) int32; s (d, n) int32 ±1 -> (d, width) f32.
    width % TILE_W == 0 and n % CHUNK_N == 0 (ops.py pads)."""
    d, n = h.shape
    grid = (d, width // TILE_W, n // CHUNK_N)
    return pl.pallas_call(
        _cs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CHUNK_N), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, CHUNK_N), lambda i, j, k: (i, k)),
            pl.BlockSpec((CHUNK_N,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((1, TILE_W), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, width), jnp.float32),
        interpret=interpret,
    )(h, s, vec)
