"""jit'd wrapper: hash + pad + Pallas CountSketch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.countsketch.kernel import CHUNK_N, TILE_W, countsketch_pallas


def countsketch(vec, hash_family, interpret: bool = True):
    """Compress a flat vector with a HashFamily -> (d, w) table.  Exactly
    matches repro.train.compression._sketch (tested)."""
    n = vec.shape[0]
    idx = jnp.arange(n, dtype=jnp.uint32)
    h = hash_family(idx).astype(jnp.int32)
    s = hash_family.signs(idx).astype(jnp.int32)
    pad_n = (-n) % CHUNK_N
    if pad_n:
        vec = jnp.pad(vec.astype(jnp.float32), (0, pad_n))
        h = jnp.pad(h, ((0, 0), (0, pad_n)))
        s = jnp.pad(s, ((0, 0), (0, pad_n)), constant_values=1)
    w = hash_family.w
    pad_w = (-w) % TILE_W
    out = countsketch_pallas(
        vec.astype(jnp.float32), h, s, width=w + pad_w, interpret=interpret
    )
    return out[:, :w]
