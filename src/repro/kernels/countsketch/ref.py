"""Pure-jnp oracle for the CountSketch gradient-compression kernel."""
import jax
import jax.numpy as jnp


def countsketch_ref(vec, h, s, width):
    """vec (n,), h (d, n) buckets, s (d, n) ±1 -> (d, w) sketch table."""
    d = h.shape[0]
    d_idx = jnp.broadcast_to(jnp.arange(d)[:, None], h.shape)
    vals = s.astype(jnp.float32) * vec[None, :]
    return jnp.zeros((d, width), jnp.float32).at[d_idx, h].add(vals)
