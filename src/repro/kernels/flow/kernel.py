"""Pallas TPU kernel: fused row+column flow reductions over all d sketches.

One pass over the (d, wr, wc) counters produces BOTH the out-flow (row sums)
and in-flow (column sums) tables — the heavy-hitter monitor (paper
Section 4.2) reads these once per refresh instead of reducing per query.
Grid (d, wr/TR, wc/TC); each program reduces its tile along both axes and
accumulates into the two outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 256
TILE_C = 256


def _flow_kernel(counters_ref, out_row_ref, out_col_ref):
    i_r = pl.program_id(1)
    i_c = pl.program_id(2)

    @pl.when(i_c == 0)
    def _init_row():
        out_row_ref[...] = jnp.zeros_like(out_row_ref)

    @pl.when(i_r == 0)
    def _init_col():
        out_col_ref[...] = jnp.zeros_like(out_col_ref)

    tile = counters_ref[0]  # (TR, TC)
    out_row_ref[...] += jnp.sum(tile, axis=1)[None]
    out_col_ref[...] += jnp.sum(tile, axis=0)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def flows_pallas(counters, interpret: bool = True):
    d, wr, wc = counters.shape
    grid = (d, wr // TILE_R, wc // TILE_C)
    return pl.pallas_call(
        _flow_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, TILE_R, TILE_C), lambda i, j, k: (i, j, k))],
        out_specs=[
            pl.BlockSpec((1, TILE_R), lambda i, j, k: (i, j)),
            pl.BlockSpec((1, TILE_C), lambda i, j, k: (i, k)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, wr), jnp.float32),
            jax.ShapeDtypeStruct((d, wc), jnp.float32),
        ],
        interpret=interpret,
    )(counters)
