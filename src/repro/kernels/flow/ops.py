"""jit'd wrapper for the flow kernel + the full point-query path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ingest import pad_to
from repro.kernels.flow.kernel import TILE_C, TILE_R, flows_pallas


def flows(counters, interpret: bool = True):
    """(d, wr, wc) -> (row_sums (d, wr), col_sums (d, wc))."""
    d, wr, wc = counters.shape
    cp = pad_to(pad_to(counters.astype(jnp.float32), TILE_R, 1), TILE_C, 2)
    rs, cs = flows_pallas(cp, interpret=interpret)
    return rs[:, :wr], cs[:, :wc]


def node_in_flow(sketch, keys, interpret: bool = True):
    _, col_sums = flows(sketch.counters, interpret=interpret)
    h = sketch.col_hash(keys)
    return jnp.min(jnp.take_along_axis(col_sums, h, axis=1), axis=0)


def node_out_flow(sketch, keys, interpret: bool = True):
    row_sums, _ = flows(sketch.counters, interpret=interpret)
    h = sketch.row_hash(keys)
    return jnp.min(jnp.take_along_axis(row_sums, h, axis=1), axis=0)
