"""Pure-jnp oracle for the point-query flow kernel."""
import jax.numpy as jnp


def flows_ref(counters):
    """counters (d, wr, wc) -> (out_flows (d, wr) row sums,
    in_flows (d, wc) col sums) — paper Section 4.2 Step 1."""
    return jnp.sum(counters, axis=2), jnp.sum(counters, axis=1)
