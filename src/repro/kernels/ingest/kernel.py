"""Pallas TPU kernel: batched sketch ingest as one-hot MXU matmuls.

The paper's per-edge scatter ``M[h(x), h(y)] += w`` is re-expressed per
(row-tile × col-tile × edge-chunk) as

    M_tile += OneHot_rows(chunk)^T @ (OneHot_cols(chunk) * w)

— a (TR × CB) @ (CB × TC) systolic matmul with fp32 accumulation in VMEM.
Grid = (d, wr/TR, wc/TC, B/CB); the edge-chunk axis is innermost so each
counter tile stays resident in VMEM while every chunk accumulates into it
(input_output_aliasing keeps the update in place).

VMEM working set per program:
    TR*TC*4 (tile) + 2*CB*4 (indices) + CB*4 (weights) + 2*CB*max(TR,TC)*4
    = 256*256*4 + ... ≈ 1.3 MB  « 16 MB VMEM.
MXU alignment: TR, TC multiples of 128; CB multiple of 8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 256
TILE_C = 256
CHUNK_B = 512


def _ingest_kernel(rows_ref, cols_ref, w_ref, counters_ref, out_ref):
    """One (d, r-tile, c-tile, b-chunk) program."""
    i_r = pl.program_id(1)
    i_c = pl.program_id(2)
    i_b = pl.program_id(3)

    @pl.when(i_b == 0)
    def _init():
        out_ref[...] = counters_ref[...]

    rows = rows_ref[0, :]                       # (CB,) int32, global row ids
    cols = cols_ref[0, :]
    w = w_ref[...]                              # (CB,) f32
    r_local = rows - i_r * TILE_R
    c_local = cols - i_c * TILE_C
    # one-hot via iota compare; out-of-tile ids match no column
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (CHUNK_B, TILE_R), 1)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (CHUNK_B, TILE_C), 1)
    oh_r = (iota_r == r_local[:, None]).astype(jnp.float32)       # (CB, TR)
    oh_c = (iota_c == c_local[:, None]).astype(jnp.float32)
    oh_c = oh_c * w[:, None]
    upd = jax.lax.dot_general(
        oh_r, oh_c, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TR, TC)
    out_ref[...] += upd[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ingest_pallas(counters, rows, cols, weights, interpret: bool = True):
    """counters (d, wr, wc) f32; rows/cols (d, B) int32; weights (B,) f32.
    Shapes must be pre-padded: wr % TILE_R == wc % TILE_C == B % CHUNK_B == 0
    (ops.py handles padding)."""
    d, wr, wc = counters.shape
    b = rows.shape[1]
    grid = (d, wr // TILE_R, wc // TILE_C, b // CHUNK_B)
    return pl.pallas_call(
        _ingest_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CHUNK_B), lambda i, j, k, l: (i, l)),   # rows
            pl.BlockSpec((1, CHUNK_B), lambda i, j, k, l: (i, l)),   # cols
            pl.BlockSpec((CHUNK_B,), lambda i, j, k, l: (l,)),       # weights
            pl.BlockSpec((1, TILE_R, TILE_C), lambda i, j, k, l: (i, j, k)),
        ],
        out_specs=pl.BlockSpec((1, TILE_R, TILE_C), lambda i, j, k, l: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct(counters.shape, jnp.float32),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(rows, cols, weights, counters)
