"""jit'd wrapper around the Pallas ingest kernel.

Padding/unpadding and index masking live in ``repro.core.ingest`` (the one
dispatch point for every ingest backend); this module keeps the historical
``sketch_ingest`` entry point for kernel benchmarks and tests.
"""
from __future__ import annotations

from repro.core.ingest import ingest


def sketch_ingest(counters, rows, cols, weights):
    """counters (d, wr, wc) f32 += scatter(rows, cols, weights).  Any shapes;
    equals ref.sketch_ingest_ref exactly for integer-valued weights.
    Interpret-vs-compiled is resolved centrally from the platform by the
    engine (interpret off TPU)."""
    return ingest(counters, rows, cols, weights, backend="pallas")
