"""jit'd wrapper: pads to kernel tile sizes, invokes the Pallas ingest
kernel, unpads.  Padded edges carry weight 0 into row/col 0 — a no-op by
linearity."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ingest.kernel import CHUNK_B, TILE_C, TILE_R, ingest_pallas


def _pad_to(x, m, axis, value=0):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def sketch_ingest(counters, rows, cols, weights, interpret: bool = True):
    """counters (d, wr, wc) f32 += scatter(rows, cols, weights).  Any shapes;
    equals ref.sketch_ingest_ref exactly for integer-valued weights."""
    d, wr, wc = counters.shape
    cp = _pad_to(_pad_to(counters.astype(jnp.float32), TILE_R, 1), TILE_C, 2)
    rp = _pad_to(rows.astype(jnp.int32), CHUNK_B, 1)
    cl = _pad_to(cols.astype(jnp.int32), CHUNK_B, 1)
    wp = _pad_to(weights.astype(jnp.float32), CHUNK_B, 0)  # pad weight = 0
    out = ingest_pallas(cp, rp, cl, wp, interpret=interpret)
    return out[:, :wr, :wc]
