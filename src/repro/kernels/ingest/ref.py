"""Pure-jnp oracle for the sketch-ingest kernel: the paper's literal
per-edge scatter M_i[r_i(b), c_i(b)] += w(b), vectorized."""
import jax.numpy as jnp


def sketch_ingest_ref(counters, rows, cols, weights):
    """counters (d, wr, wc) f32; rows/cols (d, B) int32; weights (B,) f32."""
    d = counters.shape[0]
    d_idx = jnp.broadcast_to(jnp.arange(d)[:, None], rows.shape)
    w = jnp.broadcast_to(weights[None, :].astype(jnp.float32), rows.shape)
    return counters.at[d_idx, rows, cols].add(w)
