"""One-pass fused ingest: counters + flow registers + touched-row bitmap
in a single sweep over the edge batch (DESIGN.md Section 10)."""
