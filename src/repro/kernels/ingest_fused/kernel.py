"""Pallas TPU kernel: ONE-PASS fused sketch ingest.

Plain ingest makes three separate passes over HBM per batch: the counter
scatter, ``scatter_flows`` for the two flow registers, and (host-side)
``touched_row_keys`` for the incremental-closure plane.  This kernel does
all four updates in a single sweep:

    counters[i, r, c] += w        row_flows[i, r] += w
    col_flows[i, c]   += w        touched[i, r]    = 1

Grid = (d, wr/TILE_R, B/CHUNK_B) with the edge-chunk axis innermost, so the
(TILE_R x wc) counter stripe, its row-flow/touched slices, and the full
col-flow row stay VMEM-resident while every chunk accumulates into them
(input_output_aliasing keeps the updates in place).  Column tiles are the
FULL padded width: col_flows has no row-tile axis, so it accumulates only
on the j == 0 row tile, and splitting columns would either double-count it
or force a second pass — the thing this kernel exists to avoid.

VMEM working set per program (wc = 1024):
    TILE_R*wc*4 (counter stripe) + CHUNK_B*wc*4 (one-hot cols)
    + CHUNK_B*TILE_R*4 (one-hot rows) + O(CHUNK_B + TILE_R + wc)
    = 1 MB + 2 MB + 0.5 MB ≈ 3.5 MB  « 16 MB VMEM.
MXU alignment: TILE_R and the padded wc are multiples of 128; CHUNK_B of 8.

Row ids may be -1 (padding / out-of-shard): the iota compare matches no
row AND the weight is masked to zero, so such slots touch nothing — not
even col_flows.  The ``touched`` output marks every row a VALID slot hashes
to, weight 0 included (ref.py mirrors both rules bit-for-bit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 256
CHUNK_B = 512
LANE = 128  # the padded column width must be a multiple of this


def _fused_kernel(
    rows_ref,
    cols_ref,
    w_ref,
    counters_ref,
    rf_ref,
    cf_ref,
    out_c_ref,
    out_rf_ref,
    out_cf_ref,
    out_t_ref,
    *,
    wc: int,
):
    """One (d, r-tile, b-chunk) program over the full column width."""
    i_j = pl.program_id(1)
    i_b = pl.program_id(2)

    @pl.when(i_b == 0)
    def _init():
        out_c_ref[...] = counters_ref[...]
        out_rf_ref[...] = rf_ref[...]
        out_t_ref[...] = jnp.zeros_like(out_t_ref)

    @pl.when((i_b == 0) & (i_j == 0))
    def _init_cf():
        out_cf_ref[...] = cf_ref[...]

    rows = rows_ref[0, :]                       # (CB,) int32, global row ids
    cols = cols_ref[0, :]
    w = w_ref[...]                              # (CB,) f32
    # -1 rows (padding / out-of-shard) contribute nothing anywhere: the iota
    # compare already misses every row; masking w kills col_flows too.
    w = w * (rows >= 0).astype(jnp.float32)
    r_local = rows - i_j * TILE_R
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (CHUNK_B, TILE_R), 1)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (CHUNK_B, wc), 1)
    oh_r = (iota_r == r_local[:, None]).astype(jnp.float32)       # (CB, TR)
    oh_c = (iota_c == cols[:, None]).astype(jnp.float32)          # (CB, wc)
    oh_cw = oh_c * w[:, None]
    upd = jax.lax.dot_general(
        oh_r, oh_cw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TR, wc)
    out_c_ref[...] += upd[None]
    out_rf_ref[...] += jnp.sum(oh_r * w[:, None], axis=0)[None]
    # touched = "a valid slot hashed here", weight-independent (oh_r is
    # built from indices alone, so w == 0 edges still mark their row).
    out_t_ref[...] = jnp.maximum(out_t_ref[...], jnp.max(oh_r, axis=0)[None])

    @pl.when(i_j == 0)
    def _col_flows():
        out_cf_ref[...] += jnp.sum(oh_cw, axis=0)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_ingest_pallas(
    counters, row_flows, col_flows, rows, cols, weights, interpret: bool = True
):
    """counters (d, wr, wc) f32; row/col_flows (d, wr)/(d, wc) f32;
    rows/cols (d, B) int32; weights (B,) f32.  Shapes must be pre-padded:
    wr % TILE_R == wc % LANE == B % CHUNK_B == 0 (ops.py handles padding).
    Returns (counters, row_flows, col_flows, touched) with touched (d, wr)
    f32 in {0, 1}."""
    d, wr, wc = counters.shape
    b = rows.shape[1]
    grid = (d, wr // TILE_R, b // CHUNK_B)
    return pl.pallas_call(
        functools.partial(_fused_kernel, wc=wc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CHUNK_B), lambda i, j, l: (i, l)),      # rows
            pl.BlockSpec((1, CHUNK_B), lambda i, j, l: (i, l)),      # cols
            pl.BlockSpec((CHUNK_B,), lambda i, j, l: (l,)),          # weights
            pl.BlockSpec((1, TILE_R, wc), lambda i, j, l: (i, j, 0)),
            pl.BlockSpec((1, TILE_R), lambda i, j, l: (i, j)),       # row_flows
            pl.BlockSpec((1, wc), lambda i, j, l: (i, 0)),           # col_flows
        ],
        out_specs=[
            pl.BlockSpec((1, TILE_R, wc), lambda i, j, l: (i, j, 0)),
            pl.BlockSpec((1, TILE_R), lambda i, j, l: (i, j)),
            pl.BlockSpec((1, wc), lambda i, j, l: (i, 0)),
            pl.BlockSpec((1, TILE_R), lambda i, j, l: (i, j)),       # touched
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, wr, wc), jnp.float32),
            jax.ShapeDtypeStruct((d, wr), jnp.float32),
            jax.ShapeDtypeStruct((d, wc), jnp.float32),
            jax.ShapeDtypeStruct((d, wr), jnp.float32),
        ],
        input_output_aliases={3: 0, 4: 1, 5: 2},
        interpret=interpret,
    )(rows, cols, weights, counters, row_flows, col_flows)
