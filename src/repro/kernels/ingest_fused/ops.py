"""Public entry point for the one-pass fused ingest.

Owns padding/unpadding around the Pallas kernel and the platform dispatch:
the compiled kernel on TPU, the bit-identical jnp ref twin elsewhere (the
interpret-mode kernel is a correctness artifact for tests, far too slow to
serve a session from), with ``interpret=True`` forcing the kernel body on
CPU for the bit-equality sweeps.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ingest import pad_to
from repro.kernels.ingest_fused.kernel import (
    CHUNK_B,
    LANE,
    TILE_R,
    fused_ingest_pallas,
)
from repro.kernels.ingest_fused.ref import fused_ingest_ref

# Past this padded column width the full-width VMEM stripe (counter tile +
# one-hot cols) no longer fits comfortably; fall back to the ref twin.
MAX_FUSED_WC = 2048


def fused_ingest(
    counters,          # (d, wr, wc) f32
    row_flows,         # (d, wr) f32
    col_flows,         # (d, wc) f32
    rows,              # (d, B) int32 — may contain -1 for masked slots
    cols,              # (d, B) int32 — in [0, wc)
    weights,           # (B,) f32
    *,
    interpret: Optional[bool] = None,
    use_kernel: Optional[bool] = None,
):
    """One-pass fused ingest (see kernel.py).  Any shapes; returns
    ``(counters, row_flows, col_flows, touched)`` with touched (d, wr)
    bool.  Bit-identical to :func:`fused_ingest_ref` for integer-valued
    weights (property-tested)."""
    d, wr, wc = counters.shape
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu" or interpret is not None
    weights = weights.astype(jnp.float32)
    rows = rows.astype(jnp.int32)
    cols = cols.astype(jnp.int32)
    if not use_kernel or wc + (-wc) % LANE > MAX_FUSED_WC:
        return fused_ingest_ref(counters, row_flows, col_flows, rows, cols, weights)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    cp = pad_to(pad_to(counters.astype(jnp.float32), TILE_R, 1), LANE, 2)
    rfp = pad_to(row_flows.astype(jnp.float32), TILE_R, 1)
    cfp = pad_to(col_flows.astype(jnp.float32), LANE, 1)
    rp = pad_to(rows, CHUNK_B, 1, value=-1)
    cl = pad_to(cols, CHUNK_B, 1)
    wp = pad_to(weights, CHUNK_B, 0)  # padded edges carry weight 0
    out_c, out_rf, out_cf, out_t = fused_ingest_pallas(
        cp, rfp, cfp, rp, cl, wp, interpret=interpret
    )
    return (
        out_c[:, :wr, :wc],
        out_rf[:, :wr],
        out_cf[:, :wc],
        out_t[:, :wr] > 0,
    )
