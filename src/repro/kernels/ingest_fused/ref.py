"""Pure-jnp oracle for the fused ingest kernel — the exact composition the
kernel replaces: the counter scatter, ``scatter_flows`` on both registers,
and the touched-row bitmap (the device-resident ``touched_row_keys``).

Semantics shared bit-for-bit with kernel.py:
  * rows == -1 (padding / out-of-shard) contribute NOTHING — not to the
    counters, not to either flow register;
  * ``touched[i, r]`` is True iff some valid slot hashes to row r, even
    with weight 0 (touched is a superset contract — refresh_closure only
    needs every changed row covered, extras are idempotent).
"""
import jax.numpy as jnp


def fused_ingest_ref(counters, row_flows, col_flows, rows, cols, weights):
    """counters (d, wr, wc) f32; row/col_flows (d, wr)/(d, wc) f32;
    rows/cols (d, B) int32 (rows may be -1); weights (B,) f32.
    Returns (counters, row_flows, col_flows, touched) with touched
    (d, wr) bool."""
    d, wr, _ = counters.shape
    d_idx = jnp.broadcast_to(jnp.arange(d)[:, None], rows.shape)
    valid = rows >= 0
    safe_r = jnp.where(valid, rows, 0)
    w = jnp.broadcast_to(weights[None, :].astype(jnp.float32), rows.shape)
    w = w * valid
    counters = counters.at[d_idx, safe_r, cols].add(w)
    row_flows = row_flows.at[d_idx, safe_r].add(w)
    col_flows = col_flows.at[d_idx, cols].add(w)
    touched = jnp.zeros((d, wr), bool).at[d_idx, safe_r].max(valid)
    return counters, row_flows, col_flows, touched
