"""Pallas TPU kernels: batched sketch edge-queries.

Gather ``M[d, r(q), c(q)]`` for a query batch is random access — hostile on
TPU.  Reformulated per (query-chunk × row-tile × col-tile) as masked one-hot
contractions on the MXU:

    vals[q] += Σ_ij OneHot_r[q, i] · M_tile[i, j] · OneHot_c[q, j]
             = rowsum( (OneHot_r @ M_tile) ⊙ OneHot_c )

Two variants share the formulation:

``query_pallas``        grid (d, Q/QB, wr/TR, wc/TC); emits the per-sketch
                        cell values (d, Q) — the Γ merge happens outside.
``multi_query_pallas``  the FUSED multi-query kernel: grid
                        (Q/QB, d, wr/TR, wc/TC) with the d axis *inside* —
                        each query chunk's per-sketch value is accumulated
                        in a VMEM scratch and folded into a running
                        min-reduce as each sketch completes, so the whole
                        f̃_e map/reduce (gather + Γ=min) is one kernel pass
                        and the (d, Q) intermediate never exists in HBM.

VMEM/program: TR*TC*4 + QB*TR*4 + QB*TC*4 ≈ 1.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_R = 256
TILE_C = 256
CHUNK_Q = 256


def _query_kernel(rows_ref, cols_ref, counters_ref, out_ref):
    i_r = pl.program_id(2)
    i_c = pl.program_id(3)

    @pl.when((i_r == 0) & (i_c == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows = rows_ref[0, :]                      # (QB,)
    cols = cols_ref[0, :]
    r_local = rows - i_r * TILE_R
    c_local = cols - i_c * TILE_C
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (CHUNK_Q, TILE_R), 1)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (CHUNK_Q, TILE_C), 1)
    oh_r = (iota_r == r_local[:, None]).astype(jnp.float32)
    oh_c = (iota_c == c_local[:, None]).astype(jnp.float32)
    m = counters_ref[0]                        # (TR, TC)
    rm = jax.lax.dot_general(
        oh_r, m, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # (QB, TC)
    vals = jnp.sum(rm * oh_c, axis=1)          # (QB,)
    out_ref[...] += vals[None]


def _multi_query_kernel(rows_ref, cols_ref, counters_ref, out_ref, acc_ref):
    i_d = pl.program_id(1)
    i_r = pl.program_id(2)
    i_c = pl.program_id(3)
    last_r = pl.num_programs(2) - 1
    last_c = pl.num_programs(3) - 1

    @pl.when((i_d == 0) & (i_r == 0) & (i_c == 0))
    def _init_out():
        out_ref[...] = jnp.full_like(out_ref, jnp.inf)

    @pl.when((i_r == 0) & (i_c == 0))
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = rows_ref[0, :]                      # (QB,) — this sketch's buckets
    cols = cols_ref[0, :]
    r_local = rows - i_r * TILE_R
    c_local = cols - i_c * TILE_C
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (CHUNK_Q, TILE_R), 1)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (CHUNK_Q, TILE_C), 1)
    oh_r = (iota_r == r_local[:, None]).astype(jnp.float32)
    oh_c = (iota_c == c_local[:, None]).astype(jnp.float32)
    m = counters_ref[0]                        # (TR, TC)
    rm = jax.lax.dot_general(
        oh_r, m, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # (QB, TC)
    acc_ref[...] += jnp.sum(rm * oh_c, axis=1)[None]

    # Sketch i_d's cell value is complete once its tile sweep finishes —
    # fold it into the running Γ (min over sketches) and move to the next d.
    @pl.when((i_r == last_r) & (i_c == last_c))
    def _gamma_fold():
        out_ref[...] = jnp.minimum(out_ref[...], acc_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def multi_query_pallas(counters, rows, cols, interpret: bool = True):
    """Fused f̃_e: (d, wr, wc) counters + (d, Q) buckets -> (Q,) min-merged
    estimates in ONE pass (gather and Γ-min never materialize (d, Q))."""
    d, wr, wc = counters.shape
    q = rows.shape[1]
    grid = (q // CHUNK_Q, d, wr // TILE_R, wc // TILE_C)
    out = pl.pallas_call(
        _multi_query_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CHUNK_Q), lambda j, i, k, l: (i, j)),
            pl.BlockSpec((1, CHUNK_Q), lambda j, i, k, l: (i, j)),
            pl.BlockSpec((1, TILE_R, TILE_C), lambda j, i, k, l: (i, k, l)),
        ],
        out_specs=pl.BlockSpec((1, CHUNK_Q), lambda j, i, k, l: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, q), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, CHUNK_Q), jnp.float32)],
        interpret=interpret,
    )(rows, cols, counters)
    return out[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def query_pallas(counters, rows, cols, interpret: bool = True):
    d, wr, wc = counters.shape
    q = rows.shape[1]
    grid = (d, q // CHUNK_Q, wr // TILE_R, wc // TILE_C)
    return pl.pallas_call(
        _query_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CHUNK_Q), lambda i, j, k, l: (i, j)),
            pl.BlockSpec((1, CHUNK_Q), lambda i, j, k, l: (i, j)),
            pl.BlockSpec((1, TILE_R, TILE_C), lambda i, j, k, l: (i, k, l)),
        ],
        out_specs=pl.BlockSpec((1, CHUNK_Q), lambda i, j, k, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, q), jnp.float32),
        interpret=interpret,
    )(rows, cols, counters)
