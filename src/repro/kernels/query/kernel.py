"""Pallas TPU kernel: batched sketch edge-queries.

Gather ``M[d, r(q), c(q)]`` for a query batch is random access — hostile on
TPU.  Reformulated per (query-chunk × row-tile × col-tile) as masked one-hot
contractions on the MXU:

    vals[q] += Σ_ij OneHot_r[q, i] · M_tile[i, j] · OneHot_c[q, j]
             = rowsum( (OneHot_r @ M_tile) ⊙ OneHot_c )

Grid = (d, Q/QB, wr/TR, wc/TC), accumulating over the two tile axes.
VMEM/program: TR*TC*4 + QB*TR*4 + QB*TC*4 ≈ 1.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 256
TILE_C = 256
CHUNK_Q = 256


def _query_kernel(rows_ref, cols_ref, counters_ref, out_ref):
    i_r = pl.program_id(2)
    i_c = pl.program_id(3)

    @pl.when((i_r == 0) & (i_c == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows = rows_ref[0, :]                      # (QB,)
    cols = cols_ref[0, :]
    r_local = rows - i_r * TILE_R
    c_local = cols - i_c * TILE_C
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (CHUNK_Q, TILE_R), 1)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (CHUNK_Q, TILE_C), 1)
    oh_r = (iota_r == r_local[:, None]).astype(jnp.float32)
    oh_c = (iota_c == c_local[:, None]).astype(jnp.float32)
    m = counters_ref[0]                        # (TR, TC)
    rm = jax.lax.dot_general(
        oh_r, m, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # (QB, TC)
    vals = jnp.sum(rm * oh_c, axis=1)          # (QB,)
    out_ref[...] += vals[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def query_pallas(counters, rows, cols, interpret: bool = True):
    d, wr, wc = counters.shape
    q = rows.shape[1]
    grid = (d, q // CHUNK_Q, wr // TILE_R, wc // TILE_C)
    return pl.pallas_call(
        _query_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CHUNK_Q), lambda i, j, k, l: (i, j)),
            pl.BlockSpec((1, CHUNK_Q), lambda i, j, k, l: (i, j)),
            pl.BlockSpec((1, TILE_R, TILE_C), lambda i, j, k, l: (i, k, l)),
        ],
        out_specs=pl.BlockSpec((1, CHUNK_Q), lambda i, j, k, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, q), jnp.float32),
        interpret=interpret,
    )(rows, cols, counters)
