"""jit'd wrapper for the edge-query kernel: pad, run, unpad, Γ-merge (min)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ingest import pad_to
from repro.kernels.query.kernel import CHUNK_Q, TILE_C, TILE_R, query_pallas


def edge_query_cells(counters, rows, cols, interpret: bool = True):
    """Per-sketch cell values (d, Q) — matches ref.edge_query_ref exactly."""
    d, wr, wc = counters.shape
    q = rows.shape[1]
    cp = pad_to(pad_to(counters.astype(jnp.float32), TILE_R, 1), TILE_C, 2)
    rp = pad_to(rows.astype(jnp.int32), CHUNK_Q, 1)
    cl = pad_to(cols.astype(jnp.int32), CHUNK_Q, 1)
    out = query_pallas(cp, rp, cl, interpret=interpret)
    return out[:, :q]


def edge_query(sketch, src_keys, dst_keys, interpret: bool = True):
    """Full f̃_e path on the kernel: hash → gather-kernel → min over d."""
    r, c = sketch.hash_edges(src_keys, dst_keys)
    vals = edge_query_cells(sketch.counters, r, c, interpret=interpret)
    return jnp.min(vals, axis=0)
