"""jit'd wrappers for the edge-query kernels: pad, run, unpad, Γ-merge (min).

Two entry points mirror the two kernels: :func:`edge_query_cells` (per-sketch
values, min applied here in jnp) and :func:`edge_query_min` (the FUSED
multi-query kernel — the min-reduce happens inside the kernel pass, used by
``repro.core.query_engine.QueryEngine`` on its ``pallas`` backend).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ingest import pad_to
from repro.kernels.query.kernel import (
    CHUNK_Q,
    TILE_C,
    TILE_R,
    multi_query_pallas,
    query_pallas,
)


def _pad_all(counters, rows, cols):
    cp = pad_to(pad_to(counters.astype(jnp.float32), TILE_R, 1), TILE_C, 2)
    rp = pad_to(rows.astype(jnp.int32), CHUNK_Q, 1)
    cl = pad_to(cols.astype(jnp.int32), CHUNK_Q, 1)
    return cp, rp, cl


def edge_query_cells(counters, rows, cols, interpret: bool = True):
    """Per-sketch cell values (d, Q) — matches ref.edge_query_ref exactly."""
    q = rows.shape[1]
    cp, rp, cl = _pad_all(counters, rows, cols)
    out = query_pallas(cp, rp, cl, interpret=interpret)
    return out[:, :q]


def edge_query_min(counters, rows, cols, interpret: bool = True):
    """Fused min-merged estimates (Q,) — matches ref.edge_query_min_ref.
    Padded queries hit bucket (0, 0) and are sliced away."""
    q = rows.shape[1]
    cp, rp, cl = _pad_all(counters, rows, cols)
    return multi_query_pallas(cp, rp, cl, interpret=interpret)[:q]


def edge_query(sketch, src_keys, dst_keys, interpret: bool = True):
    """Full f̃_e path on the fused kernel: hash → gather+min in one pass."""
    r, c = sketch.hash_edges(src_keys, dst_keys)
    return edge_query_min(sketch.counters, r, c, interpret=interpret)
