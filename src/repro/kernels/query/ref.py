"""Pure-jnp oracle for the batched edge-query kernel."""
import jax.numpy as jnp


def edge_query_ref(counters, rows, cols):
    """counters (d, wr, wc); rows/cols (d, Q) -> per-sketch cell values (d, Q).
    (The min-over-d Γ merge happens outside — ops.py applies it.)"""
    d = counters.shape[0]
    d_idx = jnp.broadcast_to(jnp.arange(d)[:, None], rows.shape)
    return counters[d_idx, rows, cols]


def edge_query_min_ref(counters, rows, cols):
    """Oracle for the FUSED multi-query kernel: gather + Γ (min over d)."""
    return jnp.min(edge_query_ref(counters, rows, cols), axis=0)
