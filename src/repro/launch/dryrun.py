import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import all_cells, get_arch
from repro.distributed.sharding import ResolveReport, resolve_tree
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.roofline.analysis import (
    compiled_memory_dict as _mem_dict,
    model_flops_for,
    parse_collectives,
    roofline_from_cost,
)

"""Multi-pod dry-run: ``.lower().compile()`` for every (architecture ×
input-shape × mesh) cell on the production meshes, recording
``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()`` (FLOPs/bytes)
and the collective schedule (parsed from the post-SPMD HLO) for the roofline
report.  Results land in results/dryrun/<arch>__<shape>__<mesh>.json and are
resumable cell-by-cell.
"""


def _sharded_bytes(sds_tree, sharding_tree) -> int:
    """Exact per-device bytes of a sharded pytree (shard_shape is exact)."""
    total = 0
    flat_s, treedef = jax.tree.flatten(sds_tree)
    flat_sh = treedef.flatten_up_to(sharding_tree)
    for sds, sh in zip(flat_s, flat_sh):
        shard = sh.shard_shape(sds.shape)
        total += int(np.prod(shard)) * sds.dtype.itemsize
    return total


def modeled_memory(bundle, state_sds, state_sh, batch_sh) -> dict:
    """Analytic per-device memory: params+opt+inputs are EXACT from the
    shardings; activations estimated for LM train (remat carry chain).  The
    XLA temp number on this host is inflated by CPU bf16→f32 legalization
    and sequential thunk live-ranges — see EXPERIMENTS.md §Dry-run."""
    state_b = _sharded_bytes(state_sds, state_sh)
    batch_b = _sharded_bytes(bundle.batch_specs, batch_sh)
    act_b = 0
    cfg = bundle.config
    if bundle.kind == "train" and hasattr(cfg, "n_layers") and hasattr(cfg, "d_model"):
        b, s1 = bundle.batch_specs["tokens"].shape
        # remat stores the layer carry: (B/dp, S/model, D) bf16 per layer
        carry = (b // 16) * ((s1 - 1) // 16) * cfg.d_model * 2
        act_b = carry * cfg.n_layers
    return {
        "state_bytes_per_device": state_b,
        "input_bytes_per_device": batch_b,
        "activation_bytes_per_device_est": act_b,
        "modeled_total_per_device": state_b + batch_b + act_b,
        "fits_16GB": (state_b + batch_b + act_b) <= 16e9,
    }


def _make_jit(bundle, state_sh, batch_sh, mesh, report):
    if bundle.is_train:
        return jax.jit(
            bundle.step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
    out_sh = None
    if bundle.out_logical is not None:
        out_shapes = jax.eval_shape(
            bundle.step, bundle.state_specs(), bundle.batch_specs
        )
        out_sh = resolve_tree(bundle.out_logical, out_shapes, mesh, report=report)
    return jax.jit(bundle.step, in_shardings=(state_sh, batch_sh), out_shardings=out_sh)


def _cost_of(bundle, mesh, report, rules=None):
    """lower+compile one bundle, return (cost dict, collectives dict)."""
    state_sds = bundle.state_specs()
    state_sh = resolve_tree(bundle.state_logical, state_sds, mesh, rules, report=report)
    batch_sh = resolve_tree(bundle.batch_logical, bundle.batch_specs, mesh, rules, report=report)
    jf = _make_jit(bundle, state_sh, batch_sh, mesh, report)
    with mesh:
        compiled = jf.lower(state_sds, bundle.batch_specs).compile()
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    colls = parse_collectives(compiled.as_text())
    return dict(cost), colls


def extrapolate_lm_cost(
    arch: str, shape: str, mesh, optimized: bool = False, rules=None
):
    """XLA cost analysis counts a while (lax.scan) body ONCE, so the scanned
    L-layer program under-reports flops/bytes/collectives by ~L×.  Layers are
    identical, so cost(L) is exactly affine in L: compile the UNROLLED model
    at L=1 and L=2, fit, and evaluate at the real depth.  Returns
    (cost, collectives, detail)."""
    import dataclasses as dc

    from repro.configs import get_arch as _ga

    full_cfg = _ga(arch).config
    L = full_cfg.n_layers
    report = ResolveReport()
    costs, colls = {}, {}
    for k in (1, 2):
        cfg_k = dc.replace(full_cfg, n_layers=k, scan_layers=False)
        b = build_step(
            arch, shape, mesh=mesh, config_override=cfg_k, optimized=optimized
        )
        costs[k], colls[k] = _cost_of(b, mesh, report, rules=rules)

    def fit(m1, m2):
        bb = m2 - m1
        return m1 - bb + bb * L  # a + b*L with a = m1 - b

    keys = set(costs[1]) & set(costs[2])
    cost_L = {
        k: float(fit(float(costs[1][k]), float(costs[2][k])))
        for k in keys
        if isinstance(costs[1][k], (int, float))
    }
    coll_L = {}
    for op in colls[1]:
        coll_L[op] = {
            "count": max(0.0, fit(colls[1][op]["count"], colls[2][op]["count"])),
            "bytes": max(0.0, fit(colls[1][op]["bytes"], colls[2][op]["bytes"])),
        }
    return cost_L, coll_L, {"depths_compiled": [1, 2], "extrapolated_to": L}


def run_cell(arch: str, shape: str, multi_pod: bool, outdir: Path, save_hlo: bool = False):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = outdir / f"{arch}__{shape}__{mesh_name}.json"
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "n_chips": 512 if multi_pod else 256,
        "status": "running",
    }
    spec = get_arch(arch)
    sh = spec.shapes[shape]
    if sh.skip:
        rec.update(status="skipped", skip_reason=sh.skip)
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[dryrun] SKIP {arch}/{shape}: {sh.skip}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    bundle = build_step(arch, shape, smoke=False, mesh=mesh)
    report = ResolveReport()
    state_sds = bundle.state_specs()
    state_sh = resolve_tree(bundle.state_logical, state_sds, mesh, report=report)
    batch_sh = resolve_tree(bundle.batch_logical, bundle.batch_specs, mesh, report=report)
    rec["sharding_fallbacks"] = report.fallbacks
    rec["notes"] = bundle.notes

    rec["modeled_memory"] = modeled_memory(bundle, state_sds, state_sh, batch_sh)
    jf = _make_jit(bundle, state_sh, batch_sh, mesh, report)

    t0 = time.time()
    with mesh:
        lowered = jf.lower(state_sds, bundle.batch_specs)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    rec["lower_s"] = round(t1 - t0, 2)
    rec["compile_s"] = round(t2 - t1, 2)

    mem = _mem_dict(compiled)
    print(compiled.memory_analysis())   # proves it fits (per-device bytes)
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    print({k: v for k, v in cost.items() if k in ("flops", "bytes accessed")})
    text = compiled.as_text()
    colls = parse_collectives(text)
    if save_hlo:
        (outdir / f"{arch}__{shape}__{mesh_name}.hlo.txt").write_text(text)
    rec["hlo_chars"] = len(text)
    del text

    mf = model_flops_for(bundle)
    cost_used, colls_used = dict(cost), colls
    spec_family = get_arch(arch).family
    if spec_family == "lm" and not multi_pod:
        # roofline-grade costs: unrolled depth extrapolation (single-pod only
        # — the roofline table is single-pod per the spec)
        try:
            cost_used, colls_used, detail = extrapolate_lm_cost(arch, shape, mesh)
            rec["cost_extrapolation"] = detail
        except Exception as e:
            rec["cost_extrapolation"] = {"failed": repr(e)}
    rf = roofline_from_cost(cost_used, colls_used, mesh.size, mf)
    rec.update(
        status="ok",
        memory=mem,
        cost_scan_module={
            k: float(v) for k, v in cost.items() if isinstance(v, (int, float))
        },
        cost={k: float(v) for k, v in cost_used.items()},
        collectives=colls_used,
        collectives_scan_module=colls,
        roofline=rf.to_dict(),
    )
    out_path.write_text(json.dumps(rec, indent=2))
    mm = rec["modeled_memory"]
    peak = (mem or {}).get("peak_bytes_per_device_est")
    xla = "" if peak is None else f" xla_peak={peak/1e9:.2f}GB"
    print(
        f"[dryrun] OK {arch}/{shape}/{mesh_name}: compile={rec['compile_s']}s "
        f"dominant={rf.dominant} frac={rf.roofline_fraction:.3f} "
        f"modeled/dev={mm['modeled_total_per_device']/1e9:.2f}GB "
        f"({'FITS' if mm['fits_16GB'] else 'OVER'}){xla}"
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = (
        all_cells(include_skipped=True)
        if args.all
        else [(args.arch, args.shape)]
    )
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            out_path = outdir / f"{arch}__{shape}__{mesh_name}.json"
            if args.skip_done and out_path.exists():
                try:
                    if json.loads(out_path.read_text()).get("status") in ("ok", "skipped"):
                        print(f"[dryrun] cached {arch}/{shape}/{mesh_name}")
                        continue
                except Exception:
                    pass
            try:
                run_cell(arch, shape, mp, outdir, save_hlo=args.save_hlo)
            except Exception as e:  # record the failure; it is a bug to fix
                failures.append((arch, shape, mesh_name, repr(e)))
                out_path.write_text(
                    json.dumps(
                        {
                            "arch": arch,
                            "shape": shape,
                            "mesh": mesh_name,
                            "status": "failed",
                            "error": repr(e),
                            "traceback": traceback.format_exc()[-4000:],
                        },
                        indent=2,
                    )
                )
                print(f"[dryrun] FAIL {arch}/{shape}/{mesh_name}: {e!r}")
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("   ", *f[:3], f[3][:200])
        raise SystemExit(1)
    print("[dryrun] all requested cells OK")


if __name__ == "__main__":
    main()
