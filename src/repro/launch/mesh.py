"""Production meshes.  A FUNCTION (not a module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 = 256 chips per pod (TPU v5e); 2 pods = 512 chips multi-pod.

    The dry-run host exposes 512 placeholder devices; the single-pod mesh
    uses the first 256 of them."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — launch "
            "via repro.launch.dryrun (it sets xla_force_host_platform_device_count)"
        )
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh: jax.sharding.Mesh) -> tuple:
    """The data-parallel axes of a mesh (pod axis included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
