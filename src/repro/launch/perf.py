import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

import argparse
import json
from pathlib import Path

import jax

from repro.configs import get_arch
from repro.launch.dryrun import extrapolate_lm_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.roofline.analysis import model_flops_for, roofline_from_cost

"""§Perf hillclimb runner: measure a (arch × shape) cell's roofline terms
under a named variant and append the hypothesis→before→after record to
results/perf/<arch>__<shape>.json."""


def measure(
    arch: str,
    shape: str,
    optimized: bool,
    no_fsdp: bool = False,
    replicate_inputs: bool = False,
):
    mesh = make_production_mesh()
    rules = None
    if no_fsdp or replicate_inputs:
        from repro.distributed.sharding import default_rules

        rules = default_rules(mesh)
        if no_fsdp:
            rules["embed"] = ()  # params TP-only; opt state follows params
        if replicate_inputs:
            for k in ("nodes", "edges", "triplets"):
                rules[k] = ()
    spec = get_arch(arch)
    if spec.family == "lm":
        cost, colls, detail = extrapolate_lm_cost(
            arch, shape, mesh, optimized=optimized, rules=rules
        )
    else:
        from repro.distributed.sharding import ResolveReport
        from repro.launch.dryrun import _cost_of

        bundle0 = build_step(arch, shape, mesh=mesh)
        cost, colls = _cost_of(bundle0, mesh, ResolveReport(), rules=rules)
    bundle = build_step(arch, shape, mesh=mesh, optimized=optimized)
    rf = roofline_from_cost(cost, colls, mesh.size, model_flops_for(bundle))
    return rf, colls


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, help="label, e.g. baseline | a2a-dispatch")
    ap.add_argument("--optimized", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate the embed/FSDP dim (TP-only params)")
    ap.add_argument("--replicate-inputs", action="store_true",
                    help="GNN: replicate node/edge inputs (kill reshard collectives)")
    ap.add_argument("--override", action="append", default=[],
                    help="config field override, e.g. attn_q_chunk=None")
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    path = outdir / f"{args.arch}__{args.shape}.json"
    log = json.loads(path.read_text()) if path.exists() else {"iterations": []}

    if args.override:
        import ast

        from repro.launch import steps as steps_mod

        for ov in args.override:
            k, v = ov.split("=", 1)
            steps_mod.PERF_OVERRIDES[k] = ast.literal_eval(v)
    rf, colls = measure(
        args.arch, args.shape, args.optimized, args.no_fsdp, args.replicate_inputs
    )
    entry = {
        "variant": args.variant,
        "optimized_flag": args.optimized,
        "no_fsdp": args.no_fsdp,
        "overrides": args.override,
        "hypothesis": args.hypothesis,
        "roofline": rf.to_dict(),
        "collectives": colls,
    }
    log["iterations"].append(entry)
    path.write_text(json.dumps(log, indent=2))
    print(
        f"[perf] {args.arch}/{args.shape} [{args.variant}]: "
        f"compute={rf.compute_s:.2f}s memory={rf.memory_s:.2f}s "
        f"collective={rf.collective_s:.2f}s dominant={rf.dominant} "
        f"frac={rf.roofline_fraction:.4f}"
    )


if __name__ == "__main__":
    main()
