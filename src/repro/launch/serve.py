"""Serving driver: ``python -m repro.launch.serve`` runs a gLava
:class:`repro.api.GraphStream` session against a synthetic network-traffic
stream with a mixed query workload served as ONE standing subscription —
registered (and planner-compiled) once before the stream starts, then
re-evaluated automatically every ``--every`` ingest batches, with
reachability refreshed incrementally from each batch's touched rows —
and prints throughput/accuracy stats.

``--tenants T`` switches to FLEET mode: the same synthetic stream is
tagged with zipf-distributed tenant ids and served by one
:class:`repro.fleet.SketchFleet` — every mixed batch is a single stacked
device dispatch, a few hot tenants carry standing subscriptions, and the
driver prints fleet-wide throughput plus the one-compile ingest cache
stat (DESIGN.md Section 11)."""
from __future__ import annotations

import argparse

import numpy as np

from repro.api import GraphStream, Query, QueryBatch, SketchConfig
from repro.core.ingest import BACKENDS
from repro.core.query_engine import QUERY_BACKENDS
from repro.data.graphs import edge_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=1024)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--nodes", type=int, default=100_000)
    ap.add_argument("--edges", type=int, default=500_000)
    ap.add_argument("--batch", type=int, default=50_000)
    ap.add_argument("--window-slices", type=int, default=0)
    ap.add_argument(
        "--every",
        type=int,
        default=1,
        help="re-evaluate the standing workload every k ingest batches",
    )
    ap.add_argument(
        "--ingest-backend",
        default="auto",
        choices=["auto", *BACKENDS],
        help="auto = pallas on TPU, scatter elsewhere (REPRO_INGEST_BACKEND overrides)",
    )
    ap.add_argument(
        "--query-backend",
        default="auto",
        choices=["auto", *QUERY_BACKENDS],
        help="auto = fused pallas multi-query kernel on TPU, jnp elsewhere "
        "(REPRO_QUERY_BACKEND overrides)",
    )
    ap.add_argument(
        "--tenants",
        type=int,
        default=0,
        help="serve T tenants as one SketchFleet (0 = single session)",
    )
    ap.add_argument(
        "--wal-dir",
        default=None,
        help="write-ahead-log directory: every batch is durably logged "
        "before its device dispatch (per-tenant lanes in fleet mode)",
    )
    ap.add_argument(
        "--slice-width",
        type=float,
        default=0.0,
        help="event-time slice width: with --window-slices, the stream "
        "carries per-edge timestamps and the watermark drives advances",
    )
    ap.add_argument(
        "--max-lateness",
        type=float,
        default=0.0,
        help="bounded out-of-orderness: edges older than the watermark "
        "minus this are late (retracted via the turnstile-delete path)",
    )
    args = ap.parse_args()

    cfg = SketchConfig(depth=args.depth, width_rows=args.width, width_cols=args.width)
    if args.tenants:
        return _serve_fleet(cfg, args)
    stream = GraphStream.open(
        cfg,
        window_slices=args.window_slices or None,
        ingest_backend=args.ingest_backend,
        query_backend=args.query_backend,
        wal_dir=args.wal_dir,
        slice_width=args.slice_width or None,
        max_lateness=args.max_lateness if args.slice_width else None,
    )
    rng = np.random.default_rng(0)
    data = edge_stream(args.nodes, args.edges, rng, zipf_a=1.2)
    ts_all = None
    if args.slice_width:
        # Synthetic event time: one slice per ingest batch, with bounded
        # out-of-orderness (uniform lag within --max-lateness) so the
        # watermark path and late routing are actually exercised.
        base = np.arange(args.edges, dtype=np.float64) * (
            args.slice_width / args.batch
        )
        ts_all = base - rng.uniform(0.0, max(args.max_lateness, 0.0), args.edges)
        ts_all = np.maximum(ts_all, 0.0)

    # The monitoring workload is STANDING: the same mixed batch re-asked
    # after every ingest batch.  Register it once — the planner compiles it
    # to one fused dispatch per family — and let the session re-evaluate it
    # on mutation, emitting timestamped events.
    qs = rng.integers(0, args.nodes, 1024).astype(np.uint32)
    qd = rng.integers(0, args.nodes, 1024).astype(np.uint32)
    workload = QueryBatch(
        [
            Query.edge(qs, qd),
            Query.in_flow(qs[:256]),
            Query.heavy(qs[:64], theta=0.01),
            Query.reach(qs[:64], qd[:64]),
        ]
    )
    sub = stream.subscribe(workload, every=args.every, name="mixed-workload")

    for lo in range(0, args.edges, args.batch):
        hi = min(args.edges, lo + args.batch)
        stream.ingest(
            data["src"][lo:hi],
            data["dst"][lo:hi],
            data["weight"][lo:hi],
            timestamps=None if ts_all is None else ts_all[lo:hi],
        )

    ticks = sub.poll()
    stats = stream.summary()
    print("[serve] " + " ".join(f"{k}={v:,.1f}" for k, v in stats.items()))
    print(
        f"[serve] subscription {sub.name!r}: {sub.ticks} ticks "
        f"({len(ticks)} events pending), last epoch {ticks[-1].epoch if ticks else '-'}, "
        f"closure full={stream.engine.closure_refreshes} "
        f"incremental={stream.engine.closure_incremental_refreshes}"
    )


def _serve_fleet(cfg: SketchConfig, args) -> None:
    from repro.fleet import SketchFleet

    fleet = SketchFleet.open(
        cfg,
        capacity=args.tenants,
        window_slices=args.window_slices or None,
        wal_dir=args.wal_dir,
    )
    rng = np.random.default_rng(0)
    data = edge_stream(args.nodes, args.edges, rng, zipf_a=1.2)
    # Skewed tenant load — a few hot tenants dominate, like real fleets.
    ids = (rng.zipf(1.3, args.edges) - 1) % args.tenants

    # Standing workloads on the three hottest tenants.
    qs = rng.integers(0, args.nodes, 256).astype(np.uint32)
    qd = rng.integers(0, args.nodes, 256).astype(np.uint32)
    workload = QueryBatch(
        [
            Query.edge(qs[:64], qd[:64]),
            Query.in_flow(qs[:64]),
            Query.reach(qs[:16], qd[:16]),
        ]
    )
    subs = [
        fleet.tenant(t).subscribe(workload, every=args.every, name=f"tenant-{t}")
        for t in range(min(3, args.tenants))
    ]

    for lo in range(0, args.edges, args.batch):
        hi = min(args.edges, lo + args.batch)
        fleet.ingest_mixed(
            ids[lo:hi],
            data["src"][lo:hi],
            data["dst"][lo:hi],
            data["weight"][lo:hi],
        )

    stats = fleet.summary()
    print("[serve-fleet] " + " ".join(f"{k}={v:,.1f}" for k, v in stats.items()))
    print(
        f"[serve-fleet] ingest compiles={fleet._ingest._cache_size()} "
        f"dispatches={fleet._ingest.dispatches} "
        f"subs={[s.ticks for s in subs]} ticks"
    )


if __name__ == "__main__":
    main()
