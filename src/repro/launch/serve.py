"""Serving driver: ``python -m repro.launch.serve`` runs a gLava
:class:`repro.api.GraphStream` session against a synthetic network-traffic
stream with a mixed query workload served as ONE standing subscription —
registered (and planner-compiled) once before the stream starts, then
re-evaluated automatically every ``--every`` ingest batches, with
reachability refreshed incrementally from each batch's touched rows —
and prints throughput/accuracy stats."""
from __future__ import annotations

import argparse

import numpy as np

from repro.api import GraphStream, Query, QueryBatch, SketchConfig
from repro.core.ingest import BACKENDS
from repro.core.query_engine import QUERY_BACKENDS
from repro.data.graphs import edge_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=1024)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--nodes", type=int, default=100_000)
    ap.add_argument("--edges", type=int, default=500_000)
    ap.add_argument("--batch", type=int, default=50_000)
    ap.add_argument("--window-slices", type=int, default=0)
    ap.add_argument(
        "--every",
        type=int,
        default=1,
        help="re-evaluate the standing workload every k ingest batches",
    )
    ap.add_argument(
        "--ingest-backend",
        default="auto",
        choices=["auto", *BACKENDS],
        help="auto = pallas on TPU, scatter elsewhere (REPRO_INGEST_BACKEND overrides)",
    )
    ap.add_argument(
        "--query-backend",
        default="auto",
        choices=["auto", *QUERY_BACKENDS],
        help="auto = fused pallas multi-query kernel on TPU, jnp elsewhere "
        "(REPRO_QUERY_BACKEND overrides)",
    )
    args = ap.parse_args()

    cfg = SketchConfig(depth=args.depth, width_rows=args.width, width_cols=args.width)
    stream = GraphStream.open(
        cfg,
        window_slices=args.window_slices or None,
        ingest_backend=args.ingest_backend,
        query_backend=args.query_backend,
    )
    rng = np.random.default_rng(0)
    data = edge_stream(args.nodes, args.edges, rng, zipf_a=1.2)

    # The monitoring workload is STANDING: the same mixed batch re-asked
    # after every ingest batch.  Register it once — the planner compiles it
    # to one fused dispatch per family — and let the session re-evaluate it
    # on mutation, emitting timestamped events.
    qs = rng.integers(0, args.nodes, 1024).astype(np.uint32)
    qd = rng.integers(0, args.nodes, 1024).astype(np.uint32)
    workload = QueryBatch(
        [
            Query.edge(qs, qd),
            Query.in_flow(qs[:256]),
            Query.heavy(qs[:64], theta=0.01),
            Query.reach(qs[:64], qd[:64]),
        ]
    )
    sub = stream.subscribe(workload, every=args.every, name="mixed-workload")

    for lo in range(0, args.edges, args.batch):
        hi = min(args.edges, lo + args.batch)
        stream.ingest(
            data["src"][lo:hi], data["dst"][lo:hi], data["weight"][lo:hi]
        )

    ticks = sub.poll()
    stats = stream.summary()
    print("[serve] " + " ".join(f"{k}={v:,.1f}" for k, v in stats.items()))
    print(
        f"[serve] subscription {sub.name!r}: {sub.ticks} ticks "
        f"({len(ticks)} events pending), last epoch {ticks[-1].epoch if ticks else '-'}, "
        f"closure full={stream.engine.closure_refreshes} "
        f"incremental={stream.engine.closure_incremental_refreshes}"
    )


if __name__ == "__main__":
    main()
