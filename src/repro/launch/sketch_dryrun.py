import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import glava as glava_cfg
from repro.core.distributed import distributed_edge_query, distributed_ingest
from repro.core.sketch import GLavaSketch
from repro.launch.mesh import make_production_mesh, dp_axes
from repro.roofline.analysis import parse_collectives, roofline_from_cost

"""Sketch-plane dry-run: the paper's OWN data structure lowered on the
production mesh — distributed ingest (stream over dp axes, rows over model,
psum merge) and batched edge queries, with roofline terms.  Complements the
40 arch cells with the paper-representative workload."""


def run(config_name: str, batch: int, multi_pod: bool, outdir: Path):
    cfg = getattr(glava_cfg, config_name.upper())
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_axes(mesh)
    sketch = GLavaSketch.empty(cfg, jax.random.key(0))

    counters_sh = NamedSharding(mesh, P(None, "model", None))
    stream_sh = NamedSharding(mesh, P(dp))
    rep = NamedSharding(mesh, P())

    sk_sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), sketch
    )

    def ingest(counters, src, dst, w):
        import dataclasses

        sk = dataclasses.replace(sketch, counters=counters)
        out = distributed_ingest(mesh, sk, src, dst, w, stream_axes=dp)
        return out.counters

    jf = jax.jit(
        ingest,
        in_shardings=(counters_sh, stream_sh, stream_sh, stream_sh),
        out_shardings=counters_sh,
        donate_argnums=(0,),
    )
    args = (
        jax.ShapeDtypeStruct(sketch.counters.shape, jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.uint32),
        jax.ShapeDtypeStruct((batch,), jnp.uint32),
        jax.ShapeDtypeStruct((batch,), jnp.float32),
    )
    with mesh:
        compiled = jf.lower(*args).compile()
    print(compiled.memory_analysis())
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else dict(cost)
    colls = parse_collectives(compiled.as_text())
    # useful flops: one-hot matmul formulation = 2 * d * B * (wr + wc) per
    # chip-set; the paper-faithful scalar semantics is d*B adds — report the
    # MXU formulation as model flops (it IS the TPU algorithm).
    model_flops = 2.0 * cfg.depth * batch * (cfg.width_rows + cfg.width_cols)
    rf = roofline_from_cost(dict(cost), colls, mesh.size, model_flops)
    rec = {
        "cell": f"glava-{config_name}/ingest_{batch}",
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "sketch": dict(depth=cfg.depth, wr=cfg.width_rows, wc=cfg.width_cols),
        "roofline": rf.to_dict(),
        "collectives": colls,
    }

    # query path
    def query(counters, qs, qd):
        import dataclasses

        sk = dataclasses.replace(sketch, counters=counters)
        return distributed_edge_query(mesh, sk, qs, qd)

    jq = jax.jit(query, in_shardings=(counters_sh, rep, rep), out_shardings=rep)
    qargs = (
        args[0],
        jax.ShapeDtypeStruct((65536,), jnp.uint32),
        jax.ShapeDtypeStruct((65536,), jnp.uint32),
    )
    with mesh:
        cq = jq.lower(*qargs).compile()
    qcost = cq.cost_analysis()
    qcost = qcost[0] if isinstance(qcost, (list, tuple)) else dict(qcost)
    qcolls = parse_collectives(cq.as_text())
    qrf = roofline_from_cost(dict(qcost), qcolls, mesh.size, 2.0 * cfg.depth * 65536)
    rec["query_roofline"] = qrf.to_dict()

    outdir.mkdir(parents=True, exist_ok=True)
    out = outdir / f"glava__{config_name}__{rec['mesh']}.json"
    out.write_text(json.dumps(rec, indent=2))
    print(
        f"[sketch-dryrun] {rec['cell']} on {rec['mesh']}: ingest "
        f"compute={rf.compute_s*1e3:.2f}ms memory={rf.memory_s*1e3:.2f}ms "
        f"collective={rf.collective_s*1e3:.2f}ms dominant={rf.dominant}; "
        f"query dominant={qrf.dominant} ({qrf.step_time_lb*1e6:.0f}µs lb)"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="web", choices=["web", "base", "nonsquare"])
    ap.add_argument("--batch", type=int, default=1 << 20)
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    for mp in (False, True):
        run(args.config, args.batch, mp, Path(args.out))


if __name__ == "__main__":
    main()
