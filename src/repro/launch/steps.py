"""Step factory: one (arch × shape) cell -> a jit-able step with init fns,
logical sharding specs, dry-run input specs, and concrete smoke batches.

This is the seam between the model zoo, the distribution layer and the
dry-run: ``build_step(arch, shape)`` returns a :class:`StepBundle` whose
``input_specs()`` are ShapeDtypeStructs (no allocation — full production
shapes) and whose ``make_batch()`` materializes reduced concrete data for
CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchSpec, ShapeSpec, get_arch, triplet_budget
from repro.data import graphs as graph_data
from repro.data import lm as lm_data
from repro.data import recsys as recsys_data
from repro.models import transformer as tfm
from repro.models.gnn import dimenet, gat, graphsage, schnet
from repro.models.gnn.common import GraphBatch
from repro.models.gnn.sampler import sampled_block_sizes
from repro.models.recsys import bert4rec
from repro.train import optimizer as opt_mod

F32, I32, U32, BF16 = jnp.float32, jnp.int32, jnp.uint32, jnp.bfloat16

# §Perf experiment channel: launch/perf.py drops config-field overrides here
# (e.g. {"attn_q_chunk": None}) so hillclimb variants need no signature churn.
PERF_OVERRIDES: dict = {}


@dataclasses.dataclass
class StepBundle:
    arch_id: str
    shape_name: str
    kind: str
    config: Any
    init_state: Callable[[jax.Array], Any]
    step: Callable
    state_logical: Any
    batch_logical: Any
    batch_specs: Dict[str, jax.ShapeDtypeStruct]
    make_batch: Callable[[np.random.Generator], Dict[str, np.ndarray]]
    is_train: bool
    out_logical: Any = None  # serve kinds: logical specs for outputs
    notes: str = ""

    def input_specs(self):
        """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
        return self.batch_specs

    def state_specs(self):
        return jax.eval_shape(self.init_state, jax.random.key(0))


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        if hasattr(x, "shape")
        else x,
        tree,
    )


def _opt_config(n_params: int) -> opt_mod.AdamWConfig:
    """Memory-fit heuristic (DESIGN.md Section 4): >100B params -> bf16
    moments (arctic on one pod would not fit fp32 m+v)."""
    if n_params > 100e9:
        return opt_mod.AdamWConfig(m_dtype=BF16, v_dtype=BF16)
    return opt_mod.AdamWConfig()


# ===========================================================================
# LM family
# ===========================================================================


def _lm_prod_config(
    cfg: tfm.TransformerConfig, mesh, kind: str, optimized: bool = False
):
    """Production knobs: chunked attention + remat + activation SP + MoE
    dispatch-buffer sharding.  ``optimized=True`` switches on the §Perf
    hillclimb levers (shard_map EP all-to-all dispatch); the default is the
    paper-faithful-parallelization BASELINE so both stay measurable."""
    from jax.sharding import NamedSharding

    act = None
    moe = cfg.moe
    if mesh is not None:
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        if kind in ("train", "prefill"):
            # NamedSharding (not bare PartitionSpec): usable without a
            # context mesh inside with_sharding_constraint
            act = NamedSharding(mesh, P(dp, "model", None))  # (batch, SP, ·)
        if moe is not None:
            # (E, C, D) dispatch/combine buffers: EP shards E, otherwise C
            # over the dp axes (unconstrained they replicate: +32 GB/chip).
            espec = "model" if moe.partition == "expert" else None
            moe = dataclasses.replace(
                moe, dispatch_pspec=NamedSharding(mesh, P(espec, dp, None))
            )
            if optimized and kind in ("train", "prefill"):
                moe = dataclasses.replace(moe, shard_dispatch=True, mesh=mesh)
    out = dataclasses.replace(
        cfg,
        attn_q_chunk=512 if kind in ("train", "prefill") else None,
        remat=kind == "train",
        act_pspec=act,
        moe=moe,
        attn_window_slicing=optimized and cfg.sliding_window is not None,
        attn_halo_mesh=(
            mesh
            if optimized and cfg.sliding_window is not None
            and kind in ("train", "prefill")
            else None
        ),
    )
    if PERF_OVERRIDES:
        out = dataclasses.replace(out, **PERF_OVERRIDES)
    return out


def _build_lm(
    spec: ArchSpec, shape: ShapeSpec, smoke: bool, mesh, optimized: bool = False
) -> StepBundle:
    cfg = (
        spec.smoke_config
        if smoke
        else _lm_prod_config(spec.config, mesh, shape.kind, optimized=optimized)
    )
    p = shape.params
    if smoke:
        batch = 2
        seq = 16 if shape.kind != "train" else 12
    else:
        batch, seq = p["global_batch"], p["seq_len"]

    pspec = tfm.param_specs(cfg)
    opt_cfg = _opt_config(cfg.param_count())

    if shape.kind == "train":

        def init_state(key):
            params = tfm.init_params(cfg, key)
            return {"params": params, "opt": opt_mod.init_adamw(opt_cfg, params)}

        def step(state, batch_in):
            def lfn(params):
                return tfm.loss_fn(cfg, params, batch_in["tokens"])

            (loss, metrics), grads = jax.value_and_grad(lfn, has_aux=True)(
                state["params"]
            )
            params, opt, om = opt_mod.apply_adamw(
                opt_cfg, state["opt"], state["params"], grads
            )
            return {"params": params, "opt": opt}, {"loss": loss, **metrics, **om}

        state_logical = {
            "params": pspec,
            "opt": opt_mod.AdamWState(step=None, m=pspec, v=pspec),
        }
        batch_logical = {"tokens": ("batch", None)}
        batch_specs = {"tokens": jax.ShapeDtypeStruct((batch, seq + 1), I32)}

        def make_batch(rng):
            gen = lm_data.MarkovTokens(cfg.vocab, seed=0)
            return {"tokens": gen.batch(batch, seq + 1, rng)}

        return StepBundle(
            spec.arch_id, shape.name, shape.kind, cfg, init_state, step,
            state_logical, batch_logical, batch_specs, make_batch, True,
        )

    if shape.kind == "prefill":

        def init_state(key):
            return tfm.init_params(cfg, key)

        def step(params, batch_in):
            return tfm.prefill(cfg, params, batch_in["tokens"], max_seq=seq)

        batch_logical = {"tokens": ("batch", None)}
        batch_specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), I32)}

        def make_batch(rng):
            gen = lm_data.MarkovTokens(cfg.vocab, seed=0)
            return {"tokens": gen.batch(batch, seq, rng)}

        prefill_cap = min(tfm.cache_capacity(cfg, seq), seq)
        out_logical = (
            ("batch", "vocab"),  # logits
            {
                "k": (None, "batch", None, None, "head_dim"),
                "v": (None, "batch", None, None, "head_dim"),
                "len": None,
            },
        )
        return StepBundle(
            spec.arch_id, shape.name, shape.kind, cfg, init_state, step,
            pspec, batch_logical, batch_specs, make_batch, False,
            out_logical=out_logical,
        )

    # decode: one new token against a KV cache of seq_len
    cap = tfm.cache_capacity(cfg, seq)

    def init_state(key):
        return tfm.init_params(cfg, key)

    def step(params, batch_in):
        return tfm.decode_step(cfg, params, batch_in["token"], batch_in["cache"])

    cache_logical = {
        "k": (None, "batch", "seq", None, "head_dim"),
        "v": (None, "batch", "seq", None, "head_dim"),
        "len": None,
    }
    batch_logical = {"token": ("batch",), "cache": cache_logical}
    cshape = (cfg.n_layers, batch, cap, cfg.n_kv_heads, cfg.head_dim)
    batch_specs = {
        "token": jax.ShapeDtypeStruct((batch,), I32),
        "cache": {
            "k": jax.ShapeDtypeStruct(cshape, cfg.compute_dtype),
            "v": jax.ShapeDtypeStruct(cshape, cfg.compute_dtype),
            "len": jax.ShapeDtypeStruct((), I32),
        },
    }

    def make_batch(rng):
        return {
            "token": rng.integers(0, cfg.vocab, batch).astype(np.int32),
            "cache": {
                "k": rng.normal(0, 1, cshape).astype(np.float32).astype(cfg.compute_dtype),
                "v": rng.normal(0, 1, cshape).astype(np.float32).astype(cfg.compute_dtype),
                "len": np.asarray(seq - 1, np.int32),
            },
        }

    return StepBundle(
        spec.arch_id, shape.name, shape.kind, cfg, init_state, step,
        pspec, batch_logical, batch_specs, make_batch, False,
        out_logical=(("batch", "vocab"), cache_logical),
        notes=f"cache capacity {cap} ({'ring/SWA' if cap < seq else 'full'})",
    )


# ===========================================================================
# GNN family
# ===========================================================================

_MOL_ATOM_TYPES = 100
_MOL_FEAT = 16  # continuous features for sage/gat on the molecule shape


def _pad512(x: int) -> int:
    """Pad graph dims to a 512 multiple so the dp axes always divide them
    (padding carries node_mask/edge_mask = False)."""
    return ((x + 511) // 512) * 512


def _gnn_shape_dims(spec: ArchSpec, shape: ShapeSpec, smoke: bool):
    p = dict(shape.params)
    if shape.kind == "gnn_full":
        if smoke:
            p.update(n_nodes=64, n_edges=256, d_feat=16, n_classes=4)
        else:
            p["n_real_nodes"], p["n_real_edges"] = p["n_nodes"], p["n_edges"]
            p.update(n_nodes=_pad512(p["n_nodes"]), n_edges=_pad512(p["n_edges"]))
        return p
    if shape.kind == "gnn_minibatch":
        if smoke:
            p.update(batch_nodes=8, fanouts=(3, 2), d_feat=16, n_classes=4)
        n_nodes, n_edges = sampled_block_sizes(p["batch_nodes"], p["fanouts"])
        p.update(n_nodes=n_nodes, n_edges=n_edges)
        return p
    # molecule
    if smoke:
        p.update(batch=4, n_nodes=10, n_edges=16)
    return p


def _gnn_config(spec: ArchSpec, shape: ShapeSpec, smoke: bool, dims):
    cfg = spec.smoke_config if smoke else spec.config
    molecular = spec.arch_id in ("schnet", "dimenet")
    if shape.kind == "gnn_molecule":
        if molecular:
            return dataclasses.replace(
                cfg, feature_mode="embed_types", task="graph_reg", out_dim=1
            )
        return dataclasses.replace(cfg, d_in=_MOL_FEAT, out_dim=1)
    if molecular:
        return dataclasses.replace(
            cfg,
            feature_mode="project",
            d_in=dims["d_feat"],
            task="node_class",
            out_dim=dims["n_classes"],
        )
    return dataclasses.replace(cfg, d_in=dims["d_feat"], out_dim=dims["n_classes"])


def _gnn_forward(arch_id: str, cfg, params, g: GraphBatch, n_graphs: int):
    if arch_id == "graphsage-reddit":
        return graphsage.forward(cfg, params, g)
    if arch_id == "gat-cora":
        return gat.forward(cfg, params, g)
    if arch_id == "schnet":
        if cfg.task == "graph_reg":
            return schnet.forward_ngraphs(cfg, params, g, n_graphs)
        return schnet.forward(cfg, params, g)
    if arch_id == "dimenet":
        return dimenet.forward(cfg, params, g, n_graphs=n_graphs)
    raise ValueError(arch_id)


def _gnn_init(arch_id: str, cfg, key):
    mod = {
        "graphsage-reddit": graphsage,
        "gat-cora": gat,
        "schnet": schnet,
        "dimenet": dimenet,
    }[arch_id]
    return mod.init_params(cfg, key)


def _build_gnn(spec: ArchSpec, shape: ShapeSpec, smoke: bool, mesh) -> StepBundle:
    dims = _gnn_shape_dims(spec, shape, smoke)
    cfg = _gnn_config(spec, shape, smoke, dims)
    arch_id = spec.arch_id
    molecular = arch_id in ("schnet", "dimenet")
    needs_triplets = arch_id == "dimenet"
    is_mol = shape.kind == "gnn_molecule"
    n = dims["n_nodes"] if not is_mol else dims["batch"] * dims["n_nodes"]
    e = dims["n_edges"] if not is_mol else dims["batch"] * dims["n_edges"]
    n_graphs = dims.get("batch", 1) if is_mol else 1
    t = triplet_budget(e) if needs_triplets else 0
    opt_cfg = _opt_config(0)

    feat_spec = (
        jax.ShapeDtypeStruct((n,), I32)
        if (molecular and is_mol)
        else jax.ShapeDtypeStruct((n, dims.get("d_feat", _MOL_FEAT)), F32)
    )
    gb_specs = dict(
        node_feat=feat_spec,
        edge_src=jax.ShapeDtypeStruct((e,), I32),
        edge_dst=jax.ShapeDtypeStruct((e,), I32),
        node_mask=jax.ShapeDtypeStruct((n,), jnp.bool_),
        edge_mask=jax.ShapeDtypeStruct((e,), jnp.bool_),
    )
    gb_logical = dict(
        node_feat=("nodes", None) if feat_spec.ndim == 2 else ("nodes",),
        edge_src=("edges",),
        edge_dst=("edges",),
        node_mask=("nodes",),
        edge_mask=("edges",),
    )
    if molecular:
        gb_specs["positions"] = jax.ShapeDtypeStruct((n, 3), F32)
        gb_logical["positions"] = ("nodes", None)
    if is_mol:
        gb_specs["graph_ids"] = jax.ShapeDtypeStruct((n,), I32)
        gb_logical["graph_ids"] = ("nodes",)
    if needs_triplets:
        gb_specs["triplets"] = {
            "in": jax.ShapeDtypeStruct((t,), I32),
            "out": jax.ShapeDtypeStruct((t,), I32),
            "mask": jax.ShapeDtypeStruct((t,), F32),
        }
        gb_logical["triplets"] = {
            "in": ("triplets",),
            "out": ("triplets",),
            "mask": ("triplets",),
        }

    if is_mol:
        label_spec = jax.ShapeDtypeStruct((n_graphs, 1), F32)
        label_logical = (None, None)
    else:
        label_spec = jax.ShapeDtypeStruct((n,), I32)
        label_logical = ("nodes",)
    batch_specs = {
        "graph": gb_specs,
        "labels": label_spec,
        "loss_mask": jax.ShapeDtypeStruct(
            (n_graphs,) if is_mol else (n,), F32
        ),
    }
    batch_logical = {
        "graph": gb_logical,
        "labels": label_logical,
        "loss_mask": (None,) if is_mol else ("nodes",),
    }

    def to_graphbatch(d):
        return GraphBatch(
            node_feat=d["node_feat"],
            edge_src=d["edge_src"],
            edge_dst=d["edge_dst"],
            node_mask=d["node_mask"],
            edge_mask=d["edge_mask"],
            positions=d.get("positions"),
            graph_ids=d.get("graph_ids"),
            triplets=d.get("triplets"),
        )

    def init_state(key):
        params = _gnn_init(arch_id, cfg, key)
        return {"params": params, "opt": opt_mod.init_adamw(opt_cfg, params)}

    def step(state, batch_in):
        g = to_graphbatch(batch_in["graph"])

        def lfn(params):
            out = _gnn_forward(arch_id, cfg, params, g, n_graphs)
            if is_mol and not molecular:
                # sage/gat emit per-node values -> mean-readout per graph
                num = jax.ops.segment_sum(
                    out * g.node_mask[:, None], g.graph_ids, num_segments=n_graphs
                )
                cnt = jax.ops.segment_sum(
                    g.node_mask.astype(jnp.float32), g.graph_ids, num_segments=n_graphs
                )
                out = num / jnp.maximum(cnt, 1.0)[:, None]
            if is_mol:  # graph regression (MSE)
                err = (out - batch_in["labels"]) ** 2
                loss = jnp.sum(err[:, 0] * batch_in["loss_mask"]) / jnp.maximum(
                    jnp.sum(batch_in["loss_mask"]), 1.0
                )
            else:  # masked node classification
                logits = out.astype(jnp.float32)
                logz = jax.nn.logsumexp(logits, -1)
                gold = jnp.take_along_axis(
                    logits, batch_in["labels"][:, None].astype(jnp.int32), axis=1
                )[:, 0]
                loss = jnp.sum((logz - gold) * batch_in["loss_mask"]) / jnp.maximum(
                    jnp.sum(batch_in["loss_mask"]), 1.0
                )
            return loss, {"xent": loss}

        (loss, metrics), grads = jax.value_and_grad(lfn, has_aux=True)(state["params"])
        params, opt, om = opt_mod.apply_adamw(opt_cfg, state["opt"], state["params"], grads)
        return {"params": params, "opt": opt}, {"loss": loss, **metrics, **om}

    param_logical = jax.tree.map(lambda _: None, jax.eval_shape(
        lambda k: _gnn_init(arch_id, cfg, k), jax.random.key(0)
    ))  # GNN params are tiny -> replicated
    state_logical = {
        "params": param_logical,
        "opt": opt_mod.AdamWState(step=None, m=param_logical, v=param_logical),
    }

    def make_batch(rng):
        if is_mol:
            d = graph_data.molecule_batch(
                n_graphs, dims["n_nodes"], dims["n_edges"], _MOL_ATOM_TYPES
                if not smoke else cfg.n_atom_types if molecular else _MOL_ATOM_TYPES,
                rng,
            )
            if not molecular:
                # continuous features for sage/gat: one-hot-ish projections
                d["node_feat"] = rng.normal(
                    0, 1, (n, _MOL_FEAT)
                ).astype(np.float32)
            if not molecular:
                d.pop("positions")
            labels = d.pop("labels")
            loss_mask = np.ones(n_graphs, np.float32)
        else:
            d = graph_data.citation_graph(
                n, e, dims["d_feat"], dims["n_classes"], rng
            )
            labels = d.pop("labels")
            if not molecular:
                d.pop("positions")
            loss_mask = (rng.random(n) < 0.5).astype(np.float32)
            if shape.kind == "gnn_minibatch":
                # only seed slots contribute to the loss
                loss_mask = np.zeros(n, np.float32)
                loss_mask[: dims["batch_nodes"]] = 1.0
        d["node_mask"] = np.ones(n, bool)
        d["edge_mask"] = np.ones(e, bool)
        if needs_triplets:
            trip = graph_data.build_triplets(d["edge_src"], d["edge_dst"], t)
            trip.pop("truncated")
            d["triplets"] = trip
        return {"graph": d, "labels": labels, "loss_mask": loss_mask}

    return StepBundle(
        arch_id, shape.name, shape.kind, cfg, init_state, step,
        state_logical, batch_logical, batch_specs, make_batch, True,
        notes=f"n={n} e={e}" + (f" triplets={t}" if needs_triplets else ""),
    )


# ===========================================================================
# RecSys family (bert4rec)
# ===========================================================================


def _build_recsys(spec: ArchSpec, shape: ShapeSpec, smoke: bool, mesh) -> StepBundle:
    cfg = spec.smoke_config if smoke else spec.config
    p = shape.params
    batch = 2 if smoke else p["batch"]
    seq = cfg.seq_len
    pspec = bert4rec.param_specs(cfg)
    opt_cfg = _opt_config(cfg.param_count())

    if shape.kind == "recsys_train":
        m, k = cfg.max_masked, cfg.n_negatives

        def init_state(key):
            params = bert4rec.init_params(cfg, key)
            return {"params": params, "opt": opt_mod.init_adamw(opt_cfg, params)}

        def step(state, batch_in):
            def lfn(params):
                return bert4rec.cloze_loss_sampled(
                    cfg,
                    params,
                    batch_in["items"],
                    batch_in["mask_positions"],
                    batch_in["mask_targets"],
                    batch_in["negatives"],
                )

            (loss, metrics), grads = jax.value_and_grad(lfn, has_aux=True)(
                state["params"]
            )
            params, opt, om = opt_mod.apply_adamw(
                opt_cfg, state["opt"], state["params"], grads
            )
            return {"params": params, "opt": opt}, {"loss": loss, **metrics, **om}

        state_logical = {
            "params": pspec,
            "opt": opt_mod.AdamWState(step=None, m=pspec, v=pspec),
        }
        batch_logical = {
            "items": ("batch", None),
            "mask_positions": ("batch", None),
            "mask_targets": ("batch", None),
            "negatives": (None,),
        }
        batch_specs = {
            "items": jax.ShapeDtypeStruct((batch, seq), I32),
            "mask_positions": jax.ShapeDtypeStruct((batch, m), I32),
            "mask_targets": jax.ShapeDtypeStruct((batch, m), I32),
            "negatives": jax.ShapeDtypeStruct((k,), I32),
        }

        def make_batch(rng):
            items = recsys_data.interaction_sequences(cfg.n_items, batch, seq, rng)
            masked, positions, targets = recsys_data.cloze_mask_positions(
                items, cfg.mask_id, m, rng
            )
            return {
                "items": masked,
                "mask_positions": positions,
                "mask_targets": targets,
                "negatives": rng.integers(1, cfg.n_items + 1, k).astype(np.int32),
            }

        return StepBundle(
            spec.arch_id, shape.name, shape.kind, cfg, init_state, step,
            state_logical, batch_logical, batch_specs, make_batch, True,
        )

    def init_state(key):
        return bert4rec.init_params(cfg, key)

    if shape.kind == "recsys_serve":

        def step(params, batch_in):
            return bert4rec.score_all_items(cfg, params, batch_in["items"])

        batch_logical = {"items": ("batch", None)}
        batch_specs = {"items": jax.ShapeDtypeStruct((batch, seq), I32)}
        out_logical = ("batch", "vocab")

        def make_batch(rng):
            return {
                "items": recsys_data.interaction_sequences(cfg.n_items, batch, seq, rng)
            }

    else:  # retrieval_cand
        n_cand = 16 if smoke else p["n_candidates"]

        def step(params, batch_in):
            return bert4rec.score_candidates(
                cfg, params, batch_in["items"], batch_in["candidates"]
            )

        batch_logical = {
            "items": ("batch", None),
            "candidates": ("batch", "candidates"),
        }
        batch_specs = {
            "items": jax.ShapeDtypeStruct((batch, seq), I32),
            "candidates": jax.ShapeDtypeStruct((batch, n_cand), I32),
        }
        out_logical = ("batch", "candidates")

        def make_batch(rng):
            return {
                "items": recsys_data.interaction_sequences(cfg.n_items, batch, seq, rng),
                "candidates": rng.integers(1, cfg.n_items + 1, (batch, n_cand)).astype(
                    np.int32
                ),
            }

    return StepBundle(
        spec.arch_id, shape.name, shape.kind, cfg, init_state, step,
        pspec, batch_logical, batch_specs, make_batch, False,
        out_logical=out_logical,
    )


# ===========================================================================
# entry point
# ===========================================================================


def build_step(
    arch_id: str,
    shape_name: str,
    smoke: bool = False,
    mesh: Optional[jax.sharding.Mesh] = None,
    config_override: Optional[Any] = None,
    optimized: bool = False,
) -> StepBundle:
    """config_override replaces the arch's full config (used by the dry-run
    depth-extrapolation: same arch at n_layers ∈ {1, 2}, unrolled).
    ``optimized`` enables the §Perf hillclimb levers (vs the baseline)."""
    spec = get_arch(arch_id)
    if config_override is not None:
        spec = dataclasses.replace(spec, config=config_override)
    shape = spec.shapes[shape_name]
    if shape.skip and not smoke:
        raise ValueError(f"{arch_id}/{shape_name} skipped: {shape.skip}")
    if spec.family == "lm":
        return _build_lm(spec, shape, smoke, mesh, optimized=optimized)
    if spec.family == "gnn":
        return _build_gnn(spec, shape, smoke, mesh)
    if spec.family == "recsys":
        return _build_recsys(spec, shape, smoke, mesh)
    raise ValueError(spec.family)
