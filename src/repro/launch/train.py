"""Generic training driver: ``python -m repro.launch.train --arch <id>``.

Runs the arch's train shape at smoke scale on the local devices (full scale
is the dry-run's job on this CPU host), with checkpoint/resume, the
straggler watchdog, and optional sketched gradient compression.
"""
from __future__ import annotations

import argparse
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.steps import build_step
from repro.train.trainer import TrainerConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    shape = args.shape or {
        "lm": "train_4k", "gnn": "full_graph_sm", "recsys": "train_batch"
    }[spec.family]
    bundle = build_step(args.arch, shape, smoke=True)
    rng = np.random.default_rng(args.seed)

    def batches():
        while True:
            yield bundle.make_batch(rng)

    cfg = TrainerConfig(
        total_steps=args.steps,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        log_every=max(1, args.steps // 10),
    )
    res = train_loop(bundle.init_state, bundle.step, batches(), cfg, seed=args.seed)
    first, last = res.history[0]["loss"], res.history[-1]["loss"]
    print(
        f"[train] {args.arch}/{shape}: {args.steps} steps, "
        f"loss {first:.4f} -> {last:.4f}"
        + (f" (resumed from step {res.resumed_from})" if res.resumed_from else "")
    )
    if res.straggler_steps:
        print(f"[train] watchdog flagged {len(res.straggler_steps)} straggler steps")


if __name__ == "__main__":
    main()
