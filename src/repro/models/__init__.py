from repro.models import gnn, layers, recsys, transformer
from repro.models.transformer import TransformerConfig

__all__ = ["gnn", "layers", "recsys", "transformer", "TransformerConfig"]
