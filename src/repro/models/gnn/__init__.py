from repro.models.gnn.common import GraphBatch
from repro.models.gnn import common, dimenet, gat, graphsage, sampler, schnet

__all__ = ["GraphBatch", "common", "dimenet", "gat", "graphsage", "sampler", "schnet"]
