"""GNN substrate: padded graph batches and segment-op message passing.

JAX has no sparse CSR / EmbeddingBag — message passing is built from
``jax.ops.segment_sum`` / ``segment_max`` over explicit edge-index arrays
(the spec's required realization).  All shapes are static (padded + masked)
so every model lowers cleanly under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """A padded (batch of) graph(s).

    ``node_feat`` is float features OR integer atom types (molecular nets).
    Padded edges carry ``edge_mask == False`` and point at node 0.
    ``graph_ids`` maps nodes to graphs for batched-small-graph readout.
    """

    node_feat: jax.Array           # (N, F) float32 or (N,) int32
    edge_src: jax.Array            # (E,) int32
    edge_dst: jax.Array            # (E,) int32
    node_mask: jax.Array           # (N,) bool
    edge_mask: jax.Array           # (E,) bool
    positions: Optional[jax.Array] = None   # (N, 3) float32
    graph_ids: Optional[jax.Array] = None   # (N,) int32
    # DimeNet-style triplet index lists {"in": (T,), "out": (T,), "mask": (T,)}
    triplets: Optional[dict] = None

    @property
    def n_nodes(self) -> int:
        return self.node_feat.shape[0]

    @property
    def n_edges(self) -> int:
        return self.edge_src.shape[0]


def scatter_sum(messages: jax.Array, dst: jax.Array, n_nodes: int, mask=None):
    if mask is not None:
        messages = messages * mask[:, None].astype(messages.dtype)
    return jax.ops.segment_sum(messages, dst, num_segments=n_nodes)


def scatter_mean(messages: jax.Array, dst: jax.Array, n_nodes: int, mask=None):
    if mask is None:
        mask = jnp.ones(messages.shape[0], bool)
    s = scatter_sum(messages, dst, n_nodes, mask)
    deg = jax.ops.segment_sum(mask.astype(jnp.float32), dst, num_segments=n_nodes)
    return s / jnp.maximum(deg, 1.0)[:, None]


def scatter_max(messages: jax.Array, dst: jax.Array, n_nodes: int, mask=None):
    if mask is not None:
        messages = jnp.where(mask[:, None], messages, -jnp.inf)
    out = jax.ops.segment_max(messages, dst, num_segments=n_nodes)
    return jnp.where(jnp.isfinite(out), out, 0.0)


def segment_softmax(scores: jax.Array, dst: jax.Array, n_nodes: int, mask=None):
    """Numerically-stable softmax over edges grouped by destination node.
    scores: (E, H)."""
    if mask is not None:
        scores = jnp.where(mask[:, None], scores, -jnp.inf)
    mx = jax.ops.segment_max(scores, dst, num_segments=n_nodes)  # (N, H)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(scores - mx[dst])
    if mask is not None:
        ex = ex * mask[:, None]
    denom = jax.ops.segment_sum(ex, dst, num_segments=n_nodes)
    return ex / jnp.maximum(denom[dst], 1e-16)


def graph_readout_sum(node_vals: jax.Array, graph_ids: jax.Array, n_graphs: int, node_mask):
    vals = node_vals * node_mask[:, None].astype(node_vals.dtype)
    return jax.ops.segment_sum(vals, graph_ids, num_segments=n_graphs)


def edge_distances(positions: jax.Array, src: jax.Array, dst: jax.Array, mask):
    """Pairwise distances per edge (molecular nets).  Padded edges -> 1.0 to
    keep rsqrt/denominators finite."""
    diff = positions[dst] - positions[src]
    d = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 1e-12))
    return jnp.where(mask, d, 1.0), diff


def dense_init(key, shape, fan_in, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) / np.sqrt(fan_in)


def mlp_params(key, dims, prefix=""):
    ps = {}
    keys = jax.random.split(key, len(dims) - 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        ps[f"{prefix}w{i}"] = dense_init(keys[i], (a, b), a)
        ps[f"{prefix}b{i}"] = jnp.zeros((b,), jnp.float32)
    return ps


def mlp_apply(ps, x, n_layers, prefix="", act=jax.nn.silu, final_act=False):
    for i in range(n_layers):
        x = x @ ps[f"{prefix}w{i}"] + ps[f"{prefix}b{i}"]
        if i < n_layers - 1 or final_act:
            x = act(x)
    return x
