"""DimeNet (Klicpera et al., arXiv:2003.03123) — directional message passing.

Kernel regime: TRIPLET gather (k→j→i index lists), not expressible as SpMM.
Messages live on directed edges; each interaction block mixes incoming
messages m_kj into m_ji through a (radial × angular) basis and a bilinear
layer (n_bilinear=8).

Faithful structure with one documented simplification (DESIGN.md): the 2-D
spherical basis uses Bessel-sine radial functions × Legendre polynomials
P_l(cos α) instead of spherical Bessel zeros j_l(z_ln·d/c)·Y_l(α) — same
tensor shapes, same triplet dataflow, simpler special functions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.common import (
    GraphBatch,
    dense_init,
    edge_distances,
    graph_readout_sum,
    mlp_apply,
    mlp_params,
)


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_atom_types: int = 100
    feature_mode: str = "embed_types"
    d_in: int = 0
    out_dim: int = 1
    task: str = "graph_reg"


def bessel_rbf(d: jax.Array, n_radial: int, cutoff: float) -> jax.Array:
    """DimeNet radial basis: sqrt(2/c) * sin(n π d / c) / d."""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    d_safe = jnp.maximum(d, 1e-6)[:, None]
    return np.sqrt(2.0 / cutoff) * jnp.sin(n * np.pi * d_safe / cutoff) / d_safe


def legendre_cos(cos_a: jax.Array, n_spherical: int) -> jax.Array:
    """P_l(cos α) for l = 0..n_spherical-1 via the recurrence."""
    outs = [jnp.ones_like(cos_a), cos_a]
    for l in range(2, n_spherical):
        p = ((2 * l - 1) * cos_a * outs[-1] - (l - 1) * outs[-2]) / l
        outs.append(p)
    return jnp.stack(outs[:n_spherical], axis=-1)  # (T, L)


def spherical_basis(d_in: jax.Array, cos_a: jax.Array, cfg: DimeNetConfig):
    """(T,) dist of incoming edge × (T,) angle -> (T, n_spherical*n_radial)."""
    rad = bessel_rbf(d_in, cfg.n_radial, cfg.cutoff)      # (T, R)
    ang = legendre_cos(cos_a, cfg.n_spherical)            # (T, L)
    return (rad[:, None, :] * ang[:, :, None]).reshape(d_in.shape[0], -1)


def init_params(cfg: DimeNetConfig, key: jax.Array) -> Dict:
    keys = jax.random.split(key, cfg.n_blocks + 4)
    f = cfg.d_hidden
    s = cfg.n_spherical * cfg.n_radial
    params: Dict = {}
    if cfg.feature_mode == "embed_types":
        params["embed"] = dense_init(keys[0], (cfg.n_atom_types, f), f)
    else:
        params["proj"] = dense_init(keys[0], (cfg.d_in, f), cfg.d_in)
    params["rbf_proj"] = dense_init(keys[1], (cfg.n_radial, f), cfg.n_radial)
    params.update(mlp_params(keys[2], [3 * f, f, f], "emb_"))
    blocks = []
    for i in range(cfg.n_blocks):
        k = keys[i + 3]
        ks = jax.random.split(k, 6)
        blocks.append(
            {
                "w_msg": dense_init(ks[0], (f, f), f),
                "w_down": dense_init(ks[1], (f, cfg.n_bilinear), f),
                "w_bil": dense_init(ks[2], (s, cfg.n_bilinear, f), s * cfg.n_bilinear),
                "w_rbf_gate": dense_init(ks[3], (cfg.n_radial, f), cfg.n_radial),
                **mlp_params(ks[4], [f, f, f], "upd_"),
                # per-block output head: edge -> node contribution
                "w_out_rbf": dense_init(ks[5], (cfg.n_radial, f), cfg.n_radial),
                **mlp_params(jax.random.fold_in(ks[5], 1), [f, f, cfg.out_dim], "out_"),
            }
        )
    params["blocks"] = blocks
    return params


def forward(cfg: DimeNetConfig, params: Dict, g: GraphBatch, n_graphs: int = 1):
    """g must carry triplet index arrays in ``g.triplets`` — see
    :func:`repro.data.graphs.build_triplets`.  Returns (n_graphs, out_dim)
    for graph_reg or (N, out_dim) for node_class."""
    trip = g.triplets
    t_in, t_out, t_mask = trip["in"], trip["out"], trip["mask"]
    if cfg.feature_mode == "embed_types":
        h = params["embed"][g.node_feat.astype(jnp.int32)]
    else:
        h = g.node_feat.astype(jnp.float32) @ params["proj"]
    n, e = g.n_nodes, g.n_edges
    d, diff = edge_distances(g.positions, g.edge_src, g.edge_dst, g.edge_mask)
    rbf = bessel_rbf(d, cfg.n_radial, cfg.cutoff)         # (E, R)
    # triplet angles at vertex j for (k->j)=t_in, (j->i)=t_out:
    # cos α = (x_k - x_j)·(x_i - x_j) / (|..| |..|)
    v_in = -diff[t_in]    # x_k - x_j  (diff is x_dst - x_src)
    v_out = diff[t_out]   # x_i - x_j
    num = jnp.sum(v_in * v_out, axis=-1)
    den = jnp.maximum(d[t_in] * d[t_out], 1e-6)
    cos_a = jnp.clip(num / den, -1.0, 1.0)
    sbf = spherical_basis(d[t_in], cos_a, cfg) * t_mask[:, None]  # (T, S)

    # embedding block: m_ji = MLP([h_j, h_i, W rbf])
    m = mlp_apply(
        params,
        jnp.concatenate([h[g.edge_src], h[g.edge_dst], rbf @ params["rbf_proj"]], -1),
        2,
        "emb_",
    )  # (E, F)
    m = m * g.edge_mask[:, None]

    node_out = jnp.zeros((n, cfg.out_dim), jnp.float32)
    for bp in params["blocks"]:
        # directional interaction: gather m_kj, mix with sbf via bilinear form
        a = (m @ bp["w_down"])[t_in]                       # (T, B)
        contrib = jnp.einsum("ts,tb,sbf->tf", sbf, a, bp["w_bil"])  # (T, F)
        agg = jax.ops.segment_sum(
            contrib * t_mask[:, None], t_out, num_segments=e
        )  # (E, F)
        gate = rbf @ bp["w_rbf_gate"]                      # (E, F)
        m = m + mlp_apply(bp, jax.nn.silu(m @ bp["w_msg"] * gate + agg), 2, "upd_")
        m = m * g.edge_mask[:, None]
        # output block: edges -> destination nodes
        edge_val = m * (rbf @ bp["w_out_rbf"])
        node_feat = jax.ops.segment_sum(
            edge_val * g.edge_mask[:, None], g.edge_dst, num_segments=n
        )
        node_out = node_out + mlp_apply(bp, node_feat, 2, "out_")

    if cfg.task == "graph_reg":
        gid = g.graph_ids if g.graph_ids is not None else jnp.zeros((n,), jnp.int32)
        return graph_readout_sum(node_out, gid, n_graphs, g.node_mask)
    return node_out
