"""GAT (Velickovic et al., arXiv:1710.10903) — attention aggregator via
SDDMM-style edge scores + segment softmax.

Assigned config gat-cora: 2 layers, d_hidden=8, 8 heads (layer-1 concat ->
64; final layer heads averaged into out_dim logits, as in the paper).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.gnn.common import GraphBatch, dense_init, segment_softmax


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 8   # per head
    n_heads: int = 8
    out_dim: int = 7
    negative_slope: float = 0.2


def init_params(cfg: GATConfig, key: jax.Array) -> Dict:
    layers = []
    d_prev = cfg.d_in
    keys = jax.random.split(key, cfg.n_layers)
    for i in range(cfg.n_layers):
        final = i == cfg.n_layers - 1
        d_out = cfg.out_dim if final else cfg.d_hidden
        k1, k2, k3 = jax.random.split(keys[i], 3)
        layers.append(
            {
                "w": dense_init(k1, (d_prev, cfg.n_heads * d_out), d_prev),
                "a_src": dense_init(k2, (cfg.n_heads, d_out), d_out),
                "a_dst": dense_init(k3, (cfg.n_heads, d_out), d_out),
            }
        )
        d_prev = d_out if final else cfg.n_heads * d_out
    return {"layers": layers}


def forward(cfg: GATConfig, params: Dict, g: GraphBatch) -> jax.Array:
    h = g.node_feat.astype(jnp.float32)
    n = g.n_nodes
    for i, lp in enumerate(params["layers"]):
        final = i == cfg.n_layers - 1
        d_out = cfg.out_dim if final else cfg.d_hidden
        wh = (h @ lp["w"]).reshape(n, cfg.n_heads, d_out)
        # SDDMM-style scores on edges
        s_src = jnp.einsum("nhd,hd->nh", wh, lp["a_src"])  # (N, H)
        s_dst = jnp.einsum("nhd,hd->nh", wh, lp["a_dst"])
        scores = jax.nn.leaky_relu(
            s_src[g.edge_src] + s_dst[g.edge_dst], cfg.negative_slope
        )  # (E, H)
        alpha = segment_softmax(scores, g.edge_dst, n, g.edge_mask)  # (E, H)
        msgs = wh[g.edge_src] * alpha[..., None]  # (E, H, D)
        agg = jax.ops.segment_sum(
            msgs * g.edge_mask[:, None, None], g.edge_dst, num_segments=n
        )
        if final:
            h = jnp.mean(agg, axis=1)  # average heads -> (N, out_dim)
        else:
            h = jax.nn.elu(agg.reshape(n, cfg.n_heads * d_out))
    return h
