"""GraphSAGE (Hamilton et al., arXiv:1706.02216) with mean aggregation.

Assigned config graphsage-reddit: 2 layers, d_hidden=128, mean aggregator,
sample sizes 25-10 (the minibatch_lg shape overrides fanouts to 15-10 per the
assignment).  Works full-batch or on sampled padded subgraphs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.gnn.common import (
    GraphBatch,
    dense_init,
    scatter_mean,
)


@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    name: str = "graphsage"
    n_layers: int = 2
    d_in: int = 602
    d_hidden: int = 128
    out_dim: int = 41
    aggregator: str = "mean"


def init_params(cfg: SAGEConfig, key: jax.Array) -> Dict:
    layers = []
    keys = jax.random.split(key, cfg.n_layers + 1)
    d_prev = cfg.d_in
    for i in range(cfg.n_layers):
        layers.append(
            {
                "w_self": dense_init(keys[i], (d_prev, cfg.d_hidden), d_prev),
                "w_neigh": dense_init(
                    jax.random.fold_in(keys[i], 1), (d_prev, cfg.d_hidden), d_prev
                ),
                "b": jnp.zeros((cfg.d_hidden,), jnp.float32),
            }
        )
        d_prev = cfg.d_hidden
    head = dense_init(keys[-1], (cfg.d_hidden, cfg.out_dim), cfg.d_hidden)
    return {"layers": layers, "head": head}


def forward(cfg: SAGEConfig, params: Dict, g: GraphBatch) -> jax.Array:
    """Returns per-node logits (N, out_dim)."""
    h = g.node_feat.astype(jnp.float32)
    n = g.n_nodes
    for lp in params["layers"]:
        neigh = scatter_mean(h[g.edge_src], g.edge_dst, n, g.edge_mask)
        h = jax.nn.relu(h @ lp["w_self"] + neigh @ lp["w_neigh"] + lp["b"])
        # L2 normalize as in the paper (Section 3.1, line 7)
        h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    return h @ params["head"]
