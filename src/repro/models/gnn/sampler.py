"""Layered uniform neighbor sampling over CSR (GraphSAGE minibatch training).

Host-side (numpy) by design: sampling is data-pipeline work that feeds padded
device batches.  This is a REAL sampler (uniform with replacement per the
GraphSAGE paper's estimator) over a CSR adjacency, producing static-shape
padded subgraphs so the jitted train step never recompiles.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray   # (N+1,) int64
    indices: np.ndarray  # (E,) int32
    n_nodes: int

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> "CSRGraph":
        """CSR over incoming edges: row v holds the in-neighbors of v
        (GraphSAGE aggregates from in-neighbors)."""
        order = np.argsort(dst, kind="stable")
        s, d = src[order], dst[order]
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(indptr, d + 1, 1)
        indptr = np.cumsum(indptr)
        return CSRGraph(indptr, s.astype(np.int32), n_nodes)

    def degree(self, v: np.ndarray) -> np.ndarray:
        return (self.indptr[v + 1] - self.indptr[v]).astype(np.int64)

    def sample_neighbors(self, v: np.ndarray, fanout: int, rng) -> np.ndarray:
        """Uniform-with-replacement sample of `fanout` in-neighbors per node;
        isolated nodes get self-loops.  Returns (len(v), fanout) int32."""
        deg = self.degree(v)
        off = rng.integers(0, 2**62, size=(len(v), fanout)) % np.maximum(deg, 1)[:, None]
        idx = self.indptr[v][:, None] + off
        nbrs = self.indices[np.minimum(idx, len(self.indices) - 1)]
        return np.where(deg[:, None] > 0, nbrs, v[:, None]).astype(np.int32)


def sampled_block_sizes(batch_nodes: int, fanouts: Sequence[int]) -> Tuple[int, int]:
    """Padded (n_nodes, n_edges) of a merged k-hop sampled subgraph."""
    n_nodes = batch_nodes
    frontier = batch_nodes
    n_edges = 0
    for f in fanouts:
        n_edges += frontier * f
        frontier *= f
        n_nodes += frontier
    return n_nodes, n_edges


def sample_subgraph(
    g: CSRGraph,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    rng,
    features: np.ndarray | None = None,
):
    """Merged multi-hop sampled subgraph with STATIC padded shapes.

    Returns dict of numpy arrays:
      nodes       (N_pad,)  global node ids (padding repeats node 0)
      edge_src/dst(E_pad,)  LOCAL indices into `nodes`
      node_mask / edge_mask
      seed_slots  (B,)      local indices of the seeds (always 0..B-1)
    """
    n_pad, e_pad = sampled_block_sizes(len(seeds), fanouts)
    nodes = [seeds.astype(np.int32)]
    src_l: List[np.ndarray] = []
    dst_l: List[np.ndarray] = []
    frontier = seeds.astype(np.int32)
    frontier_local = np.arange(len(seeds), dtype=np.int32)
    next_local = len(seeds)
    for f in fanouts:
        nbrs = g.sample_neighbors(frontier, f, rng)              # (|F|, f)
        flat = nbrs.reshape(-1)
        local_ids = np.arange(next_local, next_local + len(flat), dtype=np.int32)
        # message edge: neighbor -> frontier node
        src_l.append(local_ids)
        dst_l.append(np.repeat(frontier_local, f))
        nodes.append(flat)
        frontier = flat
        frontier_local = local_ids
        next_local += len(flat)
    nodes = np.concatenate(nodes)
    edge_src = np.concatenate(src_l)
    edge_dst = np.concatenate(dst_l)
    node_mask = np.ones(len(nodes), bool)
    edge_mask = np.ones(len(edge_src), bool)
    # pad to static sizes
    nodes = np.pad(nodes, (0, n_pad - len(nodes)))
    node_mask = np.pad(node_mask, (0, n_pad - len(node_mask)))
    edge_src = np.pad(edge_src, (0, e_pad - len(edge_src)))
    edge_dst = np.pad(edge_dst, (0, e_pad - len(edge_dst)))
    edge_mask = np.pad(edge_mask, (0, e_pad - len(edge_mask)))
    out = {
        "nodes": nodes,
        "edge_src": edge_src,
        "edge_dst": edge_dst,
        "node_mask": node_mask,
        "edge_mask": edge_mask,
        "seed_slots": np.arange(len(seeds), dtype=np.int32),
    }
    if features is not None:
        out["node_feat"] = features[nodes]
    return out


def degree_weighted_seeds(
    degrees: np.ndarray, batch: int, rng, alpha: float = 0.5
) -> np.ndarray:
    """Importance seed sampling ∝ deg^alpha — the hook where the gLava sketch
    plugs in: on a STREAMED graph the exact degree table does not exist, and
    ``repro.integration.sketch_sampler`` substitutes sketch-estimated
    degrees (paper point queries) here."""
    p = np.power(np.maximum(degrees.astype(np.float64), 1.0), alpha)
    p /= p.sum()
    return rng.choice(len(degrees), size=batch, replace=False, p=p).astype(np.int32)
