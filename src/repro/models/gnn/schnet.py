"""SchNet (Schütt et al., arXiv:1706.08566) — continuous-filter convolutions.

Assigned config: 3 interactions, d_hidden=64, 300 RBF centers, cutoff 10 Å.
Kernel regime: triplet-free edge gather + RBF filter MLP + scatter-sum.

Two task heads: ``graph_reg`` (energy; the molecule shape) and
``node_class`` (per-node logits; the citation/product graph shapes — SchNet
still consumes 3-D positions, synthesized by the data pipeline there).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.common import (
    GraphBatch,
    dense_init,
    edge_distances,
    graph_readout_sum,
    mlp_apply,
    mlp_params,
    scatter_sum,
)


def shifted_softplus(x):
    return jax.nn.softplus(x) - np.log(2.0)


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_atom_types: int = 100
    feature_mode: str = "embed_types"  # or "project" (continuous node feats)
    d_in: int = 0                       # used when feature_mode == "project"
    out_dim: int = 1
    task: str = "graph_reg"             # "graph_reg" | "node_class"


def rbf_expand(d: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """Gaussian radial basis on [0, cutoff] (gamma as in SchNet)."""
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 10.0 / cutoff
    return jnp.exp(-gamma * jnp.square(d[:, None] - centers[None, :]))


def init_params(cfg: SchNetConfig, key: jax.Array) -> Dict:
    keys = jax.random.split(key, cfg.n_interactions + 3)
    params: Dict = {}
    if cfg.feature_mode == "embed_types":
        params["embed"] = dense_init(keys[0], (cfg.n_atom_types, cfg.d_hidden), cfg.d_hidden)
    else:
        params["proj"] = dense_init(keys[0], (cfg.d_in, cfg.d_hidden), cfg.d_in)
    blocks = []
    for i in range(cfg.n_interactions):
        k = keys[i + 1]
        blocks.append(
            {
                # cfconv filter generator: rbf -> d_hidden (2-layer MLP)
                **mlp_params(k, [cfg.n_rbf, cfg.d_hidden, cfg.d_hidden], "filt_"),
                "w_in": dense_init(jax.random.fold_in(k, 1), (cfg.d_hidden, cfg.d_hidden), cfg.d_hidden),
                **mlp_params(
                    jax.random.fold_in(k, 2), [cfg.d_hidden, cfg.d_hidden, cfg.d_hidden], "out_"
                ),
            }
        )
    params["blocks"] = blocks
    params.update(
        mlp_params(keys[-1], [cfg.d_hidden, cfg.d_hidden // 2, cfg.out_dim], "head_")
    )
    return params


def forward(cfg: SchNetConfig, params: Dict, g: GraphBatch) -> jax.Array:
    """Returns (n_graphs, out_dim) for graph_reg or (N, out_dim) for node_class."""
    if cfg.feature_mode == "embed_types":
        h = params["embed"][g.node_feat.astype(jnp.int32)]
    else:
        h = g.node_feat.astype(jnp.float32) @ params["proj"]
    n = g.n_nodes
    d, _ = edge_distances(g.positions, g.edge_src, g.edge_dst, g.edge_mask)
    rbf = rbf_expand(d, cfg.n_rbf, cfg.cutoff)
    # smooth cutoff envelope (cosine)
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d / cfg.cutoff, 0, 1)) + 1.0)
    for bp in params["blocks"]:
        w_filter = mlp_apply(bp, rbf, 2, "filt_", act=shifted_softplus, final_act=True)
        w_filter = w_filter * env[:, None]
        msg = (h @ bp["w_in"])[g.edge_src] * w_filter       # (E, d_hidden)
        agg = scatter_sum(msg, g.edge_dst, n, g.edge_mask)
        h = h + mlp_apply(bp, agg, 2, "out_", act=shifted_softplus)
    out = mlp_apply(params, h, 2, "head_", act=shifted_softplus)  # (N, out_dim)
    if cfg.task == "graph_reg":
        n_graphs = 1 if g.graph_ids is None else int(jnp.max(g.graph_ids)) + 1
        gid = g.graph_ids if g.graph_ids is not None else jnp.zeros((n,), jnp.int32)
        return graph_readout_sum(out, gid, n_graphs, g.node_mask)
    return out


def forward_ngraphs(cfg: SchNetConfig, params: Dict, g: GraphBatch, n_graphs: int):
    """jit-friendly variant with static n_graphs for graph_reg readout."""
    out = forward(
        dataclasses.replace(cfg, task="node_class"), params, g
    )
    gid = g.graph_ids if g.graph_ids is not None else jnp.zeros((g.n_nodes,), jnp.int32)
    return graph_readout_sum(out, gid, n_graphs, g.node_mask)
