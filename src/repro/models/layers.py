"""Transformer building blocks: norms, RoPE, GQA attention (full / sliding
window / decode-with-cache), SwiGLU MLP, and capacity-based MoE.

Everything is a pure function over explicit param pytrees (pjit-friendly);
layer params are stacked on a leading L axis and consumed with ``lax.scan``
to keep HLO size independent of depth.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compat import shard_map


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: Optional[jax.Array], eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    return y.astype(dtype)


def layer_norm_nonparam(x: jax.Array, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm: standardize, no scale/bias."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dtype)


def apply_norm(kind: str, x: jax.Array, weight: Optional[jax.Array]):
    if kind == "rmsnorm":
        return rms_norm(x, weight)
    if kind == "layernorm_nonparam":
        return layer_norm_nonparam(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)  # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def gqa_attention(
    q: jax.Array,  # (B, Sq, Hq, Dh)
    k: jax.Array,  # (B, Skv, Hkv, Dh)
    v: jax.Array,  # (B, Skv, Hkv, Dh)
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,
    sliding_window: Optional[int] = None,
    kv_valid_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Grouped-query attention.  q head h attends kv head h // (Hq//Hkv).

    ``q_offset``: absolute position of q[0] (decode: the cache length).
    ``kv_valid_len``: number of valid cache slots (decode with ring/partial
    caches); None means all of Skv is valid.
    """
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    groups = hq // hkv
    qg = q.reshape(b, sq, hkv, groups, dh)
    scale = 1.0 / np.sqrt(dh)
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale  # (B, Hkv, G, Sq, Skv)

    q_pos = jnp.arange(sq)[:, None] + q_offset  # (Sq, 1) absolute
    k_pos = jnp.arange(skv)[None, :]            # (1, Skv) cache slot index
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if sliding_window is not None:
        mask &= k_pos > q_pos - sliding_window
    mask_b = jnp.broadcast_to(mask, (b, 1, 1, sq, skv))
    if kv_valid_len is not None:
        valid = k_pos < jnp.reshape(kv_valid_len, (-1, 1, 1, 1, 1))
        mask_b = mask_b & valid
    logits = jnp.where(mask_b, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, dh)


def chunked_attention(
    q: jax.Array,  # (B, Sq, Hq, Dh)
    k: jax.Array,  # (B, Skv, Hkv, Dh)
    v: jax.Array,
    *,
    causal: bool,
    sliding_window: Optional[int] = None,
    q_chunk: int = 512,
    window_slicing: bool = False,
) -> jax.Array:
    """Query-chunked attention with rematerialized chunk bodies.

    Peak live memory is one (B, Hkv, G, q_chunk, Skv) fp32 logits block
    instead of the full S² score tensor; ``jax.checkpoint`` on the chunk body
    keeps backward memory at the same bound (probs are recomputed, not
    stored).

    ``window_slicing`` (§Perf lever for SWA archs): each query chunk attends
    only a dynamic (window + q_chunk)-wide KV slice instead of all of Skv —
    attention FLOPs drop Skv/(window+q_chunk)-fold (7.1× on mixtral
    prefill_32k).  Baseline (False) computes the masked dense blocks."""
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / np.sqrt(dh)
    nq = -(-sq // q_chunk)
    pad = nq * q_chunk - sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = qp.reshape(b, nq, q_chunk, hkv, g, dh).transpose(1, 0, 3, 4, 2, 5)
    # (nq, B, Hkv, G, qc, Dh)

    sliced = (
        window_slicing
        and sliding_window is not None
        and skv > sliding_window + q_chunk
    )
    if sliced:
        # front-pad so every chunk's (window + qc) slice is in-bounds; real
        # kv position = slice_start + offset - window
        win = sliding_window
        kp = jnp.pad(k, ((0, 0), (win, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (win, 0), (0, 0), (0, 0)))

    @jax.checkpoint
    def one_chunk(args):
        qi, qblk = args
        q_pos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
        if sliced:
            start = qi * q_chunk  # in padded coords: covers q_lo-win .. q_hi
            kb = jax.lax.dynamic_slice_in_dim(kp, start, win + q_chunk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vp, start, win + q_chunk, axis=1)
            k_pos = start + jnp.arange(win + q_chunk)[None, :] - win
        else:
            kb, vb = k, v
            k_pos = jnp.arange(skv)[None, :]
        logits = jnp.einsum(
            "bhgqd,bkhd->bhgqk", qblk.astype(jnp.float32), kb.astype(jnp.float32)
        ) * scale
        mask = k_pos >= 0
        if causal:
            mask &= k_pos <= q_pos
        if sliding_window is not None:
            mask &= k_pos > q_pos - sliding_window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhgqk,bkhd->bhgqd", probs.astype(vb.dtype), vb)

    out = jax.lax.map(one_chunk, (jnp.arange(nq), qb))  # (nq, B, Hkv, G, qc, Dh)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * q_chunk, hq, dh)
    return out[:, :sq]


def decode_attention(
    q: jax.Array,        # (B, 1, Hq, Dh)
    cache_k: jax.Array,  # (B, Skv, Hkv, Dh) — k already rotated at write time
    cache_v: jax.Array,
    cache_len: jax.Array,  # (B,) or scalar: number of valid slots
) -> jax.Array:
    """One-token decode against a (possibly ring) KV cache.  Ring caches pass
    cache_len == capacity once full; ordering inside the ring is irrelevant
    for plain (non-ALiBi) attention since k carries its own rotation."""
    return gqa_attention(
        q,
        cache_k,
        cache_v,
        causal=False,
        kv_valid_len=cache_len,
    )


def swa_attention_halo(
    q: jax.Array,  # (B, S, Hq, Dh) sharded (dp, model, ·, ·)
    k: jax.Array,  # (B, S, Hkv, Dh)
    v: jax.Array,
    *,
    sliding_window: int,
    mesh,
    q_chunk: int = 512,
) -> jax.Array:
    """SWA attention with HALO EXCHANGE instead of a full KV gather (§Perf
    iteration 4 on mixtral prefill_32k).

    With seq sharded tp-ways, a window-w query shard only needs keys from
    itself + ceil(w / s_loc) left neighbors.  A traced-start dynamic_slice
    on the sharded seq axis makes GSPMD all-gather K/V entirely (measured
    2.4 GB × 56 layers per chip); here each shard ppermutes its K/V shard
    rightward n_halo times (n_halo × 134 MB on the same cell) and attends
    locally.  Requires w < S·(tp-1)/tp — otherwise it degenerates to full
    attention and the caller should use the dense path."""
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    tp = mesh.shape["model"]
    s_total = q.shape[1]
    s_loc = s_total // tp
    n_halo = min(-(-sliding_window // s_loc), tp - 1)
    fwd = [(i, (i + 1) % tp) for i in range(tp)]

    def body(q_loc, k_loc, v_loc):
        b_loc = q_loc.shape[0]
        rank = jax.lax.axis_index("model")
        ks, vs = [k_loc], [v_loc]
        ck, cv = k_loc, v_loc
        for _ in range(n_halo):
            ck = jax.lax.ppermute(ck, "model", fwd)
            cv = jax.lax.ppermute(cv, "model", fwd)
            ks.insert(0, ck)
            vs.insert(0, cv)
        k_ext = jnp.concatenate(ks, axis=1)  # ((n_halo+1)·s_loc, …)
        v_ext = jnp.concatenate(vs, axis=1)
        # global positions: my q rows start at rank·s_loc; k_ext starts
        # n_halo shards earlier (ring wrap-around rows get k_pos < 0 → masked)
        q_start = rank * s_loc
        k_pos = q_start - n_halo * s_loc + jnp.arange((n_halo + 1) * s_loc)

        hq, dh = q_loc.shape[2], q_loc.shape[3]
        hkv = k_loc.shape[2]
        g = hq // hkv
        scale = 1.0 / np.sqrt(dh)
        nq = s_loc // q_chunk
        qb = q_loc.reshape(b_loc, nq, q_chunk, hkv, g, dh).transpose(1, 0, 3, 4, 2, 5)

        @jax.checkpoint
        def one_chunk(args):
            qi, qblk = args
            q_pos = q_start + qi * q_chunk + jnp.arange(q_chunk)[:, None]
            logits = jnp.einsum(
                "bhgqd,bkhd->bhgqk",
                qblk.astype(jnp.float32),
                k_ext.astype(jnp.float32),
            ) * scale
            mask = (k_pos[None, :] >= 0) & (k_pos[None, :] <= q_pos)
            mask &= k_pos[None, :] > q_pos - sliding_window
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            probs = jax.nn.softmax(logits, axis=-1)
            return jnp.einsum("bhgqk,bkhd->bhgqd", probs.astype(v_ext.dtype), v_ext)

        out = jax.lax.map(one_chunk, (jnp.arange(nq), qb))
        return out.transpose(1, 0, 4, 2, 3, 5).reshape(b_loc, s_loc, hq, dh)

    spec = P(dp, "model", None, None)
    return shard_map(
        body, mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec,
        check_vma=False,
    )(q, k, v)


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


@dataclasses.dataclass(frozen=True)
class MoEArgs:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    dense_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    aux_loss_coef: float = 0.01
    # sharding strategy: "expert" (EP over model axis) or "ffn" (TP inside
    # each expert; used when n_experts doesn't divide the model axis)
    partition: str = "expert"
    # PartitionSpec for the (E, C, D) dispatch/combine buffers — without a
    # constraint GSPMD replicates them (measured: +32 GB/chip on mixtral
    # train_4k).  Set by the step factory from the live mesh.
    dispatch_pspec: Optional[Any] = None
    # shard_map EP dispatch (§Perf hillclimb): explicit all-to-all token
    # exchange instead of GSPMD-resolved gather/scatter (which all-gathers
    # the full token buffer and all-reduces the combine — measured 100×
    # collective overhead on arctic).  Requires a mesh and seq % model == 0,
    # so only the train/prefill paths enable it.
    shard_dispatch: bool = False
    mesh: Optional[Any] = None  # jax.sharding.Mesh (hashable; config stays static)


def moe_capacity(n_tokens: int, args: MoEArgs) -> int:
    c = int(np.ceil(n_tokens * args.top_k / args.n_experts * args.capacity_factor))
    return max(4, int(np.ceil(c / 4)) * 4)


def moe_block(
    x: jax.Array,  # (T, D)
    router_w: jax.Array,  # (D, E)
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,    # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    args: MoEArgs,
) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based top-k MoE with gather dispatch / scatter combine.

    No giant one-hot einsum: dispatch is an (E, C) index table + gather, so
    compiled FLOPs ≈ active-expert FLOPs × capacity factor (keeps the
    MODEL_FLOPS/HLO_FLOPS roofline ratio honest — DESIGN.md Section 4).
    Returns (output (T, D), aux_loss scalar).
    """
    t, d = x.shape
    e, k = args.n_experts, args.top_k
    c = moe_capacity(t, args)

    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balance aux loss (Switch/GShard style).
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = args.aux_loss_coef * e * jnp.sum(me * ce)

    # Slot assignment: rank of each (token, k) within its expert.
    e_flat = expert_idx.reshape(-1)                                # (T*K,)
    gate_flat = gate_vals.reshape(-1)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)            # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]  # (T*K,)
    keep = slot < c
    token_id = jnp.arange(t * k) // k

    # (E, C) dispatch tables; dropped tokens scatter to expert index E (OOB →
    # dropped by XLA scatter semantics), unfilled slots point at the zero row.
    e_safe = jnp.where(keep, e_flat, e)
    slot_safe = jnp.clip(slot, 0, c - 1)
    table = jnp.full((e, c), t, jnp.int32).at[e_safe, slot_safe].set(token_id)
    gates = jnp.zeros((e, c), jnp.float32).at[e_safe, slot_safe].set(gate_flat)

    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = x_pad[table]  # (E, C, D) gather

    def _csp(a):
        if args.dispatch_pspec is not None:
            return jax.lax.with_sharding_constraint(a, args.dispatch_pspec)
        return a

    xe = _csp(xe)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", xe, w_up
    )
    ye = _csp(jnp.einsum("ecf,efd->ecd", h, w_down))  # (E, C, D)
    ye = ye * gates[..., None].astype(ye.dtype)

    y = jnp.zeros((t + 1, d), x.dtype).at[table.reshape(-1)].add(
        ye.reshape(-1, d)
    )[:t]
    return y, aux


# ---------------------------------------------------------------------------
# shard_map EP dispatch (§Perf): explicit all-to-all instead of GSPMD gather
# ---------------------------------------------------------------------------


def _route_local(x, router_w, e, k, cap_factor, aux_coef):
    """Route LOCAL tokens -> ((E, C_loc) token table, gates, aux).  Pure
    per-device math, no collectives."""
    t, d = x.shape
    c = moe_capacity(t, MoEArgs(n_experts=e, top_k=k, capacity_factor=cap_factor))
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=1), axis=0)
    aux = aux_coef * e * jnp.sum(me * ce)
    e_flat = expert_idx.reshape(-1)
    gate_flat = gate_vals.reshape(-1)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]
    keep = slot < c
    token_id = jnp.arange(t * k) // k
    e_safe = jnp.where(keep, e_flat, e)
    slot_safe = jnp.clip(slot, 0, c - 1)
    table = jnp.full((e, c), t, jnp.int32).at[e_safe, slot_safe].set(token_id)
    gates = jnp.zeros((e, c), jnp.float32).at[e_safe, slot_safe].set(gate_flat)
    return table, gates, aux


def moe_ffn_sharded(
    x: jax.Array,  # (B, S, D) activations, sharded (dp, model, ·)
    router_w: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    args: MoEArgs,
) -> Tuple[jax.Array, jax.Array]:
    """Expert FFN with EXPLICIT collectives under shard_map.

    partition="expert" (EP): local route → local gather → all_to_all(model)
    tokens→experts → expert matmuls → reverse all_to_all → local combine.
    Per-layer wire: 2× the (E, C_loc, D) buffer + the FSDP weight gather —
    vs GSPMD's all-gather of the FULL (T, D) token buffer + an all-reduce of
    the (T, D) combine (measured 100× more bytes on arctic train_4k).

    partition="ffn" (TP inside experts, mixtral): no token exchange — every
    device computes all experts on its local tokens with the F/tp weight
    shard, then one psum of the (E, C_loc, D) partial combine.
    """
    from jax.sharding import PartitionSpec as P

    mesh = args.mesh
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    tp = mesh.shape["model"]
    e, k = args.n_experts, args.top_k

    if args.partition == "expert":

        def body(xb, rw, wg, wu, wd):
            b_loc, s_loc, d = xb.shape
            xl = xb.reshape(b_loc * s_loc, d)
            # FSDP gather of this shard's experts (transpose = reduce-scatter)
            wg = jax.lax.all_gather(wg, dp, axis=1, tiled=True)  # (E/tp, D, F)
            wu = jax.lax.all_gather(wu, dp, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, dp, axis=2, tiled=True)  # (E/tp, F, D)
            table, gates, aux = _route_local(
                xl, rw, e, k, args.capacity_factor, args.aux_loss_coef
            )
            c_loc = table.shape[1]
            x_pad = jnp.concatenate([xl, jnp.zeros((1, d), xl.dtype)], axis=0)
            xe = x_pad[table]                                   # (E, C_loc, D)
            # tokens -> expert owners
            xe = jax.lax.all_to_all(xe, "model", split_axis=0, concat_axis=1, tiled=True)
            gt = jax.lax.all_to_all(
                gates[..., None], "model", split_axis=0, concat_axis=1, tiled=True
            )[..., 0]                                           # (E/tp, tp*C_loc)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum(
                "ecd,edf->ecf", xe, wu
            )
            ye = jnp.einsum("ecf,efd->ecd", h, wd)
            ye = ye * gt[..., None].astype(ye.dtype)
            # expert outputs -> token owners
            ye = jax.lax.all_to_all(ye, "model", split_axis=1, concat_axis=0, tiled=True)
            y = jnp.zeros((b_loc * s_loc + 1, d), xb.dtype).at[
                table.reshape(-1)
            ].add(ye.reshape(-1, d))[: b_loc * s_loc]
            aux = jax.lax.pmean(aux, ("model",) + dp)
            return y.reshape(b_loc, s_loc, d), aux

        wspec_in = P("model", dp, None)    # (E→model, D→dp/FSDP, F)
        wspec_dn = P("model", None, dp)    # (E→model, F, D→dp)
    else:  # "ffn": Megatron-style TP inside each expert (E < tp, mixtral)

        def body(xb, rw, wg, wu, wd):
            # tokens are sharded over model (SP); the F-contraction psum
            # requires every model-peer to process the SAME token set →
            # gather the model-axis token shards first, compute the F/tp
            # partial for all of them, psum, then slice back (Megatron SP).
            b_loc, s_loc, d = xb.shape
            t_loc = b_loc * s_loc
            xl = xb.reshape(t_loc, d)
            xl = jax.lax.all_gather(xl, "model", axis=0, tiled=True)  # (tp·t, D)
            wg = jax.lax.all_gather(wg, dp, axis=1, tiled=True)  # (E, D, F/tp)
            wu = jax.lax.all_gather(wu, dp, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, dp, axis=2, tiled=True)  # (E, F/tp, D)
            table, gates, aux = _route_local(
                xl, rw, e, k, args.capacity_factor, args.aux_loss_coef
            )  # identical on every model peer (same inputs)
            x_pad = jnp.concatenate([xl, jnp.zeros((1, d), xl.dtype)], axis=0)
            xe = x_pad[table]                                   # (E, C, D)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum(
                "ecd,edf->ecf", xe, wu
            )
            ye = jnp.einsum("ecf,efd->ecd", h, wd)              # partial over F
            ye = ye * gates[..., None].astype(ye.dtype)
            # combine FIRST (still partial over F), then psum_scatter: each
            # peer only needs its own t_loc token rows, so reducing the
            # (tp·t_loc, D) combine costs tp× less wire than psum-ing the
            # (E, C, D) expert buffer (§Perf iteration 3: 3.77 GB -> 0.76 GB
            # per layer on mixtral prefill_32k).
            y = jnp.zeros((xl.shape[0] + 1, d), xb.dtype).at[
                table.reshape(-1)
            ].add(ye.reshape(-1, d))[: xl.shape[0]]
            y = jax.lax.psum_scatter(y, "model", scatter_dimension=0, tiled=True)
            aux = jax.lax.pmean(aux, ("model",) + dp)
            return y.reshape(b_loc, s_loc, d), aux

        wspec_in = P(None, dp, "model")
        wspec_dn = P(None, "model", dp)

    act_spec = P(dp, "model", None)
    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(act_spec, P(None, None), wspec_in, wspec_in, wspec_dn),
        out_specs=(act_spec, P()),
        check_vma=False,
    )(x, router_w, w_gate, w_up, w_down)
    return y, aux
