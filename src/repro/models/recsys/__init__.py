from repro.models.recsys import bert4rec
from repro.models.recsys.bert4rec import Bert4RecConfig, embedding_bag

__all__ = ["bert4rec", "Bert4RecConfig", "embedding_bag"]
