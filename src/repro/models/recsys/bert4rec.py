"""BERT4Rec (Sun et al., arXiv:1904.06690) — bidirectional self-attention
sequential recommender with Cloze (masked-item) training.

Assigned config: embed_dim=64, 2 blocks, 2 heads, seq_len=200, bidirectional
interaction.  The item-embedding table is the huge-sparse-table axis of the
recsys regime (1M items here); lookups are gathers, and the multi-hot bag
path is EmbeddingBag built from take + segment_sum (JAX has no native one).

Encoder-only: no autoregressive decode — the four recsys shapes are
train_batch (Cloze loss), serve_p99 / serve_bulk (score all items at masked
positions), retrieval_cand (one user against 1M candidates).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str = "bert4rec"
    n_items: int = 1_000_000     # vocab incl. [PAD]=0; [MASK]=n_items+1
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    d_ff_mult: int = 4
    n_negatives: int = 2048      # sampled-softmax negatives (train_batch)
    compute_dtype: object = jnp.bfloat16

    @property
    def vocab(self) -> int:
        # PAD + MASK, padded to a 512 multiple so the vocab axis shards
        # evenly on the 16/32-way mesh axes.
        return ((self.n_items + 2 + 511) // 512) * 512

    @property
    def max_masked(self) -> int:
        return max(1, self.seq_len // 4)

    @property
    def mask_id(self) -> int:
        return self.n_items + 1

    def param_count(self) -> int:
        d = self.embed_dim
        per = 4 * d * d + 2 * d * d * self.d_ff_mult
        return self.vocab * d + self.seq_len * d + self.n_blocks * per


def init_params(cfg: Bert4RecConfig, key: jax.Array) -> Dict:
    d = cfg.embed_dim
    keys = iter(jax.random.split(key, 4 + 8 * cfg.n_blocks))

    def init(k, shape, fan):
        return jax.random.normal(k, shape, jnp.float32) / np.sqrt(fan)

    blocks = {
        "wq": jnp.stack([init(next(keys), (d, d), d) for _ in range(cfg.n_blocks)]),
        "wk": jnp.stack([init(next(keys), (d, d), d) for _ in range(cfg.n_blocks)]),
        "wv": jnp.stack([init(next(keys), (d, d), d) for _ in range(cfg.n_blocks)]),
        "wo": jnp.stack([init(next(keys), (d, d), d) for _ in range(cfg.n_blocks)]),
        "w1": jnp.stack(
            [init(next(keys), (d, d * cfg.d_ff_mult), d) for _ in range(cfg.n_blocks)]
        ),
        "w2": jnp.stack(
            [init(next(keys), (d * cfg.d_ff_mult, d), d * cfg.d_ff_mult) for _ in range(cfg.n_blocks)]
        ),
        "ln1_w": jnp.ones((cfg.n_blocks, d), jnp.float32),
        "ln1_b": jnp.zeros((cfg.n_blocks, d), jnp.float32),
        "ln2_w": jnp.ones((cfg.n_blocks, d), jnp.float32),
        "ln2_b": jnp.zeros((cfg.n_blocks, d), jnp.float32),
    }
    return {
        "item_embed": init(next(keys), (cfg.vocab, d), d),
        "pos_embed": init(next(keys), (cfg.seq_len, d), d),
        "out_bias": jnp.zeros((cfg.vocab,), jnp.float32),
        "blocks": blocks,
    }


def param_specs(cfg: Bert4RecConfig) -> Dict:
    return {
        "item_embed": ("vocab", None),
        "pos_embed": (None, None),
        "out_bias": ("vocab",),
        "blocks": {
            "wq": (None, "embed", "heads"),
            "wk": (None, "embed", "heads"),
            "wv": (None, "embed", "heads"),
            "wo": (None, "heads", "embed"),
            "w1": (None, "embed", "ffn"),
            "w2": (None, "ffn", "embed"),
            "ln1_w": (None, None),
            "ln1_b": (None, None),
            "ln2_w": (None, None),
            "ln2_b": (None, None),
        },
    }


def _layer_norm(x, w, b, eps=1e-6):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * w + b


def encode(cfg: Bert4RecConfig, params: Dict, items: jax.Array) -> jax.Array:
    """items (B, S) int32 -> hidden states (B, S, D).  PAD=0 is masked out of
    attention (bidirectional otherwise)."""
    b, s = items.shape
    dt = cfg.compute_dtype
    x = (params["item_embed"][items] + params["pos_embed"][None, :s]).astype(dt)
    pad_mask = (items != 0)  # (B, S)
    attn_mask = pad_mask[:, None, None, :]  # (B, 1, 1, S)
    h = cfg.n_heads
    dh = cfg.embed_dim // h

    def body(x, bp):
        bp = jax.tree.map(lambda a: a.astype(dt), bp)
        y = _layer_norm(x.astype(jnp.float32), bp["ln1_w"].astype(jnp.float32), bp["ln1_b"].astype(jnp.float32)).astype(dt)
        q = (y @ bp["wq"]).reshape(b, s, h, dh)
        k = (y @ bp["wk"]).reshape(b, s, h, dh)
        v = (y @ bp["wv"]).reshape(b, s, h, dh)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) / np.sqrt(dh)
        logits = jnp.where(attn_mask, logits, -1e30)
        probs = jax.nn.softmax(logits, -1).astype(dt)
        attn = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, -1)
        x = x + attn @ bp["wo"]
        y2 = _layer_norm(x.astype(jnp.float32), bp["ln2_w"].astype(jnp.float32), bp["ln2_b"].astype(jnp.float32)).astype(dt)
        x = x + jax.nn.gelu(y2 @ bp["w1"]) @ bp["w2"]
        return x, None

    # python loop (n_blocks=2): keeps HLO cost analysis exact (while bodies
    # are counted once by XLA cost analysis — DESIGN.md Section 8)
    for i in range(cfg.n_blocks):
        bp = jax.tree.map(lambda a: a[i], params["blocks"])
        x, _ = body(x, bp)
    return x


def cloze_loss(cfg: Bert4RecConfig, params: Dict, items: jax.Array, targets: jax.Array) -> Tuple[jax.Array, Dict]:
    """Full-softmax Cloze loss (small vocabs / smoke configs).  items has
    [MASK] tokens; targets holds the true item at masked positions, else 0."""
    hidden = encode(cfg, params, items).astype(jnp.float32)
    logits = hidden @ params["item_embed"].T + params["out_bias"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    mask = (targets != 0).astype(jnp.float32)
    loss = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"xent": loss, "n_masked": jnp.sum(mask)}


def cloze_loss_sampled(
    cfg: Bert4RecConfig,
    params: Dict,
    items: jax.Array,          # (B, S) with [MASK]
    mask_positions: jax.Array,  # (B, M) indices of masked slots
    mask_targets: jax.Array,    # (B, M) true items at those slots; 0 = unused
    negatives: jax.Array,       # (K,) shared negative samples
) -> Tuple[jax.Array, Dict]:
    """Sampled-softmax Cloze for production vocabs (1M items): full softmax
    at 65 536×200 positions is ~50 TB of logits; instead gather the ≤M masked
    positions and score gold vs K shared uniform negatives (no logQ
    correction — uniform proposal, documented)."""
    hidden = encode(cfg, params, items).astype(jnp.float32)       # (B, S, D)
    h_m = jnp.take_along_axis(
        hidden, mask_positions[..., None], axis=1
    )                                                             # (B, M, D)
    gold_emb = params["item_embed"][mask_targets]                 # (B, M, D)
    gold = jnp.sum(h_m * gold_emb, -1) + params["out_bias"][mask_targets]
    neg_emb = params["item_embed"][negatives]                     # (K, D)
    neg = jnp.einsum("bmd,kd->bmk", h_m, neg_emb) + params["out_bias"][negatives]
    logits = jnp.concatenate([gold[..., None], neg], axis=-1)     # (B, M, K+1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    mask = (mask_targets != 0).astype(jnp.float32)
    loss = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"xent": loss, "n_masked": jnp.sum(mask)}


def score_all_items(cfg: Bert4RecConfig, params: Dict, items: jax.Array) -> jax.Array:
    """Next-item serving: hidden state at the LAST position scores every item
    — (B, vocab) logits.  serve_p99 / serve_bulk shapes."""
    hidden = encode(cfg, params, items).astype(jnp.float32)
    last = hidden[:, -1]
    return last @ params["item_embed"].T + params["out_bias"]


def score_candidates(
    cfg: Bert4RecConfig, params: Dict, items: jax.Array, candidates: jax.Array
) -> jax.Array:
    """retrieval_cand: score (B,) users' last positions against an explicit
    (B, C) candidate list — gather + batched dot, NOT a loop."""
    hidden = encode(cfg, params, items).astype(jnp.float32)
    last = hidden[:, -1]  # (B, D)
    cand_emb = params["item_embed"][candidates]  # (B, C, D)
    return jnp.einsum("bd,bcd->bc", last, cand_emb) + params["out_bias"][candidates]


def embedding_bag(
    table: jax.Array, bags: jax.Array, bag_mask: jax.Array, mode: str = "mean"
) -> jax.Array:
    """EmbeddingBag built from take + masked reduce (no native op in JAX).

    bags: (B, L) int32 item ids, bag_mask: (B, L) bool. Returns (B, D).
    Used for multi-hot user-feature bags in the retrieval tower.
    """
    emb = table[bags]  # (B, L, D)
    m = bag_mask[..., None].astype(emb.dtype)
    s = jnp.sum(emb * m, axis=1)
    if mode == "sum":
        return s
    if mode == "mean":
        return s / jnp.maximum(jnp.sum(m, axis=1), 1.0)
    if mode == "max":
        neg = jnp.where(bag_mask[..., None], emb, -jnp.inf)
        out = jnp.max(neg, axis=1)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(mode)
