"""Decoder-only transformer LM covering the five assigned LM architectures.

One parametric implementation: GQA (+qk-norm for Qwen3), RoPE, sliding-window
attention (Mixtral), SwiGLU dense FFN, capacity-based MoE (Mixtral 8e /
Arctic 128e top-2) with optional dense-residual branch (Arctic), parametric
RMSNorm or OLMo's non-parametric LayerNorm.  Layer params are stacked on a
leading L axis and the stack is executed with ``lax.scan`` (HLO size — and
compile time on the dry-run host — independent of depth).

Three entry points per the assigned shapes:
  ``loss_fn``      train_4k            (causal LM loss)
  ``prefill``      prefill_32k         (logits + KV cache)
  ``decode_step``  decode_32k/long_500k (one token against the cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (
    MoEArgs,
    apply_norm,
    apply_rope,
    chunked_attention,
    decode_attention,
    gqa_attention,
    moe_block,
    rms_norm,
    swiglu,
)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm_nonparam"
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    moe: Optional[MoEArgs] = None
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # perf/memory knobs (production configs set these; smoke configs don't)
    attn_q_chunk: Optional[int] = None   # query-chunked attention block size
    remat: bool = False                  # rematerialize each layer body
    act_pspec: Optional[Any] = None      # PartitionSpec for the layer carry
    #                                      (activation sequence sharding / SP)
    scan_layers: bool = True             # False: python-loop (unrolled HLO —
    #                                      XLA cost analysis counts while
    #                                      bodies ONCE, so the roofline path
    #                                      compiles unrolled depths; see
    #                                      launch/dryrun.py extrapolation)
    attn_window_slicing: bool = False    # §Perf: SWA chunks slice their KV
    #                                      window instead of masking dense
    attn_halo_mesh: Optional[Any] = None  # §Perf iter-4: halo-exchange SWA
    #                                      (shard_map ppermute, no KV gather)

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    def param_count(self) -> int:
        d, dh = self.d_model, self.head_dim
        attn = d * dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        dense = 3 * d * self.d_ff
        per_layer = attn + dense
        if self.moe is not None:
            per_layer += self.moe.n_experts * 3 * d * self.d_ff + d * self.moe.n_experts
            if not self.moe.dense_residual:
                per_layer -= dense  # MoE replaces the dense FFN
        emb = self.vocab * d
        head = 0 if self.tie_embeddings else d * self.vocab
        return self.n_layers * per_layer + emb + head

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only) — the N in
        MODEL_FLOPS = 6·N_active·D."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        dense = 3 * d * self.d_ff
        inactive = (self.moe.n_experts - self.moe.top_k) * dense
        return self.param_count() - self.n_layers * inactive


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_params(cfg: TransformerConfig, key: jax.Array) -> Dict:
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv, f, L = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.n_layers
    keys = iter(jax.random.split(key, 32))
    pd = cfg.param_dtype

    def dense_init(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / np.sqrt(fan_in)).astype(pd)

    layers: Dict[str, jax.Array] = {
        "wq": dense_init(next(keys), (L, d, hq * dh), d),
        "wk": dense_init(next(keys), (L, d, hkv * dh), d),
        "wv": dense_init(next(keys), (L, d, hkv * dh), d),
        "wo": dense_init(next(keys), (L, hq * dh, d), hq * dh),
    }
    if cfg.norm == "rmsnorm":
        layers["attn_norm_w"] = jnp.ones((L, d), pd)
        layers["mlp_norm_w"] = jnp.ones((L, d), pd)
    if cfg.qk_norm:
        layers["q_norm_w"] = jnp.ones((L, dh), pd)
        layers["k_norm_w"] = jnp.ones((L, dh), pd)
    use_dense = cfg.moe is None or cfg.moe.dense_residual
    if use_dense:
        layers["w_gate"] = dense_init(next(keys), (L, d, f), d)
        layers["w_up"] = dense_init(next(keys), (L, d, f), d)
        layers["w_down"] = dense_init(next(keys), (L, f, d), f)
    if cfg.moe is not None:
        e = cfg.moe.n_experts
        layers["router"] = dense_init(next(keys), (L, d, e), d)
        layers["moe_gate"] = dense_init(next(keys), (L, e, d, f), d)
        layers["moe_up"] = dense_init(next(keys), (L, e, d, f), d)
        layers["moe_down"] = dense_init(next(keys), (L, e, f, d), f)

    params = {
        "embed": dense_init(next(keys), (cfg.vocab, d), d),
        "layers": layers,
    }
    if cfg.norm == "rmsnorm":
        params["final_norm_w"] = jnp.ones((d,), pd)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(next(keys), (d, cfg.vocab), d)
    return params


def param_specs(cfg: TransformerConfig) -> Dict:
    """Logical-axis names per param dim, mirrored on the param pytree.
    Resolved to mesh PartitionSpecs by ``repro.distributed.sharding``."""
    layers: Dict[str, tuple] = {
        "wq": (None, "embed", "heads"),
        "wk": (None, "embed", "kv_heads"),
        "wv": (None, "embed", "kv_heads"),
        "wo": (None, "heads", "embed"),
    }
    if cfg.norm == "rmsnorm":
        layers["attn_norm_w"] = (None, None)
        layers["mlp_norm_w"] = (None, None)
    if cfg.qk_norm:
        layers["q_norm_w"] = (None, None)
        layers["k_norm_w"] = (None, None)
    use_dense = cfg.moe is None or cfg.moe.dense_residual
    if use_dense:
        layers["w_gate"] = (None, "embed", "ffn")
        layers["w_up"] = (None, "embed", "ffn")
        layers["w_down"] = (None, "ffn", "embed")
    if cfg.moe is not None:
        layers["router"] = (None, "embed", None)
        if cfg.moe.partition == "expert":
            espec = (None, "experts", "embed", None)
            espec_dn = (None, "experts", None, "embed")
        else:  # "ffn": TP inside each expert (n_experts < model axis)
            espec = (None, None, "embed", "ffn")
            espec_dn = (None, None, "ffn", "embed")
        layers["moe_gate"] = espec
        layers["moe_up"] = espec
        layers["moe_down"] = espec_dn
    specs = {"embed": ("vocab", "embed"), "layers": layers}
    if cfg.norm == "rmsnorm":
        specs["final_norm_w"] = (None,)
    if not cfg.tie_embeddings:
        specs["lm_head"] = ("embed", "vocab")
    return specs


# ---------------------------------------------------------------------------
# Layer body
# ---------------------------------------------------------------------------


def _project_qkv(cfg: TransformerConfig, lp, h, positions):
    b, s, _ = h.shape
    dh = cfg.head_dim
    q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, dh)
    k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, dh)
    v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm_w"])
        k = rms_norm(k, lp["k_norm_w"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(cfg: TransformerConfig, lp, h2) -> Tuple[jax.Array, jax.Array]:
    """Dense / MoE / MoE+dense-residual FFN on (B, S, D)."""
    b, s, d = h2.shape
    aux = jnp.zeros((), jnp.float32)
    y = jnp.zeros_like(h2)
    if cfg.moe is not None:
        if cfg.moe.shard_dispatch and cfg.moe.mesh is not None:
            from repro.models.layers import moe_ffn_sharded

            moe_out, aux = moe_ffn_sharded(
                h2, lp["router"], lp["moe_gate"], lp["moe_up"], lp["moe_down"],
                cfg.moe,
            )
            y = y + moe_out
        else:
            flat = h2.reshape(b * s, d)
            moe_out, aux = moe_block(
                flat, lp["router"], lp["moe_gate"], lp["moe_up"], lp["moe_down"],
                cfg.moe,
            )
            y = y + moe_out.reshape(b, s, d)
    if cfg.moe is None or cfg.moe.dense_residual:
        y = y + swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])
    return y, aux


def _attend(cfg: TransformerConfig, q, k, v):
    if cfg.attn_halo_mesh is not None and cfg.sliding_window is not None:
        from repro.models.layers import swa_attention_halo

        mesh = cfg.attn_halo_mesh
        tp = mesh.shape.get("model", 1)
        s = q.shape[1]
        qc = cfg.attn_q_chunk or 512
        usable = (
            tp > 1
            and s % tp == 0
            and (s // tp) % qc == 0
            and cfg.sliding_window < s * (tp - 1) // tp
        )
        if usable:
            return swa_attention_halo(
                q, k, v, sliding_window=cfg.sliding_window, mesh=mesh, q_chunk=qc
            )
    if cfg.attn_q_chunk is not None:
        return chunked_attention(
            q, k, v, causal=True, sliding_window=cfg.sliding_window,
            q_chunk=cfg.attn_q_chunk, window_slicing=cfg.attn_window_slicing,
        )
    return gqa_attention(q, k, v, causal=True, sliding_window=cfg.sliding_window)


def _constrain(cfg: TransformerConfig, x):
    if cfg.act_pspec is not None:
        return jax.lax.with_sharding_constraint(x, cfg.act_pspec)
    return x


def _layer(cfg: TransformerConfig, x, lp, positions):
    h = apply_norm(cfg.norm, x, lp.get("attn_norm_w"))
    q, k, v = _project_qkv(cfg, lp, h, positions)
    attn = _attend(cfg, q, k, v)
    b, s, _ = x.shape
    x = x + attn.reshape(b, s, -1) @ lp["wo"]
    h2 = apply_norm(cfg.norm, x, lp.get("mlp_norm_w"))
    y, aux = _ffn(cfg, lp, h2)
    return _constrain(cfg, x + y), aux


# ---------------------------------------------------------------------------
# Training forward / loss
# ---------------------------------------------------------------------------


def forward(cfg: TransformerConfig, params: Dict, tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> (logits (B, S, V), aux_loss)."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, lp):
        lp = jax.tree.map(lambda a: a.astype(cfg.compute_dtype), lp)
        x, aux = _layer(cfg, x, lp, positions)
        return x, aux

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, auxes = jax.lax.scan(body, x, params["layers"])
        aux = jnp.sum(auxes)
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, a = body(x, lp)
            aux = aux + a
    x = apply_norm(cfg.norm, x, params.get("final_norm_w"))
    head = params.get("lm_head", params["embed"].T)
    logits = x @ head.astype(cfg.compute_dtype)
    # (B, S, V) is the largest tensor in the program (mixtral train_4k: 137 GB
    # fp32) — without a constraint the seq-vs-vocab "model"-axis conflict made
    # GSPMD replicate it (measured; DESIGN.md Section 8).
    logits = _constrain(cfg, logits)
    return logits, aux


def loss_fn(cfg: TransformerConfig, params: Dict, tokens: jax.Array) -> Tuple[jax.Array, Dict]:
    """Causal LM loss over tokens (B, S+1): predict tokens[:,1:]."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits, aux = forward(cfg, params, inputs)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    xent = jnp.mean(logz - gold)
    return xent + aux, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def cache_capacity(cfg: TransformerConfig, max_seq: int) -> int:
    """Ring capacity: SWA archs bound the cache by the window (the
    sub-quadratic property that makes long_500k runnable for Mixtral)."""
    if cfg.sliding_window is not None:
        return min(max_seq, cfg.sliding_window)
    return max_seq


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int) -> Dict:
    cap = cache_capacity(cfg, max_seq)
    shape = (cfg.n_layers, batch, cap, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, cfg.compute_dtype),
        "v": jnp.zeros(shape, cfg.compute_dtype),
        "len": jnp.zeros((), jnp.int32),  # tokens seen so far (absolute)
    }


def prefill(
    cfg: TransformerConfig,
    params: Dict,
    tokens: jax.Array,
    max_seq: Optional[int] = None,
) -> Tuple[jax.Array, Dict]:
    """tokens (B, S) -> (last-position logits (B, V), cache).

    ``max_seq`` sizes the cache for subsequent decoding (>= S); SWA archs cap
    it at the window (ring cache)."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    cap = min(cache_capacity(cfg, max_seq or s), s)

    def body(x, lp):
        lp = jax.tree.map(lambda a: a.astype(cfg.compute_dtype), lp)
        h = apply_norm(cfg.norm, x, lp.get("attn_norm_w"))
        q, k, v = _project_qkv(cfg, lp, h, positions)
        attn = _attend(cfg, q, k, v)
        x = x + attn.reshape(b, s, -1) @ lp["wo"]
        h2 = apply_norm(cfg.norm, x, lp.get("mlp_norm_w"))
        y, _ = _ffn(cfg, lp, h2)
        # cache the last `cap` rotated keys/values
        return _constrain(cfg, x + y), (k[:, s - cap :], v[:, s - cap :])

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    else:
        ks_l, vs_l = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (ki, vi) = body(x, lp)
            ks_l.append(ki)
            vs_l.append(vi)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)
    x = apply_norm(cfg.norm, x, params.get("final_norm_w"))
    head = params.get("lm_head", params["embed"].T)
    logits = x[:, -1] @ head.astype(cfg.compute_dtype)
    target_cap = cache_capacity(cfg, max_seq or s)
    if cap < target_cap:
        # Full-attention decode headroom: positions occupy slots [0, s).
        pad = [(0, 0), (0, 0), (0, target_cap - cap), (0, 0), (0, 0)]
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    elif cfg.sliding_window is not None and s > cap:
        # Ring layout: absolute position p lives in slot p % cap.
        shift = (s - cap) % cap
        ks = jnp.roll(ks, shift, axis=2)
        vs = jnp.roll(vs, shift, axis=2)
    cache = {"k": ks, "v": vs, "len": jnp.asarray(s, jnp.int32)}
    return logits.astype(jnp.float32), cache


def decode_step(
    cfg: TransformerConfig, params: Dict, token: jax.Array, cache: Dict
) -> Tuple[jax.Array, Dict]:
    """One decode step.  token (B,) int32; cache from init_cache/prefill.
    Returns (logits (B, V), updated cache)."""
    b = token.shape[0]
    cap = cache["k"].shape[2]
    pos = cache["len"]  # scalar absolute position
    write_idx = pos % cap
    valid = jnp.minimum(pos + 1, cap)
    x = params["embed"][token][:, None, :].astype(cfg.compute_dtype)
    positions = jnp.full((b, 1), pos, jnp.int32)

    def body(x, scanned):
        lp, ck, cv = scanned
        lp = jax.tree.map(lambda a: a.astype(cfg.compute_dtype), lp)
        h = apply_norm(cfg.norm, x, lp.get("attn_norm_w"))
        q, k, v = _project_qkv(cfg, lp, h, positions)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, write_idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, write_idx, axis=1)
        attn = decode_attention(q, ck, cv, valid)
        x = x + attn.reshape(b, 1, -1) @ lp["wo"]
        h2 = apply_norm(cfg.norm, x, lp.get("mlp_norm_w"))
        y, _ = _ffn(cfg, lp, h2)
        return x + y, (ck, cv)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    else:
        ks_l, vs_l = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (ki, vi) = body(x, (lp, cache["k"][i], cache["v"][i]))
            ks_l.append(ki)
            vs_l.append(vi)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)
    x = apply_norm(cfg.norm, x, params.get("final_norm_w"))
    head = params.get("lm_head", params["embed"].T)
    logits = x[:, 0] @ head.astype(cfg.compute_dtype)
    new_cache = {"k": ks, "v": vs, "len": pos + 1}
    return logits.astype(jnp.float32), new_cache
