"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), TPU v5e constants:
  compute    = HLO_FLOPs_per_chip / 197e12           (bf16 MXU peak)
  memory     = HLO_bytes_per_chip / 819e9            (HBM bandwidth)
  collective = collective_bytes_per_chip / 50e9      (ICI per-link)

``cost_analysis()`` of an SPMD-partitioned executable reports the PER-DEVICE
module (verified in tests/test_dryrun_small.py), so flops/bytes are already
per-chip.  collective bytes are parsed from ``compiled.as_text()`` (the
post-partitioning HLO — ``lowered.as_text()`` predates SPMD and has no
collectives), summing result-shard bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (all-reduce counts 2×:
reduce-scatter + all-gather phases).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional


def compiled_cost_dict(compiled) -> Dict[str, float]:
    """``cost_analysis()`` of a compiled executable as a plain float dict.
    XLA returns either a dict or a one-element list of dicts depending on
    version; both normalize to ``{"flops": ..., "bytes accessed": ..., ...}``.
    """
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    return {
        k: float(v) for k, v in dict(cost).items() if isinstance(v, (int, float))
    }


def compiled_memory_dict(compiled) -> Optional[Dict[str, int]]:
    """``memory_analysis()`` of a compiled executable as a plain int dict,
    plus ``peak_bytes_per_device_est`` = args + output - alias + temp (the
    donation-aware resident estimate).  ``None`` when the backend exposes no
    memory analysis.  Shared by the launch dry-run and costlint."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
        "host_argument_size_in_bytes",
        "host_output_size_in_bytes",
        "host_temp_size_in_bytes",
    ):
        if hasattr(ma, k):
            out[k] = int(getattr(ma, k))
    if out:
        args = out.get("argument_size_in_bytes", 0)
        alias = out.get("alias_size_in_bytes", 0)
        out["peak_bytes_per_device_est"] = (
            args + out.get("output_size_in_bytes", 0) - alias
            + out.get("temp_size_in_bytes", 0)
        )
    return out or None


HW = dict(
    name="tpu_v5e",
    peak_flops_bf16=197e12,   # per chip
    hbm_bw=819e9,             # bytes/s per chip
    ici_bw=50e9,              # bytes/s per link
    hbm_bytes=16e9,           # capacity per chip
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g. "bf16[16,4096,512]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # unknown: conservative small group


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-op-type {count, bytes} of ICI wire traffic PER CHIP
    from post-SPMD HLO text.

    Bandwidth-optimal (ring) cost model with g = replica-group size and
    S = per-device result bytes:
      all-gather         S·(g-1)/g     (receives every other shard)
      reduce-scatter     S·(g-1)/g
      all-reduce         2·S·(g-1)/g   (reduce-scatter + all-gather phases)
      all-to-all         S·(g-1)/g
      collective-permute S             (one hop)

    GSPMD-on-CPU artifact (DESIGN.md Section 8): reduce-scatters are emitted
    as all-reduce + dynamic-slice(partition-id).  When every consumer of an
    all-reduce is a dynamic-slice, it is re-classified as reduce-scatter with
    the sliced (1/g) payload — the op a TPU build actually emits.
    *-start ops are counted once (their *-done twin carries no new payload).
    """
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES
    }
    lines = hlo_text.splitlines()
    # map: op name -> set of consumer opcodes
    consumers: Dict[str, set] = {}
    name_re = re.compile(r"%([\w.\-]+)")
    for line in lines:
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        opcode_m = re.search(r"\s([a-z][a-z0-9\-]*)\(", rhs)
        opcode = opcode_m.group(1) if opcode_m else ""
        paren = rhs.find("(")
        if paren >= 0:
            for nm in name_re.findall(rhs[paren:]):
                consumers.setdefault(nm, set()).add(opcode)

    for line in lines:
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"^(?:\([^)]*\)|[\w\[\],{}:#\s]*?)\s*([a-z\-]+)(?:-start)?\(", rhs)
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-start"):
            op = op[: -len("-start")]
        if op not in _COLLECTIVES:
            continue
        if "-done(" in rhs:
            continue
        head = rhs.split(op)[0]
        nbytes = _shape_bytes(head)
        g = _group_size(line)
        ring = (g - 1) / max(g, 1)
        name_m = name_re.search(lhs)
        name = name_m.group(1) if name_m else ""
        cons = consumers.get(name, set())
        if op == "all-reduce" and cons and cons <= {"dynamic-slice"}:
            # TPU would emit a reduce-scatter of the sliced payload
            out["reduce-scatter"]["count"] += 1
            out["reduce-scatter"]["bytes"] += (nbytes / g) * ring
            continue
        if op == "all-reduce":
            nbytes *= 2.0
        if op != "collective-permute":
            nbytes *= ring
        out[op]["count"] += 1
        out[op]["bytes"] += nbytes
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float
    n_chips: int
    useful_ratio: Optional[float]  # MODEL_FLOPS / (HLO_FLOPs × chips)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_lb(self) -> float:
        """Roofline lower bound on step time (no overlap assumption: max)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step-time bound spent on useful model math — the
        score: MODEL_FLOPS / (chips × peak × step_time_lb)."""
        if self.step_time_lb == 0:
            return 0.0
        return self.model_flops / (
            self.n_chips * HW["peak_flops_bf16"] * self.step_time_lb
        )

    def to_dict(self):
        return {
            **dataclasses.asdict(self),
            "dominant": self.dominant,
            "step_time_lb": self.step_time_lb,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_from_cost(
    cost: Dict[str, float],
    collectives: Dict[str, Dict[str, float]],
    n_chips: int,
    model_flops: float,
) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    # cost_analysis "bytes accessed" keys vary; sum the canonical one.
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll_bytes = sum(v["bytes"] for v in collectives.values())
    hlo_total_flops = flops * n_chips
    return Roofline(
        compute_s=flops / HW["peak_flops_bf16"],
        memory_s=nbytes / HW["hbm_bw"],
        collective_s=coll_bytes / HW["ici_bw"],
        flops_per_chip=flops,
        bytes_per_chip=nbytes,
        collective_bytes_per_chip=coll_bytes,
        model_flops=model_flops,
        n_chips=n_chips,
        useful_ratio=(model_flops / hlo_total_flops) if hlo_total_flops else None,
    )


def model_flops_for(bundle, tokens_or_items: Optional[int] = None) -> float:
    """MODEL_FLOPS = 6·N·D for training (N = active params, D = tokens), 2·N·D
    for forward-only serving."""
    cfg = bundle.config
    kind = bundle.kind
    specs = bundle.batch_specs

    def n_tokens_lm():
        if kind == "train":
            b, s1 = specs["tokens"].shape
            return b * (s1 - 1)
        if kind == "prefill":
            b, s = specs["tokens"].shape
            return b * s
        return specs["token"].shape[0]  # decode: 1 token per sequence

    if hasattr(cfg, "active_param_count"):
        n = cfg.active_param_count()
        d = n_tokens_lm()
        return (6.0 if kind == "train" else 2.0) * n * d
    if hasattr(cfg, "param_count"):  # bert4rec
        # embedding rows are GATHERED, not multiplied — count the transformer
        # math + the scoring matmul explicitly.
        b, s = specs["items"].shape
        d_model = cfg.embed_dim
        per_tok = cfg.n_blocks * (8 * d_model**2 + 16 * d_model**2 + 4 * s * d_model)
        enc = b * s * per_tok
        if kind == "recsys_train":
            m = specs["mask_positions"].shape[1]
            k = specs["negatives"].shape[0]
            head = 2.0 * b * m * (k + 1) * d_model
            return 3.0 * (enc + head)
        if kind == "recsys_retrieval":
            c = specs["candidates"].shape[1]
            return enc + 2.0 * b * c * d_model
        return enc + 2.0 * b * cfg.vocab * d_model  # score all items
    return _gnn_model_flops(bundle)


def _gnn_model_flops(bundle) -> float:
    """Analytic matmul FLOPs of the GNN forward (×3 for train: bwd ≈ 2×fwd).
    Counts dense contractions only (gather/scatter are bytes, not FLOPs)."""
    cfg = bundle.config
    g = bundle.batch_specs["graph"]
    n = g["node_feat"].shape[0]
    e = g["edge_src"].shape[0]
    name = type(cfg).__name__
    if name == "SAGEConfig":
        f = 0.0
        d_prev = cfg.d_in
        for _ in range(cfg.n_layers):
            f += 2.0 * n * d_prev * cfg.d_hidden * 2  # self + neigh
            f += e * d_prev                            # mean aggregation adds
            d_prev = cfg.d_hidden
        f += 2.0 * n * cfg.d_hidden * cfg.out_dim
    elif name == "GATConfig":
        f = 0.0
        d_prev = cfg.d_in
        for i in range(cfg.n_layers):
            d_out = cfg.out_dim if i == cfg.n_layers - 1 else cfg.d_hidden
            f += 2.0 * n * d_prev * cfg.n_heads * d_out
            f += 6.0 * e * cfg.n_heads * d_out  # scores + weighted messages
            d_prev = cfg.n_heads * d_out
    elif name == "SchNetConfig":
        d = cfg.d_hidden
        f = 0.0
        for _ in range(cfg.n_interactions):
            f += 2.0 * e * (cfg.n_rbf * d + d * d)  # filter MLP
            f += 2.0 * n * d * d                     # w_in
            f += 2.0 * e * d                         # message mult + scatter
            f += 2.0 * n * (d * d + d * d)           # out MLP
        f += 2.0 * n * (d * d // 2 + (d // 2) * cfg.out_dim)
    elif name == "DimeNetConfig":
        fdim = cfg.d_hidden
        s = cfg.n_spherical * cfg.n_radial
        t = g["triplets"]["in"].shape[0] if "triplets" in g else 0
        f = 2.0 * e * (3 * fdim * fdim + fdim * fdim + cfg.n_radial * fdim)
        for _ in range(cfg.n_blocks):
            f += 2.0 * e * fdim * fdim                     # w_msg
            f += 2.0 * e * fdim * cfg.n_bilinear           # w_down (gathered)
            f += 2.0 * t * s * cfg.n_bilinear              # bilinear (sbf)
            f += 2.0 * t * cfg.n_bilinear * fdim           # bilinear (out)
            f += 2.0 * e * 2 * fdim * fdim                 # update MLP
            f += 2.0 * e * cfg.n_radial * fdim             # rbf gates
            f += 2.0 * n * (fdim * fdim + fdim * cfg.out_dim)
    else:
        raise ValueError(name)
    return (3.0 if bundle.is_train else 1.0) * f
