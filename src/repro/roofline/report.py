"""Roofline report generator: results/dryrun/*.json -> markdown tables for
EXPERIMENTS.md (§Dry-run and §Roofline)."""
from __future__ import annotations

import json
from pathlib import Path


def load_cells(outdir: str = "results/dryrun"):
    cells = {}
    for p in sorted(Path(outdir).glob("*.json")):
        rec = json.loads(p.read_text())
        if "arch" not in rec:  # sketch-plane records have their own schema
            continue
        cells[(rec["arch"], rec["shape"], rec["mesh"])] = rec
    return cells


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def roofline_table(cells, mesh="pod16x16") -> str:
    """Single-pod roofline table (the §Roofline deliverable)."""
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPS | useful | roofline frac | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m), rec in sorted(cells.items()):
        if m != mesh:
            continue
        if rec["status"] == "skipped":
            lines.append(
                f"| {arch} | {shape} | — | — | — | SKIP | — | — | — | "
                f"({rec['skip_reason'][:48]}…) |"
            )
            continue
        rf = rec["roofline"]
        mm = rec.get("modeled_memory", {})
        lines.append(
            "| {a} | {s} | {c} | {me} | {co} | **{dom}** | {mf:.2e} | {ur} | "
            "{frac:.3f} | {fits} |".format(
                a=arch,
                s=shape,
                c=fmt_s(rf["compute_s"]),
                me=fmt_s(rf["memory_s"]),
                co=fmt_s(rf["collective_s"]),
                dom=rf["dominant"],
                mf=rf["model_flops"],
                ur=f"{rf['useful_ratio']:.2f}" if rf["useful_ratio"] else "—",
                frac=rf["roofline_fraction"],
                fits="yes" if mm.get("fits_16GB") else "CHECK",
            )
        )
    return "\n".join(lines)


def dryrun_table(cells) -> str:
    """Both-mesh compile/memory summary (§Dry-run deliverable)."""
    lines = [
        "| arch | shape | mesh | compile | modeled mem/dev | xla args/dev | "
        "collective ops | status |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m), rec in sorted(cells.items()):
        if rec["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | {m} | — | — | — | — | SKIP |")
            continue
        mm = rec.get("modeled_memory", {})
        mem = rec.get("memory") or {}
        colls = rec.get("collectives_scan_module") or rec.get("collectives") or {}
        n_coll = sum(int(v["count"]) for v in colls.values())
        lines.append(
            "| {a} | {s} | {m} | {c}s | {mm:.2f}GB | {xa:.2f}GB | {nc} | ok |".format(
                a=arch, s=shape, m=m, c=rec.get("compile_s", "—"),
                mm=mm.get("modeled_total_per_device", 0) / 1e9,
                xa=mem.get("argument_size_in_bytes", 0) / 1e9,
                nc=n_coll,
            )
        )
    return "\n".join(lines)


def bottleneck_summary(cells, mesh="pod16x16") -> str:
    lines = []
    for (arch, shape, m), rec in sorted(cells.items()):
        if m != mesh or rec["status"] != "ok":
            continue
        rf = rec["roofline"]
        colls = rec["collectives"]
        top = max(colls, key=lambda k: colls[k]["bytes"])
        lines.append(
            f"- **{arch}/{shape}**: {rf['dominant']}-bound "
            f"(lb {fmt_s(rf['step_time_lb'])}); top collective: {top} "
            f"{colls[top]['bytes']/1e9:.1f} GB/chip over {int(colls[top]['count'])} ops"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    cells = load_cells()
    print("## Roofline (single pod, 256 chips)\n")
    print(roofline_table(cells))
    print("\n## Dry-run (both meshes)\n")
    print(dryrun_table(cells))
