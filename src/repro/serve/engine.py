"""gLava serving engine: the paper's data structure as an online service.

Ingest path: batched edge updates through the :mod:`repro.core.ingest`
engine (one jitted call per batch, O(1)/edge), DOUBLE-BUFFERED — the next
batch is staged on the host and dispatched while the device still
accumulates the previous one; the server only blocks when the in-flight
queue exceeds ``max_inflight`` or a query needs the live counters.
Backend "auto" selects the Pallas fast path on TPU hosts.

Query path: every family dispatches through one
:class:`repro.core.query_engine.QueryEngine` (persistent jit cache, query
padding, backend "auto" = fused Pallas multi-query kernel on TPU).  Point
and heavy-hitter queries read the sketch's maintained flow registers
(O(d·Q) gathers); reachability is served from the engine's epoch-tagged
transitive closure, which refreshes lazily after ingest so all-pairs
closure cost amortizes over query batches (DESIGN.md Sections 2-4).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GLavaSketch, SketchConfig
from repro.core.ingest import resolve_backend
from repro.core.query_engine import QueryEngine
from repro.core.window import SlidingWindowSketch


@dataclasses.dataclass
class ServeStats:
    edges_ingested: int = 0
    ingest_s: float = 0.0
    queries_served: int = 0
    query_s: float = 0.0
    closure_refreshes: int = 0

    def summary(self) -> Dict[str, float]:
        return {
            "edges_ingested": self.edges_ingested,
            "ingest_edges_per_s": self.edges_ingested / max(self.ingest_s, 1e-9),
            "queries_served": self.queries_served,
            "queries_per_s": self.queries_served / max(self.query_s, 1e-9),
            "closure_refreshes": self.closure_refreshes,
        }


class SketchServer:
    def __init__(
        self,
        config: SketchConfig,
        seed: int = 0,
        window_slices: Optional[int] = None,
        ingest_backend: str = "scatter",
        query_backend: str = "auto",
        double_buffer: bool = True,
        max_inflight: int = 2,
    ):
        if window_slices:
            self.window = SlidingWindowSketch.empty(
                config, window_slices, jax.random.key(seed)
            )
            self.sketch = None
        else:
            self.window = None
            self.sketch = GLavaSketch.empty(config, jax.random.key(seed))
        self.backend = resolve_backend(ingest_backend)
        self.stats = ServeStats()
        self.engine = QueryEngine(query_backend)
        # Sketch epoch: bumped on every mutation; tags the engine's closure
        # cache so reach queries amortize one closure per quiescent period.
        self._epoch = 0
        # double-buffered ingest: JAX dispatch is async, so staging the next
        # host batch overlaps the device accumulating the previous one; the
        # deque bounds how many un-materialized updates may be in flight.
        self._max_inflight = max_inflight if double_buffer else 0
        self._inflight: collections.deque = collections.deque()
        backend = self.backend
        self._jit_update = jax.jit(
            lambda live, s, d, w: live.update(s, d, w, backend=backend)
        )

    # -- ingest ---------------------------------------------------------------

    def _live(self) -> GLavaSketch:
        return self.window.window_sketch() if self.window else self.sketch

    def ingest(self, src: np.ndarray, dst: np.ndarray, weights=None):
        """Dispatch one edge batch; returns as soon as the device accepts it
        (call :meth:`flush` / any query to synchronize)."""
        t0 = time.time()
        s = jnp.asarray(src, jnp.uint32)
        d = jnp.asarray(dst, jnp.uint32)
        w = (
            jnp.ones(s.shape, jnp.float32)
            if weights is None
            else jnp.asarray(weights, jnp.float32)
        )
        if self.window:
            self.window = self._jit_update(self.window, s, d, w)
            self._inflight.append(self.window.slices)
        else:
            self.sketch = self._jit_update(self.sketch, s, d, w)
            self._inflight.append(self.sketch.counters)
        while len(self._inflight) > self._max_inflight:
            jax.block_until_ready(self._inflight.popleft())
        self.stats.edges_ingested += len(src)
        self.stats.ingest_s += time.time() - t0
        self._epoch += 1

    def flush(self):
        """Block until every dispatched ingest batch has landed on device."""
        if not self._inflight:
            return
        t0 = time.time()
        while self._inflight:
            jax.block_until_ready(self._inflight.popleft())
        self.stats.ingest_s += time.time() - t0

    def summary(self) -> Dict[str, float]:
        """Flushed stats — the only honest read of ingest throughput while
        ingest is double-buffered (raw ``stats.summary()`` counts dispatch
        time only for still-in-flight batches)."""
        self.flush()
        return self.stats.summary()

    def advance_window(self):
        if self.window:
            self.flush()
            self.window = self.window.advance()
            self._epoch += 1

    # -- queries --------------------------------------------------------------

    def _timed(self, fn, *args):
        self.flush()
        t0 = time.time()
        out = np.asarray(fn(self._live(), *args))
        self.stats.query_s += time.time() - t0
        self.stats.queries_served += int(np.size(out))
        return out

    def edge_frequency(self, src, dst):
        return self._timed(
            self.engine.edge,
            jnp.asarray(src, jnp.uint32),
            jnp.asarray(dst, jnp.uint32),
        )

    def in_flow(self, keys):
        return self._timed(self.engine.in_flow, jnp.asarray(keys, jnp.uint32))

    def out_flow(self, keys):
        return self._timed(self.engine.out_flow, jnp.asarray(keys, jnp.uint32))

    def heavy_hitters(self, keys, theta: float):
        return self.in_flow(keys) > theta

    def reachable(self, src, dst):
        self.flush()
        t0 = time.time()
        out = np.asarray(
            self.engine.reach(
                self._live(),
                jnp.asarray(src, jnp.uint32),
                jnp.asarray(dst, jnp.uint32),
                epoch=self._epoch,
            )
        )
        self.stats.query_s += time.time() - t0
        self.stats.queries_served += len(out)
        self.stats.closure_refreshes = self.engine.closure_refreshes
        return out

    def subgraph_weight(self, src, dst):
        self.flush()
        t0 = time.time()
        out = float(
            self.engine.subgraph(
                self._live(),
                jnp.asarray(src, jnp.uint32),
                jnp.asarray(dst, jnp.uint32),
            )
        )
        self.stats.query_s += time.time() - t0
        self.stats.queries_served += 1
        return out
