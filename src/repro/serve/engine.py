"""gLava serving engine: the paper's data structure as an online service.

Ingest path: batched edge updates (one jitted call per batch, O(1)/edge).
Query path: batched estimators over the live sketch; reachability queries
are served from a cached transitive closure that refreshes lazily after
ingest (all-pairs closure amortizes over query batches — DESIGN.md
Section 2).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GLavaSketch, SketchConfig, queries, reach
from repro.core.window import SlidingWindowSketch


@dataclasses.dataclass
class ServeStats:
    edges_ingested: int = 0
    ingest_s: float = 0.0
    queries_served: int = 0
    query_s: float = 0.0
    closure_refreshes: int = 0

    def summary(self) -> Dict[str, float]:
        return {
            "edges_ingested": self.edges_ingested,
            "ingest_edges_per_s": self.edges_ingested / max(self.ingest_s, 1e-9),
            "queries_served": self.queries_served,
            "queries_per_s": self.queries_served / max(self.query_s, 1e-9),
            "closure_refreshes": self.closure_refreshes,
        }


class SketchServer:
    def __init__(
        self,
        config: SketchConfig,
        seed: int = 0,
        window_slices: Optional[int] = None,
        ingest_backend: str = "scatter",
    ):
        if window_slices:
            self.window = SlidingWindowSketch.empty(
                config, window_slices, jax.random.key(seed)
            )
            self.sketch = None
        else:
            self.window = None
            self.sketch = GLavaSketch.empty(config, jax.random.key(seed))
        self.backend = ingest_backend
        self.stats = ServeStats()
        self._closure = None
        self._closure_dirty = True
        self._jit_edge = jax.jit(queries.edge_query)
        self._jit_in = jax.jit(queries.node_in_flow)
        self._jit_out = jax.jit(queries.node_out_flow)
        self._jit_closure = jax.jit(reach.transitive_closure)

    # -- ingest ---------------------------------------------------------------

    def _live(self) -> GLavaSketch:
        return self.window.window_sketch() if self.window else self.sketch

    def ingest(self, src: np.ndarray, dst: np.ndarray, weights=None):
        t0 = time.time()
        s = jnp.asarray(src, jnp.uint32)
        d = jnp.asarray(dst, jnp.uint32)
        w = None if weights is None else jnp.asarray(weights, jnp.float32)
        if self.window:
            self.window = self.window.update(s, d, w, backend=self.backend)
        else:
            self.sketch = self.sketch.update(s, d, w, backend=self.backend)
        jax.block_until_ready(self._live().counters)
        self.stats.edges_ingested += len(src)
        self.stats.ingest_s += time.time() - t0
        self._closure_dirty = True

    def advance_window(self):
        if self.window:
            self.window = self.window.advance()
            self._closure_dirty = True

    # -- queries --------------------------------------------------------------

    def _timed(self, fn, *args):
        t0 = time.time()
        out = np.asarray(fn(self._live(), *args))
        self.stats.query_s += time.time() - t0
        self.stats.queries_served += int(np.size(out))
        return out

    def edge_frequency(self, src, dst):
        return self._timed(
            self._jit_edge, jnp.asarray(src, jnp.uint32), jnp.asarray(dst, jnp.uint32)
        )

    def in_flow(self, keys):
        return self._timed(self._jit_in, jnp.asarray(keys, jnp.uint32))

    def out_flow(self, keys):
        return self._timed(self._jit_out, jnp.asarray(keys, jnp.uint32))

    def heavy_hitters(self, keys, theta: float):
        return self.in_flow(keys) > theta

    def reachable(self, src, dst):
        t0 = time.time()
        live = self._live()
        if self._closure_dirty or self._closure is None:
            self._closure = self._jit_closure(live.counters)
            self._closure_dirty = False
            self.stats.closure_refreshes += 1
        out = np.asarray(
            reach.reach_query_precomputed(
                live,
                self._closure,
                jnp.asarray(src, jnp.uint32),
                jnp.asarray(dst, jnp.uint32),
            )
        )
        self.stats.query_s += time.time() - t0
        self.stats.queries_served += len(out)
        return out

    def subgraph_weight(self, src, dst):
        live = self._live()
        t0 = time.time()
        out = float(
            queries.subgraph_query(
                live, jnp.asarray(src, jnp.uint32), jnp.asarray(dst, jnp.uint32)
            )
        )
        self.stats.query_s += time.time() - t0
        self.stats.queries_served += 1
        return out
