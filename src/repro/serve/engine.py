"""gLava serving engine: the paper's data structure as an online service.

`SketchServer` is the network-service wrapper around the public API plane:
one :class:`repro.api.GraphStream` session carries the summary, the
double-buffered ingest path, the planned/fused query path, the sliding
window, and the session stats — the server only adds the service-shaped
method surface (per-family endpoints a request router binds to).  All
user-facing operations go through `repro.api`; no core internals are
touched here (DESIGN.md Section 7).

Ingest path: batched edge updates, DOUBLE-BUFFERED — the next batch is
staged on the host and dispatched while the device still accumulates the
previous one; the server only blocks when the in-flight queue exceeds
``max_inflight`` or a query needs the live counters.  Backend "auto"
selects the Pallas fast path on TPU hosts.

Query path: every endpoint builds typed :class:`repro.api.Query` objects
and lets the session's planner fuse them through the jit-cached
QueryEngine; reachability is served from the engine's epoch-tagged
transitive closure, which refreshes lazily after ingest (DESIGN.md
Sections 2-4, 7).

Standing queries: a serving workload is usually the SAME mixed batch
re-asked after every ingest batch — the server exposes the session's
subscription plane (:meth:`subscribe` / :meth:`events`), so request
routers register the workload once (compiled once by the planner) and
stream timestamped result events, with reach served by incremental
closure refreshes instead of per-request rebuilds (DESIGN.md Section 8).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

from repro.api import GraphStream, Query, SketchConfig, Subscription, SubscriptionEvent


class SketchServer:
    def __init__(
        self,
        config: SketchConfig,
        seed: int = 0,
        window_slices: Optional[int] = None,
        ingest_backend: str = "scatter",
        query_backend: str = "auto",
        double_buffer: bool = True,
        max_inflight: int = 2,
        tenants: Optional[int] = None,
        checkpoint_dir: Optional[str] = None,
        wal_dir: Optional[str] = None,
        slice_width: Optional[float] = None,
        max_lateness: Optional[float] = None,
        late_policy: str = "retract",
    ):
        """``tenants=N`` opens the server in MULTI-SESSION (fleet) mode:
        one :class:`repro.fleet.SketchFleet` with N resident slots serves
        every tenant through single stacked device dispatches; per-family
        endpoints then take a required ``tenant=`` id, :meth:`tenant`
        exposes the per-tenant session surface, and :meth:`ingest_mixed`
        is the mixed-stream hot path.  ``checkpoint_dir`` enables LRU
        eviction of cold tenants to host shards (and, single-session mode,
        plain session checkpointing).

        ``wal_dir`` makes ingest durable (write-ahead-logged before every
        device dispatch; :meth:`recover` replays the suffix after a
        crash).  ``slice_width``/``max_lateness`` switch the single
        session to event-time windowing — ingest then requires per-edge
        ``timestamps`` and the watermark drives window advances (the
        fleet plane records event times in its WAL lanes but does not
        window by them, so those knobs are single-session only)."""
        if tenants is not None:
            if slice_width is not None or max_lateness is not None:
                raise ValueError(
                    "event-time windowing (slice_width/max_lateness) is "
                    "single-session only; fleet WAL lanes record event "
                    "times but tenants window by explicit advance_window()"
                )
            from repro.fleet import SketchFleet

            self.fleet: Optional["SketchFleet"] = SketchFleet.open(
                config,
                capacity=tenants,
                seed=seed,
                window_slices=window_slices,
                checkpoint_dir=checkpoint_dir,
                max_inflight=max_inflight,
                wal_dir=wal_dir,
            )
            self.stream = None
        else:
            self.fleet = None
            self.stream = GraphStream(
                config,
                seed=seed,
                window_slices=window_slices,
                ingest_backend=ingest_backend,
                query_backend=query_backend,
                double_buffer=double_buffer,
                max_inflight=max_inflight,
                checkpoint_dir=checkpoint_dir,
                wal_dir=wal_dir,
                slice_width=slice_width,
                max_lateness=max_lateness,
                late_policy=late_policy,
            )

    def _session(self, tenant=None):
        """The session a request addresses: the single stream, or the
        tenant's fleet session (fleet mode requires ``tenant=``)."""
        if self.fleet is None:
            if tenant is not None:
                raise ValueError(
                    "tenant= requires a fleet server (tenants=N)"
                )
            return self.stream
        if tenant is None:
            raise ValueError(
                "this server runs in fleet mode: pass tenant= (or use "
                ".tenant(tid) / .ingest_mixed(...))"
            )
        return self.fleet.tenant(tenant)

    # -- multi-session (fleet) surface ----------------------------------------

    def tenant(self, tenant_id):
        """The tenant's session handle (fleet mode only)."""
        if self.fleet is None:
            raise ValueError("tenant() requires a fleet server (tenants=N)")
        return self.fleet.tenant(tenant_id)

    def ingest_mixed(self, tenant_ids, src, dst, weights=None, *, timestamps=None):
        """One mixed multi-tenant arrival batch -> one device dispatch
        (fleet mode only)."""
        if self.fleet is None:
            raise ValueError(
                "ingest_mixed() requires a fleet server (tenants=N)"
            )
        return self.fleet.ingest_mixed(
            tenant_ids, src, dst, weights, timestamps=timestamps
        )

    @property
    def stats(self):
        return self.stream.stats if self.fleet is None else self.fleet.stats

    @property
    def engine(self):
        return self.stream.engine if self.fleet is None else self.fleet.engine

    # -- ingest ---------------------------------------------------------------

    def ingest(self, src, dst, weights=None, tenant=None, *, timestamps=None):
        """Dispatch one edge batch; returns as soon as the device accepts it
        (call :meth:`flush` / any query to synchronize)."""
        self._session(tenant).ingest(src, dst, weights, timestamps=timestamps)

    def recover(self):
        """Crash recovery (requires ``wal_dir``): restore the newest
        checkpoint/shards and replay the WAL suffix — see
        :meth:`repro.api.GraphStream.recover` /
        :meth:`repro.fleet.SketchFleet.recover`."""
        return (self.stream if self.fleet is None else self.fleet).recover()

    def flush(self):
        """Block until every dispatched ingest batch has landed on device."""
        (self.stream if self.fleet is None else self.fleet).flush()

    def summary(self) -> Dict[str, float]:
        """Flushed stats — the only honest read of ingest throughput while
        ingest is double-buffered."""
        return (self.stream if self.fleet is None else self.fleet).summary()

    def advance_window(self, tenant=None):
        self._session(tenant).advance_window()

    # -- per-family service endpoints -----------------------------------------

    def edge_frequency(self, src, dst, tenant=None):
        return self._session(tenant).edge_frequency(src, dst)

    def in_flow(self, keys, tenant=None):
        return self._session(tenant).in_flow(keys)

    def out_flow(self, keys, tenant=None):
        return self._session(tenant).out_flow(keys)

    def heavy_hitters(self, keys, theta: float, tenant=None):
        return self._session(tenant).heavy_hitters(keys, theta)

    def reachable(self, src, dst, tenant=None):
        return self._session(tenant).reachable(src, dst)

    def subgraph_weight(self, src, dst, tenant=None):
        return self._session(tenant).subgraph_weight(src, dst)

    def query(self, *queries, tenant=None):
        """Heterogeneous mixed-family batches, planned and fused — the
        service endpoint for callers that speak the typed IR directly."""
        return self._session(tenant).query(*queries)

    # -- standing subscriptions -----------------------------------------------

    def subscribe(self, *queries, tenant=None, **kwargs) -> Subscription:
        """Register a standing query batch (compiled once, re-evaluated
        after every ``every``-th ingest/window mutation) — the endpoint a
        request router binds long-lived client subscriptions to.  See
        :meth:`repro.api.GraphStream.subscribe`."""
        return self._session(tenant).subscribe(*queries, **kwargs)

    def monitor(self, src, dst, weights, watch, theta: float) -> bool:
        """Threshold monitor (thin wrapper over a heavy-hitter
        subscription; θ is a fraction of total stream weight).
        Single-session only — fleet callers register a per-tenant heavy
        subscription via ``tenant(tid).subscribe(..., alarm=...)``."""
        if self.fleet is not None:
            raise ValueError(
                "monitor() is single-session; use "
                "tenant(tid).subscribe(..., alarm=...) on a fleet server"
            )
        return self.stream.monitor(src, dst, weights, watch, theta)

    def events(self, tenant=None) -> Iterator[SubscriptionEvent]:
        """Drain the subscription event feed — the whole fleet's when no
        ``tenant`` is given on a fleet server."""
        if self.fleet is not None and tenant is None:
            return self.fleet.events()
        return self._session(tenant).events()

    # intentionally re-exported so request routers can build IR objects
    Query = Query
