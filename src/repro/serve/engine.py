"""gLava serving engine: the paper's data structure as an online service.

`SketchServer` is the network-service wrapper around the public API plane:
one :class:`repro.api.GraphStream` session carries the summary, the
double-buffered ingest path, the planned/fused query path, the sliding
window, and the session stats — the server only adds the service-shaped
method surface (per-family endpoints a request router binds to).  All
user-facing operations go through `repro.api`; no core internals are
touched here (DESIGN.md Section 7).

Ingest path: batched edge updates, DOUBLE-BUFFERED — the next batch is
staged on the host and dispatched while the device still accumulates the
previous one; the server only blocks when the in-flight queue exceeds
``max_inflight`` or a query needs the live counters.  Backend "auto"
selects the Pallas fast path on TPU hosts.

Query path: every endpoint builds typed :class:`repro.api.Query` objects
and lets the session's planner fuse them through the jit-cached
QueryEngine; reachability is served from the engine's epoch-tagged
transitive closure, which refreshes lazily after ingest (DESIGN.md
Sections 2-4, 7).

Standing queries: a serving workload is usually the SAME mixed batch
re-asked after every ingest batch — the server exposes the session's
subscription plane (:meth:`subscribe` / :meth:`events`), so request
routers register the workload once (compiled once by the planner) and
stream timestamped result events, with reach served by incremental
closure refreshes instead of per-request rebuilds (DESIGN.md Section 8).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

from repro.api import GraphStream, Query, SketchConfig, Subscription, SubscriptionEvent


class SketchServer:
    def __init__(
        self,
        config: SketchConfig,
        seed: int = 0,
        window_slices: Optional[int] = None,
        ingest_backend: str = "scatter",
        query_backend: str = "auto",
        double_buffer: bool = True,
        max_inflight: int = 2,
    ):
        self.stream = GraphStream(
            config,
            seed=seed,
            window_slices=window_slices,
            ingest_backend=ingest_backend,
            query_backend=query_backend,
            double_buffer=double_buffer,
            max_inflight=max_inflight,
        )

    @property
    def stats(self):
        return self.stream.stats

    @property
    def engine(self):
        return self.stream.engine

    # -- ingest ---------------------------------------------------------------

    def ingest(self, src, dst, weights=None):
        """Dispatch one edge batch; returns as soon as the device accepts it
        (call :meth:`flush` / any query to synchronize)."""
        self.stream.ingest(src, dst, weights)

    def flush(self):
        """Block until every dispatched ingest batch has landed on device."""
        self.stream.flush()

    def summary(self) -> Dict[str, float]:
        """Flushed stats — the only honest read of ingest throughput while
        ingest is double-buffered."""
        return self.stream.summary()

    def advance_window(self):
        self.stream.advance_window()

    # -- per-family service endpoints -----------------------------------------

    def edge_frequency(self, src, dst):
        return self.stream.edge_frequency(src, dst)

    def in_flow(self, keys):
        return self.stream.in_flow(keys)

    def out_flow(self, keys):
        return self.stream.out_flow(keys)

    def heavy_hitters(self, keys, theta: float):
        return self.stream.heavy_hitters(keys, theta)

    def reachable(self, src, dst):
        return self.stream.reachable(src, dst)

    def subgraph_weight(self, src, dst):
        return self.stream.subgraph_weight(src, dst)

    def query(self, *queries):
        """Heterogeneous mixed-family batches, planned and fused — the
        service endpoint for callers that speak the typed IR directly."""
        return self.stream.query(*queries)

    # -- standing subscriptions -----------------------------------------------

    def subscribe(self, *queries, **kwargs) -> Subscription:
        """Register a standing query batch (compiled once, re-evaluated
        after every ``every``-th ingest/window mutation) — the endpoint a
        request router binds long-lived client subscriptions to.  See
        :meth:`repro.api.GraphStream.subscribe`."""
        return self.stream.subscribe(*queries, **kwargs)

    def monitor(self, src, dst, weights, watch, theta: float) -> bool:
        """Threshold monitor (thin wrapper over a heavy-hitter
        subscription; θ is a fraction of total stream weight)."""
        return self.stream.monitor(src, dst, weights, watch, theta)

    def events(self) -> Iterator[SubscriptionEvent]:
        """Drain the session-wide subscription event feed."""
        return self.stream.events()

    # intentionally re-exported so request routers can build IR objects
    Query = Query
