"""Event-time stream plane: watermarks, WAL durability, backpressure.

The paper's streams are high-volume and *dynamic* — edges arrive late and
out of order, and the turnstile model (Section 6.1.1) exists precisely so
a summary can absorb corrections.  This package supplies the host-side
machinery that turns the arrival-ordered session facade
(:class:`repro.api.stream.GraphStream`) into an event-time system:

- :mod:`repro.stream.watermark` — per-source low-watermark tracking with
  bounded out-of-orderness (``max_lateness``) plus the slice arithmetic
  that maps event times onto the sliding-window ring.
- :mod:`repro.stream.wal` — an append-only segmented write-ahead log of
  fixed-size binary records.  Every logical mutation is appended *before*
  the donated device dispatch, so a crash can always be replayed from the
  newest checkpoint.
- :mod:`repro.stream.events` — the bounded event feed with an explicit
  overflow policy (``drop_oldest`` / ``drop_newest`` / ``error``) and a
  dropped-events counter, replacing silent ``deque(maxlen=...)`` loss.

Everything here is deliberately host-side (numpy + stdlib, no jax): the
jit boundaries stay in ``repro.api.stream`` where the donation contracts
are registered, and this package stays importable in a process that never
touches an accelerator (e.g. a WAL inspection tool).
"""
from repro.stream.events import (
    OVERFLOW_POLICIES,
    EventFeed,
    EventOverflowError,
)
from repro.stream.wal import (
    OP_ADVANCE,
    OP_COMMIT,
    OP_EDGE,
    OP_MERGE,
    WAL_RECORD,
    AdvanceMutation,
    EdgeMutation,
    MergeMutation,
    WriteAheadLog,
)
from repro.stream.watermark import WatermarkTracker, slice_of

__all__ = [
    "OVERFLOW_POLICIES",
    "EventFeed",
    "EventOverflowError",
    "OP_ADVANCE",
    "OP_COMMIT",
    "OP_EDGE",
    "OP_MERGE",
    "WAL_RECORD",
    "AdvanceMutation",
    "EdgeMutation",
    "MergeMutation",
    "WriteAheadLog",
    "WatermarkTracker",
    "slice_of",
]
