"""Bounded event feeds with an explicit overflow policy.

``gs.events()`` and every :class:`~repro.api.subscription.Subscription`
used to hold pending events in a bare ``deque(maxlen=...)`` — overflow
silently evicted the OLDEST pending event, so a slow consumer lost data
with no signal.  :class:`EventFeed` makes the loss explicit:

- ``drop_oldest`` (default, the old behavior) — evict the oldest pending
  event to make room, but count it in :attr:`dropped`;
- ``drop_newest`` — refuse the incoming event instead (keep the history a
  consumer is mid-way through draining), counted the same way;
- ``error`` — raise :class:`EventOverflowError`, surfacing backpressure
  to the producer (the ingest call that triggered the evaluation).

The counter is monotone and cheap to poll; monitoring loops should treat
``feed.dropped > 0`` as an alert that ``every=`` is too fine or polling
is too slow.
"""
from __future__ import annotations

import collections
from typing import Iterator, List, Optional

OVERFLOW_POLICIES = ("drop_oldest", "drop_newest", "error")


class EventOverflowError(RuntimeError):
    """A bounded event feed with ``policy="error"`` was pushed while full."""


class EventFeed:
    """A bounded FIFO of pending events with an explicit overflow policy."""

    def __init__(self, maxlen: int, policy: str = "drop_oldest"):
        if maxlen < 1:
            raise ValueError(f"maxlen must be >= 1, got {maxlen}")
        if policy not in OVERFLOW_POLICIES:
            raise ValueError(
                f"unknown overflow policy {policy!r} (want one of "
                f"{OVERFLOW_POLICIES})"
            )
        self.maxlen = int(maxlen)
        self.policy = policy
        self._events: collections.deque = collections.deque()
        self._dropped = 0

    @property
    def dropped(self) -> int:
        """Events lost to overflow since the feed was created (monotone)."""
        return self._dropped

    def push(self, event) -> None:
        """Enqueue ``event``, applying the overflow policy when full."""
        if len(self._events) >= self.maxlen:
            if self.policy == "drop_oldest":
                self._events.popleft()
                self._dropped += 1
            elif self.policy == "drop_newest":
                self._dropped += 1
                return
            else:
                raise EventOverflowError(
                    f"event feed full ({self.maxlen} pending, "
                    f"{self._dropped} previously dropped); drain poll()/"
                    f"events() or pick a drop_* overflow policy"
                )
        self._events.append(event)

    def popleft(self):
        return self._events.popleft()

    def drain(self, max_events: Optional[int] = None) -> List:
        """Pop up to ``max_events`` pending events, oldest first."""
        out: List = []
        while self._events and (max_events is None or len(out) < max_events):
            out.append(self._events.popleft())
        return out

    def __len__(self) -> int:
        return len(self._events)

    def __bool__(self) -> bool:
        return bool(self._events)

    def __iter__(self) -> Iterator:
        while self._events:
            yield self._events.popleft()

    def __repr__(self) -> str:  # pragma: no cover — debugging sugar
        return (
            f"<EventFeed pending={len(self._events)}/{self.maxlen} "
            f"policy={self.policy} dropped={self._dropped}>"
        )
