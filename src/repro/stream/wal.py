"""Append-only segmented write-ahead log for graph-stream mutations.

Durability contract (see DESIGN.md §13): every LOGICAL mutation —
ingest / delete / explicit window advance / merge — is appended here
*before* the donated device dispatch, so the stream state is always
``newest checkpoint + WAL suffix``.  Watermark-driven *auto* advances are
NOT logged: they are a pure function of the logged event times and are
re-derived bit-identically during replay.

Layout
------
Fixed-size 40-byte little-endian records (:data:`WAL_RECORD`)::

    seq u8 | event_time f8 | tenant i4 | src u4 | dst u4 | weight f4 | op u4 | pad u4

grouped into *segments* ``wal-<start_seq>.seg``, each opened by a 16-byte
header (``GSWAL001`` magic + u8 start seq).  An ingest/delete of B edges
is B ``OP_EDGE`` records followed by one ``OP_COMMIT`` record whose
``src`` field carries the edge count and whose ``dst`` carries the
source key (watermark lane); explicit advances and merge barriers are
single self-committing records.  ``seq`` is a global monotone record
counter — the commit record's seq is the mutation's durable position.

Crash safety: appends are the only writes, so a crash leaves at most a
torn tail — a trailing partial record (dropped by size) or a trailing
edge run with no commit record (dropped by the replay scanner).  A
mutation is replayed iff its commit record is fully on disk.

fsync batching: ``fsync_every=N`` fsyncs every N-th committed mutation
(and on :meth:`sync`, which checkpointing always calls), trading a
bounded window of recent mutations for append throughput.

Segment rotation is keyed to checkpoint steps: the session rotates right
after each checkpoint saves, so a segment never straddles a checkpoint
boundary and :meth:`gc` can drop exactly the segments whose records are
all covered by the OLDEST retained checkpoint (``CheckpointManager`` GC
never strands a needed suffix).
"""
from __future__ import annotations

import dataclasses
import os
from pathlib import Path
from typing import Iterator, List, Optional, Union

import numpy as np

MAGIC = b"GSWAL001"
HEADER_SIZE = 16  # 8-byte magic + u8 start_seq

#: One fixed-size WAL record (little-endian, 40 bytes).
WAL_RECORD = np.dtype(
    [
        ("seq", "<u8"),
        ("event_time", "<f8"),
        ("tenant", "<i4"),
        ("src", "<u4"),
        ("dst", "<u4"),
        ("weight", "<f4"),
        ("op", "<u4"),
        ("pad", "<u4"),
    ]
)
RECORD_SIZE = WAL_RECORD.itemsize

OP_EDGE = 1      # one edge of an ingest/delete batch (weight signed)
OP_COMMIT = 2    # batch commit marker: src=n_edges, dst=source_key
OP_ADVANCE = 3   # explicit advance_window() (self-committing)
OP_MERGE = 4     # merge barrier (self-committing; replay refuses past it)


@dataclasses.dataclass(frozen=True)
class EdgeMutation:
    """One replayable ingest/delete batch (weights carry the sign)."""

    seq: int                      # commit record's seq
    src: np.ndarray               # uint32 keys (post-codec)
    dst: np.ndarray               # uint32 keys
    weights: np.ndarray           # float32, signed
    timestamps: Optional[np.ndarray]  # float64 event times, or None
    source_key: int               # watermark lane (0 = default source)
    tenant: int = 0


@dataclasses.dataclass(frozen=True)
class AdvanceMutation:
    """One explicit ``advance_window()``."""

    seq: int


@dataclasses.dataclass(frozen=True)
class MergeMutation:
    """A merge barrier: state entered the session outside this log."""

    seq: int


Mutation = Union[EdgeMutation, AdvanceMutation, MergeMutation]


class WalCorruptError(RuntimeError):
    """A segment failed structural validation (bad magic / seq gap)."""


def _segment_path(directory: Path, start_seq: int) -> Path:
    return directory / f"wal-{start_seq:020d}.seg"


def _parse_start_seq(path: Path) -> int:
    return int(path.name[len("wal-"):-len(".seg")])


class WriteAheadLog:
    """Segmented append-only WAL (one per session, or one per tenant lane)."""

    def __init__(self, directory: Union[str, Path], fsync_every: int = 1):
        if fsync_every < 1:
            raise ValueError(f"fsync_every must be >= 1, got {fsync_every}")
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.fsync_every = int(fsync_every)
        self._fh = None          # open segment file handle (append mode)
        self._since_sync = 0
        self._next_seq = 1
        segs = self.segments()
        if segs:
            # Resume numbering after everything on disk, committed or torn —
            # seqs must stay monotone even past records replay will skip.
            records = _read_segment(segs[-1])
            if records.size:
                self._next_seq = int(records["seq"][-1]) + 1
            else:
                self._next_seq = _parse_start_seq(segs[-1])

    # -- append path ---------------------------------------------------------

    @property
    def last_seq(self) -> int:
        """Seq of the last appended record (0 = empty log)."""
        return self._next_seq - 1

    def segments(self) -> List[Path]:
        """Segment paths, oldest first."""
        return sorted(self.dir.glob("wal-*.seg"), key=_parse_start_seq)

    def _ensure_open(self, start_seq: int):
        if self._fh is None:
            path = _segment_path(self.dir, start_seq)
            self._fh = open(path, "ab")
            if self._fh.tell() == 0:
                self._fh.write(MAGIC + np.uint64(start_seq).tobytes())

    def _append(self, records: np.ndarray) -> int:
        self._ensure_open(int(records["seq"][0]))
        self._fh.write(records.tobytes())
        self._fh.flush()
        self._since_sync += 1
        if self._since_sync >= self.fsync_every:
            os.fsync(self._fh.fileno())
            self._since_sync = 0
        return int(records["seq"][-1])

    def append_edges(
        self,
        src: np.ndarray,
        dst: np.ndarray,
        weights: np.ndarray,
        timestamps: Optional[np.ndarray] = None,
        source_key: int = 0,
        tenant: int = 0,
    ) -> int:
        """Append one ingest/delete batch + its commit marker.  Returns the
        commit seq (the mutation's durable position)."""
        n = int(np.asarray(src).shape[0])
        records = np.zeros(n + 1, WAL_RECORD)
        records["seq"] = np.arange(self._next_seq, self._next_seq + n + 1, dtype=np.uint64)
        records["op"][:n] = OP_EDGE
        records["src"][:n] = np.asarray(src, np.uint32)
        records["dst"][:n] = np.asarray(dst, np.uint32)
        records["weight"][:n] = np.asarray(weights, np.float32)
        records["tenant"][:] = tenant
        if timestamps is not None:
            records["event_time"][:n] = np.asarray(timestamps, np.float64)
        else:
            records["event_time"][:n] = np.nan
        commit = records[-1:]
        commit["op"] = OP_COMMIT
        commit["src"] = n
        commit["dst"] = np.uint32(source_key)
        self._next_seq += n + 1
        return self._append(records)

    def _append_marker(self, op: int, tenant: int = 0) -> int:
        record = np.zeros(1, WAL_RECORD)
        record["seq"] = self._next_seq
        record["op"] = op
        record["tenant"] = tenant
        record["event_time"] = np.nan
        self._next_seq += 1
        return self._append(record)

    def append_advance(self, tenant: int = 0) -> int:
        """Append one explicit window advance (self-committing)."""
        return self._append_marker(OP_ADVANCE, tenant)

    def append_merge_barrier(self, tenant: int = 0) -> int:
        """Append a merge barrier: replay cannot cross it (the merged-in
        state never went through this log) — checkpoint right after."""
        return self._append_marker(OP_MERGE, tenant)

    def sync(self) -> None:
        """Force fsync of the open segment (checkpointing calls this)."""
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._since_sync = 0

    def rotate(self) -> None:
        """Close the current segment; the next append opens a fresh one.
        Called right after a checkpoint commits so segment boundaries align
        with checkpoint steps (the GC contract)."""
        if self._fh is not None:
            self.sync()
            self._fh.close()
            self._fh = None

    def close(self) -> None:
        self.rotate()

    # -- retention -----------------------------------------------------------

    def gc(self, covered_seq: int) -> int:
        """Drop segments whose records are ALL <= ``covered_seq`` (i.e.
        already folded into every retained checkpoint).  Pass the minimum
        ``wal_seq`` across retained checkpoint manifests.  Returns the
        number of segments removed — never the open segment, and never a
        segment the newest manifest still needs."""
        removed = 0
        segs = self.segments()
        for i, path in enumerate(segs):
            nxt_start = (
                _parse_start_seq(segs[i + 1]) if i + 1 < len(segs) else None
            )
            if nxt_start is None:
                break  # the newest (possibly open) segment always stays
            if nxt_start - 1 <= covered_seq:
                path.unlink()
                removed += 1
            else:
                break  # segments are seq-ordered; later ones are needed too
        return removed

    # -- replay --------------------------------------------------------------

    def replay(self, after_seq: int = 0) -> Iterator[Mutation]:
        """Yield committed mutations with commit seq > ``after_seq``,
        oldest first.  Torn tails (partial trailing record, or a trailing
        edge run with no commit marker) are silently ignored — by the
        append protocol they were never acknowledged."""
        for path in self.segments():
            records = _read_segment(path)
            # A fresh run per segment: a batch never spans segments (the
            # append protocol only rotates between batches), so an edge run
            # still pending at a segment's end is a torn, unacknowledged
            # tail from a crash — dropped, like trailing partial bytes.
            pending: List[np.ndarray] = []
            for rec in _group_mutations(records, pending):
                if rec.seq > after_seq:
                    yield rec

    def record_count(self) -> int:
        """Total records currently on disk (diagnostics)."""
        return sum(int(_read_segment(p).size) for p in self.segments())


def _read_segment(path: Path) -> np.ndarray:
    raw = path.read_bytes()
    if len(raw) < HEADER_SIZE or raw[:8] != MAGIC:
        raise WalCorruptError(f"bad WAL segment header: {path}")
    body = raw[HEADER_SIZE:]
    usable = (len(body) // RECORD_SIZE) * RECORD_SIZE  # drop torn tail bytes
    return np.frombuffer(body[:usable], WAL_RECORD)


def _group_mutations(
    records: np.ndarray, pending: List[np.ndarray]
) -> Iterator[Mutation]:
    """Group one segment's records into committed logical mutations;
    ``pending`` accumulates the current (not yet committed) edge run."""
    ops = records["op"]
    for i in range(records.size):
        op = int(ops[i])
        rec = records[i : i + 1]
        if op == OP_EDGE:
            pending.append(rec)
        elif op == OP_COMMIT:
            n = int(rec["src"][0])
            run = (
                np.concatenate(pending) if pending else np.zeros(0, WAL_RECORD)
            )
            pending.clear()
            if run.size != n:
                raise WalCorruptError(
                    f"commit record seq={int(rec['seq'][0])} claims {n} edges "
                    f"but {run.size} are on disk"
                )
            ts = run["event_time"].astype(np.float64)
            has_ts = run.size > 0 and not np.any(np.isnan(ts))
            yield EdgeMutation(
                seq=int(rec["seq"][0]),
                src=run["src"].astype(np.uint32),
                dst=run["dst"].astype(np.uint32),
                weights=run["weight"].astype(np.float32),
                timestamps=ts if has_ts else None,
                source_key=int(rec["dst"][0]),
                tenant=int(rec["tenant"][0]),
            )
        elif op == OP_ADVANCE:
            pending.clear()
            yield AdvanceMutation(seq=int(rec["seq"][0]))
        elif op == OP_MERGE:
            pending.clear()
            yield MergeMutation(seq=int(rec["seq"][0]))
        else:
            raise WalCorruptError(f"unknown WAL op {op} at seq {int(rec['seq'][0])}")
