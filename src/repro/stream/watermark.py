"""Watermarks: bounded out-of-orderness for event-time ingest.

A *watermark* is the stream's low-water mark: the promise that no edge
with event time below it will be accepted any more.  We use the standard
bounded-lateness construction — each source's watermark trails the
maximum event time it has emitted by ``max_lateness``, and the session
watermark is the MINIMUM over sources (a slow source holds the whole
stream back, which is what makes the merge safe):

    W  =  min over sources ( max event time seen )  -  max_lateness

The watermark is monotone by construction (per-source maxima only grow,
and we clamp against the previous value so registering a new lagging
source can never move W backwards).  ``GraphStream`` advances the sliding
window whenever W crosses a slice boundary, routes late-but-in-bound
edges (event time >= W but behind the head slice) into their correct open
slice, and retracts or drops too-late edges (event time < W, or landing
below the live ring) via the turnstile-delete path — counted here in
``late_dropped`` / ``late_retracted``.

Host-side only: tracking is a tiny dict update per batch; the per-edge
work (slice routing) is vectorized numpy in the session.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import numpy as np

#: Source key used when ``ingest`` is called without ``source=`` — a
#: single anonymous source, which degrades to plain per-stream lateness.
DEFAULT_SOURCE = 0


def slice_of(t: float, slice_width: float) -> int:
    """Absolute slice index of event time ``t``: floor(t / slice_width)."""
    return int(math.floor(t / slice_width))


def slices_of(ts: np.ndarray, slice_width: float) -> np.ndarray:
    """Vectorized :func:`slice_of` over an event-time column (int64)."""
    return np.floor_divide(ts, slice_width).astype(np.int64)


class WatermarkTracker:
    """Per-source low-watermark merge with bounded lateness.

    ``observe(source_key, t_max)`` folds one batch's maximum event time
    for one source and returns the (monotone) session watermark.  State is
    JSON-serializable via :meth:`state` / :meth:`from_state` so it rides
    in checkpoint metadata and WAL replay re-derives the identical
    advance schedule."""

    def __init__(self, max_lateness: float):
        if not (max_lateness >= 0.0) or not math.isfinite(max_lateness):
            raise ValueError(
                f"max_lateness must be finite and >= 0, got {max_lateness}"
            )
        self.max_lateness = float(max_lateness)
        self._sources: Dict[int, float] = {}
        self._watermark = -math.inf
        self.late_dropped = 0
        self.late_retracted = 0

    # -- observation ---------------------------------------------------------

    def observe(self, source_key: int, t_max: float) -> float:
        """Fold one batch's max event time for ``source_key``; returns the
        updated session watermark (monotone)."""
        if not math.isfinite(t_max):
            raise ValueError(f"event times must be finite, got max {t_max}")
        key = int(source_key)
        prev = self._sources.get(key, -math.inf)
        if t_max > prev:
            self._sources[key] = float(t_max)
        candidate = min(self._sources.values()) - self.max_lateness
        if candidate > self._watermark:
            self._watermark = candidate
        return self._watermark

    @property
    def watermark(self) -> float:
        """The current low watermark (-inf before the first observation)."""
        return self._watermark

    @property
    def sources(self) -> Dict[int, float]:
        """Per-source max event times (copy; keys are uint32 source keys)."""
        return dict(self._sources)

    # -- persistence ---------------------------------------------------------

    def state(self) -> dict:
        """JSON-safe snapshot for checkpoint metadata."""
        return {
            "max_lateness": self.max_lateness,
            "sources": {str(k): v for k, v in self._sources.items()},
            "watermark": None if self._watermark == -math.inf else self._watermark,
            "late_dropped": self.late_dropped,
            "late_retracted": self.late_retracted,
        }

    @classmethod
    def from_state(cls, state: Optional[dict]) -> "WatermarkTracker":
        tracker = cls(float(state["max_lateness"]))
        tracker._sources = {int(k): float(v) for k, v in state["sources"].items()}
        wm = state.get("watermark")
        tracker._watermark = -math.inf if wm is None else float(wm)
        tracker.late_dropped = int(state.get("late_dropped", 0))
        tracker.late_retracted = int(state.get("late_retracted", 0))
        return tracker

    def __repr__(self) -> str:  # pragma: no cover — debugging sugar
        wm = "-inf" if self._watermark == -math.inf else f"{self._watermark:g}"
        return (
            f"<WatermarkTracker W={wm} lateness={self.max_lateness:g} "
            f"sources={len(self._sources)} late_dropped={self.late_dropped} "
            f"late_retracted={self.late_retracted}>"
        )
