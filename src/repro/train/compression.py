"""Sketched gradient all-reduce with error feedback (FetchSGD-style,
arXiv:2007.07682) — the paper's linear-sketch machinery doing double duty as
the distributed-optimization compression trick.

The gradient vector is CountSketch'd into a (d, w) table (the SAME signed
affine-Mersenne hashing as the gLava core), the sketches are ``psum``-merged
(linearity — exactly the paper's Section 6.3 merge), the top-k coordinates
are un-sketched (median estimator), and the un-transmitted residual is kept
locally as error feedback for the next step.

Compression ratio = n_params / (d·w).  Biased (top-k), but error feedback
makes it convergent; the quality benchmark is bench_compression.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.hashing import HashFamily, make_hash_family


@dataclasses.dataclass(frozen=True)
class CompressorConfig:
    depth: int = 5
    width: int = 16384
    top_k: int = 2048
    momentum: float = 0.9  # sketch-side momentum as in FetchSGD (0 = off)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CompressorState:
    error: jax.Array      # (n,) error-feedback accumulator
    momentum: jax.Array   # (d, w) sketch-side momentum
    hash: HashFamily
    config: CompressorConfig = dataclasses.field(metadata=dict(static=True))


def init_compressor(cfg: CompressorConfig, n_params: int, key: jax.Array) -> CompressorState:
    fam = make_hash_family(key, cfg.depth, cfg.width)
    return CompressorState(
        error=jnp.zeros((n_params,), jnp.float32),
        momentum=jnp.zeros((cfg.depth, cfg.width), jnp.float32),
        hash=fam,
        config=cfg,
    )


def _sketch(state: CompressorState, vec: jax.Array) -> jax.Array:
    """CountSketch a flat vector -> (d, w)."""
    idx = jnp.arange(vec.shape[0], dtype=jnp.uint32)
    h = state.hash(idx)                      # (d, n)
    s = state.hash.signs(idx).astype(jnp.float32)
    d = h.shape[0]
    d_idx = jnp.broadcast_to(jnp.arange(d)[:, None], h.shape)
    return jnp.zeros((d, state.config.width), jnp.float32).at[d_idx, h].add(
        s * vec[None, :]
    )


def _unsketch(state: CompressorState, table: jax.Array, n: int) -> jax.Array:
    """Median-of-d estimate for every coordinate -> (n,)."""
    idx = jnp.arange(n, dtype=jnp.uint32)
    h = state.hash(idx)
    s = state.hash.signs(idx).astype(jnp.float32)
    vals = jnp.take_along_axis(table, h, axis=1) * s  # (d, n)
    return jnp.median(vals, axis=0)


def roundtrip(
    state: CompressorState,
    grad_vec: jax.Array,
    psum_fn=None,
) -> Tuple[jax.Array, CompressorState]:
    """One full compress → (psum) → decompress cycle with exact error
    feedback.  ``psum_fn`` merges sketches across data-parallel workers
    (None = single worker)."""
    cfg = state.config
    n = grad_vec.shape[0]
    corrected = grad_vec + state.error
    table = _sketch(state, corrected)
    if psum_fn is not None:
        table = psum_fn(table)
    mom = cfg.momentum * state.momentum + table
    est = _unsketch(state, mom, n)
    k = min(cfg.top_k, n)
    thresh = jnp.sort(jnp.abs(est))[-k]
    update = jnp.where(jnp.abs(est) >= thresh, est, 0.0)
    new_mom = mom - _sketch(state, update)
    new_error = corrected - update
    new_state = dataclasses.replace(state, momentum=new_mom, error=new_error)
    return update, new_state


# -- pytree <-> flat helpers --------------------------------------------------


def flatten_grads(grads: Any) -> Tuple[jax.Array, Any]:
    leaves, treedef = jax.tree.flatten(grads)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    shapes = [(l.shape, l.dtype) for l in leaves]
    return flat, (treedef, shapes)


def unflatten_grads(flat: jax.Array, spec) -> Any:
    treedef, shapes = spec
    out = []
    off = 0
    for shape, dtype in shapes:
        import numpy as np

        n = int(np.prod(shape)) if shape else 1
        out.append(flat[off : off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, out)
