"""Optimizers built from scratch (no optax in the target container).

AdamW with configurable state dtypes — the dtype knobs are what let
arctic-480b fit a single v5e pod under FSDP (bf16 moments ≈ 4 bytes/param of
optimizer state instead of 8; see DESIGN.md Section 4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # dtype knobs (FSDP memory fit)
    m_dtype: Any = jnp.float32
    v_dtype: Any = jnp.float32
    # schedule
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any  # pytree like params
    v: Any  # pytree like params


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1.0, cfg.warmup_steps))
    progress = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
        0.0,
        1.0,
    )
    cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cosine
    return cfg.lr * warm * frac


def init_adamw(cfg: AdamWConfig, params: Any) -> AdamWState:
    m = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=cfg.m_dtype), params)
    v = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=cfg.v_dtype), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_adamw(
    cfg: AdamWConfig,
    state: AdamWState,
    params: Any,
    grads: Any,
) -> tuple[Any, AdamWState, dict]:
    """One AdamW step.  Math in fp32 regardless of storage dtypes."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return (
            new_p.astype(p.dtype),
            m32.astype(cfg.m_dtype),
            v32.astype(cfg.v_dtype),
        )

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, AdamWState(step, new_m, new_v), metrics


# Convenience single-tensor SGD used by tiny tests / examples.
def sgd(params: Any, grads: Any, lr: float) -> Any:
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
