"""Production train loop: microbatch accumulation, periodic async
checkpoints, crash-exact resume, straggler watchdog, optional sketched
gradient compression, and failure injection for FT tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.train import compression as comp_mod
from repro.train import optimizer as opt_mod


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    log_every: int = 10
    # microbatch gradient accumulation (1 = off)
    grad_accum: int = 1
    # straggler mitigation: flag steps slower than watchdog_factor × the
    # running median (on real pods: triggers backup-worker dispatch; here:
    # recorded + surfaced to the caller)
    watchdog_factor: float = 3.0
    # sketched gradient compression (None = off)
    compressor: Optional[comp_mod.CompressorConfig] = None
    # failure injection for FT tests: raise at this step (simulates preempt)
    fail_at_step: Optional[int] = None


@dataclasses.dataclass
class TrainResult:
    state: Any
    history: list
    straggler_steps: list
    resumed_from: Optional[int]


def make_accum_step(loss_fn: Callable, opt_cfg: opt_mod.AdamWConfig, n_accum: int):
    """Turn loss_fn(params, microbatch) into an accumulated train step over a
    batch with a leading microbatch axis (n_accum, ...)."""

    def step(state, batch):
        params, opt = state["params"], state["opt"]

        def micro(accum, mb):
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            return jax.tree.map(jnp.add, accum, grads), loss

        zero = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        if n_accum > 1:
            grads, losses = jax.lax.scan(micro, zero, batch)
            grads = jax.tree.map(lambda g: g / n_accum, grads)
            loss = jnp.mean(losses)
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, jax.tree.map(lambda x: x[0], batch)
            )
        new_params, new_opt, om = opt_mod.apply_adamw(opt_cfg, opt, params, grads)
        return {"params": new_params, "opt": new_opt}, {"loss": loss, **om}

    return step


def train_loop(
    init_state: Callable[[jax.Array], Any],
    step_fn: Callable,
    batches: Iterator[Dict[str, np.ndarray]],
    cfg: TrainerConfig,
    seed: int = 0,
) -> TrainResult:
    """Run to total_steps with FT: if a checkpoint exists in checkpoint_dir,
    resume EXACTLY (step counter + optimizer state + params)."""
    mgr = (
        CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep_checkpoints)
        if cfg.checkpoint_dir
        else None
    )
    state = init_state(jax.random.key(seed))
    start_step = 0
    resumed_from = None
    if mgr is not None and mgr.latest_step() is not None:
        state, meta = mgr.restore(like=state)
        start_step = meta["step"]
        resumed_from = start_step

    jstep = jax.jit(step_fn, donate_argnums=(0,))
    history = []
    stragglers = []
    durations = []
    for step in range(start_step, cfg.total_steps):
        batch = next(batches)
        if cfg.fail_at_step is not None and step == cfg.fail_at_step:
            # Simulated preemption: checkpoints initiated on earlier steps
            # are durable by the time a later step dies (on real pods the
            # async writer has had many step-times to land; here steps are
            # microseconds, so join it explicitly before dying).
            if mgr is not None:
                mgr.wait()
            raise RuntimeError(f"injected failure at step {step}")
        t0 = time.time()
        state, metrics = jstep(state, jax.tree.map(jnp.asarray, batch))
        metrics = jax.tree.map(float, jax.device_get(metrics))
        dt = time.time() - t0
        durations.append(dt)
        med = float(np.median(durations[-50:]))
        if len(durations) > 5 and dt > cfg.watchdog_factor * med:
            stragglers.append({"step": step, "duration": dt, "median": med})
        history.append({"step": step, "duration_s": dt, **metrics})
        if cfg.log_every and step % cfg.log_every == 0:
            print(f"[train] step {step}: loss={metrics.get('loss', float('nan')):.4f} {dt*1e3:.0f}ms")
        next_step = step + 1
        if mgr is not None and (
            next_step % cfg.checkpoint_every == 0 or next_step == cfg.total_steps
        ):
            mgr.save_async(next_step, state, {"step": next_step})
    if mgr is not None:
        mgr.wait()
    return TrainResult(state, history, stragglers, resumed_from)


def compressed_data_parallel_step(
    loss_fn: Callable,
    opt_cfg: opt_mod.AdamWConfig,
    comp_cfg: comp_mod.CompressorConfig,
    axis_name: Optional[str] = None,
):
    """Train step whose gradient exchange is the SKETCHED all-reduce: grads
    are CountSketch'd (gLava's signed cousin), psum'd over `axis_name` (the
    linear-sketch merge), top-k-decoded with error feedback.  Used under
    shard_map over the data axis; axis_name=None gives the single-worker
    semantics for tests."""

    def step(state, batch):
        params, opt, cstate = state["params"], state["opt"], state["comp"]
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        flat, spec = comp_mod.flatten_grads(grads)
        psum_fn = (
            (lambda t: jax.lax.psum(t, axis_name)) if axis_name is not None else None
        )
        update_flat, cstate = comp_mod.roundtrip(cstate, flat, psum_fn)
        if axis_name is not None:
            loss = jax.lax.pmean(loss, axis_name)
        grads_hat = comp_mod.unflatten_grads(update_flat, spec)
        new_params, new_opt, om = opt_mod.apply_adamw(opt_cfg, opt, params, grads_hat)
        return {"params": new_params, "opt": new_opt, "comp": cstate}, {
            "loss": loss,
            **om,
        }

    return step
