"""Minimal offline stand-in for ``hypothesis``.

The CI container has no network access, so ``pip install hypothesis`` is
impossible; this shim provides the slice of the API the suite uses
(``given``, ``settings``, and the ``strategies`` below) with deterministic
pseudo-random example generation.  ``tests/conftest.py`` installs it into
``sys.modules["hypothesis"]`` ONLY when the real package is missing — with
hypothesis installed the suite runs unchanged against the real thing.

Semantics: ``@given`` re-runs the test body ``max_examples`` times with
freshly drawn kwargs; draw #0 uses every strategy's minimal example so
boundary cases (``n=1``-style) are always exercised.  No shrinking — the
failing example's kwargs are attached to the assertion message instead.
"""
from __future__ import annotations

import functools
import inspect
import random
import types


class Strategy:
    def __init__(self, draw_fn, minimal_fn):
        self._draw = draw_fn
        self._minimal = minimal_fn

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def minimal(self):
        return self._minimal()


def integers(min_value=0, max_value=2**63 - 1) -> Strategy:
    return Strategy(
        lambda rng: rng.randint(min_value, max_value), lambda: min_value
    )


def floats(min_value=0.0, max_value=1.0, **_kw) -> Strategy:
    return Strategy(
        lambda rng: rng.uniform(min_value, max_value), lambda: min_value
    )


def booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5, lambda: False)


def sampled_from(elements) -> Strategy:
    elements = list(elements)
    return Strategy(lambda rng: rng.choice(elements), lambda: elements[0])


def lists(elements: Strategy, min_size=0, max_size=10) -> Strategy:
    return Strategy(
        lambda rng: [
            elements.draw(rng) for _ in range(rng.randint(min_size, max_size))
        ],
        lambda: [elements.minimal() for _ in range(min_size)],
    )


def just(value) -> Strategy:
    return Strategy(lambda rng: value, lambda: value)


DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Records max_examples on the (already-``given``-wrapped) test."""

    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(**strategy_kwargs):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **fixture_kwargs):
            n = getattr(wrapper, "_shim_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for i in range(n):
                if i == 0:
                    drawn = {k: s.minimal() for k, s in strategy_kwargs.items()}
                else:
                    drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **{**fixture_kwargs, **drawn})
                except Exception as e:  # surface the failing example
                    raise AssertionError(
                        f"falsifying example (shim, run {i}): {drawn}"
                    ) from e

        # Hide the drawn parameters from pytest's fixture resolution.
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategy_kwargs
            ]
        )
        return wrapper

    return deco


# `from hypothesis import strategies as st` needs a module-like attribute.
strategies = types.ModuleType("hypothesis.strategies")
for _name in ("integers", "floats", "booleans", "sampled_from", "lists", "just"):
    setattr(strategies, _name, globals()[_name])
