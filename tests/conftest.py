"""Suite bootstrap: make every test module collect OFFLINE.

If the real ``hypothesis`` is importable it is used untouched; otherwise
the vendored shim (``tests/_hypothesis_compat.py``) is installed under the
``hypothesis`` name before any test module imports it.
"""
import importlib.util
import pathlib
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    _shim_path = pathlib.Path(__file__).parent / "_hypothesis_compat.py"
    _spec = importlib.util.spec_from_file_location("hypothesis", _shim_path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies
