"""Tests for the static-analysis plane (repro.analysis).

Every rule gets a positive fixture (violates exactly that rule) and a
clean twin (negative), plus an end-to-end run over the real ``src/repro``
tree asserting zero unbaselined violations — the same gate CI runs.
"""
import json
import pathlib
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    ENTRY_POINTS,
    EntryPoint,
    TracedEntry,
    lint_file,
    lint_tree,
    reduces_full_counters,
    run_jaxpr_pass,
)
from repro.analysis.contracts import (
    Violation,
    apply_baseline,
    check_retrace_query_families,
)
from repro.analysis.jaxpr_lint import check_entry_point
from repro.analysis.runner import main, run_analysis

SRC_REPRO = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
TESTS_DIR = pathlib.Path(__file__).resolve().parent


def _rules(violations):
    return sorted({v.rule for v in violations})


# ---------------------------------------------------------------------------
# jaxpr pass — one positive + one negative per contract
# ---------------------------------------------------------------------------


def _ep(name, contracts, entry):
    return EntryPoint(name=name, contracts=contracts, build=lambda: entry)


def test_no_host_callback_positive_and_negative():
    x = jnp.ones(4)

    def dirty(a):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(a.shape, a.dtype), a
        )

    bad = check_entry_point(
        _ep("fix.cb", ("no-host-callback",), TracedEntry(dirty, (x,)))
    )
    assert _rules(bad) == ["no-host-callback"]
    good = check_entry_point(
        _ep("fix.clean", ("no-host-callback",), TracedEntry(lambda a: a + 1, (x,)))
    )
    assert good == []


def test_no_wide_dtype_positive_and_negative():
    from jax.experimental import enable_x64

    x = jnp.ones(4)

    def dirty(a):
        with enable_x64():
            return a.astype(jnp.float64) * 2.0

    bad = check_entry_point(
        _ep("fix.wide", ("no-wide-dtype",), TracedEntry(dirty, (x,)))
    )
    assert _rules(bad) == ["no-wide-dtype"]
    good = check_entry_point(
        _ep("fix.narrow", ("no-wide-dtype",), TracedEntry(lambda a: a * 2.0, (x,)))
    )
    assert good == []


def test_no_counter_reduction_positive_and_negative():
    counters = jnp.ones((2, 8, 8))
    shape = (2, 8, 8)
    bad = check_entry_point(
        _ep(
            "fix.reduce",
            ("no-counter-reduction",),
            TracedEntry(lambda c: jnp.sum(c), (counters,), counters_shape=shape),
        )
    )
    assert _rules(bad) == ["no-counter-reduction"]
    good = check_entry_point(
        _ep(
            "fix.gather",
            ("no-counter-reduction",),
            TracedEntry(lambda c: c[:, 0, 0], (counters,), counters_shape=shape),
        )
    )
    assert good == []
    # the test-facing helper agrees (used by test_query_engine.py)
    assert reduces_full_counters(lambda c: jnp.sum(c), shape, counters)
    assert not reduces_full_counters(lambda c: c[:, 0, 0], shape, counters)


def test_collectives_only_under_shard_map():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    # pmap's psum sits OUTSIDE any shard_map region -> violation
    naked = jax.pmap(lambda x: jax.lax.psum(x, "i"), axis_name="i")
    bad = check_entry_point(
        _ep(
            "fix.naked_psum",
            ("collectives-under-shard-map",),
            TracedEntry(naked, (jnp.ones((1, 4)),)),
        )
    )
    assert _rules(bad) == ["collectives-under-shard-map"]

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    sharded = shard_map(
        lambda x: jax.lax.psum(x, "data"),
        mesh=mesh,
        in_specs=P("data"),
        out_specs=P(),
    )
    good = check_entry_point(
        _ep(
            "fix.sharded_psum",
            ("collectives-under-shard-map",),
            TracedEntry(sharded, (jnp.ones(4),)),
        )
    )
    assert good == []


def test_donation_applied_positive_and_negative():
    x = jnp.ones((8, 8))
    undonated = jax.jit(lambda a: a + 1.0)
    bad = check_entry_point(
        _ep(
            "fix.undonated",
            ("donation-applied",),
            TracedEntry(undonated, (x,), jit_fn=undonated),
        )
    )
    assert _rules(bad) == ["donation-applied"]
    donated = jax.jit(lambda a: a + 1.0, donate_argnums=0)
    good = check_entry_point(
        _ep(
            "fix.donated",
            ("donation-applied",),
            TracedEntry(donated, (x,), jit_fn=donated),
        )
    )
    assert good == []


def test_retrace_detector_flags_salted_cache():
    from repro.core import queries
    from repro.core.query_engine import QueryEngine

    class RetracingEngine:
        """Minimal engine whose jit cache is salted per call — every
        dispatch re-traces, the exact failure mode the detector exists
        to catch."""

        def __init__(self, backend, pad_q=8):
            self._jits = {}
            self._calls = 0

        def _fn(self, family, fn):
            return self._jits.setdefault(
                family, jax.jit(fn, static_argnames=("salt",))
            )

        def _call(self, family, fn, *args):
            self._calls += 1
            return self._fn(family, fn)(*args, salt=self._calls)

        def edge(self, sk, src, dst):
            return self._call(
                "edge", lambda s, a, b, salt: queries.edge_query(s, a, b), sk, src, dst
            )

        def in_flow(self, sk, keys):
            return self._call(
                "in_flow", lambda s, k, salt: queries.node_in_flow(s, k), sk, keys
            )

        def out_flow(self, sk, keys):
            return self._call(
                "out_flow", lambda s, k, salt: queries.node_out_flow(s, k), sk, keys
            )

        def flow(self, sk, keys):
            return self._call(
                "flow", lambda s, k, salt: queries.node_flow(s, k), sk, keys
            )

        def heavy_rel_vec(self, sk, keys, thetas):
            return self._call(
                "heavy_rel_vec",
                lambda s, k, t, salt: queries.check_heavy_keys_rel_vec(s, k, t),
                sk, keys, thetas,
            )

    bad = check_retrace_query_families(RetracingEngine)
    assert bad and all(v.rule == "retrace" for v in bad)
    assert check_retrace_query_families(QueryEngine) == []


# ---------------------------------------------------------------------------
# source pass — fixture trees, one rule each
# ---------------------------------------------------------------------------


def _write(tmp_path, rel, body):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(body))
    return p


def test_direct_jit_rule(tmp_path):
    bad = _write(
        tmp_path, "core/adhoc.py",
        """
        import jax

        def f(fn):
            return jax.jit(fn)
        """,
    )
    assert _rules(lint_file(bad, "core/adhoc.py")) == ["direct-jit"]
    # the engine cache module is allowed; so is code outside the scoped dirs
    assert lint_file(bad, "core/query_engine.py") == []
    assert lint_file(bad, "launch/adhoc.py") == []


def test_host_sync_rule(tmp_path):
    bad = _write(
        tmp_path, "kernels/foo/ops.py",
        """
        def f(x):
            return x.item()
        """,
    )
    assert _rules(lint_file(bad, "kernels/foo/ops.py")) == ["host-sync"]
    bad_np = _write(
        tmp_path, "core/reach_bad.py",
        """
        import numpy as np

        def f(x):
            return np.asarray(x)
        """,
    )
    assert _rules(lint_file(bad_np, "core/reach.py")) == ["host-sync"]
    # api/ modules stage host<->device transfers by design: out of scope
    assert lint_file(bad, "api/stream.py") == []
    clean = _write(
        tmp_path, "kernels/foo/clean_ops.py",
        """
        import jax.numpy as jnp

        def f(x):
            return jnp.sum(x)
        """,
    )
    assert lint_file(clean, "kernels/foo/clean_ops.py") == []


def test_jnp_in_loop_rule(tmp_path):
    bad = _write(
        tmp_path, "core/hot.py",
        """
        import jax.numpy as jnp

        def f(xs):
            out = []
            for x in xs:
                out.append(jnp.sum(x))
            return out
        """,
    )
    assert _rules(lint_file(bad, "core/hot.py")) == ["jnp-in-loop"]
    clean = _write(
        tmp_path, "core/cold.py",
        """
        import jax.numpy as jnp

        def f(xs):
            return jnp.sum(jnp.stack(list(xs)))
        """,
    )
    assert lint_file(clean, "core/cold.py") == []
    # api/ is not a hot module for this rule
    assert lint_file(bad, "api/hot.py") == []


def test_env_read_rule(tmp_path):
    bad = _write(
        tmp_path, "api/cfg.py",
        """
        import os

        def f():
            return os.environ.get("REPRO_QUERY_BACKEND", "")
        """,
    )
    assert _rules(lint_file(bad, "api/cfg.py")) == ["env-read"]
    bad_sub = _write(
        tmp_path, "api/cfg2.py",
        """
        import os

        def f():
            return os.environ["REPRO_INGEST_BACKEND"]
        """,
    )
    assert _rules(lint_file(bad_sub, "api/cfg2.py")) == ["env-read"]
    # the dispatch boundaries are allowed; non-REPRO vars anywhere are fine
    assert lint_file(bad, "core/ingest.py") == []
    clean = _write(
        tmp_path, "api/cfg3.py",
        """
        import os

        def f():
            return os.environ.get("HOME", "")
        """,
    )
    assert lint_file(clean, "api/cfg3.py") == []


def test_kernel_ref_rule(tmp_path):
    root = tmp_path / "pkg"
    _write(root, "kernels/newk/kernel.py", "def k():\n    return 0\n")
    _write(root, "kernels/newk/ops.py", "def op():\n    return 0\n")
    tests = tmp_path / "tests"
    _write(tmp_path, "tests/test_kernels.py", "# no imports of newk\n")
    found = lint_tree(root, tests)
    assert _rules(found) == ["kernel-ref"]
    # missing ref.py + neither ops nor ref imported by the harness
    assert len(found) == 3

    _write(root, "kernels/newk/ref.py", "def k_ref():\n    return 0\n")
    _write(
        tmp_path, "tests/test_kernels.py",
        """
        from pkg.kernels.newk.ops import op
        from pkg.kernels.newk.ref import k_ref
        """,
    )
    assert lint_tree(root, tests) == []


# ---------------------------------------------------------------------------
# baseline mechanism + CLI + end-to-end gate
# ---------------------------------------------------------------------------


def test_baseline_marks_but_keeps_violations():
    v = Violation(rule="direct-jit", subject="core/x.py::f:3", message="m",
                  pass_name="source")
    out = apply_baseline([v], {("direct-jit", "core/x.py::f:3"): "why"})
    assert out[0].baselined and out[0].justification == "why"
    out2 = apply_baseline([v], {("direct-jit", "core/other.py::f:3"): "why"})
    assert not out2[0].baselined


def test_cli_exit_codes_and_json_report(tmp_path):
    bad_root = tmp_path / "pkg"
    _write(
        bad_root, "core/adhoc.py",
        """
        import jax

        def f(fn):
            return jax.jit(fn)
        """,
    )
    report_path = tmp_path / "report.json"
    rc = main([
        "--passes", "source", "--root", str(bad_root),
        "--format", "json", "--output", str(report_path),
    ])
    assert rc == 1
    report = json.loads(report_path.read_text())
    assert not report["ok"]
    assert report["counts"]["violations"] == 1
    assert report["violations"][0]["rule"] == "direct-jit"

    clean_root = tmp_path / "pkg2"
    _write(clean_root, "core/clean.py", "def f():\n    return 0\n")
    assert main(["--passes", "source", "--root", str(clean_root)]) == 0


def test_jaxpr_pass_respects_entry_point_override():
    counters = jnp.ones((2, 8, 8))
    eps = (
        _ep(
            "fix.reduce",
            ("no-counter-reduction",),
            TracedEntry(lambda c: jnp.sum(c), (counters,), counters_shape=(2, 8, 8)),
        ),
    )
    found = run_jaxpr_pass(eps)
    assert _rules(found) == ["no-counter-reduction"]


def test_end_to_end_repo_is_clean():
    """The CI gate: both passes over the real tree, zero unbaselined."""
    report = run_analysis(("jaxpr", "source"), root=SRC_REPRO, tests_dir=TESTS_DIR)
    new = [v for v in report["violations"] if not v["baselined"]]
    assert report["ok"], "unbaselined violations:\n" + "\n".join(
        f"{v['rule']} {v['subject']}: {v['message']}" for v in new
    )
    # the registry really covers the engine surface
    assert report["counts"]["entry_points"] == len(ENTRY_POINTS) >= 24
