"""Tests for the static-analysis plane (repro.analysis).

Every rule gets a positive fixture (violates exactly that rule) and a
clean twin (negative), plus an end-to-end run over the real ``src/repro``
tree asserting zero unbaselined violations — the same gate CI runs.
"""
import json
import pathlib
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    ENTRY_POINTS,
    EntryPoint,
    TracedEntry,
    lint_file,
    lint_tree,
    reduces_full_counters,
    run_jaxpr_pass,
)
from repro.analysis.contracts import (
    Violation,
    apply_baseline,
    check_retrace_query_families,
)
from repro.analysis.jaxpr_lint import check_entry_point
from repro.analysis.runner import main, run_analysis

SRC_REPRO = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
TESTS_DIR = pathlib.Path(__file__).resolve().parent


def _rules(violations):
    return sorted({v.rule for v in violations})


# ---------------------------------------------------------------------------
# jaxpr pass — one positive + one negative per contract
# ---------------------------------------------------------------------------


def _ep(name, contracts, entry):
    return EntryPoint(name=name, contracts=contracts, build=lambda: entry)


def test_no_host_callback_positive_and_negative():
    x = jnp.ones(4)

    def dirty(a):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(a.shape, a.dtype), a
        )

    bad = check_entry_point(
        _ep("fix.cb", ("no-host-callback",), TracedEntry(dirty, (x,)))
    )
    assert _rules(bad) == ["no-host-callback"]
    good = check_entry_point(
        _ep("fix.clean", ("no-host-callback",), TracedEntry(lambda a: a + 1, (x,)))
    )
    assert good == []


def test_no_wide_dtype_positive_and_negative():
    from jax.experimental import enable_x64

    x = jnp.ones(4)

    def dirty(a):
        with enable_x64():
            return a.astype(jnp.float64) * 2.0

    bad = check_entry_point(
        _ep("fix.wide", ("no-wide-dtype",), TracedEntry(dirty, (x,)))
    )
    assert _rules(bad) == ["no-wide-dtype"]
    good = check_entry_point(
        _ep("fix.narrow", ("no-wide-dtype",), TracedEntry(lambda a: a * 2.0, (x,)))
    )
    assert good == []


def test_no_counter_reduction_positive_and_negative():
    counters = jnp.ones((2, 8, 8))
    shape = (2, 8, 8)
    bad = check_entry_point(
        _ep(
            "fix.reduce",
            ("no-counter-reduction",),
            TracedEntry(lambda c: jnp.sum(c), (counters,), counters_shape=shape),
        )
    )
    assert _rules(bad) == ["no-counter-reduction"]
    good = check_entry_point(
        _ep(
            "fix.gather",
            ("no-counter-reduction",),
            TracedEntry(lambda c: c[:, 0, 0], (counters,), counters_shape=shape),
        )
    )
    assert good == []
    # the test-facing helper agrees (used by test_query_engine.py)
    assert reduces_full_counters(lambda c: jnp.sum(c), shape, counters)
    assert not reduces_full_counters(lambda c: c[:, 0, 0], shape, counters)


def test_collectives_only_under_shard_map():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    # pmap's psum sits OUTSIDE any shard_map region -> violation
    naked = jax.pmap(lambda x: jax.lax.psum(x, "i"), axis_name="i")
    bad = check_entry_point(
        _ep(
            "fix.naked_psum",
            ("collectives-under-shard-map",),
            TracedEntry(naked, (jnp.ones((1, 4)),)),
        )
    )
    assert _rules(bad) == ["collectives-under-shard-map"]

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    sharded = shard_map(
        lambda x: jax.lax.psum(x, "data"),
        mesh=mesh,
        in_specs=P("data"),
        out_specs=P(),
    )
    good = check_entry_point(
        _ep(
            "fix.sharded_psum",
            ("collectives-under-shard-map",),
            TracedEntry(sharded, (jnp.ones(4),)),
        )
    )
    assert good == []


def test_donation_applied_positive_and_negative():
    x = jnp.ones((8, 8))
    undonated = jax.jit(lambda a: a + 1.0)
    bad = check_entry_point(
        _ep(
            "fix.undonated",
            ("donation-applied",),
            TracedEntry(undonated, (x,), jit_fn=undonated),
        )
    )
    assert _rules(bad) == ["donation-applied"]
    donated = jax.jit(lambda a: a + 1.0, donate_argnums=0)
    good = check_entry_point(
        _ep(
            "fix.donated",
            ("donation-applied",),
            TracedEntry(donated, (x,), jit_fn=donated),
        )
    )
    assert good == []


def test_retrace_detector_flags_salted_cache():
    from repro.core import queries
    from repro.core.query_engine import QueryEngine

    class RetracingEngine:
        """Minimal engine whose jit cache is salted per call — every
        dispatch re-traces, the exact failure mode the detector exists
        to catch."""

        def __init__(self, backend, pad_q=8):
            self._jits = {}
            self._calls = 0

        def _fn(self, family, fn):
            return self._jits.setdefault(
                family, jax.jit(fn, static_argnames=("salt",))
            )

        def _call(self, family, fn, *args):
            self._calls += 1
            return self._fn(family, fn)(*args, salt=self._calls)

        def edge(self, sk, src, dst):
            return self._call(
                "edge", lambda s, a, b, salt: queries.edge_query(s, a, b), sk, src, dst
            )

        def in_flow(self, sk, keys):
            return self._call(
                "in_flow", lambda s, k, salt: queries.node_in_flow(s, k), sk, keys
            )

        def out_flow(self, sk, keys):
            return self._call(
                "out_flow", lambda s, k, salt: queries.node_out_flow(s, k), sk, keys
            )

        def flow(self, sk, keys):
            return self._call(
                "flow", lambda s, k, salt: queries.node_flow(s, k), sk, keys
            )

        def heavy_rel_vec(self, sk, keys, thetas):
            return self._call(
                "heavy_rel_vec",
                lambda s, k, t, salt: queries.check_heavy_keys_rel_vec(s, k, t),
                sk, keys, thetas,
            )

    bad = check_retrace_query_families(RetracingEngine)
    assert bad and all(v.rule == "retrace" for v in bad)
    assert check_retrace_query_families(QueryEngine) == []


# ---------------------------------------------------------------------------
# source pass — fixture trees, one rule each
# ---------------------------------------------------------------------------


def _write(tmp_path, rel, body):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(body))
    return p


def test_direct_jit_rule(tmp_path):
    bad = _write(
        tmp_path, "core/adhoc.py",
        """
        import jax

        def f(fn):
            return jax.jit(fn)
        """,
    )
    assert _rules(lint_file(bad, "core/adhoc.py")) == ["direct-jit"]
    # the engine cache module is allowed; so is code outside the scoped dirs
    assert lint_file(bad, "core/query_engine.py") == []
    assert lint_file(bad, "launch/adhoc.py") == []


def test_host_sync_rule(tmp_path):
    bad = _write(
        tmp_path, "kernels/foo/ops.py",
        """
        def f(x):
            return x.item()
        """,
    )
    assert _rules(lint_file(bad, "kernels/foo/ops.py")) == ["host-sync"]
    bad_np = _write(
        tmp_path, "core/reach_bad.py",
        """
        import numpy as np

        def f(x):
            return np.asarray(x)
        """,
    )
    assert _rules(lint_file(bad_np, "core/reach.py")) == ["host-sync"]
    # api/ modules stage host<->device transfers by design: out of scope
    assert lint_file(bad, "api/stream.py") == []
    clean = _write(
        tmp_path, "kernels/foo/clean_ops.py",
        """
        import jax.numpy as jnp

        def f(x):
            return jnp.sum(x)
        """,
    )
    assert lint_file(clean, "kernels/foo/clean_ops.py") == []


def test_jnp_in_loop_rule(tmp_path):
    bad = _write(
        tmp_path, "core/hot.py",
        """
        import jax.numpy as jnp

        def f(xs):
            out = []
            for x in xs:
                out.append(jnp.sum(x))
            return out
        """,
    )
    assert _rules(lint_file(bad, "core/hot.py")) == ["jnp-in-loop"]
    clean = _write(
        tmp_path, "core/cold.py",
        """
        import jax.numpy as jnp

        def f(xs):
            return jnp.sum(jnp.stack(list(xs)))
        """,
    )
    assert lint_file(clean, "core/cold.py") == []
    # api/ is not a hot module for this rule
    assert lint_file(bad, "api/hot.py") == []


def test_env_read_rule(tmp_path):
    bad = _write(
        tmp_path, "api/cfg.py",
        """
        import os

        def f():
            return os.environ.get("REPRO_QUERY_BACKEND", "")
        """,
    )
    assert _rules(lint_file(bad, "api/cfg.py")) == ["env-read"]
    bad_sub = _write(
        tmp_path, "api/cfg2.py",
        """
        import os

        def f():
            return os.environ["REPRO_INGEST_BACKEND"]
        """,
    )
    assert _rules(lint_file(bad_sub, "api/cfg2.py")) == ["env-read"]
    # the dispatch boundaries are allowed; non-REPRO vars anywhere are fine
    assert lint_file(bad, "core/ingest.py") == []
    clean = _write(
        tmp_path, "api/cfg3.py",
        """
        import os

        def f():
            return os.environ.get("HOME", "")
        """,
    )
    assert lint_file(clean, "api/cfg3.py") == []


def test_kernel_ref_rule(tmp_path):
    root = tmp_path / "pkg"
    _write(root, "kernels/newk/kernel.py", "def k():\n    return 0\n")
    _write(root, "kernels/newk/ops.py", "def op():\n    return 0\n")
    tests = tmp_path / "tests"
    _write(tmp_path, "tests/test_kernels.py", "# no imports of newk\n")
    found = lint_tree(root, tests)
    assert _rules(found) == ["kernel-ref"]
    # missing ref.py + neither ops nor ref imported by the harness
    assert len(found) == 3

    _write(root, "kernels/newk/ref.py", "def k_ref():\n    return 0\n")
    _write(
        tmp_path, "tests/test_kernels.py",
        """
        from pkg.kernels.newk.ops import op
        from pkg.kernels.newk.ref import k_ref
        """,
    )
    assert lint_tree(root, tests) == []


# ---------------------------------------------------------------------------
# baseline mechanism + CLI + end-to-end gate
# ---------------------------------------------------------------------------


def test_baseline_marks_but_keeps_violations():
    v = Violation(rule="direct-jit", subject="core/x.py::f:3", message="m",
                  pass_name="source")
    out = apply_baseline([v], {("direct-jit", "core/x.py::f:3"): "why"})
    assert out[0].baselined and out[0].justification == "why"
    out2 = apply_baseline([v], {("direct-jit", "core/other.py::f:3"): "why"})
    assert not out2[0].baselined


def test_cli_exit_codes_and_json_report(tmp_path):
    bad_root = tmp_path / "pkg"
    _write(
        bad_root, "core/adhoc.py",
        """
        import jax

        def f(fn):
            return jax.jit(fn)
        """,
    )
    report_path = tmp_path / "report.json"
    rc = main([
        "--passes", "source", "--root", str(bad_root),
        "--format", "json", "--output", str(report_path),
    ])
    assert rc == 1
    report = json.loads(report_path.read_text())
    assert not report["ok"]
    assert report["counts"]["violations"] == 1
    assert report["violations"][0]["rule"] == "direct-jit"

    clean_root = tmp_path / "pkg2"
    _write(clean_root, "core/clean.py", "def f():\n    return 0\n")
    assert main(["--passes", "source", "--root", str(clean_root)]) == 0


def test_jaxpr_pass_respects_entry_point_override():
    counters = jnp.ones((2, 8, 8))
    eps = (
        _ep(
            "fix.reduce",
            ("no-counter-reduction",),
            TracedEntry(lambda c: jnp.sum(c), (counters,), counters_shape=(2, 8, 8)),
        ),
    )
    found = run_jaxpr_pass(eps)
    assert _rules(found) == ["no-counter-reduction"]


def test_end_to_end_repo_is_clean():
    """The CI gate: jaxpr + source passes over the real tree, zero
    unbaselined (costlint has its own gate test below — compiling the
    cost ladders here would double the suite's compile bill)."""
    report = run_analysis(("jaxpr", "source"), root=SRC_REPRO, tests_dir=TESTS_DIR)
    new = [v for v in report["violations"] if not v["baselined"]]
    assert report["ok"], "unbaselined violations:\n" + "\n".join(
        f"{v['rule']} {v['subject']}: {v['message']}" for v in new
    )
    # the registry really covers the engine surface, including the
    # turnstile-delete and window-advance session boundaries
    assert report["counts"]["entry_points"] == len(ENTRY_POINTS) >= 26
    names = set(report["checked_entry_points"])
    assert {"ingest.delete_boundary", "window.advance_boundary"} <= names


# ---------------------------------------------------------------------------
# costlint — exponent fits, planted twins, donation proof, budgets
# ---------------------------------------------------------------------------


def test_fit_exponent_basics():
    from repro.analysis.costlint import _fit_exponent

    assert _fit_exponent((2, 4, 8), (7.0, 7.0, 7.0)) == pytest.approx(0.0)
    assert _fit_exponent((2, 4), (10.0, 40.0)) == pytest.approx(2.0)
    # all-zero metric clips at 1 -> exponent 0, not -inf
    assert _fit_exponent((2, 4), (0.0, 0.0)) == pytest.approx(0.0)


def test_planted_quadratic_ingest_fails_B_contract():
    """An ingest twin with a hidden O(B²) pairwise coupling must blow the
    declared O(B) flops exponent — the exact regression costlint exists
    to catch.  The coupling rides in at 1e-9 so XLA cannot DCE it."""
    from repro.analysis.contracts import (
        AxisContract,
        CostEntryPoint,
        CostProbe,
    )
    from repro.analysis.costlint import run_cost_pass

    def build(B=64):
        from repro.core.ingest import ingest
        from repro.core.sketch import GLavaSketch, SketchConfig

        cfg = SketchConfig(depth=2, width_rows=64, width_cols=64)
        sk = GLavaSketch.empty(cfg, jax.random.key(0))
        src = jnp.arange(B, dtype=jnp.uint32)
        rows, cols = sk.hash_edges(src, src + jnp.uint32(B))
        wts = jnp.ones(B, jnp.float32)

        def bad(c, r, cc, ww):
            sim = jnp.sum(ww[:, None] * ww[None, :], axis=1)  # O(B²)
            return ingest(c, r, cc, ww + 1e-9 * sim, backend="scatter")

        return CostProbe(
            fn=bad, args=(sk.counters, rows, cols, wts),
            state_bytes=4 * 2 * 64 * 64,
        )

    ep = CostEntryPoint(
        name="fix.cost.quadratic_ingest",
        axes=(AxisContract("B", 1.0, (64, 128, 256)),),
        build=build,
    )
    violations, meas = run_cost_pass([ep], check_budgets=False)
    assert _rules(violations) == ["cost-exponent"]
    assert violations[0].subject == "fix.cost.quadratic_ingest[B]"
    assert meas[0]["axes"][0]["measured"] > 1.35


def test_planted_tenant_wide_reduction_fails_T_contract():
    """A fleet query twin that also scans the whole tenant stack must blow
    the declared O(1)-in-T flops exponent — tenant isolation is the
    fleet's headline claim."""
    from repro.analysis.contracts import (
        AxisContract,
        CostEntryPoint,
        CostProbe,
    )
    from repro.analysis.costlint import run_cost_pass

    def build(T=2):
        from repro.fleet.query import FleetQueryEngine

        fn, args, shape = FleetQueryEngine.family_probe(
            "in_flow", tenants=T, width=64, depth=2, n_queries=32
        )

        def bad(state, *rest):
            return fn(state, *rest) + 1e-9 * jnp.sum(state.counters)

        n = 1
        for s in shape:
            n *= s
        return CostProbe(fn=bad, args=args, state_bytes=4 * n)

    ep = CostEntryPoint(
        name="fix.cost.tenant_scan",
        axes=(AxisContract("T", 0.0, (2, 8)),),
        build=build,
    )
    violations, meas = run_cost_pass([ep], check_budgets=False)
    assert _rules(violations) == ["cost-exponent"]
    assert violations[0].subject == "fix.cost.tenant_scan[T]"
    assert meas[0]["axes"][0]["measured"] > 0.35


def test_donation_memory_proof_positive_and_negative():
    """An undonated jit presented as a donated boundary aliases 0 bytes ->
    cost-donation-memory; the real session boundary aliases the sketch."""
    from repro.analysis.contracts import (
        COST_ENTRY_POINTS,
        AxisContract,
        CostEntryPoint,
        CostProbe,
    )
    from repro.analysis.costlint import run_cost_pass

    def build(w=64):
        counters = jnp.ones((2, w, w))
        jf = jax.jit(lambda c: c * 2.0 + 1.0)
        return CostProbe(
            fn=jf, args=(counters,), jit_fn=jf, state_bytes=4 * 2 * w * w
        )

    undonated = CostEntryPoint(
        name="fix.cost.undonated",
        axes=(AxisContract("w", 3.0, (32, 64), tol=1.0),),
        build=build,
        donated=True,
    )
    violations, _ = run_cost_pass([undonated], check_budgets=False)
    assert _rules(violations) == ["cost-donation-memory"]
    assert "donation dropped" in violations[0].message

    real = next(
        ep for ep in COST_ENTRY_POINTS if ep.name == "cost.ingest.jit_boundary"
    )
    clean, _ = run_cost_pass([real], check_budgets=False)
    assert clean == []


def test_broken_probe_is_a_finding_not_a_crash():
    from repro.analysis.contracts import (
        AxisContract,
        CostEntryPoint,
    )
    from repro.analysis.costlint import run_cost_pass

    def build(Q=8):
        raise RuntimeError("probe exploded")

    ep = CostEntryPoint(
        name="fix.cost.broken",
        axes=(AxisContract("Q", 1.0, (8, 16)),),
        build=build,
    )
    violations, meas = run_cost_pass([ep], check_budgets=False)
    assert _rules(violations) == ["cost-entry-broken"]
    assert meas == []


def test_cost_registry_passes_committed_budgets():
    """The costlint CI gate: every registry entry measured at >=2 sizes
    per axis, every exponent within contract, every committed ceiling
    honored."""
    from repro.analysis.contracts import COST_ENTRY_POINTS
    from repro.analysis.costlint import load_budgets, run_cost_pass

    budgets = load_budgets()
    assert budgets is not None, "ANALYSIS_BUDGETS.json must be committed"
    violations, measurements = run_cost_pass(budgets=budgets)
    assert violations == [], "\n".join(v.render() for v in violations)
    assert len(measurements) == len(COST_ENTRY_POINTS) >= 10
    for m in measurements:
        for fit in m["axes"]:
            assert len(fit["sizes"]) >= 2 and len(fit["values"]) >= 2


def test_budget_ratchet_roundtrip(tmp_path):
    """update -> clean run passes -> hand-shrunk ceiling -> exit 1 with a
    human-readable regression diff."""
    budgets = tmp_path / "budgets.json"
    entry = "cost.query.in_flow"
    assert main([
        "--update-budgets", "--cost-entries", entry,
        "--budgets", str(budgets),
    ]) == 0
    data = json.loads(budgets.read_text())
    assert entry in data["entries"]
    # a filtered update must not ratchet the full-registry compile count
    assert "compile_count" not in data

    assert main([
        "--passes", "costlint", "--cost-entries", entry,
        "--budgets", str(budgets),
    ]) == 0

    data["entries"][entry]["peak_bytes"] = 1
    budgets.write_text(json.dumps(data))
    report_path = tmp_path / "report.json"
    rc = main([
        "--passes", "costlint", "--cost-entries", entry,
        "--budgets", str(budgets),
        "--format", "json", "--output", str(report_path),
    ])
    assert rc == 1
    report = json.loads(report_path.read_text())
    bad = [v for v in report["violations"] if v["rule"] == "cost-budget"]
    assert bad and "exceeds committed ceiling" in bad[0]["message"]


def test_missing_budgets_file_is_a_violation(tmp_path):
    from repro.analysis.costlint import run_cost_pass

    violations, _ = run_cost_pass([], budgets=None, full_registry=False)
    assert _rules(violations) == ["cost-budget"]
    assert violations[0].subject == "ANALYSIS_BUDGETS.json"


# ---------------------------------------------------------------------------
# baseline staleness + prune
# ---------------------------------------------------------------------------


def test_stale_baseline_warns_and_prunes(tmp_path):
    from repro.analysis.baseline import load_baseline

    clean_root = tmp_path / "pkg"
    _write(clean_root, "core/clean.py", "def f():\n    return 0\n")
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps([
        {"rule": "direct-jit", "subject": "core/gone.py::f:1",
         "justification": "code was deleted"},
    ]))

    # the rule's pass ran and nothing matched -> stale, but only a WARN
    report = run_analysis(
        ("source",), root=clean_root, baseline=load_baseline(bl)
    )
    assert report["ok"]
    assert report["stale_baseline"] == [["direct-jit", "core/gone.py::f:1"]]
    assert report["counts"]["stale_baseline"] == 1

    # the rule's pass did NOT run -> staleness is undecidable, no warn
    report2 = run_analysis(
        ("jaxpr",), root=clean_root, entry_points=(),
        baseline=load_baseline(bl),
    )
    assert report2["stale_baseline"] == []

    # --prune-baseline deletes it from the file
    assert main([
        "--passes", "source", "--root", str(clean_root),
        "--baseline", str(bl), "--prune-baseline",
    ]) == 0
    assert json.loads(bl.read_text()) == []


def test_live_baseline_entry_is_not_stale(tmp_path):
    from repro.analysis.baseline import load_baseline

    root = tmp_path / "pkg"
    _write(
        root, "core/adhoc.py",
        """
        import jax

        def f(fn):
            return jax.jit(fn)
        """,
    )
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps([
        {"rule": "direct-jit", "subject": "core/adhoc.py::f:5",
         "justification": "still here"},
    ]))
    report = run_analysis(("source",), root=root, baseline=load_baseline(bl))
    assert report["ok"] and report["stale_baseline"] == []
    assert report["counts"]["baselined"] == 1


def test_committed_baseline_loads_and_maps_rules():
    from repro.analysis.baseline import BASELINE, RULE_PASS

    assert BASELINE, "committed baseline.json must load"
    for rule, _subject in BASELINE:
        assert rule in RULE_PASS, f"rule {rule} missing from RULE_PASS"


# ---------------------------------------------------------------------------
# benchmark trajectory history
# ---------------------------------------------------------------------------


def test_bench_history_append(tmp_path):
    import sys

    repo_root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(repo_root))
    try:
        from benchmarks.run import append_history
    finally:
        sys.path.remove(str(repo_root))

    p = tmp_path / "BENCH_x.json"
    # legacy flat list becomes the pr-0 seed record
    p.write_text(json.dumps([{"name": "a", "us_per_call": 1.0}]))
    h = append_history(p, [{"name": "b"}], pr=9, commit="abc1234")
    assert [r["pr"] for r in h] == [0, 9]
    assert h[0]["commit"] == "legacy"
    assert h[0]["rows"] == [{"name": "a", "us_per_call": 1.0}]

    # re-running the same PR replaces its record, no duplicates
    h = append_history(p, [{"name": "c"}], pr=9, commit="def5678")
    assert [r["pr"] for r in h] == [0, 9]
    assert h[-1]["rows"] == [{"name": "c"}]

    # no explicit pr -> one past the last record
    h = append_history(p, [{"name": "d"}], commit="eee9999")
    assert h[-1]["pr"] == 10
    # and the file round-trips as history, not a flat list
    on_disk = json.loads(p.read_text())
    assert [r["pr"] for r in on_disk] == [0, 9, 10]
