"""API-plane tests: the vectorized key codec, the typed query IR, the
plan-and-fuse execution contract (request order, bit-identity to the
per-family oracle, exactly one engine dispatch per family, persistent jit
cache), (ε, δ) annotations round-tripping through ``SketchConfig.for_error``,
the GraphStream facade lifecycle (window / checkpoint / merge / monitor),
and the turnstile-delete backend resolution satellite."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import (
    GraphStream,
    Query,
    QueryBatch,
    QueryResult,
    SketchConfig,
    encode_labels,
    error_bound_for,
)
from repro.core import GLavaSketch, QueryEngine, queries
from repro.core.hashing import fnv1a_label, fnv1a_labels


# ---------------------------------------------------------------------------
# vectorized key codec
# ---------------------------------------------------------------------------


_CHARS = list("abz019._:- 世éß")


@settings(max_examples=25, deadline=None)
@given(
    labels=st.lists(
        st.lists(st.sampled_from(_CHARS), min_size=0, max_size=12),
        min_size=1,
        max_size=16,
    )
)
def test_fnv1a_labels_matches_scalar_strings(labels):
    labels = ["".join(cs) for cs in labels]
    got = fnv1a_labels(labels)
    want = np.array([fnv1a_label(l) for l in labels], np.uint32)
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.uint32


@settings(max_examples=25, deadline=None)
@given(values=st.lists(st.integers(-(2**40), 2**40), min_size=1, max_size=16))
def test_fnv1a_labels_matches_scalar_ints(values):
    got = fnv1a_labels(values)
    want = np.array([fnv1a_label(int(v)) for v in values], np.uint32)
    np.testing.assert_array_equal(got, want)


def test_fnv1a_labels_edge_cases():
    # empty string = FNV offset basis; scalars stay 0-d; uint wrap matches
    assert fnv1a_labels([""])[0] == np.uint32(0x811C9DC5)
    assert np.ndim(fnv1a_labels("abc")) == 0
    assert fnv1a_labels("abc") == fnv1a_label("abc")
    assert fnv1a_labels(np.uint64(2**32 + 7)) == 7
    # mixed int/str lists must NOT silently stringify the ints
    got = fnv1a_labels([7, "7"])
    assert got[0] == 7 and got[1] == fnv1a_label("7") and got[1] != 7
    # NUL-bearing labels take the exact per-element path
    assert fnv1a_labels(["a\x00b"])[0] == fnv1a_label("a\x00b")
    # bool labels hash as ints (True -> 1) regardless of batch composition
    assert fnv1a_labels([True])[0] == fnv1a_label(True) == 1
    assert fnv1a_labels([True, 5])[0] == 1
    # already-uint32 arrays pass through without a copy
    keys = np.asarray([3, 4], np.uint32)
    assert fnv1a_labels(keys) is keys
    # 2-D shape is preserved
    assert fnv1a_labels([["a", "b"], ["c", "d"]]).shape == (2, 2)


def test_encode_labels_integer_identity():
    keys = np.asarray([0, 1, 2**31, 2**32 - 1], np.uint32)
    np.testing.assert_array_equal(encode_labels(keys), keys)
    np.testing.assert_array_equal(
        encode_labels(jnp.asarray(keys)), keys
    )  # jax arrays encode too


# ---------------------------------------------------------------------------
# (ε, δ) annotations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth,width", [(1, 2), (2, 7), (3, 64), (4, 256), (5, 8192)])
def test_error_bound_roundtrips_for_error(depth, width):
    cfg = SketchConfig(depth=depth, width_rows=width, width_cols=width)
    eps, delta = cfg.error_bound()
    assert SketchConfig.for_error(eps, delta) == cfg


def test_error_bound_sides():
    cfg = SketchConfig(depth=3, width_rows=64, width_cols=64)
    count = error_bound_for("edge", cfg)
    boolean = error_bound_for("reach", cfg)
    assert count.side == "over-estimate" and count.epsilon is not None
    assert boolean.side == "no-false-negative" and boolean.epsilon is None
    assert count.delta == boolean.delta


# ---------------------------------------------------------------------------
# plan-and-fuse: order, bit-identity, one dispatch per family, jit cache
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def loaded_stream():
    gs = GraphStream.open(
        SketchConfig(depth=3, width_rows=64, width_cols=64),
        ingest_backend="scatter",
        query_backend="jnp",
    )
    rng = np.random.default_rng(7)
    src = rng.integers(0, 150, 1200).astype(np.uint32)
    dst = rng.integers(0, 150, 1200).astype(np.uint32)
    gs.ingest(src, dst, rng.integers(1, 5, 1200).astype(np.float32))
    return gs, src, dst


def _mixed_queries(rng, src, dst):
    """A pool of queries spanning every family, with ragged batch sizes."""
    pick = lambda n: np.asarray(rng.choice(src, n), np.uint32)
    return [
        Query.edge(pick(5), np.asarray(rng.choice(dst, 5), np.uint32)),
        Query.edge(int(src[0]), int(dst[0])),
        Query.in_flow(pick(3)),
        Query.in_flow(int(dst[1])),
        Query.out_flow(pick(7)),
        Query.flow(pick(2)),
        Query.heavy(pick(4), theta=0.005),
        Query.heavy(int(src[2]), theta=0.25),
        Query.reach(pick(3), np.asarray(rng.choice(dst, 3), np.uint32)),
        Query.subgraph(src[:2], dst[:2]),
        Query.subgraph(src[2:7], dst[2:7]),
    ]


def _oracle_value(q, sk, epoch):
    """Answer one query with a FRESH engine (the per-family oracle path)."""
    eng = QueryEngine("jnp")
    u = None if q.u is None else jnp.asarray(q.u)
    v = None if q.v is None else jnp.asarray(q.v)
    if q.family == "edge":
        out = np.asarray(eng.edge(sk, u, v))
    elif q.family == "in_flow":
        out = np.asarray(eng.in_flow(sk, u))
    elif q.family == "out_flow":
        out = np.asarray(eng.out_flow(sk, u))
    elif q.family == "flow":
        out = np.asarray(eng.flow(sk, u))
    elif q.family == "heavy":
        # API θ is RELATIVE (fraction of total stream weight F̃)
        i, o = eng.heavy_rel_vec(
            sk, u, jnp.full(u.shape, q.theta, jnp.float32)
        )
        i, o = np.asarray(i), np.asarray(o)
        return (i[0], o[0]) if q.scalar else (i, o)
    elif q.family == "reach":
        out = np.asarray(eng.reach(sk, u, v, epoch=epoch))
    elif q.family == "subgraph":
        return np.asarray(eng.subgraph(sk, u, v))
    return out[0] if q.scalar else out


def _assert_value_equal(got, want, msg):
    if isinstance(want, tuple):
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=msg)
    else:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want), err_msg=msg)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_shuffled_mixed_batch_contract(loaded_stream, seed):
    """THE acceptance property: a shuffled batch spanning >= 4 families
    returns request-ordered results bit-identical to the per-family oracle,
    with exactly one engine dispatch per family and config-derived (ε, δ)."""
    gs, src, dst = loaded_stream
    rng = np.random.default_rng(seed)
    pool = _mixed_queries(rng, src, dst)
    order = rng.permutation(len(pool))
    batch = QueryBatch([pool[i] for i in order])
    assert len(batch.families) >= 4

    gs.engine.dispatches.clear()
    results = gs.query(batch)

    # request order: result i belongs to query i (identity — Query fields
    # are numpy arrays, so == would be ambiguous)
    assert all(r.query is q for r, q in zip(results, batch))
    assert len(results) == len(batch)

    # exactly one dispatch per family present (reach = reach_pre; the
    # closure build is a separate amortized cache, not a query dispatch)
    dispatch_key = {
        "heavy": "heavy_rel_vec",
        "reach": "reach_pre",
        "subgraph": "subgraph_batch",
    }
    want = {dispatch_key.get(f, f): 1 for f in batch.families}
    assert dict(gs.engine.dispatches) == want

    # bit-identity to the per-family oracle + (ε, δ) annotations
    sk = gs.sketch
    for i, r in enumerate(results):
        _assert_value_equal(
            r.value,
            _oracle_value(r.query, sk, gs.epoch),
            f"slot {i} family {r.family} (seed {seed})",
        )
        assert r.error == error_bound_for(r.family, gs.config)


def test_mixed_batch_jit_cache_hit(loaded_stream):
    """Re-running a same-shaped batch re-dispatches but never re-traces:
    the engine's per-family jitted callables stay singletons and their
    shape caches do not grow."""
    gs, src, dst = loaded_stream
    rng = np.random.default_rng(3)
    batch = QueryBatch(_mixed_queries(rng, src, dst))
    gs.query(batch)
    jits_before = dict(gs.engine._jits)
    sizes_before = {
        f: fn._cache_size() for f, fn in jits_before.items()
        if hasattr(fn, "_cache_size")
    }
    gs.engine.dispatches.clear()
    gs.query(batch)
    assert dict(gs.engine._jits) == jits_before  # same jitted callables
    for f, fn in gs.engine._jits.items():
        if hasattr(fn, "_cache_size") and f in sizes_before:
            assert fn._cache_size() == sizes_before[f], f"re-trace in {f}"
    assert all(v == 1 for v in gs.engine.dispatches.values())


def test_subgraph_padding_is_exact(loaded_stream):
    """Fusing ragged subgraph edge lists (mask padding) cannot change any
    answer — including the revised absent-edge zero-propagation."""
    gs, src, dst = loaded_stream
    sk = gs.sketch
    absent = Query.subgraph(
        np.asarray([999_999], np.uint32), np.asarray([999_998], np.uint32)
    )
    qs = [
        Query.subgraph(src[:1], dst[:1]),
        Query.subgraph(src[:6], dst[:6]),
        absent,
    ]
    results = gs.query(QueryBatch(qs))
    for q, r in zip(qs, results):
        want = queries.subgraph_query(sk, jnp.asarray(q.u), jnp.asarray(q.v))
        np.testing.assert_array_equal(np.asarray(r.value), np.asarray(want))
    assert float(results[2].value) == 0.0


def test_string_labels_end_to_end():
    gs = GraphStream.open("smoke", query_backend="jnp")
    gs.ingest(["alice", "alice", "bob"], ["bob", "carol", "carol"])
    res = gs.query(
        Query.edge("alice", "bob"),
        Query.in_flow("carol"),
        Query.reach("alice", "carol"),
    )
    assert float(res[0].value) >= 1.0
    assert float(res[1].value) >= 2.0
    assert bool(res[2].value)
    # the facade's codec and the scalar host hash agree
    sk = gs.sketch
    manual = queries.edge_query(
        sk,
        jnp.asarray([fnv1a_label("alice")], jnp.uint32),
        jnp.asarray([fnv1a_label("bob")], jnp.uint32),
    )
    assert float(res[0].value) == float(manual[0])


# ---------------------------------------------------------------------------
# facade lifecycle
# ---------------------------------------------------------------------------


def test_open_presets_and_error_target():
    assert GraphStream.open("smoke").config.width_rows == 256
    gs = GraphStream.open(epsilon=0.01, delta=0.05)
    assert gs.config == SketchConfig.for_error(0.01, 0.05)
    with pytest.raises(ValueError):
        GraphStream.open("nope")
    with pytest.raises(ValueError):
        GraphStream.open()


def test_windowed_session_expiry():
    gs = GraphStream.open(
        SketchConfig(depth=3, width_rows=128, width_cols=128), window_slices=2
    )
    gs.ingest([10], [20])
    assert float(gs.query(Query.edge(10, 20)).value) == 1.0
    gs.advance_window()
    gs.advance_window()  # wraps: slice holding (10,20) zeroed
    assert float(gs.query(Query.edge(10, 20)).value) == 0.0


def test_merge_linearity():
    cfg = SketchConfig(depth=3, width_rows=64, width_cols=64)
    a = GraphStream.open(cfg, seed=5, query_backend="jnp")
    b = GraphStream.open(cfg, seed=5, query_backend="jnp")
    whole = GraphStream.open(cfg, seed=5, query_backend="jnp")
    rng = np.random.default_rng(0)
    s1, d1 = (rng.integers(0, 99, 300).astype(np.uint32) for _ in range(2))
    s2, d2 = (rng.integers(0, 99, 300).astype(np.uint32) for _ in range(2))
    a.ingest(s1, d1)
    b.ingest(s2, d2)
    whole.ingest(np.concatenate([s1, s2]), np.concatenate([d1, d2]))
    a.merge(b)
    np.testing.assert_array_equal(
        np.asarray(a.sketch.counters), np.asarray(whole.sketch.counters)
    )
    mismatched = GraphStream.open(cfg, seed=6)
    with pytest.raises(ValueError):
        a.merge(mismatched)


def test_checkpoint_restore_roundtrip(tmp_path):
    cfg = SketchConfig(depth=2, width_rows=32, width_cols=32)
    gs = GraphStream.open(cfg, checkpoint_dir=tmp_path, query_backend="jnp")
    rng = np.random.default_rng(1)
    src = rng.integers(0, 50, 200).astype(np.uint32)
    dst = rng.integers(0, 50, 200).astype(np.uint32)
    gs.ingest(src, dst)
    step = gs.checkpoint()
    want = gs.edge_frequency(src[:20], dst[:20])

    fresh = GraphStream.open(cfg, checkpoint_dir=tmp_path, query_backend="jnp")
    assert fresh.restore() == step
    np.testing.assert_array_equal(fresh.edge_frequency(src[:20], dst[:20]), want)
    # registers restored exactly (not refilled garbage)
    np.testing.assert_array_equal(
        np.asarray(fresh.sketch.row_flows),
        np.asarray(jnp.sum(fresh.sketch.counters, axis=2)),
    )


def test_monitor_is_threshold_subscription():
    """monitor() is a thin wrapper over a standing heavy-hitter
    subscription: θ is a fraction of total stream weight, the subscription
    is registered once per (watch, θ) and re-used, and the alarm is the
    subscription's predicate on the post-ingest estimate."""
    gs = GraphStream.open(SketchConfig(depth=3, width_rows=128, width_cols=128))
    bg_src = np.arange(50, dtype=np.uint32)
    bg_dst = np.arange(100, 150, dtype=np.uint32)
    w1 = np.ones(50, np.float32)
    # background only: target 7 draws (at most a collision's worth of)
    # traffic — far below 90% of F
    assert not gs.monitor(bg_src, bg_dst, w1, watch=7, theta=0.9)
    assert len(gs.subscriptions) == 1  # the standing monitor subscription
    # flood: 460 of the 510 total now flows into 7 -> share > 0.9 (the
    # in-flow estimate only over-estimates; F̃ is exact here)
    flood_src = np.zeros(46, np.uint32)
    flood_dst = np.full(46, 7, np.uint32)
    assert gs.monitor(
        flood_src, flood_dst, np.full(46, 10.0, np.float32), watch=7, theta=0.9
    )
    assert len(gs.subscriptions) == 1  # reused, not re-registered
    assert gs.stats.edges_ingested == 96
    assert gs.stats.subscription_ticks == 2
    # absolute thresholds are a clear error now, not silently-false bits
    with pytest.raises(ValueError):
        gs.monitor(bg_src, bg_dst, w1, watch=7, theta=600.0)


@pytest.mark.slow
def test_graphstream_mesh_matches_local():
    """The facade's distributed plane (mesh=) answers exactly like a local
    session — run in a subprocess with 8 placeholder host devices so the
    rest of the suite keeps seeing 1 device."""
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import numpy as np
        from repro.api import GraphStream, Query, QueryBatch, SketchConfig

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = SketchConfig(depth=3, width_rows=64, width_cols=64)
        dist = GraphStream.open(cfg, mesh=mesh, query_backend="jnp")
        local = GraphStream.open(cfg, query_backend="jnp",
                                 ingest_backend="scatter")

        rng = np.random.default_rng(0)
        src = rng.integers(0, 500, 256).astype(np.uint32)
        dst = rng.integers(0, 500, 256).astype(np.uint32)
        w = rng.integers(1, 4, 256).astype(np.float32)
        dist.ingest(src, dst, w)
        local.ingest(src, dst, w)

        batch = QueryBatch([
            Query.edge(src[:32], dst[:32]),
            Query.in_flow(src[:16]),
            Query.reach(src[:8], dst[:8]),
        ])
        got = dist.query(batch)
        want = local.query(batch)
        for g, wnt in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g.value),
                                          np.asarray(wnt.value))
        print("facade mesh session == local session")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "facade mesh session == local session" in proc.stdout


# ---------------------------------------------------------------------------
# satellite: turnstile deletes resolve the ingest backend like updates
# ---------------------------------------------------------------------------


def test_delete_resolves_backend_through_engine(monkeypatch):
    import importlib

    # repro.core re-exports the ingest FUNCTION under the same name, so plain
    # attribute imports shadow the module — resolve the module explicitly.
    ingest_mod = importlib.import_module("repro.core.ingest")

    hits = []
    real = ingest_mod._BACKEND_FNS["onehot"]

    def spy(*args, **kwargs):
        hits.append(1)
        return real(*args, **kwargs)

    monkeypatch.setitem(ingest_mod._BACKEND_FNS, "onehot", spy)
    monkeypatch.setenv("REPRO_INGEST_BACKEND", "onehot")

    cfg = SketchConfig(depth=2, width_rows=32, width_cols=32)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    rng = np.random.default_rng(2)
    src = jnp.asarray(rng.integers(0, 40, 100), jnp.uint32)
    dst = jnp.asarray(rng.integers(0, 40, 100), jnp.uint32)
    w = jnp.asarray(rng.integers(1, 4, 100), jnp.float32)

    sk = sk.update(src, dst, w)          # auto -> env -> onehot
    n_update = len(hits)
    assert n_update > 0
    sk = sk.delete(src[:30], dst[:30], w[:30])  # deletes take the same path
    assert len(hits) > n_update

    # semantics unchanged: delete == negative-weight scatter oracle
    oracle = (
        GLavaSketch.empty(cfg, jax.random.key(0))
        .update(src, dst, w, backend="scatter")
        .update(src[:30], dst[:30], -w[:30], backend="scatter")
    )
    np.testing.assert_array_equal(
        np.asarray(sk.counters), np.asarray(oracle.counters)
    )
    np.testing.assert_array_equal(
        np.asarray(sk.row_flows), np.asarray(oracle.row_flows)
    )


def test_one_shot_reach_rides_incremental_closure_refresh():
    """One-shot Query.reach pulls sync the closure from the session's
    touched-key delta: one full build on first use, touched-row refreshes
    afterwards — never a second re-squaring on an additions-only stream."""
    gs = GraphStream.open(
        SketchConfig(depth=2, width_rows=64, width_cols=64),
        ingest_backend="scatter",
        query_backend="jnp",
    )
    rng = np.random.default_rng(7)
    src = rng.integers(0, 40, 64).astype(np.uint32)
    dst = rng.integers(0, 40, 64).astype(np.uint32)
    gs.ingest(src, dst)

    r0 = gs.query(Query.reach(int(src[0]), int(dst[0])))
    assert gs.engine.closure_refreshes == 1
    assert gs.engine.closure_incremental_refreshes == 0

    gs.ingest(rng.integers(0, 40, 8).astype(np.uint32),
              rng.integers(0, 40, 8).astype(np.uint32))
    r1 = gs.query(Query.reach(int(src[0]), int(dst[0])))
    assert gs.engine.closure_refreshes == 1, "reach pull re-squared the closure"
    assert gs.engine.closure_incremental_refreshes == 1

    # refreshed closure answers match the from-scratch oracle
    from repro.core import reach as reach_mod

    oracle = reach_mod.reach_query(
        gs.sketch,
        jnp.asarray([fnv1a_label(int(src[0]))], jnp.uint32),
        jnp.asarray([fnv1a_label(int(dst[0]))], jnp.uint32),
    )
    assert bool(np.asarray(r1.value)) == bool(np.asarray(oracle)[0])
    assert isinstance(r0, QueryResult)
