"""Per-architecture smoke tests: instantiate a REDUCED config of each family
and run one forward/train step on CPU, asserting output shapes + no NaNs.
Covers every assigned (arch × shape) kind at smoke scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_arch
from repro.launch.steps import build_step

ARCHS = [a for a in all_archs() if a != "glava"]


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))


@pytest.mark.parametrize("arch_id", ARCHS)
def test_smoke_train_shape(arch_id):
    spec = get_arch(arch_id)
    train_shape = {
        "lm": "train_4k", "gnn": "full_graph_sm", "recsys": "train_batch"
    }[spec.family]
    b = build_step(arch_id, train_shape, smoke=True)
    state = b.init_state(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, b.make_batch(np.random.default_rng(0)))
    state, metrics = jax.jit(b.step)(state, batch)
    assert np.isfinite(float(metrics["loss"])), metrics
    assert _finite(state["params"]), f"{arch_id}: non-finite params after step"


@pytest.mark.parametrize("arch_id", ARCHS)
def test_smoke_loss_decreases(arch_id):
    """A few steps of the smoke config must reduce the loss (the step is a
    real optimizer step, not just a forward)."""
    spec = get_arch(arch_id)
    train_shape = {
        "lm": "train_4k", "gnn": "full_graph_sm", "recsys": "train_batch"
    }[spec.family]
    b = build_step(arch_id, train_shape, smoke=True)
    state = b.init_state(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = jax.tree.map(jnp.asarray, b.make_batch(rng))  # fixed batch
    step = jax.jit(b.step)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], f"{arch_id}: {losses}"


LM_ARCHS = [a for a in ARCHS if get_arch(a).family == "lm"]
GNN_ARCHS = [a for a in ARCHS if get_arch(a).family == "gnn"]


@pytest.mark.parametrize("arch_id", LM_ARCHS)
@pytest.mark.parametrize("shape", ["prefill_32k", "decode_32k"])
def test_smoke_lm_serving(arch_id, shape):
    b = build_step(arch_id, shape, smoke=True)
    params = b.init_state(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, b.make_batch(np.random.default_rng(0)))
    out = jax.jit(b.step)(params, batch)
    if shape == "prefill_32k":
        logits, cache = out
        assert logits.shape == (batch["tokens"].shape[0], b.config.vocab)
        assert _finite(logits)
        assert cache["k"].shape[0] == b.config.n_layers
    else:
        logits, cache = out
        assert logits.shape == (batch["token"].shape[0], b.config.vocab)
        assert _finite(logits)
        assert int(cache["len"]) == int(batch["cache"]["len"]) + 1


def test_smoke_long_context_mixtral_only():
    """long_500k builds for mixtral (SWA ring cache), and refuses for pure
    full-attention archs with the recorded skip reason."""
    b = build_step("mixtral-8x22b", "long_500k", smoke=True)
    params = b.init_state(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, b.make_batch(np.random.default_rng(0)))
    logits, cache = jax.jit(b.step)(params, batch)
    assert _finite(logits)
    for arch in ("qwen3-4b", "olmo-1b", "granite-8b", "arctic-480b"):
        with pytest.raises(ValueError, match="full-attention"):
            build_step(arch, "long_500k")
        # ... but smoke builds are allowed for testing the machinery
        assert get_arch(arch).shapes["long_500k"].skip


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
@pytest.mark.parametrize("shape", ["minibatch_lg", "molecule"])
def test_smoke_gnn_shapes(arch_id, shape):
    b = build_step(arch_id, shape, smoke=True)
    state = b.init_state(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, b.make_batch(np.random.default_rng(0)))
    state, metrics = jax.jit(b.step)(state, batch)
    assert np.isfinite(float(metrics["loss"])), (arch_id, shape, metrics)


@pytest.mark.parametrize("shape", ["serve_p99", "retrieval_cand"])
def test_smoke_recsys_serving(shape):
    b = build_step("bert4rec", shape, smoke=True)
    params = b.init_state(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, b.make_batch(np.random.default_rng(0)))
    out = jax.jit(b.step)(params, batch)
    assert _finite(out)
    if shape == "retrieval_cand":
        assert out.shape == batch["candidates"].shape
    else:
        assert out.shape == (batch["items"].shape[0], b.config.vocab)


def test_full_configs_param_counts():
    """Full configs match the published scales (sanity on the exact configs)."""
    mix = get_arch("mixtral-8x22b").config
    assert 130e9 < mix.param_count() < 155e9          # ~141B
    assert 35e9 < mix.active_param_count() < 45e9     # ~39B active
    arc = get_arch("arctic-480b").config
    assert 430e9 < arc.param_count() < 510e9          # ~475B
    q = get_arch("qwen3-4b").config
    assert 3e9 < q.param_count() < 5e9
    o = get_arch("olmo-1b").config
    assert 0.8e9 < o.param_count() < 1.5e9
    g = get_arch("granite-8b").config
    assert 7e9 < g.param_count() < 9.5e9
    b4r = get_arch("bert4rec").config
    assert 60e6 < b4r.param_count() < 80e6            # table-dominated


def test_cell_enumeration():
    from repro.configs import all_cells

    live = all_cells()
    allc = all_cells(include_skipped=True)
    assert len(allc) == 40, len(allc)  # 5*4 + 4*4 + 1*4
    # 4 skipped long_500k cells (all but mixtral)
    assert len(allc) - len(live) == 4
