"""Data-plane tests: LM Markov stream, bigram graph-stream view, recsys
cloze/statics, graph generators."""
import numpy as np
import pytest

from repro.data import graphs as gd
from repro.data import lm as lmd
from repro.data import recsys as rd


def test_markov_tokens_learnable_structure():
    gen = lmd.MarkovTokens(vocab=100, branch=4, seed=0)
    rng = np.random.default_rng(0)
    toks = gen.batch(8, 65, rng)
    assert toks.shape == (8, 65)
    assert toks.min() >= 0 and toks.max() < 100
    # successor structure: every transition is one of the 4 successors
    ok = 0
    for b in range(8):
        for t in range(64):
            ok += toks[b, t + 1] in gen.succ[toks[b, t]]
    assert ok == 8 * 64


def test_bigram_stream_view():
    toks = np.array([[1, 2, 3], [4, 5, 6]], np.int32)
    bs = lmd.bigram_stream(toks)
    np.testing.assert_array_equal(bs["src"], [1, 2, 4, 5])
    np.testing.assert_array_equal(bs["dst"], [2, 3, 5, 6])


def test_interaction_sequences_left_padded():
    rng = np.random.default_rng(1)
    items = rd.interaction_sequences(1000, 16, 20, rng)
    assert items.shape == (16, 20)
    for row in items:
        nz = np.nonzero(row)[0]
        if len(nz):
            # contiguous suffix: all zeros precede all items
            assert nz[0] == 20 - len(nz)
    assert items.max() <= 1000 and items.min() >= 0


def test_cloze_mask_positions_static_budget():
    rng = np.random.default_rng(2)
    items = rd.interaction_sequences(500, 8, 40, rng)
    mask_id = 501
    masked, pos, tgt = rd.cloze_mask_positions(items, mask_id, 10, rng)
    assert pos.shape == (8, 10) and tgt.shape == (8, 10)
    n_masked_in_seq = (masked == mask_id).sum(axis=1)
    n_targets = (tgt != 0).sum(axis=1)
    np.testing.assert_array_equal(n_masked_in_seq, n_targets)  # budget respected
    assert (n_targets >= 1).all()  # at least one mask per row
    for b in range(8):
        for j in range(10):
            if tgt[b, j]:
                assert masked[b, pos[b, j]] == mask_id
                assert items[b, pos[b, j]] == tgt[b, j]


def test_interaction_stream_drops_padding():
    items = np.array([[0, 0, 5], [7, 0, 9]], np.int32)
    users = np.array([100, 200], np.uint32)
    st = rd.interaction_stream(items, users)
    np.testing.assert_array_equal(st["dst"], [5, 7, 9])
    np.testing.assert_array_equal(st["src"], [100, 200, 200])


def test_edge_stream_zipf_skew():
    rng = np.random.default_rng(3)
    st = gd.edge_stream(10_000, 50_000, rng, zipf_a=1.5)
    counts = np.bincount(st["src"], minlength=10_000)
    # heavy head: top-10 sources carry far more than uniform share
    assert counts[np.argsort(counts)[-10:]].sum() > 0.2 * 50_000
    assert np.all(st["time"][:-1] <= st["time"][1:])  # timestamps sorted


def test_citation_graph_homophily():
    rng = np.random.default_rng(4)
    g = gd.citation_graph(500, 4000, 16, 5, rng)
    lab = g["labels"]
    same = (lab[g["edge_src"]] == lab[g["edge_dst"]]).mean()
    assert same > 0.4  # 70% homophilous edges + jitter


def test_molecule_batch_structure():
    rng = np.random.default_rng(5)
    d = gd.molecule_batch(4, 10, 16, 20, rng)
    assert d["node_feat"].shape == (40,)
    assert d["positions"].shape == (40, 3)
    assert d["labels"].shape == (4, 1)
    # edges stay within their own molecule
    g_of = d["graph_ids"]
    assert (g_of[d["edge_src"]] == g_of[d["edge_dst"]]).all()
