"""Distributed sketch plane (paper Section 6.3) — runs in a subprocess with 8
placeholder host devices so the rest of the suite keeps seeing 1 device."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding

    from repro.core import GLavaSketch, SketchConfig, queries
    from repro.core.distributed import (
        distributed_edge_query,
        distributed_ingest,
        distributed_point_query,
    )

    assert jax.device_count() == 8
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    cfg = SketchConfig(depth=3, width_rows=64, width_cols=64)
    sk = GLavaSketch.empty(cfg, jax.random.key(0))

    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, 500, 256), jnp.uint32)
    dst = jnp.asarray(rng.integers(0, 500, 256), jnp.uint32)
    w = jnp.asarray(rng.integers(1, 4, 256), jnp.float32)

    # Place the stream sharded over data, sketch rows over model.
    import dataclasses
    sk_sharded = dataclasses.replace(
        sk,
        counters=jax.device_put(
            sk.counters, NamedSharding(mesh, P(None, "model", None))
        ),
    )
    srcs = jax.device_put(src, NamedSharding(mesh, P("data")))
    dsts = jax.device_put(dst, NamedSharding(mesh, P("data")))
    ws = jax.device_put(w, NamedSharding(mesh, P("data")))

    out = distributed_ingest(mesh, sk_sharded, srcs, dsts, ws)

    # Reference: single-device ingest.
    ref = sk.update(src, dst, w)
    np.testing.assert_array_equal(np.asarray(out.counters), np.asarray(ref.counters))
    print("distributed ingest == local ingest")

    est = distributed_edge_query(mesh, out, src[:32], dst[:32])
    ref_est = queries.edge_query(ref, src[:32], dst[:32])
    np.testing.assert_allclose(np.asarray(est), np.asarray(ref_est))
    print("distributed edge query OK")

    np.testing.assert_array_equal(np.asarray(out.row_flows), np.asarray(ref.row_flows))
    np.testing.assert_array_equal(np.asarray(out.col_flows), np.asarray(ref.col_flows))
    print("distributed flow registers bit-match local oracle")

    for direction, ref_fn in (
        ("in", queries.node_in_flow),
        ("out", queries.node_out_flow),
    ):
        ref_pq = ref_fn(ref, src[:16])
        # registers fast path AND the collective counter-reduction fallback
        pq = distributed_point_query(mesh, out, src[:16], direction)
        np.testing.assert_allclose(np.asarray(pq), np.asarray(ref_pq))
        pq2 = distributed_point_query(
            mesh, out, src[:16], direction, use_registers=False
        )
        np.testing.assert_allclose(np.asarray(pq2), np.asarray(ref_pq))
    print("distributed point queries OK (both paths)")
    print("ALL_OK")
    """
)


@pytest.mark.slow
def test_distributed_sketch_matches_local():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "ALL_OK" in proc.stdout
