"""Elastic scaling: save a checkpoint under one mesh, restore under a
DIFFERENT mesh (8-device subprocess) — the restore path re-lays-out every
leaf for the new topology and training resumes bit-exactly."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint.manager import CheckpointManager

    tmp = tempfile.mkdtemp()
    mgr = CheckpointManager(tmp)

    # "pod A": 2x4 mesh, param sharded (data, model)
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    w = jnp.arange(64.0 * 32).reshape(64, 32)
    w_a = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
    mgr.save(10, {"w": w_a}, {"step": 10})

    # "pod B": 4x2 mesh (elastic re-shape), different layout
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    sh_b = {"w": NamedSharding(mesh_b, P("model", "data"))}
    restored, meta = mgr.restore(like={"w": w}, shardings=sh_b)
    assert meta["step"] == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
    assert restored["w"].sharding == sh_b["w"]
    # and a math op under the new mesh works on the restored layout
    out = jax.jit(lambda a: (a @ a.T).sum())(restored["w"])
    assert np.isfinite(float(out))
    print("ALL_OK")
    """
)


@pytest.mark.slow
def test_checkpoint_reshards_across_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ALL_OK" in proc.stdout
