"""Event-time stream plane tests: watermarks (bounded out-of-orderness,
per-source low-watermark merge, state roundtrip), the segmented
write-ahead log (roundtrip, suffix replay, torn-tail tolerance, rotation
+ checkpoint-keyed GC), exactly-once subscription replay (fault injection
at every batch boundary — crash, ``recover()``, and the event transcript
is bit-identical to the uninterrupted run), out-of-order-within-lateness
bit-identity, late-edge policies via the turnstile-delete path,
backpressure overflow policies, corrupt-checkpoint fallback, and the
fleet's per-tenant WAL lanes."""
import math
import os
import warnings
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import (
    GraphStream,
    Query,
    RecoveryReport,
    SketchConfig,
)
from repro.checkpoint.manager import CheckpointCorruptError, CheckpointManager
from repro.stream.events import EventFeed, EventOverflowError
from repro.stream.wal import (
    AdvanceMutation,
    EdgeMutation,
    MergeMutation,
    WriteAheadLog,
)
from repro.stream.watermark import WatermarkTracker, slice_of, slices_of

CFG = SketchConfig(depth=2, width_rows=64, width_cols=64)


def _open(**kw):
    kw.setdefault("ingest_backend", "scatter")
    kw.setdefault("query_backend", "jnp")
    return GraphStream.open(CFG, **kw)


def _counters(gs):
    """Window state in HEAD-RELATIVE (canonical) slot order.

    The raw arrays are ring-slot indexed, and the ring's alignment is a
    representation detail: two runs of the same logical stream can start
    their heads at different slices (the first batch's max event time
    picks the initial head) and so rotate the ring a different number of
    times while holding identical per-slice content.  Queries aggregate
    over the slice axis, so only the head-relative view is semantic.
    """
    gs.flush()
    w = gs._window
    slices = np.asarray(w.slices)
    rows = np.asarray(w.row_flows)
    cols = np.asarray(w.col_flows)
    head = getattr(gs, "_head_slice", None)
    if head is not None:
        K = w.n_slices
        slot_off = (gs._ring_pos - head) % K
        order = [(head - K + 1 + rel + slot_off) % K for rel in range(K)]
        slices, rows, cols = slices[order], rows[order], cols[order]
    return (slices, rows, cols, head if head is not None else int(w.current))


# ---------------------------------------------------------------------------
# watermark tracker
# ---------------------------------------------------------------------------


def test_slice_of():
    assert slice_of(0.0, 1.0) == 0
    assert slice_of(2.999, 1.0) == 2
    assert slice_of(-0.5, 1.0) == -1
    np.testing.assert_array_equal(
        slices_of(np.array([0.0, 1.5, 7.99]), 2.0), [0, 0, 3]
    )


def test_watermark_min_over_sources_and_monotone():
    t = WatermarkTracker(max_lateness=2.0)
    assert t.watermark == -math.inf
    assert t.observe(0, 10.0) == 8.0
    # a second, lagging source pulls the MIN down, but W never regresses
    assert t.observe(1, 5.0) == 8.0
    # the laggard catching up is what moves W now
    assert t.observe(1, 20.0) == 8.0  # min is still source 0 at 10
    assert t.observe(0, 30.0) == 18.0  # min(30, 20) - 2
    assert t.sources == {0: 30.0, 1: 20.0}


def test_watermark_rejects_bad_input():
    with pytest.raises(ValueError):
        WatermarkTracker(max_lateness=-1.0)
    with pytest.raises(ValueError):
        WatermarkTracker(max_lateness=math.inf)
    t = WatermarkTracker(1.0)
    with pytest.raises(ValueError):
        t.observe(0, math.nan)


def test_watermark_state_roundtrip():
    t = WatermarkTracker(1.5)
    t.observe(3, 7.0)
    t.observe(4, 9.0)
    t.late_dropped = 2
    t.late_retracted = 5
    t2 = WatermarkTracker.from_state(t.state())
    assert t2.watermark == t.watermark
    assert t2.sources == t.sources
    assert (t2.late_dropped, t2.late_retracted) == (2, 5)
    # fresh tracker (no observations) survives the None watermark encoding
    t3 = WatermarkTracker.from_state(WatermarkTracker(1.5).state())
    assert t3.watermark == -math.inf


# ---------------------------------------------------------------------------
# write-ahead log
# ---------------------------------------------------------------------------


def _edges(rng, n=8):
    return (
        rng.integers(0, 100, n).astype(np.uint32),
        rng.integers(0, 100, n).astype(np.uint32),
        rng.random(n).astype(np.float32),
    )


def test_wal_roundtrip_and_suffix_replay(tmp_path):
    rng = np.random.default_rng(0)
    wal = WriteAheadLog(tmp_path)
    s1, d1, w1 = _edges(rng)
    seq1 = wal.append_edges(s1, d1, w1, timestamps=np.arange(8.0))
    wal.append_advance()
    s2, d2, w2 = _edges(rng, 5)
    wal.append_edges(s2, d2, w2)
    wal.close()

    muts = list(WriteAheadLog(tmp_path).replay())
    assert [type(m) for m in muts] == [EdgeMutation, AdvanceMutation, EdgeMutation]
    np.testing.assert_array_equal(muts[0].src, s1)
    np.testing.assert_array_equal(muts[0].dst, d1)
    np.testing.assert_array_equal(muts[0].weights, w1)
    np.testing.assert_array_equal(muts[0].timestamps, np.arange(8.0))
    assert muts[2].timestamps is None
    np.testing.assert_array_equal(muts[2].weights, w2)
    # suffix replay: everything after the first commit
    suffix = list(WriteAheadLog(tmp_path).replay(after_seq=seq1))
    assert [type(m) for m in suffix] == [AdvanceMutation, EdgeMutation]


def test_wal_reopen_continues_sequence(tmp_path):
    rng = np.random.default_rng(1)
    wal = WriteAheadLog(tmp_path)
    wal.append_edges(*_edges(rng))
    first = wal.last_seq
    wal.close()
    wal2 = WriteAheadLog(tmp_path)
    wal2.append_edges(*_edges(rng))
    assert wal2.last_seq > first
    seqs = [m.seq for m in wal2.replay()]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)


def test_wal_torn_tail_is_dropped(tmp_path):
    rng = np.random.default_rng(2)
    wal = WriteAheadLog(tmp_path)
    wal.append_edges(*_edges(rng))
    wal.append_edges(*_edges(rng, 4))
    wal.close()
    seg = sorted(tmp_path.glob("wal-*.seg"))[-1]
    # Chop mid-record: the torn record AND its uncommitted edge run drop.
    data = seg.read_bytes()
    seg.write_bytes(data[: len(data) - 13])
    muts = list(WriteAheadLog(tmp_path).replay())
    assert len(muts) == 1  # only the first committed batch survives
    # and a fresh append after reopen keeps sequence numbers consistent
    wal3 = WriteAheadLog(tmp_path)
    wal3.append_edges(*_edges(rng, 3))
    muts = list(wal3.replay())
    assert len(muts) == 2


def test_wal_rotation_and_gc(tmp_path):
    rng = np.random.default_rng(3)
    wal = WriteAheadLog(tmp_path)
    wal.append_edges(*_edges(rng))
    covered = wal.last_seq
    wal.rotate()
    wal.append_edges(*_edges(rng))
    assert len(wal.segments()) == 2
    removed = wal.gc(covered)
    assert removed == 1
    assert len(wal.segments()) == 1
    # the uncovered mutation is still replayable
    assert len(list(wal.replay(after_seq=covered))) == 1
    # gc never removes the newest segment, even if fully covered
    wal.sync()
    assert wal.gc(wal.last_seq) == 0
    assert len(wal.segments()) == 1


# ---------------------------------------------------------------------------
# event feed overflow policies
# ---------------------------------------------------------------------------


def test_event_feed_policies():
    f = EventFeed(2, "drop_oldest")
    for i in range(4):
        f.push(i)
    assert list(f.drain()) == [2, 3] and f.dropped == 2

    f = EventFeed(2, "drop_newest")
    for i in range(4):
        f.push(i)
    assert list(f.drain()) == [0, 1] and f.dropped == 2

    f = EventFeed(2, "error")
    f.push(0), f.push(1)
    with pytest.raises(EventOverflowError):
        f.push(2)
    with pytest.raises(ValueError):
        EventFeed(2, "bogus")


def test_subscription_overflow_counter():
    gs = _open()
    sub = gs.subscribe(
        Query.in_flow(7), every=1, max_pending=2, overflow="drop_newest"
    )
    for i in range(5):
        gs.ingest([1, 7], [7, 2])
    assert sub.pending == 2
    assert sub.events_dropped == 3
    assert gs.events_dropped == 0  # session feed is larger; nothing lost
    ticks = [e.tick for e in sub.poll()]
    assert ticks == [1, 2]  # drop_newest keeps the OLDEST two


# ---------------------------------------------------------------------------
# event-time ingest: watermark-driven advances, late policies, bit-identity
# ---------------------------------------------------------------------------


def _open_eventtime(**kw):
    kw.setdefault("window_slices", 8)
    kw.setdefault("slice_width", 1.0)
    kw.setdefault("max_lateness", 2.0)
    return _open(**kw)


def test_eventtime_requires_timestamps():
    gs = _open_eventtime()
    with pytest.raises(ValueError, match="timestamps"):
        gs.ingest([1], [2])
    with pytest.raises(ValueError, match="finite"):
        gs.ingest([1], [2], timestamps=[math.nan])
    with pytest.raises(ValueError, match="shape"):
        gs.ingest([1, 2], [2, 3], timestamps=[1.0])


def test_eventtime_validation():
    with pytest.raises(ValueError):  # max_lateness needs slice_width
        _open(window_slices=4, max_lateness=1.0)
    with pytest.raises(ValueError):  # slice_width needs a window
        _open(slice_width=1.0)
    with pytest.raises(ValueError):  # lead must leave live slices
        _open(window_slices=2, slice_width=1.0, max_lateness=5.0)


def test_watermark_drives_window_advance():
    gs = _open_eventtime()
    gs.ingest([1], [2], timestamps=[0.5])
    assert gs.stats.auto_advances == 0
    r = gs.ingest([3], [4], timestamps=[4.5])
    assert r.auto_advances > 0
    assert gs.watermark == 2.5
    assert gs.stats.auto_advances == r.auto_advances


def test_in_order_stream_never_late():
    """An in-order stream is never late, regardless of how batch spans
    compare to max_lateness — lateness is judged against the watermark
    promised BEFORE each batch (regression: a batch spanning more than
    max_lateness must not retract its own head)."""
    gs = _open_eventtime(max_lateness=0.5)
    ts = np.arange(0.0, 12.0, 0.05)  # every batch spans 3 slices
    rng = np.random.default_rng(0)
    for lo in range(0, ts.size, 60):
        chunk = ts[lo : lo + 60]
        gs.ingest(
            rng.integers(0, 50, chunk.size),
            rng.integers(0, 50, chunk.size),
            timestamps=chunk,
        )
    assert gs.late_dropped == 0 and gs.late_retracted == 0


def _bounded_shuffle(rng, n, width):
    """A permutation where element i moves at most ``width`` positions."""
    keys = np.arange(n) + rng.uniform(0, width, n)
    return np.argsort(keys, kind="stable")


def _run_permuted(order, src, dst, w, ts):
    n = src.size
    gs = _open_eventtime(double_buffer=False)
    for lo in range(0, n, 30):
        idx = order[lo : lo + 30]
        gs.ingest(src[idx], dst[idx], w[idx], timestamps=ts[idx])
    return gs, _counters(gs)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_out_of_order_within_lateness_bit_identical(seed):
    """Property: ingest shuffled within the lateness bound is bit-identical
    (counters, registers, ring position) to in-order ingest.  Integer
    weights — float32 integer sums are exact, so per-cell accumulation is
    order-free and the comparison is exact equality (the turnstile model's
    integer-Δ case).  Arbitrary float weights agree to float precision
    (see the companion test)."""
    rng = np.random.default_rng(seed)
    n = 300
    src = rng.integers(0, 200, n).astype(np.uint32)
    dst = rng.integers(0, 200, n).astype(np.uint32)
    w = rng.integers(1, 6, n).astype(np.float32)
    ts = np.sort(rng.uniform(0, 10.0, n))

    gs_a, in_order = _run_permuted(np.arange(n), src, dst, w, ts)
    # bound the TIME displacement directly: shuffle within windows of
    # 2.0 time units (== max_lateness), so nothing is ever late.
    keys = ts + rng.uniform(0, 2.0, n)
    gs_b, shuffled = _run_permuted(np.argsort(keys, kind="stable"), src, dst, w, ts)
    assert gs_a.late_retracted == 0 and gs_b.late_retracted == 0
    for a, b in zip(in_order[:3], shuffled[:3]):
        np.testing.assert_array_equal(a, b, err_msg=f"seed {seed}")
    assert in_order[3] == shuffled[3]


def test_out_of_order_float_weights_close():
    """Arbitrary float32 weights: the same multiset reaches every cell, in
    a different order — agreement is to addition-rounding precision."""
    rng = np.random.default_rng(0)
    n = 300
    src = rng.integers(0, 200, n).astype(np.uint32)
    dst = rng.integers(0, 200, n).astype(np.uint32)
    w = rng.random(n).astype(np.float32)
    ts = np.sort(rng.uniform(0, 10.0, n))
    _, in_order = _run_permuted(np.arange(n), src, dst, w, ts)
    keys = ts + rng.uniform(0, 2.0, n)
    _, shuffled = _run_permuted(np.argsort(keys, kind="stable"), src, dst, w, ts)
    for a, b in zip(in_order[:3], shuffled[:3]):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_late_drop_policy_counts_and_filters():
    gs = _open_eventtime(late_policy="drop", max_lateness=1.0)
    gs.ingest([1], [2], timestamps=[10.0])  # watermark -> 9.0
    r = gs.ingest([3, 4], [5, 6], timestamps=[0.5, 9.5])
    assert r.late_dropped == 1 and r.late_retracted == 0
    assert gs.late_dropped == 1
    # the in-bound edge landed, the late one did not
    assert gs.query(Query.edge(4, 6)).value > 0
    assert float(gs.query(Query.edge(3, 5)).value) == 0.0


def test_late_retract_policy_nets_to_zero():
    """Retract (default): the late edge rides the turnstile-delete path —
    its weight lands and is immediately backed out, so the final state
    equals a run that never saw the late edge (exact cancellation)."""
    gs = _open_eventtime(max_lateness=1.0, double_buffer=False)
    gs.ingest([1], [2], [2.0], timestamps=[10.0])
    r = gs.ingest([3, 4], [5, 6], [1.5, 2.5], timestamps=[0.5, 9.5])
    assert r.late_retracted == 1
    ref = _open_eventtime(max_lateness=1.0, double_buffer=False)
    ref.ingest([1], [2], [2.0], timestamps=[10.0])
    ref.ingest([4], [6], [2.5], timestamps=[9.5])
    for a, b in zip(_counters(gs)[:3], _counters(ref)[:3]):
        np.testing.assert_array_equal(a, b)


def test_per_source_watermark_holds_back():
    gs = _open_eventtime(max_lateness=1.0)
    gs.ingest([3], [4], timestamps=[2.0], source="slow")
    # the slow source holds the session watermark at 2.0 - 1.0
    gs.ingest([1], [2], timestamps=[5.0], source="fast")
    assert gs.watermark == 1.0
    gs.ingest([5], [6], timestamps=[6.0], source="slow")
    assert gs.watermark == 4.0  # min(5, 6) - 1
    # a source REGISTERING after the watermark has advanced cannot
    # regress it (the tracker clamps: watermarks are promises)
    gs.ingest([7], [8], timestamps=[0.5], source="latecomer")
    assert gs.watermark == 4.0


# ---------------------------------------------------------------------------
# exactly-once recovery: fault injection at every batch boundary
# ---------------------------------------------------------------------------

N_BATCHES = 8
CKPT_EVERY = 3


def _mk_batches(seed=7):
    rng = np.random.default_rng(seed)
    out = []
    t = 0.0
    for _ in range(N_BATCHES):
        n = 20
        ts = np.sort(t + rng.uniform(0, 1.5, n))
        t = float(ts.max())
        out.append(
            (
                rng.integers(0, 100, n).astype(np.uint32),
                rng.integers(0, 100, n).astype(np.uint32),
                rng.random(n).astype(np.float32),
                ts,
            )
        )
    return out


def _event_key(ev):
    vals = tuple(
        float(x) for r in ev.results for x in np.asarray(r.value).ravel()
    )
    return (ev.name, ev.tick, ev.epoch, vals, ev.alarm)


def _drive(gs, sub, batches, transcript):
    for i, (s, d, w, ts) in enumerate(batches):
        gs.ingest(s, d, w, timestamps=ts)
        transcript.extend(_event_key(e) for e in sub.poll())
        if (i + 1) % CKPT_EVERY == 0 and gs._ckpt is not None:
            gs.checkpoint()


def _subscribed(gs):
    return gs.subscribe(
        Query.in_flow(7),
        Query.reach(3, 9),
        every=1,
        name="m",
        alarm=lambda rs: bool(np.asarray(rs[0].value) > 5),
    )


@pytest.mark.parametrize("crash_at", list(range(N_BATCHES + 1)))
def test_exactly_once_replay_any_crash_point(tmp_path, crash_at):
    """Crash after ``crash_at`` batches, recover into a fresh process, and
    the consumed event sequence + final counters are bit-identical to the
    uninterrupted run — including crash before any checkpoint (genesis
    replay) and crash after the final batch."""
    batches = _mk_batches()
    wal, ckpt = tmp_path / "wal", tmp_path / "ckpt"

    oracle = _open_eventtime(double_buffer=False)
    want = []
    _drive(oracle, _subscribed(oracle), batches, want)
    want_counters = _counters(oracle)

    def open_durable():
        return _open_eventtime(
            double_buffer=False,
            wal_dir=str(wal),
            checkpoint_dir=str(ckpt),
        )

    gs1 = open_durable()
    sub1 = _subscribed(gs1)
    got = []
    _drive(gs1, sub1, batches[:crash_at], got)
    consumed_tick = sub1.ticks
    del gs1  # crash: no close, no final checkpoint

    gs2 = open_durable()
    sub2 = _subscribed(gs2)
    sub2.seek(consumed_tick)  # consumer's durable position, BEFORE recover
    report = gs2.recover()
    assert isinstance(report, RecoveryReport)
    got.extend(_event_key(e) for e in sub2.poll())
    _drive(gs2, sub2, batches[crash_at:], got)

    assert got == want, f"crash_at={crash_at}"
    if crash_at % CKPT_EVERY != 0:
        # crash between checkpoints: recovery must have actually replayed
        # (a crash right ON a checkpoint leaves an empty WAL suffix)
        assert sub2.events_deduped + report.mutations_replayed > 0
    for a, b in zip(_counters(gs2)[:3], want_counters[:3]):
        np.testing.assert_array_equal(a, b, err_msg=f"crash_at={crash_at}")


def test_recover_requires_wal(tmp_path):
    gs = _open(checkpoint_dir=str(tmp_path))
    with pytest.raises(ValueError, match="wal_dir"):
        gs.recover()


def test_checkpoint_gc_drops_covered_wal_segments(tmp_path):
    gs = _open(
        wal_dir=str(tmp_path / "wal"), checkpoint_dir=str(tmp_path / "ckpt")
    )
    rng = np.random.default_rng(0)
    for i in range(4):
        s, d, w = _edges(rng)
        gs.ingest(s, d, w)
        gs.checkpoint()
    # every retained checkpoint covers the whole log; old segments are gone
    assert len(gs._wal.segments()) <= 2
    # and recovery from what remains still works
    gs.flush()
    ref = _counters_plain(gs)
    gs2 = _open(
        wal_dir=str(tmp_path / "wal"), checkpoint_dir=str(tmp_path / "ckpt")
    )
    gs2.recover()
    np.testing.assert_array_equal(_counters_plain(gs2), ref)


def _counters_plain(gs):
    gs.flush()
    return np.asarray(gs.sketch.counters)


# ---------------------------------------------------------------------------
# corrupt-checkpoint fallback (satellite)
# ---------------------------------------------------------------------------


def test_corrupt_latest_falls_back_to_previous(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    state = {"x": np.arange(4, dtype=np.float32)}
    mgr.save(1, state, metadata={"tag": "one"})
    mgr.save(2, {"x": np.arange(4, dtype=np.float32) * 2}, metadata={"tag": "two"})
    shard = tmp_path / "step_0000000002" / "arrays.npz"
    shard.write_bytes(shard.read_bytes()[:40])  # truncate mid-zip
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        got, meta = mgr.restore(like={"x": np.zeros(4, np.float32)})
    assert meta["tag"] == "one" and meta["step"] == 1
    np.testing.assert_array_equal(np.asarray(got["x"]), state["x"])
    assert any(
        isinstance(w.message, RuntimeWarning) and "step 2" in str(w.message)
        for w in caught
    )
    # an explicitly requested step never silently substitutes
    with pytest.raises(CheckpointCorruptError) as ei:
        mgr.restore(step=2, like={"x": np.zeros(4, np.float32)})
    assert ei.value.step == 2 and ei.value.path.name == "arrays.npz"


def test_all_checkpoints_corrupt_raises_first_error(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, {"x": np.zeros(2, np.float32)})
    (tmp_path / "step_0000000001" / "arrays.npz").write_bytes(b"not a zip")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        with pytest.raises(CheckpointCorruptError):
            mgr.restore(like={"x": np.zeros(2, np.float32)})


def test_read_metadata_manifest_only(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(5, {"x": np.zeros(2, np.float32)}, metadata={"wal_seq": 42})
    meta = mgr.read_metadata(5)
    assert meta["wal_seq"] == 42 and meta["step"] == 5


# ---------------------------------------------------------------------------
# fleet per-tenant WAL lanes
# ---------------------------------------------------------------------------


def _fleet(tmp_path, **kw):
    from repro.fleet.session import SketchFleet

    kw.setdefault("capacity", 2)
    kw.setdefault("seed", 3)
    kw.setdefault("checkpoint_dir", str(tmp_path / "ckpt"))
    kw.setdefault("wal_dir", str(tmp_path / "wal"))
    return SketchFleet(CFG, **kw)


def test_fleet_lane_recovery_matches_oracle(tmp_path):
    from repro.fleet.session import SketchFleet

    rng = np.random.default_rng(0)
    n_tenants, batches = 4, []
    for b in range(6):
        batches.append(
            (
                rng.integers(0, n_tenants, 30),
                rng.integers(0, 200, 30).astype(np.uint32),
                rng.integers(0, 200, 30).astype(np.uint32),
                rng.random(30).astype(np.float32),
            )
        )

    oracle = SketchFleet(CFG, capacity=n_tenants, seed=3)
    for ids, s, d, w in batches:
        oracle.ingest_mixed(ids, s, d, w)
    oracle.flush()
    want = {
        t: np.asarray(oracle.tenant(t).sketch.counters) for t in range(n_tenants)
    }

    # capacity 2 < 4 tenants: evictions (and lane GC) happen mid-stream
    f1 = _fleet(tmp_path)
    for ids, s, d, w in batches[:4]:
        f1.ingest_mixed(ids, s, d, w)
    f1.flush()
    assert f1.stats.evictions > 0  # shard+wal_seq coverage is exercised
    del f1  # crash

    f2 = _fleet(tmp_path)
    reports = f2.recover()
    assert set(reports) == set(range(n_tenants))
    for ids, s, d, w in batches[4:]:
        f2.ingest_mixed(ids, s, d, w)
    f2.flush()
    for t in range(n_tenants):
        np.testing.assert_array_equal(
            np.asarray(f2.tenant(t).sketch.counters), want[t], err_msg=f"t={t}"
        )


def test_fleet_close_retires_lane(tmp_path):
    f = _fleet(tmp_path)
    f.tenant("a").ingest([1, 2], [3, 4])
    f.tenant("b").ingest([5], [6])
    f.flush()
    f.tenant("a").close()
    f2 = _fleet(tmp_path)
    reports = f2.recover()
    assert set(reports) == {"b"}


def test_fleet_wal_receipt_and_timestamps(tmp_path):
    f = _fleet(tmp_path)
    r = f.tenant("x").ingest([1, 2], [3, 4], timestamps=[1.0, 2.0])
    assert r.wal_seq is not None
    muts = list(f._wal_lane("x").replay())
    edge = [m for m in muts if isinstance(m, EdgeMutation)][0]
    np.testing.assert_array_equal(edge.timestamps, [1.0, 2.0])


def test_fleet_events_overflow_counter(tmp_path):
    f = _fleet(tmp_path, events_policy="drop_newest")
    sess = f.tenant("t")
    sess.subscribe(Query.in_flow(7), every=1, max_pending=1, name="s")
    for _ in range(3):
        sess.ingest([1, 7], [7, 2])
    assert sess.subscriptions[0].events_dropped == 2
    assert f.events_dropped == 0  # fleet feed is deep enough here
