"""Fleet plane tests: per-tenant BIT-IDENTITY of a mixed-stream fleet to
independent ``GraphStream`` sessions (ingest, delete, window advance,
every query family, standing subscription ticks), the one-compile /
one-dispatch-per-batch ingest contract at T=64, LRU eviction to
checkpoint shards + fault-in, the stale-closure regression (cancel /
evict must drop the slot's closure entry), and the SketchServer fleet
mode."""
import numpy as np
import pytest

from repro.api import GraphStream, Query, QueryBatch, SketchConfig
from repro.fleet import FleetSketch, SketchFleet
from repro.serve.engine import SketchServer

CFG = SketchConfig(depth=2, width_rows=64, width_cols=64)
SEED = 11


def _open_session(**kw):
    return GraphStream.open(
        CFG, seed=SEED, ingest_backend="scatter", query_backend="jnp", **kw
    )


def _rand_batch(rng, n=32, nodes=500):
    return (
        rng.integers(0, nodes, n).astype(np.uint32),
        rng.integers(0, nodes, n).astype(np.uint32),
        rng.integers(1, 4, n).astype(np.float32),
    )


def _assert_value_equal(a, b, ctx=""):
    if isinstance(a, tuple):
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=ctx)
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=ctx)


def _query_suite(rng, nodes=500):
    qs = rng.integers(0, nodes, 12).astype(np.uint32)
    qd = rng.integers(0, nodes, 12).astype(np.uint32)
    return [
        Query.edge(qs, qd),
        Query.in_flow(qs),
        Query.out_flow(qs),
        Query.flow(qs),
        Query.heavy(qs, 0.05),
        Query.reach(qs, qd),
        Query.subgraph(qs[:3], qd[:3]),
    ]


# ---------------------------------------------------------------------------
# Tenant isolation: fleet == T independent sessions, bit for bit
# ---------------------------------------------------------------------------


def test_fleet_matches_independent_sessions_every_family():
    """Interleaved mixed stream with ingest/delete/window-advance per
    tenant: counters AND all seven query families bit-match T independent
    windowed GraphStream sessions, and a standing subscription ticks to
    the same results."""
    t_count = 4
    rng = np.random.default_rng(0)
    fleet = SketchFleet.open(CFG, capacity=t_count, seed=SEED, window_slices=3)
    sessions = [_open_session(window_slices=3) for _ in range(t_count)]

    # Standing subscription on tenant 0 in both worlds (every=2).
    sub_q = QueryBatch([Query.in_flow(np.arange(8, dtype=np.uint32)),
                        Query.reach(np.arange(4, dtype=np.uint32),
                                    np.arange(4, 8, dtype=np.uint32))])
    f_sub = fleet.tenant(0).subscribe(sub_q, every=2, name="t0")
    s_sub = sessions[0].subscribe(sub_q, every=2, name="t0")

    for step in range(6):
        n = 120
        ids = rng.integers(0, t_count, n)
        src, dst, w = _rand_batch(rng, n)
        fleet.ingest_mixed(ids, src, dst, w)
        for t in range(t_count):
            m = ids == t
            if m.any():
                sessions[t].ingest(src[m], dst[m], w[m])
        if step == 2:
            # turnstile delete on tenant 1
            ds, dd, dw = _rand_batch(rng, 8)
            fleet.tenant(1).delete(ds, dd, dw)
            sessions[1].delete(ds, dd, dw)
        if step == 3:
            fleet.tenant(2).advance_window()
            sessions[2].advance_window()

    for t in range(t_count):
        sk = sessions[t].sketch
        fk = fleet.tenant(t).sketch
        np.testing.assert_array_equal(
            np.asarray(sk.counters), np.asarray(fk.counters)
        )
        np.testing.assert_array_equal(
            np.asarray(sk.row_flows), np.asarray(fk.row_flows)
        )
        np.testing.assert_array_equal(
            np.asarray(sk.col_flows), np.asarray(fk.col_flows)
        )
        assert fleet.tenant(t).epoch == sessions[t].epoch
        for q in _query_suite(np.random.default_rng(5)):
            a = sessions[t].query(q).value
            b = fleet.tenant(t).query(q).value
            _assert_value_equal(a, b, ctx=f"tenant {t} family {q.family}")

    # Subscription ticks happened in lockstep with identical results.
    f_events, s_events = f_sub.poll(), s_sub.poll()
    assert f_sub.ticks == s_sub.ticks > 0
    assert len(f_events) == len(s_events)
    for fe, se in zip(f_events, s_events):
        assert fe.tick == se.tick and fe.epoch == se.epoch
        for fr, sr in zip(fe.results, se.results):
            _assert_value_equal(fr.value, sr.value, ctx="subscription tick")


def test_fleet_64_tenants_one_compile_one_dispatch_per_batch():
    """The acceptance contract: 64 tenants, fixed-size mixed batches →
    exactly 1 jit compile total and 1 device dispatch per batch, results
    bit-identical per tenant to 64 independent sessions."""
    t_count = 64
    rng = np.random.default_rng(1)
    fleet = SketchFleet.open(CFG, capacity=t_count, seed=SEED)
    sessions = [_open_session() for _ in range(t_count)]
    n_batches = 4
    for _ in range(n_batches):
        n = 1024
        ids = rng.integers(0, t_count, n)
        src, dst, w = _rand_batch(rng, n)
        fleet.ingest_mixed(ids, src, dst, w)
        for t in range(t_count):
            m = ids == t
            if m.any():
                sessions[t].ingest(src[m], dst[m], w[m])
    fleet.flush()
    assert fleet._ingest.dispatches == n_batches
    assert fleet._ingest._cache_size() == 1
    for t in range(0, t_count, 7):
        np.testing.assert_array_equal(
            np.asarray(sessions[t].sketch.counters),
            np.asarray(fleet.tenant(t).sketch.counters),
        )


def test_fleet_query_cache_stable_under_tenant_permutation():
    """Permuting which tenants a query batch addresses reuses the same
    traced signatures — the slot lane is data, not structure."""
    rng = np.random.default_rng(2)
    fleet = SketchFleet.open(CFG, capacity=8, seed=SEED)
    ids = np.arange(8)
    src, dst, w = _rand_batch(rng, 256)
    fleet.ingest_mixed(np.repeat(ids, 32), src, dst, w)
    qs = rng.integers(0, 500, 8).astype(np.uint32)
    for t in range(8):
        fleet.tenant(t).query(Query.in_flow(qs))
    size_after_first = fleet.engine._cache_size()
    for t in reversed(range(8)):
        fleet.tenant(t).query(Query.in_flow(qs))
    assert fleet.engine._cache_size() == size_after_first


# ---------------------------------------------------------------------------
# LRU residency: eviction to shards, fault-in, closure hygiene
# ---------------------------------------------------------------------------


def test_fleet_eviction_faults_back_bit_identical(tmp_path):
    rng = np.random.default_rng(3)
    fleet = SketchFleet.open(
        CFG, capacity=2, seed=SEED, checkpoint_dir=str(tmp_path)
    )
    ref = {}
    for tid in ("a", "b", "c"):
        src, dst, w = _rand_batch(rng, 64)
        fleet.tenant(tid).ingest(src, dst, w)
        ref[tid] = (src, dst, w)
    # capacity 2 → "a" was evicted when "c" arrived
    assert fleet.stats.evictions == 1
    assert "a" not in fleet.resident_tenants
    assert not fleet._sessions["a"].resident

    oracle = _open_session()
    oracle.ingest(*ref["a"])
    # touching "a" faults it back in (evicting the coldest resident)
    np.testing.assert_array_equal(
        np.asarray(fleet.tenant("a").sketch.counters),
        np.asarray(oracle.sketch.counters),
    )
    assert fleet.stats.fault_ins == 1
    assert fleet.tenant("a").epoch == oracle.epoch
    # queries keep answering correctly after the round trip
    qs = rng.integers(0, 500, 6).astype(np.uint32)
    _assert_value_equal(
        oracle.query(Query.out_flow(qs)).value,
        fleet.tenant("a").query(Query.out_flow(qs)).value,
    )


def test_fleet_over_capacity_without_checkpoint_dir_raises():
    fleet = SketchFleet.open(CFG, capacity=1, seed=SEED)
    fleet.tenant("a")
    with pytest.raises(ValueError, match="checkpoint_dir"):
        fleet.tenant("b")


def test_fleet_mixed_batch_more_tenants_than_capacity(tmp_path):
    """Regression: one mixed batch spanning more distinct tenants than the
    fleet has slots must not evict a batch member mid-route (which left its
    slot ``None`` and crashed the slot-lane build).  The batch is split
    into capacity-sized tenant groups, and per-tenant state stays
    bit-identical to independent sessions through the evict/fault-in
    churn."""
    t_count, cap = 5, 2
    rng = np.random.default_rng(9)
    fleet = SketchFleet.open(
        CFG, capacity=cap, seed=SEED, checkpoint_dir=str(tmp_path)
    )
    sessions = [_open_session() for _ in range(t_count)]
    for _ in range(2):
        n = 100
        ids = rng.integers(0, t_count, n)
        src, dst, w = _rand_batch(rng, n)
        receipts = fleet.ingest_mixed(ids, src, dst, w)
        assert set(receipts) == set(np.unique(ids).tolist())
        assert sum(r.n_edges for r in receipts.values()) == n
        for t in range(t_count):
            m = ids == t
            if m.any():
                sessions[t].ingest(src[m], dst[m], w[m])
    assert len(fleet.resident_tenants) == cap
    assert fleet.stats.evictions > 0
    for t in range(t_count):
        np.testing.assert_array_equal(
            np.asarray(sessions[t].sketch.counters),
            np.asarray(fleet.tenant(t).sketch.counters),
            err_msg=f"tenant {t}",
        )
        assert fleet.tenant(t).epoch == sessions[t].epoch


def test_fleet_ingest_weights_length_mismatch_raises():
    fleet = SketchFleet.open(CFG, capacity=2, seed=SEED)
    src = np.arange(4, dtype=np.uint32)
    with pytest.raises(ValueError, match="weights"):
        fleet.ingest_mixed("a", src, src, np.ones(3, np.float32))
    with pytest.raises(ValueError, match="weights"):
        fleet.ingest_mixed(
            np.zeros(4, np.int64), src, src, np.ones(6, np.float32)
        )


def test_evicted_then_readmitted_tenant_gets_fresh_closure(tmp_path):
    """Regression (stale-closure fix): tenant A builds a closure at epoch
    E, is evicted, another tenant B occupies the slot and reaches epoch E
    too — B (and A after fault-in) must never see A's cached closure."""
    rng = np.random.default_rng(4)
    fleet = SketchFleet.open(
        CFG, capacity=1, seed=SEED, checkpoint_dir=str(tmp_path)
    )
    # A: 1 ingest batch (epoch 1), then a reach query caches A's closure.
    a_batch = _rand_batch(rng, 32)
    fleet.tenant("A").ingest(*a_batch)
    pair = (np.asarray([a_batch[0][0]]), np.asarray([a_batch[1][0]]))
    assert bool(fleet.tenant("A").query(Query.reach(*pair)).value[0])
    assert fleet.engine.closure_builds == 1

    # B evicts A, ingests a DIFFERENT batch, lands on the same epoch 1.
    b_batch = _rand_batch(rng, 32)
    fleet.tenant("B").ingest(*b_batch)
    assert fleet.tenant("B").epoch == 1
    oracle_b = _open_session()
    oracle_b.ingest(*b_batch)
    _assert_value_equal(
        oracle_b.query(Query.reach(*pair)).value,
        fleet.tenant("B").query(Query.reach(*pair)).value,
        ctx="B must not see A's closure at the colliding epoch",
    )
    assert fleet.engine.closure_builds == 2  # B built its own

    # A faults back in (evicting B) at its checkpointed epoch 1: fresh build.
    oracle_a = _open_session()
    oracle_a.ingest(*a_batch)
    _assert_value_equal(
        oracle_a.query(Query.reach(*pair)).value,
        fleet.tenant("A").query(Query.reach(*pair)).value,
        ctx="A after fault-in must rebuild, not reuse B's closure",
    )
    assert fleet.engine.closure_builds == 3


def test_cancel_reach_subscription_drops_slot_closure():
    """Regression (stale-closure fix): ``Subscription.cancel()`` on a
    reach-bearing plan drops the tenant slot's closure entry."""
    rng = np.random.default_rng(5)
    fleet = SketchFleet.open(CFG, capacity=2, seed=SEED)
    sess = fleet.tenant("x")
    sub = sess.subscribe(
        Query.reach(np.asarray([1], np.uint32), np.asarray([2], np.uint32)),
        every=1,
    )
    sess.ingest(*_rand_batch(rng, 16))
    assert sub.ticks == 1
    assert sess._slot in fleet.engine._closures
    sub.cancel()
    assert sess._slot not in fleet.engine._closures
    # session close drops it too
    sess.query(Query.reach(np.asarray([1], np.uint32), np.asarray([2], np.uint32)))
    assert sess._slot in fleet.engine._closures
    slot = sess._slot
    sess.close()
    assert slot not in fleet.engine._closures


def test_session_unsubscribe_invalidates_closure_on_reach_cancel():
    """The single-session twin of the fix: cancelling a reach subscription
    invalidates the GraphStream engine's closure cache."""
    rng = np.random.default_rng(6)
    gs = _open_session()
    sub = gs.subscribe(
        Query.reach(np.asarray([1], np.uint32), np.asarray([2], np.uint32)),
        every=1,
    )
    gs.ingest(*_rand_batch(rng, 16))
    assert gs.engine._closure is not None
    sub.cancel()
    assert gs.engine._closure is None


# ---------------------------------------------------------------------------
# Subscription ticking economics on the fleet
# ---------------------------------------------------------------------------


def test_fleet_subscription_incremental_closure_counts():
    """Additions-only standing reach on one tenant: 1 full build on the
    first tick, incremental refreshes after — same economics as the
    single-session subscription plane."""
    rng = np.random.default_rng(7)
    fleet = SketchFleet.open(CFG, capacity=4, seed=SEED)
    sess = fleet.tenant("t")
    sess.subscribe(
        Query.reach(
            np.arange(4, dtype=np.uint32), np.arange(4, 8, dtype=np.uint32)
        ),
        every=1,
    )
    n_ticks = 4
    for _ in range(n_ticks):
        sess.ingest(*_rand_batch(rng, 8))
    assert fleet.engine.closure_builds == 1
    assert fleet.engine.closure_incremental_refreshes == n_ticks - 1


# ---------------------------------------------------------------------------
# SketchServer fleet mode
# ---------------------------------------------------------------------------


def test_sketch_server_fleet_mode():
    rng = np.random.default_rng(8)
    srv = SketchServer(CFG, seed=SEED, tenants=4)
    src, dst, w = _rand_batch(rng, 128)
    ids = rng.integers(0, 4, 128)
    srv.ingest_mixed(ids, src, dst, w)
    srv.ingest(src[:8], dst[:8], w[:8], tenant=2)
    oracle = _open_session()
    m = ids == 2
    oracle.ingest(src[m], dst[m], w[m])
    oracle.ingest(src[:8], dst[:8], w[:8])
    qs = rng.integers(0, 500, 5).astype(np.uint32)
    np.testing.assert_array_equal(
        srv.in_flow(qs, tenant=2), np.atleast_1d(oracle.query(Query.in_flow(qs)).value)
    )
    # fleet mode demands a tenant; single-session endpoints reject one
    with pytest.raises(ValueError, match="fleet mode"):
        srv.in_flow(qs)
    single = SketchServer(CFG, seed=SEED)
    with pytest.raises(ValueError, match="fleet server"):
        single.in_flow(qs, tenant=0)


def test_fleet_sketch_shares_session_hash_family():
    fleet_state = FleetSketch.empty(CFG, 3, __import__("jax").random.key(SEED))
    gs = _open_session()
    np.testing.assert_array_equal(
        np.asarray(fleet_state.row_hash.a), np.asarray(gs.sketch.row_hash.a)
    )
    np.testing.assert_array_equal(
        np.asarray(fleet_state.row_hash.b), np.asarray(gs.sketch.row_hash.b)
    )
