"""Halo-exchange SWA attention (§Perf iter-4) must equal dense-masked SWA
exactly.  8-device subprocess mesh."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models.layers import gqa_attention, swa_attention_halo

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    B, S, HQ, HKV, DH, WIN = 4, 64, 8, 4, 16, 20
    key = jax.random.key(0)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, S, HQ, DH))
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, S, HKV, DH))
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, S, HKV, DH))

    ref = gqa_attention(q, k, v, causal=True, sliding_window=WIN)

    spec = NamedSharding(mesh, P("data", "model", None, None))
    qs, ks, vs = (jax.device_put(t, spec) for t in (q, k, v))
    out = jax.jit(
        lambda q, k, v: swa_attention_halo(
            q, k, v, sliding_window=WIN, mesh=mesh, q_chunk=8
        )
    )(qs, ks, vs)
    err = float(jnp.max(jnp.abs(out - ref)))
    print("halo vs dense max err:", err)
    assert err < 1e-5

    # gradient path
    g = jax.grad(
        lambda q: swa_attention_halo(
            q, ks, vs, sliding_window=WIN, mesh=mesh, q_chunk=8
        ).sum()
    )(qs)
    assert bool(jnp.all(jnp.isfinite(g)))
    print("ALL_OK")
    """
)


@pytest.mark.slow
def test_halo_swa_matches_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ALL_OK" in proc.stdout, proc.stdout
