"""Property tests for the limb-based pairwise-independent hash family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hashing as H

U32 = st.integers(min_value=0, max_value=2**32 - 1)
PVAL = st.integers(min_value=0, max_value=H.MERSENNE_P - 1)


@settings(max_examples=200, deadline=None)
@given(a=st.integers(1, H.MERSENNE_P - 1), x=U32)
def test_mulmod31_exact(a, x):
    dev = int(H.mulmod31(jnp.uint32(a), jnp.uint32(H._reduce31(jnp.uint32(x)))))
    ref = (a * (x % H.MERSENNE_P)) % H.MERSENNE_P
    assert dev == ref


@settings(max_examples=100, deadline=None)
@given(
    a=st.integers(1, H.MERSENNE_P - 1),
    b=PVAL,
    x=U32,
    w=st.integers(2, 2**20),
)
def test_affine_hash_matches_bigint(a, b, x, w):
    dev = int(H.affine_hash(jnp.uint32(x), jnp.uint32(a), jnp.uint32(b), w))
    ref = ((a * (x % H.MERSENNE_P) + b) % H.MERSENNE_P) % w
    assert dev == ref


def test_affine_hash_batch_matches_numpy():
    rng = np.random.default_rng(0)
    a = rng.integers(1, H.MERSENNE_P, 4096, dtype=np.uint32)
    b = rng.integers(0, H.MERSENNE_P, 4096, dtype=np.uint32)
    x = rng.integers(0, 2**32, 4096, dtype=np.uint32)
    dev = np.asarray(H.affine_hash(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), 12345))
    ref = H.affine_hash_np(x, a, b, 12345)
    np.testing.assert_array_equal(dev, ref)


def test_family_shapes_and_range():
    fam = H.make_hash_family(jax.random.key(0), 5, 777)
    keys = jnp.arange(1000, dtype=jnp.uint32)
    hs = fam(keys)
    assert hs.shape == (5, 1000)
    assert int(hs.min()) >= 0 and int(hs.max()) < 777
    # 2D keys broadcast
    hs2 = fam(keys.reshape(10, 100))
    assert hs2.shape == (5, 10, 100)
    np.testing.assert_array_equal(np.asarray(hs2).reshape(5, -1), np.asarray(hs))


def test_pairwise_collision_rate():
    """Empirical Pr[h(x)=h(y)] for x != y should be ~1/w (2-universality)."""
    w = 256
    fam = H.make_hash_family(jax.random.key(3), 64, w)  # 64 independent fns
    keys = jnp.arange(512, dtype=jnp.uint32)
    hs = np.asarray(fam(keys))  # (64, 512)
    coll = 0
    tot = 0
    rng = np.random.default_rng(0)
    for _ in range(2000):
        i, j = rng.integers(0, 512, 2)
        if i == j:
            continue
        coll += int(np.sum(hs[:, i] == hs[:, j]))
        tot += hs.shape[0]
    rate = coll / tot
    assert rate < 3.0 / w, f"collision rate {rate:.4f} vs 1/w={1/w:.4f}"


def test_sign_hash_balance():
    fam = H.make_hash_family(jax.random.key(9), 8, 1024)
    keys = jnp.arange(4096, dtype=jnp.uint32)
    s = np.asarray(fam.signs(keys))
    assert set(np.unique(s)) <= {-1, 1}
    # Each row should be roughly balanced.
    frac = np.abs(s.mean(axis=1))
    assert np.all(frac < 0.15), frac


def test_mix_keys_spreads():
    x = jnp.arange(10000, dtype=jnp.uint32)
    y = jnp.zeros(10000, dtype=jnp.uint32)
    m = np.asarray(H.mix_keys(x, y))
    assert len(np.unique(m)) == 10000  # injective on this range
    # mixing is order-sensitive (directed edges)
    m2 = np.asarray(H.mix_keys(y, x))
    assert np.sum(m == m2) <= 1


def test_fnv1a_stable():
    assert H.fnv1a_label("192.168.29.1") == H.fnv1a_label("192.168.29.1")
    assert H.fnv1a_label("a") != H.fnv1a_label("b")
    assert H.fnv1a_label(7) == 7
    assert H.fnv1a_label(2**32 + 7) == 7  # uint32 wrap
