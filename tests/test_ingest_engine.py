"""IngestEngine contract tests: one dispatch point, every backend and every
sharding decomposition bit-identical for integer weights (see
repro/core/ingest.py module docstring)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GLavaSketch, SketchConfig
from repro.core.ingest import BACKENDS, IngestEngine, ingest, resolve_backend

CONFIGS = (
    SketchConfig(depth=3, width_rows=64, width_cols=64),    # square (paper)
    SketchConfig(depth=2, width_rows=96, width_cols=40),    # non-square §6.1.2
)


def _stream(n=700, seed=0, max_w=4):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.integers(0, 500, n), jnp.uint32),
        jnp.asarray(rng.integers(0, 500, n), jnp.uint32),
        jnp.asarray(rng.integers(1, max_w, n), jnp.float32),
    )


@pytest.mark.parametrize("cfg", CONFIGS, ids=["square", "nonsquare"])
def test_all_backends_bit_equal(cfg):
    sk = GLavaSketch.empty(cfg, jax.random.key(0))
    src, dst, w = _stream()
    ref = np.asarray(sk.update(src, dst, w, backend="scatter").counters)
    for backend in BACKENDS:
        got = np.asarray(sk.update(src, dst, w, backend=backend).counters)
        np.testing.assert_array_equal(ref, got, err_msg=backend)


@pytest.mark.parametrize("cfg", CONFIGS, ids=["square", "nonsquare"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_row_shard_decomposition_exact(cfg, backend):
    """Concatenating per-shard row-offset ingests == unsharded ingest, for
    every backend — the invariant the distributed psum merge rests on."""
    sk = GLavaSketch.empty(cfg, jax.random.key(1))
    src, dst, w = _stream(seed=1)
    r, c = sk.hash_edges(src, dst)
    ref = np.asarray(ingest(sk.counters, r, c, w, backend="scatter"))
    n_shards = 4
    assert cfg.width_rows % n_shards == 0
    wr_shard = cfg.width_rows // n_shards
    shards = [
        np.asarray(
            ingest(
                jnp.zeros((cfg.depth, wr_shard, cfg.width_cols), jnp.float32),
                r, c, w, backend=backend, row_offset=i * wr_shard,
            )
        )
        for i in range(n_shards)
    ]
    np.testing.assert_array_equal(ref, np.concatenate(shards, axis=1))


def test_engine_resolves_auto(monkeypatch):
    monkeypatch.delenv("REPRO_INGEST_BACKEND", raising=False)
    resolved = resolve_backend("auto")
    assert resolved in BACKENDS
    if jax.default_backend() != "tpu":
        assert resolved == "scatter"
    monkeypatch.setenv("REPRO_INGEST_BACKEND", "onehot")
    assert resolve_backend("auto") == "onehot"
    assert IngestEngine("auto").backend == "onehot"
    with pytest.raises(ValueError):
        resolve_backend("systolic")


_DIST_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import GLavaSketch, SketchConfig
    from repro.core.distributed import distributed_ingest
    from repro.distributed.sharding import sketch_plane_shardings

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    counter_sh, stream_sh = sketch_plane_shardings(mesh)

    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, 500, 256), jnp.uint32)
    dst = jnp.asarray(rng.integers(0, 500, 256), jnp.uint32)
    w = jnp.asarray(rng.integers(1, 4, 256), jnp.float32)

    for wr, wc in ((64, 64), (64, 48)):          # square and non-square
        cfg = SketchConfig(depth=3, width_rows=wr, width_cols=wc)
        sk = GLavaSketch.empty(cfg, jax.random.key(0))
        sk_sharded = dataclasses.replace(
            sk, counters=jax.device_put(sk.counters, counter_sh)
        )
        args = [jax.device_put(a, stream_sh) for a in (src, dst, w)]
        for backend in ("onehot", "scatter"):
            out = distributed_ingest(mesh, sk_sharded, *args, backend=backend)
            ref = sk.update(src, dst, w, backend="scatter")  # local oracle
            np.testing.assert_array_equal(
                np.asarray(out.counters), np.asarray(ref.counters),
                err_msg=f"{wr}x{wc} {backend}",
            )
        print(f"{wr}x{wc} OK")
    print("ALL_OK")
    """
)


@pytest.mark.slow
def test_distributed_matches_local_oracle_square_and_nonsquare():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _DIST_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "ALL_OK" in proc.stdout, proc.stdout
