"""Heavy-tail ingest fast path property tests.

The exactness story: in-batch pre-aggregation collapses duplicate (src, dst)
pairs by SUMMING their signed fp32 weights before the sketch add.  Because
integer-valued fp32 addition below 2**24 is associative, the collapsed batch
lands bit-identically to the per-edge sequential oracle — on counters AND
both flow-register planes, for additions and turnstile deletes alike.  These
tests pin that contract for every layer: the in-jit collapse, the host-side
collapse + marginal registers, the fused one-pass kernel's ref twin, the
GraphStream session boundary, the sliding window, and the touched-row bitmap
handoff into the incremental closure refresh.
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.stream import GraphStream
from repro.core import GLavaSketch, QueryEngine, SketchConfig
from repro.core.ingest import (
    PREAGG_MIN_BATCH,
    bucket_size,
    ingest,
    pad_bucket,
    preaggregate_edges,
    preaggregate_host,
    resolve_preagg,
    touched_row_keys,
)
from repro.core.sketch import scatter_flows
from repro.core.window import SlidingWindowSketch
from repro.kernels.ingest_fused.ref import fused_ingest_ref

RNG = np.random.default_rng(11)


def _sketch(depth=3, wr=128, wc=128, seed=0, directed=True):
    cfg = SketchConfig(
        depth=depth, width_rows=wr, width_cols=wc, directed=directed
    )
    return GLavaSketch.empty(cfg, jax.random.key(seed))


def _dup_heavy(n, n_keys=40, signed=False, seed=1):
    """A duplicate-heavy batch: few distinct endpoints, integer weights."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_keys, n).astype(np.uint32)
    dst = rng.integers(0, n_keys, n).astype(np.uint32)
    lo = -8 if signed else 1
    w = rng.integers(lo, 9, n)
    if signed:
        w[w == 0] = 1
    return src, dst, w.astype(np.float32)


def _assert_sketch_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.counters), np.asarray(b.counters))
    np.testing.assert_array_equal(
        np.asarray(a.row_flows), np.asarray(b.row_flows)
    )
    np.testing.assert_array_equal(
        np.asarray(a.col_flows), np.asarray(b.col_flows)
    )


# ---------------------------------------------------------------------------
# in-jit pre-aggregation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("directed", [True, False])
def test_preagg_bit_identical_duplicate_heavy(directed):
    sk = _sketch(directed=directed)
    src, dst, w = _dup_heavy(3000)
    s, d, ww = jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w)
    on = sk.update(s, d, ww, backend="scatter", preagg="on")
    off = sk.update(s, d, ww, backend="scatter", preagg="off")
    seq = sk.update_sequential(s, d, ww)
    _assert_sketch_equal(on, off)
    np.testing.assert_array_equal(
        np.asarray(on.counters), np.asarray(seq.counters)
    )


def test_preagg_mixed_sign_weights_turnstile():
    """Signed collapse is exact: deletes sum against inserts before the add,
    and the result still lands bit-identically (fp32 ints < 2**24)."""
    sk = _sketch()
    src, dst, w = _dup_heavy(3000, signed=True)
    s, d, ww = jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w)
    on = sk.update(s, d, ww, backend="scatter", preagg="on")
    off = sk.update(s, d, ww, backend="scatter", preagg="off")
    _assert_sketch_equal(on, off)


def test_preagg_empty_after_collapse():
    """Every pair's weights cancel exactly — the collapsed batch is all
    zeros and the sketch must come back bit-identical to the original."""
    sk = _sketch()
    src = np.repeat(np.arange(20, dtype=np.uint32), 2)
    dst = np.repeat(np.arange(100, 120, dtype=np.uint32), 2)
    w = np.tile(np.asarray([5.0, -5.0], np.float32), 20)
    s, d, ww = jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w)
    on = sk.update(s, d, ww, backend="scatter", preagg="on")
    off = sk.update(s, d, ww, backend="scatter", preagg="off")
    _assert_sketch_equal(on, off)
    np.testing.assert_array_equal(
        np.asarray(on.counters), np.asarray(sk.counters)
    )


def test_preagg_fallback_when_low_duplication():
    """All-unique pairs overflow the collapsed buffer (n_seg > out_size), so
    the in-jit cond must fall back to the raw batch — still bit-identical."""
    sk = _sketch()
    n = 2048  # out_size = max(256, n // 4) = 512 < n unique pairs
    src = np.arange(n, dtype=np.uint32)
    dst = np.arange(n, 2 * n, dtype=np.uint32)
    w = np.ones(n, np.float32)
    s, d, ww = jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w)
    on = sk.update(s, d, ww, backend="scatter", preagg="on")
    off = sk.update(s, d, ww, backend="scatter", preagg="off")
    _assert_sketch_equal(on, off)


def test_preaggregate_edges_collapses_exactly():
    src, dst, w = _dup_heavy(1024, n_keys=12, signed=True)
    s_rep, d_rep, w_agg, n_seg = jax.jit(
        lambda s, d, ww: preaggregate_edges(s, d, ww, out_size=256)
    )(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w))
    n = int(n_seg)
    got = {}
    for s_, d_, w_ in zip(
        np.asarray(s_rep)[:n], np.asarray(d_rep)[:n], np.asarray(w_agg)[:n]
    ):
        key = (int(s_), int(d_))
        assert key not in got, "duplicate pair survived the collapse"
        got[key] = float(w_)
    want = {}
    for s_, d_, w_ in zip(src, dst, w):
        want[(int(s_), int(d_))] = want.get((int(s_), int(d_)), 0.0) + float(w_)
    assert got == want
    # padding slots beyond n_seg carry zero weight (inert on add)
    assert not np.asarray(w_agg)[n:].any()


def test_resolve_preagg_gating():
    assert resolve_preagg("on", batch=8)
    assert not resolve_preagg("off", batch=10**6)
    assert not resolve_preagg("auto", batch=PREAGG_MIN_BATCH - 1)
    assert resolve_preagg("auto", batch=PREAGG_MIN_BATCH)


# ---------------------------------------------------------------------------
# host-side collapse + marginal registers (the session fast path's core)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("directed", [True, False])
def test_host_preagg_update_matches_plain(directed):
    sk = _sketch(directed=directed)
    src, dst, w = _dup_heavy(4000, signed=True, seed=3)
    pre = preaggregate_host(src, dst, w)
    assert pre.n_pairs < len(src)
    got = sk.update_preaggregated(
        jnp.asarray(pre.src),
        jnp.asarray(pre.dst),
        jnp.asarray(pre.weights),
        jnp.asarray(pre.src_unique),
        jnp.asarray(pre.src_totals),
        jnp.asarray(pre.dst_unique),
        jnp.asarray(pre.dst_totals),
    )
    want = sk.update(
        jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w),
        backend="scatter", preagg="off",
    )
    _assert_sketch_equal(got, want)


def test_host_preagg_marginals_match_numpy_oracle():
    src, dst, w = _dup_heavy(2000, n_keys=25, signed=True, seed=5)
    pre = preaggregate_host(src, dst, w)
    for uniq, tot, keys in (
        (pre.src_unique, pre.src_totals, src),
        (pre.dst_unique, pre.dst_totals, dst),
    ):
        want_keys, inv = np.unique(keys, return_inverse=True)
        want_tot = np.zeros(len(want_keys), np.float32)
        np.add.at(want_tot, inv, w)
        order = np.argsort(np.asarray(uniq), kind="stable")
        np.testing.assert_array_equal(np.asarray(uniq)[order], want_keys)
        np.testing.assert_array_equal(np.asarray(tot)[order], want_tot)


def test_host_preagg_empty_batch():
    pre = preaggregate_host(
        np.empty(0, np.uint32), np.empty(0, np.uint32), np.empty(0, np.float32)
    )
    assert pre.n_pairs == 0 and pre.src_unique.size == 0


def test_bucket_padding_helpers():
    assert bucket_size(1) == 256 and bucket_size(256) == 256
    assert bucket_size(257) == 512 and bucket_size(5000) == 8192
    x = np.arange(5, dtype=np.float32)
    padded = pad_bucket(x, minimum=8, value=0)
    assert padded.shape == (8,) and not padded[5:].any()
    np.testing.assert_array_equal(padded[:5], x)


# ---------------------------------------------------------------------------
# conservative update: pre-aggregation must NOT apply
# ---------------------------------------------------------------------------


def test_conservative_update_keeps_per_edge_semantics():
    """Conservative update is order-dependent and non-linear, so the collapse
    is ineligible: the API must not grow a preagg knob, and the result must
    compose sequentially (split batch == whole batch), which a duplicate
    collapse would break."""
    assert "preagg" not in inspect.signature(
        GLavaSketch.update_conservative
    ).parameters
    sk = _sketch(depth=2, wr=32, wc=32)
    src, dst, w = _dup_heavy(400, n_keys=10, seed=7)
    s, d, ww = jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w)
    whole = sk.update_conservative(s, d, ww)
    split = sk.update_conservative(s[:200], d[:200], ww[:200])
    split = split.update_conservative(s[200:], d[200:], ww[200:])
    np.testing.assert_array_equal(
        np.asarray(whole.counters), np.asarray(split.counters)
    )


# ---------------------------------------------------------------------------
# fused one-pass kernel ref twin == the three-pass composition (acceptance)
# ---------------------------------------------------------------------------


def test_fused_ref_matches_three_pass_composition():
    sk = _sketch(seed=9)
    src, dst, w = _dup_heavy(1500, n_keys=200, signed=True, seed=9)
    s, d, ww = jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w)
    rows, cols = sk.hash_edges(s, d)
    c1, rf1, cf1, touched = fused_ingest_ref(
        sk.counters, sk.row_flows, sk.col_flows, rows, cols, ww
    )
    c2 = ingest(sk.counters, rows, cols, ww, backend="scatter")
    rf2, cf2 = scatter_flows(sk.row_flows, sk.col_flows, rows, cols, ww)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(rf1), np.asarray(rf2))
    np.testing.assert_array_equal(np.asarray(cf1), np.asarray(cf2))
    # the bitmap marks exactly the row buckets of touched_row_keys
    keys = touched_row_keys(src)
    key_rows = np.asarray(sk.row_hash(jnp.asarray(keys)))  # (d, K)
    want = np.zeros(sk.row_flows.shape, bool)
    for di in range(want.shape[0]):
        want[di, np.unique(key_rows[di])] = True
    np.testing.assert_array_equal(np.asarray(touched), want)


# ---------------------------------------------------------------------------
# GraphStream session boundary
# ---------------------------------------------------------------------------


def _open(ingest_backend="scatter", preagg="auto", **kw):
    cfg = SketchConfig(depth=3, width_rows=128, width_cols=128)
    return GraphStream.open(
        cfg,
        ingest_backend=ingest_backend,
        query_backend="jnp",
        preagg=preagg,
        **kw,
    )


def test_stream_preagg_on_off_bit_identical():
    a, b = _open(preagg="on"), _open(preagg="off")
    for seed in (0, 1):
        src, dst, w = _dup_heavy(4000, seed=seed)
        ra = a.ingest(src, dst, w)
        rb = b.ingest(src, dst, w)
        np.testing.assert_array_equal(
            np.sort(np.asarray(ra.touched_keys)),
            np.sort(np.asarray(rb.touched_keys)),
        )
    a.flush(), b.flush()
    _assert_sketch_equal(a._sketch, b._sketch)


def test_stream_fused_matches_scatter_session():
    a, b = _open(ingest_backend="fused"), _open(ingest_backend="scatter")
    src, dst, w = _dup_heavy(3000, seed=2)
    ra = a.ingest(src, dst, w)
    b.ingest(src, dst, w)
    a.flush(), b.flush()
    _assert_sketch_equal(a._sketch, b._sketch)
    # the fused receipt carries the row bitmap, not a key list
    assert ra.touched_rows is not None
    assert ra.touched_rows.shape == (3, 128) and ra.touched_rows.dtype == bool
    assert ra.touched_keys is None


def test_stream_fused_bitmap_drives_incremental_refresh():
    """Reach answers across plain / preagg / fused sessions agree, after the
    fused session's second tick rode the bitmap incremental refresh."""
    sessions = [
        _open(preagg="off"),
        _open(preagg="on"),
        _open(ingest_backend="fused"),
    ]
    rng = np.random.default_rng(4)
    q_src = rng.integers(0, 30, 16).astype(np.uint32)
    q_dst = rng.integers(0, 30, 16).astype(np.uint32)
    for tick_seed in (10, 11):
        rng2 = np.random.default_rng(tick_seed)
        src = rng2.integers(0, 30, 500).astype(np.uint32)
        dst = rng2.integers(0, 30, 500).astype(np.uint32)
        for gs in sessions:
            gs.ingest(src, dst)
            gs.reachable(q_src, q_dst)  # forces a closure build/refresh
    answers = [np.asarray(gs.reachable(q_src, q_dst)) for gs in sessions]
    np.testing.assert_array_equal(answers[0], answers[1])
    np.testing.assert_array_equal(answers[0], answers[2])
    fused = sessions[2]
    assert fused.engine.closure_refreshes == 1
    assert fused.engine.closure_incremental_refreshes >= 1


def test_stream_deletes_force_full_rebuild():
    gs = _open(ingest_backend="fused")
    src = np.arange(10, dtype=np.uint32)
    dst = np.arange(10, 20, dtype=np.uint32)
    gs.ingest(src, dst)
    gs.reachable(src[:2], dst[:2])
    assert gs.engine.closure_refreshes == 1
    gs.ingest(src, dst, np.full(10, -1.0, np.float32))  # turnstile delete
    gs.reachable(src[:2], dst[:2])
    # closure_refresh is additions-only exact: deletes must poison the cache
    assert gs.engine.closure_refreshes == 2


# ---------------------------------------------------------------------------
# refresh_closure: bitmap path == full rebuild
# ---------------------------------------------------------------------------


def test_refresh_closure_bitmap_matches_full_rebuild():
    sk0 = _sketch(depth=2, wr=64, wc=64, seed=13)
    src1, dst1, _ = _dup_heavy(300, n_keys=20, seed=13)
    sk1 = sk0.update(jnp.asarray(src1), jnp.asarray(dst1))
    # few distinct new sources, so touched rows stay under the frac cap
    # (CLOSURE_REFRESH_FRAC * w_r) and the incremental path actually runs
    src2, dst2, _ = _dup_heavy(120, n_keys=8, seed=14)
    sk2 = sk1.update(jnp.asarray(src2), jnp.asarray(dst2))
    q = jnp.asarray(np.arange(6, dtype=np.uint32))

    fresh = QueryEngine("jnp", pad_q=8)
    want = np.asarray(fresh.reach(sk2, q, q, epoch=1))

    inc = QueryEngine("jnp", pad_q=8)
    inc.reach(sk1, q, q, epoch=0)
    rows = np.asarray(sk1.row_hash(jnp.asarray(np.unique(src2))))
    bitmap = np.zeros(sk1.row_flows.shape, bool)
    for di in range(bitmap.shape[0]):
        bitmap[di, np.unique(rows[di])] = True
    inc.refresh_closure(sk2, bitmap, epoch=1)
    got = np.asarray(inc.reach(sk2, q, q, epoch=1))
    np.testing.assert_array_equal(got, want)
    assert inc.closure_refreshes == 1
    assert inc.closure_incremental_refreshes == 1


# ---------------------------------------------------------------------------
# sliding window
# ---------------------------------------------------------------------------


def test_window_preagg_matches_off():
    cfg = SketchConfig(depth=2, width_rows=64, width_cols=64)
    a = SlidingWindowSketch.empty(cfg, 3, jax.random.key(21))
    b = SlidingWindowSketch.empty(cfg, 3, jax.random.key(21))
    for seed in (30, 31):
        src, dst, w = _dup_heavy(2000, signed=True, seed=seed)
        s, d, ww = jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w)
        a = a.update(s, d, ww, preagg="on")
        b = b.update(s, d, ww, preagg="off")
        a, b = a.advance(), b.advance()
    _assert_sketch_equal(a.window_sketch(), b.window_sketch())
    np.testing.assert_array_equal(np.asarray(a.slices), np.asarray(b.slices))
